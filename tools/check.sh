#!/usr/bin/env bash
# CI gate: tier-1 tests + engine conformance + serving and perf smokes.
#
#   bash tools/check.sh            # from the repo root
#
# 1. tier-1: the full pytest suite (ROADMAP "Tier-1 verify").
# 2. conformance: every registry engine through the shared oracle sweep
#    (tests/test_conformance.py — also part of tier-1; gated explicitly so
#    a narrowed pytest invocation can't silently drop it).
# 3. serve smoke: multi-device (8 fake) end-to-end serve through the
#    sharded range-adaptive hybrid engine, all three distribution modes
#    (structure-sharded, batch-sharded, 2D structure x batch).
# 4. distributed-build conformance gate: the halo-exchange sparse-table
#    build on 8 fake devices — bit-identity with the replicated build plus
#    the per-device allocation probe (tests/test_distributed.py) — then
#    oracle-verified end-to-end through the async serve smoke on a 2D
#    (2x4 struct x qbatch) mesh.
# 5. async-serve smoke: multi-device (8 fake) serve through the async
#    micro-batching subsystem (repro.serve) — concurrent Poisson clients,
#    mixed (medium) ranges, every request verified bit-identical against
#    the numpy oracle (serve.py exits 1 on any mismatch).
# 6. online-update gate: the mutation-conformance sweep (every updatable
#    engine x mutation scenario, patched state bit-identical to a
#    from-scratch rebuild), the >=5x single-point patch-vs-rebuild speedup
#    acceptance bar at n = 2^16 on the CPU baseline, and an oracle-verified
#    mutate-while-serving smoke on 8 fake devices (sharded_hybrid, every
#    request checked against the oracle of its pinned MVCC version).
# 7. chaos gate: the seeded fault-injection soak on 8 fake devices
#    (repro.fault.chaos) — mutate-while-serving through a durable
#    sharded_hybrid engine while the plan kills workers, fails a patch
#    apply, and fails a checkpoint write; every response oracle-verified
#    against its pinned version, then a crash-restore that must be
#    bit-identical to the live engine AND to a from-scratch rebuild —
#    plus the journaling-overhead bar: <= 10% added request p99 with WAL
#    journaling on vs off in the no-fault serve benchmark.
# 8. fleet gate: the replicated-serving soak on 8 fake devices
#    (repro.serve.fleet) — 3 sharded_hybrid replicas on disjoint device
#    groups behind the regime-routing front door, mutate-while-serving
#    under bounded-lag rollouts with a mid-run replica crash + durable
#    restore; exits 1 unless every response is oracle-verified against its
#    version, no request is lost, read-your-writes sessions never see a
#    stale floor, and the observed version lag stays <= the bound.
# 9. autotune gate: a tiny interpret-mode kernel-config sweep against a
#    throwaway cache path — the tuned winner must round-trip through the
#    persistent cache, a second tuned run must perform ZERO timing sweeps
#    (counted at the hybrid._measure seam, the only place a sweep can
#    time), and the policy=None default path must never touch the cache.
# 10. packed gate: the fused (value, index) word layouts (§13) — the
#    packed encoding/engine test file (which includes the 8-fake-device
#    packed mesh conformance subprocess), then the bandwidth bar at
#    n = 2^16: packed32 must move <= 60% of unpacked bytes on both the
#    long-path query and the doubling merge (benchmarks/bandwidth.py
#    derives the counts from the built structures' real leaf dtypes).
# 11. observability gate (§14): the obs test file (tracer semantics, ring
#    overflow, zero-alloc disabled path, Chrome-trace schema, metrics
#    reconciliation), then an async serve smoke on 8 fake devices with
#    --trace — the CLI itself exits 1 unless every served request exports a
#    complete admission->flush->launch->scatter->resolve span chain — the
#    exported JSON re-verified offline (chains + launch attrs survive the
#    Chrome-trace round trip), and the tracing-overhead bar: <= 10% added
#    request p99 with span tracing enabled vs disabled (same best-of-runs
#    interleaved protocol as the journaling bar).
# 12. perf smoke: benchmarks/run.py --only fig12 --smoke (interpret mode on
#    CPU — Pallas kernels validate through the test suite; the smoke catches
#    perf-path regressions like import errors, shape breaks, or a suite that
#    stopped emitting rows).
#
# Perf baseline: BENCH_PR10.json (benchmarks/run.py --json; adds the
# obs_overhead suite and stamps the process metrics registry into _meta);
# refresh per PR.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 tests =="
python -m pytest -x -q

echo "== engine conformance sweep =="
python -m pytest -q tests/test_conformance.py

echo "== sharded-hybrid serve smoke (8 fake devices) =="
XLA_FLAGS=--xla_force_host_platform_device_count=8 timeout 300 \
    python -m repro.launch.serve --engine sharded_hybrid \
    --n 65536 --batch 2048 --batches 2 --block-size 128 --dist medium
XLA_FLAGS=--xla_force_host_platform_device_count=8 timeout 300 \
    python -m repro.launch.serve --engine sharded_hybrid --qshard \
    --n 65536 --batch 2048 --batches 2 --block-size 128 --dist medium

echo "== distributed-build conformance gate (8 fake devices, halo exchange) =="
python -m pytest -q tests/test_distributed.py \
    -k "halo_exchange or calibration_times_sharded"
XLA_FLAGS=--xla_force_host_platform_device_count=8 timeout 600 \
    python -m repro.launch.serve --mode async --engine sharded_hybrid \
    --qshard 2d --n 65536 --block-size 128 --dist medium --clients 4 \
    --requests 12 --rate 300 --req-batch 16 --max-batch 128

echo "== async micro-batching serve smoke (8 fake devices, oracle-verified) =="
XLA_FLAGS=--xla_force_host_platform_device_count=8 timeout 600 \
    python -m repro.launch.serve --mode async --engine sharded_hybrid \
    --n 65536 --block-size 128 --dist medium --clients 4 --requests 12 \
    --rate 300 --req-batch 16 --max-batch 128

echo "== online-update gate (patch bit-identity, 5x speedup bar, mutate-while-serving) =="
python -m pytest -q tests/test_update.py \
    -k "mutation_conformance or sharded_patch or snapshot_isolation"
python - <<'PY'
# Acceptance bar: patching beats a full rebuild by >= 5x for single-point
# updates at n >= 2^16 on the CPU baseline.
import time
import numpy as np
import jax
import jax.numpy as jnp
from repro import update
from repro.core import build as build_mod

n = 1 << 16
x = np.random.default_rng(0).random(n, dtype=np.float32)
online = update.make_online("sparse_table", jnp.asarray(x))
online.apply(update.DeltaLog().point(0, float(x[0])))  # warm the publish path
ts = []
for i in range(5):
    log = update.DeltaLog().point(12345 + i, 0.5)
    t0 = time.perf_counter()
    online.apply(log)
    ts.append(time.perf_counter() - t0)
patch = float(np.median(ts))

def rebuild():
    jax.block_until_ready(
        jax.tree_util.tree_leaves(build_mod.execute(online.plan, jnp.asarray(x)))
    )

rebuild()
ts = []
for _ in range(3):
    t0 = time.perf_counter()
    rebuild()
    ts.append(time.perf_counter() - t0)
reb = float(np.median(ts))
print(f"single-point patch {patch*1e3:.2f} ms vs rebuild {reb*1e3:.2f} ms "
      f"-> {reb/patch:.1f}x (bar: 5x)")
assert reb / patch >= 5.0, f"patch speedup {reb/patch:.1f}x below the 5x bar"
PY
XLA_FLAGS=--xla_force_host_platform_device_count=8 timeout 600 \
    python -m repro.launch.serve --mode async --engine sharded_hybrid \
    --n 65536 --block-size 128 --dist medium --clients 4 --requests 12 \
    --rate 300 --req-batch 16 --max-batch 128 --mutate 6 --adaptive-deadline

echo "== chaos gate (8 fake devices, seeded fault soak + crash-restore) =="
python -m pytest -q tests/test_fault.py \
    -k "restore or torn or poisoned or crash_restart or close_fails"
XLA_FLAGS=--xla_force_host_platform_device_count=8 timeout 600 \
    python -m repro.fault.chaos --engine sharded_hybrid --seed 7 \
    --n 8192 --requests 60 --updates 6 --workers 2
python - <<'PY'
# Acceptance bar: WAL journaling adds <= 10% to request p99 in the no-fault
# serve benchmark (journaling sits on the update path, not the query path).
# Best-of-5 fresh-engine runs per config, configs interleaved, long runs:
# tail latency on a shared CPU is upward-noisy, the minimum converges on
# the true p99.
from benchmarks import fault_overhead
plain, journ = fault_overhead.p99_gate()
over = journ / plain - 1.0
print(f"serve p99: plain {plain*1e3:.2f} ms, journaled {journ*1e3:.2f} ms "
      f"-> {over*100:+.1f}% (bar: +10%)")
assert over <= 0.10, f"journaling p99 overhead {over*100:+.1f}% above the 10% bar"
PY

echo "== fleet gate (8 fake devices, 3 replicas, bounded-lag rollouts + crash-restore) =="
python -m pytest -q tests/test_fleet.py \
    -k "lag_bound or read_your_writes or regime_routing or crash"
XLA_FLAGS=--xla_force_host_platform_device_count=8 timeout 600 \
    python -m repro.serve.fleet --engine sharded_hybrid --replicas 3 \
    --n 4096 --requests 48 --updates 4 --max-lag 2

echo "== autotune gate (tiny sweep, cache round-trip, zero re-timings) =="
python - <<'PY'
# Acceptance bar: the kernel autotuner persists its winner, a warm cache
# performs zero timing sweeps, and the untuned default never touches the
# cache or any machine state.
import tempfile
from pathlib import Path
from repro.core import calib_cache, hybrid
from repro.kernels import tuning

n, batch = 1 << 12, 64
with tempfile.TemporaryDirectory() as td:
    cache = Path(td) / "calibration.json"
    sweeps = []
    orig = hybrid._measure
    hybrid._measure = lambda *a, **k: sweeps.append(a[0]) or orig(*a, **k)
    try:
        won = tuning.get_config(n, batch, policy="tuned", block_size=128,
                                path=cache, interpret=True)
    finally:
        hybrid._measure = orig
    assert sweeps, "tuned policy on a cold cache ran no timing sweeps"
    entry = calib_cache.load_entry(tuning.tuning_key(n, batch), cache)
    assert tuning.config_from_entry(entry) == won, \
        f"winner {won} did not round-trip the cache: {entry}"

    def boom(*a, **k):
        raise AssertionError("timing sweep ran on a warm cache")
    hybrid._measure = boom
    try:
        again = tuning.get_config(n, batch, policy="tuned", block_size=128,
                                  path=cache)
        assert again == won, (again, won)
        # The default path: deterministic, cache-blind, measurement-free.
        assert tuning.get_config(n, batch, policy=None) == tuning.default_config(128)
    finally:
        hybrid._measure = orig
print(f"autotune gate: {len(sweeps)} cold sweeps, winner "
      f"tile={won.tile} fetch={won.fetch} bs={won.block_size} round-tripped, "
      f"warm run re-timed 0 candidates")
PY

echo "== packed gate (fused-word conformance + n=2^16 bandwidth bar) =="
python -m pytest -q tests/test_packing.py
python - <<'PY'
# Acceptance bar: at n = 2^16 with packed32-fitting data, the packed long
# path touches <= 60% of the unpacked bytes per query (>= 1.5x reduction)
# and the packed doubling merge ships <= 60% of the unpacked halo traffic.
from benchmarks.bandwidth import N_GATE, report

r = report(N_GATE)
red = r["unpacked_query_bytes"] / r["packed32_query_bytes"]
print(f"packed gate @ n=2^16: query {r['packed32_query_bytes']}B vs "
      f"{r['unpacked_query_bytes']}B (x{red:.2f}, ratio "
      f"{r['gate_query_ratio']:.2f}), merge ratio {r['gate_merge_ratio']:.2f} "
      f"(bar: <= 0.60 both)")
assert r["packed32_resolved"] == "packed32", r["packed32_resolved"]
assert r["gate_query_ratio"] <= 0.60, r["gate_query_ratio"]
assert r["gate_merge_ratio"] <= 0.60, r["gate_merge_ratio"]
assert red >= 1.5, red
PY

echo "== observability gate (trace chains, metrics reconcile, tracing-overhead bar) =="
python -m pytest -q tests/test_obs.py
tracef=$(mktemp /tmp/rmq-trace-XXXXXX.json)
XLA_FLAGS=--xla_force_host_platform_device_count=8 timeout 600 \
    python -m repro.launch.serve --mode async --engine sharded_hybrid \
    --n 65536 --block-size 128 --dist medium --clients 4 --requests 12 \
    --rate 300 --req-batch 16 --max-batch 128 --trace "$tracef"
python - "$tracef" <<'PY'
# Offline re-verify of the exported document: the span chains and launch
# attrs must survive the Chrome-trace JSON round trip (the in-process check
# already passed or serve.py would have exited 1).
import json, sys
from repro.obs import verify_request_chains

doc = json.load(open(sys.argv[1]))
xs = [e for e in doc["traceEvents"] if e.get("ph") == "X"]
complete, problems = verify_request_chains(doc)
assert complete >= 40 and not problems, (complete, problems[:5])
launch = next(e for e in xs if e["name"] == "launch")
for a in ("engine", "layout", "pool", "padded", "queries"):
    assert a in launch["args"], f"launch span missing {a!r}: {launch['args']}"
assert launch["args"]["engine"] == "sharded_hybrid"
print(f"offline re-verify: {complete} complete chains / {len(xs)} spans, "
      f"launch attrs {sorted(launch['args'])}")
PY
rm -f "$tracef"
python - <<'PY'
# Acceptance bar: span tracing adds <= 10% to request p99 on the threaded
# serve workload (same best-of-runs interleaved protocol as the journaling
# bar; the metrics registry is active in both configs).
from benchmarks import obs_overhead
off, on = obs_overhead.p99_gate()
over = on / off - 1.0
print(f"serve p99: untraced {off*1e3:.2f} ms, traced {on*1e3:.2f} ms "
      f"-> {over*100:+.1f}% (bar: +10%)")
assert over <= 0.10, f"tracing p99 overhead {over*100:+.1f}% above the 10% bar"
PY

echo "== perf smoke (fig12, smoke sizes) =="
out=$(timeout 300 python -m benchmarks.run --only fig12 --smoke)
echo "$out"
rows=$(echo "$out" | grep -c '^fig12/' || true)
if [ "$rows" -lt 4 ]; then
    echo "FAIL: fig12 smoke emitted only $rows rows (expected >= 4)" >&2
    exit 1
fi
echo "OK: tier-1 green, conformance green, distributed-build gate green, serve smokes green, online-update gate green, chaos gate green, fleet gate green, autotune gate green, packed gate green, observability gate green, fig12 smoke emitted $rows rows"
