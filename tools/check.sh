#!/usr/bin/env bash
# CI gate: tier-1 tests + a seconds-long smoke of the perf path.
#
#   bash tools/check.sh            # from the repo root
#
# 1. tier-1: the full pytest suite (ROADMAP "Tier-1 verify").
# 2. perf smoke: benchmarks/run.py --only fig12 --smoke (interpret mode on
#    CPU — Pallas kernels validate through the test suite; the smoke catches
#    perf-path regressions like import errors, shape breaks, or a suite that
#    stopped emitting rows).
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== tier-1 tests =="
python -m pytest -x -q

echo "== perf smoke (fig12, smoke sizes) =="
out=$(timeout 300 python -m benchmarks.run --only fig12 --smoke)
echo "$out"
rows=$(echo "$out" | grep -c '^fig12/' || true)
if [ "$rows" -lt 4 ]; then
    echo "FAIL: fig12 smoke emitted only $rows rows (expected >= 4)" >&2
    exit 1
fi
echo "OK: tier-1 green, fig12 smoke emitted $rows rows"
