"""Regenerate the EXPERIMENTS.md tables from experiments/dryrun/*.json.

    PYTHONPATH=src python tools/make_tables.py
"""

import glob
import json
import os
import re

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DRY = os.path.join(ROOT, "experiments", "dryrun")


def _load(suffix):
    out = {}
    for fn in sorted(glob.glob(os.path.join(DRY, f"*__{suffix}.json"))):
        with open(fn) as f:
            d = json.load(f)
        out[(d["arch"], d["shape"], d["mesh"])] = d
    return out


def _gib(b):
    if not b:
        return "-"
    return f"{float(b)/2**30:.1f}"


def roofline_table(full):
    rows = [
        "| arch | shape | t_compute ms | t_memory ms | t_collective ms | bottleneck | useful | temp GiB/dev | what moves the dominant term |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    advice = {
        ("compute",): "more TP/DP sharding of the dominant matmuls or lower remat recompute",
        ("memory",): "fuse/retire pre-fusion byte hotspots; bf16 intermediates; larger kv-chunks",
        ("collective",): "overlap weight all-gathers under microbatch scan; int8-EF grads; fewer SP boundary reshards",
    }
    for (arch, shape, mesh), d in sorted(full.items()):
        if mesh != "single":
            continue
        rows.append(
            f"| {arch} | {shape} | {d['t_compute']*1e3:.2f} | {d['t_memory']*1e3:.2f} "
            f"| {d['t_collective']*1e3:.2f} | {d['bottleneck']} | {d['useful_ratio']:.2f} "
            f"| {_gib(d.get('temp_bytes_per_dev'))} | {advice[(d['bottleneck'],)]} |"
        )
    return "\n".join(rows)


def dryrun_table(full, compileonly):
    rows = [
        "| arch | shape | mesh | compile | temp GiB/dev | args GiB/dev | collective schedule (kinds) |",
        "|---|---|---|---|---|---|---|",
    ]
    both = dict(full)
    both.update(compileonly)
    for (arch, shape, mesh), d in sorted(both.items()):
        sched = d.get("coll_schedule_scan_artifact", {})
        kinds = ",".join(sorted(sched)) or "-"
        rows.append(
            f"| {arch} | {shape} | {mesh} | OK ({d.get('compile_s','?')}s) "
            f"| {_gib(d.get('temp_bytes_per_dev'))} | {_gib(d.get('arg_bytes_per_dev'))} | {kinds} |"
        )
    return "\n".join(rows)


def main():
    full = _load("full")
    conly = _load("compileonly")
    path = os.path.join(ROOT, "EXPERIMENTS.md")
    with open(path) as f:
        text = f.read()
    text = re.sub(
        r"<!-- DRYRUN_TABLE -->(.*?)(?=\n## |\Z)",
        "<!-- DRYRUN_TABLE -->\n\n" + dryrun_table(full, conly) + "\n\n",
        text,
        flags=re.S,
    )
    text = re.sub(
        r"<!-- ROOFLINE_TABLE -->(.*?)(?=\n## |\Z)",
        "<!-- ROOFLINE_TABLE -->\n\n" + roofline_table(full) + "\n\n",
        text,
        flags=re.S,
    )
    with open(path, "w") as f:
        f.write(text)
    print(f"tables regenerated: {len(full)} full cells, {len(conly)} compile-only cells")


if __name__ == "__main__":
    main()
