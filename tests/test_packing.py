"""Packed (value, index) word encoding + its engine integration (§13).

Covers the ISSUE's packed-structure acceptance surface:

* encoding properties — order isomorphism (word ``min`` == exact leftmost
  argmin), round-trips, extreme keys, duplicate runs, n=1, packed32 misfits;
* quantized bucket collisions — the exact fallback must resolve in-bucket
  ties bit-identically to the unpacked oracle;
* online overflow semantics — a batch the build-time spec cannot encode
  triggers a structural rebuild (never a wrong patch), bit-identical to a
  from-scratch packed build of the mutated array;
* durable round-trips — the concrete ``PackSpec`` survives checkpoint +
  restore, including after an overflow rebuild re-biased the key range;
* cache schema v3 — layout-scoped calibration/tuning slots and the v2
  migration;
* an 8-fake-device subprocess sweep — packed mesh engines bit-identical to
  the single-host oracle, packed halos and patches included.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import block_rmq, calib_cache, packing, sparse_table
from repro.core import build as build_mod
from repro.kernels import tuning
from repro.update.deltas import DeltaLog
from repro.update.engines import make_online


def _oracle(x: np.ndarray, l: np.ndarray, r: np.ndarray):
    idx = np.empty(l.shape, np.int64)
    for k, (a, b) in enumerate(zip(l, r)):
        idx[k] = a + int(np.argmin(x[a : b + 1]))  # argmin = leftmost
    return idx, x[idx]


def _random_ranges(rng, n: int, m: int):
    l = rng.integers(0, n, m)
    r = rng.integers(0, n, m)
    return np.minimum(l, r), np.maximum(l, r)


# --- encoding properties ------------------------------------------------------


@pytest.mark.parametrize("layout", ["packed64", "packed32"])
@pytest.mark.parametrize(
    "data",
    [
        "float_dupes",
        "int_extremes",
        "all_equal",
        "descending",
        "single",
    ],
)
def test_word_min_is_exact_leftmost_argmin(layout, data):
    """min over packed words == the leftmost exact argmin, on adversarial
    key sets: duplicate runs, negative keys, int32 extremes, n=1."""
    rng = np.random.default_rng(3)
    if data == "float_dupes":
        x = rng.choice(np.array([-2.5, -1.0, 0.5, 3.75], np.float32), 257)
    elif data == "int_extremes":
        x = rng.integers(-1000, 1000, 256).astype(np.int32)
        x[17] = -1000
        x[200] = -1000  # duplicated min: leftmost must win
    elif data == "all_equal":
        x = np.full(64, -7.0, np.float32)
    elif data == "descending":
        x = np.arange(100, 0, -1).astype(np.float32)
    else:
        x = np.array([42.0], np.float32)
    n = x.shape[0]
    if layout == "packed32" and x.dtype == np.float32:
        pytest.skip("float keys span the full bitcast range; packed32 is int-range data")
    spec = packing.spec_for(jnp.asarray(x), n, layout)
    words = packing.pack_np(spec, x, np.arange(n, dtype=np.int32))
    for _ in range(50):
        a, b = sorted(rng.integers(0, n, 2))
        w = words[a : b + 1].min()
        want = a + int(np.argmin(x[a : b + 1]))
        assert packing.unpack_idx_np(spec, np.array([w]))[0] == want
        got_v = packing.unpack_val_np(spec, np.array([w]))[0]
        assert got_v == x[want]


def test_int32_min_max_keys_roundtrip():
    """The full int32 key range survives pack/unpack exactly (packed64)."""
    x = np.array([np.iinfo(np.int32).min, 0, np.iinfo(np.int32).max], np.int32)
    spec = packing.spec_for(jnp.asarray(x), 3, "packed64")
    w = packing.pack_np(spec, x, np.arange(3, dtype=np.int32))
    assert list(packing.unpack_val_np(spec, w)) == list(x)
    assert list(packing.unpack_idx_np(spec, w)) == [0, 1, 2]
    assert w[0] == w.min()  # int32 min is the smallest key


def test_pad_word_never_wins():
    """pad_word is the word-domain maximum: a real word always beats it."""
    x = np.array([np.iinfo(np.int32).max], np.int32)
    for layout in ("packed64", "packed32"):
        spec = packing.spec_for(jnp.asarray(x), 128, layout)
        w = packing.pack_np(spec, x, np.zeros(1, np.int32))
        assert w[0] < packing.pad_word(spec)


def test_packed32_misfit_is_loud():
    """A key range packed32 cannot hold raises at spec time (explicit
    layout) and at pack time (post-build out-of-range writes) — never a
    silent wrong encoding."""
    wide = jnp.asarray(np.array([-(2**30), 2**30], np.int32))
    with pytest.raises(ValueError):
        packing.spec_for(wide, 2, "packed32")
    narrow = np.array([5, 9, 7], np.int32)
    spec = packing.spec_for(jnp.asarray(narrow), 3, "packed32")
    with pytest.raises(OverflowError):
        packing.pack_np(
            spec, np.array([np.iinfo(np.int32).max], np.int32), np.zeros(1, np.int32)
        )


def test_spec_for_auto_resolution():
    """auto -> packed32 when the key span fits, else packed64; deterministic."""
    narrow = jnp.asarray(np.arange(100, dtype=np.int32))
    s1 = packing.spec_for(narrow, 100, "auto")
    assert s1.layout == "packed32"
    assert s1 == packing.spec_for(narrow, 100, "auto")
    floats = jnp.asarray(np.random.default_rng(0).standard_normal(100).astype(np.float32))
    assert packing.spec_for(floats, 100, "auto").layout == "packed64"


# --- quantized collisions -----------------------------------------------------


def test_quantized_bucket_collisions_resolve_exactly():
    """Values packed into the SAME bucket (spread far below the bucket
    width) must still answer with the exact leftmost argmin — the fallback
    compares raw values, the bucket only prunes."""
    rng = np.random.default_rng(11)
    n = 1 << 10
    # A wide coarse ramp + per-element jitter far below bucket resolution:
    # many in-bucket collisions, including across block boundaries.
    x = (np.repeat(np.linspace(0, 1000, 8), n // 8) + rng.random(n) * 1e-4).astype(
        np.float32
    )
    s = build_mod.build("hybrid", jnp.asarray(x), packed="quantized", use_kernels=False)
    from repro.core import hybrid

    l, r = _random_ranges(rng, n, 256)
    qi, qv = hybrid.query(s, l, r)
    oi, ov = _oracle(x, l, r)
    np.testing.assert_array_equal(np.asarray(qi), oi)
    np.testing.assert_array_equal(np.asarray(qv), ov)


def test_quantized_value_drift_patches_without_rebuild():
    """Quantized bucket clipping is weakly monotone, so value writes far
    outside the build-time grid still PATCH (never rebuild) and stay exact."""
    rng = np.random.default_rng(5)
    n = 512
    x = rng.random(n, dtype=np.float32)
    online = make_online("hybrid", jnp.asarray(x), packed="quantized")
    log = DeltaLog()
    log.point(37, -1e6)  # far below qmin: clips to bucket 0
    log.point(300, 1e6)  # far above: clips to the top bucket
    res = online.apply(log)
    assert res.patched
    xm = x.copy()
    xm[37], xm[300] = -1e6, 1e6
    l, r = _random_ranges(rng, n, 128)
    qi, qv = online.query(online.store.current.state, l, r)
    oi, ov = _oracle(xm, l, r)
    np.testing.assert_array_equal(np.asarray(qi), oi)
    np.testing.assert_array_equal(np.asarray(qv), ov)


# --- online overflow -> structural rebuild -----------------------------------


def _leaves(tree):
    return [l for l in jax.tree_util.tree_leaves(tree) if isinstance(l, jax.Array)]


def _assert_bit_identical(state, want_state):
    got, want = _leaves(state), _leaves(want_state)
    assert len(got) == len(want)
    for a, b in zip(got, want):
        assert a.dtype == b.dtype and a.shape == b.shape
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_packed32_value_overflow_rebuilds():
    """A write outside the packed32 key range cannot patch in place: under
    ``packed='auto'`` the engine rebuilds with a re-resolved spec (packed64
    here), bit-identical to a from-scratch packed build of the mutated
    array. An *explicit* packed32 request fail-stops instead (below)."""
    rng = np.random.default_rng(7)
    n = 1 << 10
    x = rng.integers(-500, 500, n).astype(np.int32)  # auto -> packed32
    online = make_online("hybrid", jnp.asarray(x), packed="auto")
    log = DeltaLog()
    log.point(n // 3, 10**8)  # far outside the build-time key span
    res = online.apply(log)
    assert not res.patched  # OverflowError -> structural rebuild
    xm = x.copy()
    xm[n // 3] = 10**8
    want = build_mod.build(
        "hybrid",
        jnp.asarray(xm),
        packed="auto",
        threshold=int(online.store.current.state.threshold),
        use_kernels=False,
    )
    _assert_bit_identical(online.store.current.state, want)
    # ... and the rebuilt engine keeps patching incrementally.
    log2 = DeltaLog()
    log2.point(5, -400)
    assert online.apply(log2).patched
    # An EXPLICIT packed32 request cannot silently widen: the rebuild
    # fail-stops loudly instead of changing the asked-for layout.
    strict = make_online("hybrid", jnp.asarray(x), packed="packed32")
    log3 = DeltaLog()
    log3.point(0, 10**8)
    with pytest.raises(ValueError, match="packed32"):
        strict.apply(log3)


def test_packed32_append_past_index_field_rebuilds():
    """Appends that outgrow ``idx_bits`` rebuild; packed64 never does."""
    rng = np.random.default_rng(9)
    n = 100  # idx_bits_for(100) = 7 -> capacity 128
    x = rng.integers(0, 50, n).astype(np.int32)
    online = make_online("hybrid", jnp.asarray(x), packed="packed32")
    log = DeltaLog()
    log.append(rng.integers(0, 50, 40).astype(np.int32))  # n=140 > 2**7
    assert not online.apply(log).patched

    xf = rng.standard_normal(n).astype(np.float32)  # auto -> packed64
    online64 = make_online("hybrid", jnp.asarray(xf), packed="auto")
    log = DeltaLog()
    log.append(rng.standard_normal(40).astype(np.float32))
    assert online64.apply(log).patched  # 32-bit index field: no overflow


# --- durable round-trip -------------------------------------------------------


def test_durable_packed_restore_bit_identical_after_overflow_rebuild(tmp_path):
    """The concrete spec must survive checkpoints: after an overflow rebuild
    re-biased the key range, ``spec_for`` over the restored array would pick
    a different (equally valid) bias — restore must come back bit-identical
    to the live engine, so the snapshot carries the spec itself."""
    from repro.fault.durable import DurableEngine

    rng = np.random.default_rng(13)
    n = 512
    x = rng.integers(-100, 100, n).astype(np.int32)  # auto -> packed32
    eng = DurableEngine.create("packed_hybrid", jnp.asarray(x), str(tmp_path))
    log = DeltaLog()
    log.point(17, 10**7)  # overflow -> rebuild under a wider spec
    assert not eng.apply(log).patched
    eng.checkpoint()
    log2 = DeltaLog()  # a journaled suffix the restore must replay
    log2.point(400, -99)
    assert eng.apply(log2).patched
    eng2 = DurableEngine.restore(str(tmp_path))
    assert eng2.online.current_vid == eng.online.current_vid
    _assert_bit_identical(
        eng2.online.store.current.state, eng.online.store.current.state
    )
    l, r = _random_ranges(rng, n, 64)
    xm = x.copy().astype(np.int64)
    xm[17], xm[400] = 10**7, -99
    qi, qv = eng2.online.query(eng2.online.store.current.state, l, r)
    oi, _ = _oracle(xm, l, r)
    np.testing.assert_array_equal(np.asarray(qi), oi)


# --- cache schema v3 ----------------------------------------------------------


def test_cache_key_v3_layout_suffix():
    base = calib_cache.cache_key(1024, 128, backend="cpu", n_devices=1)
    assert calib_cache.cache_key(
        1024, 128, backend="cpu", n_devices=1, layout="unpacked"
    ) == base  # default layout keeps v2 keys byte-identical
    packed = calib_cache.cache_key(
        1024, 128, backend="cpu", n_devices=1, layout="packed32"
    )
    assert packed == base + "/layout=packed32"


def test_cache_v2_file_migrates_to_v3(tmp_path):
    """A v2 file loads (thresholds intact, kernel entries stamped with the
    unpacked layout) and the next store rewrites it as v3."""
    path = tmp_path / "calib.json"
    thr_key = "n=1024/bs=128/backend=cpu/ndev=1"
    krn_key = "kernel/n=4096/batch=64/backend=cpu/ndev=1"
    path.write_text(
        json.dumps(
            {
                "version": 2,
                "entries": {
                    thr_key: 48,
                    krn_key: {"tile": 8, "fetch": "resident", "block_size": 128},
                },
            }
        )
    )
    assert calib_cache.load_entry(thr_key, path) == 48
    krn = calib_cache.load_entry(krn_key, path)
    assert krn["layout"] == "unpacked"
    cfg = tuning.config_from_entry(krn)
    assert cfg is not None and cfg.layout == "unpacked"
    calib_cache.store_entry(thr_key + "/layout=packed32", 32, path)
    data = json.loads(path.read_text())
    assert data["version"] == calib_cache.CACHE_VERSION
    assert calib_cache.load_entry(thr_key + "/layout=packed32", path) == 32
    assert calib_cache.load_entry(thr_key, path) == 48  # migrated entry kept


def test_tuned_layout_winner_round_trips(tmp_path):
    """A swept winner carrying a packed layout persists and reloads with the
    layout intact (config v3), through the same get_config policy path the
    hybrid build uses."""
    path = tmp_path / "calib.json"
    won = tuning.KernelConfig(tile=16, fetch="resident", block_size=128, layout="packed32")
    key = tuning.tuning_key(4096, 64, backend="cpu", n_devices=1)
    calib_cache.store_entry(key, dict(won._asdict()), path)
    got = tuning.get_config(
        4096, 64, policy="cached", backend="cpu", n_devices=1, path=path
    )
    assert got == won


def test_candidate_configs_layout_feasibility():
    """The swept layout axis excludes what can never run: packed64 has no
    kernel path (int64 words), quantized has no dma strategy (the exact
    fallback needs its resident plane)."""
    cands = tuning.candidate_configs(4096, 128, layouts=tuning.TUNE_LAYOUTS)
    assert any(c.layout == "packed32" for c in cands)
    assert any(c.layout == "quantized" and c.fetch == "resident" for c in cands)
    assert not any(c.layout == "packed64" for c in cands)
    assert not any(c.layout == "quantized" and c.fetch == "dma" for c in cands)


# --- 8-fake-device conformance sweep -----------------------------------------

_CHILD_PACKED_MESH = textwrap.dedent(
    """
    import numpy as np, jax, jax.numpy as jnp
    from repro.core import build as build_mod
    from repro.core import block_rmq, sharded_hybrid
    from repro.launch.mesh import make_mesh
    from repro.update.deltas import DeltaLog
    from repro.update.engines import make_online

    assert len(jax.devices()) == 8
    rng = np.random.default_rng(0)
    n = 1 << 11
    mesh = make_mesh((8,), ("shard",))

    for layout, x in (
        ("packed32", rng.integers(-1000, 1000, n).astype(np.int32)),
        ("packed64", rng.standard_normal(n).astype(np.float32)),
    ):
        xj = jnp.asarray(x)
        oracle = block_rmq.build(xj, 128)
        l = rng.integers(0, n, 256); r = rng.integers(0, n, 256)
        l, r = np.minimum(l, r), np.maximum(l, r)
        oi, ov = block_rmq.query(oracle, jnp.asarray(l), jnp.asarray(r))
        for mode in ("shard_structure", "shard_batch", "shard_2d"):
            s = sharded_hybrid.build(
                xj, mesh, ("shard",), 128, threshold=64, mode=mode, packed=layout
            )
            qi, qv = sharded_hybrid.query(s, l, r)
            assert np.array_equal(np.asarray(qi), np.asarray(oi)), (layout, mode)
            assert np.array_equal(np.asarray(qv), np.asarray(ov)), (layout, mode)

        # Online packed mesh patch: bit-identical to a rebuild of the
        # mutated array (same spec: mutations stay inside the key range).
        eng = make_online(
            "sharded_hybrid", xj, mesh=mesh, axis_names=("shard",),
            threshold=64, packed=layout,
        )
        log = DeltaLog()
        log.point(3, x[5])       # duplicate the min-side value across shards
        log.point(n - 7, x[5])
        res = eng.apply(log)
        assert res.patched, (layout, "expected incremental patch")
        xm = x.copy(); xm[3] = x[5]; xm[n - 7] = x[5]
        plan = build_mod.plan_for(
            "sharded_hybrid", xm.shape[0], mesh=mesh, axis_names=("shard",),
            block_size=128, threshold=64, packed=layout,
        )
        fresh = build_mod.execute(plan, jnp.asarray(xm))
        got = [t for t in jax.tree_util.tree_leaves(eng.store.current.state)
               if isinstance(t, jax.Array)]
        want = [t for t in jax.tree_util.tree_leaves(fresh)
                if isinstance(t, jax.Array)]
        assert len(got) == len(want)
        for a, b in zip(got, want):
            assert a.shape == b.shape and np.array_equal(np.asarray(a), np.asarray(b)), layout
    print("PACKED_MESH_OK")
    """
)


def _run_child(code):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = "src"
    return subprocess.run(
        [sys.executable, "-c", code], env=env, capture_output=True, text=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=420,
    )


def test_packed_mesh_conformance_8_devices():
    """packed32 + packed64 sharded hybrids (all three modes) bit-identical to
    the single-host oracle on an 8-device mesh, and the packed SPMD patch
    bit-identical to a from-scratch packed build of the mutated array."""
    out = _run_child(_CHILD_PACKED_MESH)
    assert "PACKED_MESH_OK" in out.stdout, out.stderr[-3000:]


def test_quantized_rejected_on_mesh():
    from repro.launch.mesh import make_mesh

    mesh = make_mesh((1,), ("shard",))
    x = jnp.asarray(np.random.default_rng(0).standard_normal(256).astype(np.float32))
    with pytest.raises(ValueError, match="single-host"):
        build_mod.build(
            "sharded_hybrid", x, mesh=mesh, axis_names=("shard",), packed="quantized"
        )


# --- bandwidth accounting gate ------------------------------------------------


def test_bandwidth_gate_ratios():
    """The benchmark suite's byte accounting meets the ISSUE bars at a small
    n (the ratios are size-independent; check.sh runs the full n=2**16 gate):
    packed32 moves <= 60% of unpacked bytes on the long-path query AND the
    doubling merge — i.e. >= 1.5x bytes/query reduction."""
    sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    try:
        from benchmarks import bandwidth
    finally:
        sys.path.pop(0)
    rep = bandwidth.report(1 << 12)
    assert rep["packed32_resolved"] == "packed32"
    assert rep["gate_query_ratio"] <= 0.6
    assert rep["gate_merge_ratio"] <= 0.6
    assert rep["unpacked_query_bytes"] / rep["packed32_query_bytes"] >= 1.5
