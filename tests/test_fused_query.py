"""Property sweeps for the fused tiled megakernel (kernels/fused_query.py).

Seeded generator loops (hypothesis-style, no dependency) against
``repro.core.ref``: leftmost-tie stress (constant arrays, repeated minima
spanning block boundaries), degenerate queries (l == r, full range), batch
sizes not divisible by the tile, several tile widths — and both table fetch
strategies (VMEM-resident vs per-query DMA windows) through the single
``fused_query`` entry point.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import block_rmq, ref
from repro.kernels import ops
from repro.kernels.fused_query import fused_query

FETCHES = ["resident", "dma"]


def _fused(x, l, r, bs=128, tile=8, fetch="auto"):
    s = block_rmq.build(jnp.asarray(x), bs)
    idx, val = fused_query(
        s.x_blocks, s.bmin_val, s.bmin_gidx, s.st.idx,
        jnp.asarray(l), jnp.asarray(r), tile=tile, fetch=fetch, interpret=True,
    )
    return np.asarray(idx), np.asarray(val)


def _check(x, l, r, **kw):
    l = np.asarray(l)
    r = np.asarray(r)
    idx, val = _fused(x, l, r, **kw)
    gold = ref.rmq_ref(x, l, r)
    np.testing.assert_array_equal(idx, gold)
    np.testing.assert_allclose(val, np.asarray(x)[gold])


@pytest.mark.parametrize("fetch", FETCHES)
def test_constant_array_prefers_leftmost(fetch):
    """All-equal values: every query must return l (hardest tie case)."""
    n = 700
    rng = np.random.default_rng(0)
    x = np.ones(n, np.float32)
    a = rng.integers(0, n, 57)  # deliberately not a multiple of the tile
    b = rng.integers(0, n, 57)
    l, r = np.minimum(a, b), np.maximum(a, b)
    idx, _ = _fused(x, l, r, fetch=fetch)
    np.testing.assert_array_equal(idx, l)


@pytest.mark.parametrize("fetch", FETCHES)
def test_repeated_minima_spanning_block_boundaries(fetch):
    """A tied global minimum planted in every block, including boundary lanes."""
    bs, nb = 128, 6
    n = bs * nb
    x = np.full(n, 5.0, np.float32)
    # Tie sites: last lane of each block, first lane of the next block.
    sites = []
    for blk in range(nb - 1):
        sites += [blk * bs + bs - 1, (blk + 1) * bs]
    x[np.array(sites)] = -3.0
    rng = np.random.default_rng(1)
    a = rng.integers(0, n, 100)
    b = rng.integers(0, n, 100)
    l, r = np.minimum(a, b), np.maximum(a, b)
    _check(x, l, r, fetch=fetch)


def test_point_and_full_range_queries():
    rng = np.random.default_rng(2)
    n = 1000
    x = rng.integers(0, 9, n).astype(np.float32)
    pts = rng.integers(0, n, 33)
    _check(x, pts, pts)  # l == r
    _check(x, np.zeros(4, np.int64), np.full(4, n - 1))  # full range


@pytest.mark.parametrize("batch", [1, 3, 7, 8, 9, 63])
def test_batch_not_divisible_by_tile(batch):
    """Padded tail queries must not leak into the first `batch` outputs."""
    rng = np.random.default_rng(batch)
    n = 513
    x = rng.integers(-4, 5, n).astype(np.float32)
    a = rng.integers(0, n, batch)
    b = rng.integers(0, n, batch)
    _check(x, np.minimum(a, b), np.maximum(a, b), tile=8)


@pytest.mark.parametrize("fetch", FETCHES)
@pytest.mark.parametrize("tile", [1, 2, 4, 16])
def test_tile_widths(tile, fetch):
    rng = np.random.default_rng(tile)
    n = 2000
    x = rng.integers(0, 6, n).astype(np.float32)
    a = rng.integers(0, n, 40)
    b = rng.integers(0, n, 40)
    _check(x, np.minimum(a, b), np.maximum(a, b), tile=tile, fetch=fetch)


@pytest.mark.parametrize("dtype", [np.float32, np.int32])
def test_property_sweep(dtype):
    """Random arrays with dense ties, random batches, several sizes, both
    fetch strategies bit-identical to the oracle and to each other."""
    rng = np.random.default_rng(42)
    for _ in range(6):
        n = int(rng.integers(1, 1500))
        x = rng.integers(-3, 4, n).astype(dtype)
        q = int(rng.integers(1, 48))
        a = rng.integers(0, n, q)
        b = rng.integers(0, n, q)
        l, r = np.minimum(a, b), np.maximum(a, b)
        _check(x, l, r, fetch="resident")
        _check(x, l, r, fetch="dma")


def test_dma_uses_precomputed_augmented_tables():
    """The FusedRMQ state path: precomputed st_val/st_gidx must give the
    same bits as the derive-on-the-fly path."""
    rng = np.random.default_rng(9)
    n = 4000
    x = rng.integers(-3, 4, n).astype(np.float32)
    a = rng.integers(0, n, 64)
    b = rng.integers(0, n, 64)
    l, r = np.minimum(a, b), np.maximum(a, b)
    s = ops.build(jnp.asarray(x), 128, interpret=True)
    i1, v1 = fused_query(
        s.x_blocks, s.bmin_val, s.bmin_gidx, s.st.idx,
        jnp.asarray(l), jnp.asarray(r),
        st_val=s.st_val, st_gidx=s.st_gidx, fetch="dma", interpret=True,
    )
    i2, v2 = _fused(x, l, r, fetch="dma")
    np.testing.assert_array_equal(np.asarray(i1), i2)
    np.testing.assert_array_equal(np.asarray(v1), v2)
    gold = ref.rmq_ref(x, l, r)
    np.testing.assert_array_equal(np.asarray(i1), gold)


def test_ops_query_routes_through_fused_and_matches_legacy():
    """ops.query (fused) must be bit-identical to the legacy two-pass path."""
    rng = np.random.default_rng(3)
    n = 3000
    x = rng.standard_normal(n).astype(np.float32)
    a = rng.integers(0, n, 90)
    b = rng.integers(0, n, 90)
    l, r = np.minimum(a, b), np.maximum(a, b)
    s = ops.build(jnp.asarray(x), 128, interpret=True)
    i1, v1 = ops.query(s, jnp.asarray(l), jnp.asarray(r), interpret=True)
    i2, v2 = ops.query(s, jnp.asarray(l), jnp.asarray(r), fused=False, interpret=True)
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))
