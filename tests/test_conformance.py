"""Engine-conformance harness: EVERY registry engine through one oracle sweep.

New engines get this coverage by enrollment in ``repro.core.registry`` — no
new test files. Each scenario builds (x, l, r) and every engine must return
exact leftmost-tie argmin indices plus the matching values:

  * duplicate-heavy arrays (leftmost-tie stress),
  * n = 1 and non-power-of-two n,
  * single-element (l == r) and full-array (0, n-1) ranges,
  * all three §6.4 range distributions (small / medium / large),
  * float32 and int32 value dtypes.

Sizes are kept modest so the interpret-mode Pallas engine (``fused128``)
stays seconds-fast off-TPU; the big-n sweeps live in tests/test_rmq_engines.
"""

import zlib

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import ref, registry
from repro.serve.workload import make_queries


def _bounded(rng, n, b):
    a = rng.integers(0, n, b)
    c = rng.integers(0, n, b)
    return np.minimum(a, c), np.maximum(a, c)


def _dup_heavy(rng, n, dtype):
    """Values drawn from 3 levels: nearly every query range has tied minima."""
    return rng.integers(0, 3, n).astype(dtype)


def _scn_dup_heavy(rng):
    n = 512
    return (_dup_heavy(rng, n, np.float32), *_bounded(rng, n, 64))


def _scn_n1(rng):
    return np.array([7.0], np.float32), np.zeros(3, np.int64), np.zeros(3, np.int64)


def _scn_non_pow2_n(rng):
    n = 1057
    return (rng.integers(-9, 9, n).astype(np.float32), *_bounded(rng, n, 48))


def _scn_single_element_ranges(rng):
    n = 700
    pts = rng.integers(0, n, 48)
    return _dup_heavy(rng, n, np.float32), pts.copy(), pts.copy()


def _scn_full_array_ranges(rng):
    n = 513
    b = 8
    return (
        _dup_heavy(rng, n, np.float32),
        np.zeros(b, np.int64),
        np.full(b, n - 1, np.int64),
    )


def _scn_dist(dist):
    def scn(rng):
        n = 1000
        x = rng.integers(0, 9, n).astype(np.float32)
        l, r = make_queries(rng, n, 64, dist)
        return x, l, r

    scn.__name__ = f"_scn_dist_{dist}"
    return scn


def _scn_int32_values(rng):
    n = 800
    return (rng.integers(-50, 50, n).astype(np.int32), *_bounded(rng, n, 64))


SCENARIOS = {
    "dup_heavy_ties": _scn_dup_heavy,
    "n1": _scn_n1,
    "non_pow2_n": _scn_non_pow2_n,
    "single_element_ranges": _scn_single_element_ranges,
    "full_array_ranges": _scn_full_array_ranges,
    "dist_small": _scn_dist("small"),
    "dist_medium": _scn_dist("medium"),
    "dist_large": _scn_dist("large"),
    "int32_values": _scn_int32_values,
}


@pytest.mark.parametrize("scenario", sorted(SCENARIOS))
@pytest.mark.parametrize("engine", registry.names())
def test_engine_conformance(engine, scenario):
    rng = np.random.default_rng(zlib.crc32(scenario.encode()))
    x, l, r = SCENARIOS[scenario](rng)
    gold = ref.rmq_ref(x, l, r)

    eng = registry.get(engine)
    s = eng.build(jnp.asarray(x))
    idx, val = eng.query(s, jnp.asarray(l), jnp.asarray(r))
    idx = np.asarray(idx)
    val = np.asarray(val)

    assert np.issubdtype(idx.dtype, np.integer), (engine, idx.dtype)
    np.testing.assert_array_equal(idx, gold, err_msg=f"{engine}/{scenario}")
    np.testing.assert_array_equal(val, x[gold], err_msg=f"{engine}/{scenario}")


def test_fused_dma_past_resident_ceiling():
    """The DMA fetch strategy must stay bit-identical to the oracle at an nb
    8x past the resident-table VMEM ceiling (the whole point of megakernel
    v2), through the single ``fused_query`` entry point.

    The structure is built with the pure-jnp builder (the Pallas block_min
    kernel's per-block grid would take minutes in interpret mode at this
    size); the query path under test is exactly the megakernel.
    """
    from repro.core import block_rmq
    from repro.kernels import tuning
    from repro.kernels.fused_query import fused_query

    bs = 128
    nb = 8 * tuning.RESIDENT_NB_CEILING  # 2^16 blocks, n = 2^23
    n = nb * bs
    rng = np.random.default_rng(13)
    x = jnp.asarray(rng.integers(0, 5, n).astype(np.float32))  # dense ties
    s = block_rmq.build(x, bs)
    assert s.x_blocks.shape[0] == nb > tuning.RESIDENT_NB_CEILING

    a = rng.integers(0, n, 24)
    b = rng.integers(0, n, 24)
    l, r = np.minimum(a, b), np.maximum(a, b)
    # Ranges that stress the interior tables at this scale, plus the edges.
    l = np.concatenate([l, [0, 0, n - 1, 5]])
    r = np.concatenate([r, [n - 1, bs, n - 1, n - 5]])
    xh = np.asarray(x)
    gold = ref.rmq_ref(xh, l, r)

    # This test builds the bare structure, so the augmented interior tables
    # are intentionally absent: opt into the on-the-fly derivation.
    qi, qv = fused_query(
        s.x_blocks, s.bmin_val, s.bmin_gidx, s.st.idx,
        jnp.asarray(l), jnp.asarray(r), fetch="dma", interpret=True,
        materialize_interior=True,
    )
    np.testing.assert_array_equal(np.asarray(qi), gold)
    np.testing.assert_array_equal(np.asarray(qv), xh[gold])
    # "auto" must resolve to the dma strategy past the ceiling and agree.
    ai, av = fused_query(
        s.x_blocks, s.bmin_val, s.bmin_gidx, s.st.idx,
        jnp.asarray(l), jnp.asarray(r), fetch="auto", interpret=True,
        materialize_interior=True,
    )
    np.testing.assert_array_equal(np.asarray(ai), gold)
    np.testing.assert_array_equal(np.asarray(av), xh[gold])


def test_sharded_hybrid_modes_match_single_device():
    """Both distribution modes agree with the oracle on a 1-device mesh."""
    from repro.core import sharded_hybrid
    from repro.launch.mesh import make_mesh

    rng = np.random.default_rng(5)
    n = 1500
    x = rng.integers(0, 6, n).astype(np.float32)
    l, r = _bounded(rng, n, 100)
    gold = ref.rmq_ref(x, l, r)
    mesh = make_mesh((1,), ("shard",))
    for mode in sharded_hybrid.MODES:
        s = sharded_hybrid.build(jnp.asarray(x), mesh, ("shard",), 128, mode=mode)
        idx, val = sharded_hybrid.query(s, l, r)
        np.testing.assert_array_equal(np.asarray(idx), gold, err_msg=mode)
        np.testing.assert_array_equal(np.asarray(val), x[gold], err_msg=mode)


def test_sharded_hybrid_empty_batch():
    from repro.core import sharded_hybrid

    # Explicit dtype: packed64 builds elsewhere in the suite enable x64,
    # under which a bare arange(256.0) would widen to float64.
    s = sharded_hybrid.build(jnp.arange(256.0, dtype=jnp.float32))
    # A launch on an empty batch would be a phantom kernel: forbid it outright.
    boom = lambda *a: (_ for _ in ()).throw(AssertionError("launched on empty batch"))
    s = s._replace(short_fn=boom, long_fn=boom)
    idx, val = sharded_hybrid.query(s, np.zeros(0, np.int64), np.zeros(0, np.int64))
    assert idx.shape == (0,) and val.shape == (0,)
    assert idx.dtype == jnp.int32 and val.dtype == jnp.float32


def test_sharded_hybrid_rejects_unknown_mode():
    from repro.core import sharded_hybrid

    with pytest.raises(ValueError):
        sharded_hybrid.build(jnp.zeros(16), mode="shard_everything")
