"""Observability tests: span tracer, metrics registry, serve-layer wiring.

Covers the DESIGN.md §14 contract directly: same-thread ambient nesting and
explicit cross-thread parenting, ring-buffer overflow keeping the newest
spans, the disabled tracer allocating nothing on the hot path (tracemalloc
probe), Chrome-trace JSON schema, exact histogram percentiles, and — end to
end through a real threaded ``RMQServer`` — that every served request exports
a complete span chain and that the metrics registry exactly reconciles with
the ``ServeStats`` snapshot rendered from it.
"""

import json
import threading
import tracemalloc

import numpy as np
import pytest

from repro.core import ref
from repro.obs import (
    NULL_TRACER,
    MetricsRegistry,
    Tracer,
    current_span,
    merge_snapshots,
    set_tracer,
    verify_request_chains,
)
from repro.obs import trace as obs_trace
from repro.serve import RMQServer, ServeConfig


@pytest.fixture
def tracer():
    """A fresh enabled tracer installed globally for the test's duration."""
    t = Tracer(enabled=True, capacity=4096)
    prev = set_tracer(t)
    try:
        yield t
    finally:
        set_tracer(prev)


def _oracle_engine(x):
    def qfn(l, r):
        idx = ref.rmq_ref(x, l, r).astype(np.int32)
        return idx, x[idx]

    return qfn


# --- tracer core ------------------------------------------------------------


def test_span_ambient_nesting_same_thread(tracer):
    with tracer.span("outer") as outer:
        assert current_span() is outer
        with tracer.span("inner") as inner:
            assert inner.parent_id == outer.span_id
        assert current_span() is outer
    assert current_span() is None
    names = [s.name for s in tracer.spans()]
    assert names == ["inner", "outer"]  # finish order: innermost first


def test_span_forced_root_and_explicit_parent(tracer):
    with tracer.span("ambient"):
        root = tracer.start("request", parent=0)  # 0 = force a root
        assert root.parent_id is None
        child = tracer.start("queue", parent=root)
        assert child.parent_id == root.span_id
        by_id = tracer.start("resolve", parent=root.span_id)
        assert by_id.parent_id == root.span_id


def test_cross_thread_parenting_is_explicit(tracer):
    """Ambient context never leaks across threads; parent= carries chains."""
    root = tracer.start("flush", parent=0)
    seen = {}

    def worker():
        seen["ambient"] = current_span()  # fresh thread: nothing current
        with tracer.span("launch", parent=root) as sp:
            seen["parent"] = sp.parent_id
            seen["thread"] = sp.thread

    t = threading.Thread(target=worker, name="pool-w9")
    t.start()
    t.join()
    tracer.finish(root)
    assert seen["ambient"] is None
    assert seen["parent"] == root.span_id
    assert seen["thread"] == "pool-w9"


def test_ring_buffer_overflow_keeps_newest():
    t = Tracer(enabled=True, capacity=8)
    for i in range(20):
        t.instant(f"s{i}")
    spans = t.spans()
    assert len(spans) == 8
    assert [s.name for s in spans] == [f"s{i}" for i in range(12, 20)]
    assert t.dropped == 12
    t.clear()
    assert t.spans() == [] and t.dropped == 0


def test_span_ctx_records_error_attr(tracer):
    with pytest.raises(ValueError):
        with tracer.span("launch"):
            raise ValueError("boom")
    (sp,) = tracer.spans()
    assert sp.attrs["error"] == "ValueError"
    assert sp.t1 is not None


def test_set_attr_noop_outside_span(tracer):
    obs_trace.set_attr("k", 1)  # nothing current: must not raise
    with tracer.span("s") as sp:
        obs_trace.set_attr("k", 2)
    assert sp.attrs == {"k": 2}


def test_disabled_tracer_allocates_nothing():
    t = NULL_TRACER
    # Warm every code path once, then assert the steady state is alloc-free.
    with t.span("x"):
        pass
    t.start("x")
    t.instant("x")
    tracemalloc.start()
    try:
        before = tracemalloc.take_snapshot()
        for _ in range(200):
            with t.span("hot"):
                pass
            s = t.start("hot")
            s.set_attr("k", 1)
            t.finish(s)
            t.instant("hot")
        after = tracemalloc.take_snapshot()
    finally:
        tracemalloc.stop()
    growth = sum(
        st.size_diff
        for st in after.compare_to(before, "lineno")
        if st.size_diff > 0 and any("obs/trace" in f.filename for f in st.traceback)
    )
    assert growth == 0, f"disabled tracer allocated {growth} bytes"


def test_chrome_trace_export_schema(tracer, tmp_path):
    with tracer.span("flush", attrs={"reason": "size"}):
        with tracer.span("launch", attrs={"engine": "hybrid", "cfg": object()}):
            pass
    path = tmp_path / "t.json"
    n = tracer.export(str(path))
    assert n == 2
    doc = json.loads(path.read_text())
    assert doc["displayTimeUnit"] == "ms"
    evs = doc["traceEvents"]
    xs = [e for e in evs if e["ph"] == "X"]
    ms = [e for e in evs if e["ph"] == "M"]
    assert {e["name"] for e in xs} == {"flush", "launch"}
    for e in xs:
        assert e["pid"] == 1 and e["cat"] == "repro"
        assert e["ts"] >= 0 and e["dur"] >= 0
        assert isinstance(e["args"]["span_id"], int)
    launch = next(e for e in xs if e["name"] == "launch")
    flush = next(e for e in xs if e["name"] == "flush")
    assert launch["args"]["parent_id"] == flush["args"]["span_id"]
    assert launch["args"]["engine"] == "hybrid"
    assert isinstance(launch["args"]["cfg"], str)  # non-scalar attrs stringified
    assert ms and all(e["args"]["name"] for e in ms)  # thread names labelled


# --- metrics registry -------------------------------------------------------


def test_counter_gauge_identity_and_labels():
    reg = MetricsRegistry()
    a = reg.counter("reqs", outcome="ok")
    b = reg.counter("reqs", outcome="ok")
    c = reg.counter("reqs", outcome="bad")
    assert a is b and a is not c
    a.inc()
    a.inc(2)
    c.inc(5)
    assert a.value == 3 and c.value == 5
    assert reg.counter_total("reqs") == 8
    assert reg.counter_total("reqs", outcome="bad") == 5
    g = reg.gauge("depth")
    g.set(4)
    g.add(-1)
    assert g.value == 3


def test_histogram_exact_percentiles_match_numpy():
    reg = MetricsRegistry()
    h = reg.histogram("lat")
    rng = np.random.default_rng(3)
    vals = rng.random(999) * 0.1
    for v in vals:
        h.observe(float(v))
    assert h.count == 999
    assert h.sum == pytest.approx(float(vals.sum()))
    for q in (50, 95, 99):
        assert h.percentile(q) == pytest.approx(float(np.percentile(vals, q)))
    assert h.percentiles((50, 99)) == pytest.approx(
        [float(np.percentile(vals, 50)), float(np.percentile(vals, 99))]
    )
    # Bucket counts account for every observation (last bucket = +inf).
    snap = reg.snapshot()["histograms"]["lat"][0]
    assert sum(snap["buckets"]["counts"]) == 999
    assert len(snap["buckets"]["counts"]) == len(snap["buckets"]["le"]) + 1


def test_histogram_empty_and_reservoir_bound():
    h = MetricsRegistry().histogram("lat", capacity=64)
    assert h.percentile(99) == 0.0 and h.mean() == 0.0
    for i in range(1000):
        h.observe(i * 1e-3)
    assert h.count == 1000  # count/sum stay exact past capacity
    assert h.sum == pytest.approx(sum(i * 1e-3 for i in range(1000)))
    assert len(h.values()) == 64  # reservoir stays bounded


def test_merge_snapshots_relabels_per_replica():
    regs = {str(i): MetricsRegistry() for i in range(2)}
    regs["0"].counter("reqs").inc(3)
    regs["1"].counter("reqs").inc(4)
    regs["1"].histogram("lat").observe(0.5)
    merged = merge_snapshots({k: r.snapshot() for k, r in regs.items()})
    rows = merged["counters"]["reqs"]
    assert {(r["labels"]["replica"], r["value"]) for r in rows} == {("0", 3.0), ("1", 4.0)}
    assert merged["histograms"]["lat"][0]["labels"]["replica"] == "1"


# --- serve-layer wiring -----------------------------------------------------


def _serve_some(tracer, n=512, reqs=12):
    rng = np.random.default_rng(0)
    x = rng.random(n).astype(np.float32)
    cfg = ServeConfig(deadline_s=0.002, max_batch=256, n=n, workers=2)
    srv = RMQServer(_oracle_engine(x), cfg)
    futs = []
    with srv:
        for i in range(reqs):
            a = rng.integers(0, n, 5)
            b = rng.integers(0, n, 5)
            futs.append(srv.submit(np.minimum(a, b), np.maximum(a, b)))
        for f in futs:
            f.result(timeout=60)
    return srv


def test_server_exports_complete_request_chains(tracer):
    srv = _serve_some(tracer, reqs=12)
    complete, problems = verify_request_chains(tracer.spans())
    assert problems == []
    assert complete == 12
    # The same chains survive a Chrome-trace round trip.
    complete2, problems2 = verify_request_chains(tracer.to_chrome_trace())
    assert (complete2, problems2) == (12, [])
    launches = [s for s in tracer.spans() if s.name == "launch"]
    assert launches and all("engine" in s.attrs and "pool" in s.attrs for s in launches)
    del srv


def test_verify_request_chains_flags_gaps(tracer):
    _serve_some(tracer, reqs=4)
    rows = [
        {"name": s.name, "span_id": s.span_id, "parent_id": s.parent_id, "attrs": dict(s.attrs)}
        for s in tracer.spans()
    ]
    broken = [r for r in rows if r["name"] != "scatter"]
    complete, problems = verify_request_chains(broken)
    assert complete == 0 and problems  # every chain now reports its gap
    assert all("missing" in p for p in problems)


def test_metrics_reconcile_with_servestats(tracer):
    srv = _serve_some(tracer, reqs=16)
    st = srv.stats()
    reg = srv.metrics
    assert (
        reg.counter_total("serve_requests_total", outcome="served")
        == st.served_requests
    )
    assert reg.counter_total("serve_queries_total") == st.served_queries
    assert reg.counter_total("serve_batches_total") == st.n_batches
    assert (
        reg.counter_total("serve_requests_total", outcome="rejected")
        == st.rejected_requests
    )
    assert reg.counter_total("serve_launches_total", pool="primary") >= st.n_batches
    h = reg.histogram("serve_total_s")
    assert h.count == st.served_requests
    assert h.percentile(50) == pytest.approx(st.p50_total_s)
    assert h.percentile(99) == pytest.approx(st.p99_total_s)
    assert reg.histogram("serve_queue_wait_s").percentile(50) == pytest.approx(
        st.p50_queue_s
    )


def test_server_traces_are_off_by_default():
    """No tracer installed -> the server records nothing and allocates no
    span objects (the global is the disabled singleton)."""
    assert obs_trace.get_tracer() is NULL_TRACER or not obs_trace.get_tracer().enabled
    srv = _serve_some(NULL_TRACER, reqs=3)
    assert srv.stats().served_requests == 3


def test_durable_observer_composes_user_trace_and_fault(tracer, tmp_path):
    """DurableEngine._observer stacks all three concerns deterministically:
    the user observer fires first for every stage, the ``patch_applied``
    trace marker lands at the apply_deltas boundary, and the fault site
    fires LAST — so user callback and trace marker both witness a completed
    stage even on an apply that injection kills."""
    import jax.numpy as jnp

    from repro import update as update_mod
    from repro.fault import DurableEngine

    rng = np.random.default_rng(7)
    x = rng.random(256).astype(np.float32)
    events = []

    def fault(site):
        events.append(("fault", site))
        if site == "patch_apply":
            # The trace marker must already be committed when injection runs.
            assert any(s.name == "patch_applied" for s in tracer.spans())

    d = DurableEngine.create(
        "sparse_table", jnp.asarray(x), str(tmp_path / "dur"), fault=fault
    )
    log = update_mod.DeltaLog()
    log.point(3, 0.25)
    d.apply(log, observer=lambda stage, state: events.append(("user", stage)))
    d.close()

    user_stages = [s for kind, s in events if kind == "user"]
    assert "apply_deltas" in user_stages  # user observer saw every stage
    i_user = events.index(("user", "apply_deltas"))
    i_fault = events.index(("fault", "patch_apply"))
    assert i_user < i_fault  # user first, injection last
    names = [s.name for s in tracer.spans()]
    assert "journal_append" in names and "patch_applied" in names
    # No trace, no fault -> the user observer passes through IDENTICALLY.
    set_tracer(None)
    try:
        d2 = DurableEngine(d.online, str(tmp_path / "dur"))
        user = lambda stage, state: None
        assert d2._observer(user) is user
        assert d2._observer(None) is None
    finally:
        set_tracer(tracer)


def test_deadline_trajectory_single_entry_rendering():
    from repro.serve.server import ServeStats

    base = dict(
        served_requests=1, served_queries=1, rejected_requests=0, n_batches=1,
        mean_batch_requests=1.0, mean_batch_queries=1.0, padded_sizes=(1,),
        p50_queue_s=0.0, p99_queue_s=0.0, p50_total_s=0.0, p99_total_s=0.0,
        throughput_qps=1.0,
    )
    one = ServeStats(**base, deadline_trajectory=(0.0015,))
    s = one.summary()
    assert "1.50 ms" in s and "1 adjusted flush" in s  # no 1.50->1.50 arrow
    two = ServeStats(**base, deadline_trajectory=(0.0015, 0.0008))
    assert "->" in two.summary() or "→" in two.summary()
    none = ServeStats(**base, deadline_trajectory=())
    assert "adaptive deadline" not in none.summary()
