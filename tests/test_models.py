"""Per-arch smoke tests (reduced configs): shapes, NaNs, decode consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, SHAPES, cells, get_config, reduce_for_smoke
from repro.models import model

KEY = jax.random.PRNGKey(0)


def _inputs(cfg, params, tokens):
    if cfg.embeds_input:
        return jnp.take(params["embed"], tokens, axis=0)
    return tokens


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    cfg = reduce_for_smoke(get_config(arch))
    params = model.init_params(cfg, KEY)
    b, l = 2, 64
    tokens = jax.random.randint(KEY, (b, l), 0, cfg.vocab_size)
    batch = {"labels": tokens}
    if cfg.embeds_input:
        batch["embeds"] = _inputs(cfg, params, tokens)
    else:
        batch["tokens"] = tokens
    loss, grads = jax.value_and_grad(lambda p: model.train_loss(p, batch, cfg))(params)
    assert np.isfinite(float(loss))
    gn = np.sqrt(sum(float(jnp.sum(jnp.square(g))) for g in jax.tree.leaves(grads)))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_shapes(arch):
    cfg = reduce_for_smoke(get_config(arch))
    params = model.init_params(cfg, KEY)
    b, l = 2, 64
    tokens = jax.random.randint(KEY, (b, l), 0, cfg.vocab_size)
    logits, cache = model.prefill(params, _inputs(cfg, params, tokens), cfg)
    assert logits.shape == (b, 1, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits)).all()
    assert int(cache.length) == l


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_full_forward(arch):
    """Incremental decode == full forward (KV cache / SSM state correctness)."""
    cfg = reduce_for_smoke(get_config(arch))
    params = model.init_params(cfg, KEY)
    b, l, extra = 2, 64, 4
    tokens = jax.random.randint(KEY, (b, l + extra), 0, cfg.vocab_size)
    _, cache = model.prefill(params, _inputs(cfg, params, tokens[:, :l]), cfg)
    lg = None
    for t in range(extra):
        lg, cache = model.decode_step(params, tokens[:, l + t : l + t + 1], cache, cfg)
    full, _ = model.prefill(params, _inputs(cfg, params, tokens), cfg)
    a, bb = np.asarray(lg)[:, 0], np.asarray(full)[:, 0]
    err = np.max(np.abs(a - bb) / (np.abs(bb).max() + 1e-6))
    assert err < 2e-3, err


def test_param_counts_reasonable():
    """Full configs must land near their nameplate sizes."""
    expect = {
        "grok-1-314b": (250e9, 380e9),
        "arctic-480b": (400e9, 560e9),
        "command-r-35b": (30e9, 42e9),
        "granite-3-8b": (6e9, 10e9),
        "qwen2-1.5b": (1.2e9, 2.0e9),
        "gemma3-12b": (9e9, 14e9),
        "mamba2-2.7b": (2.2e9, 3.3e9),
        "zamba2-2.7b": (2.2e9, 3.5e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, (arch, n)


def test_cells_registry():
    all_cells = cells(include_skipped=True)
    assert len(all_cells) == 40  # 10 archs x 4 shapes
    runnable = [c for c in all_cells if not c[2]]
    assert len(runnable) == 33  # long_500k runs only for 3 sub-quadratic archs
    skipped = {(a, s) for a, s, sk in all_cells if sk}
    assert all(s == "long_500k" for _, s in skipped)


def test_moe_capacity_drops_counted():
    from repro.models.moe import moe_ffn

    cfg = reduce_for_smoke(get_config("grok-1-314b"))
    key = jax.random.PRNGKey(1)
    t, d, e, f = 64, 16, 4, 32
    x = jax.random.normal(key, (t, d))
    router = jax.random.normal(key, (d, e))
    wg = jax.random.normal(key, (e, d, f)) * 0.1
    wu = jax.random.normal(key, (e, d, f)) * 0.1
    wd = jax.random.normal(key, (e, f, d)) * 0.1
    out = moe_ffn(x, router, wg, wu, wd, top_k=2, capacity_factor=0.5)
    assert 0.0 < float(out.dropped_frac) < 1.0
    assert np.isfinite(float(out.aux_loss))
    out2 = moe_ffn(x, router, wg, wu, wd, top_k=2, capacity_factor=8.0)
    assert float(out2.dropped_frac) == 0.0


def test_moe_grouping_invariance():
    """Group count changes capacity locality, not drop-free results."""
    from repro.models.moe import moe_ffn

    key = jax.random.PRNGKey(2)
    t, d, e, f = 128, 16, 4, 32
    x = jax.random.normal(key, (t, d))
    router = jax.random.normal(key, (d, e))
    wg = jax.random.normal(key, (e, d, f)) * 0.1
    wu = jax.random.normal(key, (e, d, f)) * 0.1
    wd = jax.random.normal(key, (e, f, d)) * 0.1
    y1 = moe_ffn(x, router, wg, wu, wd, top_k=2, capacity_factor=16.0, num_groups=1)
    y4 = moe_ffn(x, router, wg, wu, wd, top_k=2, capacity_factor=16.0, num_groups=4)
    np.testing.assert_allclose(np.asarray(y1.y), np.asarray(y4.y), atol=1e-5)


def test_flash_attention_matches_naive():
    from repro.models.attention import flash_attention

    key = jax.random.PRNGKey(3)
    b, l, h, kv, hd = 2, 128, 4, 2, 16
    q = jax.random.normal(key, (b, l, h, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, l, kv, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, l, kv, hd))
    out = flash_attention(q, k, v, causal=True, kv_chunk=32)
    # naive reference
    kk = jnp.repeat(k, h // kv, axis=2)
    vv = jnp.repeat(v, h // kv, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, kk) / np.sqrt(hd)
    mask = np.tril(np.ones((l, l), bool))
    s = jnp.where(mask[None, None], s, -1e30)
    ref_out = jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(s, -1), vv)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref_out), atol=2e-5)


def test_sliding_window_mask():
    from repro.models.attention import flash_attention

    key = jax.random.PRNGKey(4)
    b, l, h, hd, w = 1, 64, 2, 8, 8
    q = jax.random.normal(key, (b, l, h, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, l, h, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, l, h, hd))
    out_w = flash_attention(q, k, v, causal=True, window=w, kv_chunk=16)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k) / np.sqrt(hd)
    qi = np.arange(l)
    mask = (qi[:, None] >= qi[None, :]) & (qi[:, None] - qi[None, :] < w)
    s = jnp.where(mask[None, None], s, -1e30)
    ref_out = jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(s, -1), v)
    np.testing.assert_allclose(np.asarray(out_w), np.asarray(ref_out), atol=2e-5)
    # is_global=True must disable the window
    out_g = flash_attention(q, k, v, causal=True, window=w, is_global=True, kv_chunk=16)
    out_full = flash_attention(q, k, v, causal=True, kv_chunk=16)
    np.testing.assert_allclose(np.asarray(out_g), np.asarray(out_full), atol=1e-6)


def test_ssd_chunked_matches_sequential():
    """Chunked SSD == token-by-token recurrence."""
    import dataclasses

    from repro.models import ssm as ssm_lib

    cfg = reduce_for_smoke(get_config("mamba2-2.7b"))
    params = model.init_params(cfg, KEY)
    lp = jax.tree.map(lambda a: a[0], params["layers"])
    p = {k: v for k, v in lp.items() if k != "ln1"}
    b, l = 1, 64
    u = jax.random.normal(KEY, (b, l, cfg.d_model)) * 0.5
    y_chunk, st = ssm_lib.ssm_forward(p, u, cfg, return_state=True)
    # sequential decode over the same tokens
    dims = ssm_lib.ssm_dims(cfg.d_model, cfg.ssm_expand, cfg.ssm_headdim, cfg.ssm_state, cfg.ssm_conv)
    state = ssm_lib.SSMState(
        conv=jnp.zeros((b, dims["conv_k"] - 1, dims["conv_dim"])),
        ssd=jnp.zeros((b, dims["nheads"], dims["headdim"], dims["state"])),
    )
    outs = []
    for t in range(l):
        o, state = ssm_lib.ssm_decode_step(p, u[:, t], state, cfg)
        outs.append(o)
    y_seq = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_seq), atol=3e-4)
    np.testing.assert_allclose(np.asarray(st.ssd), np.asarray(state.ssd), atol=3e-4)
