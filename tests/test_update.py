"""Online-update subsystem tests: mutation conformance, MVCC, serving.

The mutation-conformance sweep runs EVERY ``updatable`` registry engine
through every mutation scenario (point write, range write, append,
write-at-boundary, leftmost-tie flip, n=1, interleaved query/update); after
each applied batch the engine must answer queries bit-identically to the
numpy oracle re-evaluated on the mutated array, AND its patched structure
leaves must be bit-identical to a from-scratch rebuild of the mutated array
(the acceptance criterion). Multi-shard patching (real shard boundaries,
halo windows, capacity-overflow rebuild) runs in an 8-fake-device
subprocess, same pattern as tests/test_distributed.py.
"""

import os
import subprocess
import sys
import textwrap
import threading
import time

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import update
from repro.core import build as build_mod
from repro.core import ref, registry
from repro.serve import RMQServer, ServeConfig


def _bounded(rng, n, b):
    a = rng.integers(0, n, b)
    c = rng.integers(0, n, b)
    return np.minimum(a, c), np.maximum(a, c)


def _array_leaves(state):
    return [a for a in jax.tree_util.tree_leaves(state) if isinstance(a, jax.Array)]


def _rebuild_reference(name, x_np, online):
    """A from-scratch build of the mutated array with the SAME plan params
    the online engine resolved (threshold pinned for the hybrids, since a
    rebuild at the new length would re-derive sqrt(n))."""
    xj = jnp.asarray(x_np)
    n = x_np.shape[0]
    if name == "hybrid":
        thr = int(online.store.current.state.threshold)
        plan = build_mod.plan_for(
            "hybrid", n, block_size=128, threshold=thr, use_kernels=False
        )
        return build_mod.execute(plan, xj)
    if name == "sharded_hybrid":
        thr = int(online.store.current.state.threshold)
        plan = build_mod.plan_for(
            "sharded_hybrid", n, block_size=128, threshold=thr,
            mode=online.plan.meta["mode"],
        )
        return build_mod.execute(plan, xj)
    return registry.get(name).build(xj)


# --- mutation-conformance sweep ---------------------------------------------
# Each scenario: (initial array, list of DeltaLogs applied in sequence).


def _scn_point_write(rng):
    x = rng.integers(0, 4, 700).astype(np.float32)  # tie-heavy
    return x, [update.DeltaLog().point(123, -3.0), update.DeltaLog().point(123, 2.0)]


def _scn_range_write(rng):
    x = rng.integers(0, 4, 700).astype(np.float32)
    return x, [
        update.DeltaLog().fill(200, 460, 0.25),
        update.DeltaLog().write(10, rng.random(50).astype(np.float32)),
    ]


def _scn_append(rng):
    x = rng.integers(0, 4, 700).astype(np.float32)
    return x, [
        update.DeltaLog().append(rng.integers(0, 4, 150).astype(np.float32)),
        # Append then immediately write into the appended region (coalesces).
        update.DeltaLog()
        .append(rng.integers(0, 4, 90).astype(np.float32))
        .point(850 + 40, -1.0),
    ]


def _scn_boundary_write(rng):
    """Writes at block boundaries (bs 128/256) — partial-block repair edges."""
    x = rng.integers(0, 4, 1024).astype(np.float32)
    return x, [
        update.DeltaLog().point(127, -5.0).point(128, -5.0),
        update.DeltaLog().point(255, -6.0).point(256, -6.0).point(1023, -7.0),
    ]


def _scn_tie_flip(rng):
    """The global min moves LEFT via an equal write: leftmost-tie discipline
    must flip the argmin to the new, earlier copy — and back when it leaves."""
    x = np.ones(700, np.float32)
    x[400] = -2.0
    return x, [
        update.DeltaLog().point(100, -2.0),  # equal min appears to the left
        update.DeltaLog().point(100, 5.0),  # and disappears again
    ]


def _scn_n1(rng):
    return np.array([7.0], np.float32), [
        update.DeltaLog().point(0, -1.0),
        update.DeltaLog().append(np.array([3.0, 4.0, -9.0], np.float32)),
        update.DeltaLog().point(2, 8.0),
    ]


SCENARIOS = {
    "point_write": _scn_point_write,
    "range_write": _scn_range_write,
    "append": _scn_append,
    "boundary_write": _scn_boundary_write,
    "tie_flip": _scn_tie_flip,
    "n1": _scn_n1,
}


@pytest.mark.parametrize("scenario", sorted(SCENARIOS))
@pytest.mark.parametrize("engine", registry.updatable_names())
def test_mutation_conformance(engine, scenario):
    rng = np.random.default_rng(hash(scenario) % (2**32))
    x, logs = SCENARIOS[scenario](rng)
    kw = {"threshold": 48} if engine in ("hybrid", "sharded_hybrid") else {}
    online = update.make_online(engine, jnp.asarray(x), **kw)
    xm = x.copy()
    for i, log in enumerate(logs):
        res = online.apply(log)
        xm = log.coalesce(xm.shape[0], xm.dtype).apply_numpy(xm)
        assert res.version == i + 1 and res.n == xm.shape[0] and online.n == res.n
        n = xm.shape[0]
        # Interleaved query after every mutation: random + targeted bounds.
        l, r = _bounded(rng, n, 64)
        l = np.concatenate([l, [0, 0, n - 1]])
        r = np.concatenate([r, [n - 1, 0, n - 1]])
        ver = online.pin()
        idx, val = online.query(ver.state, jnp.asarray(l), jnp.asarray(r))
        online.release(ver.vid)
        gold = ref.rmq_ref(xm, l, r)
        np.testing.assert_array_equal(np.asarray(idx), gold, err_msg=f"{engine}/{scenario}/{i}")
        np.testing.assert_array_equal(np.asarray(val), xm[gold], err_msg=f"{engine}/{scenario}/{i}")
    # Acceptance criterion: the patched state is bit-identical, leaf for
    # leaf, to a from-scratch rebuild of the mutated array.
    fresh = _rebuild_reference(engine, xm, online)
    got = _array_leaves(online.store.current.state)
    want = _array_leaves(fresh)
    assert len(got) == len(want)
    for a, b in zip(want, got):
        assert a.shape == b.shape and a.dtype == b.dtype, (engine, scenario)
        np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b), err_msg=f"{engine}/{scenario} leaf"
        )


def test_sharded_hybrid_shard_batch_mode_patches_replicated_mirrors():
    """The struct_axes-empty online branch (host mirrors + re-replication):
    oracle conformance after writes AND appends, plus bit-identity vs a
    from-scratch shard_batch build."""
    rng = np.random.default_rng(12)
    x = rng.integers(0, 4, 900).astype(np.float32)
    online = update.make_online(
        "sharded_hybrid", jnp.asarray(x), mode="shard_batch", threshold=48
    )
    xm = x.copy()
    for log in (
        update.DeltaLog().point(127, -4.0).fill(400, 600, 0.5),
        update.DeltaLog().append(rng.integers(0, 4, 200).astype(np.float32)),
    ):
        res = online.apply(log)
        assert res.patched  # replicated mirrors never need the rebuild path
        xm = log.coalesce(xm.shape[0], xm.dtype).apply_numpy(xm)
        l, r = _bounded(rng, xm.shape[0], 80)
        ver = online.pin()
        idx, val = online.query(ver.state, jnp.asarray(l), jnp.asarray(r))
        online.release(ver.vid)
        gold = ref.rmq_ref(xm, l, r)
        np.testing.assert_array_equal(np.asarray(idx), gold)
        np.testing.assert_array_equal(np.asarray(val), xm[gold])
    assert online.store.current.state.n == xm.shape[0]
    fresh = _rebuild_reference("sharded_hybrid", xm, online)
    for a, b in zip(_array_leaves(fresh), _array_leaves(online.store.current.state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_apply_validates_batches_before_touching_mirrors():
    """Malformed raw batches are rejected with the engine fully usable."""
    online = update.make_online("sparse_table", jnp.arange(64.0))
    good = update.DeltaLog().point(1, -1.0).coalesce(64)
    bad = good._replace(idx=np.array([64], np.int64))  # out of range
    with pytest.raises(ValueError):
        online.apply(bad)
    res = online.apply(good)  # NOT fail-stopped: nothing was mutated
    assert res.version == 1
    ver = online.pin()
    idx, _ = online.query(ver.state, jnp.asarray([0]), jnp.asarray([63]))
    online.release(ver.vid)
    assert int(idx[0]) == 1


def test_mid_patch_failure_fail_stops_but_queries_keep_serving(monkeypatch):
    """An exception inside the patch marks the engine failed (later applies
    raise, pointing at the original error) instead of silently publishing a
    diverged version; published versions still answer queries."""
    online = update.make_online("sparse_table", jnp.arange(32.0))
    online.apply(update.DeltaLog().point(3, -5.0))
    boom = online._impl._replace(
        patch=lambda batch, prev: (_ for _ in ()).throw(RuntimeError("device lost"))
    )
    monkeypatch.setattr(online, "_impl", boom)
    with pytest.raises(RuntimeError, match="device lost"):
        online.apply(update.DeltaLog().point(4, -9.0))
    with pytest.raises(RuntimeError, match="fail-stopped"):
        online.apply(update.DeltaLog().point(5, -9.0))
    assert online.current_vid == 1  # nothing published after the failure
    ver = online.pin()
    idx, _ = online.query(ver.state, jnp.asarray([0]), jnp.asarray([31]))
    online.release(ver.vid)
    assert int(idx[0]) == 3


def test_update_result_reports_touched_shards():
    online = update.make_online("sparse_table", jnp.arange(128.0))
    res = online.apply(update.DeltaLog().point(5, -1.0))
    assert res.touched_shards == 1  # single-host layout: one shard
    # The accounting helper itself distinguishes locality.
    wide = update.DeltaLog().point(1, 0.0).point(100, 0.0).coalesce(128)
    assert len(update.shard_batches(wide, 4, 32)) == 2


def test_registry_updatable_matches_online_implementations():
    assert set(registry.updatable_names()) == set(update.online_names())
    for name in registry.updatable_names():
        assert registry.get(name).serveable  # updatable implies serveable


def test_non_updatable_engine_rejected():
    with pytest.raises(ValueError):
        update.make_online("lane", jnp.arange(16.0))


# --- delta log --------------------------------------------------------------


def test_delta_log_coalesce_last_write_wins():
    log = update.DeltaLog().point(3, 1.0).fill(2, 5, 7.0).point(3, 9.0)
    b = log.coalesce(10)
    np.testing.assert_array_equal(b.idx, [2, 3, 4, 5])
    np.testing.assert_array_equal(b.val, [7.0, 9.0, 7.0, 7.0])
    assert b.tail.size == 0 and b.n_old == 10 and b.n_new == 10
    xm = b.apply_numpy(np.zeros(10, np.float32))
    np.testing.assert_array_equal(xm[2:6], [7, 9, 7, 7])


def test_delta_log_append_then_write_folds_into_tail():
    log = update.DeltaLog().append([1.0, 2.0, 3.0]).point(11, 8.0).fill(9, 10, 4.0)
    b = log.coalesce(10)
    assert b.n_new == 13 and b.n_old == 10
    np.testing.assert_array_equal(b.idx, [9])  # the in-prefix part of the fill
    np.testing.assert_array_equal(b.val, [4.0])
    np.testing.assert_array_equal(b.tail, [4.0, 8.0, 3.0])  # writes folded in
    np.testing.assert_array_equal(b.touched(), [9, 10, 11, 12])


def test_delta_log_rejects_out_of_range_and_empty():
    with pytest.raises(ValueError):
        update.DeltaLog().point(10, 1.0).coalesce(10)  # past the end
    with pytest.raises(ValueError):
        update.DeltaLog().fill(8, 12, 1.0).coalesce(10)  # straddles the end
    with pytest.raises(ValueError):
        update.DeltaLog().coalesce(10)  # empty log
    with pytest.raises(ValueError):
        update.DeltaLog().point(-1, 0.0)
    with pytest.raises(ValueError):
        update.DeltaLog().append(np.zeros(0))
    # Appends extend the writable range in arrival order.
    update.DeltaLog().append([1.0, 2.0]).point(11, 5.0).coalesce(10)


def test_shard_batches_groups_by_owner():
    b = update.DeltaLog().point(1, 1.0).point(130, 2.0).point(131, 3.0).coalesce(512)
    per = update.shard_batches(b, num_shards=4, shard_len=128)
    assert [(s, list(p)) for s, p, _ in per] == [(0, [1]), (1, [130, 131])]
    np.testing.assert_array_equal(per[1][2], [2.0, 3.0])


# --- patch kernels (host mirrors) -------------------------------------------


def test_level_windows_merge_and_clip():
    assert update.level_windows(np.array([5]), 3, 100) == [(2, 5)]
    assert update.level_windows(np.array([1, 5, 50]), 3, 100) == [(0, 5), (47, 50)]
    assert update.level_windows(np.array([0]), 7, 100) == [(0, 0)]


def test_patch_doubling_matches_build_for_scattered_writes():
    from repro.core import sparse_table

    rng = np.random.default_rng(3)
    x = rng.random(257).astype(np.float32)
    idx = np.array(np.asarray(sparse_table.build(jnp.asarray(x)).idx))
    x[7] = -1.0
    x[200] = -1.0  # tied pair, far apart: two windows per level
    out = update.patch_doubling(idx, x, np.array([7, 200]), 257)
    want = np.asarray(sparse_table.build(jnp.asarray(x)).idx)
    np.testing.assert_array_equal(out, want)


def test_patch_doubling_append_grows_levels():
    from repro.core import sparse_table

    x = np.arange(4, 0, -1).astype(np.float32)  # n=4: K=3
    idx = np.array(np.asarray(sparse_table.build(jnp.asarray(x)).idx))
    x2 = np.concatenate([x, np.array([-5.0, 9.0], np.float32)])  # n=6: K=4
    out = update.patch_doubling(idx, x2, np.array([4, 5]), 4)
    want = np.asarray(sparse_table.build(jnp.asarray(x2)).idx)
    assert out.shape == want.shape == (4, 6)
    np.testing.assert_array_equal(out, want)


# --- MVCC version store ------------------------------------------------------


def test_version_store_pin_publish_retire():
    store = update.VersionStore()
    store.publish("v0-state", 10)
    v0 = store.pin()
    assert (v0.vid, v0.state, v0.n) == (0, "v0-state", 10)
    assert store.publish("v1-state", 11) == 1
    assert store.live_vids() == (0, 1)  # v0 still pinned
    assert store.current.state == "v1-state"
    store.release(0)
    assert store.live_vids() == (1,)  # drained -> retired
    with pytest.raises(ValueError):
        store.release(0)  # double release


def test_version_store_retires_unpinned_superseded_immediately():
    store = update.VersionStore()
    store.publish("a", 1)
    store.publish("b", 1)
    assert store.live_vids() == (1,)


def test_version_store_errors_before_first_publish():
    store = update.VersionStore()
    with pytest.raises(RuntimeError):
        store.pin()


# --- update plan stages -------------------------------------------------------


def test_update_lowered_through_apply_deltas_and_publish_stages():
    online = update.make_online("sparse_table", jnp.arange(64.0))
    seen = []
    res = online.apply(
        update.DeltaLog().point(5, -1.0),
        observer=lambda stage, state: seen.append(stage),
    )
    assert seen == ["apply_deltas", "publish"]
    assert res.patched and res.n_writes == 1 and res.n_appended == 0
    assert [build_mod.STAGE_NAMES.index(s) for s in seen] == sorted(
        build_mod.STAGE_NAMES.index(s) for s in seen
    )


def test_apply_rejects_stale_batch():
    online = update.make_online("sparse_table", jnp.arange(32.0))
    stale = update.DeltaLog().point(1, 0.5).coalesce(31)  # wrong length
    with pytest.raises(ValueError):
        online.apply(stale)


# --- serving: snapshot isolation, interleaving, stats ------------------------


def test_snapshot_isolation_inflight_query_sees_pinned_version():
    """A query flushed (pinned) before an update publishes must be answered
    against its snapshot even though the engine executes it afterwards."""
    x = np.arange(64, 0, -1).astype(np.float32)  # argmin = 63
    online = update.make_online("sparse_table", jnp.asarray(x))
    gate = threading.Event()
    real_query = online.query

    def gated(state, l, r):
        gate.wait(30)
        return real_query(state, l, r)

    online.query = gated
    srv = RMQServer(online=online, config=ServeConfig(deadline_s=0.0, n=64)).start()
    try:
        fut = srv.submit(np.array([0], np.int32), np.array([63], np.int32))
        deadline = time.time() + 10  # wait for the flush to pin version 0
        while not online.store._pins and time.time() < deadline:
            time.sleep(0.005)
        assert online.store._pins, "batch never pinned a version"
        # Publish version 1 while the query is in flight (new global min).
        online.apply(update.DeltaLog().point(5, -100.0))
        assert online.current_vid == 1
        gate.set()
        res = fut.result(timeout=30)
        assert res.version == 0
        assert res.idx[0] == 63 and res.val[0] == 1.0  # the OLD argmin
        # A fresh query sees the new version.
        res2 = srv.submit(np.array([0], np.int32), np.array([63], np.int32)).result(timeout=30)
        assert res2.version == 1 and res2.idx[0] == 5
    finally:
        gate.set()
        srv.close()
    st = srv.stats()
    assert st.version_lags == (1, 0) and st.version_lag_max == 1
    assert online.store.live_vids() == (1,)  # v0 drained and retired


def test_server_interleaves_updates_with_queries():
    """submit_update is a batcher barrier: pre-update requests answer against
    the pre-update version, post-update requests see the published one."""
    x = np.ones(128, np.float32)
    online = update.make_online("hybrid", jnp.asarray(x), threshold=16)
    with RMQServer(online=online, config=ServeConfig(deadline_s=0.2, max_batch=64)) as srv:
        one = np.array([0], np.int32)
        last = np.array([127], np.int32)
        f1 = srv.submit(one, last)  # coalescing: pending when the update lands
        log = update.DeltaLog().point(64, -3.0)
        uf = srv.submit_update(log)
        ures = uf.result(timeout=30)
        f2 = srv.submit(one, last)
        r1 = f1.result(timeout=30)
        r2 = f2.result(timeout=30)
    assert ures.version == 1 and ures.patched and ures.n_writes == 1
    assert r1.version == 0 and r1.idx[0] == 0  # pre-update snapshot
    assert r2.version == 1 and r2.idx[0] == 64  # sees the write
    st = srv.stats()
    assert st.applied_updates == 1
    assert st.p99_update_s >= st.p50_update_s > 0


def test_submit_update_requires_online_engine():
    srv = RMQServer(lambda l, r: (l, l.astype(np.float32)), ServeConfig(n=8)).start()
    try:
        with pytest.raises(ValueError):
            srv.submit_update(update.DeltaLog().point(0, 1.0))
    finally:
        srv.close()


def test_online_server_validates_against_current_length():
    online = update.make_online("sparse_table", jnp.arange(16.0))
    with RMQServer(online=online, config=ServeConfig(deadline_s=0.0)) as srv:
        with pytest.raises(ValueError):
            srv.submit(np.array([0], np.int32), np.array([16], np.int32))
        srv.submit_update(update.DeltaLog().append(np.arange(4.0))).result(timeout=30)
        res = srv.submit(np.array([0], np.int32), np.array([19], np.int32)).result(timeout=30)
        assert res.idx[0] == 0


# --- multi-shard patching (8 fake devices, subprocess) ------------------------

_CHILD_SHARDED = textwrap.dedent(
    """
    import numpy as np, jax, jax.numpy as jnp
    from repro import update
    from repro.core import build as build_mod
    from repro.core import distributed, ref
    from repro.launch.mesh import make_mesh

    mesh = make_mesh((2, 4), ("data", "model"))
    axes = ("data", "model")
    rng = np.random.default_rng(7)
    n = 4096  # 8 shards x 512 cols
    x = rng.integers(0, 4, n).astype(np.float32)

    def leaves(s):
        return [a for a in jax.tree_util.tree_leaves(s)
                if isinstance(a, jax.Array)]

    for name, kw in [("distributed", {}),
                     ("sharded_hybrid", {"mode": "shard_structure"}),
                     ("sharded_hybrid", {"mode": "shard_2d"})]:
        eng = update.make_online(name, jnp.asarray(x), mesh=mesh,
                                 axis_names=axes, **kw)
        xm = x.copy()
        logs = [
            # leftmost tie straddling a real shard boundary (cols 512*2)
            update.DeltaLog().point(1023, -7.0).point(1024, -7.0),
            # range write spanning three shards: halo windows cross shards
            update.DeltaLog().fill(500, 1600, 0.25),
            # append inside the padded capacity = writes at pad columns
            update.DeltaLog().append(rng.integers(0, 4, 50).astype(np.float32)),
            # grow past capacity: structural rebuild fallback
            update.DeltaLog().append(rng.integers(0, 4, 9000).astype(np.float32)),
        ]
        expect_patch = [True, True, None, False]  # None: depends on padding
        for log, want in zip(logs, expect_patch):
            res = eng.apply(log)
            xm = log.coalesce(xm.shape[0], xm.dtype).apply_numpy(xm)
            if want is not None:
                assert res.patched is want, (name, kw, res)
            l, r = rng.integers(0, xm.shape[0], 300), rng.integers(0, xm.shape[0], 300)
            l, r = np.minimum(l, r), np.maximum(l, r)
            ver = eng.pin()
            idx, val = eng.query(ver.state, jnp.asarray(l), jnp.asarray(r))
            eng.release(ver.vid)
            gold = ref.rmq_ref(xm, l, r)
            assert np.array_equal(np.asarray(idx), gold), (name, kw)
            assert np.array_equal(np.asarray(val), xm[gold]), (name, kw)
        # bit-identity of the final (patched + rebuilt) state vs from-scratch
        if name == "distributed":
            plan = build_mod.plan_for("distributed", xm.shape[0], mesh=mesh,
                                      axis_names=axes, block_size=128)
        else:
            plan = build_mod.plan_for(
                "sharded_hybrid", xm.shape[0], mesh=mesh, axis_names=axes,
                block_size=128,
                threshold=int(eng.store.current.state.threshold), **kw)
        fresh = build_mod.execute(plan, jnp.asarray(xm))
        got = leaves(eng.store.current.state)
        want_leaves = leaves(fresh)
        assert len(got) == len(want_leaves)
        for a, b in zip(want_leaves, got):
            assert a.shape == b.shape and np.array_equal(np.asarray(a), np.asarray(b)), (name, kw, a.shape)
    print("SHARDED_UPDATE_OK")
    """
)


def _run_child(code):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = "src"
    return subprocess.run(
        [sys.executable, "-c", code], env=env, capture_output=True, text=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=420,
    )


def test_sharded_patch_bit_identical_on_8_device_mesh():
    """Shard-boundary ties, multi-shard halo windows, pad-capacity appends,
    and the capacity-overflow rebuild fallback — all bit-identical to a
    from-scratch distributed build of the mutated array."""
    out = _run_child(_CHILD_SHARDED)
    assert "SHARDED_UPDATE_OK" in out.stdout, out.stderr[-3000:]


def test_windowed_cow_publish_tracks_patch_windows():
    """Publish-cost regression: a point write used to re-upload every leaf in
    full. The windowed-COW publish must upload only the patched windows —
    orders of magnitude less than the structure — while appends that grow the
    leaves legitimately fall back to a full upload."""
    n = 4096
    x = np.random.default_rng(0).standard_normal(n).astype(np.float32)
    for engine in ("sparse_table", "block128", "hybrid"):
        online = update.make_online(engine, jnp.asarray(x))
        full_bytes = sum(
            leaf.nbytes
            for leaf in jax.tree_util.tree_leaves(online.store.current.state)
            if hasattr(leaf, "nbytes")
        )
        log = update.DeltaLog()
        log.point(n // 2, -123.0)
        res = online.apply(log)
        assert res.patched
        assert 0 < res.publish_bytes < full_bytes // 4, (
            engine, res.publish_bytes, full_bytes,
        )
        # Growth changes leaf shapes: the publish re-uploads in full, and the
        # byte count says so (no silent undercount).
        log2 = update.DeltaLog()
        log2.append(np.full(8, 9.0, np.float32))
        res2 = online.apply(log2)
        assert res2.publish_bytes > res.publish_bytes


def test_windowed_cow_publish_preserves_old_versions():
    """COW at the leaf level: a pinned old version must keep answering from
    its own arrays after windowed publishes splice new ones."""
    n = 1024
    x = np.random.default_rng(1).standard_normal(n).astype(np.float32)
    online = update.make_online("sparse_table", jnp.asarray(x))
    ver0 = online.pin()
    log = update.DeltaLog()
    log.fill(0, 255, -50.0)
    online.apply(log)
    l = np.array([0], np.int32)
    r = np.array([n - 1], np.int32)
    idx0, _ = online.query(ver0.state, l, r)
    assert int(idx0[0]) == int(np.argmin(x))  # pre-update oracle
    ver1 = online.pin()
    idx1, _ = online.query(ver1.state, l, r)
    assert 0 <= int(idx1[0]) <= 255  # the fill owns the minimum now
    online.release(ver0.vid)
    online.release(ver1.vid)
