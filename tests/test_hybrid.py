"""Hybrid dispatcher: bit-identical results + correct scatter-back ordering."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import block_rmq, hybrid, ref


def _mixed_batch(rng, n, b, threshold):
    """Half short ranges (<= threshold), half long, interleaved randomly."""
    length_short = rng.integers(1, threshold + 1, b // 2)
    length_long = rng.integers(threshold + 1, n + 1, b - b // 2)
    length = np.concatenate([length_short, length_long])
    rng.shuffle(length)
    l = rng.integers(0, np.maximum(n - length + 1, 1), b)
    r = np.minimum(l + length - 1, n - 1)
    return l, r


@pytest.mark.parametrize("n", [300, 1000, 4096])
def test_hybrid_bit_identical_to_blocked(n, rng):
    x = rng.integers(0, 11, n).astype(np.float32)  # dense ties
    s = hybrid.build(jnp.asarray(x), 128, use_kernels=False)
    sb = block_rmq.build(jnp.asarray(x), 128)
    l, r = _mixed_batch(rng, n, 256, s.threshold)
    hi, hv = hybrid.query(s, l, r)
    bi, bv = block_rmq.query(sb, jnp.asarray(l), jnp.asarray(r))
    np.testing.assert_array_equal(np.asarray(hi), np.asarray(bi))
    np.testing.assert_array_equal(np.asarray(hv), np.asarray(bv))


def test_hybrid_kernel_path_matches_oracle(rng):
    """Short ranges through the fused Pallas megakernel (interpret off-TPU)."""
    n = 1500
    x = rng.integers(-5, 6, n).astype(np.float32)
    s = hybrid.build(jnp.asarray(x), 128, use_kernels=True)
    l, r = _mixed_batch(rng, n, 64, s.threshold)
    hi, hv = hybrid.query(s, l, r)
    gold = ref.rmq_ref(x, l, r)
    np.testing.assert_array_equal(np.asarray(hi), gold)
    np.testing.assert_allclose(np.asarray(hv), x[gold])


def test_scatter_back_ordering():
    """Known alternating short/long pattern: outputs stay in batch order."""
    n = 1024
    x = np.arange(n, 0, -1).astype(np.float32)  # strictly decreasing: min at r
    s = hybrid.build(jnp.asarray(x), 128, use_kernels=False, threshold=8)
    # Even positions short (len 2 <= 8), odd positions long (len 512 > 8).
    b = 40
    l = np.empty(b, np.int64)
    r = np.empty(b, np.int64)
    l[0::2] = np.arange(20) * 3
    r[0::2] = l[0::2] + 1
    l[1::2] = np.arange(20) * 5
    r[1::2] = l[1::2] + 511
    idx, val = hybrid.query(s, l, r)
    np.testing.assert_array_equal(np.asarray(idx), r)  # min of decreasing = r
    np.testing.assert_allclose(np.asarray(val), x[r])


def test_all_short_and_all_long_batches(rng):
    """Single-sided batches must not call the other engine's path at all."""
    n = 2048
    x = rng.standard_normal(n).astype(np.float32)
    s = hybrid.build(jnp.asarray(x), 128, use_kernels=False, threshold=64)
    for lo, hi in [(1, 64), (65, n)]:  # all-short, then all-long
        length = rng.integers(lo, hi + 1, 50)
        l = rng.integers(0, np.maximum(n - length + 1, 1), 50)
        r = np.minimum(l + length - 1, n - 1)
        idx, val = hybrid.query(s, l, r)
        gold = ref.rmq_ref(x, l, r)
        np.testing.assert_array_equal(np.asarray(idx), gold)
        np.testing.assert_allclose(np.asarray(val), x[gold])


def test_empty_batch_returns_empty_without_launching():
    """Regression: an empty batch used to pad to a phantom (0, 0) query and
    launch a kernel for nothing. It must return empty (idx, val) early."""
    s = hybrid.build(jnp.arange(64.0), 128, use_kernels=False)
    boom = lambda *a: (_ for _ in ()).throw(AssertionError("launched on empty batch"))
    s = s._replace(short_fn=boom, long_fn=boom)
    idx, val = hybrid.query(s, np.zeros(0, np.int64), np.zeros(0, np.int64))
    assert idx.shape == (0,) and val.shape == (0,)
    assert idx.dtype == jnp.int32
    assert val.dtype == s.x.dtype


def test_threshold_default_and_calibrate_smoke():
    s = hybrid.build(jnp.zeros(10_000, jnp.float32), 128, use_kernels=False)
    assert s.threshold == 100  # sqrt(n) default
    # 0 (all-long) and 4096 (all-short) are honest degenerate measurements.
    thr = hybrid.calibrate(4096, batch=256, use_kernels=False, repeats=1)
    assert 0 <= thr <= 4096
