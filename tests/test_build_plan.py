"""BuildPlan pipeline: stage sequencing, layout/threshold metadata, warmup
regimes, and the no-full-table guarantee of the distributed ST build."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import build as build_mod
from repro.core import distributed, ref, registry, sparse_table


def test_unknown_planner_raises():
    with pytest.raises(ValueError, match="no build planner"):
        build_mod.plan_for("warp_drive", 64)


def test_execute_rejects_wrong_length():
    plan = build_mod.plan_for("sparse_table", 64)
    with pytest.raises(ValueError, match="n=64"):
        build_mod.execute(plan, jnp.zeros(65, jnp.float32))


@pytest.mark.parametrize(
    "engine,kwargs,has_halo",
    [
        ("sparse_table", {}, False),
        ("block", {"block_size": 128}, False),
        ("hybrid", {"block_size": 128}, False),
        ("sharded_st", {}, True),
        ("sharded_hybrid", {"block_size": 128}, True),
        ("sharded_hybrid", {"block_size": 128, "mode": "shard_batch"}, False),
        ("distributed", {"block_size": 128}, False),
    ],
)
def test_stage_sequence(engine, kwargs, has_halo):
    """Observer sees the declared stages in canonical order; the halo stage
    appears exactly when the plan builds a structure-sharded doubling table."""
    plan = build_mod.plan_for(engine, 300, **kwargs)
    seen = []
    build_mod.execute(
        plan, jnp.arange(300.0), observer=lambda name, state: seen.append(name)
    )
    assert seen == [s.name for s in plan.stages]
    assert seen[0] == "shard_layout" and seen[-1] == "finalize"
    assert ("halo_exchange" in seen) == has_halo
    order = [build_mod.STAGE_NAMES.index(s) for s in seen]
    assert order == sorted(order)


def test_engine_build_results_match_direct_builders():
    """Lowering through the plan is a refactor, not a behavior change."""
    rng = np.random.default_rng(0)
    n = 700
    x = rng.integers(0, 5, n).astype(np.float32)
    l = rng.integers(0, n, 64)
    r = np.maximum(l, rng.integers(0, n, 64))
    gold = ref.rmq_ref(x, l, r)
    for name in registry.names():
        eng = registry.get(name)
        s = eng.build(jnp.asarray(x))
        idx, val = eng.query(s, jnp.asarray(l), jnp.asarray(r))
        np.testing.assert_array_equal(np.asarray(idx), gold, err_msg=name)
        np.testing.assert_array_equal(np.asarray(val), x[gold], err_msg=name)


def test_plan_metadata_threshold_resolution(tmp_path):
    from repro.core import calib_cache

    # Int pins; None is the deterministic sqrt(n); "cached" falls back on miss.
    assert build_mod.plan_for("hybrid", 1000, threshold=33).meta["threshold"] == 33
    assert build_mod.plan_for("hybrid", 1000).meta["threshold"] == 32  # sqrt
    p = tmp_path / "cal.json"
    # Sharded plans read the v2 key (mode + mesh shape); a v1 entry for the
    # same configuration is NOT consulted (the PR5 key bump).
    calib_cache.store(calib_cache.cache_key(1000, 128, n_devices=1), 99, path=p)
    calib_cache.store(
        calib_cache.cache_key(
            1000, 128, n_devices=1, mode="shard_structure", mesh_shape=(1,)
        ),
        55,
        path=p,
    )
    plan = build_mod.plan_for(
        "sharded_hybrid", 1000, threshold="cached", cache_path=p
    )
    assert plan.meta["threshold"] == 55
    with pytest.raises(ValueError, match="threshold"):
        build_mod.plan_for("hybrid", 1000, threshold="tuesday")


def test_warmup_bounds_cover_each_regime():
    # Threshold engine, both regimes reachable: one short + one long probe.
    plan = build_mod.plan_for("hybrid", 1000, threshold=32)
    [(ls, rs), (ll, rl)] = build_mod.warmup_bounds(plan)(4)
    assert rs[0] - ls[0] + 1 == 32  # longest length that still routes short
    assert rl[0] - ll[0] + 1 == 1000  # full range routes long
    assert ls.dtype == np.int32 and rs.shape == (4,)
    # threshold 0: everything routes long -> a single long probe.
    probes = build_mod.warmup_bounds(build_mod.plan_for("hybrid", 1000, threshold=0))(2)
    assert [int(r[0] - l[0] + 1) for l, r in probes] == [1000]
    # threshold >= n: everything routes short -> a single short probe.
    probes = build_mod.warmup_bounds(
        build_mod.plan_for("hybrid", 1000, threshold=5000)
    )(2)
    assert [int(r[0] - l[0] + 1) for l, r in probes] == [1000]
    # No threshold metadata: the two extremes.
    probes = build_mod.warmup_bounds(build_mod.plan_for("sparse_table", 1000))(2)
    assert [int(r[0] - l[0] + 1) for l, r in probes] == [1, 1000]


def test_sharded_st_never_calls_replicated_build(monkeypatch):
    """The dead single-device materialization path stays dead: the distributed
    build must not fall back to ``sparse_table.build`` on the full array."""

    def boom(x):
        raise AssertionError(
            f"sparse_table.build called on shape {x.shape} during distributed build"
        )

    monkeypatch.setattr(sparse_table, "build", boom)
    monkeypatch.setattr(distributed.sparse_table, "build", boom)
    x = jnp.asarray(np.random.default_rng(1).random(256, dtype=np.float32))
    mesh, axes = build_mod.default_mesh()
    t = distributed.build_sharded_st(x, mesh, axes)
    assert t.idx.shape[1] == 256


def test_sharded_st_per_device_allocation_bounded():
    """Allocation probe: at every stage of the distributed ST build, each
    addressable shard of every live build-state array stays within the
    per-shard budget — the full (K, n) table never lands on one device."""
    n = 1024
    plan = build_mod.plan_for("sharded_st", n)
    layout = plan.layout
    k_levels = distributed.st_levels(layout.n_pad)
    budget = (k_levels + 2) * layout.shard_len  # rows-per-shard + level-0 pair

    import jax

    def probe(stage, state):
        for key, leaf in state.items():
            for arr in jax.tree_util.tree_leaves(leaf):
                if not isinstance(arr, jax.Array):
                    continue
                if key == "x":  # the caller's input, not a build allocation
                    continue
                for shard in arr.addressable_shards:
                    assert np.prod(shard.data.shape) <= budget, (
                        stage,
                        key,
                        shard.data.shape,
                    )

    t = build_mod.execute(plan, jnp.arange(float(n)), observer=probe)
    # Steady state is column-sharded: (K, n_pad / num_shards) per device.
    for shard in t.idx.addressable_shards:
        assert shard.data.shape == (k_levels, layout.shard_len)
