"""Distributed engine tests (8 fake devices via subprocess so the main test
process keeps its single-device view)."""

import os
import subprocess
import sys
import textwrap

import pytest

_CHILD = textwrap.dedent(
    """
    import numpy as np, jax, jax.numpy as jnp
    from repro.core import distributed, ref
    from repro.launch.mesh import make_mesh, set_mesh

    mesh = make_mesh((2, 4), ("data", "model"))
    rng = np.random.default_rng(1)
    n = 5000
    x = rng.integers(0, 50, n).astype(np.float32)
    l = rng.integers(0, n, 300); r = rng.integers(0, n, 300)
    l, r = np.minimum(l, r), np.maximum(l, r)
    gold = ref.rmq_ref(x, l, r)
    with set_mesh(mesh):
        s = distributed.build_sharded(jnp.asarray(x), mesh, ("data", "model"), 128)
        qfn = distributed.make_query_fn(mesh, ("data", "model"))
        gi, gv = qfn(s, jnp.asarray(l), jnp.asarray(r))
    assert (np.asarray(gi) == gold).all()
    assert np.allclose(np.asarray(gv), x[gold])
    print("DISTRIBUTED_OK")
    """
)

_CHILD_TRAIN = textwrap.dedent(
    """
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_config, reduce_for_smoke
    from repro.data import pipeline
    from repro.launch.mesh import make_mesh, set_mesh
    from repro.models import model
    from repro.optim import adamw
    from repro.train.steps import make_train_step

    cfg = reduce_for_smoke(get_config("granite-3-8b"))
    mesh = make_mesh((2, 4), ("data", "model"))
    with set_mesh(mesh):
        params = model.init_params(cfg, jax.random.PRNGKey(0))
        opt = adamw.init(params)
        step, info = make_train_step(cfg, mesh, lr_fn=lambda s: jnp.float32(1e-3),
                                     batch=4, seq_len=64)
        from repro.train.steps import place_state
        params, opt = place_state(mesh, info, params, opt)
        for i in range(3):
            batch = pipeline.synthetic_batch(cfg, 4, 64, seed=0, step=i)
            params, opt, m = step(params, opt, batch)
            assert np.isfinite(float(m["loss"]))
    print("SHARDED_TRAIN_OK")
    """
)


_CHILD_TIE = textwrap.dedent(
    """
    import numpy as np, jax, jax.numpy as jnp
    from repro.core import distributed
    from repro.launch.mesh import make_mesh, set_mesh

    mesh = make_mesh((8,), ("shard",))
    n = 8 * 256  # each shard owns 256 elements (2 blocks of 128)
    x = np.ones(n, np.float32)
    p1, p2 = 2 * 256 + 17, 5 * 256 + 100  # tied global min in shards 2 and 5
    x[p1] = x[p2] = -3.0
    l = np.array([0, p1, p1 + 1, p2 + 1])
    r = np.array([n - 1, p2, p2, n - 1])
    with set_mesh(mesh):
        s = distributed.build_sharded(jnp.asarray(x), mesh, ("shard",), 128)
        qfn = distributed.make_query_fn(mesh, ("shard",))
        gi, gv = qfn(s, jnp.asarray(l), jnp.asarray(r))
        gi, gv = np.asarray(gi), np.asarray(gv)
        # Two-pmin merge must pick the LEFTMOST of the two tied shard minima.
        assert gi[0] == p1 and gv[0] == -3.0, (gi[0], gv[0])
        assert gi[1] == p1, gi[1]
        assert gi[2] == p2, gi[2]  # p1 excluded: the other shard's copy wins
        assert gi[3] == p2 + 1 and gv[3] == 1.0, (gi[3], gv[3])

        # Same tie discipline on the column-sharded sparse-table path.
        t = distributed.build_sharded_st(jnp.asarray(x), mesh, ("shard",))
        stq = distributed.make_st_query_fn(mesh, ("shard",))
        si, sv = stq(t, jnp.asarray(l), jnp.asarray(r))
        si, sv = np.asarray(si), np.asarray(sv)
        assert (si == gi).all(), (si, gi)
        assert (sv == gv).all(), (sv, gv)
    print("TIE_OK")
    """
)

_CHILD_SHYBRID = textwrap.dedent(
    """
    import numpy as np, jax, jax.numpy as jnp
    from repro.core import block_rmq, sharded_hybrid
    from repro.launch.mesh import make_mesh

    mesh = make_mesh((2, 4), ("data", "model"))
    rng = np.random.default_rng(2)
    n = 5000
    x = rng.integers(0, 9, n).astype(np.float32)  # dense ties
    thr = 64
    ls_ = rng.integers(1, thr + 1, 150)
    ll_ = rng.integers(thr + 1, n + 1, 150)
    length = np.concatenate([ls_, ll_])
    rng.shuffle(length)
    l = rng.integers(0, np.maximum(n - length + 1, 1), 300)
    r = np.minimum(l + length - 1, n - 1)

    sb = block_rmq.build(jnp.asarray(x), 128)
    bi, bv = block_rmq.query(sb, jnp.asarray(l), jnp.asarray(r))
    for mode in sharded_hybrid.MODES:
        s = sharded_hybrid.build(jnp.asarray(x), mesh, ("data", "model"), 128,
                                 threshold=thr, mode=mode)
        hi, hv = sharded_hybrid.query(s, l, r)  # 300 % 8 != 0: pad path too
        assert (np.asarray(hi) == np.asarray(bi)).all(), mode
        assert (np.asarray(hv) == np.asarray(bv)).all(), mode
    print("SHYBRID_OK")
    """
)


def _run_child(code):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = "src"
    return subprocess.run(
        [sys.executable, "-c", code], env=env, capture_output=True, text=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=420,
    )


def test_distributed_rmq_8_shards():
    out = _run_child(_CHILD)
    assert "DISTRIBUTED_OK" in out.stdout, out.stderr[-3000:]


def test_distributed_leftmost_tie_across_shards():
    """Global min duplicated in two different shards: the merge must return
    the leftmost global index (blocked and sparse-table paths alike)."""
    out = _run_child(_CHILD_TIE)
    assert "TIE_OK" in out.stdout, out.stderr[-3000:]


def test_sharded_hybrid_bit_identical_on_8_device_mesh():
    """Mixed small/large batch through both distribution modes must be
    bit-identical to the single-host blocked oracle (acceptance criterion)."""
    out = _run_child(_CHILD_SHYBRID)
    assert "SHYBRID_OK" in out.stdout, out.stderr[-3000:]


def test_sharded_train_step_2x4_mesh():
    out = _run_child(_CHILD_TRAIN)
    assert "SHARDED_TRAIN_OK" in out.stdout, out.stderr[-3000:]
