"""Distributed engine tests (8 fake devices via subprocess so the main test
process keeps its single-device view)."""

import os
import subprocess
import sys
import textwrap

import pytest

_CHILD = textwrap.dedent(
    """
    import numpy as np, jax, jax.numpy as jnp
    from repro.core import distributed, ref
    from repro.launch.mesh import make_mesh, set_mesh

    mesh = make_mesh((2, 4), ("data", "model"))
    rng = np.random.default_rng(1)
    n = 5000
    x = rng.integers(0, 50, n).astype(np.float32)
    l = rng.integers(0, n, 300); r = rng.integers(0, n, 300)
    l, r = np.minimum(l, r), np.maximum(l, r)
    gold = ref.rmq_ref(x, l, r)
    with set_mesh(mesh):
        s = distributed.build_sharded(jnp.asarray(x), mesh, ("data", "model"), 128)
        qfn = distributed.make_query_fn(mesh, ("data", "model"))
        gi, gv = qfn(s, jnp.asarray(l), jnp.asarray(r))
    assert (np.asarray(gi) == gold).all()
    assert np.allclose(np.asarray(gv), x[gold])
    print("DISTRIBUTED_OK")
    """
)

_CHILD_TRAIN = textwrap.dedent(
    """
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_config, reduce_for_smoke
    from repro.data import pipeline
    from repro.launch.mesh import make_mesh, set_mesh
    from repro.models import model
    from repro.optim import adamw
    from repro.train.steps import make_train_step

    cfg = reduce_for_smoke(get_config("granite-3-8b"))
    mesh = make_mesh((2, 4), ("data", "model"))
    with set_mesh(mesh):
        params = model.init_params(cfg, jax.random.PRNGKey(0))
        opt = adamw.init(params)
        step, info = make_train_step(cfg, mesh, lr_fn=lambda s: jnp.float32(1e-3),
                                     batch=4, seq_len=64)
        from repro.train.steps import place_state
        params, opt = place_state(mesh, info, params, opt)
        for i in range(3):
            batch = pipeline.synthetic_batch(cfg, 4, 64, seed=0, step=i)
            params, opt, m = step(params, opt, batch)
            assert np.isfinite(float(m["loss"]))
    print("SHARDED_TRAIN_OK")
    """
)


def _run_child(code):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = "src"
    return subprocess.run(
        [sys.executable, "-c", code], env=env, capture_output=True, text=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=420,
    )


def test_distributed_rmq_8_shards():
    out = _run_child(_CHILD)
    assert "DISTRIBUTED_OK" in out.stdout, out.stderr[-3000:]


def test_sharded_train_step_2x4_mesh():
    out = _run_child(_CHILD_TRAIN)
    assert "SHARDED_TRAIN_OK" in out.stdout, out.stderr[-3000:]
