"""Distributed engine tests (8 fake devices via subprocess so the main test
process keeps its single-device view)."""

import os
import subprocess
import sys
import textwrap

import pytest

_CHILD = textwrap.dedent(
    """
    import numpy as np, jax, jax.numpy as jnp
    from repro.core import distributed, ref
    from repro.launch.mesh import make_mesh, set_mesh

    mesh = make_mesh((2, 4), ("data", "model"))
    rng = np.random.default_rng(1)
    n = 5000
    x = rng.integers(0, 50, n).astype(np.float32)
    l = rng.integers(0, n, 300); r = rng.integers(0, n, 300)
    l, r = np.minimum(l, r), np.maximum(l, r)
    gold = ref.rmq_ref(x, l, r)
    with set_mesh(mesh):
        s = distributed.build_sharded(jnp.asarray(x), mesh, ("data", "model"), 128)
        qfn = distributed.make_query_fn(mesh, ("data", "model"))
        gi, gv = qfn(s, jnp.asarray(l), jnp.asarray(r))
    assert (np.asarray(gi) == gold).all()
    assert np.allclose(np.asarray(gv), x[gold])
    print("DISTRIBUTED_OK")
    """
)

_CHILD_TRAIN = textwrap.dedent(
    """
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_config, reduce_for_smoke
    from repro.data import pipeline
    from repro.launch.mesh import make_mesh, set_mesh
    from repro.models import model
    from repro.optim import adamw
    from repro.train.steps import make_train_step

    cfg = reduce_for_smoke(get_config("granite-3-8b"))
    mesh = make_mesh((2, 4), ("data", "model"))
    with set_mesh(mesh):
        params = model.init_params(cfg, jax.random.PRNGKey(0))
        opt = adamw.init(params)
        step, info = make_train_step(cfg, mesh, lr_fn=lambda s: jnp.float32(1e-3),
                                     batch=4, seq_len=64)
        from repro.train.steps import place_state
        params, opt = place_state(mesh, info, params, opt)
        for i in range(3):
            batch = pipeline.synthetic_batch(cfg, 4, 64, seed=0, step=i)
            params, opt, m = step(params, opt, batch)
            assert np.isfinite(float(m["loss"]))
    print("SHARDED_TRAIN_OK")
    """
)


_CHILD_TIE = textwrap.dedent(
    """
    import numpy as np, jax, jax.numpy as jnp
    from repro.core import distributed
    from repro.launch.mesh import make_mesh, set_mesh

    mesh = make_mesh((8,), ("shard",))
    n = 8 * 256  # each shard owns 256 elements (2 blocks of 128)
    x = np.ones(n, np.float32)
    p1, p2 = 2 * 256 + 17, 5 * 256 + 100  # tied global min in shards 2 and 5
    x[p1] = x[p2] = -3.0
    l = np.array([0, p1, p1 + 1, p2 + 1])
    r = np.array([n - 1, p2, p2, n - 1])
    with set_mesh(mesh):
        s = distributed.build_sharded(jnp.asarray(x), mesh, ("shard",), 128)
        qfn = distributed.make_query_fn(mesh, ("shard",))
        gi, gv = qfn(s, jnp.asarray(l), jnp.asarray(r))
        gi, gv = np.asarray(gi), np.asarray(gv)
        # Two-pmin merge must pick the LEFTMOST of the two tied shard minima.
        assert gi[0] == p1 and gv[0] == -3.0, (gi[0], gv[0])
        assert gi[1] == p1, gi[1]
        assert gi[2] == p2, gi[2]  # p1 excluded: the other shard's copy wins
        assert gi[3] == p2 + 1 and gv[3] == 1.0, (gi[3], gv[3])

        # Same tie discipline on the column-sharded sparse-table path.
        t = distributed.build_sharded_st(jnp.asarray(x), mesh, ("shard",))
        stq = distributed.make_st_query_fn(mesh, ("shard",))
        si, sv = stq(t, jnp.asarray(l), jnp.asarray(r))
        si, sv = np.asarray(si), np.asarray(sv)
        assert (si == gi).all(), (si, gi)
        assert (sv == gv).all(), (sv, gv)
    print("TIE_OK")
    """
)

_CHILD_SHYBRID = textwrap.dedent(
    """
    import numpy as np, jax, jax.numpy as jnp
    from repro.core import block_rmq, sharded_hybrid
    from repro.launch.mesh import make_mesh

    mesh = make_mesh((2, 4), ("data", "model"))
    rng = np.random.default_rng(2)
    n = 5000
    x = rng.integers(0, 9, n).astype(np.float32)  # dense ties
    thr = 64
    ls_ = rng.integers(1, thr + 1, 150)
    ll_ = rng.integers(thr + 1, n + 1, 150)
    length = np.concatenate([ls_, ll_])
    rng.shuffle(length)
    l = rng.integers(0, np.maximum(n - length + 1, 1), 300)
    r = np.minimum(l + length - 1, n - 1)

    sb = block_rmq.build(jnp.asarray(x), 128)
    bi, bv = block_rmq.query(sb, jnp.asarray(l), jnp.asarray(r))
    for mode in sharded_hybrid.MODES:
        s = sharded_hybrid.build(jnp.asarray(x), mesh, ("data", "model"), 128,
                                 threshold=thr, mode=mode)
        hi, hv = sharded_hybrid.query(s, l, r)  # 300 % 8 != 0: pad path too
        assert (np.asarray(hi) == np.asarray(bi)).all(), mode
        assert (np.asarray(hv) == np.asarray(bv)).all(), mode
    print("SHYBRID_OK")
    """
)


_CHILD_HALO = textwrap.dedent(
    """
    import numpy as np, jax, jax.numpy as jnp
    from repro.core import build as build_mod
    from repro.core import distributed, sparse_table
    from repro.core.block_rmq import maxval
    from repro.launch.mesh import make_mesh

    def replicated_reference(x, num):
        # What the deleted single-device path used to produce: the full
        # doubling table over the shard-padded array plus gathered values.
        n = x.shape[0]
        n_pad = -(-n // num) * num
        xp = jnp.pad(jnp.asarray(x), (0, n_pad - n), constant_values=maxval(x.dtype))
        st = sparse_table.build(xp)
        return np.asarray(st.idx), np.asarray(xp[st.idx])

    # K levels whose 2^k span crosses MULTIPLE shards: n = 8 * 64 -> C = 64,
    # levels k with 2^(k-1) in {128, 256} pull halos 2 and 4 shards away.
    # Plus non-power-of-two n (pad tail in the last shard) and tiny n
    # (shard_len 1: every level crosses shards).
    for mesh, axes in [
        (make_mesh((8,), ("shard",)), ("shard",)),
        (make_mesh((2, 4), ("data", "model")), ("data", "model")),
    ]:
        num = distributed.num_shards(mesh, axes)
        rng = np.random.default_rng(3)
        for n in (512, 5000, 1057, 17, 8, 1):
            x = rng.integers(0, 4, max(n, 1)).astype(np.float32)  # dense ties
            t = distributed.build_sharded_st(jnp.asarray(x), mesh, axes)
            gi, gv = replicated_reference(x, num)
            assert np.array_equal(np.asarray(t.idx), gi), (n, axes)
            assert np.array_equal(np.asarray(t.val), gv), (n, axes)

    # Leftmost ties straddling a shard boundary: equal minima as the last
    # element of shard 2 and the first element of shard 3 must resolve to
    # the left copy at every level that sees both.
    mesh = make_mesh((8,), ("shard",))
    n = 8 * 32
    x = np.ones(n, np.float32)
    x[3 * 32 - 1] = x[3 * 32] = -7.0  # boundary-straddling tie
    t = distributed.build_sharded_st(jnp.asarray(x), mesh, ("shard",))
    gi, gv = replicated_reference(x, 8)
    assert np.array_equal(np.asarray(t.idx), gi)
    qfn = distributed.make_st_query_fn(mesh, ("shard",))
    si, sv = qfn(t, jnp.asarray(np.array([0, 3 * 32])), jnp.asarray(np.array([n - 1, n - 1])))
    assert int(si[0]) == 3 * 32 - 1  # leftmost of the tied pair
    assert int(si[1]) == 3 * 32      # left copy excluded -> right copy

    # Allocation probe on a REAL multi-device mesh: at every pipeline stage,
    # every addressable shard of every build-state array stays within the
    # per-shard budget; the full (K, n_pad) table never lands on one device.
    n = 4096
    plan = build_mod.plan_for("sharded_st", n, mesh=mesh, axis_names=("shard",))
    layout = plan.layout
    K = distributed.st_levels(layout.n_pad)
    budget = (K + 2) * layout.shard_len
    full_table = K * layout.n_pad
    assert budget < full_table  # the probe is non-vacuous on 8 shards

    def probe(stage, state):
        for key, leaf in state.items():
            if key == "x":
                continue  # the caller's input, not a build allocation
            for arr in jax.tree_util.tree_leaves(leaf):
                if isinstance(arr, jax.Array):
                    for shard in arr.addressable_shards:
                        size = int(np.prod(shard.data.shape))
                        assert size <= budget, (stage, key, shard.data.shape)
                        assert size < full_table, (stage, key, shard.data.shape)

    t = build_mod.execute(plan, jnp.asarray(rng.random(n, dtype=np.float32)), observer=probe)
    for shard in t.idx.addressable_shards:
        assert shard.data.shape == (K, layout.shard_len)
    print("HALO_OK")
    """
)


_CHILD_CALIB = textwrap.dedent(
    """
    import numpy as np, jax, jax.numpy as jnp
    from repro.core import hybrid
    from repro.launch.mesh import make_mesh, set_mesh

    mesh = make_mesh((2, 4), ("data", "model"))
    measured = []
    def fake_measure(kind, fn, lj, rj, repeats):
        measured.append(kind)
        return 0.0 if kind == "short" else 1.0
    hybrid._measure = fake_measure
    with set_mesh(mesh):
        for mode in ("shard_structure", "shard_2d"):
            thr = hybrid.calibrate(
                256, batch=8, repeats=1, mesh=mesh,
                axis_names=("data", "model"), mode=mode,
            )
            assert thr == 256, (mode, thr)  # short always wins -> threshold n
    assert "short" in measured and "long" in measured
    print("SHARDED_CALIBRATE_OK")
    """
)


def _run_child(code):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = "src"
    return subprocess.run(
        [sys.executable, "-c", code], env=env, capture_output=True, text=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=420,
    )


def test_distributed_rmq_8_shards():
    out = _run_child(_CHILD)
    assert "DISTRIBUTED_OK" in out.stdout, out.stderr[-3000:]


def test_distributed_leftmost_tie_across_shards():
    """Global min duplicated in two different shards: the merge must return
    the leftmost global index (blocked and sparse-table paths alike)."""
    out = _run_child(_CHILD_TIE)
    assert "TIE_OK" in out.stdout, out.stderr[-3000:]


def test_sharded_hybrid_bit_identical_on_8_device_mesh():
    """Mixed small/large batch through every distribution mode (including the
    2D structure x batch mesh) must be bit-identical to the single-host
    blocked oracle (acceptance criterion)."""
    out = _run_child(_CHILD_SHYBRID)
    assert "SHYBRID_OK" in out.stdout, out.stderr[-3000:]


def test_distributed_st_build_halo_exchange_8_shards():
    """The distributed doubling-table build: bit-identity with the replicated
    build on non-power-of-two n, boundary-straddling leftmost ties, levels
    whose 2^k span crosses multiple shards, and the per-device allocation
    probe (no device ever holds the full (K, n) table)."""
    out = _run_child(_CHILD_HALO)
    assert "HALO_OK" in out.stdout, out.stderr[-3000:]


def test_sharded_calibration_times_sharded_constituents():
    """calibrate(mesh=...) must build and time the sharded constituents on a
    real 2x4 mesh (deterministic via the _measure seam)."""
    out = _run_child(_CHILD_CALIB)
    assert "SHARDED_CALIBRATE_OK" in out.stdout, out.stderr[-3000:]


def test_sharded_train_step_2x4_mesh():
    out = _run_child(_CHILD_TRAIN)
    assert "SHARDED_TRAIN_OK" in out.stdout, out.stderr[-3000:]
