"""Serve-subsystem tests: micro-batcher, server loop, registry capabilities.

The pure coalesce/pad/scatter core is tested directly against the numpy
oracle; the threaded server is tested with generous deadlines (no timing
races) and with a numpy-only fake engine where device execution would only
add noise. End-to-end scatter-back under mixed range distributions runs
through the real registry ``hybrid`` engine.
"""

import threading
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import hybrid, ref, registry
from repro.serve import (
    RMQServer,
    ServeConfig,
    ServerClosed,
    ServerOverloaded,
    batcher,
)
from repro.serve.workload import make_queries


def _oracle_engine(x):
    """A (l, r) -> (idx, val) engine that is literally the oracle."""

    def qfn(l, r):
        idx = ref.rmq_ref(x, l, r).astype(np.int32)
        return idx, x[idx]

    return qfn


def _bounded(rng, n, b):
    a = rng.integers(0, n, b)
    c = rng.integers(0, n, b)
    return np.minimum(a, c).astype(np.int32), np.maximum(a, c).astype(np.int32)


# --- pure batcher core ------------------------------------------------------


def test_bucket_powers_of_two():
    assert [batcher.bucket(b) for b in (1, 2, 3, 4, 5, 127, 128, 129)] == [
        1, 2, 4, 4, 8, 128, 128, 256,
    ]
    with pytest.raises(ValueError):
        batcher.bucket(0)


def test_coalesce_pads_to_bucket_and_preserves_order():
    ls = [np.array([1, 2, 3], np.int32), np.array([7], np.int32), np.array([4, 5], np.int32)]
    rs = [np.array([9, 9, 9], np.int32), np.array([8], np.int32), np.array([6, 7], np.int32)]
    mb = batcher.coalesce(ls, rs)
    assert mb.n_queries == 6
    assert mb.l.shape == (8,)  # bucket(6)
    assert mb.spans == ((0, 3), (3, 1), (4, 2))
    np.testing.assert_array_equal(mb.l[:6], [1, 2, 3, 7, 4, 5])
    np.testing.assert_array_equal(mb.r[:6], [9, 9, 9, 8, 6, 7])
    np.testing.assert_array_equal(mb.l[6:], 0)  # trivial (0, 0) pad queries
    np.testing.assert_array_equal(mb.r[6:], 0)


def test_scatter_back_roundtrip_vs_oracle():
    rng = np.random.default_rng(0)
    n = 512
    x = rng.integers(0, 4, n).astype(np.float32)  # tie-heavy
    ls, rs = zip(*[_bounded(rng, n, b) for b in (3, 8, 1, 5)])
    mb = batcher.coalesce(ls, rs)
    idx = ref.rmq_ref(x, mb.l, mb.r)
    parts = batcher.scatter_back(mb, idx, x[idx])
    assert len(parts) == 4
    for (l, r), (pi, pv) in zip(zip(ls, rs), parts):
        gold = ref.rmq_ref(x, l, r)
        np.testing.assert_array_equal(pi, gold)
        np.testing.assert_array_equal(pv, x[gold])


# --- server: coalescing, deadline, padding buckets --------------------------


def test_microbatcher_coalesces_across_clients():
    rng = np.random.default_rng(1)
    n = 256
    x = rng.random(n).astype(np.float32)
    # Generous deadline: all requests submitted well inside it -> ONE batch.
    with RMQServer(_oracle_engine(x), ServeConfig(deadline_s=0.5, max_batch=1024, n=n)) as srv:
        subs = [(*_bounded(rng, n, 4 + c), c) for c in range(3)]
        futs = [(l, r, srv.submit(l, r)) for l, r, _ in subs]
        results = [(l, r, f.result(timeout=30)) for l, r, f in futs]
    st = srv.stats()
    assert st.n_batches == 1, st
    assert st.served_requests == 3
    assert st.served_queries == 4 + 5 + 6
    assert st.padded_sizes == (16,)  # bucket(15)
    for l, r, res in results:
        np.testing.assert_array_equal(res.idx, ref.rmq_ref(x, l, r))


def test_deadline_flush_without_filling_batch():
    x = np.arange(64, 0, -1).astype(np.float32)
    cfg = ServeConfig(deadline_s=0.05, max_batch=4096, n=64)
    with RMQServer(_oracle_engine(x), cfg) as srv:
        t0 = time.perf_counter()
        res = srv.submit(np.array([3], np.int32), np.array([60], np.int32)).result(timeout=30)
        wall = time.perf_counter() - t0
    # Flushed by the deadline (batch nowhere near max_batch), not stuck.
    assert srv.stats().n_batches == 1
    assert res.timing.queue_s >= 0.04  # held for coalescing ~the full deadline
    assert wall < 10
    np.testing.assert_array_equal(res.idx, [60])  # min of descending array


def test_padding_bucket_selection_and_bounded_shapes():
    rng = np.random.default_rng(2)
    n = 128
    x = rng.random(n).astype(np.float32)
    # deadline=0: every request flushes alone -> padded shape == bucket(size).
    with RMQServer(_oracle_engine(x), ServeConfig(deadline_s=0.0, max_batch=64, n=n)) as srv:
        for size in (1, 3, 5, 9, 33):
            l, r = _bounded(rng, n, size)
            srv.submit(l, r).result(timeout=30)
    st = srv.stats()
    assert st.padded_sizes == (1, 4, 8, 16, 64)
    # The jit-cache bound: every shape a power of two, at most log2(max)+1 of them.
    assert all(s & (s - 1) == 0 for s in st.padded_sizes)
    assert len(st.padded_sizes) <= int(np.log2(batcher.bucket(64))) + 1


def test_max_batch_splits_flushes():
    rng = np.random.default_rng(3)
    n = 64
    x = rng.random(n).astype(np.float32)
    # 3 requests of 4 queries against max_batch=8: the third overflows -> 2 batches.
    with RMQServer(_oracle_engine(x), ServeConfig(deadline_s=0.5, max_batch=8, n=n)) as srv:
        futs = []
        for _ in range(3):
            l, r = _bounded(rng, n, 4)
            futs.append(srv.submit(l, r))
        for f in futs:
            f.result(timeout=30)
    st = srv.stats()
    assert st.n_batches == 2
    assert max(st.padded_sizes) <= 8


def test_regime_split_counts_in_stats():
    """Per-launch (short, long) sub-batch sizes surface in ServeStats, with
    the batcher's trivial (0, 0) pad queries excluded from the counts."""
    rng = np.random.default_rng(7)
    n = 2048
    x = rng.random(n, dtype=np.float32)
    s = hybrid.build(jnp.asarray(x), 128, use_kernels=False, threshold=16)
    qfn = lambda l, r: hybrid.query(s, l, r)

    # 5 short (len <= 16) + 3 long queries in one request: bucket(8) = 8, no
    # pad; then a 3-query all-short request: bucket(3) = 4, one pad query.
    l1 = np.array([0, 5, 9, 100, 200, 300, 400, 500], np.int32)
    r1 = np.array([3, 20, 9, 115, 210, 1300, 1400, 1500], np.int32)
    l2 = np.array([1, 2, 3], np.int32)
    r2 = np.array([4, 5, 6], np.int32)
    with RMQServer(qfn, ServeConfig(deadline_s=0.0, max_batch=64, n=n)) as srv:
        srv.submit(l1, r1).result(timeout=60)
        srv.submit(l2, r2).result(timeout=60)
    st = srv.stats()
    assert st.regime_splits == ((5, 3), (3, 0))
    assert st.short_queries == 8 and st.long_queries == 3
    assert st.mixed_batches == 1
    assert "regime split 8 short / 3 long" in st.summary()


def test_regime_splits_empty_for_single_path_engine():
    x = np.arange(32, 0, -1).astype(np.float32)
    with RMQServer(_oracle_engine(x), ServeConfig(deadline_s=0.0, n=32)) as srv:
        srv.submit(np.array([0], np.int32), np.array([31], np.int32)).result(timeout=30)
    st = srv.stats()
    assert st.regime_splits == ()
    assert st.short_queries == 0 and st.mixed_batches == 0
    assert "regime split" not in st.summary()


def test_warmup_bounds_from_plan_compiles_each_regime():
    """Plan-derived warmup probes: every probe batch the plan prescribes is
    issued at every padded size, and the probes route one per regime."""
    from repro.core import build as build_mod

    n = 512
    plan = registry.plan_for_serving("hybrid", n, threshold=32)
    x = np.random.default_rng(0).random(n, dtype=np.float32)
    calls = []

    def qfn(l, r):
        calls.append((l.size, int(r[0] - l[0] + 1)))
        return _oracle_engine(x)(l, r)

    srv = RMQServer(
        qfn,
        ServeConfig(max_batch=8, n=n),
        warmup_bounds=build_mod.warmup_bounds(plan),
    )
    srv.warmup()
    # Sizes 1, 2, 4, 8; per size one length-32 (short regime) and one
    # length-n (long regime) probe.
    assert calls == [
        (s, ln) for s in (1, 2, 4, 8) for ln in (32, n)
    ]


def test_scatter_back_mixed_dists_through_hybrid_engine():
    """End-to-end through the real registry engine under all three §6.4 regimes."""
    rng = np.random.default_rng(4)
    n = 4096
    x = rng.integers(0, 9, n).astype(np.float32)  # dense ties
    spec = registry.get("hybrid")
    state = registry.build_for_serving("hybrid", jnp.asarray(x))
    qfn = lambda l, r: spec.query(state, l, r)

    results = []
    lock = threading.Lock()

    def client(c, dist):
        crng = np.random.default_rng(100 + c)
        for _ in range(5):
            l, r = make_queries(crng, n, 1 + crng.integers(1, 12), dist)
            with lock:
                results.append((l, r, srv.submit(l, r)))

    with RMQServer(qfn, ServeConfig(deadline_s=0.02, max_batch=256, n=n)) as srv:
        threads = [
            threading.Thread(target=client, args=(c, d))
            for c, d in enumerate(("small", "medium", "large"))
        ]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        done = [(l, r, f.result(timeout=120)) for l, r, f in results]
    assert len(done) == 15
    for l, r, res in done:
        gold = ref.rmq_ref(x, l, r)
        np.testing.assert_array_equal(res.idx, gold)
        np.testing.assert_array_equal(res.val, x[gold])
    assert srv.stats().n_batches < 15  # actually coalesced across clients


# --- adaptive deadline -------------------------------------------------------


def test_adaptive_deadline_shrinks_under_load_then_grows_when_idle():
    """Size-triggered flushes halve the effective deadline (down to the
    floor); near-empty deadline flushes grow it back. The trajectory is
    recorded per flush in ServeStats."""
    rng = np.random.default_rng(11)
    n = 64
    x = rng.random(n).astype(np.float32)
    cfg = ServeConfig(
        deadline_s=0.008,
        deadline_min_s=0.001,
        deadline_max_s=0.032,
        adaptive_deadline=True,
        max_batch=8,
        n=n,
    )
    with RMQServer(_oracle_engine(x), cfg) as srv:
        for _ in range(4):  # 8-query requests: every flush is size-triggered
            l, r = _bounded(rng, n, 8)
            srv.submit(l, r).result(timeout=30)
        # Idle: a single 1-query request flushes by deadline and grows it.
        l, r = _bounded(rng, n, 1)
        srv.submit(l, r).result(timeout=30)
    traj = srv.stats().deadline_trajectory
    assert traj[:4] == (
        pytest.approx(0.004),
        pytest.approx(0.002),
        pytest.approx(0.001),
        pytest.approx(0.001),  # clamped at deadline_min_s
    )
    assert traj[4] == pytest.approx(0.0015)  # grew by 1.5x from the floor


def test_adaptive_deadline_defaults_and_validation():
    cfg = ServeConfig(deadline_s=0.008, adaptive_deadline=True)
    assert cfg.deadline_bounds() == (0.001, 0.032)
    with pytest.raises(ValueError):
        ServeConfig(deadline_s=0.0, adaptive_deadline=True)
    with pytest.raises(ValueError):
        ServeConfig(deadline_s=0.002, deadline_min_s=0.004, adaptive_deadline=True)
    with pytest.raises(ValueError):
        ServeConfig(deadline_s=0.002, deadline_max_s=0.001, adaptive_deadline=True)


def test_fixed_deadline_records_no_trajectory():
    x = np.ones(8, np.float32)
    with RMQServer(_oracle_engine(x), ServeConfig(deadline_s=0.0, n=8)) as srv:
        one = np.zeros(1, np.int32)
        srv.submit(one, one).result(timeout=30)
    assert srv.stats().deadline_trajectory == ()


# --- server: edges, admission control, validation ---------------------------


def test_empty_request_resolves_immediately():
    x = np.ones(8, np.float32)
    with RMQServer(_oracle_engine(x), ServeConfig(n=8)) as srv:
        res = srv.submit(np.zeros(0, np.int64), np.zeros(0, np.int64)).result(timeout=5)
        assert res.idx.shape == (0,) and res.val.shape == (0,)
    assert srv.stats().n_batches == 0  # never reached the engine


def test_admission_control_backpressure():
    x = np.ones(8, np.float32)
    release = threading.Event()

    def slow_engine(l, r):
        release.wait(30)
        idx = ref.rmq_ref(x, l, r).astype(np.int32)
        return idx, x[idx]

    cfg = ServeConfig(deadline_s=0.0, max_batch=4, max_pending=2, n=8)
    with RMQServer(slow_engine, cfg) as srv:
        one = np.zeros(1, np.int32)
        f1 = srv.submit(one, one)
        f2 = srv.submit(one, one)
        with pytest.raises(ServerOverloaded):
            srv.submit(one, one)  # 2 in flight >= max_pending
        release.set()
        f1.result(timeout=30)
        f2.result(timeout=30)
        # Completion drains in-flight: admission opens again.
        srv.submit(one, one).result(timeout=30)
    st = srv.stats()
    assert st.rejected_requests == 1
    assert st.served_requests == 3


def test_submit_validation():
    x = np.ones(16, np.float32)
    with RMQServer(_oracle_engine(x), ServeConfig(max_batch=8, n=16)) as srv:
        one = np.zeros(1, np.int32)
        with pytest.raises(ValueError):  # l > r
            srv.submit(np.array([5], np.int32), np.array([2], np.int32))
        with pytest.raises(ValueError):  # negative
            srv.submit(np.array([-1], np.int32), one)
        with pytest.raises(ValueError):  # r >= n
            srv.submit(one, np.array([16], np.int32))
        with pytest.raises(TypeError):  # float bounds
            srv.submit(np.array([0.5]), np.array([1.5]))
        with pytest.raises(ValueError):  # oversized vs max_batch
            srv.submit(np.zeros(9, np.int32), np.zeros(9, np.int32))
        with pytest.raises(ValueError):  # shape mismatch
            srv.submit(np.zeros(2, np.int32), np.zeros(3, np.int32))
    # Without a configured n, the int32 index range is still enforced.
    with RMQServer(_oracle_engine(x), ServeConfig()) as unbounded:
        with pytest.raises(ValueError):
            unbounded.submit(np.zeros(1, np.int32), np.array([2**31], np.int64))


def test_submit_after_close_raises():
    x = np.ones(8, np.float32)
    srv = RMQServer(_oracle_engine(x), ServeConfig(n=8)).start()
    srv.close()
    with pytest.raises(ServerClosed):
        srv.submit(np.zeros(1, np.int32), np.zeros(1, np.int32))


def test_engine_failure_fails_batch_but_server_survives():
    calls = []

    def flaky(l, r):
        calls.append(len(l))
        if len(calls) == 1:
            raise RuntimeError("engine down")
        idx = np.zeros(len(l), np.int32)
        return idx, np.zeros(len(l), np.float32)

    with RMQServer(flaky, ServeConfig(deadline_s=0.0, max_batch=8, n=8)) as srv:
        one = np.zeros(1, np.int32)
        bad = srv.submit(one, one)
        with pytest.raises(RuntimeError):
            bad.result(timeout=30)
        ok = srv.submit(one, one).result(timeout=30)  # still serving
        assert ok.idx.shape == (1,)


# --- query-path dtype guard (hybrid dispatch boundary) ----------------------


def test_dispatch_rejects_float_bounds():
    with pytest.raises(TypeError):
        hybrid.dispatch_by_length(
            np.array([0.0]), np.array([1.0]), 4, None, None, np.float32
        )


def test_dispatch_rejects_out_of_int32_bounds():
    with pytest.raises(ValueError):
        hybrid.dispatch_by_length(
            np.array([0], np.int64), np.array([2**31], np.int64), 4, None, None, np.float32
        )
    with pytest.raises(ValueError):
        hybrid.dispatch_by_length(
            np.array([-1], np.int64), np.array([3], np.int64), 4, None, None, np.float32
        )


def test_make_queries_int32_boundary():
    rng = np.random.default_rng(0)
    for dist in ("small", "medium", "large"):
        l, r = make_queries(rng, 1 << 16, 64, dist)
        assert l.dtype == np.int32 and r.dtype == np.int32
        assert (l >= 0).all() and (l <= r).all() and (r < (1 << 16)).all()
    with pytest.raises(ValueError):
        make_queries(rng, 2**31 + 5, 4, "small")


# --- registry capability metadata -------------------------------------------


def test_serveable_names_excludes_oracles():
    names = registry.serveable_names()
    assert "exhaustive" not in names
    assert set(names) <= set(registry.names())
    for flagship in ("hybrid", "sharded_hybrid", "fused128", "distributed"):
        assert flagship in names


def test_capability_metadata_drives_flags():
    sh = registry.get("sharded_hybrid")
    assert "shard_batch" in sh.modes and sh.needs_mesh
    assert {"block_size", "threshold", "mode"} <= set(sh.build_kwargs)
    hy = registry.get("hybrid")
    assert "threshold" in hy.build_kwargs and not hy.needs_mesh and hy.modes == ()
    assert registry.get("distributed").needs_mesh
    assert "block_size" in registry.get("fused128").build_kwargs


def test_build_for_serving_validates_kwargs():
    x = jnp.arange(256.0)
    with pytest.raises(ValueError):
        registry.build_for_serving("lca", x, threshold=7)  # undeclared kwarg
    with pytest.raises(ValueError):
        registry.build_for_serving("sharded_hybrid", x, mode="shard_everything")
    with pytest.raises(ValueError):
        registry.build_for_serving("exhaustive", x)  # not serveable
    state = registry.build_for_serving("hybrid", x, threshold=32)
    assert state.threshold == 32


def test_distributed_registry_engine_matches_oracle():
    rng = np.random.default_rng(6)
    n = 777
    x = rng.integers(0, 5, n).astype(np.float32)
    spec = registry.get("distributed")
    s = spec.build(jnp.asarray(x))
    l, r = _bounded(rng, n, 50)
    idx, val = spec.query(s, l, r)
    gold = ref.rmq_ref(x, l, r)
    np.testing.assert_array_equal(np.asarray(idx), gold)
    np.testing.assert_array_equal(np.asarray(val), x[gold])


# --- PR 7 regressions -------------------------------------------------------


def test_coalesce_rejects_mismatched_inputs():
    """Silent-truncation regression: coalesce used to zip() unequal l/r lists
    (dropping the excess requests' queries on the floor) and accept ragged
    per-request bounds. Both must be loud errors now."""
    good = [np.array([1, 2], np.int32)]
    with pytest.raises(ValueError, match="l-arrays vs"):
        batcher.coalesce(good + [np.array([3], np.int32)], [np.array([4, 5], np.int32)])
    with pytest.raises(ValueError, match="equal-length"):
        batcher.coalesce(good, [np.array([4, 5, 6], np.int32)])
    with pytest.raises(ValueError, match="1-D"):
        batcher.coalesce([np.array([[1]], np.int32)], [np.array([[2]], np.int32)])


def test_poisson_client_streams_do_not_collide_across_seeds():
    """Seed-collision regression: client c under base seed s used to draw
    from default_rng(s + c), so (seed=0, client=1) and (seed=1, client=0)
    shared a stream. Sequence seeding must keep every (seed, client) pair
    independent."""
    from repro.serve.workload import run_poisson_clients

    def collect(seed):
        reqs = {}

        def make_request(rng, c):
            reqs.setdefault(c, []).append(rng.integers(0, 1 << 30, 4).tolist())
            return np.zeros(1, np.int32), np.zeros(1, np.int32)

        def submit(_l, _r):
            return None

        run_poisson_clients(2, 3, 0.0, make_request, submit, seed=seed)
        return reqs

    a = collect(0)
    b = collect(1)
    assert a[1] != b[0]  # the old seed+c scheme made exactly these equal
    assert a[0] != a[1] and b[0] != b[1]  # clients within a run independent


def test_submit_min_version_gates_on_stale_servers():
    from repro.serve import StaleVersion
    from repro.update import DeltaLog
    from repro.update.engines import make_online

    x = np.arange(64.0, dtype=np.float32)
    online = make_online("sparse_table", x)
    with RMQServer(online=online, config=ServeConfig(deadline_s=1e-4)) as srv:
        l = np.array([0], np.int32)
        r = np.array([63], np.int32)
        res = srv.submit(l, r, min_version=0).result(timeout=60)
        assert res.version == 0
        with pytest.raises(StaleVersion):
            srv.submit(l, r, min_version=1)
        log = DeltaLog()
        log.point(3, -1.0)
        srv.submit_update(log).result(timeout=60)
        res = srv.submit(l, r, min_version=1).result(timeout=60)
        assert res.version >= 1 and res.idx[0] == 3
    # min_version is meaningless without an MVCC engine.
    with RMQServer(_oracle_engine(x), ServeConfig(deadline_s=1e-4)) as srv:
        with pytest.raises(ValueError):
            srv.submit(l, r, min_version=0)
