"""The megakernel autotuner (kernels/tuning.py) and its persistent cache.

Mirrors tests/test_calibration.py: deterministic sweeps via a fake
``hybrid._measure`` (the one timing seam), cache hit / miss / stale /
corrupt behavior through ``calib_cache``'s generic entries, and the
determinism contract — untuned/default paths never touch the cache and stay
bit-identical before vs after a cache write.
"""

import json

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import build as build_mod
from repro.core import calib_cache, hybrid
from repro.kernels import tuning


def _fail_measure(*a, **k):
    pytest.fail("timing sweep ran despite a warm cache / default policy")


# --- candidate product -------------------------------------------------------


def test_candidate_configs_pinned_block_size():
    cands = tuning.candidate_configs(1 << 12, 128)
    assert all(c.block_size == 128 for c in cands)
    assert len(cands) == len(set(cands)) == len(tuning.TUNE_TILES) * 2
    # The resolved default is always a member (the winner can't lose to it).
    nb = (1 << 12) // 128
    default = tuning.KernelConfig(
        tuning.DEFAULT_TILE, tuning.resolve_fetch("auto", nb), 128
    )
    assert default in cands


def test_candidate_configs_exclude_resident_past_ceiling():
    n = (tuning.RESIDENT_NB_CEILING + 1) * 128  # nb just past the ceiling
    cands = tuning.candidate_configs(n, 128)
    assert cands and all(c.fetch == "dma" for c in cands)


def test_candidate_configs_sweep_block_sizes_by_default():
    cands = tuning.candidate_configs(1 << 12)
    assert {c.block_size for c in cands} == set(tuning.TUNE_BLOCK_SIZES)


def test_resolve_fetch():
    assert tuning.resolve_fetch("auto", tuning.RESIDENT_NB_CEILING) == "resident"
    assert tuning.resolve_fetch("auto", tuning.RESIDENT_NB_CEILING + 1) == "dma"
    assert tuning.resolve_fetch("dma", 4) == "dma"
    with pytest.raises(ValueError):
        tuning.resolve_fetch("mmap", 4)


# --- key + entry schema ------------------------------------------------------


def test_tuning_key_namespace_and_fields():
    key = tuning.tuning_key(65536, 4096, backend="tpu", n_devices=8)
    assert key == "kernel/n=65536/batch=4096/backend=tpu/ndev=8"
    # Disjoint from the threshold keys in the same file.
    assert not key.startswith("n=")
    others = {
        tuning.tuning_key(65537, 4096, backend="tpu", n_devices=8),
        tuning.tuning_key(65536, 2048, backend="tpu", n_devices=8),
        tuning.tuning_key(65536, 4096, backend="cpu", n_devices=8),
        tuning.tuning_key(65536, 4096, backend="tpu", n_devices=1),
    }
    assert key not in others and len(others) == 4


def test_config_from_entry_rejects_malformed():
    good = {"tile": 8, "fetch": "dma", "block_size": 128}
    assert tuning.config_from_entry(good) == tuning.KernelConfig(8, "dma", 128)
    for bad in (
        None,
        41,
        "dma",
        {"tile": 8},
        {"tile": 8, "fetch": "mmap", "block_size": 128},
        {"tile": 0, "fetch": "dma", "block_size": 128},
        {"tile": 8, "fetch": "dma", "block_size": 100},
        {"tile": "x", "fetch": "dma", "block_size": 128},
    ):
        assert tuning.config_from_entry(bad) is None, bad


# --- sweep + autotune via the fake timing seam -------------------------------


def _fake_measure_preferring(want):
    """A deterministic _measure: the wanted config times fastest."""

    def fake(kind, fn, lj, rj, repeats):
        tag = f"kernel/tile={want.tile}/fetch={want.fetch}/bs={want.block_size}"
        return 0.5 if kind == tag else 1.0

    return fake


def test_autotune_picks_the_fastest_candidate(monkeypatch):
    want = tuning.KernelConfig(16, "dma", 128)
    monkeypatch.setattr(hybrid, "_measure", _fake_measure_preferring(want))
    got = tuning.autotune(1 << 12, 64, block_size=128, interpret=True)
    assert got == want


def test_autotune_tie_breaks_deterministically(monkeypatch):
    """All-equal timings: the first candidate in product order wins, so the
    tuned result is reproducible on a machine with flat measurements."""
    monkeypatch.setattr(hybrid, "_measure", lambda *a, **k: 1.0)
    cands = tuning.candidate_configs(1 << 12, 128)
    got = tuning.autotune(1 << 12, 64, block_size=128, interpret=True)
    assert got == cands[0]


def test_sweep_times_every_candidate_through_the_seam(monkeypatch):
    seen = []
    monkeypatch.setattr(
        hybrid, "_measure", lambda kind, *a, **k: seen.append(kind) or 1.0
    )
    results = tuning.sweep(1 << 12, 64, block_size=128, interpret=True)
    assert len(results) == len(seen) == len(tuning.candidate_configs(1 << 12, 128))


# --- persistent cache lifecycle ---------------------------------------------


def test_tuned_policy_sweeps_once_then_hits(tmp_path, monkeypatch):
    p = tmp_path / "cal.json"
    want = tuning.KernelConfig(4, "resident", 128)
    monkeypatch.setattr(hybrid, "_measure", _fake_measure_preferring(want))
    kw = dict(block_size=128, backend="cpu", n_devices=1, path=p)
    cfg = tuning.get_config(1 << 12, 64, policy="tuned", interpret=True, **kw)
    assert cfg == want
    # Persisted under the kernel/ namespace as a JSON dict.
    key = tuning.tuning_key(1 << 12, 64, backend="cpu", n_devices=1)
    assert calib_cache.load_entry(key, path=p) == dict(want._asdict())
    # Warm cache: zero timing sweeps.
    monkeypatch.setattr(hybrid, "_measure", _fail_measure)
    cfg2 = tuning.get_config(1 << 12, 64, policy="tuned", **kw)
    assert cfg2 == want


def test_cached_policy_never_measures(tmp_path, monkeypatch):
    p = tmp_path / "cal.json"
    monkeypatch.setattr(hybrid, "_measure", _fail_measure)
    kw = dict(block_size=128, backend="cpu", n_devices=1, path=p)
    # Miss: default fallback, no sweep.
    assert tuning.get_config(1 << 12, 64, policy="cached", **kw) == (
        tuning.default_config(128)
    )
    # Hit: the stored winner.
    key = tuning.tuning_key(1 << 12, 64, backend="cpu", n_devices=1)
    calib_cache.store_entry(key, {"tile": 16, "fetch": "dma", "block_size": 128}, p)
    assert tuning.get_config(1 << 12, 64, policy="cached", **kw) == (
        tuning.KernelConfig(16, "dma", 128)
    )


def test_stale_version_and_corrupt_entries_are_misses(tmp_path, monkeypatch):
    p = tmp_path / "cal.json"
    key = tuning.tuning_key(1 << 12, 64, backend="cpu", n_devices=1)
    monkeypatch.setattr(hybrid, "_measure", _fail_measure)
    kw = dict(block_size=128, backend="cpu", n_devices=1, path=p)
    # Stale file version: every entry is a miss.
    p.write_text(
        json.dumps(
            {
                "version": calib_cache.CACHE_VERSION + 1,
                "entries": {key: {"tile": 16, "fetch": "dma", "block_size": 128}},
            }
        )
    )
    assert tuning.get_config(1 << 12, 64, policy="cached", **kw) == (
        tuning.default_config(128)
    )
    # Corrupt file: miss, and a later store recovers it.
    p.write_text("definitely{not json")
    assert tuning.get_config(1 << 12, 64, policy="cached", **kw) == (
        tuning.default_config(128)
    )
    calib_cache.store_entry(key, {"tile": 4, "fetch": "dma", "block_size": 128}, p)
    assert tuning.get_config(1 << 12, 64, policy="cached", **kw) == (
        tuning.KernelConfig(4, "dma", 128)
    )
    # Malformed entry under a valid version: miss, not a crash.
    calib_cache.store_entry(key, {"tile": "eight"}, p)
    assert tuning.get_config(1 << 12, 64, policy="cached", **kw) == (
        tuning.default_config(128)
    )


def test_threshold_and_kernel_entries_share_one_file(tmp_path):
    """The kernel/ namespace coexists with int thresholds in the same file."""
    p = tmp_path / "cal.json"
    tkey = calib_cache.cache_key(1024, 128, backend="cpu", n_devices=1)
    kkey = tuning.tuning_key(1024, 64, backend="cpu", n_devices=1)
    calib_cache.store(tkey, 77, path=p)
    calib_cache.store_entry(kkey, {"tile": 8, "fetch": "dma", "block_size": 128}, p)
    assert calib_cache.load(tkey, path=p) == 77
    assert tuning.config_from_entry(calib_cache.load_entry(kkey, path=p)) == (
        tuning.KernelConfig(8, "dma", 128)
    )


# --- determinism: untuned paths are machine-state independent ----------------


def test_default_policy_never_touches_the_cache(tmp_path, monkeypatch):
    monkeypatch.setattr(hybrid, "_measure", _fail_measure)
    monkeypatch.setattr(
        calib_cache, "load_entry", lambda *a, **k: pytest.fail("cache read")
    )
    assert tuning.get_config(1 << 12, 64, policy=None) == tuning.default_config(128)
    assert tuning.get_config(
        1 << 12, 64, policy=None, block_size=256
    ) == tuning.default_config(256)


def test_untuned_build_bit_identical_before_and_after_cache_write(
    tmp_path, monkeypatch
):
    """kernel_config=None builds must not see a cache write (machine-state
    independence: the default path gives the same bits on every host)."""
    monkeypatch.setenv(calib_cache.ENV_VAR, str(tmp_path / "cal.json"))
    rng = np.random.default_rng(21)
    n = 2048
    x = jnp.asarray(rng.integers(0, 4, n).astype(np.float32))
    a = rng.integers(0, n, 64)
    b = rng.integers(0, n, 64)
    l, r = jnp.asarray(np.minimum(a, b)), jnp.asarray(np.maximum(a, b))

    def run():
        state, cfg = build_mod.build("fused", x, block_size=128)
        from repro import kernels

        i, v = kernels.ops.query(state, l, r, config=cfg, interpret=True)
        return cfg, np.asarray(i), np.asarray(v)

    cfg1, i1, v1 = run()
    # A tuned winner lands in the cache (different geometry than the default).
    calib_cache.store_entry(
        tuning.tuning_key(n, backend="cpu", n_devices=1),
        {"tile": 16, "fetch": "dma", "block_size": 256},
        tmp_path / "cal.json",
    )
    cfg2, i2, v2 = run()
    assert cfg1 == cfg2 == tuning.default_config(128)
    np.testing.assert_array_equal(i1, i2)
    np.testing.assert_array_equal(v1, v2)


def test_fused_plan_carries_resolved_config(tmp_path):
    """The BuildPlan meta exposes the resolved geometry (serving prints it,
    warmup and benchmarks read it)."""
    plan = build_mod.plan_for("fused", 4096, kernel_config=(4, "dma", 128))
    assert plan.meta["kernel_config"] == tuning.KernelConfig(4, "dma", 128)
    assert plan.meta["block_size"] == 128
    # A tuned config's block size drives the build when none is pinned.
    plan2 = build_mod.plan_for("fused", 4096, kernel_config=(8, "auto", 256))
    assert plan2.meta["block_size"] == 256


def test_pinned_dma_variant_survives_serving_policy(tmp_path, monkeypatch):
    """The fused128_dma registry engine pins fetch="dma"; the serving layer's
    cached/tuned policy kwarg must not silently unpin it."""
    from repro.core import registry

    monkeypatch.setenv(calib_cache.ENV_VAR, str(tmp_path / "cal.json"))
    plan = registry.plan_for_serving("fused128_dma", 4096, kernel_config="cached")
    assert plan.meta["kernel_config"] == tuning.KernelConfig(8, "dma", 128)
    # The unpinned engine honors the policy (cold cache -> default).
    plan2 = registry.plan_for_serving("fused128", 4096, kernel_config="cached")
    assert plan2.meta["kernel_config"] == tuning.default_config(128)


def test_hybrid_kernel_config_resolved_only_with_kernels(tmp_path, monkeypatch):
    monkeypatch.setattr(hybrid, "_measure", _fail_measure)
    plan = build_mod.plan_for("hybrid", 4096, use_kernels=False, kernel_config=None)
    assert plan.meta["kernel_config"] is None
    plan2 = build_mod.plan_for("hybrid", 4096, use_kernels=True, kernel_config=None)
    assert plan2.meta["kernel_config"] == tuning.default_config(128)
