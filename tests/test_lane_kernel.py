"""Sweep for the fused lane-RMQ Pallas kernel vs the pure-jnp engine/oracle."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import lane_rmq, ref
from repro.kernels import ops


@pytest.mark.parametrize("n", [64, 130, 1000, 4096])
@pytest.mark.parametrize("dtype", [np.float32, np.int32])
def test_lane_query_kernel_matches_oracle(n, dtype, rng):
    x = rng.integers(0, 25, n).astype(dtype)
    b = 64
    l = rng.integers(0, n, b)
    r = rng.integers(0, n, b)
    l, r = np.minimum(l, r), np.maximum(l, r)
    s = lane_rmq.build(jnp.asarray(x))
    gi, gv = ops.lane_query(s, jnp.asarray(l), jnp.asarray(r), interpret=True)
    gold = ref.rmq_ref(x, l, r)
    np.testing.assert_array_equal(np.asarray(gi), gold)
    np.testing.assert_allclose(np.asarray(gv).astype(np.float64), x[gold].astype(np.float64))


def test_lane_query_kernel_matches_jnp_engine(rng):
    n = 3000
    x = rng.standard_normal(n).astype(np.float32)
    b = 128
    l = rng.integers(0, n, b)
    r = rng.integers(0, n, b)
    l, r = np.minimum(l, r), np.maximum(l, r)
    s = lane_rmq.build(jnp.asarray(x))
    i1, v1 = ops.lane_query(s, jnp.asarray(l), jnp.asarray(r), interpret=True)
    i2, v2 = lane_rmq.query(s, jnp.asarray(l), jnp.asarray(r))
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))
