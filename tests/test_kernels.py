"""Pallas kernel sweeps: shapes x dtypes, assert_allclose vs ref.py oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ref as core_ref
from repro.core.block_rmq import maxval
from repro.kernels import block_min, ops, rmq_partials
from repro.kernels import ref as kref

SHAPES = [(4, 128), (7, 128), (16, 256), (3, 512), (32, 128)]
DTYPES = [jnp.float32, jnp.int32, jnp.bfloat16]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_block_min_kernel(shape, dtype, rng):
    nb, bs = shape
    x = rng.integers(-100, 100, (nb, bs)).astype(np.float32)
    xj = jnp.asarray(x).astype(dtype)
    val, idx = block_min(xj, interpret=True)
    gval, gidx = kref.block_min_ref(xj)
    np.testing.assert_allclose(np.asarray(val), np.asarray(gval))
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(gidx))


@pytest.mark.parametrize("tile_rows", [1, 3, 8])
def test_block_min_tiling(tile_rows, rng):
    x = jnp.asarray(rng.standard_normal((13, 128)).astype(np.float32))
    val, idx = block_min(x, tile_rows=tile_rows, interpret=True)
    gval, gidx = kref.block_min_ref(x)
    np.testing.assert_allclose(np.asarray(val), np.asarray(gval))
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(gidx))


@pytest.mark.parametrize("shape", [(8, 128), (4, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.int32])
def test_rmq_partials_kernel(shape, dtype, rng):
    nb, bs = shape
    x = rng.integers(0, 40, (nb, bs)).astype(np.float32)
    xj = jnp.asarray(x).astype(dtype)
    b = 64
    bl = rng.integers(0, nb, b)
    br = np.minimum(bl + rng.integers(0, nb, b), nb - 1)
    bl, br = np.minimum(bl, br), np.maximum(bl, br)
    ls = rng.integers(0, bs, b)
    re = rng.integers(0, bs, b)
    le = np.where(bl == br, np.maximum(ls, re), bs - 1)
    re2 = np.where(bl == br, np.maximum(ls, re), re)
    args = [jnp.asarray(a, jnp.int32) for a in (bl, br, ls, le, re2)]
    val, idx = rmq_partials(xj, *args, interpret=True)
    gval, gidx = kref.rmq_partials_ref(xj, *args)
    np.testing.assert_allclose(
        np.asarray(val).astype(np.float32), np.asarray(gval).astype(np.float32)
    )
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(gidx))


@pytest.mark.parametrize("n,bs", [(1000, 128), (4096, 256), (700, 128), (130, 128)])
def test_kernelized_engine_end_to_end(n, bs, rng):
    x = rng.integers(0, 30, n).astype(np.float32)
    l = rng.integers(0, n, 64)
    r = rng.integers(0, n, 64)
    l, r = np.minimum(l, r), np.maximum(l, r)
    s = ops.build(jnp.asarray(x), bs, interpret=True)
    idx, val = ops.query(s, jnp.asarray(l), jnp.asarray(r), interpret=True)
    gold = core_ref.rmq_ref(x, l, r)
    np.testing.assert_array_equal(np.asarray(idx), gold)
    np.testing.assert_allclose(np.asarray(val), x[gold])


def test_kernel_vs_pure_jnp_engine(rng):
    """ops.query must agree with core.block_rmq.query bit-for-bit."""
    from repro.core import block_rmq

    n = 3000
    x = rng.standard_normal(n).astype(np.float32)
    l = rng.integers(0, n, 128)
    r = rng.integers(0, n, 128)
    l, r = np.minimum(l, r), np.maximum(l, r)
    s1 = ops.build(jnp.asarray(x), 128, interpret=True)
    s2 = block_rmq.build(jnp.asarray(x), 128)
    i1, v1 = ops.query(s1, jnp.asarray(l), jnp.asarray(r), interpret=True)
    i2, v2 = block_rmq.query(s2, jnp.asarray(l), jnp.asarray(r))
    np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
    np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))
