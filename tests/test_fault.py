"""Crash-safety tests: WAL, checkpoints, restore, supervision, fault injection.

The durability half proves the subsystem's core claim — restore (latest
checkpoint + journal-suffix replay) is bit-identical to the never-crashed
engine — for EVERY updatable registry engine, including the 8-fake-device
sharded ones (subprocess, same pattern as tests/test_update.py), and keeps
holding when the journal tail is torn mid-record or a checkpoint write dies
half-way. The serving half exercises the supervised worker pool: a crashed
worker fails only its own batch (typed + retryable), the supervisor restarts
it, the circuit breaker trips to the degraded pure-jnp fallback and closes
again after a health probe, and ``close(timeout=)`` never leaves a client
future hanging.
"""

import os
import subprocess
import sys
import textwrap
import time

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import checkpoint as ckpt_mod
from repro import update
from repro.core import ref, registry
from repro.fault import (
    DegradedFallback,
    DurableEngine,
    FaultPlan,
    FaultSpec,
    InjectedFault,
    Journal,
)
from repro.serve import (
    DeadlineExceeded,
    EngineFailure,
    RMQServer,
    ServeConfig,
    ServerClosed,
)
from repro.update.deltas import DeltaBatch, DeltaLog

SINGLE_HOST_UPDATABLE = [
    n for n in registry.updatable_names() if not registry.get(n).needs_mesh
]


def _array_leaves(state):
    return [
        np.asarray(a)
        for a in jax.tree_util.tree_leaves(state)
        if hasattr(a, "shape")
    ]


def _assert_states_equal(a, b, ctx=""):
    la, lb = _array_leaves(a), _array_leaves(b)
    assert len(la) == len(lb), ctx
    for x, y in zip(la, lb):
        assert x.shape == y.shape and np.array_equal(x, y), (ctx, x.shape)


def _mutations(n):
    """Point writes, a leftmost-tie flip, a range fill, and an append."""
    return [
        DeltaLog().point(0, -3.0).point(n - 1, -3.0),
        DeltaLog().fill(n // 4, n // 4 + 70, 0.125),
        DeltaLog().append(np.arange(5, dtype=np.float32)),
    ]


# --- fault plan determinism ---------------------------------------------------


def test_fault_plan_exact_invocations():
    plan = FaultPlan(seed=3, specs={"worker_query": FaultSpec(at=(2, 4))})
    fired = []
    for i in range(1, 6):
        try:
            plan.check("worker_query")
        except InjectedFault as e:
            fired.append((i, e.count, e.site, e.kind))
    assert [f[0] for f in fired] == [2, 4]
    assert all(f[0] == f[1] for f in fired)
    assert fired[0][2:] == ("worker_query", "error")


def test_fault_plan_rate_is_seed_deterministic():
    def firings(seed):
        plan = FaultPlan(seed=seed, specs={"patch_apply": FaultSpec(rate=0.3)})
        out = []
        for i in range(1, 101):
            try:
                plan.check("patch_apply")
            except InjectedFault:
                out.append(i)
        return out

    a, b, c = firings(11), firings(11), firings(12)
    assert a == b and a  # same seed -> same schedule, and it does fire
    assert a != c  # different seed -> different schedule


def test_fault_plan_rejects_unknown_site():
    with pytest.raises(ValueError):
        FaultPlan(specs={"nope": FaultSpec(rate=1.0)})


# --- WAL ----------------------------------------------------------------------


def _batch(seq_marker, n_old=8):
    log = DeltaLog().point(0, float(seq_marker))
    return log.coalesce(n_old, np.float32)


def test_journal_roundtrip_and_replay_dedup(tmp_path):
    path = str(tmp_path / "j.wal")
    j = Journal(path)
    j.append(1, _batch(1.0))
    j.append(2, _batch(2.0))
    j.append(2, _batch(2.0))  # duplicate seq (crash between append and ack)
    j.append(3, _batch(3.0))
    j.close()

    j2 = Journal(path)
    replayed = j2.replay(after_seq=0)
    assert [s for s, _ in replayed] == [1, 2, 3]  # deduped, in order
    assert all(isinstance(b, DeltaBatch) for _, b in replayed)
    assert float(replayed[1][1].val[0]) == 2.0
    suffix = j2.replay(after_seq=2)
    assert [s for s, _ in suffix] == [3]
    assert j2.last_seq == 3
    j2.close()


def test_journal_abort_marker_skips_seq(tmp_path):
    path = str(tmp_path / "j.wal")
    j = Journal(path)
    j.append(1, _batch(1.0))
    j.append(2, _batch(2.0))
    j.abort(2)  # the apply of seq 2 failed: replay must skip it
    j.append(3, _batch(3.0))
    j.close()
    j2 = Journal(path)
    assert [s for s, _ in j2.replay(after_seq=0)] == [1, 3]
    assert j2.last_seq == 3
    j2.close()


def test_journal_torn_tail_recovery(tmp_path):
    """A crash mid-append leaves a torn record; scan stops at the last
    complete one and the next append overwrites the garbage."""
    path = str(tmp_path / "j.wal")
    j = Journal(path)
    j.append(1, _batch(1.0))
    j.append(2, _batch(2.0))
    j.close()
    good_records = Journal(path)
    good = good_records.replay(after_seq=0)
    good_records.close()

    full = open(path, "rb").read()
    for cut in (len(full) - 1, len(full) - 7, len(full) - (len(full) // 3)):
        torn = str(tmp_path / f"torn{cut}.wal")
        with open(torn, "wb") as f:
            f.write(full[:cut])
        jt = Journal(torn)
        rec = jt.replay(after_seq=0)
        assert [s for s, _ in rec] == [1], cut  # seq 2 torn -> dropped
        assert np.array_equal(rec[0][1].val, good[0][1].val)
        jt.append(9, _batch(9.0))  # append after recovery truncates the tail
        assert [s for s, _ in jt.replay(after_seq=0)] == [1, 9]
        jt.close()

    # Garbled bytes inside the tail record (bit rot) fail the checksum.
    bad = bytearray(full)
    bad[-3] ^= 0xFF
    garbled = str(tmp_path / "garbled.wal")
    with open(garbled, "wb") as f:
        f.write(bytes(bad))
    jg = Journal(garbled)
    assert [s for s, _ in jg.replay(after_seq=0)] == [1]
    jg.close()


def test_journal_truncate_upto_compacts(tmp_path):
    path = str(tmp_path / "j.wal")
    j = Journal(path)
    for s in (1, 2, 3, 4):
        j.append(s, _batch(float(s)))
    j.truncate_upto(2)
    assert [s for s, _ in j.replay(after_seq=0)] == [3, 4]
    assert j.last_seq == 4
    j.truncate_upto(4)
    assert j.replay(after_seq=0) == []
    assert j.last_seq == 4  # seqs never reused, even once compacted away
    j.close()
    assert os.path.getsize(path) == 0


def test_journal_injected_append_fault_keeps_journal_clean(tmp_path):
    """An injected (non-crash) append failure must roll the file back to the
    previous record boundary — no torn bytes for later appends to trip on."""
    plan = FaultPlan(seed=0, specs={"journal_append": FaultSpec(at=(2,))})
    path = str(tmp_path / "j.wal")
    j = Journal(path, fault=plan.check)
    j.append(1, _batch(1.0))
    size1 = os.path.getsize(path)
    with pytest.raises(InjectedFault):
        j.append(2, _batch(2.0))
    assert os.path.getsize(path) == size1
    j.append(3, _batch(3.0))
    assert [s for s, _ in j.replay(after_seq=0)] == [1, 3]
    j.close()


def test_delta_batch_bytes_roundtrip():
    log = DeltaLog().point(3, -1.5).fill(10, 20, 0.25).append(
        np.arange(7, dtype=np.float32)
    )
    batch = log.coalesce(64, np.float32)
    back = DeltaBatch.from_bytes(batch.to_bytes())
    assert np.array_equal(back.idx, batch.idx)
    assert np.array_equal(back.val, batch.val)
    assert np.array_equal(back.tail, batch.tail)
    assert (back.n_old, back.n_new) == (batch.n_old, batch.n_new)


# --- checkpoint + restore, every single-host updatable engine -----------------


@pytest.mark.parametrize("name", SINGLE_HOST_UPDATABLE)
def test_durable_restore_bit_identical(name, tmp_path):
    """Restore = checkpoint + journal suffix, bit-identical to the live
    engine, with version-id continuity — for every updatable engine."""
    rng = np.random.default_rng(5)
    n = 1536
    x = rng.integers(0, 5, n).astype(np.float32)  # small alphabet: real ties
    root = str(tmp_path / name)
    d = DurableEngine.create(name, jnp.asarray(x), root)
    xm = x.copy()
    for i, log in enumerate(_mutations(n)):
        d.apply(log)
        xm = log.coalesce(xm.shape[0], xm.dtype).apply_numpy(xm)
        if i == 0:
            d.checkpoint()  # restore crosses a checkpoint + a journal suffix

    r = DurableEngine.restore(root)
    assert r.current_vid == d.current_vid
    assert r.n == d.n == xm.shape[0]
    assert r.replayed == 2  # the two post-checkpoint batches
    _assert_states_equal(d.online.store.current.state, r.online.store.current.state, name)

    # Replay idempotence: restoring the same root again converges.
    r2 = DurableEngine.restore(root)
    assert r2.current_vid == r.current_vid and r2.seq == r.seq
    _assert_states_equal(r.online.store.current.state, r2.online.store.current.state, name)

    # And the restored engine answers oracle-correct for its version.
    l = rng.integers(0, xm.shape[0], 128)
    rr = rng.integers(0, xm.shape[0], 128)
    l, rr = np.minimum(l, rr), np.maximum(l, rr)
    ver = r.pin()
    idx, val = r.query(ver.state, jnp.asarray(l), jnp.asarray(rr))
    r.release(ver.vid)
    gold = ref.rmq_ref(xm, l, rr)
    assert np.array_equal(np.asarray(idx), gold), name
    assert np.array_equal(np.asarray(val), xm[gold]), name
    d.close(), r.close(), r2.close()


def test_durable_restore_survives_torn_journal_tail(tmp_path):
    """Crash mid-journal-append: the torn record's update was never
    acknowledged, so restore lands exactly on the last acked state."""
    rng = np.random.default_rng(6)
    x = rng.standard_normal(512).astype(np.float32)
    root = str(tmp_path / "torn")
    d = DurableEngine.create("hybrid", jnp.asarray(x), root)
    d.apply(DeltaLog().point(5, -9.0))
    vid_acked = d.current_vid
    d.close()

    # A crash-kind journal fault leaves torn bytes mid-record on disk.
    plan = FaultPlan(seed=0, specs={"journal_append": FaultSpec(at=(1,), kind="crash")})
    base = DurableEngine.restore(root)
    base_online = base.online
    base.close()
    d2 = DurableEngine(base_online, root, fault=plan.check)
    with pytest.raises(InjectedFault):
        d2.apply(DeltaLog().point(6, -9.0))
    d2.close()

    r = DurableEngine.restore(root)
    assert r.current_vid == vid_acked  # torn (unacked) update is gone
    assert r.replayed == 1
    xm = x.copy()
    xm[5] = -9.0
    assert np.isclose(np.asarray(r.online.store.current.x_host)[5], -9.0)
    assert np.array_equal(np.asarray(r.online.store.current.x_host), xm)
    r.close()


def test_failed_checkpoint_leaves_journal_authoritative(tmp_path):
    """An injected checkpoint_write failure leaves a torn temp dir that
    latest_step ignores; restore replays from the previous checkpoint."""
    plan = FaultPlan(seed=0, specs={"checkpoint_write": FaultSpec(at=(2,))})
    rng = np.random.default_rng(7)
    x = rng.standard_normal(512).astype(np.float32)
    root = str(tmp_path / "ck")
    d = DurableEngine.create("sparse_table", jnp.asarray(x), root, fault=plan)
    d.apply(DeltaLog().point(1, -1.0))
    with pytest.raises(InjectedFault):
        d.checkpoint()  # invocation 2: dies after leaf writes
    assert ckpt_mod.latest_step(d.ckpt_dir) == 0  # only the base checkpoint
    assert os.path.getsize(os.path.join(root, "journal.wal")) > 0  # uncompacted
    d.apply(DeltaLog().point(2, -2.0))
    r = DurableEngine.restore(root)
    assert r.replayed == 2 and r.current_vid == d.current_vid
    _assert_states_equal(d.online.store.current.state, r.online.store.current.state)
    d.close(), r.close()


def test_poisoned_engine_recovers_via_replay(tmp_path):
    """Mid-patch failure -> EnginePoisoned (cause + seq); recover() replays
    the journal (aborted seq skipped) and clears the poison."""
    plan = FaultPlan(seed=0, specs={"patch_apply": FaultSpec(at=(2,))})
    rng = np.random.default_rng(8)
    x = rng.standard_normal(1024).astype(np.float32)
    root = str(tmp_path / "poison")
    d = DurableEngine.create("hybrid", jnp.asarray(x), root, fault=plan)
    d.apply(DeltaLog().point(3, -5.0))
    with pytest.raises(InjectedFault):
        d.apply(DeltaLog().point(4, -6.0))  # invocation 2 of patch_apply
    assert d.poisoned
    with pytest.raises(update.EnginePoisoned) as ei:
        d.apply(DeltaLog().point(5, -7.0))
    assert ei.value.seq == 2  # the journaled seq that failed
    assert isinstance(ei.value.cause, InjectedFault)
    assert "fail-stopped" in str(ei.value)

    replayed = d.recover()
    assert not d.poisoned
    assert replayed == 1  # seq 1 replays; aborted seq 2 is skipped
    assert d.current_vid == 1
    res = d.apply(DeltaLog().point(4, -6.0))  # resubmit works post-recovery
    assert res.version == 2
    xm = x.copy()
    xm[3], xm[4] = -5.0, -6.0
    assert np.array_equal(np.asarray(d.online.store.current.x_host), xm)
    d.close()


# --- degraded fallback --------------------------------------------------------


def test_degraded_fallback_matches_oracle():
    rng = np.random.default_rng(9)
    x = rng.integers(0, 4, 2048).astype(np.float32)
    online = update.make_online("hybrid", jnp.asarray(x))
    online.apply(DeltaLog().point(100, -2.0))
    fb = DegradedFallback()
    ver = online.pin()
    l = rng.integers(0, 2048, 64)
    r = np.minimum(2047, l + rng.integers(0, 512, 64))
    idx, val = fb.query(ver, jnp.asarray(l.astype(np.int32)), jnp.asarray(r.astype(np.int32)))
    online.release(ver.vid)
    xm = x.copy()
    xm[100] = -2.0
    gold = ref.rmq_ref(xm, l, r)
    assert np.array_equal(np.asarray(idx), gold)
    assert np.array_equal(np.asarray(val), xm[gold])


# --- supervised serving -------------------------------------------------------


def _serve_x(n=2048, seed=1):
    rng = np.random.default_rng(seed)
    return rng.integers(0, 5, n).astype(np.float32), rng


def test_worker_crash_restart_and_retry_nothing_lost():
    """An injected crash kills the worker thread mid-launch; the supervisor
    restarts it and the batch's requests retry — every answer still exact."""
    x, rng = _serve_x()
    plan = FaultPlan(seed=2, specs={"worker_query": FaultSpec(at=(2,), kind="crash")})
    online = update.make_online("hybrid", jnp.asarray(x))
    cfg = ServeConfig(workers=2, deadline_s=5e-4, max_retries=4,
                      worker_backoff_s=0.005)
    with RMQServer(online=online, fault_plan=plan, config=cfg) as srv:
        futs = []
        for _ in range(12):
            l = rng.integers(0, x.shape[0], 3).astype(np.int32)
            r = np.minimum(x.shape[0] - 1, l + rng.integers(0, 400, 3)).astype(np.int32)
            futs.append((l, r, srv.submit(l, r)))
            time.sleep(0.002)
        for l, r, f in futs:
            res = f.result(timeout=60)
            gold = ref.rmq_ref(x, l, r)
            assert np.array_equal(res.idx, gold)
        st = srv.stats()
    assert st.worker_restarts >= 1
    assert st.retried_requests >= 1
    assert st.failed_requests == 0


def test_engine_failure_is_typed_and_carries_cause():
    x, _ = _serve_x()
    plan = FaultPlan(seed=2, specs={"worker_query": FaultSpec(at=(1,))})
    online = update.make_online("hybrid", jnp.asarray(x))
    cfg = ServeConfig(workers=1, deadline_s=1e-4)  # max_retries=0: fail fast
    with RMQServer(online=online, fault_plan=plan, config=cfg) as srv:
        f = srv.submit(np.zeros(1, np.int32), np.zeros(1, np.int32))
        with pytest.raises(EngineFailure) as ei:
            f.result(timeout=60)
        assert isinstance(ei.value.cause, InjectedFault)
        assert ei.value.retryable
        st = srv.stats()
    assert st.failed_requests == 1


def test_breaker_trips_to_degraded_then_recloses():
    """K consecutive failures open the breaker; launches route to the
    pure-jnp fallback (correct, counted); a health probe recloses it and
    the primary serves again."""
    x, rng = _serve_x()
    # Invocations 1..3 fail (the trip + the first health probe); after that
    # the primary is healthy and the next probe recloses the breaker.
    plan = FaultPlan(seed=2, specs={"worker_query": FaultSpec(at=(1, 2, 3))})
    online = update.make_online("hybrid", jnp.asarray(x))
    cfg = ServeConfig(workers=1, deadline_s=5e-4, max_retries=6,
                      breaker_threshold=2, breaker_cooldown_s=0.005)
    with RMQServer(online=online, fault_plan=plan, config=cfg) as srv:
        def wave(count, gap):
            futs = []
            for _ in range(count):
                l = rng.integers(0, x.shape[0], 2).astype(np.int32)
                r = np.minimum(x.shape[0] - 1, l + rng.integers(0, 300, 2)).astype(np.int32)
                futs.append((l, r, srv.submit(l, r)))
                time.sleep(gap)
            for l, r, f in futs:
                res = f.result(timeout=60)
                gold = ref.rmq_ref(x, l, r)
                assert np.array_equal(res.idx, gold)
                assert np.array_equal(res.val, x[gold])

        wave(10, 0.003)  # trips the breaker, mostly degraded launches
        # Spaced past the cooldown: each launch gets a probe opportunity, so
        # the breaker recloses within the first couple of requests.
        wave(12, 0.02)
        st = srv.stats()
    assert st.breaker_trips >= 1
    assert st.degraded_launches >= 1
    assert st.failed_requests == 0
    # The breaker reclosed: the tail of the traffic ran on the primary.
    assert st.degraded_launches < st.n_batches


def test_request_timeout_expires_stale_requests():
    """A request older than request_timeout_s fails with DeadlineExceeded at
    flush instead of occupying a launch."""
    done = []

    def slow(l, r):
        done.append(l.size)
        time.sleep(0.15)
        return np.zeros(l.size, np.int32), np.zeros(l.size, np.float32)

    cfg = ServeConfig(workers=1, deadline_s=0.3, request_timeout_s=0.05, n=16)
    with RMQServer(query_fn=slow, config=cfg) as srv:
        f = srv.submit(np.zeros(1, np.int32), np.zeros(1, np.int32))
        # Sits in the batcher past its deadline (flush deadline is 0.3s).
        with pytest.raises(DeadlineExceeded):
            f.result(timeout=60)
        st = srv.stats()
    assert st.expired_requests == 1
    assert done == []  # never launched


def test_close_fails_pending_futures():
    """close(timeout=) must not leave a blocked client: leftover futures
    fail with ServerClosed."""
    def wedge(l, r):
        time.sleep(30)
        return np.zeros(l.size, np.int32), np.zeros(l.size, np.float32)

    srv = RMQServer(query_fn=wedge, config=ServeConfig(workers=1, deadline_s=1e-4)).start()
    f = srv.submit(np.zeros(1, np.int32), np.zeros(1, np.int32))
    time.sleep(0.05)
    srv.close(timeout=0.2)
    with pytest.raises(ServerClosed):
        f.result(timeout=1)


def test_close_fails_pending_update_futures():
    """An update still queued behind a wedged one fails with ServerClosed."""
    x, _ = _serve_x(512)

    class SlowOnline:
        def __init__(self, inner):
            self._inner = inner

        def __getattr__(self, k):
            return getattr(self._inner, k)

        def apply(self, deltas, **kw):
            time.sleep(30)
            return self._inner.apply(deltas, **kw)

    online = SlowOnline(update.make_online("sparse_table", jnp.asarray(x)))
    srv = RMQServer(online=online, config=ServeConfig(workers=1, deadline_s=1e-4)).start()
    f1 = srv.submit_update(DeltaLog().point(0, 1.0))
    f2 = srv.submit_update(DeltaLog().point(1, 1.0))
    time.sleep(0.05)
    srv.close(timeout=0.2)
    with pytest.raises(ServerClosed):
        f2.result(timeout=1)
    assert f1.done() or True  # f1 may be mid-apply; f2 must be failed


def test_server_restore_kwarg_serves_restored_engine(tmp_path):
    x, rng = _serve_x(1024)
    root = str(tmp_path / "srvroot")
    d = DurableEngine.create("hybrid", jnp.asarray(x), root)
    d.apply(DeltaLog().point(10, -4.0))
    d.close()
    xm = x.copy()
    xm[10] = -4.0
    with RMQServer(restore=root, config=ServeConfig(workers=1, deadline_s=5e-4)) as srv:
        assert srv._online.current_vid == 1
        l = rng.integers(0, 1024, 16).astype(np.int32)
        r = np.minimum(1023, l + rng.integers(0, 200, 16)).astype(np.int32)
        res = srv.submit(l, r).result(timeout=60)
        gold = ref.rmq_ref(xm, l, r)
        assert np.array_equal(res.idx, gold)
        srv._online.close()


# --- 8-fake-device sharded engines (subprocess) -------------------------------

_CHILD_SHARDED_DURABLE = textwrap.dedent(
    """
    import numpy as np, jax, jax.numpy as jnp, tempfile
    from repro.fault import DurableEngine
    from repro.launch.mesh import make_mesh
    from repro.update.deltas import DeltaLog
    from repro.core import ref

    mesh = make_mesh((8,), ("shard",))
    axes = ("shard",)
    rng = np.random.default_rng(4)
    n = 4096  # 8 shards x 512 cols
    x = rng.integers(0, 4, n).astype(np.float32)

    def leaves(s):
        return [np.asarray(a) for a in jax.tree_util.tree_leaves(s)
                if hasattr(a, "shape")]

    for name, kw in [("distributed", {}),
                     ("sharded_hybrid", {"mode": "shard_structure"})]:
        root = tempfile.mkdtemp()
        d = DurableEngine.create(name, jnp.asarray(x), root,
                                 mesh=mesh, axis_names=axes, **kw)
        xm = x.copy()
        logs = [
            DeltaLog().point(1023, -7.0).point(1024, -7.0),  # shard-boundary tie
            DeltaLog().fill(500, 1600, 0.25),                # 3-shard range
            DeltaLog().append(rng.integers(0, 4, 50).astype(np.float32)),
        ]
        for i, log in enumerate(logs):
            d.apply(log)
            xm = log.coalesce(xm.shape[0], xm.dtype).apply_numpy(xm)
            if i == 0:
                d.checkpoint()
        r = DurableEngine.restore(root, mesh=mesh, axis_names=axes)
        assert r.current_vid == d.current_vid, (name, kw)
        assert r.replayed == 2, (name, kw, r.replayed)
        got = leaves(r.online.store.current.state)
        want = leaves(d.online.store.current.state)
        assert len(got) == len(want), (name, kw)
        for a, b in zip(want, got):
            assert a.shape == b.shape and np.array_equal(a, b), (name, kw, a.shape)
        l = rng.integers(0, xm.shape[0], 200)
        rr = rng.integers(0, xm.shape[0], 200)
        l, rr = np.minimum(l, rr), np.maximum(l, rr)
        ver = r.pin()
        idx, val = r.query(ver.state, jnp.asarray(l), jnp.asarray(rr))
        r.release(ver.vid)
        gold = ref.rmq_ref(xm, l, rr)
        assert np.array_equal(np.asarray(idx), gold), (name, kw)
        assert np.array_equal(np.asarray(val), xm[gold]), (name, kw)
        d.close(); r.close()
    print("SHARDED_DURABLE_OK")
    """
)


def _run_child(code):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = "src"
    return subprocess.run(
        [sys.executable, "-c", code], env=env, capture_output=True, text=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=420,
    )


def test_sharded_durable_restore_on_8_device_mesh():
    """Checkpoint round-trip + journal replay for the mesh engines: restore
    re-runs the deterministic BuildPlan over the saved host array, so the
    restored leaves are bit-identical to the live patched ones."""
    out = _run_child(_CHILD_SHARDED_DURABLE)
    assert "SHARDED_DURABLE_OK" in out.stdout, out.stderr[-3000:]
