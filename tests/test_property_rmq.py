"""Hypothesis property tests on the RMQ system's invariants."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import block_rmq, lane_rmq, ref, sparse_table

arrays = st.lists(
    st.integers(min_value=-1000, max_value=1000), min_size=1, max_size=600
)


@st.composite
def array_and_queries(draw):
    xs = draw(arrays)
    n = len(xs)
    qs = draw(
        st.lists(
            st.tuples(st.integers(0, n - 1), st.integers(0, n - 1)),
            min_size=1,
            max_size=32,
        )
    )
    l = np.array([min(a, b) for a, b in qs])
    r = np.array([max(a, b) for a, b in qs])
    return np.array(xs, np.float32), l, r


@given(array_and_queries())
@settings(max_examples=80, deadline=None)
def test_blocked_matches_oracle(data):
    x, l, r = data
    s = block_rmq.build(jnp.asarray(x), 128)
    idx, val = block_rmq.query(s, jnp.asarray(l), jnp.asarray(r))
    np.testing.assert_array_equal(np.asarray(idx), ref.rmq_ref(x, l, r))


@given(array_and_queries())
@settings(max_examples=80, deadline=None)
def test_lane_matches_oracle(data):
    x, l, r = data
    s = lane_rmq.build(jnp.asarray(x))
    idx, _ = lane_rmq.query(s, jnp.asarray(l), jnp.asarray(r))
    np.testing.assert_array_equal(np.asarray(idx), ref.rmq_ref(x, l, r))


@given(array_and_queries())
@settings(max_examples=60, deadline=None)
def test_rmq_invariants(data):
    """Structural invariants: answer in range; value is the min; leftmost."""
    x, l, r = data
    s = block_rmq.build(jnp.asarray(x), 128)
    idx, val = block_rmq.query(s, jnp.asarray(l), jnp.asarray(r))
    idx = np.asarray(idx)
    val = np.asarray(val)
    assert ((idx >= l) & (idx <= r)).all()
    for q in range(len(l)):
        seg = x[l[q] : r[q] + 1]
        assert val[q] == seg.min()
        assert (seg[: idx[q] - l[q]] > val[q]).all()  # leftmost


@given(arrays)
@settings(max_examples=60, deadline=None)
def test_sparse_table_idempotent_levels(xs):
    """Doubling level k answers must equal oracle for windows 2^k."""
    x = np.array(xs, np.float32)
    st_ = sparse_table.build(jnp.asarray(x))
    n = len(x)
    idx = np.asarray(st_.idx)
    for k in range(idx.shape[0]):
        w = 1 << k
        for i in range(0, n, max(1, n // 7)):
            hi = min(i + w - 1, n - 1)
            assert idx[k, i] == ref.rmq_ref(x, [i], [hi])[0]


@given(st.integers(1, 10_000))
@settings(max_examples=60, deadline=None)
def test_exact_log2(length):
    k = int(sparse_table.exact_log2(jnp.asarray([length], jnp.int32))[0])
    assert (1 << k) <= length < (1 << (k + 1))
