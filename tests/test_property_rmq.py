"""Property tests on the RMQ system's invariants.

Seeded generator loops (hypothesis-style, no hypothesis dependency — the
container does not ship it) sweeping random array lengths, value ranges with
dense ties, and random query batches against the numpy oracle.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import block_rmq, lane_rmq, ref, sparse_table


def _random_cases(seed, cases, max_n=600, max_q=32):
    """Yield (x, l, r) with skewed sizes and dense ties (tie-break stress)."""
    rng = np.random.default_rng(seed)
    for c in range(cases):
        n = int(rng.integers(1, max_n + 1))
        # Narrow value ranges produce many ties; include constant arrays.
        spread = int(rng.choice([0, 1, 3, 1000]))
        x = rng.integers(-spread, spread + 1, n).astype(np.float32)
        q = int(rng.integers(1, max_q + 1))
        a = rng.integers(0, n, q)
        b = rng.integers(0, n, q)
        yield x, np.minimum(a, b), np.maximum(a, b)


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_blocked_matches_oracle(seed):
    for x, l, r in _random_cases(seed, 20):
        s = block_rmq.build(jnp.asarray(x), 128)
        idx, val = block_rmq.query(s, jnp.asarray(l), jnp.asarray(r))
        np.testing.assert_array_equal(np.asarray(idx), ref.rmq_ref(x, l, r))


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_lane_matches_oracle(seed):
    for x, l, r in _random_cases(seed, 20):
        s = lane_rmq.build(jnp.asarray(x))
        idx, _ = lane_rmq.query(s, jnp.asarray(l), jnp.asarray(r))
        np.testing.assert_array_equal(np.asarray(idx), ref.rmq_ref(x, l, r))


@pytest.mark.parametrize("seed", [10, 11, 12])
def test_rmq_invariants(seed):
    """Structural invariants: answer in range; value is the min; leftmost."""
    for x, l, r in _random_cases(seed, 15):
        s = block_rmq.build(jnp.asarray(x), 128)
        idx, val = block_rmq.query(s, jnp.asarray(l), jnp.asarray(r))
        idx = np.asarray(idx)
        val = np.asarray(val)
        assert ((idx >= l) & (idx <= r)).all()
        for q in range(len(l)):
            seg = x[l[q] : r[q] + 1]
            assert val[q] == seg.min()
            assert (seg[: idx[q] - l[q]] > val[q]).all()  # leftmost


@pytest.mark.parametrize("seed", [20, 21])
def test_sparse_table_idempotent_levels(seed):
    """Doubling level k answers must equal oracle for windows 2^k."""
    rng = np.random.default_rng(seed)
    for _ in range(12):
        n = int(rng.integers(1, 600))
        x = rng.integers(-5, 6, n).astype(np.float32)
        st_ = sparse_table.build(jnp.asarray(x))
        idx = np.asarray(st_.idx)
        for k in range(idx.shape[0]):
            w = 1 << k
            for i in range(0, n, max(1, n // 7)):
                hi = min(i + w - 1, n - 1)
                assert idx[k, i] == ref.rmq_ref(x, [i], [hi])[0]


def test_exact_log2():
    rng = np.random.default_rng(7)
    lengths = np.unique(
        np.concatenate(
            [
                rng.integers(1, 10_000, 60),
                [1, 2, 3, 4, 7, 8, 9, 1023, 1024, 1025, 9999],
            ]
        )
    )
    ks = np.asarray(sparse_table.exact_log2(jnp.asarray(lengths, jnp.int32)))
    for length, k in zip(lengths, ks):
        assert (1 << k) <= length < (1 << (k + 1)), (length, k)
