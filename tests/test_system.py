"""End-to-end system tests: training loop, fault tolerance, checkpointing,
data pipeline determinism, optimizer behavior, packing."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint
from repro.configs import get_config, reduce_for_smoke
from repro.data import packing, pipeline
from repro.launch.mesh import make_mesh, set_mesh
from repro.models import model
from repro.optim import adamw, compress
from repro.train import runner as runner_lib
from repro.train.steps import make_train_step

KEY = jax.random.PRNGKey(0)


def _setup(arch="qwen2-1.5b", steps=12):
    cfg = reduce_for_smoke(get_config(arch))
    mesh = make_mesh((1, 1), ("data", "model"))
    params = model.init_params(cfg, KEY)
    opt = adamw.init(params)
    step_fn, _ = make_train_step(
        cfg, mesh, lr_fn=adamw.cosine_schedule(1e-3, 2, steps), batch=4, seq_len=32
    )
    return cfg, mesh, params, opt, step_fn


def test_training_reduces_loss():
    cfg, mesh, params, opt, step_fn = _setup(steps=30)
    with set_mesh(mesh):
        losses = []
        for s in range(30):
            batch = pipeline.synthetic_batch(cfg, 4, 32, seed=7, step=0)  # same batch
            params, opt, m = step_fn(params, opt, batch)
            losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses[:3] + losses[-3:]


def test_runner_fault_recovery(tmp_path):
    """Kill the step twice; the runner must restart from checkpoints and
    finish all steps with deterministic data replay."""
    cfg, mesh, params, opt, step_fn = _setup()
    boom = {8: True, 5: True}

    def fault_hook(step):
        if boom.pop(step, None):
            raise RuntimeError(f"injected node failure at step {step}")

    rcfg = runner_lib.RunnerConfig(
        total_steps=12, ckpt_dir=str(tmp_path), ckpt_every=4, seed=3, max_retries=5
    )
    with set_mesh(mesh):
        report = runner_lib.run_training(
            step_fn, params, opt, cfg, 4, 32, rcfg, fault_hook=fault_hook
        )
    assert report.restarts == 2
    assert report.steps_done >= 12
    assert checkpoint.latest_step(str(tmp_path)) == 12


def test_checkpoint_roundtrip(tmp_path):
    cfg, mesh, params, opt, step_fn = _setup()
    tree = {"params": params, "opt": opt}
    checkpoint.save(str(tmp_path), 5, tree)
    restored = checkpoint.restore(str(tmp_path), 5, tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_atomicity(tmp_path):
    cfg, mesh, params, opt, _ = _setup()
    checkpoint.save(str(tmp_path), 1, {"p": params})
    # a torn write (tmp dir) must be invisible to latest_step
    os.makedirs(os.path.join(str(tmp_path), "step_00000002.tmp"))
    assert checkpoint.latest_step(str(tmp_path)) == 1


def test_checkpoint_async(tmp_path):
    cfg, mesh, params, opt, _ = _setup()
    checkpoint.save(str(tmp_path), 3, {"p": params}, background=True)
    checkpoint.wait_pending()
    assert checkpoint.latest_step(str(tmp_path)) == 3


def test_elastic_restore_resharding(tmp_path):
    """Save under one sharding, restore under another (elastic scaling)."""
    cfg, mesh, params, opt, _ = _setup()
    checkpoint.save(str(tmp_path), 1, {"p": params})
    devs = jax.devices()
    mesh2 = make_mesh((1, len(devs)), ("data", "model"))
    from jax.sharding import NamedSharding, PartitionSpec as P

    sh = jax.tree.map(lambda _: NamedSharding(mesh2, P()), params)
    restored = checkpoint.restore(str(tmp_path), 1, {"p": params}, shardings={"p": sh})
    assert all(
        leaf.sharding.mesh.shape == mesh2.shape
        for leaf in jax.tree.leaves(restored["p"])
    )


def test_data_pipeline_deterministic_replay():
    cfg = reduce_for_smoke(get_config("granite-3-8b"))
    b1 = pipeline.synthetic_batch(cfg, 4, 32, seed=11, step=17)
    b2 = pipeline.synthetic_batch(cfg, 4, 32, seed=11, step=17)
    b3 = pipeline.synthetic_batch(cfg, 4, 32, seed=11, step=18)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]), np.asarray(b2["tokens"]))
    assert not np.array_equal(np.asarray(b1["tokens"]), np.asarray(b3["tokens"]))


def test_packing_uses_rmq_and_fits():
    lengths = pipeline.synthetic_documents(500, 512, seed=0)
    assign, free = packing.pack_documents(lengths, 512)
    assert (assign >= 0).all()
    # capacity never exceeded
    used = np.zeros(free.shape[0], np.int64)
    for d, b in enumerate(assign):
        used[b] += min(lengths[d], 512)
    assert (used <= 512).all()
    # packing efficiency sane vs naive one-doc-per-bin
    assert (used > 0).sum() < len(lengths)


def test_adamw_step_and_clip():
    params = {"w": jnp.ones((4, 4))}
    st = adamw.init(params)
    grads = {"w": jnp.full((4, 4), 100.0)}  # should be clipped
    new_params, st2, m = adamw.update(
        grads, st, lr_fn=lambda s: jnp.float32(0.1), clip_norm=1.0,
        param_dtype=jnp.float32,
    )
    assert float(m["grad_norm"]) == pytest.approx(400.0)
    assert int(st2.step) == 1
    assert not np.allclose(np.asarray(new_params["w"]), 1.0)


def test_grad_compression_error_feedback():
    g = {"w": jnp.asarray(np.random.default_rng(0).standard_normal((64, 64)) * 1e-3)}
    ef = compress.init_ef(g)
    deq, ef2 = compress.ef_compress_grads(g, ef)
    # int8 quantization error is bounded by scale/2 per element
    scale = float(jnp.max(jnp.abs(g["w"]))) / 127.0
    assert float(jnp.max(jnp.abs(deq["w"] - g["w"]))) <= scale * 0.51
    # residual carries the error; applying twice recovers ~all mass
    deq2, _ = compress.ef_compress_grads(jax.tree.map(jnp.zeros_like, g), ef2)
    total = np.asarray(deq["w"] + deq2["w"])
    np.testing.assert_allclose(total, np.asarray(g["w"]), atol=scale)


def test_microbatch_accumulation_matches_single():
    cfg = reduce_for_smoke(get_config("granite-3-8b"))
    mesh = make_mesh((1, 1), ("data", "model"))
    params = model.init_params(cfg, KEY)
    batch = pipeline.synthetic_batch(cfg, 4, 32, seed=0, step=0)
    with set_mesh(mesh):
        s1, _ = make_train_step(cfg, mesh, lr_fn=lambda s: jnp.float32(0.0), batch=4, seq_len=32)
        s2, _ = make_train_step(
            cfg, mesh, lr_fn=lambda s: jnp.float32(0.0), batch=4, seq_len=32, microbatches=2
        )
        p1, _, m1 = s1(params, adamw.init(params), batch)
        p2, _, m2 = s2(params, adamw.init(params), batch)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-5)
