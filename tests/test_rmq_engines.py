"""Core RMQ engines vs. the numpy oracle (exact leftmost-argmin semantics).

Engines are enumerated from ``repro.core.registry`` so every registered
engine — including the fused Pallas megakernel and the range-adaptive hybrid
dispatcher — is swept against the oracle automatically.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import block_rmq, ref, registry


def _queries(rng, n, b):
    l = rng.integers(0, n, b)
    r = rng.integers(0, n, b)
    return np.minimum(l, r), np.maximum(l, r)


ENGINES = list(registry.names())
# Keep the interpret-mode Pallas engine out of the big n-sweep (it is a
# Python emulation off-TPU — functional, but slow); it gets its own sweep in
# tests/test_fused_query.py plus the tie/paper cases below.
SWEEP_ENGINES = [e for e in ENGINES if e != "fused128"]


def _run(engine, x, l, r):
    eng = registry.get(engine)
    s = eng.build(jnp.asarray(x))
    idx, _ = eng.query(s, jnp.asarray(l), jnp.asarray(r))
    return np.asarray(idx)


@pytest.mark.parametrize("engine", SWEEP_ENGINES)
@pytest.mark.parametrize("n", [1, 2, 127, 128, 129, 1000, 4096])
def test_engine_matches_oracle(engine, n, rng):
    x = rng.integers(0, 17, n).astype(np.float32)  # dense ties
    l, r = _queries(rng, n, 200)
    gold = ref.rmq_ref(x, l, r)
    got = _run(engine, x, l, r)
    np.testing.assert_array_equal(got, gold)


@pytest.mark.parametrize("engine", ["block128", "lane", "lca", "hybrid"])
def test_float_values(engine, rng):
    n = 777
    x = rng.standard_normal(n).astype(np.float32)
    l, r = _queries(rng, n, 300)
    np.testing.assert_array_equal(_run(engine, x, l, r), ref.rmq_ref(x, l, r))


def test_all_equal_prefers_leftmost(rng):
    n = 500
    x = np.zeros(n, np.float32)
    l, r = _queries(rng, n, 100)
    for engine in ENGINES:
        got = _run(engine, x, l, r)
        np.testing.assert_array_equal(got, l, err_msg=engine)


def test_paper_example():
    """Section 2: X=[9,2,7,8,4,1,3], RMQ(2,6)=5."""
    x = np.array([9, 2, 7, 8, 4, 1, 3], np.float32)
    for engine in ENGINES:
        got = _run(engine, x, np.array([2]), np.array([6]))
        assert got[0] == 5, engine


def test_block_size_must_be_lane_aligned():
    with pytest.raises(ValueError):
        block_rmq.build(jnp.zeros(100), 100)


def test_unknown_engine_rejected():
    with pytest.raises(ValueError):
        registry.get("definitely-not-an-engine")


@pytest.mark.parametrize("engine", ENGINES)
def test_values_returned_match_indices(engine, rng):
    n = 2048
    x = rng.integers(0, 50, n).astype(np.float32)
    l, r = _queries(rng, n, 100)
    eng = registry.get(engine)
    s = eng.build(jnp.asarray(x))
    idx, val = eng.query(s, jnp.asarray(l), jnp.asarray(r))
    np.testing.assert_allclose(np.asarray(val), x[np.asarray(idx)])
