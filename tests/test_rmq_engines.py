"""Core RMQ engines vs. the numpy oracle (exact leftmost-argmin semantics)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import block_rmq, exhaustive, lane_rmq, lca, ref, sparse_table


def _queries(rng, n, b):
    l = rng.integers(0, n, b)
    r = rng.integers(0, n, b)
    return np.minimum(l, r), np.maximum(l, r)


ENGINES = ["sparse_table", "block128", "block256", "lane", "lca", "exhaustive"]


def _run(engine, x, l, r):
    xj, lj, rj = jnp.asarray(x), jnp.asarray(l), jnp.asarray(r)
    if engine == "sparse_table":
        return np.asarray(sparse_table.query(sparse_table.build(xj), lj, rj))
    if engine == "block128":
        return np.asarray(block_rmq.query(block_rmq.build(xj, 128), lj, rj)[0])
    if engine == "block256":
        return np.asarray(block_rmq.query(block_rmq.build(xj, 256), lj, rj)[0])
    if engine == "lane":
        return np.asarray(lane_rmq.query(lane_rmq.build(xj), lj, rj)[0])
    if engine == "lca":
        return np.asarray(lca.query(lca.build(x), lj, rj))
    if engine == "exhaustive":
        return np.asarray(exhaustive.rmq_exhaustive(xj, lj, rj, query_chunk=64))
    raise ValueError(engine)


@pytest.mark.parametrize("engine", ENGINES)
@pytest.mark.parametrize("n", [1, 2, 127, 128, 129, 1000, 4096])
def test_engine_matches_oracle(engine, n, rng):
    x = rng.integers(0, 17, n).astype(np.float32)  # dense ties
    l, r = _queries(rng, n, 200)
    gold = ref.rmq_ref(x, l, r)
    got = _run(engine, x, l, r)
    np.testing.assert_array_equal(got, gold)


@pytest.mark.parametrize("engine", ["block128", "lane", "lca"])
def test_float_values(engine, rng):
    n = 777
    x = rng.standard_normal(n).astype(np.float32)
    l, r = _queries(rng, n, 300)
    np.testing.assert_array_equal(_run(engine, x, l, r), ref.rmq_ref(x, l, r))


def test_all_equal_prefers_leftmost(rng):
    n = 500
    x = np.zeros(n, np.float32)
    l, r = _queries(rng, n, 100)
    for engine in ENGINES:
        got = _run(engine, x, l, r)
        np.testing.assert_array_equal(got, l, err_msg=engine)


def test_paper_example():
    """Section 2: X=[9,2,7,8,4,1,3], RMQ(2,6)=5."""
    x = np.array([9, 2, 7, 8, 4, 1, 3], np.float32)
    for engine in ENGINES:
        got = _run(engine, x, np.array([2]), np.array([6]))
        assert got[0] == 5, engine


def test_block_size_must_be_lane_aligned():
    with pytest.raises(ValueError):
        block_rmq.build(jnp.zeros(100), 100)


def test_values_returned_match_indices(rng):
    n = 2048
    x = rng.integers(0, 50, n).astype(np.float32)
    l, r = _queries(rng, n, 100)
    s = block_rmq.build(jnp.asarray(x), 128)
    idx, val = block_rmq.query(s, jnp.asarray(l), jnp.asarray(r))
    np.testing.assert_allclose(np.asarray(val), x[np.asarray(idx)])
