"""Replica-fleet tests: rollout propagation, bounded lag, read-your-writes,
regime routing, and crash -> restore -> rejoin (DESIGN.md §11).

In-process tests run small ``hybrid`` fleets on the default single device
(device-group carving is a mesh-engine concern, covered by the 8-fake-device
subprocess test at the bottom). Every query is verified against the host
oracle of the version it was answered at — the same invariant the serve and
chaos suites enforce.
"""

import os
import subprocess
import sys
import textwrap
import time

import numpy as np
import pytest

from repro.fault.inject import FaultPlan, FaultSpec
from repro.serve import ServeConfig
from repro.serve.fleet import FleetConfig, FleetSession, RMQFleet, run_fleet_soak
from repro.update import DeltaLog

N = 2048


def _x(seed=0, n=N):
    return np.random.default_rng(seed).standard_normal(n).astype(np.float32)


def _cfg(**kw):
    kw.setdefault("replicas", 3)
    kw.setdefault("max_version_lag", 2)
    kw.setdefault(
        "server", ServeConfig(workers=1, deadline_s=2e-4, max_retries=8)
    )
    return FleetConfig(**kw)


def _point(i, v):
    log = DeltaLog()
    log.point(i, v)
    return log


def _verify(res, ox, l, r):
    for j in range(l.size):
        seg = ox[l[j] : r[j] + 1]
        assert res.idx[j] == l[j] + int(np.argmin(seg))


# --- config ------------------------------------------------------------------


def test_fleet_config_validation():
    with pytest.raises(ValueError):
        FleetConfig(replicas=0)
    with pytest.raises(ValueError):
        FleetConfig(max_version_lag=0)
    with pytest.raises(ValueError):
        FleetConfig(replicas=2, affinities=("short",))  # wrong arity
    with pytest.raises(ValueError):
        FleetConfig(replicas=2, affinities=("short", "sideways"))
    assert FleetConfig(replicas=4).resolved_affinities() == (
        "short", "long", "short", "long",
    )
    assert FleetConfig(replicas=1).resolved_affinities() == (None,)


def test_session_floor_is_monotonic():
    s = FleetSession()
    assert s.last_vid == -1
    s.observe(3)
    s.observe(1)  # stale observation must not lower the floor
    assert s.last_vid == 3


# --- rollouts ----------------------------------------------------------------


def test_rollout_reaches_every_replica_and_respects_lag_bound():
    x = _x()
    fleet = RMQFleet.build("hybrid", x, config=_cfg())
    try:
        cur = x.copy()
        expected = {fleet.head_vid: cur.copy()}
        for k in range(6):
            i, v = 37 * (k + 1) % N, float(-10.0 - k)
            res = fleet.submit_update(_point(i, v)).result(timeout=60)
            cur[i] = np.float32(v)
            expected[res.version] = cur.copy()
        assert fleet.wait_settled(timeout=60)
        head = fleet.head_vid
        assert head == 6
        # Every replica converged to the head and vids stayed aligned.
        for rep in fleet.replicas:
            assert rep.active
            assert rep.engine.current_vid == head
        assert fleet.tracker.max_lag_seen <= fleet.config.max_version_lag
        # Each replica answers the head oracle through its own server.
        rng = np.random.default_rng(1)
        l = rng.integers(0, N, 16).astype(np.int32)
        r = np.minimum(N - 1, l + rng.integers(0, N // 2, 16)).astype(np.int32)
        for rep in fleet.replicas:
            res = rep.server.submit(l, r, min_version=head).result(timeout=60)
            assert res.version == head
            _verify(res, expected[head], l, r)
    finally:
        fleet.close()


def test_update_future_resolves_at_first_publish_and_raises_session_floor():
    fleet = RMQFleet.build("hybrid", _x(), config=_cfg())
    try:
        sess = fleet.session()
        res = fleet.submit_update(_point(5, -50.0), session=sess).result(timeout=60)
        assert res.version == 1
        # The ack point moved the floor before the future resolved.
        assert sess.last_vid == 1
    finally:
        fleet.close()


def test_append_rollout_raises_routing_floor():
    x = _x()
    fleet = RMQFleet.build("hybrid", x, config=_cfg(replicas=2))
    try:
        tail = np.full(8, -99.0, np.float32)
        log = DeltaLog()
        log.append(tail)
        res = fleet.submit_update(log).result(timeout=60)
        grown = np.concatenate([x, tail])
        # A query past the old length is only valid at the grown version; the
        # front door must route it to a replica that has published it.
        l = np.array([0], np.int32)
        r = np.array([grown.shape[0] - 1], np.int32)
        out = fleet.submit(l, r).result(timeout=60)
        assert out.version >= res.version
        _verify(out, grown, l, r)
        # Beyond the head is a client error, not a routing wait.
        with pytest.raises(ValueError):
            fleet.submit(l, np.array([grown.shape[0]], np.int32))
    finally:
        fleet.close()


def test_read_your_writes_under_forced_lag():
    """One replica is made artificially slow to apply; a session that awaited
    its update must still read it back immediately (routed to a fresh
    replica), every time."""
    x = _x()
    fleet = RMQFleet.build("hybrid", x, config=_cfg(replicas=2, max_version_lag=4))
    try:
        slow = fleet.replicas[1].engine
        real_apply = slow.apply

        def slow_apply(deltas, **kw):
            time.sleep(0.15)
            return real_apply(deltas, **kw)

        slow.apply = slow_apply  # instance attribute shadows the bound method
        sess = fleet.session()
        cur = x.copy()
        for k in range(3):
            i, v = 101 * (k + 1) % N, float(-20.0 - k)
            res = fleet.submit_update(_point(i, v), session=sess).result(timeout=60)
            cur[i] = np.float32(v)
            assert sess.last_vid == res.version
            l = np.array([max(0, i - 3)], np.int32)
            r = np.array([min(N - 1, i + 3)], np.int32)
            out = fleet.submit(l, r, session=sess).result(timeout=60)
            # Never answered below the session floor, and correct at its
            # version (which must include the session's own write).
            assert out.version >= res.version
            _verify(out, cur, l, r)
        assert fleet.wait_settled(timeout=60)
    finally:
        fleet.close()


# --- regime routing ----------------------------------------------------------


def test_regime_routing_prefers_affinity_pools():
    x = _x()
    fleet = RMQFleet.build(
        "hybrid", x, config=_cfg(replicas=2, threshold=32), threshold=32
    )
    try:
        assert fleet.threshold == 32
        assert [rep.affinity for rep in fleet.replicas] == ["short", "long"]
        rng = np.random.default_rng(2)
        for _ in range(8):  # clearly short batches: lengths <= 8
            l = rng.integers(0, N - 8, 4).astype(np.int32)
            r = (l + rng.integers(0, 8, 4)).astype(np.int32)
            _verify(fleet.submit(l, r).result(timeout=60), x, l, r)
        for _ in range(8):  # clearly long batches: lengths >= 256
            l = rng.integers(0, N - 512, 4).astype(np.int32)
            r = (l + 256 + rng.integers(0, 256, 4)).astype(np.int32)
            _verify(fleet.submit(l, r).result(timeout=60), x, l, r)
        st = fleet.stats()
        assert st.requests == 16
        assert st.affinity_hits == 16 and st.affinity_misses == 0
        assert st.routed == (8, 8)  # short pool got the short half, long the long
    finally:
        fleet.close()


def test_majority_regime_classifies_mixed_batches():
    fleet = RMQFleet.build("hybrid", _x(), config=_cfg(replicas=2, threshold=32))
    try:
        l = np.zeros(3, np.int32)
        assert fleet._classify(l, np.array([1, 2, 500], np.int32)) == "short"
        assert fleet._classify(l, np.array([1, 500, 600], np.int32)) == "long"
    finally:
        fleet.close()


# --- crash / restore ---------------------------------------------------------


def test_mid_rollout_crash_auto_revives_with_vid_continuity(tmp_path):
    """The rollout_apply fault kills one replica mid-rollout; auto-revive
    restores it from its WAL (checkpoint + journal, then fleet-history
    catch-up) and it rejoins at the fleet head with its vid timeline
    intact."""
    x = _x()
    # 4th check = first replica picking up rollout 2 (3 replicas).
    plan = FaultPlan(0, {"rollout_apply": FaultSpec(at=(4,))})
    fleet = RMQFleet.build(
        "hybrid", x, config=_cfg(), durable_root=str(tmp_path), fault_plan=plan
    )
    try:
        cur = x.copy()
        expected = {0: cur.copy()}
        for k in range(5):
            i, v = 53 * (k + 1) % N, float(-30.0 - k)
            res = fleet.submit_update(_point(i, v)).result(timeout=60)
            cur[i] = np.float32(v)
            expected[res.version] = cur.copy()
        assert plan.fired()["rollout_apply"] == 1
        # Auto-revive runs on a daemon thread; give it a bounded window.
        deadline = time.monotonic() + 60
        while time.monotonic() < deadline:
            st = fleet.stats()
            if st.restores >= 1 and st.active == 3:
                break
            time.sleep(0.05)
        st = fleet.stats()
        assert st.crashes == 1 and st.restores == 1 and st.active == 3
        assert fleet.wait_settled(timeout=60)
        head = fleet.head_vid
        for rep in fleet.replicas:
            # first_vid continuity: the restored engine continued the SAME
            # timeline (vid == number of rollouts), not a fresh one from 0.
            assert rep.engine.current_vid == head == 5
        l = np.arange(0, 64, dtype=np.int32)
        r = l + 32
        for rep in fleet.replicas:
            res = rep.server.submit(l, r, min_version=head).result(timeout=60)
            _verify(res, expected[head], l, r)
    finally:
        fleet.close()


def test_external_crash_then_restore_catches_up_from_history(tmp_path):
    x = _x()
    fleet = RMQFleet.build(
        "hybrid", x, config=_cfg(), durable_root=str(tmp_path)
    )
    try:
        cur = x.copy()
        res = fleet.submit_update(_point(7, -40.0)).result(timeout=60)
        cur[7] = np.float32(-40.0)
        assert fleet.wait_settled(timeout=60)
        fleet.crash_replica(1)
        assert not fleet.replicas[1].active
        assert 1 not in fleet.tracker.vids()  # dead keys can't wedge the barrier
        # Updates continue without the dead replica (fanout excludes it).
        for k in range(3):
            i, v = 211 * (k + 1) % N, float(-41.0 - k)
            fleet.submit_update(_point(i, v)).result(timeout=60)
            cur[i] = np.float32(v)
        assert fleet.wait_settled(timeout=60)
        fleet.restore_replica(1)
        rep = fleet.replicas[1]
        assert rep.active and rep.restores == 1
        assert rep.engine.current_vid == fleet.head_vid == 4
        l = np.array([0], np.int32)
        r = np.array([N - 1], np.int32)
        res = rep.server.submit(l, r, min_version=4).result(timeout=60)
        _verify(res, cur, l, r)
        # And it takes part in the next rollout normally.
        fleet.submit_update(_point(3, -99.0)).result(timeout=60)
        cur[3] = np.float32(-99.0)
        assert fleet.wait_settled(timeout=60)
        assert rep.engine.current_vid == 5
    finally:
        fleet.close()


def test_restore_replica_requires_durable_root():
    fleet = RMQFleet.build("hybrid", _x(), config=_cfg(replicas=2))
    try:
        fleet.crash_replica(1)
        with pytest.raises(RuntimeError):
            fleet.restore_replica(1)
        # The in-memory fleet keeps serving on the survivor.
        l = np.array([0], np.int32)
        out = fleet.submit(l, np.array([100], np.int32)).result(timeout=60)
        assert out.idx.shape == (1,)
    finally:
        fleet.close()


# --- acceptance soak ---------------------------------------------------------


def test_fleet_soak_in_process():
    """The check.sh gate's soak, scaled down: mutate-while-serving with an
    injected mid-rollout crash AND an external crash + restore; zero lost,
    zero mismatches, zero RYW violations, lag within bound."""
    report = run_fleet_soak(
        engine="hybrid", replicas=3, n=1 << 11, requests=60, updates=4, seed=3
    )
    assert report.ok, report.summary()
    assert report.crashes >= 2 and report.restores >= 2


_CHILD_FLEET8 = textwrap.dedent(
    """
    from repro.serve.fleet import run_fleet_soak
    report = run_fleet_soak(
        engine="sharded_hybrid", replicas=3, n=4096, requests=48, updates=4,
        qbatch=4, seed=1, max_lag=2,
    )
    assert report.ok, report.summary()
    print("FLEET8_OK", report.summary())
    """
)


def _run_child(code):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = "src"
    return subprocess.run(
        [sys.executable, "-c", code], env=env, capture_output=True, text=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=420,
    )


def test_sharded_fleet_on_8_device_mesh():
    """3 sharded_hybrid replicas on disjoint device groups carved from an
    8-fake-device mesh: full soak with crash + restore, oracle-verified."""
    out = _run_child(_CHILD_FLEET8)
    assert "FLEET8_OK" in out.stdout, out.stderr[-3000:]
