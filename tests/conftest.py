"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests must see 1 device;
multi-device tests spawn subprocesses (tests/test_distributed.py) or use the
devices the environment provides."""

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
