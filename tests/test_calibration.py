"""Calibration: degenerate crossover paths (deterministic via a fake timer)
and the persistent threshold cache (hit / miss / stale-key / corrupt)."""

import json

import numpy as np
import pytest

from repro.core import calib_cache, hybrid


# --- hybrid.calibrate degenerate paths ------------------------------------
# calibrate's control flow is driven entirely by hybrid._measure (the only
# timing primitive); swapping it for a constant-per-path fake pins the
# decision at every swept range length.


def test_calibrate_returns_n_when_short_always_wins(monkeypatch):
    monkeypatch.setattr(
        hybrid, "_measure", lambda kind, *a, **k: 0.0 if kind == "short" else 1.0
    )
    # Short path wins at every swept length -> threshold = largest length = n.
    assert hybrid.calibrate(256, batch=8, use_kernels=False, repeats=1) == 256


def test_calibrate_returns_zero_when_long_wins_at_length_one(monkeypatch):
    monkeypatch.setattr(
        hybrid, "_measure", lambda kind, *a, **k: 1.0 if kind == "short" else 0.0
    )
    # Long path wins even at length 1 -> threshold 0 = route everything long.
    assert hybrid.calibrate(256, batch=8, use_kernels=False, repeats=1) == 0


def test_calibrate_reports_interior_crossover(monkeypatch):
    """Long overtakes short above length 16: the last short win is returned."""

    def fake_measure(kind, fn, lj, rj, repeats):
        length = int(np.asarray(rj)[0] - np.asarray(lj)[0] + 1)
        if kind == "short":
            return 1.0
        return 2.0 if length <= 16 else 0.5

    monkeypatch.setattr(hybrid, "_measure", fake_measure)
    thr = hybrid.calibrate(256, batch=8, use_kernels=False, repeats=1)
    # Swept lengths are log-spaced over [1, 256]; the crossover must be the
    # largest swept length <= 16.
    lengths = np.unique(np.geomspace(1, 256, num=8).astype(np.int64).clip(1, 256))
    assert thr == int(lengths[lengths <= 16].max())


def test_calibrate_with_mesh_uses_sharded_constituents(monkeypatch):
    """The mesh path must time the sharded blocked / column-sharded ST paths,
    not the single-host HybridRMQ closures."""
    from repro.core import sharded_hybrid
    from repro.launch.mesh import make_mesh

    built = {}
    real_build = sharded_hybrid.build

    def spy_build(x, mesh=None, axis_names=None, *a, **kw):
        built["mesh"] = mesh
        built["mode"] = kw.get("mode")
        return real_build(x, mesh, axis_names, *a, **kw)

    monkeypatch.setattr(sharded_hybrid, "build", spy_build)
    monkeypatch.setattr(
        hybrid, "_measure", lambda kind, *a, **k: 1.0 if kind == "short" else 0.0
    )
    mesh = make_mesh((1,), ("shard",))
    thr = hybrid.calibrate(
        256, batch=8, repeats=1, mesh=mesh, axis_names=("shard",), mode="shard_batch"
    )
    assert thr == 0  # long wins everywhere -> route everything long
    assert built["mesh"] is mesh and built["mode"] == "shard_batch"


def test_sharded_build_calibrated_passes_mesh_to_calibrate(tmp_path, monkeypatch):
    """threshold="calibrated" on a sharded build must request a sharded-aware
    measurement (mesh + mode forwarded) and persist under the v2
    (n, bs, backend, ndev, mode, mesh) key."""
    import jax.numpy as jnp

    from repro.core import sharded_hybrid

    p = tmp_path / "cal.json"
    seen = {}

    def fake_calibrate(n, **kw):
        seen.update(kw, n=n)
        return 17

    monkeypatch.setattr(hybrid, "calibrate", fake_calibrate)
    s = sharded_hybrid.build(
        jnp.zeros(512, jnp.float32), threshold="calibrated", cache_path=p
    )
    assert s.threshold == 17
    assert seen["mesh"] is not None and seen["mode"] == "shard_structure"
    assert seen["axis_names"] == ("shard",)
    key = calib_cache.cache_key(
        512, 128, n_devices=1, mode="shard_structure", mesh_shape=(1,)
    )
    assert calib_cache.load(key, path=p) == 17
    # The v1 key does NOT own the sharded measurement (that was the bug).
    assert calib_cache.load(calib_cache.cache_key(512, 128, n_devices=1), path=p) is None
    # Second build: cache hit, no re-measurement.
    monkeypatch.setattr(
        hybrid, "calibrate", lambda *a, **k: pytest.fail("re-measured on a hit")
    )
    s2 = sharded_hybrid.build(
        jnp.zeros(512, jnp.float32), threshold="calibrated", cache_path=p
    )
    assert s2.threshold == 17


# --- threshold cache round-trip -------------------------------------------


def test_cache_miss_then_hit_then_other_key_miss(tmp_path):
    p = tmp_path / "cal.json"
    key = calib_cache.cache_key(1024, 128, backend="cpu", n_devices=1)
    assert calib_cache.load(key, path=p) is None  # miss: no file yet
    calib_cache.store(key, 77, path=p)
    assert calib_cache.load(key, path=p) == 77  # hit
    other = calib_cache.cache_key(2048, 128, backend="cpu", n_devices=1)
    assert calib_cache.load(other, path=p) is None  # miss: different key
    dev8 = calib_cache.cache_key(1024, 128, backend="cpu", n_devices=8)
    assert dev8 != key  # device count is part of the key
    assert calib_cache.load(dev8, path=p) is None


def test_cache_stale_version_is_a_miss_and_store_drops_it(tmp_path):
    p = tmp_path / "cal.json"
    key = calib_cache.cache_key(512, 128, backend="cpu", n_devices=1)
    stale_key = "n=99/bs=128/backend=cpu/ndev=1"
    p.write_text(
        json.dumps(
            {"version": calib_cache.CACHE_VERSION + 1, "entries": {stale_key: 5}}
        )
    )
    assert calib_cache.load(stale_key, path=p) is None  # stale format: miss
    calib_cache.store(key, 33, path=p)
    assert calib_cache.load(key, path=p) == 33
    assert calib_cache.load(stale_key, path=p) is None  # old entries dropped
    data = json.loads(p.read_text())
    assert data["version"] == calib_cache.CACHE_VERSION
    assert stale_key not in data["entries"]


def test_cache_corrupt_file_is_a_miss_and_recoverable(tmp_path):
    p = tmp_path / "cal.json"
    p.write_text("definitely{not json")
    key = calib_cache.cache_key(64, 128, backend="cpu", n_devices=1)
    assert calib_cache.load(key, path=p) is None
    calib_cache.store(key, 9, path=p)
    assert calib_cache.load(key, path=p) == 9


# --- cache key v2: distribution mode + mesh shape ---------------------------


def test_cache_key_v2_extends_v1_with_mode_and_mesh():
    v1 = calib_cache.cache_key(1024, 128, backend="cpu", n_devices=8)
    assert v1 == "n=1024/bs=128/backend=cpu/ndev=8"  # unchanged: old entries live
    v2 = calib_cache.cache_key(
        1024, 128, backend="cpu", n_devices=8, mode="shard_2d", mesh_shape=(2, 4)
    )
    assert v2 == "n=1024/bs=128/backend=cpu/ndev=8/mode=shard_2d/mesh=2x4"
    other_mode = calib_cache.cache_key(
        1024, 128, backend="cpu", n_devices=8, mode="shard_batch", mesh_shape=(2, 4)
    )
    other_mesh = calib_cache.cache_key(
        1024, 128, backend="cpu", n_devices=8, mode="shard_2d", mesh_shape=(8,)
    )
    assert len({v1, v2, other_mode, other_mesh}) == 4  # all distinct slots


def test_modes_no_longer_share_one_threshold_slot(tmp_path, monkeypatch):
    """The ROADMAP bug: whichever mode calibrated first used to own the
    threshold for every mode on that mesh size. With key v2 each mode (and
    mesh factoring) resolves its own entry."""
    import jax.numpy as jnp

    from repro.core import sharded_hybrid

    p = tmp_path / "cal.json"
    calib_cache.store(
        calib_cache.cache_key(640, 128, n_devices=1, mode="shard_structure",
                              mesh_shape=(1,)),
        99,
        path=p,
    )
    monkeypatch.setattr(
        hybrid, "calibrate", lambda *a, **k: pytest.fail('"cached" must never measure')
    )
    hit = sharded_hybrid.build(
        jnp.zeros(640, jnp.float32), threshold="cached", cache_path=p
    )
    assert hit.threshold == 99
    other = sharded_hybrid.build(
        jnp.zeros(640, jnp.float32), threshold="cached", cache_path=p,
        mode="shard_batch",
    )
    assert other.threshold == 25  # round(sqrt(640)) fallback, NOT 99


def test_single_host_builds_keep_reading_v1_entries(tmp_path, monkeypatch):
    """hybrid (no mesh, no mode) stays on the v1 key, so entries calibrated
    before the key bump remain valid for single-host builds."""
    import jax.numpy as jnp

    p = tmp_path / "cal.json"
    monkeypatch.setenv(calib_cache.ENV_VAR, str(p))
    calib_cache.store(calib_cache.cache_key(900, 128), 61, path=p)  # v1 key
    monkeypatch.setattr(
        hybrid, "calibrate", lambda *a, **k: pytest.fail("must hit the v1 entry")
    )
    s = hybrid.build(jnp.zeros(900, jnp.float32), 128, threshold="cached",
                     use_kernels=False)
    assert s.threshold == 61


def test_get_threshold_v2_forwards_mode_to_calibrate(tmp_path, monkeypatch):
    p = tmp_path / "cal.json"
    seen = {}
    monkeypatch.setattr(
        hybrid, "calibrate", lambda n, **kw: seen.update(kw) or 13
    )
    thr = calib_cache.get_threshold(
        256, 128, backend="cpu", n_devices=4, mode="shard_2d", mesh_shape=(2, 2),
        path=p,
    )
    assert thr == 13 and seen["mode"] == "shard_2d"
    key = calib_cache.cache_key(
        256, 128, backend="cpu", n_devices=4, mode="shard_2d", mesh_shape=(2, 2)
    )
    assert calib_cache.load(key, path=p) == 13


def test_get_threshold_measures_once_then_hits(tmp_path, monkeypatch):
    p = tmp_path / "cal.json"
    calls = []
    monkeypatch.setattr(
        hybrid, "calibrate", lambda n, **kw: calls.append(n) or 42
    )
    kw = dict(backend="cpu", n_devices=1, path=p)
    assert calib_cache.get_threshold(512, 128, **kw) == 42  # miss -> measures
    assert calib_cache.get_threshold(512, 128, **kw) == 42  # hit -> cached
    assert calls == [512]


def test_build_calibrated_threshold_reads_cache(tmp_path, monkeypatch):
    """hybrid.build(threshold="calibrated") must not re-measure on a hit."""
    import jax.numpy as jnp

    p = tmp_path / "cal.json"
    monkeypatch.setenv(calib_cache.ENV_VAR, str(p))
    key = calib_cache.cache_key(1000, 128)  # live backend/device defaults
    calib_cache.store(key, 21, path=p)
    monkeypatch.setattr(
        hybrid,
        "calibrate",
        lambda *a, **k: pytest.fail("re-measured despite a cache hit"),
    )
    s = hybrid.build(jnp.zeros(1000, jnp.float32), 128, threshold="calibrated",
                     use_kernels=False)
    assert s.threshold == 21


def test_sharded_hybrid_build_reads_cache_without_measuring(tmp_path, monkeypatch):
    import jax.numpy as jnp

    from repro.core import sharded_hybrid

    p = tmp_path / "cal.json"
    key = calib_cache.cache_key(
        777, 128, n_devices=1, mode="shard_structure", mesh_shape=(1,)
    )
    calib_cache.store(key, 55, path=p)
    monkeypatch.setattr(
        hybrid,
        "calibrate",
        lambda *a, **k: pytest.fail('"cached"/None must never measure'),
    )
    s = sharded_hybrid.build(
        jnp.zeros(777, jnp.float32), threshold="cached", cache_path=p
    )
    assert s.threshold == 55
    # "cached" without an entry: sqrt(n) fallback, still no measurement.
    s2 = sharded_hybrid.build(
        jnp.zeros(778, jnp.float32), threshold="cached", cache_path=p
    )
    assert s2.threshold == 28  # round(sqrt(778))
    # Default build is deterministic sqrt(n): machine state stays opt-in.
    s3 = sharded_hybrid.build(jnp.zeros(777, jnp.float32))
    assert s3.threshold == 28  # round(sqrt(777)), NOT the cached 55
