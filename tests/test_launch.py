"""Launch-layer unit tests: sharding rules, specs, roofline parsing."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, SHAPES, get_config
from repro.launch import roofline, sharding
from repro.launch.mesh import make_mesh
from repro.launch.specs import input_specs, model_flops
from repro.models import model as model_lib


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_specs_cover_every_leaf(arch):
    cfg = get_config(arch)
    mesh = make_mesh((1, 1), ("data", "model"))
    shapes = model_lib.param_shapes(cfg)
    specs = sharding.param_specs(cfg, mesh)
    s_leaves = jax.tree.leaves(shapes, is_leaf=lambda x: isinstance(x, tuple))
    p_leaves = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(s_leaves) == len(p_leaves)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_specs_divisible_on_production_shape(arch):
    """Every sharded dim must divide by its axis size on a 16x16-shaped mesh.

    The mesh itself needs 256 devices, so validate the divisibility rule
    directly against the guard logic with fake sizes.
    """
    cfg = get_config(arch)
    shapes = model_lib.param_shapes(cfg)

    sizes = {"data": 16, "model": 16}

    class FakeMesh:
        axis_names = ("data", "model")
        shape = sizes

    specs = sharding.param_specs(cfg, FakeMesh)
    flat_s = jax.tree_util.tree_flatten_with_path(
        shapes, is_leaf=lambda x: isinstance(x, tuple)
    )[0]
    flat_p = jax.tree_util.tree_flatten_with_path(
        specs, is_leaf=lambda x: isinstance(x, P)
    )[0]
    for (path_s, shape), (path_p, spec) in zip(flat_s, flat_p):
        assert path_s == path_p
        for dim, ax in zip(shape, tuple(spec)):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            size = int(np.prod([sizes[a] for a in axes]))
            assert dim % size == 0, (path_s, shape, spec)


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("shape_name", list(SHAPES))
def test_input_specs_wellformed(arch, shape_name):
    cfg = get_config(arch)
    if shape_name == "long_500k" and not cfg.subquadratic:
        pytest.skip("long_500k skipped for full-attention archs")
    specs = input_specs(arch, shape_name)
    for leaf in jax.tree.leaves(specs):
        assert isinstance(leaf, jax.ShapeDtypeStruct)
    assert model_flops(arch, shape_name) > 0


def test_collective_bytes_parser():
    hlo = """
  %ag = bf16[16,1024] all-gather(bf16[1,1024] %x), replica_groups={}
  %ar = f32[256] all-reduce(f32[256] %y), to_apply=%sum
  %rs.1 = f32[8,2] reduce-scatter(f32[64,2] %z), dimensions={0}
  %done = (f32[4]) all-reduce-done(f32[4] %w)
  %cp = u32[10] collective-permute(u32[10] %q)
"""
    out = roofline.collective_bytes(hlo)
    assert out["all-gather"] == 16 * 1024 * 2
    assert out["all-reduce"] == 256 * 4
    assert out["reduce-scatter"] == 8 * 2 * 4
    assert out["collective-permute"] == 10 * 4


def test_roofline_terms_math():
    rl = roofline.roofline_terms(
        arch="a", shape="s", mesh_name="single", chips=256,
        cost={"flops": 197e12, "bytes accessed": 819e9},
        hlo_text="%x = bf16[25000000000,1] all-reduce(bf16[1] %y)",
        model_flops=197e12 * 256,
    )
    assert rl.t_compute == pytest.approx(1.0)
    assert rl.t_memory == pytest.approx(1.0)
    assert rl.t_collective == pytest.approx(1.0)
    assert rl.useful_ratio == pytest.approx(1.0)


def test_cache_spec_long_context():
    """long_500k (batch=1): cache must shard seq over model, not batch."""
    cfg = get_config("gemma3-12b")

    sizes = {"data": 16, "model": 16}

    class FakeMesh:
        axis_names = ("data", "model")
        shape = sizes

    spec = sharding.cache_spec(cfg, FakeMesh, batch=1, capacity=524288)
    assert spec.k[2] == "model"  # seq dim
    assert spec.k[1] is None  # batch=1 unshardable


def test_dp_axes():
    single = make_mesh((1, 1), ("data", "model"))
    assert sharding.dp_axes(single) == ("data",)
