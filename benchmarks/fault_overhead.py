"""Crash-safety overhead benchmarks: WAL journaling + fault-tolerant serving.

Three measurements (DESIGN.md §10):

* **Journaled apply**: median wall time of a single-point
  ``DurableEngine.apply`` (fsynced WAL append + patch + COW publish) vs the
  same apply through a bare ``OnlineEngine`` — the per-update price of
  durability.
* **Serve overhead, journaling on vs off**: an async RMQServer over an
  online ``hybrid`` engine with a concurrent update stream; request
  p50/p99 and sustained throughput with the updates journaled (DurableEngine)
  vs unjournaled. The acceptance bar (tools/check.sh) is <= 10% added p99 in
  this no-fault configuration — journaling sits on the update path, so query
  latency should barely move.
* **1% injected worker faults**: the same serve workload with a seeded
  ``FaultPlan`` crashing ~1% of engine launches (supervisor restarts +
  automatic retries); p50/p99/throughput quantify the cost of riding through
  real failures. ``FAULT_SEED`` is recorded in the run's JSON meta so the
  fault schedule is reproducible.

Each serve configuration runs on four fresh engines and keeps the lowest-p99
run (tail latency on a shared CPU is upward-noisy — scheduler stalls, jit
compiles — so the minimum converges on the true tail); CSV convention:
``name,us_per_call,derived``.
"""

from __future__ import annotations

import shutil
import tempfile
import threading
import time

import jax.numpy as jnp
import numpy as np

from repro import update
from repro.core import build as build_mod
from repro.fault import DurableEngine, FaultPlan, FaultSpec
from repro.serve import RMQServer, ServeConfig
from repro.serve.workload import make_queries, run_poisson_clients

from . import common

# The seed every injected-fault measurement derives from; benchmarks/run.py
# records it in the JSON meta so a regression can be replayed exactly.
FAULT_SEED = 1234


def _sizes():
    if common.SMOKE:
        return 1 << 12, 2, 8, 4  # n, clients, requests/client, updates
    return 1 << 15, 4, 40, 16


def journaled_apply():
    """Single-point apply: bare OnlineEngine vs WAL-journaled DurableEngine."""
    n = (1 << 12) if common.SMOKE else (1 << 16)
    rng = np.random.default_rng(0)
    x = rng.random(n, dtype=np.float32)
    repeats = 5 if common.SMOKE else 15

    def median_apply(eng):
        ts = []
        arng = np.random.default_rng(1)
        eng.apply(update.DeltaLog().point(0, float(x[0])))  # compile
        for _ in range(repeats):
            log = update.DeltaLog().point(int(arng.integers(0, n)), float(arng.random()))
            t0 = time.perf_counter()
            eng.apply(log)
            ts.append(time.perf_counter() - t0)
        return float(np.median(ts))

    plain = update.make_online("hybrid", jnp.asarray(x), threshold=64)
    plain_s = median_apply(plain)
    root = tempfile.mkdtemp(prefix="rmq-bench-wal-")
    try:
        durable = DurableEngine.create("hybrid", jnp.asarray(x), root, threshold=64)
        durable_s = median_apply(durable)
        durable.close()
    finally:
        shutil.rmtree(root, ignore_errors=True)
    over = (durable_s / plain_s - 1.0) * 100 if plain_s > 0 else 0.0
    common.emit(f"fault_overhead/apply_plain_n{n}", plain_s)
    common.emit(
        f"fault_overhead/apply_journaled_n{n}",
        durable_s,
        f"journal overhead {over:+.1f}%",
    )


def _serve_once(online, plan, *, fault_plan=None, max_retries=0, requests=None):
    """One serve run: Poisson clients + concurrent update stream -> stats."""
    n0, clients, default_requests, updates = _sizes()
    requests = default_requests if requests is None else requests
    cfg = ServeConfig(
        deadline_s=1e-3,
        max_batch=1024,
        workers=2,
        max_retries=max_retries,
        worker_backoff_s=0.002,
    )
    srv = RMQServer(
        online=online,
        config=cfg,
        fault_plan=fault_plan,
        warmup_bounds=build_mod.warmup_bounds(plan),
    )
    srv.warmup()
    online.apply(update.DeltaLog().point(0, 0.5))  # compile the patch path
    stop = threading.Event()

    def mutator():
        mrng = np.random.default_rng(9)
        for _ in range(updates):
            if stop.is_set():
                return
            cur_n = online.n
            log = update.DeltaLog().point(int(mrng.integers(0, cur_n)), float(mrng.random()))
            try:
                srv.submit_update(log).result(timeout=120)
            except Exception:
                pass
            time.sleep(0.002)

    with srv:
        mut = threading.Thread(target=mutator, name="bench-mutator")
        mut.start()
        per_client = run_poisson_clients(
            clients,
            requests,
            500.0,
            lambda rng, c: make_queries(rng, n0, 16, "small"),
            srv.submit,
            seed=42,
        )
        for out in per_client:
            for _, fut in out:
                if fut is not None:
                    fut.result(timeout=300)
        stop.set()
        mut.join()
        st = srv.stats()
    return st


def _best_of(make_online_fn, runs=2, **kw):
    """Run the serve config on fresh engines `runs` times; keep the lowest-p99
    run. p99 over a threaded serve on a shared CPU is upward-noisy (scheduler
    stalls, first-run jit compiles); the minimum converges on the true tail."""
    best = None
    for _ in range(runs):
        online, plan, cleanup = make_online_fn()
        try:
            st = _serve_once(online, plan, **kw)
        finally:
            cleanup()
        if best is None or st.p99_total_s < best.p99_total_s:
            best = st
    return best


def _factories():
    """Engine factories for the serve comparison: bare vs WAL-journaled."""
    n0, _, _, _ = _sizes()
    rng = np.random.default_rng(2)
    x = rng.random(n0, dtype=np.float32)

    def plain():
        online = update.make_online("hybrid", jnp.asarray(x), threshold=64)
        return online, online.plan, (lambda: None)

    def journaled():
        root = tempfile.mkdtemp(prefix="rmq-bench-srv-")
        online = DurableEngine.create("hybrid", jnp.asarray(x), root, threshold=64)

        def cleanup():
            online.close()
            shutil.rmtree(root, ignore_errors=True)

        return online, online.plan, cleanup

    return plain, journaled


def p99_gate(runs=5, requests=400):
    """tools/check.sh acceptance bar: best-of-`runs` request p99 with WAL
    journaling on vs off, no injected faults. Returns (plain_s, journaled_s).

    Drives more requests per run than the recorded benchmark so the p99
    estimate has enough tail samples to compare at a 10% tolerance, and
    alternates the two configs so neither systematically runs on a colder
    process (jit caches, page cache) than the other.
    """
    plain, journaled = _factories()
    best = [float("inf"), float("inf")]
    for _ in range(runs):
        for i, make in enumerate((plain, journaled)):
            best[i] = min(
                best[i], _best_of(make, runs=1, requests=requests).p99_total_s
            )
    return best[0], best[1]


def serve_overhead():
    plain, journaled = _factories()
    st_plain = _best_of(plain, runs=4)
    st_j = _best_of(journaled, runs=4)
    over = (
        (st_j.p99_total_s / st_plain.p99_total_s - 1.0) * 100
        if st_plain.p99_total_s > 0
        else 0.0
    )
    common.emit("fault_overhead/serve_p50_plain", st_plain.p50_total_s)
    common.emit(
        "fault_overhead/serve_p99_plain",
        st_plain.p99_total_s,
        f"{st_plain.throughput_qps:,.0f} RMQ/s",
    )
    common.emit("fault_overhead/serve_p50_journaled", st_j.p50_total_s)
    common.emit(
        "fault_overhead/serve_p99_journaled",
        st_j.p99_total_s,
        f"{st_j.throughput_qps:,.0f} RMQ/s; p99 overhead {over:+.1f}%",
    )

    # 1% injected worker crashes: supervisor restarts + automatic retries.
    plan_f = FaultPlan(
        FAULT_SEED, {"worker_query": FaultSpec(rate=0.01, kind="crash")}
    )
    st_f = _best_of(journaled, runs=4, fault_plan=plan_f, max_retries=6)
    common.emit("fault_overhead/serve_p50_faulty1pct", st_f.p50_total_s)
    common.emit(
        "fault_overhead/serve_p99_faulty1pct",
        st_f.p99_total_s,
        f"{st_f.throughput_qps:,.0f} RMQ/s; {st_f.worker_restarts} restarts, "
        f"{st_f.retried_requests} retried, {st_f.failed_requests} failed",
    )


def run():
    journaled_apply()
    serve_overhead()


if __name__ == "__main__":
    run()
