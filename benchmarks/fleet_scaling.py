"""Fleet scaling benchmarks: aggregate throughput vs replicas + rollout p99.

Two measurements (DESIGN.md §11):

* **Throughput vs replica count**: a closed loop of client threads drives
  batched RMQs through ``RMQFleet`` at 1/2/4 replicas over the same array.
  Each replica owns its own micro-batcher and engine worker, so aggregate
  queries/sec should rise with the replica count once a single server's
  flush loop saturates. The ``derived`` column carries qps and the speedup
  over the 1-replica fleet.
* **p99 under rolling updates**: a 3-replica fleet serves open-loop Poisson
  clients while a mutator streams bounded-lag rollouts through
  ``submit_update``. Reports the client-observed query p99 *during* the
  rollouts and the max version lag the tracker ever saw — the latency cost
  of fleet-wide mutation, which per-replica MVCC pinning plus the lag bound
  is supposed to keep flat.

CSV convention: ``name,us_per_call,derived``.
"""

from __future__ import annotations

import threading
import time

import numpy as np

from repro import update
from repro.serve import FleetConfig, ServeConfig
from repro.serve.fleet import RMQFleet
from repro.serve.workload import make_queries, run_poisson_clients

from . import common

_ENGINE = "hybrid"  # pure-jit engine: replicas run concurrently on CPU


def _serve_cfg(n, deadline_s=5e-4):
    return ServeConfig(deadline_s=deadline_s, max_batch=256, n=n, workers=1)


def _closed_loop_qps(fleet, n, threads, batches_per_thread, qbatch):
    """Aggregate queries/sec from ``threads`` synchronous client loops."""
    barrier = threading.Barrier(threads + 1)
    done = []

    def client(c):
        rng = np.random.default_rng(100 + c)
        barrier.wait()
        for _ in range(batches_per_thread):
            l, r = make_queries(rng, n, qbatch, "medium")
            fleet.submit(l, r).result(timeout=120)
        done.append(c)

    workers = [threading.Thread(target=client, args=(c,)) for c in range(threads)]
    for w in workers:
        w.start()
    barrier.wait()
    t0 = time.perf_counter()
    for w in workers:
        w.join()
    wall = time.perf_counter() - t0
    assert len(done) == threads
    total_q = threads * batches_per_thread * qbatch
    return total_q / wall if wall > 0 else 0.0, wall


def throughput_vs_replicas():
    n = 1 << 12 if common.SMOKE else 1 << 13
    counts = (1, 2) if common.SMOKE else (1, 2, 4)
    # Scale-out methodology: offered load grows with capacity (a fixed number
    # of client threads *per replica*), so the measurement is how much
    # aggregate throughput the fleet sustains at constant per-replica
    # concurrency. The per-server bottleneck is the deadline flush cycle
    # (sleep-dominated at this n), which replicas overlap even on one core.
    per_rep, batches, qbatch = (4, 6, 16) if common.SMOKE else (4, 16, 16)
    rng = np.random.default_rng(0)
    x = rng.random(n, dtype=np.float32)
    base_qps = None
    for replicas in counts:
        # No regime affinity here: the closed-loop load is homogeneous, and
        # affinity routing would (correctly) concentrate it on one pool.
        # Capacity scaling wants round-robin across every replica.
        cfg = FleetConfig(
            replicas=replicas,
            max_version_lag=1,
            server=_serve_cfg(n, deadline_s=2e-3),
            affinities=(None,) * replicas,
        )
        fleet = RMQFleet.build(_ENGINE, x, config=cfg, threshold=64)
        threads = per_rep * replicas
        try:
            fleet.warmup()
            qps, wall = _closed_loop_qps(fleet, n, threads, batches, qbatch)
        finally:
            fleet.close()
        if base_qps is None:
            base_qps = qps
        speedup = qps / base_qps if base_qps > 0 else float("inf")
        common.emit(
            f"fleet_scaling/throughput_r{replicas}",
            wall / (threads * batches),
            f"{qps:.0f} RMQ/s aggregate ({threads} clients), "
            f"{speedup:.2f}x vs 1 replica",
        )


def p99_under_rolling_updates():
    n = 1 << 12 if common.SMOKE else 1 << 14
    clients, requests, updates = (2, 8, 4) if common.SMOKE else (4, 24, 12)
    max_lag = 2
    rng = np.random.default_rng(3)
    x = rng.random(n, dtype=np.float32)
    cfg = FleetConfig(replicas=3, max_version_lag=max_lag, server=_serve_cfg(n))
    fleet = RMQFleet.build(_ENGINE, x, config=cfg, threshold=64)
    try:
        fleet.warmup()
        applied = []

        def mutator():
            mrng = np.random.default_rng(9)
            for i in range(updates):
                log = update.DeltaLog().point(
                    int(mrng.integers(0, n)), float(mrng.random())
                )
                if i % 3 == 1:
                    a = int(mrng.integers(0, n - 64))
                    log.fill(a, a + 63, float(mrng.random()))
                t0 = time.perf_counter()
                fleet.submit_update(log).result(timeout=120)
                applied.append(time.perf_counter() - t0)

        mut = threading.Thread(target=mutator)
        t0 = time.perf_counter()
        mut.start()
        out = run_poisson_clients(
            clients,
            requests,
            400.0,
            lambda crng, c: make_queries(crng, n, 16, "medium"),
            fleet.submit,
            seed=4,
        )
        mut.join()
        totals = []
        for per in out:
            for _, fut in per:
                if fut is not None:
                    totals.append(fut.result(timeout=120).timing.total_s)
        wall = time.perf_counter() - t0
        assert fleet.wait_settled(timeout=120), "rollouts never settled fleet-wide"
        st = fleet.stats()
    finally:
        fleet.close()
    p99 = float(np.percentile(totals, 99)) if totals else 0.0
    ups = len(applied) / wall if wall > 0 else 0.0
    common.emit(
        "fleet_scaling/query_p99_under_rollouts",
        p99,
        f"{len(totals) * 16} RMQs alongside {len(applied)} rollouts "
        f"({ups:.1f} rollouts/s), lag {st.max_lag_seen} <= {max_lag}",
    )
    common.emit(
        "fleet_scaling/rollout_p50",
        float(np.median(applied)) if applied else 0.0,
        f"fleet-wide publish across {st.replicas} replicas",
    )


def run():
    throughput_vs_replicas()
    p99_under_rolling_updates()


if __name__ == "__main__":
    common.SMOKE = True
    run()
