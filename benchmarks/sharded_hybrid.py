"""Sharded range-adaptive hybrid sweep: devices x range distribution.

Extends fig14's shard-scaling story to the fused engine (core/sharded_hybrid):
for each fake-device count and each §6.4 range distribution, serve a batch
through the range-adaptive sharded engine and report ns/RMQ. The small/large
regimes exercise the single-constituent fast paths (sharded blocked / sharded
sparse table); medium mixes regimes and exercises the partition+scatter-back.
One batch-sharded-mode row per device count shows the replicated-structure /
sharded-queries dual; one 2D-mode row (structure x batch mesh, squarest
factoring) shows the product.

Subprocess per device count (XLA fixes the device count at first jax import).
"""

from __future__ import annotations

import os
import subprocess
import sys

from . import common
from .common import emit

_BATCH = 8192

_CHILD = r"""
import os, time, numpy as np, jax, jax.numpy as jnp
from repro.core import sharded_hybrid
from repro.launch.mesh import factor_2d, make_mesh
from benchmarks.common import make_queries
n_dev = len(jax.devices())
mesh = make_mesh((n_dev,), ("shard",))
mesh2d = make_mesh(factor_2d(n_dev), ("struct", "qbatch"))
rng = np.random.default_rng(0)
n = int(os.environ["RMQ_SHYBRID_BENCH_N"])
batch = int(os.environ["RMQ_SHYBRID_BENCH_B"])
x = rng.random(n, dtype=np.float32)
for mode in ("shard_structure", "shard_batch", "shard_2d"):
    m, axes = (mesh2d, ("struct", "qbatch")) if mode == "shard_2d" else (mesh, ("shard",))
    s = sharded_hybrid.build(jnp.asarray(x), m, axes, 1024, mode=mode)
    dists = ("small", "medium", "large") if mode == "shard_structure" else ("medium",)
    for dist in dists:
        l, r = make_queries(rng, n, batch, dist)
        out = sharded_hybrid.query(s, l, r)  # warmup / compile
        jax.block_until_ready(out)
        t0 = time.perf_counter()
        for _ in range(5):
            out = sharded_hybrid.query(s, l, r)
        jax.block_until_ready(out)
        print(f"{mode},{dist},{(time.perf_counter() - t0) / 5}")
"""


def run():
    devices = [1, 2] if common.SMOKE else [1, 2, 4, 8]
    n = 1 << 16 if common.SMOKE else 1 << 20
    batch = 2048 if common.SMOKE else _BATCH
    for n_dev in devices:
        env = dict(os.environ)
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_dev}"
        env["PYTHONPATH"] = "src:."
        env["RMQ_SHYBRID_BENCH_N"] = str(n)
        env["RMQ_SHYBRID_BENCH_B"] = str(batch)
        out = subprocess.run(
            [sys.executable, "-c", _CHILD], env=env, capture_output=True, text=True
        )
        if out.returncode != 0:
            emit(f"sharded_hybrid/shards={n_dev}", 0.0, "FAILED")
            continue
        for line in out.stdout.strip().splitlines():
            mode, dist, t = line.split(",")
            t = float(t)
            tag = {"shard_batch": "qshard/", "shard_2d": "2d/"}.get(mode, "")
            emit(
                f"sharded_hybrid/shards={n_dev}/{tag}dist={dist}",
                t / batch,
                f"{t/batch*1e9:.1f}ns_per_rmq",
            )


if __name__ == "__main__":
    run()
