"""Shared benchmark utilities: the paper's query-range distributions (§6.4)
and timing helpers. CSV convention: ``name,us_per_call,derived``."""

from __future__ import annotations

import time

import jax
import numpy as np

__all__ = ["make_queries", "time_fn", "emit", "RESULTS", "SMOKE"]

# Every emit() also lands here (name -> us_per_call) so the harness can dump
# machine-readable JSON (benchmarks/run.py --json) for cross-PR tracking.
RESULTS: dict = {}

# Set by `benchmarks.run --smoke`: suites shrink sizes/batches to finish in
# seconds (CI smoke via tools/check.sh).
SMOKE = False


def make_queries(rng, n: int, batch: int, dist: str):
    """Large: uniform range len in [1, n]; Medium: LogNormal(log n^0.6, .3);
    Small: LogNormal(log n^0.3, .3) — exactly the paper's three regimes."""
    if dist == "large":
        length = rng.integers(1, n + 1, batch)
    else:
        exp = 0.6 if dist == "medium" else 0.3
        length = np.exp(rng.normal(np.log(n**exp), 0.3, batch))
        length = np.clip(length, 1, n).astype(np.int64)
    l = rng.integers(0, np.maximum(n - length + 1, 1), batch)
    r = np.minimum(l + length - 1, n - 1)
    return l.astype(np.int64), r.astype(np.int64)


def time_fn(fn, *args, repeats: int = 5, warmup: int = 2):
    """Median wall time of fn(*args) with block_until_ready, in seconds."""
    for _ in range(warmup):
        out = fn(*args)
    jax.block_until_ready(out)
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def emit(name: str, seconds: float, derived: str = ""):
    RESULTS[name] = seconds * 1e6
    print(f"{name},{seconds*1e6:.2f},{derived}")
