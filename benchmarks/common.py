"""Shared benchmark utilities: the paper's query-range distributions (§6.4)
and timing helpers. CSV convention: ``name,us_per_call,derived``."""

from __future__ import annotations

import time

import jax
import numpy as np

from repro.serve.workload import make_queries  # one source for the §6.4 regimes

__all__ = ["make_queries", "time_fn", "emit", "RESULTS", "SMOKE"]

# Every emit() also lands here (name -> us_per_call) so the harness can dump
# machine-readable JSON (benchmarks/run.py --json) for cross-PR tracking.
RESULTS: dict = {}

# Set by `benchmarks.run --smoke`: suites shrink sizes/batches to finish in
# seconds (CI smoke via tools/check.sh).
SMOKE = False


def time_fn(fn, *args, repeats: int = 5, warmup: int = 2):
    """Median wall time of fn(*args) with block_until_ready, in seconds."""
    for _ in range(warmup):
        out = fn(*args)
    jax.block_until_ready(out)
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def emit(name: str, seconds: float, derived: str = ""):
    RESULTS[name] = seconds * 1e6
    print(f"{name},{seconds*1e6:.2f},{derived}")
