"""Online-update benchmarks: patch-vs-rebuild speedup + mutate-while-serving.

Two measurements (DESIGN.md §9):

* **Patch vs full rebuild**: per engine x n, the median wall time of applying
  a coalesced single-point update through ``OnlineEngine.apply`` (windowed
  patch + COW publish) against re-executing the engine's BuildPlan on the
  mutated array. The ``derived`` column carries the speedup — the acceptance
  bar is >= 5x for single-point updates at n >= 2^16 on the CPU baseline
  (tools/check.sh gates it).
* **Mutate-while-serving**: an async RMQServer over an online ``hybrid``
  engine under open-loop Poisson query clients while a mutator thread
  streams update batches; reports sustained updates/sec, update p50, and the
  query p99 observed *while mutating* (the latency cost of concurrent
  mutation, which MVCC pinning is supposed to keep flat).

CSV convention: ``name,us_per_call,derived``.
"""

from __future__ import annotations

import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import update
from repro.core import build as build_mod
from repro.serve import RMQServer, ServeConfig
from repro.serve.workload import make_queries, run_poisson_clients

from . import common

# Engines in the patch-vs-rebuild sweep: the raw doubling table (worst case:
# the patched structure IS the whole O(n log n) table) and the serving
# flagship hybrid (blocked + raw table).
_SWEEP_ENGINES = ("sparse_table", "hybrid")


def _median_apply_s(online, n, repeats=5):
    """Median wall seconds of a single-point ``apply`` (fresh write each rep)."""
    rng = np.random.default_rng(1)
    ts = []
    for _ in range(repeats):
        log = update.DeltaLog().point(int(rng.integers(0, n)), float(rng.random()))
        t0 = time.perf_counter()
        online.apply(log)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def _median_rebuild_s(plan, x, repeats=3):
    def rebuild():
        return build_mod.execute(plan, x)

    return common.time_fn(rebuild, repeats=repeats, warmup=1)


def patch_vs_rebuild(sizes=None):
    sizes = sizes if sizes is not None else ((1 << 12,) if common.SMOKE else (1 << 14, 1 << 16, 1 << 18))
    rng = np.random.default_rng(0)
    for engine in _SWEEP_ENGINES:
        for n in sizes:
            x = rng.random(n, dtype=np.float32)
            kw = {"threshold": 64} if engine == "hybrid" else {}
            online = update.make_online(engine, jnp.asarray(x), **kw)
            patch_s = _median_apply_s(online, n)
            rebuild_s = _median_rebuild_s(online.plan, jnp.asarray(np.asarray(x)))
            speedup = rebuild_s / patch_s if patch_s > 0 else float("inf")
            common.emit(
                f"update_throughput/patch_point_{engine}_n{n}",
                patch_s,
                f"vs rebuild {rebuild_s*1e3:.1f}ms speedup={speedup:.1f}x",
            )
            common.emit(f"update_throughput/rebuild_{engine}_n{n}", rebuild_s)


def publish_bytes():
    """Windowed-COW publish cost: bytes uploaded per point write vs full state.

    The publish path splices only the patched windows into the pinned device
    structure; a single-point write must therefore upload a small fraction of
    the full structure (asserted at < 25% — in practice it is orders of
    magnitude less for large n, since only O(log n) windows are touched).
    """
    n = 1 << 12 if common.SMOKE else 1 << 16
    rng = np.random.default_rng(7)
    x = rng.random(n, dtype=np.float32)
    for engine in _SWEEP_ENGINES:
        kw = {"threshold": 64} if engine == "hybrid" else {}
        online = update.make_online(engine, jnp.asarray(x), **kw)
        full = sum(
            leaf.nbytes
            for leaf in jax.tree_util.tree_leaves(online.store.current.state)
            if hasattr(leaf, "nbytes")
        )
        log = update.DeltaLog().point(int(rng.integers(0, n)), float(rng.random()))
        t0 = time.perf_counter()
        res = online.apply(log)
        apply_s = time.perf_counter() - t0
        assert 0 < res.publish_bytes < full // 4, (
            f"{engine}: point publish uploaded {res.publish_bytes}B of "
            f"{full}B full structure — windowed COW regressed to full upload"
        )
        common.emit(
            f"update_throughput/publish_bytes_point_{engine}_n{n}",
            apply_s,
            f"{res.publish_bytes}B of {full}B full ({100.0 * res.publish_bytes / full:.2f}%)",
        )


def mutate_while_serving():
    n = 1 << 12 if common.SMOKE else 1 << 15
    clients, requests, updates = (2, 8, 6) if common.SMOKE else (4, 24, 24)
    rng = np.random.default_rng(3)
    x = rng.random(n, dtype=np.float32)
    online = update.make_online("hybrid", jnp.asarray(x), threshold=64)
    cfg = ServeConfig(deadline_s=2e-3, max_batch=512, n=n)
    srv = RMQServer(online=online, config=cfg,
                    warmup_bounds=build_mod.warmup_bounds(online.plan))
    srv.warmup()
    # Pre-compile the patch/publish path so the measured loop is steady-state.
    online.apply(update.DeltaLog().point(0, float(x[0])))

    stop = threading.Event()
    applied = []

    def mutator():
        mrng = np.random.default_rng(9)
        for i in range(updates):
            if stop.is_set():
                break
            log = update.DeltaLog().point(int(mrng.integers(0, n)), float(mrng.random()))
            if i % 3 == 1:
                a = int(mrng.integers(0, n - 64))
                log.fill(a, a + 63, float(mrng.random()))
            t0 = time.perf_counter()
            srv.submit_update(log).result(timeout=120)
            applied.append(time.perf_counter() - t0)

    with srv:
        mut = threading.Thread(target=mutator)
        t0 = time.perf_counter()
        mut.start()
        out = run_poisson_clients(
            clients,
            requests,
            400.0,
            lambda crng, c: make_queries(crng, n, 16, "medium"),
            srv.submit,
            seed=4,
        )
        mut.join()
        stop.set()
        for per in out:
            for _, fut in per:
                if fut is not None:
                    fut.result(timeout=120)
        wall = time.perf_counter() - t0
    st = srv.stats()
    ups = len(applied) / wall if wall > 0 else 0.0
    common.emit(
        "update_throughput/serve_update_p50",
        float(np.median(applied)) if applied else 0.0,
        f"{ups:.0f} updates/s, version lag max {st.version_lag_max}",
    )
    common.emit(
        "update_throughput/serve_query_p99_while_mutating",
        st.p99_total_s,
        f"{st.throughput_qps:.0f} RMQ/s alongside {len(applied)} updates",
    )


def run():
    patch_vs_rebuild()
    publish_bytes()
    mutate_while_serving()


if __name__ == "__main__":
    common.SMOKE = True
    run()
