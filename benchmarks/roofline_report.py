"""Summarize the dry-run roofline JSONs into the EXPERIMENTS.md table rows.

Reads experiments/dryrun/*__full.json (written by repro.launch.dryrun) and
prints one CSV row per (arch x shape x mesh) cell.
"""

from __future__ import annotations

import glob
import json
import os

from .common import emit


def run(dirname: str = "experiments/dryrun"):
    files = sorted(
        glob.glob(os.path.join(dirname, "*__full.json"))
        + glob.glob(os.path.join(dirname, "*__optimized.json"))
    )
    if not files:
        emit("roofline/none", 0.0, "no dryrun artifacts; run repro.launch.dryrun --all")
        return
    for fn in files:
        with open(fn) as f:
            d = json.load(f)
        dom = d["bottleneck"]
        t_dom = d[f"t_{dom}" if dom != "collective" else "t_collective"]
        emit(
            f"roofline/{d['arch']}/{d['shape']}/{d['mesh']}",
            float(t_dom),
            f"bottleneck={dom};useful={d['useful_ratio']:.2f};"
            f"temp={d.get('temp_bytes_per_dev', 0) and d['temp_bytes_per_dev']/2**30:.1f}GiB",
        )


if __name__ == "__main__":
    run()
