"""Paper Fig. 12: ns/RMQ for each approach under Large/Medium/Small ranges.

Approaches (paper §6.1 mapped to this repo):
  RTXRMQ      -> blocked RMQ, scan backend (core.block_rmq)
  RTXRMQ-K    -> same algorithm, Pallas-kernel path (interpret on CPU; we
                 benchmark the jnp path and validate the kernel separately —
                 interpret-mode timing is a Python emulation, not a perf #)
  LANE        -> beyond-paper O(1)-gather variant (core.lane_rmq)
  LCA         -> Cartesian-tree/Euler-tour baseline
  HRMQ-proxy  -> sparse table (O(1) two-gather; the fast in-memory CPU
                 structure standing in for Ferrada-Navarro's compact one)
  EXHAUSTIVE  -> brute-force masked scan

Sizes are scaled down from the paper's 2^26 (CPU container); the regime
*shape* (small ranges cheapest for blocked; exhaustive catastrophic at
large n) is the reproduced claim.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import block_rmq, exhaustive, lane_rmq, lca, sparse_table

from . import common
from .common import emit, make_queries, time_fn

SIZES = [1 << 14, 1 << 17, 1 << 20]
BATCH = 1 << 14
DISTS = ["large", "medium", "small"]


def run():
    rng = np.random.default_rng(0)
    sizes, batch = ([1 << 14], 1 << 11) if common.SMOKE else (SIZES, BATCH)
    for n in sizes:
        x = rng.random(n, dtype=np.float32)
        xj = jnp.asarray(x)
        blk = block_rmq.build(xj, 1024 if n >= (1 << 17) else 128)
        lane = lane_rmq.build(xj)
        st = sparse_table.build(xj)
        lc = lca.build(x)
        q_blk = jax.jit(lambda l, r: block_rmq.query(blk, l, r)[0])
        q_lane = jax.jit(lambda l, r: lane_rmq.query(lane, l, r)[0])
        q_st = jax.jit(lambda l, r: sparse_table.query(st, l, r))
        q_lca = jax.jit(lambda l, r: lca.query(lc, l, r))
        q_ex = jax.jit(lambda l, r: exhaustive.rmq_exhaustive(xj, l, r))
        for dist in DISTS:
            l, r = make_queries(rng, n, batch, dist)
            lj, rj = jnp.asarray(l), jnp.asarray(r)
            for name, fn in [
                ("RTXRMQ", q_blk),
                ("LANE", q_lane),
                ("HRMQ-proxy", q_st),
                ("LCA", q_lca),
            ]:
                t = time_fn(fn, lj, rj)
                emit(f"fig12/{name}/n={n}/{dist}", t / batch, f"{t/batch*1e9:.1f}ns_per_rmq")
            if n <= (1 << 17):  # exhaustive is O(n) per query — cap sizes
                t = time_fn(q_ex, lj, rj)
                emit(f"fig12/EXHAUSTIVE/n={n}/{dist}", t / batch, f"{t/batch*1e9:.1f}ns_per_rmq")


if __name__ == "__main__":
    run()
