"""Replaces paper Fig. 14/15 (GPU-generation / SM scaling, not measurable in
this container): scaling of the DISTRIBUTED RMQ engine with shard count,
measured on fake CPU devices via a subprocess sweep.

Reproduced claim analogue: the blocked engine's throughput scales with
parallel resources (paper: RT cores/SMs; here: mesh shards), because the
query batch is embarrassingly parallel up to the two min all-reduces.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys

from . import common
from .common import emit

_CHILD = r"""
import os, time, numpy as np, jax, jax.numpy as jnp
from repro.core import distributed
from repro.launch.mesh import make_mesh, set_mesh
from benchmarks.common import make_queries
n_dev = len(jax.devices())
mesh = make_mesh((n_dev,), ("shard",))
rng = np.random.default_rng(0)
n = int(os.environ.get("RMQ_MESH_BENCH_N", 1 << 20))
x = rng.random(n, dtype=np.float32)
with set_mesh(mesh):
    s = distributed.build_sharded(jnp.asarray(x), mesh, ("shard",), 1024)
    qfn = distributed.make_query_fn(mesh, ("shard",))
    l, r = make_queries(rng, n, 8192, "small")
    lj, rj = jnp.asarray(l), jnp.asarray(r)
    out = qfn(s, lj, rj); jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(5):
        out = qfn(s, lj, rj)
    jax.block_until_ready(out)
    print((time.perf_counter() - t0) / 5)
"""


def run():
    devices = [1, 2] if common.SMOKE else [1, 2, 4, 8]
    for n_dev in devices:
        env = dict(os.environ)
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_dev}"
        env["PYTHONPATH"] = "src:."
        if common.SMOKE:
            env["RMQ_MESH_BENCH_N"] = str(1 << 16)
        out = subprocess.run(
            [sys.executable, "-c", _CHILD], env=env, capture_output=True, text=True
        )
        if out.returncode != 0:
            emit(f"fig14/shards={n_dev}", 0.0, "FAILED")
            continue
        t = float(out.stdout.strip().splitlines()[-1])
        emit(f"fig14/distributed-rmq/shards={n_dev}", t / 8192, f"{t/8192*1e9:.1f}ns_per_rmq")


if __name__ == "__main__":
    run()
