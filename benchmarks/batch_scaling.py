"""Paper Fig. 13: parallel saturation — ns/RMQ as the batch size grows.

Reproduced claim: the blocked engine keeps gaining throughput with batch
size (it is parallelism-limited, not structure-limited), while O(1)-query
structures saturate early.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import block_rmq, sparse_table

from . import common
from .common import emit, make_queries, time_fn

N = 1 << 20
BATCHES = [1 << k for k in range(6, 17, 2)]


def run():
    rng = np.random.default_rng(1)
    n, batches = (1 << 14, BATCHES[:3]) if common.SMOKE else (N, BATCHES)
    x = rng.random(n, dtype=np.float32)
    xj = jnp.asarray(x)
    blk = block_rmq.build(xj, 1024 if n >= (1 << 17) else 128)
    st = sparse_table.build(xj)
    q_blk = jax.jit(lambda l, r: block_rmq.query(blk, l, r)[0])
    q_st = jax.jit(lambda l, r: sparse_table.query(st, l, r))
    for b in batches:
        l, r = make_queries(rng, n, b, "small")
        lj, rj = jnp.asarray(l), jnp.asarray(r)
        for name, fn in [("RTXRMQ", q_blk), ("HRMQ-proxy", q_st)]:
            t = time_fn(fn, lj, rj)
            emit(f"fig13/{name}/batch={b}", t / b, f"{t/b*1e9:.1f}ns_per_rmq")


if __name__ == "__main__":
    run()
