"""Crossover suite: range-length regimes x engines + dispatch-count audit.

Reproduces the paper's central perf claim — the winner is *regime-dependent*
(blocked/RT-style fastest at small ranges, O(1) tables at large) — and
measures the two things this repo's fused/hybrid work adds on top:

  1. **Dispatch audit**: the fused tiled megakernel answers a whole query
     batch in ONE ``pallas_call`` with zero XLA gathers/selects after it,
     vs the legacy path's kernel + sparse-table interior + merge passes.
     Counted statically from the jaxpr, so it holds on CPU (interpret mode)
     exactly as on TPU.
  2. **Hybrid dominance**: across small/medium/large regimes the hybrid
     dispatcher must never be slower than the worst of its two constituent
     engines (it routes each query to the better one; a FAIL in the derived
     column means the routing threshold is mis-calibrated).

Off-TPU, Pallas kernels run as Python emulation — their wall-clock is
meaningless, so kernel-path rows emit the dispatch audit instead of time.
CSV rows follow the ``name,us_per_call,derived`` convention.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro import kernels
from repro.core import block_rmq, hybrid, lane_rmq, sparse_table

from . import common
from .common import emit, make_queries, time_fn

N = 1 << 16
BATCH = 1 << 13
DISTS = ["small", "medium", "large"]


def _jaxpr_audit(fn, *args):
    """(pallas_calls, xla_gathers, xla_selects) outside kernel bodies."""
    closed = jax.make_jaxpr(fn)(*args)

    def walk(jaxpr):
        pallas = gathers = selects = 0
        for eq in jaxpr.eqns:
            name = eq.primitive.name
            if name == "pallas_call":
                pallas += 1
                continue  # do not descend into the kernel body
            if name == "gather":
                gathers += 1
            if name == "select_n":
                selects += 1
            for v in eq.params.values():
                sub = None
                if isinstance(v, jax.core.ClosedJaxpr):
                    sub = v.jaxpr
                elif isinstance(v, jax.core.Jaxpr):
                    sub = v
                if sub is not None:
                    p, g, s = walk(sub)
                    pallas += p
                    gathers += g
                    selects += s
        return pallas, gathers, selects

    return walk(closed.jaxpr)


def run():
    rng = np.random.default_rng(0)
    # Smoke shrinks the array, not the batch: the dispatcher's fixed per-call
    # cost must stay amortized or per-query numbers measure dispatch latency.
    n, batch = (1 << 12, BATCH) if common.SMOKE else (N, BATCH)
    on_tpu = jax.default_backend() == "tpu"

    x = rng.random(n, dtype=np.float32)
    xj = jnp.asarray(x)
    blk = block_rmq.build(xj, 128)
    lane = lane_rmq.build(xj)
    st = sparse_table.build(xj)
    hyb = hybrid.build(xj, 128, use_kernels=on_tpu)
    kblk = kernels.ops.build(xj, 128, interpret=not on_tpu)

    # --- dispatch audit (static; backend-independent) --------------------
    l0, r0 = make_queries(rng, n, batch, "medium")
    l0j, r0j = jnp.asarray(l0), jnp.asarray(r0)
    for name, fn in [
        ("fused-tiled", lambda l, r: kernels.ops.query(kblk, l, r, interpret=not on_tpu)),
        ("legacy-2pass", lambda l, r: kernels.ops.query(kblk, l, r, fused=False, interpret=not on_tpu)),
    ]:
        p, g, s = _jaxpr_audit(fn, l0j, r0j)
        emit(
            f"crossover/dispatch/{name}",
            0.0,
            f"pallas_calls={p}_xla_gathers={g}_xla_selects={s}",
        )

    # --- regime sweep ----------------------------------------------------
    # All engines are timed at the same host boundary the dispatcher serves
    # (numpy queries in), so H2D transfer costs fall on every row equally.
    q_blk = jax.jit(lambda l, r: block_rmq.query(blk, l, r))
    q_lane = jax.jit(lambda l, r: lane_rmq.query(lane, l, r))
    q_st = jax.jit(lambda l, r: sparse_table.query(st, l, r))
    engines = [("RTXRMQ-block", q_blk), ("LANE", q_lane), ("ST", q_st)]
    if on_tpu:  # kernel wall-clock is only meaningful on hardware
        engines.append(("FUSED-K", lambda l, r: kernels.ops.query(kblk, l, r)))
        engines.append(
            ("LEGACY-K", lambda l, r: kernels.ops.query(kblk, l, r, fused=False))
        )

    for dist in DISTS:
        l, r = make_queries(rng, n, batch, dist)
        times = {}
        for name, fn in engines:
            t = time_fn(lambda a, b, fn=fn: fn(jnp.asarray(a), jnp.asarray(b)), l, r)
            times[name] = t
            emit(f"crossover/{name}/n={n}/{dist}", t / batch, f"{t/batch*1e9:.1f}ns_per_rmq")

        # Hybrid vs. its constituents: never slower than the worst of them.
        t_h = time_fn(lambda a, b: hybrid.query(hyb, a, b), l, r)
        short_name = "FUSED-K" if on_tpu else "RTXRMQ-block"
        worst = max(times[short_name], times["ST"])
        # Tolerance = timing noise floor: 5% on TPU; CPU containers show
        # ±~20% run-to-run on the ms-scale small-regime path, so hold the
        # regime-level claim there without false FAILs.
        tol = 1.05 if on_tpu else 1.25
        verdict = "PASS" if t_h <= worst * tol else "FAIL"
        emit(
            f"crossover/HYBRID/n={n}/{dist}",
            t_h / batch,
            f"{t_h/batch*1e9:.1f}ns_per_rmq_vs_worst_constituent={worst/batch*1e9:.1f}ns_{verdict}",
        )

    emit(f"crossover/threshold/n={n}", 0.0, f"range_len<={hyb.threshold}->blocked")


if __name__ == "__main__":
    run()
