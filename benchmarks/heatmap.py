"""Paper Fig. 10/11: performance heat map over (n, |l,r| range, block config).

The 3-D sweep (n x range-fraction x block size) reproduces the paper's
observation that the optimal block configuration moves with (n, range):
small ranges favor many small blocks (partial scans dominate), large ranges
favor fewer blocks (the O(1) interior path dominates).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import block_rmq

from . import common
from .common import emit, time_fn

SIZES = [1 << 14, 1 << 17, 1 << 20]
RANGE_EXP = [-12, -8, -4, -1]  # |l,r| = n * 2^y
BLOCKS = [128, 512, 2048]
BATCH = 1 << 13


def run():
    rng = np.random.default_rng(3)
    if common.SMOKE:
        sizes, range_exp, blocks, batch = [1 << 14], [-8, -1], [128, 512], 1 << 10
    else:
        sizes, range_exp, blocks, batch = SIZES, RANGE_EXP, BLOCKS, BATCH
    for n in sizes:
        x = rng.random(n, dtype=np.float32)
        xj = jnp.asarray(x)
        for bs in blocks:
            if bs * 2 > n:
                continue
            s = block_rmq.build(xj, bs)
            qfn = jax.jit(lambda l, r, s=s: block_rmq.query(s, l, r)[0])
            for y in range_exp:
                length = max(1, int(n * (2.0**y)))
                l = rng.integers(0, n - length + 1, batch)
                r = l + length - 1
                t = time_fn(qfn, jnp.asarray(l), jnp.asarray(r))
                emit(
                    f"fig10/RTXRMQ/n={n}/len=n*2^{y}/bs={bs}",
                    t / batch,
                    f"{t/batch*1e9:.1f}ns_per_rmq",
                )


if __name__ == "__main__":
    run()
