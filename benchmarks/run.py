"""Benchmark harness entry point — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only fig12,...] [--json OUT]

Prints ``name,us_per_call,derived`` CSV rows. ``--json OUT`` also writes the
results as ``{suite: {name: us_per_call}}`` JSON (e.g. BENCH_PR1.json) so the
perf trajectory is machine-trackable across PRs. ``--smoke`` shrinks sizes so
a suite finishes in seconds (CI smoke; see tools/check.sh).
"""

import argparse
import json
import subprocess


def _metrics_meta():
    """Snapshot of the process-global metrics registry (counters only —
    histograms here would be noise: every suite shares the process)."""
    from repro.obs import default_registry

    snap = default_registry().snapshot()
    out = {}
    for name, rows in snap["counters"].items():
        for row in rows:
            key = name
            if row["labels"]:
                key += "{" + ",".join(f"{k}={v}" for k, v in sorted(row["labels"].items())) + "}"
            out[key] = row["value"]
    return out or None


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--only",
        default="",
        help="comma list: fig12,fig13,fig10,fig14,table2,build_mem,roofline,"
        "crossover,sharded_hybrid,serve_latency,update_throughput,"
        "fault_overhead,fleet_scaling,kernel_tuning,bandwidth,obs_overhead",
    )
    ap.add_argument("--json", default="", metavar="OUT", help="also write results JSON")
    ap.add_argument("--smoke", action="store_true", help="tiny sizes, seconds-long run")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    if args.json:  # fail on an unwritable path BEFORE minutes of benchmarking
        try:
            open(args.json, "a").close()
        except OSError as e:
            ap.error(f"--json {args.json}: {e}")

    from . import (
        bandwidth,
        batch_scaling,
        common,
        fault_overhead,
        fleet_scaling,
        heatmap,
        hybrid_crossover,
        kernel_tuning,
        memory_usage,
        mesh_scaling,
        obs_overhead,
        roofline_report,
        serve_latency,
        sharded_hybrid,
        time_per_rmq,
        update_throughput,
    )

    common.SMOKE = args.smoke

    suites = {
        "fig12": time_per_rmq.run,
        "fig13": batch_scaling.run,
        "fig10": heatmap.run,
        "table2": memory_usage.run,
        "build_mem": memory_usage.run_build_mem,
        "fig14": mesh_scaling.run,
        "roofline": roofline_report.run,
        "crossover": hybrid_crossover.run,
        "sharded_hybrid": sharded_hybrid.run,
        "serve_latency": serve_latency.run,
        "update_throughput": update_throughput.run,
        "fault_overhead": fault_overhead.run,
        "fleet_scaling": fleet_scaling.run,
        "kernel_tuning": kernel_tuning.run,
        "bandwidth": bandwidth.run,
        "obs_overhead": obs_overhead.run,
    }
    if only:
        unknown = only - set(suites)
        if unknown:
            ap.error(f"unknown suite(s) {sorted(unknown)}; have {sorted(suites)}")
    for name, fn in suites.items():
        if only and name not in only:
            continue
        print(f"# --- {name} ---")
        fn()

    if args.json:
        by_suite: dict = {}
        for name, us in common.RESULTS.items():
            suite, _, rest = name.partition("/")
            by_suite.setdefault(suite, {})[rest or suite] = us
        # Provenance: which tree and backend produced these numbers, which
        # fault schedule the injected-fault measurements used, and whether
        # the autotune cache was warm (a hit means zero timing sweeps ran).
        try:
            rev = subprocess.run(
                ["git", "rev-parse", "HEAD"], capture_output=True, text=True, timeout=10
            ).stdout.strip() or None
        except OSError:
            rev = None
        import jax

        from repro.core import packing

        by_suite["_meta"] = {
            "git_rev": rev,
            "fault_seed": fault_overhead.FAULT_SEED,
            "smoke": bool(args.smoke),
            "backend": jax.default_backend(),
            "device_count": len(jax.devices()),
            "jax_version": jax.__version__,
            "autotune_cache": dict(kernel_tuning.CACHE_STATE) or None,
            # Packed-layout stamp: which fused-word layouts this tree ships
            # and the measured byte ratios (populated when `bandwidth` ran).
            "layouts": ["unpacked"] + list(packing.PACKED_LAYOUTS),
            "bandwidth_report": dict(bandwidth.LAST_REPORT) or None,
            # Process-global metrics registry at run end: counters the
            # benchmarked subsystems incremented (WAL appends, checkpoints,
            # restores, ...) so a perf regression can be cross-checked
            # against the work actually done.
            "metrics": _metrics_meta(),
        }
        with open(args.json, "w") as f:
            json.dump(by_suite, f, indent=2, sort_keys=True)
        print(f"# wrote {args.json}")


if __name__ == "__main__":
    main()
