"""Benchmark harness entry point — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only fig12,...]

Prints ``name,us_per_call,derived`` CSV rows.
"""

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="", help="comma list: fig12,fig13,fig10,fig14,table2,roofline")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    from . import batch_scaling, heatmap, memory_usage, mesh_scaling, roofline_report, time_per_rmq

    suites = {
        "fig12": time_per_rmq.run,
        "fig13": batch_scaling.run,
        "fig10": heatmap.run,
        "table2": memory_usage.run,
        "fig14": mesh_scaling.run,
        "roofline": roofline_report.run,
    }
    for name, fn in suites.items():
        if only and name not in only:
            continue
        print(f"# --- {name} ---")
        fn()


if __name__ == "__main__":
    main()
