"""Bytes-moved accounting for the packed (value, index) structures (§13).

RMQ at serving batch sizes is bandwidth-bound (the roofline suite pins every
engine far left of the ridge), so the packed layouts' claim is a *traffic*
claim: fused words halve the long-path query's touched bytes and the
distributed doubling merge's halo traffic. This suite derives the byte
counts from the **built structures themselves** — leaf dtypes, plane counts,
level counts — so the numbers move if the layouts do, and cross-checks with
a wall-clock measurement of both query paths on the same batch.

Accounting (per RMQ, from the real leaf dtypes):

* sparse-table long path — unpacked touches two ``idx`` cells and gathers
  two candidate values (+ the final value lookup shares one of them);
  packed touches two fused words, full stop. quantized adds two raw-value
  gathers only on bucket ties (upper-bounded here as always-taken).
* blocked short path — both layouts scan two partial blocks; unpacked adds
  two (idx, val) interior cells, packed two words. The scan dominates, so
  the short-path win is marginal by construction — the hybrid's routing is
  why the long-path win matters.
* doubling merge — per level the unpacked halo exchange ships an index
  plane AND a value plane; packed ships one word plane. Counted over the
  levels/width of the actually-built tables.

Gate (tools/check.sh): at n=2**16 with packed32-fitting data, packed
bytes/query <= 60% of unpacked on the long path (>= 1.67x reduction; the
ISSUE bar is 1.5x) and packed merge traffic <= 60% of unpacked.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import packing, sparse_table

from . import common
from .common import emit, make_queries, time_fn

N_GATE = 1 << 16

# Set by run(): the last byte-accounting report, stamped into the harness's
# ``_meta`` JSON so BENCH_*.json records which layouts the tree ships and
# what their measured byte ratios were.
LAST_REPORT: dict = {}


def _st_query_bytes_unpacked(t: sparse_table.SparseTable) -> int:
    # Two doubling-table cells, two candidate-value gathers.
    return 2 * t.idx.dtype.itemsize + 2 * t.x.dtype.itemsize


def _st_query_bytes_packed(t: sparse_table.PackedSparseTable) -> int:
    b = 2 * t.words.dtype.itemsize
    if t.x is not None:  # quantized: exact fallback gathers (tie upper bound)
        b += 2 * t.x.dtype.itemsize
    return b


def _merge_bytes_unpacked(t: sparse_table.SparseTable) -> int:
    # Per doubling level the merge reads a shifted index plane and gathers a
    # value plane; the distributed build ships exactly these two planes per
    # level across shard boundaries.
    levels, width = t.idx.shape
    return levels * width * (t.idx.dtype.itemsize + t.x.dtype.itemsize)


def _merge_bytes_packed(t: sparse_table.PackedSparseTable) -> int:
    levels, width = t.words.shape
    return levels * width * t.words.dtype.itemsize


def report(n: int = N_GATE) -> dict:
    """Byte accounting for layouts over packed32-fitting data (the gate)."""
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.integers(-1000, 1000, size=n).astype(np.int32))
    un = sparse_table.build(x)
    out = {"n": int(n), "unpacked_query_bytes": _st_query_bytes_unpacked(un),
           "unpacked_merge_bytes": _merge_bytes_unpacked(un)}
    for layout in ("packed32", "packed64", "quantized"):
        t, spec = sparse_table.build_packed(x, layout=layout)
        out[f"{layout}_query_bytes"] = _st_query_bytes_packed(t)
        out[f"{layout}_merge_bytes"] = _merge_bytes_packed(t)
        out[f"{layout}_resolved"] = spec.layout
    out["gate_query_ratio"] = out["packed32_query_bytes"] / out["unpacked_query_bytes"]
    out["gate_merge_ratio"] = out["packed32_merge_bytes"] / out["unpacked_merge_bytes"]
    return out


def run():
    n = 1 << 12 if common.SMOKE else N_GATE
    rep = report(n)
    LAST_REPORT.clear()
    LAST_REPORT.update(rep)
    for layout in ("packed32", "packed64", "quantized"):
        q, m = rep[f"{layout}_query_bytes"], rep[f"{layout}_merge_bytes"]
        emit(
            f"bandwidth/query_bytes/{layout}/n={n}",
            0.0,
            f"{q}B_vs_unpacked_{rep['unpacked_query_bytes']}B"
            f"_x{rep['unpacked_query_bytes'] / q:.2f}",
        )
        emit(
            f"bandwidth/merge_bytes/{layout}/n={n}",
            0.0,
            f"{m}B_vs_unpacked_{rep['unpacked_merge_bytes']}B"
            f"_x{rep['unpacked_merge_bytes'] / m:.2f}",
        )

    # Wall-clock cross-check: the same long-range batch through both layouts.
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.integers(-1000, 1000, size=n).astype(np.int32))
    batch = 1 << 10 if common.SMOKE else 1 << 14
    l, r = make_queries(rng, n, batch, "large")
    lj, rj = jnp.asarray(l), jnp.asarray(r)
    un = sparse_table.build(x)

    def q_unpacked(lq, rq):
        idx = sparse_table.query(un, lq, rq)
        return idx, un.x[idx]

    q_unpacked_jit = jax.jit(q_unpacked)
    t_un = time_fn(q_unpacked_jit, lj, rj)
    emit(f"bandwidth/st_query_unpacked/n={n}", t_un / batch, f"batch={batch}")
    for layout in ("packed32", "packed64"):
        t, spec = sparse_table.build_packed(x, layout=layout)
        t_pk = time_fn(lambda a, b: sparse_table.query_packed(t, spec, a, b), lj, rj)
        emit(
            f"bandwidth/st_query_{layout}/n={n}",
            t_pk / batch,
            f"x{t_un / t_pk:.2f}_vs_unpacked",
        )
