"""Paper Table 2: memory of each approach's data structure (MB).

Reproduced claim ordering: geometric/blocked structure uses the most memory
(the paper's BVH is ~9n+ the input; our blocked structure is ~(1+1/BS)n +
tables), LCA/Euler is mid, the O(1)-table structures trade memory for time.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import block_rmq, lane_rmq, lca, sparse_table

from . import common
from .common import emit

SIZES = [1 << 10, 1 << 15, 1 << 20]


def tree_mb(tree) -> float:
    return sum(leaf.size * leaf.dtype.itemsize for leaf in jax.tree.leaves(tree)) / 2**20


def run():
    rng = np.random.default_rng(2)
    sizes = SIZES[:2] if common.SMOKE else SIZES
    for n in sizes:
        x = rng.random(n, dtype=np.float32)
        xj = jnp.asarray(x)
        input_mb = n * 4 / 2**20
        rows = {
            "RTXRMQ": tree_mb(block_rmq.build(xj, 128)),
            "LANE": tree_mb(lane_rmq.build(xj)),
            "LCA": tree_mb(lca.build(x)),
            "SPARSE_TABLE": tree_mb(sparse_table.build(xj)),
        }
        for name, mb in rows.items():
            emit(f"table2/{name}/n={n}", 0.0, f"{mb:.3f}MB_vs_input_{input_mb:.3f}MB")


if __name__ == "__main__":
    run()
