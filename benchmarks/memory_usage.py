"""Paper Table 2: memory of each approach's data structure (MB) — plus the
``build_mem`` sweep: *peak per-device build memory* of the doubling-table
family across device counts.

Reproduced claim ordering: geometric/blocked structure uses the most memory
(the paper's BVH is ~9n+ the input; our blocked structure is ~(1+1/BS)n +
tables), LCA/Euler is mid, the O(1)-table structures trade memory for time.

``build_mem`` (``run_build_mem``) compares, per fake-device count:

* ``replicated`` — ``build_replicated_st``: every device holds the full
  (K, n) table (batch-sharded mode's structure);
* ``sharded_steady`` — the column-sharded ``ShardedSparseTable`` steady
  state: (K, n/D) idx+val per device;
* ``distributed_build_peak`` — the max per-device bytes live at ANY stage of
  the staged BuildPlan build (observer over shard layout -> local build ->
  halo exchange), demonstrating the build transient is bounded by the shard
  too — the old single-device materialization would show up here as a full
  (K, n) spike.

Subprocess per device count (XLA fixes the device count at first jax import).
"""

from __future__ import annotations

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import block_rmq, lane_rmq, lca, sparse_table

from . import common
from .common import emit

SIZES = [1 << 10, 1 << 15, 1 << 20]


def tree_mb(tree) -> float:
    return sum(leaf.size * leaf.dtype.itemsize for leaf in jax.tree.leaves(tree)) / 2**20


def run():
    rng = np.random.default_rng(2)
    sizes = SIZES[:2] if common.SMOKE else SIZES
    for n in sizes:
        x = rng.random(n, dtype=np.float32)
        xj = jnp.asarray(x)
        input_mb = n * 4 / 2**20
        rows = {
            "RTXRMQ": tree_mb(block_rmq.build(xj, 128)),
            "LANE": tree_mb(lane_rmq.build(xj)),
            "LCA": tree_mb(lca.build(x)),
            "SPARSE_TABLE": tree_mb(sparse_table.build(xj)),
        }
        for name, mb in rows.items():
            emit(f"table2/{name}/n={n}", 0.0, f"{mb:.3f}MB_vs_input_{input_mb:.3f}MB")


_BUILD_MEM_CHILD = r"""
import os, numpy as np, jax, jax.numpy as jnp
from collections import defaultdict
from repro.core import build as build_mod, distributed
from repro.launch.mesh import make_mesh

n = int(os.environ["RMQ_BUILDMEM_N"])
n_dev = len(jax.devices())
mesh = make_mesh((n_dev,), ("shard",))
x = jnp.asarray(np.random.default_rng(0).random(n, dtype=np.float32))

def max_device_bytes(tree):
    by_dev = defaultdict(int)
    seen = set()  # the finalize stage aliases arrays (state -> result):
    for arr in jax.tree_util.tree_leaves(tree):  # count each buffer once
        if isinstance(arr, jax.Array) and id(arr) not in seen:
            seen.add(id(arr))
            for sh in arr.addressable_shards:
                by_dev[sh.device] += sh.data.nbytes
    return max(by_dev.values()) if by_dev else 0

rep = distributed.build_replicated_st(x, mesh)
jax.block_until_ready(rep)
print("replicated", max_device_bytes(rep))

peak = 0
def observe(stage, state):
    global peak
    live = [v for k, v in state.items() if k != "x"]
    jax.block_until_ready(live)
    peak = max(peak, max_device_bytes(live))

sharded = build_mod.build(
    "sharded_st", x, mesh=mesh, axis_names=("shard",), observer=observe
)
print("distributed_build_peak", peak)
print("sharded_steady", max_device_bytes(sharded))
"""


def run_build_mem():
    devices = [1, 2] if common.SMOKE else [1, 2, 4, 8]
    n = 1 << 16 if common.SMOKE else 1 << 20
    for n_dev in devices:
        env = dict(os.environ)
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_dev}"
        env["PYTHONPATH"] = "src:."
        env["RMQ_BUILDMEM_N"] = str(n)
        out = subprocess.run(
            [sys.executable, "-c", _BUILD_MEM_CHILD],
            env=env,
            capture_output=True,
            text=True,
        )
        if out.returncode != 0:
            emit(f"build_mem/ndev={n_dev}", 0.0, "FAILED")
            continue
        for line in out.stdout.strip().splitlines():
            kind, nbytes = line.split()
            emit(
                f"build_mem/ndev={n_dev}/{kind}/n={n}",
                0.0,
                f"{int(nbytes) / 2**20:.3f}MB_per_device_peak",
            )


if __name__ == "__main__":
    run()
    run_build_mem()
