"""Kernel-autotuner suite: tuned vs default megakernel launch geometry.

Sweeps the ``repro.kernels.tuning`` config product once per size, then
reports the tuned winner against the deterministic default config **from the
same sweep's measurements**, so the central claim — tuned is never slower
than default — is checked on identical builds and query batches. A second
pass exercises the persistent cache: the winner is stored, re-loaded under
the read-only ``"cached"`` policy, and the re-load is asserted to perform
zero timing sweeps (the cache-hit path is counted at the ``hybrid._measure``
seam, the only place a sweep can time anything).

Off-TPU the kernels run in interpret mode — absolute wall-clock is
emulation, so sizes stay small and the tolerance is wide; the cache
round-trip and the tuned<=default ordering are backend-independent.

Every run records its cache hit/miss outcomes in ``CACHE_STATE`` so the
harness can stamp them into the results JSON ``_meta``.
"""

from __future__ import annotations

import tempfile
from pathlib import Path

import jax

from repro.core import calib_cache, hybrid
from repro.kernels import tuning

from . import common
from .common import emit

# name -> "hit" | "miss", refreshed per run(); run.py copies it into _meta.
CACHE_STATE: dict = {}


def run():
    CACHE_STATE.clear()
    on_tpu = jax.default_backend() == "tpu"
    interpret = not on_tpu
    # Interpret-mode grid steps cost milliseconds each, so off-TPU points
    # stay tiny; the orderings under test are size-independent.
    if common.SMOKE or not on_tpu:
        points = [(1 << 12, 64)]
        repeats = 1
        block_size = 128  # pin: one build per point keeps smoke seconds-fast
    else:
        points = [(1 << 16, 4096), (1 << 20, 4096)]
        repeats = 3
        block_size = None  # full product, block sizes included
    tol = 1.05 if on_tpu else 1.25

    with tempfile.TemporaryDirectory() as td:
        cache = Path(td) / "calibration.json"
        for n, batch in points:
            results = tuning.sweep(
                n, batch, block_size=block_size, repeats=repeats, interpret=interpret
            )
            best_cfg, best_t = min(results, key=lambda cv: cv[1])
            bs = block_size if block_size is not None else 128
            default = tuning.default_config(bs)
            resolved = default._replace(fetch=tuning.resolve_fetch("auto", -(-n // bs)))
            default_t = dict(results)[resolved]

            tag = f"tile={best_cfg.tile}/fetch={best_cfg.fetch}/bs={best_cfg.block_size}"
            verdict = "PASS" if best_t <= default_t * tol else "FAIL"
            emit(f"kernel_tuning/default/n={n}", default_t / batch, "")
            emit(
                f"kernel_tuning/tuned/n={n}",
                best_t / batch,
                f"{tag}_vs_default_{verdict}",
            )

            # Cache lifecycle: store the winner, then prove the cached policy
            # re-loads it with zero timing sweeps.
            key = tuning.tuning_key(n, batch)
            CACHE_STATE[key] = (
                "hit" if calib_cache.load_entry(key, cache) is not None else "miss"
            )
            calib_cache.store_entry(key, dict(best_cfg._asdict()), cache)
            sweeps = []
            orig = hybrid._measure
            hybrid._measure = lambda *a, **k: sweeps.append(a) or orig(*a, **k)
            try:
                cached = tuning.get_config(
                    n, batch, policy="cached", block_size=block_size, path=cache
                )
            finally:
                hybrid._measure = orig
            ok = cached == best_cfg and not sweeps
            CACHE_STATE[key] = "hit" if ok else CACHE_STATE[key]
            emit(
                f"kernel_tuning/cache/n={n}",
                0.0,
                f"roundtrip={'PASS' if ok else 'FAIL'}_retimings={len(sweeps)}",
            )


if __name__ == "__main__":
    run()
