"""Observability overhead benchmarks: span tracing on vs off (DESIGN.md §14).

The tracer's design bar is *zero* cost when disabled (the shared no-op
singleton — asserted allocation-free by tests/test_obs.py) and negligible
cost when enabled: one Span object + a ring-buffer append per recorded unit
of work, attr dicts gated on ``tracer.enabled`` at every hot call site.

The measured workload is **closed-loop** on purpose: four client threads
each submit one request and wait for its result before the next, over an
online hybrid engine with a concurrent update stream. A closed loop bounds
the queue depth at the client count, so request p99 reflects the batcher
deadline + engine service time — the path span recording actually touches —
instead of open-loop queueing collapse, whose p99 swings several-fold run
to run on a shared CPU and would drown a 10% comparison in scheduler noise
(the open-loop ``fault_overhead`` workload prices durability, where the
journaled fsync is large enough to survive that noise; span recording is
not).

``p99_gate`` is the tools/check.sh acceptance bar — <= 10% added request
p99 with tracing enabled — built like ``fault_overhead.p99_gate``:
best-of-runs per config, the two configs alternated so neither
systematically runs on a colder process (jit caches, page cache) than the
other. Metrics-registry instrumentation is active in BOTH configs (the
server always carries its registry); the gate isolates span recording.

CSV rows: ``obs_overhead/serve_p{50,99}_{untraced,traced}`` plus the span
volume the traced run recorded.
"""

from __future__ import annotations

import threading

import jax.numpy as jnp
import numpy as np

from repro import update
from repro.core import build as build_mod
from repro.obs import Tracer, set_tracer
from repro.serve import RMQServer, ServeConfig
from repro.serve.workload import make_queries

from . import common

# Ample for every benchmark workload: no ring overflow perturbing the run.
_TRACE_CAPACITY = 1 << 16
_CLIENTS = 4


def _sizes():
    if common.SMOKE:
        return 1 << 12, 30, 4  # n, requests/client, updates
    return 1 << 15, 150, 12


def _factory():
    """Fresh online hybrid engine per run (new jit closures each time, so
    neither config ever serves from the other's warm engine)."""
    n0, _, _ = _sizes()
    rng = np.random.default_rng(2)
    x = rng.random(n0, dtype=np.float32)

    def make():
        return update.make_online("hybrid", jnp.asarray(x), threshold=64)

    return make


def _serve_once(online, *, requests=None):
    """One closed-loop serve run (see module docstring) -> ServeStats."""
    _, default_requests, updates = _sizes()
    requests = default_requests if requests is None else requests
    cfg = ServeConfig(deadline_s=1e-3, max_batch=1024, workers=2)
    srv = RMQServer(
        online=online, config=cfg, warmup_bounds=build_mod.warmup_bounds(online.plan)
    )
    srv.warmup()
    online.apply(update.DeltaLog().point(0, 0.5))  # compile the patch path

    def client(seed):
        rng = np.random.default_rng(seed)
        n = online.n
        for _ in range(requests):
            l, r = make_queries(rng, n, 16, "small")
            srv.submit(l, r).result(timeout=120)

    def mutator():
        mrng = np.random.default_rng(9)
        for _ in range(updates):
            cur_n = online.n
            log = update.DeltaLog().point(int(mrng.integers(0, cur_n)), float(mrng.random()))
            try:
                srv.submit_update(log).result(timeout=120)
            except Exception:
                pass

    with srv:
        threads = [
            threading.Thread(target=client, args=(100 + i,), name=f"bench-client-{i}")
            for i in range(_CLIENTS)
        ]
        threads.append(threading.Thread(target=mutator, name="bench-mutator"))
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        st = srv.stats()
    return st


def _serve_traced(make, tracing: bool, *, requests=None):
    """One serve run with the global tracer installed (or not); returns
    (stats, spans_recorded)."""
    tracer = Tracer(enabled=True, capacity=_TRACE_CAPACITY) if tracing else None
    prev = set_tracer(tracer)
    try:
        st = _serve_once(make(), requests=requests)
    finally:
        set_tracer(prev)
    return st, (len(tracer.spans()) if tracer is not None else 0)


def p99_gate(runs=5, requests=150):
    """tools/check.sh acceptance bar: best-of-``runs`` request p99 with span
    tracing off vs on. Returns (untraced_s, traced_s)."""
    make = _factory()
    best = [float("inf"), float("inf")]
    for _ in range(runs):
        for i, tracing in enumerate((False, True)):
            st, _ = _serve_traced(make, tracing, requests=requests)
            best[i] = min(best[i], st.p99_total_s)
    return best[0], best[1]


def serve_overhead():
    make = _factory()
    runs = 2 if common.SMOKE else 4
    best_off = best_on = None
    spans = 0
    for _ in range(runs):
        st, _ = _serve_traced(make, False)
        if best_off is None or st.p99_total_s < best_off.p99_total_s:
            best_off = st
        st, ns = _serve_traced(make, True)
        if best_on is None or st.p99_total_s < best_on.p99_total_s:
            best_on = st
            spans = ns
    over = (
        (best_on.p99_total_s / best_off.p99_total_s - 1.0) * 100
        if best_off.p99_total_s > 0
        else 0.0
    )
    common.emit("obs_overhead/serve_p50_untraced", best_off.p50_total_s)
    common.emit(
        "obs_overhead/serve_p99_untraced",
        best_off.p99_total_s,
        f"{best_off.throughput_qps:,.0f} RMQ/s",
    )
    common.emit("obs_overhead/serve_p50_traced", best_on.p50_total_s)
    common.emit(
        "obs_overhead/serve_p99_traced",
        best_on.p99_total_s,
        f"{best_on.throughput_qps:,.0f} RMQ/s; {spans} spans; "
        f"p99 overhead {over:+.1f}%",
    )


def run():
    serve_overhead()


if __name__ == "__main__":
    run()
