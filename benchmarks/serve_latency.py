"""Serve-latency suite: p50/p99 + sustained throughput vs load and deadline.

Open-loop Poisson clients drive `repro.serve.RMQServer` over the hybrid
engine at a sweep of offered loads (requests/s) and micro-batch deadlines.
Open-loop means arrival times are fixed in advance — a slow server cannot
slow the clients down — so the measured latency honestly includes queueing
under overload, and throughput saturates instead of tracking the offer.

Rows: ``serve_latency/deadline=<ms>/load=<rps>`` with the p50 total latency
as the metric and p99 + achieved throughput in the derived column. Larger
deadlines trade per-request latency for bigger coalesced batches (fewer,
fuller engine launches); the sweep makes that trade measurable.

Each configuration also emits a ``serve_latency/decomp/...`` row decomposing
total latency into queue wait (submit -> flush pulled the request) vs
service time (flush -> result scattered back), read from the server's
metrics registry (``serve_queue_wait_s`` / ``serve_service_s`` histograms,
DESIGN.md §14). The queue fraction is the tuning signal: deadline-dominated
configs show it near 100% at low load, engine-bound configs near 0%.

Standalone (the harness also runs it via ``benchmarks.run``):

    PYTHONPATH=src python benchmarks/serve_latency.py --smoke
"""

from __future__ import annotations

import pathlib
import sys

if __package__ in (None, ""):  # executed as a script: make repo-root imports work
    _ROOT = pathlib.Path(__file__).resolve().parent.parent
    sys.path.insert(0, str(_ROOT))
    sys.path.insert(0, str(_ROOT / "src"))

import numpy as np

from benchmarks import common
from benchmarks.common import emit

_ENGINE = "hybrid"
_REQ_BATCH = 16  # queries per client request
_CLIENTS = 4


def _drive(srv, n: int, dist: str, rate_hz: float, requests: int, seed: int):
    """Open-loop Poisson client fleet; returns (futures, dropped)."""
    from repro.serve.workload import make_queries, run_poisson_clients

    per_client = run_poisson_clients(
        _CLIENTS,
        requests // _CLIENTS,
        rate_hz / _CLIENTS,
        lambda rng, c: make_queries(rng, n, _REQ_BATCH, dist),
        srv.submit,
        seed=seed,
    )
    flat = [fut for out in per_client for _, fut in out]
    return [f for f in flat if f is not None], sum(f is None for f in flat)


def run() -> None:
    import jax.numpy as jnp

    from repro.core import registry
    from repro.serve import RMQServer, ServeConfig

    smoke = common.SMOKE
    n = 1 << 16 if smoke else 1 << 20
    requests = 80 if smoke else 400  # total, split across clients
    deadlines_ms = (0.5, 2.0) if smoke else (0.5, 2.0, 8.0)
    loads_rps = (200.0, 800.0) if smoke else (200.0, 800.0, 3200.0)

    rng = np.random.default_rng(0)
    x = rng.random(n, dtype=np.float32)
    spec = registry.get(_ENGINE)
    state = registry.build_for_serving(_ENGINE, jnp.asarray(x))
    qfn = lambda l, r: spec.query(state, l, r)

    for deadline_ms in deadlines_ms:
        for load in loads_rps:
            srv = RMQServer(
                qfn,
                ServeConfig(
                    deadline_s=deadline_ms * 1e-3,
                    max_batch=4096,
                    max_pending=requests,
                    n=n,
                ),
            )
            srv.warmup()
            with srv:
                futs, dropped = _drive(srv, n, "medium", load, requests, seed=17)
                for f in futs:
                    f.result(timeout=600)
            st = srv.stats()
            emit(
                f"serve_latency/deadline={deadline_ms:g}ms/load={load:g}rps",
                st.p50_total_s,
                f"p50={st.p50_total_s*1e3:.2f}ms,p99={st.p99_total_s*1e3:.2f}ms,"
                f"thr={st.throughput_qps:.0f}rmq_s,batches={st.n_batches},"
                f"mean_batch={st.mean_batch_queries:.1f}q,dropped={dropped}",
            )
            # Queue-wait vs service-time decomposition (registry histograms).
            qp50, qp95 = srv.metrics.histogram("serve_queue_wait_s").percentiles((50, 95))
            sp50, sp95 = srv.metrics.histogram("serve_service_s").percentiles((50, 95))
            qfrac = qp50 / (qp50 + sp50) * 100 if (qp50 + sp50) > 0 else 0.0
            emit(
                f"serve_latency/decomp/deadline={deadline_ms:g}ms/load={load:g}rps",
                qp50,
                f"queue_p50={qp50*1e3:.2f}ms,queue_p95={qp95*1e3:.2f}ms,"
                f"service_p50={sp50*1e3:.2f}ms,service_p95={sp95*1e3:.2f}ms,"
                f"queue_frac_p50={qfrac:.0f}%",
            )


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="tiny sizes, seconds-long run")
    common.SMOKE = ap.parse_args().smoke
    run()
