"""Beyond-paper O(1) gather RMQ ("lane RMQ").

RTXRMQ's within-block work is a scan (the RT core brute-forces candidate
triangles in a leaf). On TPU we can go further than the paper: precompute
per-lane-block (width 128 = VPU lane count) prefix/suffix minima so that any
query decomposes into pure gathers:

    answer(l, r) = min( suffix_min[l]        # tail of l's lane-block
                      , ST(sub_min, ...)     # fully covered lane-blocks, O(1)
                      , prefix_min[r] )      # head of r's lane-block

Only queries living inside a single lane-block still touch raw data, and then
exactly one 128-wide vector min — the hardware-native primitive. This is the
"gather backend" measured against the paper-faithful scan in §Perf.
"""

from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from . import sparse_table
from .block_rmq import maxval, _pick

LANE = 128

__all__ = ["LaneRMQ", "build", "query", "LANE"]


class LaneRMQ(NamedTuple):
    xs: jax.Array  # (nsub, LANE) padded values
    pref_val: jax.Array  # (nsub, LANE) prefix minima within lane-block
    pref_idx: jax.Array  # (nsub, LANE) int32 global argmin (leftmost)
    suff_val: jax.Array  # (nsub, LANE) suffix minima within lane-block
    suff_idx: jax.Array  # (nsub, LANE) int32
    st: sparse_table.SparseTable  # over per-lane-block minima
    sub_gidx: jax.Array  # (nsub,) int32 global argmin per lane-block


def _minpair_scan(v: jax.Array, i: jax.Array, reverse: bool):
    """Running (min value, leftmost index) along axis 1."""

    def comb(a, b):
        av, ai = a
        bv, bi = b
        take_a = (av < bv) | ((av == bv) & (ai <= bi))
        return jnp.where(take_a, av, bv), jnp.where(take_a, ai, bi)

    return jax.lax.associative_scan(comb, (v, i), axis=1, reverse=reverse)


def build(x: jax.Array) -> LaneRMQ:
    n = x.shape[0]
    nsub = -(-n // LANE)
    big = maxval(x.dtype)
    xp = jnp.pad(x, (0, nsub * LANE - n), constant_values=big)
    xs = xp.reshape(nsub, LANE)
    gidx = jnp.arange(nsub * LANE, dtype=jnp.int32).reshape(nsub, LANE)
    pref_val, pref_idx = _minpair_scan(xs, gidx, reverse=False)
    suff_val, suff_idx = _minpair_scan(xs, gidx, reverse=True)
    st = sparse_table.build(suff_val[:, 0])  # suffix at lane 0 == block min
    return LaneRMQ(
        xs=xs,
        pref_val=pref_val,
        pref_idx=pref_idx,
        suff_val=suff_val,
        suff_idx=suff_idx,
        st=st,
        sub_gidx=suff_idx[:, 0],
    )


def query(s: LaneRMQ, l: jax.Array, r: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Batched O(1)-gather RMQ. Returns (leftmost argmin index int32, value)."""
    nsub = s.xs.shape[0]
    big = maxval(s.xs.dtype)
    l = l.astype(jnp.int32)
    r = r.astype(jnp.int32)
    sl = l // LANE
    sr = r // LANE
    llo = l - sl * LANE
    rlo = r - sr * LANE
    same = sl == sr

    # Straddling path: 3 gathers.
    lv = s.suff_val[sl, llo]
    li = s.suff_idx[sl, llo]
    rv = s.pref_val[sr, rlo]
    ri = s.pref_idx[sr, rlo]
    has_interior = (sr - sl) >= 2
    ilo = jnp.clip(sl + 1, 0, nsub - 1)
    ihi = jnp.maximum(jnp.clip(sr - 1, 0, nsub - 1), ilo)
    bi = sparse_table.query(s.st, ilo, ihi)
    iv = jnp.where(has_interior, s.st.x[bi], big)
    ii = s.sub_gidx[bi]
    v, i = _pick(lv, li, iv, ii)
    v, i = _pick(v, i, jnp.where(same, big, rv), ri)

    # Same-lane-block path: one 128-wide masked vector min (lane hardware).
    rows = jnp.take(s.xs, sl, axis=0)  # (B, LANE)
    lanes = jnp.arange(LANE, dtype=jnp.int32)[None, :]
    inside = (lanes >= llo[:, None]) & (lanes <= rlo[:, None])
    masked = jnp.where(inside, rows, big)
    lidx = jnp.argmin(masked, axis=1).astype(jnp.int32)
    sv = jnp.take_along_axis(masked, lidx[:, None], axis=1)[:, 0]
    si = sl * LANE + lidx

    return jnp.where(same, si, i), jnp.where(same, sv, v)
