"""Capability-aware engine registry: one uniform ``EngineSpec`` per engine.

Tests and benchmarks enumerate engines from here instead of hard-coding
module calls, so adding an engine automatically enrolls it in the oracle
sweeps and the crossover benchmark. The serving layer (``repro.serve``,
``repro.launch.serve``) additionally derives its ``--engine`` choices and
flag validation from the declared capabilities instead of hard-coded engine
name lists.

Conformance contract (unchanged from the bare ``Engine(build, query)``
era): ``build(x_jnp) -> state``; ``query(state, l, r) -> (idx, val)`` with
exact leftmost-tie argmin indices (int32) and the corresponding values.
Engines whose native query returns only indices are wrapped with a value
gather so the interface stays uniform.

Every build — conformance and serving alike — lowers through the staged
``core.build`` BuildPlan pipeline (shard layout -> local build -> halo
exchange -> finalize): ``EngineSpec.build`` runs the engine's plan with its
conformance defaults, and ``plan_for_serving``/``build_for_serving`` resolve
the plan from the declared serving capabilities (``serve_plan``), validating
kwargs/modes at one enforcement point. The serving layer keeps the plan —
its metadata (threshold, mode, layout) drives engine warmup.
"""

from __future__ import annotations

from typing import Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from . import (
    block_rmq,
    build as build_mod,
    distributed,
    exhaustive,
    hybrid,
    lane_rmq,
    lca,
    packing,
    sharded_hybrid,
    sparse_table,
)

__all__ = [
    "Engine",
    "EngineSpec",
    "ENGINES",
    "build_for_serving",
    "default_mesh",
    "get",
    "names",
    "plan_for_serving",
    "serveable_names",
    "updatable_names",
]


class EngineSpec(NamedTuple):
    """An engine plus its declared serving capabilities.

    ``build``/``query`` are the conformance contract every oracle sweep
    uses. ``serveable`` gates enrollment as a serving engine (``exhaustive``
    is a test oracle, not a server). ``build_kwargs`` is the vocabulary of
    serving build options the engine understands — the CLI validates flags
    against it rather than keeping per-engine name lists. ``modes`` are the
    distribution modes a mesh engine supports. ``serve_plan`` resolves the
    engine's serving BuildPlan: ``(n, mesh, axis_names, **kw) -> BuildPlan``.
    ``doc`` is one line for CLI help and error messages.
    """

    build: Callable  # (x: jax.Array) -> state
    query: Callable  # (state, l, r) -> (idx int32, val)
    serveable: bool = True
    needs_mesh: bool = False
    build_kwargs: frozenset = frozenset()
    modes: Tuple[str, ...] = ()
    serve_plan: Optional[Callable] = None  # (n, mesh, axis_names, **kw) -> BuildPlan
    # The engine enrolls in the online-update subsystem (``repro.update``):
    # its structures can be mutated incrementally (delta patch + MVCC version
    # publish) instead of rebuilt. ``repro.update.make_online`` validates the
    # flag against its per-engine patch implementations.
    updatable: bool = False
    doc: str = ""


# The former bare (build, query) tuple; positional construction still works.
Engine = EngineSpec


def _is_packed_state(s) -> bool:
    """Packed planner results are ``(structure, PackSpec)`` pairs."""
    return (
        isinstance(s, tuple)
        and len(s) == 2
        and isinstance(s[1], packing.PackSpec)
    )


def _with_values(planner: str, query_fn, packed_query_fn=None, **spec_kw) -> EngineSpec:
    """Adapt an index-only engine to the uniform (idx, val) contract.

    The planner's finalize stage already pairs the built state with ``x``
    (``with_x``); the query wrapper gathers values from it. When the planner
    has a packed variant (``packed=`` kwarg), its state is
    ``((structure, PackSpec), x)`` and ``packed_query_fn`` serves it —
    packed queries return (idx, val) natively (the word carries both), so
    no gather is needed.
    """

    def build(x):
        return build_mod.build(planner, x)

    def query(state, l, r):
        s, x = state
        if packed_query_fn is not None and _is_packed_state(s):
            struct, spec = s
            return packed_query_fn(struct, spec, l, r)
        idx = query_fn(s, l, r)
        return idx, x[idx]

    return EngineSpec(build, query, **spec_kw)


def _simple_serve_plan(planner: str, **fixed):
    def serve_plan(n, mesh, axis_names, **kw):
        return build_mod.plan_for(
            planner, n, mesh=mesh, axis_names=axis_names, **{**fixed, **kw}
        )

    return serve_plan


def _kernels_engine(block_size: int, kernel_config=None, doc: str = "") -> EngineSpec:
    """The fused-megakernel engine: state is ``(FusedRMQ, KernelConfig)``.

    ``kernel_config`` pins the conformance-build launch geometry (the
    ``fused128_dma`` variant forces the DMA fetch strategy so it rides every
    oracle sweep); serving resolves the policy through the plan instead
    (``kernel_config="cached"`` — tuned geometry with zero re-timing).
    """

    def query(state, l, r):
        from repro import kernels

        s, cfg = state
        if _is_packed_state(s):
            struct, spec = s
            return kernels.ops.query_packed(struct, spec, l, r, config=cfg)
        return kernels.ops.query(s, l, r, config=cfg)

    def serve_plan(n, mesh, axis_names, **kw):
        # A pinned variant serves its pin — the CLI's cached/tuned policy
        # must not silently unpin the forced fetch strategy.
        if kernel_config is not None:
            kw["kernel_config"] = kernel_config
        else:
            kw.setdefault("kernel_config", "cached")
        kw.setdefault("block_size", block_size)
        return build_mod.plan_for("fused", n, mesh=mesh, axis_names=axis_names, **kw)

    return EngineSpec(
        lambda x: build_mod.build(
            "fused", x, block_size=block_size, kernel_config=kernel_config
        ),
        query,
        build_kwargs=frozenset({"block_size", "kernel_config", "packed"}),
        serve_plan=serve_plan,
        doc=doc or "fused tiled Pallas megakernel (interpret mode off-TPU)",
    )


def default_mesh():
    """The all-devices 1-D serving mesh: (mesh, axis_names).

    The one definition of "no mesh was passed" — shared with the BuildPlan
    pipeline (``core.build.default_mesh``) so planner defaults, serving
    builds, and the serve CLI can never silently disagree.
    """
    return build_mod.default_mesh()


# --- mesh engines ----------------------------------------------------------


def _distributed_query(state, l, r):
    s, qfn = state
    return qfn(s, jnp.asarray(l), jnp.asarray(r))


def _block_query(state, l, r):
    """Blocked-engine query, dispatching on the packed tuple shape."""
    if _is_packed_state(state):
        s, spec = state
        return block_rmq.query_packed(s, spec, l, r)
    return block_rmq.query(state, l, r)


ENGINES: dict = {
    "sparse_table": _with_values(
        "sparse_table",
        sparse_table.query,
        packed_query_fn=sparse_table.query_packed,
        build_kwargs=frozenset({"packed"}),
        serve_plan=_simple_serve_plan("sparse_table"),
        updatable=True,
        doc="O(1) doubling-table lookups",
    ),
    "block128": EngineSpec(
        lambda x: build_mod.build("block", x, block_size=128),
        _block_query,
        build_kwargs=frozenset({"packed"}),
        serve_plan=_simple_serve_plan("block", block_size=128),
        updatable=True,
        doc="pure-jnp blocked, bs=128",
    ),
    "block256": EngineSpec(
        lambda x: build_mod.build("block", x, block_size=256),
        _block_query,
        build_kwargs=frozenset({"packed"}),
        serve_plan=_simple_serve_plan("block", block_size=256),
        updatable=True,
        doc="pure-jnp blocked, bs=256",
    ),
    "lane": EngineSpec(
        lambda x: build_mod.build("lane", x),
        lane_rmq.query,
        serve_plan=_simple_serve_plan("lane"),
        doc="beyond-paper lane-RMQ",
    ),
    "lca": _with_values(
        "lca",
        lca.query,
        serve_plan=_simple_serve_plan("lca"),
        doc="LCA/Euler-tour O(1) engine",
    ),
    # Test oracle, not a server: O(n) scan per query chunk.
    "exhaustive": _with_values(
        "exhaustive",
        lambda x, l, r: exhaustive.rmq_exhaustive(x, l, r, query_chunk=64),
        serveable=False,
        doc="O(n)-per-query scan oracle",
    ),
    # Fused tiled Pallas megakernel (interpret mode off-TPU). The _dma
    # variant forces the bounded-VMEM per-query window fetch strategy, so
    # both megakernel fetch paths ride every oracle sweep.
    "fused128": _kernels_engine(128),
    "fused128_dma": _kernels_engine(
        128,
        kernel_config=(8, "dma", 128),  # (tile, fetch, block_size) pinned
        doc="fused megakernel, DMA window fetch (bounded VMEM, any nb)",
    ),
    # Range-adaptive dispatcher over blocked + sparse-table paths.
    "hybrid": EngineSpec(
        lambda x: build_mod.build("hybrid", x, block_size=128),
        hybrid.query,
        build_kwargs=frozenset({"block_size", "threshold", "kernel_config", "packed"}),
        serve_plan=_simple_serve_plan(
            "hybrid", block_size=128, threshold="cached", kernel_config="cached"
        ),
        updatable=True,
        doc="range-adaptive blocked/sparse-table crossover dispatcher",
    ),
    # The packed-word hybrid: both tiers carry fused (value, index) words
    # (``core.packing``), halving merge traffic; layout resolved per-array
    # ("auto" -> packed32 when the key range fits, else packed64).
    "packed_hybrid": EngineSpec(
        lambda x: build_mod.build("hybrid", x, block_size=128, packed="auto"),
        hybrid.query,
        build_kwargs=frozenset({"block_size", "threshold", "kernel_config", "packed"}),
        serve_plan=_simple_serve_plan(
            "hybrid",
            block_size=128,
            threshold="cached",
            kernel_config="cached",
            packed="auto",
        ),
        updatable=True,
        doc="hybrid over fused (value,index) word planes (bandwidth-optimal)",
    ),
    # Mesh-sharded blocked engine (structure sharded, queries replicated).
    "distributed": EngineSpec(
        lambda x: build_mod.build("distributed", x, block_size=128),
        _distributed_query,
        needs_mesh=True,
        build_kwargs=frozenset({"block_size", "packed"}),
        serve_plan=_simple_serve_plan("distributed", block_size=1024),
        updatable=True,
        doc="mesh-sharded blocked engine, two-pmin merge",
    ),
    # Mesh-sharded range-adaptive dispatcher (builds over all visible
    # devices; 1-device meshes degenerate to the single-host hybrid).
    "sharded_hybrid": EngineSpec(
        lambda x: build_mod.build("sharded_hybrid", x, block_size=128),
        sharded_hybrid.query,
        needs_mesh=True,
        build_kwargs=frozenset({"block_size", "threshold", "mode", "packed"}),
        modes=sharded_hybrid.MODES,
        serve_plan=_simple_serve_plan(
            "sharded_hybrid", block_size=128, threshold="cached"
        ),
        updatable=True,
        doc="sharded range-adaptive hybrid "
        "(shard_structure | shard_batch | shard_2d)",
    ),
    # Packed sharded hybrid: words carry global indices, so the sharded
    # merge is ONE pmin and the halo recurrence ships ONE plane per level.
    "packed_sharded_hybrid": EngineSpec(
        lambda x: build_mod.build("sharded_hybrid", x, block_size=128, packed="auto"),
        sharded_hybrid.query,
        needs_mesh=True,
        build_kwargs=frozenset({"block_size", "threshold", "mode", "packed"}),
        modes=sharded_hybrid.MODES,
        serve_plan=_simple_serve_plan(
            "sharded_hybrid", block_size=128, threshold="cached", packed="auto"
        ),
        updatable=True,
        doc="sharded hybrid over packed word planes (one-pmin merge, "
        "single-plane halos)",
    ),
}


def names() -> Tuple[str, ...]:
    return tuple(ENGINES)


def serveable_names() -> Tuple[str, ...]:
    return tuple(n for n, s in ENGINES.items() if s.serveable)


def updatable_names() -> Tuple[str, ...]:
    """Engines enrolled in the online-update subsystem (``repro.update``)."""
    return tuple(n for n, s in ENGINES.items() if s.updatable)


def get(name: str) -> EngineSpec:
    try:
        return ENGINES[name]
    except KeyError:
        raise ValueError(f"unknown engine {name!r}; have {sorted(ENGINES)}") from None


def plan_for_serving(name: str, n: int, mesh=None, axis_names=None, **kwargs):
    """Resolve engine ``name``'s serving BuildPlan, validating kwargs.

    Unknown kwargs and unsupported modes raise ``ValueError`` naming the
    engine's declared capabilities — the single enforcement point behind
    CLI flag validation. Mesh engines get a default all-devices 1-D mesh
    when none is passed. The returned plan carries the resolved layout and
    metadata (threshold, mode) that serving warmup derives its per-regime
    probe batches from.
    """
    spec = get(name)
    if not spec.serveable:
        raise ValueError(f"engine {name!r} is not serveable ({spec.doc})")
    unknown = set(kwargs) - set(spec.build_kwargs)
    if unknown:
        raise ValueError(
            f"engine {name!r} does not accept {sorted(unknown)}; "
            f"declared build kwargs: {sorted(spec.build_kwargs)}"
        )
    if "mode" in kwargs and kwargs["mode"] not in spec.modes:
        raise ValueError(
            f"engine {name!r} does not support mode {kwargs['mode']!r}; have {spec.modes}"
        )
    if spec.needs_mesh and mesh is None:
        mesh, axis_names = default_mesh()
    if spec.serve_plan is None:
        raise ValueError(f"engine {name!r} declares no serving BuildPlan")
    return spec.serve_plan(int(n), mesh, axis_names, **kwargs)


def build_for_serving(name: str, x, mesh=None, axis_names=None, **kwargs):
    """Build engine ``name`` for serving: resolve its plan, then execute it."""
    x = jnp.asarray(x)
    plan = plan_for_serving(name, x.shape[0], mesh, axis_names, **kwargs)
    return build_mod.execute(plan, x)
