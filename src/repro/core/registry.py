"""Capability-aware engine registry: one uniform ``EngineSpec`` per engine.

Tests and benchmarks enumerate engines from here instead of hard-coding
module calls, so adding an engine automatically enrolls it in the oracle
sweeps and the crossover benchmark. The serving layer (``repro.serve``,
``repro.launch.serve``) additionally derives its ``--engine`` choices and
flag validation from the declared capabilities instead of hard-coded engine
name lists.

Conformance contract (unchanged from the bare ``Engine(build, query)``
era): ``build(x_jnp) -> state``; ``query(state, l, r) -> (idx, val)`` with
exact leftmost-tie argmin indices (int32) and the corresponding values.
Engines whose native query returns only indices are wrapped with a value
gather so the interface stays uniform.

Serving contract: ``serve_build(x, mesh, axis_names, **kwargs) -> state``
with ``kwargs`` restricted to the spec's declared ``build_kwargs``;
``needs_mesh`` marks engines that build over a device mesh; ``modes`` names
the supported distribution modes (``--qshard`` requires ``"shard_batch"``
here). ``build_for_serving`` validates and dispatches.
"""

from __future__ import annotations

from typing import Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from . import (
    block_rmq,
    distributed,
    exhaustive,
    hybrid,
    lane_rmq,
    lca,
    sharded_hybrid,
    sparse_table,
)

__all__ = [
    "Engine",
    "EngineSpec",
    "ENGINES",
    "build_for_serving",
    "default_mesh",
    "get",
    "names",
    "serveable_names",
]


class EngineSpec(NamedTuple):
    """An engine plus its declared serving capabilities.

    ``build``/``query`` are the conformance contract every oracle sweep
    uses. ``serveable`` gates enrollment as a serving engine (``exhaustive``
    is a test oracle, not a server). ``build_kwargs`` is the vocabulary of
    serving build options the engine understands — the CLI validates flags
    against it rather than keeping per-engine name lists. ``modes`` are the
    distribution modes a mesh engine supports. ``doc`` is one line for CLI
    help and error messages.
    """

    build: Callable  # (x: jax.Array) -> state
    query: Callable  # (state, l, r) -> (idx int32, val)
    serveable: bool = True
    needs_mesh: bool = False
    build_kwargs: frozenset = frozenset()
    modes: Tuple[str, ...] = ()
    serve_build: Optional[Callable] = None  # (x, mesh, axis_names, **kw) -> state
    doc: str = ""


# The former bare (build, query) tuple; positional construction still works.
Engine = EngineSpec


def _with_values(build_fn, query_fn, **spec_kw) -> EngineSpec:
    """Adapt an index-only engine to the uniform (idx, val) contract."""

    def build(x):
        return (build_fn(x), x)

    def query(state, l, r):
        s, x = state
        idx = query_fn(s, l, r)
        return idx, x[idx]

    return EngineSpec(build, query, **spec_kw)


def _kernels_engine(block_size: int) -> EngineSpec:
    def build(x):
        from repro import kernels

        return kernels.ops.build(x, block_size)

    def query(s, l, r):
        from repro import kernels

        return kernels.ops.query(s, l, r)

    def serve_build(x, mesh, axis_names, block_size=block_size):
        from repro import kernels

        return kernels.ops.build(jnp.asarray(x), block_size)

    return EngineSpec(
        build,
        query,
        build_kwargs=frozenset({"block_size"}),
        serve_build=serve_build,
        doc="fused tiled Pallas megakernel (interpret mode off-TPU)",
    )


def default_mesh():
    """The all-devices 1-D serving mesh: (mesh, axis_names).

    The one definition of "no mesh was passed" — ``build_for_serving`` and
    the serve CLI both use it, so they can never silently disagree.
    """
    from repro.launch.mesh import make_mesh

    return make_mesh((len(jax.devices()),), ("shard",)), ("shard",)


# --- mesh engines ----------------------------------------------------------


def _distributed_serve_build(x, mesh, axis_names, block_size=1024):
    s = distributed.build_sharded(jnp.asarray(x), mesh, axis_names, block_size)
    qfn = distributed.make_query_fn(mesh, tuple(axis_names))
    return (s, qfn)


def _distributed_build(x):
    mesh, axes = default_mesh()
    return _distributed_serve_build(x, mesh, axes, block_size=128)


def _distributed_query(state, l, r):
    s, qfn = state
    return qfn(s, jnp.asarray(l), jnp.asarray(r))


def _sharded_hybrid_serve_build(
    x, mesh, axis_names, block_size=128, threshold="cached", mode="shard_structure"
):
    return sharded_hybrid.build(
        jnp.asarray(x), mesh, axis_names, block_size, threshold=threshold, mode=mode
    )


def _hybrid_serve_build(x, mesh, axis_names, block_size=128, threshold="cached"):
    return hybrid.build(jnp.asarray(x), block_size, threshold=threshold)


ENGINES: dict = {
    "sparse_table": _with_values(
        sparse_table.build, sparse_table.query, doc="O(1) doubling-table lookups"
    ),
    "block128": EngineSpec(
        lambda x: block_rmq.build(x, 128), block_rmq.query, doc="pure-jnp blocked, bs=128"
    ),
    "block256": EngineSpec(
        lambda x: block_rmq.build(x, 256), block_rmq.query, doc="pure-jnp blocked, bs=256"
    ),
    "lane": EngineSpec(lane_rmq.build, lane_rmq.query, doc="beyond-paper lane-RMQ"),
    "lca": _with_values(lca.build, lca.query, doc="LCA/Euler-tour O(1) engine"),
    # Test oracle, not a server: O(n) scan per query chunk.
    "exhaustive": _with_values(
        lambda x: x,
        lambda x, l, r: exhaustive.rmq_exhaustive(x, l, r, query_chunk=64),
        serveable=False,
        doc="O(n)-per-query scan oracle",
    ),
    # Fused tiled Pallas megakernel (interpret mode off-TPU).
    "fused128": _kernels_engine(128),
    # Range-adaptive dispatcher over blocked + sparse-table paths.
    "hybrid": EngineSpec(
        lambda x: hybrid.build(x, 128),
        hybrid.query,
        build_kwargs=frozenset({"block_size", "threshold"}),
        serve_build=_hybrid_serve_build,
        doc="range-adaptive blocked/sparse-table crossover dispatcher",
    ),
    # Mesh-sharded blocked engine (structure sharded, queries replicated).
    "distributed": EngineSpec(
        _distributed_build,
        _distributed_query,
        needs_mesh=True,
        build_kwargs=frozenset({"block_size"}),
        serve_build=_distributed_serve_build,
        doc="mesh-sharded blocked engine, two-pmin merge",
    ),
    # Mesh-sharded range-adaptive dispatcher (builds over all visible
    # devices; 1-device meshes degenerate to the single-host hybrid).
    "sharded_hybrid": EngineSpec(
        lambda x: sharded_hybrid.build(x, block_size=128),
        sharded_hybrid.query,
        needs_mesh=True,
        build_kwargs=frozenset({"block_size", "threshold", "mode"}),
        modes=sharded_hybrid.MODES,
        serve_build=_sharded_hybrid_serve_build,
        doc="sharded range-adaptive hybrid (shard_structure | shard_batch)",
    ),
}


def names() -> Tuple[str, ...]:
    return tuple(ENGINES)


def serveable_names() -> Tuple[str, ...]:
    return tuple(n for n, s in ENGINES.items() if s.serveable)


def get(name: str) -> EngineSpec:
    try:
        return ENGINES[name]
    except KeyError:
        raise ValueError(f"unknown engine {name!r}; have {sorted(ENGINES)}") from None


def build_for_serving(name: str, x, mesh=None, axis_names=None, **kwargs):
    """Build engine ``name`` for serving, validating kwargs against its spec.

    Unknown kwargs and unsupported modes raise ``ValueError`` naming the
    engine's declared capabilities — the single enforcement point behind
    CLI flag validation. Mesh engines get a default all-devices 1-D mesh
    when none is passed.
    """
    spec = get(name)
    if not spec.serveable:
        raise ValueError(f"engine {name!r} is not serveable ({spec.doc})")
    unknown = set(kwargs) - set(spec.build_kwargs)
    if unknown:
        raise ValueError(
            f"engine {name!r} does not accept {sorted(unknown)}; "
            f"declared build kwargs: {sorted(spec.build_kwargs)}"
        )
    if "mode" in kwargs and kwargs["mode"] not in spec.modes:
        raise ValueError(
            f"engine {name!r} does not support mode {kwargs['mode']!r}; have {spec.modes}"
        )
    if spec.needs_mesh and mesh is None:
        mesh, axis_names = default_mesh()
    if spec.serve_build is None:
        return spec.build(jnp.asarray(x))
    return spec.serve_build(jnp.asarray(x), mesh, axis_names, **kwargs)
