"""First-class engine registry: one uniform (build, query) interface.

Tests and benchmarks enumerate engines from here instead of hard-coding
module calls, so adding an engine (e.g. ``hybrid``) automatically enrolls it
in the oracle sweeps and the crossover benchmark.

Contract: ``build(x_jnp) -> state``; ``query(state, l, r) -> (idx, val)``
with exact leftmost-tie argmin indices (int32) and the corresponding values.
Engines whose native query returns only indices are wrapped with a value
gather so the interface stays uniform.
"""

from __future__ import annotations

from typing import Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from . import block_rmq, exhaustive, hybrid, lane_rmq, lca, sharded_hybrid, sparse_table

__all__ = ["Engine", "ENGINES", "get", "names"]


class Engine(NamedTuple):
    build: Callable  # (x: jax.Array) -> state
    query: Callable  # (state, l, r) -> (idx int32, val)


def _with_values(build_fn, query_fn):
    """Adapt an index-only engine to the uniform (idx, val) contract."""

    def build(x):
        return (build_fn(x), x)

    def query(state, l, r):
        s, x = state
        idx = query_fn(s, l, r)
        return idx, x[idx]

    return Engine(build, query)


def _kernels_engine(block_size: int) -> Engine:
    def build(x):
        from repro import kernels

        return kernels.ops.build(x, block_size)

    def query(s, l, r):
        from repro import kernels

        return kernels.ops.query(s, l, r)

    return Engine(build, query)


ENGINES: dict = {
    "sparse_table": _with_values(sparse_table.build, sparse_table.query),
    "block128": Engine(lambda x: block_rmq.build(x, 128), block_rmq.query),
    "block256": Engine(lambda x: block_rmq.build(x, 256), block_rmq.query),
    "lane": Engine(lane_rmq.build, lane_rmq.query),
    "lca": _with_values(lca.build, lca.query),
    "exhaustive": _with_values(
        lambda x: x, lambda x, l, r: exhaustive.rmq_exhaustive(x, l, r, query_chunk=64)
    ),
    # Fused tiled Pallas megakernel (interpret mode off-TPU).
    "fused128": _kernels_engine(128),
    # Range-adaptive dispatcher over blocked + sparse-table paths.
    "hybrid": Engine(lambda x: hybrid.build(x, 128), hybrid.query),
    # Mesh-sharded range-adaptive dispatcher (builds over all visible
    # devices; 1-device meshes degenerate to the single-host hybrid).
    "sharded_hybrid": Engine(
        lambda x: sharded_hybrid.build(x, block_size=128), sharded_hybrid.query
    ),
}


def names() -> Tuple[str, ...]:
    return tuple(ENGINES)


def get(name: str) -> Engine:
    try:
        return ENGINES[name]
    except KeyError:
        raise ValueError(f"unknown engine {name!r}; have {sorted(ENGINES)}") from None
