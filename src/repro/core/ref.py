"""Reference RMQ oracles.

``rmq_ref`` is the ground-truth used by every test and kernel sweep: a plain
numpy scan per query, returning the *leftmost* argmin index, matching the
paper's tie-breaking convention (Section 2).
"""

from __future__ import annotations

import numpy as np

__all__ = ["rmq_ref", "rmq_values_ref"]


def rmq_ref(x, l, r) -> np.ndarray:
    """Batched ground-truth RMQ. Returns leftmost argmin index per query.

    Args:
      x: (n,) array of comparable values.
      l, r: (B,) integer arrays with 0 <= l <= r < n.
    """
    x = np.asarray(x)
    l = np.asarray(l).ravel()
    r = np.asarray(r).ravel()
    if np.any(l > r) or np.any(l < 0) or np.any(r >= x.shape[0]):
        raise ValueError("invalid query bounds")
    out = np.empty(l.shape, dtype=np.int64)
    for q in range(l.size):
        seg = x[l[q] : r[q] + 1]
        out[q] = l[q] + int(np.argmin(seg))  # np.argmin returns first (leftmost) min
    return out


def rmq_values_ref(x, l, r) -> np.ndarray:
    """Batched ground-truth range-minimum *values*."""
    x = np.asarray(x)
    return x[rmq_ref(x, l, r)]
