"""Level-3 of the hierarchy: RMQ sharded across the device mesh.

The paper leaves multi-BVH distribution as future work (§7.i): "one BVH per
cluster of blocks". On a TPU pod that is exactly block-range ownership per
device: each device holds a contiguous chunk of the array with its own local
blocked structure, answers the query restricted to its chunk, and the shards
merge with two all-reduce-mins over ICI (value min, then leftmost index among
value-matching shards — exact leftmost semantics with only min collectives).

Works on any mesh: the array is sharded over *all* given axes flattened, so
the same code runs a 16x16 pod and a (pod=2, 16, 16) multi-pod mesh.

Two orthogonal distribution strategies are provided (DESIGN.md §6):

* **Structure-sharded** (``build_sharded`` / ``build_sharded_st`` +
  ``make_query_fn`` / ``make_st_query_fn``): the *array* is sharded, the
  query batch is replicated, and every device answers every query against
  its chunk; shards merge with the two-pmin leftmost trick. Memory scales
  with device count; per-query work is replicated.
* **Batch-sharded** (``build_replicated`` / ``build_replicated_st`` + the
  same query factories with ``batch_sharded=True``): the *query batch* is
  sharded over the flattened mesh axes and each device answers its slice
  locally against a replicated structure. Serving throughput scales with
  device count; each query is answered by exactly one device, so the merge
  degenerates from the two-pmin reduction to a collective-free concatenation
  along the sharded batch dim.

The sharded sparse-table path (``ShardedSparseTable``) is the long-range
constituent of ``core.sharded_hybrid``: the doubling table is built globally
and column-sharded, each lookup column is owned by exactly one device, and
the two window candidates merge with the same pmin trick.
"""

from __future__ import annotations

import functools
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

try:  # jax >= 0.6 exposes shard_map at top level with `check_vma`
    from jax import shard_map as _shard_map_impl

    _SHARD_MAP_CHECK_KW = "check_vma"
except ImportError:  # jax 0.4.x: experimental module, kwarg named `check_rep`
    from jax.experimental.shard_map import shard_map as _shard_map_impl

    _SHARD_MAP_CHECK_KW = "check_rep"


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=True):
    """Version-compat wrapper: maps ``check_vma`` to the installed jax's kwarg."""
    kw = {_SHARD_MAP_CHECK_KW: check_vma}
    return _shard_map_impl(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)

from typing import NamedTuple

from . import block_rmq, sparse_table
from .block_rmq import BlockRMQ, maxval
from .sparse_table import SparseTable

__all__ = [
    "ShardedSparseTable",
    "build_replicated",
    "build_replicated_st",
    "build_sharded",
    "build_sharded_st",
    "make_query_fn",
    "make_st_query_fn",
    "num_shards",
    "pad_to_shards",
]

_INT_BIG = jnp.int32(2**31 - 1)


def num_shards(mesh: Mesh, axis_names: Sequence[str]) -> int:
    """Product of the given mesh axes — the flattened shard count."""
    num = 1
    for a in axis_names:
        num *= mesh.shape[a]
    return num


def _axis_size(name: str):
    if hasattr(jax.lax, "axis_size"):  # jax >= 0.5
        return jax.lax.axis_size(name)
    return jax.lax.psum(1, name)  # folds to a constant inside shard_map


def _flat_axis_index(axis_names: Sequence[str]) -> jax.Array:
    """Flattened linear device index across the given mesh axes."""
    idx = jnp.int32(0)
    for name in axis_names:
        idx = idx * _axis_size(name) + jax.lax.axis_index(name)
    return idx


def pad_to_shards(x: jax.Array, num_shards: int, block_size: int) -> jax.Array:
    """Pad so every shard owns the same whole number of blocks."""
    chunk = num_shards * block_size
    n_pad = -(-x.shape[0] // chunk) * chunk
    return jnp.pad(x, (0, n_pad - x.shape[0]), constant_values=maxval(x.dtype))


def build_sharded(x: jax.Array, mesh: Mesh, axis_names: Sequence[str], block_size: int) -> BlockRMQ:
    """Build per-shard blocked structures; leaves are sharded on the block dim."""
    axis_names = tuple(axis_names)
    num = num_shards(mesh, axis_names)
    x = pad_to_shards(x, num, block_size)

    def local_build(x_local):
        return block_rmq.build(x_local[0], block_size)

    out_specs = BlockRMQ(
        x_blocks=P(axis_names),
        bmin_val=P(axis_names),
        bmin_gidx=P(axis_names),
        st=SparseTable(idx=P(None, axis_names), x=P(axis_names)),
    )
    fn = shard_map(
        local_build,
        mesh=mesh,
        in_specs=P(axis_names),
        out_specs=out_specs,
        check_vma=False,
    )
    # shard_map gives each shard x of shape (n/num,); wrap in a leading dim so
    # the local function sees a rank-1 chunk regardless of axis grouping.
    return fn(x.reshape(num, -1))


def _block_rmq_specs(spec_blocks, spec_table):
    """BlockRMQ pytree of PartitionSpecs: block dim `spec_blocks`, tables too."""
    return BlockRMQ(
        x_blocks=spec_blocks,
        bmin_val=spec_blocks,
        bmin_gidx=spec_blocks,
        st=SparseTable(idx=spec_table, x=spec_blocks),
    )


def _pad_batch(l, r, num: int):
    """Pad a query batch with trivial (0, 0) queries to a multiple of `num`."""
    b = l.shape[0]
    bp = -(-b // num) * num
    return jnp.pad(l, (0, bp - b)), jnp.pad(r, (0, bp - b)), b


def make_query_fn(mesh: Mesh, axis_names: Sequence[str], *, batch_sharded: bool = False):
    """Jitted batched distributed query: (BlockRMQ, l, r) -> (idx, val).

    ``batch_sharded=False`` (default): the structure is sharded
    (``build_sharded``), queries are replicated, every device answers every
    query against its chunk, and shards merge with two pmin all-reduces.

    ``batch_sharded=True``: the structure is replicated (``build_replicated``),
    the query batch is sharded over the flattened mesh axes, and each device
    answers only its ``B / num_shards`` slice — work scales with device count
    and the outputs concatenate along the sharded batch dim with no
    collective. Batches are padded internally to a shard multiple.
    """
    axis_names = tuple(axis_names)

    if batch_sharded:
        num = num_shards(mesh, axis_names)
        inner = shard_map(
            block_rmq.query,
            mesh=mesh,
            in_specs=(_block_rmq_specs(P(), P()), P(axis_names), P(axis_names)),
            out_specs=(P(axis_names), P(axis_names)),
            check_vma=False,
        )

        def fn(s: BlockRMQ, l, r):
            lp, rp, b = _pad_batch(l, r, num)
            idx, val = inner(s, lp, rp)
            return idx[:b], val[:b]

        return jax.jit(fn)

    def local_query(s: BlockRMQ, l, r):
        bs = s.x_blocks.shape[1]
        local_n = s.x_blocks.shape[0] * bs
        big = maxval(s.x_blocks.dtype)
        off = _flat_axis_index(axis_names) * local_n

        has = (r >= off) & (l <= off + local_n - 1)
        ql = jnp.clip(l - off, 0, local_n - 1)
        qr = jnp.clip(r - off, 0, local_n - 1)
        idx, val = block_rmq.query(s, ql, qr)
        val = jnp.where(has, val, big)
        gidx = jnp.where(has, idx + off, _INT_BIG)

        # Exact leftmost merge with two min all-reduces over ICI.
        vmin = jax.lax.pmin(val, axis_names)
        cand = jnp.where(val == vmin, gidx, _INT_BIG)
        imin = jax.lax.pmin(cand, axis_names)
        return imin, vmin

    in_specs = (
        _block_rmq_specs(P(axis_names), P(None, axis_names)),
        P(),  # queries replicated
        P(),
    )
    fn = shard_map(
        local_query,
        mesh=mesh,
        in_specs=in_specs,
        out_specs=(P(), P()),
        check_vma=False,
    )
    return jax.jit(fn)


def build_replicated(x: jax.Array, mesh: Mesh, block_size: int) -> BlockRMQ:
    """Full blocked structure, replicated on every device (batch-sharded mode).

    The memory/throughput dual of ``build_sharded``: every device holds the
    whole structure so it can answer any query slice locally.
    """
    s = block_rmq.build(x, block_size)
    return jax.device_put(s, jax.sharding.NamedSharding(mesh, P()))


class ShardedSparseTable(NamedTuple):
    """Globally-built doubling table, column-sharded over the mesh.

    Unlike the per-shard tables inside ``build_sharded`` (whose windows never
    cross a chunk boundary), this table is built over the *full* array and
    then sharded by column, so any O(1) window lookup is answered by exactly
    the device owning that column. ``val`` materializes ``x[idx]`` so a
    lookup never needs a cross-shard value gather.
    """

    idx: jax.Array  # (K, n_pad) int32 leftmost argmin per doubling window
    val: jax.Array  # (K, n_pad) the corresponding window-min values


def build_sharded_st(x: jax.Array, mesh: Mesh, axis_names: Sequence[str]) -> ShardedSparseTable:
    """Build the global doubling table and shard its columns over the mesh.

    The *steady-state* layout is sharded (K*n/D entries per device), but the
    build itself materializes the full (K, n) table on the default device
    before the device_put — the build-time memory ceiling is one device's
    table, not one shard's. A distributed build (level-k halo exchange under
    shard_map) lifts that ceiling; see ROADMAP.
    """
    axis_names = tuple(axis_names)
    num = num_shards(mesh, axis_names)
    n = x.shape[0]
    n_pad = -(-n // num) * num
    # Pad columns with +inf values; queries never index past n-1 and every
    # window [c, c + 2^k) they touch lies inside [l, r], so pads never win.
    xp = jnp.pad(x, (0, n_pad - n), constant_values=maxval(x.dtype))
    st = sparse_table.build(xp)
    sh = jax.sharding.NamedSharding(mesh, P(None, axis_names))
    return ShardedSparseTable(
        idx=jax.device_put(st.idx, sh),
        val=jax.device_put(xp[st.idx], sh),
    )


def build_replicated_st(x: jax.Array, mesh: Mesh) -> SparseTable:
    """Full doubling table replicated on every device (batch-sharded mode)."""
    st = sparse_table.build(x)
    return jax.device_put(st, jax.sharding.NamedSharding(mesh, P()))


def make_st_query_fn(mesh: Mesh, axis_names: Sequence[str], *, batch_sharded: bool = False):
    """Jitted distributed sparse-table query -> (idx, val).

    ``batch_sharded=False``: takes a ``ShardedSparseTable`` (column-sharded
    global table), queries replicated. Each query needs two window lookups
    (columns ``l`` and ``r - 2^k + 1``); each column is owned by exactly one
    device, so non-owners contribute +inf/int-max and two pmins recover both
    candidates everywhere, then the standard leftmost-tie pick (prefer the
    left window on value ties) finishes the query.

    ``batch_sharded=True``: takes a replicated ``SparseTable``
    (``build_replicated_st``), the query batch is sharded, and each device
    answers its slice with the plain O(1) lookup plus a local value gather.
    """
    axis_names = tuple(axis_names)

    if batch_sharded:
        num = num_shards(mesh, axis_names)

        def local_st(t: SparseTable, l, r):
            idx = sparse_table.query(t, l, r)
            return idx, t.x[idx]

        inner = shard_map(
            local_st,
            mesh=mesh,
            in_specs=(SparseTable(idx=P(), x=P()), P(axis_names), P(axis_names)),
            out_specs=(P(axis_names), P(axis_names)),
            check_vma=False,
        )

        def fn(t: SparseTable, l, r):
            lp, rp, b = _pad_batch(l, r, num)
            idx, val = inner(t, lp, rp)
            return idx[:b], val[:b]

        return jax.jit(fn)

    def local_query(t: ShardedSparseTable, l, r):
        cols = t.idx.shape[1]  # columns owned by this shard
        c0 = _flat_axis_index(axis_names) * cols
        big = maxval(t.val.dtype)
        l = l.astype(jnp.int32)
        r = r.astype(jnp.int32)
        k = sparse_table.exact_log2(r - l + 1)
        # The two covering windows start at columns l and r - 2^k + 1.
        cand = jnp.stack([l, r - jnp.left_shift(jnp.int32(1), k) + 1])  # (2, B)
        owned = (cand >= c0) & (cand < c0 + cols)
        cl = jnp.clip(cand - c0, 0, cols - 1)
        kk = jnp.broadcast_to(k[None, :], cand.shape)
        v = jnp.where(owned, t.val[kk, cl], big)
        i = jnp.where(owned, t.idx[kk, cl], _INT_BIG)
        # One owner per column: the pmins select the owner's candidate.
        v = jax.lax.pmin(v, axis_names)
        i = jax.lax.pmin(i, axis_names)
        take_left = v[0] <= v[1]  # left window on ties -> exact leftmost
        return jnp.where(take_left, i[0], i[1]), jnp.where(take_left, v[0], v[1])

    fn = shard_map(
        local_query,
        mesh=mesh,
        in_specs=(ShardedSparseTable(idx=P(None, axis_names), val=P(None, axis_names)), P(), P()),
        out_specs=(P(), P()),
        check_vma=False,
    )
    return jax.jit(fn)
