"""Level-3 of the hierarchy: RMQ sharded across the device mesh.

The paper leaves multi-BVH distribution as future work (§7.i): "one BVH per
cluster of blocks". On a TPU pod that is exactly block-range ownership per
device: each device holds a contiguous chunk of the array with its own local
blocked structure, answers the query restricted to its chunk, and the shards
merge with two all-reduce-mins over ICI (value min, then leftmost index among
value-matching shards — exact leftmost semantics with only min collectives).

Works on any mesh: the array is sharded over *all* given axes flattened, so
the same code runs a 16x16 pod and a (pod=2, 16, 16) multi-pod mesh.

Three distribution strategies are provided (DESIGN.md §6, §8):

* **Structure-sharded** (``build_sharded`` / ``build_sharded_st`` +
  ``make_query_fn`` / ``make_st_query_fn``): the *array* is sharded, the
  query batch is replicated, and every device answers every query against
  its chunk; shards merge with the two-pmin leftmost trick. Memory scales
  with device count; per-query work is replicated.
* **Batch-sharded** (``build_replicated`` / ``build_replicated_st`` + the
  same query factories with ``batch_sharded=True``): the *query batch* is
  sharded over the flattened mesh axes and each device answers its slice
  locally against a replicated structure. Serving throughput scales with
  device count; each query is answered by exactly one device, so the merge
  degenerates from the two-pmin reduction to a collective-free concatenation
  along the sharded batch dim.
* **2D (structure x batch)** (the same factories with ``batch_axes=...``):
  the structure is sharded over the given ``axis_names`` and the query batch
  over the disjoint ``batch_axes``, so memory AND throughput both scale —
  each batch slice is answered by one structure-shard group, merged with
  pmins over the structure axes only.

The sharded sparse-table path (``ShardedSparseTable``) is the long-range
constituent of ``core.sharded_hybrid``: the doubling table is column-sharded,
each lookup column is owned by exactly one device (per structure-shard
group), and the two window candidates merge with the same pmin trick. Its
build is *distributed* — per-shard doubling with a level-k halo exchange of
boundary columns (``st_local_level0`` + ``st_halo_doubling``, sequenced by
the ``core.build`` BuildPlan pipeline) — so build-time memory is bounded by
the shard, never the full (K, n) table.
"""

from __future__ import annotations

import functools
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

try:  # jax >= 0.6 exposes shard_map at top level with `check_vma`
    from jax import shard_map as _shard_map_impl

    _SHARD_MAP_CHECK_KW = "check_vma"
except ImportError:  # jax 0.4.x: experimental module, kwarg named `check_rep`
    from jax.experimental.shard_map import shard_map as _shard_map_impl

    _SHARD_MAP_CHECK_KW = "check_rep"


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=True):
    """Version-compat wrapper: maps ``check_vma`` to the installed jax's kwarg."""
    kw = {_SHARD_MAP_CHECK_KW: check_vma}
    return _shard_map_impl(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)

from typing import NamedTuple

from . import block_rmq, packing, sparse_table
from .block_rmq import BlockRMQ, PackedBlockRMQ, maxval
from .sparse_table import PackedSparseTable, SparseTable

__all__ = [
    "ShardedSparseTable",
    "build_replicated",
    "build_replicated_packed",
    "build_replicated_st",
    "build_replicated_st_packed",
    "build_sharded",
    "build_sharded_packed",
    "build_sharded_st",
    "build_sharded_st_packed",
    "make_packed_query_fn",
    "make_packed_st_query_fn",
    "make_query_fn",
    "make_st_query_fn",
    "num_shards",
    "pack_global",
    "pad_to_shards",
    "patch_sharded",
    "patch_sharded_packed",
    "patch_sharded_st",
    "patch_sharded_st_packed",
    "st_halo_doubling",
    "st_halo_doubling_packed",
    "st_levels",
    "st_local_level0",
]

_INT_BIG = jnp.int32(2**31 - 1)


def num_shards(mesh: Mesh, axis_names: Sequence[str]) -> int:
    """Product of the given mesh axes — the flattened shard count."""
    num = 1
    for a in axis_names:
        num *= mesh.shape[a]
    return num


def _axis_size(name: str):
    if hasattr(jax.lax, "axis_size"):  # jax >= 0.5
        return jax.lax.axis_size(name)
    return jax.lax.psum(1, name)  # folds to a constant inside shard_map


def _flat_axis_index(axis_names: Sequence[str]) -> jax.Array:
    """Flattened linear device index across the given mesh axes."""
    idx = jnp.int32(0)
    for name in axis_names:
        idx = idx * _axis_size(name) + jax.lax.axis_index(name)
    return idx


def pad_to_shards(x: jax.Array, num_shards: int, block_size: int) -> jax.Array:
    """Pad so every shard owns the same whole number of blocks."""
    chunk = num_shards * block_size
    n_pad = -(-x.shape[0] // chunk) * chunk
    return jnp.pad(x, (0, n_pad - x.shape[0]), constant_values=maxval(x.dtype))


@functools.lru_cache(maxsize=None)
def _sharded_build_fn(mesh: Mesh, axis_names: Tuple[str, ...], block_size: int):
    def local_build(x_local):
        return block_rmq.build(x_local[0], block_size)

    out_specs = BlockRMQ(
        x_blocks=P(axis_names),
        bmin_val=P(axis_names),
        bmin_gidx=P(axis_names),
        st=SparseTable(idx=P(None, axis_names), x=P(axis_names)),
    )
    return jax.jit(
        shard_map(
            local_build,
            mesh=mesh,
            in_specs=P(axis_names),
            out_specs=out_specs,
            check_vma=False,
        )
    )


def build_sharded(x: jax.Array, mesh: Mesh, axis_names: Sequence[str], block_size: int) -> BlockRMQ:
    """Build per-shard blocked structures; leaves are sharded on the block dim.

    The BuildPlan "local build" stage of the mesh engines: no communication,
    one compiled (and cached) per-shard ``block_rmq.build`` over the mesh.
    """
    axis_names = tuple(axis_names)
    num = num_shards(mesh, axis_names)
    x = pad_to_shards(x, num, block_size)
    # shard_map gives each shard x of shape (n/num,); wrap in a leading dim so
    # the local function sees a rank-1 chunk regardless of axis grouping.
    return _sharded_build_fn(mesh, axis_names, block_size)(x.reshape(num, -1))


def _block_rmq_specs(spec_blocks, spec_table):
    """BlockRMQ pytree of PartitionSpecs: block dim `spec_blocks`, tables too."""
    return BlockRMQ(
        x_blocks=spec_blocks,
        bmin_val=spec_blocks,
        bmin_gidx=spec_blocks,
        st=SparseTable(idx=spec_table, x=spec_blocks),
    )


def _pad_batch(l, r, num: int):
    """Pad a query batch with trivial (0, 0) queries to a multiple of `num`."""
    b = l.shape[0]
    bp = -(-b // num) * num
    return jnp.pad(l, (0, bp - b)), jnp.pad(r, (0, bp - b)), b


def _check_batch_axes(axis_names, batch_axes, batch_sharded):
    """Normalize/validate the 2D-mode batch axes (disjoint from structure)."""
    batch_axes = tuple(batch_axes or ())
    if batch_axes and batch_sharded:
        raise ValueError("batch_axes is the 2D mode; batch_sharded shards over "
                         "ALL axes — pass one or the other")
    overlap = set(batch_axes) & set(axis_names)
    if overlap:
        raise ValueError(f"batch_axes {sorted(overlap)} overlap the structure axes")
    return batch_axes


def make_query_fn(
    mesh: Mesh,
    axis_names: Sequence[str],
    *,
    batch_sharded: bool = False,
    batch_axes: Sequence[str] | None = None,
):
    """Jitted batched distributed query: (BlockRMQ, l, r) -> (idx, val).

    ``batch_sharded=False`` (default): the structure is sharded
    (``build_sharded``), queries are replicated, every device answers every
    query against its chunk, and shards merge with two pmin all-reduces.

    ``batch_sharded=True``: the structure is replicated (``build_replicated``),
    the query batch is sharded over the flattened mesh axes, and each device
    answers only its ``B / num_shards`` slice — work scales with device count
    and the outputs concatenate along the sharded batch dim with no
    collective. Batches are padded internally to a shard multiple.

    ``batch_axes=...`` (2D mesh mode): the structure stays sharded over
    ``axis_names`` while the query batch is sharded over the disjoint
    ``batch_axes`` — each batch slice is answered by one structure-shard
    group, so the pmin merge runs over the structure axes only and both
    memory and throughput scale. Empty ``batch_axes`` degrades exactly to
    the default structure-sharded path.
    """
    axis_names = tuple(axis_names)
    batch_axes = _check_batch_axes(axis_names, batch_axes, batch_sharded)

    if batch_sharded:
        num = num_shards(mesh, axis_names)
        inner = shard_map(
            block_rmq.query,
            mesh=mesh,
            in_specs=(_block_rmq_specs(P(), P()), P(axis_names), P(axis_names)),
            out_specs=(P(axis_names), P(axis_names)),
            check_vma=False,
        )

        def fn(s: BlockRMQ, l, r):
            lp, rp, b = _pad_batch(l, r, num)
            idx, val = inner(s, lp, rp)
            return idx[:b], val[:b]

        return jax.jit(fn)

    def local_query(s: BlockRMQ, l, r):
        bs = s.x_blocks.shape[1]
        local_n = s.x_blocks.shape[0] * bs
        big = maxval(s.x_blocks.dtype)
        off = _flat_axis_index(axis_names) * local_n

        has = (r >= off) & (l <= off + local_n - 1)
        ql = jnp.clip(l - off, 0, local_n - 1)
        qr = jnp.clip(r - off, 0, local_n - 1)
        idx, val = block_rmq.query(s, ql, qr)
        val = jnp.where(has, val, big)
        gidx = jnp.where(has, idx + off, _INT_BIG)

        # Exact leftmost merge with two min all-reduces over ICI.
        vmin = jax.lax.pmin(val, axis_names)
        cand = jnp.where(val == vmin, gidx, _INT_BIG)
        imin = jax.lax.pmin(cand, axis_names)
        return imin, vmin

    spec_b = P(batch_axes) if batch_axes else P()
    in_specs = (
        _block_rmq_specs(P(axis_names), P(None, axis_names)),
        spec_b,  # queries replicated (default) or sharded over batch_axes (2D)
        spec_b,
    )
    inner = shard_map(
        local_query,
        mesh=mesh,
        in_specs=in_specs,
        out_specs=(spec_b, spec_b),
        check_vma=False,
    )
    if not batch_axes:
        return jax.jit(inner)
    nb = num_shards(mesh, batch_axes)

    def fn(s: BlockRMQ, l, r):
        lp, rp, b = _pad_batch(l, r, nb)
        idx, val = inner(s, lp, rp)
        return idx[:b], val[:b]

    return jax.jit(fn)


def build_replicated(x: jax.Array, mesh: Mesh, block_size: int) -> BlockRMQ:
    """Full blocked structure, replicated on every device (batch-sharded mode).

    The memory/throughput dual of ``build_sharded``: every device holds the
    whole structure so it can answer any query slice locally.
    """
    s = block_rmq.build(x, block_size)
    return jax.device_put(s, jax.sharding.NamedSharding(mesh, P()))


class ShardedSparseTable(NamedTuple):
    """Globally-built doubling table, column-sharded over the mesh.

    Unlike the per-shard tables inside ``build_sharded`` (whose windows never
    cross a chunk boundary), this table is built over the *full* array and
    then sharded by column, so any O(1) window lookup is answered by exactly
    the device owning that column. ``val`` materializes ``x[idx]`` so a
    lookup never needs a cross-shard value gather.
    """

    idx: jax.Array  # (K, n_pad) int32 leftmost argmin per doubling window
    val: jax.Array  # (K, n_pad) the corresponding window-min values


def _flat_shift(x, mesh: Mesh, axis_names: Sequence[str], d: int):
    """Value held by the shard ``d`` places to the right in flattened order.

    The halo-exchange transport: each device receives the array held by the
    device whose flattened index (over ``axis_names``) is its own plus ``d``;
    devices whose source falls off the grid receive zeros (callers mask those
    positions — they correspond to out-of-range global columns). A flat shift
    over a multi-axis product decomposes into a minor-axis rotation plus a
    carry-select between two recursive shifts of the remaining axes, so only
    single-axis ``ppermute`` collectives are ever issued.
    """
    if d == 0:
        return x
    name = axis_names[-1]
    size = mesh.shape[name]
    if len(axis_names) == 1:
        if d >= size:
            return jnp.zeros_like(x)
        return jax.lax.ppermute(x, name, [(i, i - d) for i in range(d, size)])
    d_major, d_minor = divmod(d, size)
    rot = (
        jax.lax.ppermute(x, name, [(i, (i - d_minor) % size) for i in range(size)])
        if d_minor
        else x
    )
    lo = _flat_shift(rot, mesh, axis_names[:-1], d_major)
    if d_minor == 0:
        return lo
    hi = _flat_shift(rot, mesh, axis_names[:-1], d_major + 1)
    carry = jax.lax.axis_index(name) + d_minor >= size
    return jnp.where(carry, hi, lo)


def st_levels(n_pad: int) -> int:
    """Doubling-table depth for a length-``n_pad`` array (matches
    ``sparse_table.build`` exactly — bit-identity depends on it)."""
    return max(1, (n_pad - 1).bit_length() + 1) if n_pad > 1 else 1


@functools.lru_cache(maxsize=None)
def _st_level0_fn(mesh: Mesh, axis_names: Tuple[str, ...], shard_len: int):
    def local(x_local):
        flat = _flat_axis_index(axis_names)
        idx = flat * shard_len + jnp.arange(shard_len, dtype=jnp.int32)
        return idx.astype(jnp.int32), x_local

    return jax.jit(
        shard_map(
            local,
            mesh=mesh,
            in_specs=P(axis_names),
            out_specs=(P(axis_names), P(axis_names)),
            check_vma=False,
        )
    )


def st_local_level0(
    xp: jax.Array, mesh: Mesh, axis_names: Sequence[str]
) -> Tuple[jax.Array, jax.Array]:
    """BuildPlan "local build" stage: per-shard level-0 (idx, val) rows.

    ``xp`` is the shard-divisible padded array; each device computes the
    trivial level-0 row for its own columns (global index + value) with no
    communication. Outputs stay column-sharded over ``axis_names``.
    """
    axis_names = tuple(axis_names)
    num = num_shards(mesh, axis_names)
    return _st_level0_fn(mesh, axis_names, xp.shape[0] // num)(xp)


@functools.lru_cache(maxsize=None)
def _st_halo_fn(mesh: Mesh, axis_names: Tuple[str, ...], n_pad: int, num: int):
    shard_len = n_pad // num
    k_levels = st_levels(n_pad)

    def local(idx, val):
        flat = _flat_axis_index(axis_names)
        cols = jnp.arange(shard_len, dtype=jnp.int32)
        is_last = flat == num - 1
        idx_rows, val_rows = [idx], [val]
        for k in range(1, k_levels):
            h = 1 << (k - 1)
            if h >= n_pad:
                # Window spans the whole array: rows repeat from here on
                # (sparse_table.build appends cur unchanged).
                idx_rows.append(idx)
                val_rows.append(val)
                continue
            d, r = divmod(h, shard_len)
            wi = _flat_shift(idx, mesh, axis_names, d)
            wv = _flat_shift(val, mesh, axis_names, d)
            if r:
                bi = _flat_shift(idx, mesh, axis_names, d + 1)
                bv = _flat_shift(val, mesh, axis_names, d + 1)
                wi = jnp.concatenate([wi[r:], bi[:r]])
                wv = jnp.concatenate([wv[r:], bv[:r]])
            # Tail clamp: global column >= n_pad reads the previous row's
            # last column. Only the last shard holds it; pmax over -1 filler
            # (indices are non-negative) and a one-contributor psum broadcast
            # the (idx, val) pair everywhere.
            g = flat * shard_len + h + cols
            last_i = jax.lax.pmax(jnp.where(is_last, idx[-1], -1), axis_names)
            last_v = jax.lax.psum(
                jnp.where(is_last, val[-1], jnp.zeros_like(val[-1])), axis_names
            )
            wi = jnp.where(g >= n_pad, last_i, wi)
            wv = jnp.where(g >= n_pad, last_v, wv)
            take = val <= wv  # leftmost-tie: prefer the unshifted (left) row
            idx = jnp.where(take, idx, wi)
            val = jnp.where(take, val, wv)
            idx_rows.append(idx)
            val_rows.append(val)
        return jnp.stack(idx_rows), jnp.stack(val_rows)

    return jax.jit(
        shard_map(
            local,
            mesh=mesh,
            in_specs=(P(axis_names), P(axis_names)),
            out_specs=(P(None, axis_names), P(None, axis_names)),
            check_vma=False,
        )
    )


def st_halo_doubling(
    idx0: jax.Array, val0: jax.Array, mesh: Mesh, axis_names: Sequence[str]
) -> Tuple[jax.Array, jax.Array]:
    """BuildPlan "halo exchange" stage: the distributed doubling recurrence.

    Level k merges the previous row with itself shifted left by
    ``h = 2^(k-1)``: for a shard owning columns ``[s*C, (s+1)*C)`` the shifted
    operand is the contiguous window ``[s*C + h, s*C + h + C)`` of the
    previous row — exactly one shard-width, owned by shards ``s + h//C`` and
    ``s + h//C + 1``. Two ``_flat_shift`` transports fetch it, global columns
    past ``n_pad`` clamp to the previous row's last column (replicating the
    replicated build's tail rule), and the leftmost-tie pick finishes the
    level. (idx, val) pairs travel together so no level ever gathers from the
    full array: per-device memory is O(K * C), never O(K * n).

    Bit-identical to ``sparse_table.build`` on the same padded array. The
    compiled doubling program is cached per (mesh, axes, geometry) so
    repeated builds trace once.
    """
    axis_names = tuple(axis_names)
    num = num_shards(mesh, axis_names)
    n_pad = idx0.shape[0]
    return _st_halo_fn(mesh, axis_names, n_pad, num)(idx0, val0)


def build_sharded_st(x: jax.Array, mesh: Mesh, axis_names: Sequence[str]) -> ShardedSparseTable:
    """Distributed build of the column-sharded global doubling table.

    Lowers through the staged ``core.build`` pipeline (shard layout ->
    local build -> halo exchange -> finalize): per-shard doubling with a
    level-k halo exchange of the boundary columns, bit-identical to
    ``sparse_table.build`` on the padded array. Build-time memory per device
    is O(K * n / D) — the full (K, n) table is never materialized anywhere.
    """
    from . import build as build_mod  # deferred: build sequences these stages

    return build_mod.build("sharded_st", x, mesh=mesh, axis_names=axis_names)


def build_replicated_st(x: jax.Array, mesh: Mesh) -> SparseTable:
    """Full doubling table replicated on every device (batch-sharded mode)."""
    st = sparse_table.build(x)
    return jax.device_put(st, jax.sharding.NamedSharding(mesh, P()))


# --- incremental patch kernels (the online-update subsystem's SPMD side) ----
#
# ``repro.update`` mutates structures under live traffic. For the sharded
# engines the patch must run where the data lives: each device scatters the
# updates it owns, repairs only its touched blocks, and re-runs the doubling
# recurrence masked to the affected column windows — the same level-k window
# containment argument as the host-side ``repro.update.patch`` kernels, the
# same ``_flat_shift`` halo transport as the distributed build when a window
# straddles shard boundaries. SPMD masking means devices outside a window do
# (discarded) lane work rather than skipping it, but no new collective kinds
# are introduced and per-device memory stays bounded by the shard. Results
# are bit-identical to a from-scratch rebuild of the mutated array.


def _pad_updates(upd_pos, upd_val, val_dtype):
    """Pad (positions, values) to a power of two with ``pos = -1`` sentinels,
    so the compiled patch kernels see a bounded set of shapes."""
    upd_pos = np.asarray(upd_pos, np.int64)
    upd_val = np.asarray(upd_val)
    if upd_pos.size == 0:
        raise ValueError("patch called with no updates")
    p = 1 << (upd_pos.size - 1).bit_length() if upd_pos.size > 1 else 1
    pos = np.full(p, -1, np.int32)
    val = np.zeros(p, np.dtype(val_dtype))
    pos[: upd_pos.size] = upd_pos
    val[: upd_val.size] = upd_val
    return jnp.asarray(pos), jnp.asarray(val)


def _window_hull(upd_pos):
    """(lo, hi) hull of the valid (non-sentinel) update positions."""
    valid = upd_pos >= 0
    lo = jnp.min(jnp.where(valid, upd_pos, _INT_BIG))
    hi = jnp.max(jnp.where(valid, upd_pos, -1))
    return lo, hi


@functools.lru_cache(maxsize=None)
def _st_patch_fn(mesh: Mesh, axis_names: Tuple[str, ...], n_pad: int, num: int, p: int):
    shard_len = n_pad // num
    k_levels = st_levels(n_pad)

    def local(idx, val, upd_pos, upd_val):
        flat = _flat_axis_index(axis_names)
        c0 = flat * shard_len
        cols = jnp.arange(shard_len, dtype=jnp.int32)
        is_last = flat == num - 1
        mn, mx = _window_hull(upd_pos)
        # Scatter the owned updates into the level-0 value row (the level-0
        # index row is the identity and never changes); non-owned updates
        # fall off the end and are dropped.
        lp = upd_pos - c0
        owned = (upd_pos >= 0) & (lp >= 0) & (lp < shard_len)
        cur_v = val[0].at[jnp.where(owned, lp, shard_len)].set(
            upd_val.astype(val.dtype), mode="drop"
        )
        cur_i = idx[0]
        idx_rows, val_rows = [cur_i], [cur_v]
        for k in range(1, k_levels):
            h = 1 << (k - 1)
            if h >= n_pad:  # window spans the whole array: rows repeat
                idx_rows.append(cur_i)
                val_rows.append(cur_v)
                continue
            # Same transport as st_halo_doubling: the shifted operand is one
            # shard-width of the previous (patched) row, fetched from up to
            # two shards to the right, tail-clamped to its last column.
            d, r = divmod(h, shard_len)
            wi = _flat_shift(cur_i, mesh, axis_names, d)
            wv = _flat_shift(cur_v, mesh, axis_names, d)
            if r:
                bi = _flat_shift(cur_i, mesh, axis_names, d + 1)
                bv = _flat_shift(cur_v, mesh, axis_names, d + 1)
                wi = jnp.concatenate([wi[r:], bi[:r]])
                wv = jnp.concatenate([wv[r:], bv[:r]])
            g = c0 + h + cols
            last_i = jax.lax.pmax(jnp.where(is_last, cur_i[-1], -1), axis_names)
            last_v = jax.lax.psum(
                jnp.where(is_last, cur_v[-1], jnp.zeros_like(cur_v[-1])), axis_names
            )
            wi = jnp.where(g >= n_pad, last_i, wi)
            wv = jnp.where(g >= n_pad, last_v, wv)
            take = cur_v <= wv  # leftmost-tie: prefer the unshifted (left) row
            cand_i = jnp.where(take, cur_i, wi)
            cand_v = jnp.where(take, cur_v, wv)
            # Affected-column window at level k: an entry at column c covers
            # [c, c + 2^k), so only c in [mn - 2^k + 1, mx] can change.
            gc = c0 + cols
            in_win = (gc >= mn - ((1 << k) - 1)) & (gc <= mx)
            cur_i = jnp.where(in_win, cand_i, idx[k])
            cur_v = jnp.where(in_win, cand_v, val[k])
            idx_rows.append(cur_i)
            val_rows.append(cur_v)
        return jnp.stack(idx_rows), jnp.stack(val_rows)

    return jax.jit(
        shard_map(
            local,
            mesh=mesh,
            in_specs=(P(None, axis_names), P(None, axis_names), P(), P()),
            out_specs=(P(None, axis_names), P(None, axis_names)),
            check_vma=False,
        )
    )


def patch_sharded_st(
    t: ShardedSparseTable, upd_pos, upd_val, mesh: Mesh, axis_names: Sequence[str]
) -> ShardedSparseTable:
    """Patch the column-sharded doubling table in place of a rebuild.

    ``upd_pos``/``upd_val`` are the coalesced changed positions and values
    (host arrays; appends within the padded capacity are just updates at pad
    columns). Per level the doubling recurrence re-runs masked to the
    affected window, with the ``_flat_shift`` halo transport covering
    windows that straddle shard boundaries — bit-identical to
    ``build_sharded_st`` on the mutated array, with no device ever holding
    the full table.
    """
    axis_names = tuple(axis_names)
    num = num_shards(mesh, axis_names)
    n_pad = t.idx.shape[1]
    pos, val = _pad_updates(upd_pos, upd_val, t.val.dtype)
    idx, vals = _st_patch_fn(mesh, axis_names, n_pad, num, pos.shape[0])(
        t.idx, t.val, pos, val
    )
    return ShardedSparseTable(idx=idx, val=vals)


@functools.lru_cache(maxsize=None)
def _blocked_patch_fn(
    mesh: Mesh, axis_names: Tuple[str, ...], nb_local: int, bs: int, p: int
):
    local_n = nb_local * bs
    k_levels = st_levels(nb_local) if nb_local > 1 else 1

    def local(s: BlockRMQ, upd_pos, upd_val):
        flat = _flat_axis_index(axis_names)
        off = flat * local_n
        lp = upd_pos - off
        owned = (upd_pos >= 0) & (lp >= 0) & (lp < local_n)
        # Scatter owned values into the padded block matrix.
        xf = s.x_blocks.reshape(-1)
        xf = xf.at[jnp.where(owned, lp, local_n)].set(
            upd_val.astype(xf.dtype), mode="drop"
        )
        xb = xf.reshape(nb_local, bs)
        # O(bs) per-update block-min repair (duplicate updates to one block
        # recompute the same answer; drops discard the rest).
        blk = jnp.clip(lp // bs, 0, nb_local - 1)
        rows = jnp.take(xb, blk, axis=0)  # (P, bs)
        lidx = jnp.argmin(rows, axis=1).astype(jnp.int32)
        newmin = jnp.take_along_axis(rows, lidx[:, None], axis=1)[:, 0]
        tgt = jnp.where(owned, blk, nb_local)
        bmin_val = s.bmin_val.at[tgt].set(newmin, mode="drop")
        bmin_gidx = s.bmin_gidx.at[tgt].set(
            (blk * bs).astype(jnp.int32) + lidx, mode="drop"
        )
        # Masked windowed repair of the LOCAL doubling table over block
        # minima (per-shard tables never cross chunk boundaries, so there is
        # no transport here — just the same window containment as the host
        # patch kernels). Shards owning no update have an empty window and
        # keep every row.
        mnb = jnp.min(jnp.where(owned, blk, _INT_BIG))
        mxb = jnp.max(jnp.where(owned, blk, -1))
        cols = jnp.arange(nb_local, dtype=jnp.int32)
        cur = s.st.idx[0]
        rows_out = [cur]
        for k in range(1, k_levels):
            h = 1 << (k - 1)
            if h >= nb_local:
                rows_out.append(cur)
                continue
            shifted = jnp.concatenate([cur[h:], jnp.broadcast_to(cur[-1], (h,))])
            cand = jnp.where(bmin_val[cur] <= bmin_val[shifted], cur, shifted)
            in_win = (cols >= mnb - ((1 << k) - 1)) & (cols <= mxb)
            cur = jnp.where(in_win, cand, s.st.idx[k])
            rows_out.append(cur)
        st = SparseTable(idx=jnp.stack(rows_out), x=bmin_val)
        return BlockRMQ(x_blocks=xb, bmin_val=bmin_val, bmin_gidx=bmin_gidx, st=st)

    specs = _block_rmq_specs(P(axis_names), P(None, axis_names))
    return jax.jit(
        shard_map(
            local,
            mesh=mesh,
            in_specs=(specs, P(), P()),
            out_specs=specs,
            check_vma=False,
        )
    )


def patch_sharded(
    s: BlockRMQ, upd_pos, upd_val, mesh: Mesh, axis_names: Sequence[str]
) -> BlockRMQ:
    """Patch the mesh-sharded blocked structure in place of a rebuild.

    Each device scatters the updates it owns into its chunk, re-argmins only
    the touched blocks (O(bs) each), and window-patches its local block-min
    doubling table. Bit-identical to ``build_sharded`` on the mutated array.
    """
    axis_names = tuple(axis_names)
    num = num_shards(mesh, axis_names)
    bs = s.x_blocks.shape[1]
    nb_local = s.x_blocks.shape[0] // num
    pos, val = _pad_updates(upd_pos, upd_val, s.x_blocks.dtype)
    return _blocked_patch_fn(mesh, axis_names, nb_local, bs, pos.shape[0])(s, pos, val)


def make_st_query_fn(
    mesh: Mesh,
    axis_names: Sequence[str],
    *,
    batch_sharded: bool = False,
    batch_axes: Sequence[str] | None = None,
):
    """Jitted distributed sparse-table query -> (idx, val).

    ``batch_sharded=False``: takes a ``ShardedSparseTable`` (column-sharded
    global table), queries replicated. Each query needs two window lookups
    (columns ``l`` and ``r - 2^k + 1``); each column is owned by exactly one
    device, so non-owners contribute +inf/int-max and two pmins recover both
    candidates everywhere, then the standard leftmost-tie pick (prefer the
    left window on value ties) finishes the query.

    ``batch_sharded=True``: takes a replicated ``SparseTable``
    (``build_replicated_st``), the query batch is sharded, and each device
    answers its slice with the plain O(1) lookup plus a local value gather.

    ``batch_axes=...`` (2D mesh mode): the table stays column-sharded over
    ``axis_names``, the query batch is sharded over the disjoint
    ``batch_axes``, and the owner-column pmins run over the structure axes
    only — one structure-shard group answers each batch slice.
    """
    axis_names = tuple(axis_names)
    batch_axes = _check_batch_axes(axis_names, batch_axes, batch_sharded)

    if batch_sharded:
        num = num_shards(mesh, axis_names)

        def local_st(t: SparseTable, l, r):
            idx = sparse_table.query(t, l, r)
            return idx, t.x[idx]

        inner = shard_map(
            local_st,
            mesh=mesh,
            in_specs=(SparseTable(idx=P(), x=P()), P(axis_names), P(axis_names)),
            out_specs=(P(axis_names), P(axis_names)),
            check_vma=False,
        )

        def fn(t: SparseTable, l, r):
            lp, rp, b = _pad_batch(l, r, num)
            idx, val = inner(t, lp, rp)
            return idx[:b], val[:b]

        return jax.jit(fn)

    def local_query(t: ShardedSparseTable, l, r):
        cols = t.idx.shape[1]  # columns owned by this shard
        c0 = _flat_axis_index(axis_names) * cols
        big = maxval(t.val.dtype)
        l = l.astype(jnp.int32)
        r = r.astype(jnp.int32)
        k = sparse_table.exact_log2(r - l + 1)
        # The two covering windows start at columns l and r - 2^k + 1.
        cand = jnp.stack([l, r - jnp.left_shift(jnp.int32(1), k) + 1])  # (2, B)
        owned = (cand >= c0) & (cand < c0 + cols)
        cl = jnp.clip(cand - c0, 0, cols - 1)
        kk = jnp.broadcast_to(k[None, :], cand.shape)
        v = jnp.where(owned, t.val[kk, cl], big)
        i = jnp.where(owned, t.idx[kk, cl], _INT_BIG)
        # One owner per column: the pmins select the owner's candidate.
        v = jax.lax.pmin(v, axis_names)
        i = jax.lax.pmin(i, axis_names)
        take_left = v[0] <= v[1]  # left window on ties -> exact leftmost
        return jnp.where(take_left, i[0], i[1]), jnp.where(take_left, v[0], v[1])

    spec_b = P(batch_axes) if batch_axes else P()
    inner = shard_map(
        local_query,
        mesh=mesh,
        in_specs=(
            ShardedSparseTable(idx=P(None, axis_names), val=P(None, axis_names)),
            spec_b,
            spec_b,
        ),
        out_specs=(spec_b, spec_b),
        check_vma=False,
    )
    if not batch_axes:
        return jax.jit(inner)
    nb = num_shards(mesh, batch_axes)

    def fn(t: ShardedSparseTable, l, r):
        lp, rp, b = _pad_batch(l, r, nb)
        idx, val = inner(t, lp, rp)
        return idx[:b], val[:b]

    return jax.jit(fn)


# --- packed (single-word-plane) distributed tier ----------------------------
#
# Every structure above moves an (idx, val) PAIR through its halos, pmins,
# and patches. The packed tier (DESIGN.md §13) moves ONE plane of
# order-isomorphic words (``core.packing``): the two-pmin leftmost merge
# collapses to a single pmin, the level-k halo exchange ships half the
# bytes (packed32) or half the collectives (packed64), and the patch
# kernels repair one plane. Exact layouts only — the quantized layout's
# bucket-tie fallback needs value gathers that would cross shards, so
# planners reject it for mesh engines.


def pack_global(x: jax.Array, spec, n_pad: int) -> jax.Array:
    """Pack ``x`` with *global* indices and pad to ``n_pad`` with pad words.

    Packing precedes padding so pads are the reserved ``pad_word`` (always
    lose a min) rather than an encodable maxval element — this is also what
    keeps packed32's measured key-range fit independent of padding.
    """
    n = x.shape[0]
    xw = packing.pack(spec, x, jnp.arange(n, dtype=jnp.int32))
    return jnp.pad(xw, (0, n_pad - n), constant_values=packing.pad_word(spec))


def _pad_word_arr(spec):
    return jnp.asarray(packing.pad_word(spec), packing.word_dtype(spec))


@functools.lru_cache(maxsize=None)
def _sharded_build_packed_fn(mesh: Mesh, axis_names: Tuple[str, ...], block_size: int, spec):
    def local_build(w_local):
        wb = w_local[0].reshape(-1, block_size)
        return PackedBlockRMQ(
            blocks=wb, stw=block_rmq._doubling_min(jnp.min(wb, axis=1))
        )

    out_specs = PackedBlockRMQ(blocks=P(axis_names), stw=P(None, axis_names))
    return jax.jit(
        shard_map(
            local_build,
            mesh=mesh,
            in_specs=P(axis_names),
            out_specs=out_specs,
            check_vma=False,
        )
    )


def build_sharded_packed(
    x: jax.Array, mesh: Mesh, axis_names: Sequence[str], block_size: int, spec
) -> PackedBlockRMQ:
    """Per-shard packed blocked structures (one word plane per tier).

    Words carry global indices, so shard merges need no index offsetting —
    the min word across shards is already the global answer.
    """
    axis_names = tuple(axis_names)
    num = num_shards(mesh, axis_names)
    chunk = num * block_size
    n_pad = -(-x.shape[0] // chunk) * chunk
    xw = pack_global(x, spec, n_pad)
    return _sharded_build_packed_fn(mesh, axis_names, block_size, spec)(
        xw.reshape(num, -1)
    )


def build_replicated_packed(
    x: jax.Array, mesh: Mesh, block_size: int, spec
) -> PackedBlockRMQ:
    """Full packed blocked structure replicated on every device."""
    s, _ = block_rmq.build_packed(x, block_size, spec=spec)
    return jax.device_put(s, jax.sharding.NamedSharding(mesh, P()))


def make_packed_query_fn(
    mesh: Mesh,
    axis_names: Sequence[str],
    spec,
    *,
    batch_sharded: bool = False,
    batch_axes: Sequence[str] | None = None,
):
    """Jitted packed distributed query: (PackedBlockRMQ, l, r) -> (idx, val).

    Mirrors ``make_query_fn``'s three modes; the structure-sharded merge is
    ONE pmin over packed words instead of the two-pmin (value, then index)
    reduction — half the collectives, and exact leftmost ties by word order.
    """
    axis_names = tuple(axis_names)
    batch_axes = _check_batch_axes(axis_names, batch_axes, batch_sharded)
    pad = packing.pad_word(spec)

    if batch_sharded:
        num = num_shards(mesh, axis_names)

        def local_bs(s: PackedBlockRMQ, l, r):
            w = block_rmq.query_words(spec, s.blocks, s.stw, l, r)
            return packing.unpack_idx(spec, w), packing.unpack_val(spec, w)

        inner = shard_map(
            local_bs,
            mesh=mesh,
            in_specs=(
                PackedBlockRMQ(blocks=P(), stw=P()),
                P(axis_names),
                P(axis_names),
            ),
            out_specs=(P(axis_names), P(axis_names)),
            check_vma=False,
        )

        def fn(s: PackedBlockRMQ, l, r):
            lp, rp, b = _pad_batch(l, r, num)
            idx, val = inner(s, lp, rp)
            return idx[:b], val[:b]

        return jax.jit(fn)

    def local_query(s: PackedBlockRMQ, l, r):
        bs = s.blocks.shape[1]
        local_n = s.blocks.shape[0] * bs
        off = _flat_axis_index(axis_names) * local_n

        has = (r >= off) & (l <= off + local_n - 1)
        ql = jnp.clip(l - off, 0, local_n - 1)
        qr = jnp.clip(r - off, 0, local_n - 1)
        w = block_rmq.query_words(spec, s.blocks, s.stw, ql, qr)
        w = jnp.where(has, w, pad)
        # Exact leftmost merge with ONE min all-reduce over ICI.
        wmin = jax.lax.pmin(w, axis_names)
        return packing.unpack_idx(spec, wmin), packing.unpack_val(spec, wmin)

    spec_b = P(batch_axes) if batch_axes else P()
    inner = shard_map(
        local_query,
        mesh=mesh,
        in_specs=(
            PackedBlockRMQ(blocks=P(axis_names), stw=P(None, axis_names)),
            spec_b,
            spec_b,
        ),
        out_specs=(spec_b, spec_b),
        check_vma=False,
    )
    if not batch_axes:
        return jax.jit(inner)
    nb = num_shards(mesh, batch_axes)

    def fn(s: PackedBlockRMQ, l, r):
        lp, rp, b = _pad_batch(l, r, nb)
        idx, val = inner(s, lp, rp)
        return idx[:b], val[:b]

    return jax.jit(fn)


@functools.lru_cache(maxsize=None)
def _st_halo_packed_fn(mesh: Mesh, axis_names: Tuple[str, ...], n_pad: int, num: int, spec):
    shard_len = n_pad // num
    k_levels = st_levels(n_pad)
    pad = packing.pad_word(spec)

    def local(w):
        flat = _flat_axis_index(axis_names)
        cols = jnp.arange(shard_len, dtype=jnp.int32)
        is_last = flat == num - 1
        rows = [w]
        for k in range(1, k_levels):
            h = 1 << (k - 1)
            if h >= n_pad:
                rows.append(w)
                continue
            # Same transport as st_halo_doubling, HALF the planes: one
            # word array rides each _flat_shift instead of an (idx, val)
            # pair, and the tail clamp is one pmin broadcast (the last
            # shard's word beats every non-contributor's pad filler).
            d, r = divmod(h, shard_len)
            ww = _flat_shift(w, mesh, axis_names, d)
            if r:
                bw = _flat_shift(w, mesh, axis_names, d + 1)
                ww = jnp.concatenate([ww[r:], bw[:r]])
            g = flat * shard_len + h + cols
            last_w = jax.lax.pmin(
                jnp.where(is_last, w[-1], jnp.asarray(pad, w.dtype)), axis_names
            )
            ww = jnp.where(g >= n_pad, last_w, ww)
            w = jnp.minimum(w, ww)  # leftmost-tie is free: word order
            rows.append(w)
        return jnp.stack(rows)

    return jax.jit(
        shard_map(
            local,
            mesh=mesh,
            in_specs=P(axis_names),
            out_specs=P(None, axis_names),
            check_vma=False,
        )
    )


def st_halo_doubling_packed(
    w0: jax.Array, mesh: Mesh, axis_names: Sequence[str], spec
) -> jax.Array:
    """Packed distributed doubling: the halo recurrence on ONE word plane.

    ``w0`` is the shard-divisible packed level-0 row (``pack_global``).
    Bit-identical (after unpacking) to ``st_halo_doubling`` on the same
    data — the leftmost-tie pick is subsumed by word ``minimum``.
    """
    axis_names = tuple(axis_names)
    num = num_shards(mesh, axis_names)
    return _st_halo_packed_fn(mesh, axis_names, w0.shape[0], num, spec)(w0)


def build_sharded_st_packed(
    x: jax.Array, mesh: Mesh, axis_names: Sequence[str], spec
) -> PackedSparseTable:
    """Distributed build of the column-sharded packed doubling table."""
    axis_names = tuple(axis_names)
    num = num_shards(mesh, axis_names)
    n_pad = -(-max(x.shape[0], 1) // num) * num
    return PackedSparseTable(
        words=st_halo_doubling_packed(pack_global(x, spec, n_pad), mesh, axis_names, spec)
    )


def build_replicated_st_packed(x: jax.Array, mesh: Mesh, spec) -> PackedSparseTable:
    """Full packed doubling table replicated on every device."""
    t, _ = sparse_table.build_packed(x, spec=spec)
    return jax.device_put(t, jax.sharding.NamedSharding(mesh, P()))


def make_packed_st_query_fn(
    mesh: Mesh,
    axis_names: Sequence[str],
    spec,
    *,
    batch_sharded: bool = False,
    batch_axes: Sequence[str] | None = None,
):
    """Jitted packed distributed sparse-table query -> (idx, val).

    The owner-column merge is one pmin over a (2, B) word stack, and the
    left/right window pick is a plain word ``minimum`` — no value/index
    plane pair, no tie select.
    """
    axis_names = tuple(axis_names)
    batch_axes = _check_batch_axes(axis_names, batch_axes, batch_sharded)
    pad = packing.pad_word(spec)

    if batch_sharded:
        num = num_shards(mesh, axis_names)

        def local_st(t: PackedSparseTable, l, r):
            return sparse_table.query_packed(t, spec, l, r)

        inner = shard_map(
            local_st,
            mesh=mesh,
            in_specs=(
                PackedSparseTable(words=P(), x=None),
                P(axis_names),
                P(axis_names),
            ),
            out_specs=(P(axis_names), P(axis_names)),
            check_vma=False,
        )

        def fn(t: PackedSparseTable, l, r):
            lp, rp, b = _pad_batch(l, r, num)
            idx, val = inner(t, lp, rp)
            return idx[:b], val[:b]

        return jax.jit(fn)

    def local_query(t: PackedSparseTable, l, r):
        cols = t.words.shape[1]
        c0 = _flat_axis_index(axis_names) * cols
        l = l.astype(jnp.int32)
        r = r.astype(jnp.int32)
        k = sparse_table.exact_log2(r - l + 1)
        cand = jnp.stack([l, r - jnp.left_shift(jnp.int32(1), k) + 1])  # (2, B)
        owned = (cand >= c0) & (cand < c0 + cols)
        cl = jnp.clip(cand - c0, 0, cols - 1)
        kk = jnp.broadcast_to(k[None, :], cand.shape)
        w = jnp.where(owned, t.words[kk, cl], jnp.asarray(pad, t.words.dtype))
        w = jax.lax.pmin(w, axis_names)  # one collective, was two
        wm = jnp.minimum(w[0], w[1])  # leftmost-tie by word order
        return packing.unpack_idx(spec, wm), packing.unpack_val(spec, wm)

    spec_b = P(batch_axes) if batch_axes else P()
    inner = shard_map(
        local_query,
        mesh=mesh,
        in_specs=(
            PackedSparseTable(words=P(None, axis_names), x=None),
            spec_b,
            spec_b,
        ),
        out_specs=(spec_b, spec_b),
        check_vma=False,
    )
    if not batch_axes:
        return jax.jit(inner)
    nb = num_shards(mesh, batch_axes)

    def fn(t: PackedSparseTable, l, r):
        lp, rp, b = _pad_batch(l, r, nb)
        idx, val = inner(t, lp, rp)
        return idx[:b], val[:b]

    return jax.jit(fn)


def _pad_updates_packed(upd_pos, upd_val, spec):
    """Pad (positions, packed update words) to a power of two.

    Packs host-side — a packed32 spec that cannot encode a new value raises
    ``OverflowError`` here, *before* any device state mutates, so callers
    can fall back to a structural rebuild with a fresh spec.
    """
    upd_pos = np.asarray(upd_pos, np.int64)
    if upd_pos.size == 0:
        raise ValueError("patch called with no updates")
    words = packing.pack_np(spec, upd_val, upd_pos.astype(np.int32))
    p = 1 << (upd_pos.size - 1).bit_length() if upd_pos.size > 1 else 1
    pos = np.full(p, -1, np.int32)
    wrd = np.full(p, packing.pad_word(spec), packing.word_dtype_np(spec))
    pos[: upd_pos.size] = upd_pos
    wrd[: words.size] = words
    return jnp.asarray(pos), jnp.asarray(wrd)


@functools.lru_cache(maxsize=None)
def _st_patch_packed_fn(mesh: Mesh, axis_names: Tuple[str, ...], n_pad: int, num: int, p: int, spec):
    shard_len = n_pad // num
    k_levels = st_levels(n_pad)
    pad = packing.pad_word(spec)

    def local(words, upd_pos, upd_w):
        flat = _flat_axis_index(axis_names)
        c0 = flat * shard_len
        cols = jnp.arange(shard_len, dtype=jnp.int32)
        is_last = flat == num - 1
        mn, mx = _window_hull(upd_pos)
        lp = upd_pos - c0
        owned = (upd_pos >= 0) & (lp >= 0) & (lp < shard_len)
        cur = words[0].at[jnp.where(owned, lp, shard_len)].set(
            upd_w.astype(words.dtype), mode="drop"
        )
        rows = [cur]
        for k in range(1, k_levels):
            h = 1 << (k - 1)
            if h >= n_pad:
                rows.append(cur)
                continue
            d, r = divmod(h, shard_len)
            ww = _flat_shift(cur, mesh, axis_names, d)
            if r:
                bw = _flat_shift(cur, mesh, axis_names, d + 1)
                ww = jnp.concatenate([ww[r:], bw[:r]])
            g = c0 + h + cols
            last_w = jax.lax.pmin(
                jnp.where(is_last, cur[-1], jnp.asarray(pad, cur.dtype)), axis_names
            )
            ww = jnp.where(g >= n_pad, last_w, ww)
            cand = jnp.minimum(cur, ww)
            # Level-k containment: an entry at column c covers [c, c + 2^k).
            gc = c0 + cols
            in_win = (gc >= mn - ((1 << k) - 1)) & (gc <= mx)
            cur = jnp.where(in_win, cand, words[k])
            rows.append(cur)
        return jnp.stack(rows)

    return jax.jit(
        shard_map(
            local,
            mesh=mesh,
            in_specs=(P(None, axis_names), P(), P()),
            out_specs=P(None, axis_names),
            check_vma=False,
        )
    )


def patch_sharded_st_packed(
    t: PackedSparseTable, upd_pos, upd_val, mesh: Mesh, axis_names: Sequence[str], spec
) -> PackedSparseTable:
    """Windowed patch of the column-sharded packed doubling table.

    One plane rides the halo transport (the unpacked patch ships two);
    bit-identical to ``build_sharded_st_packed`` on the mutated array.
    Raises ``OverflowError`` before touching device state when a packed32
    spec cannot encode a new value.
    """
    axis_names = tuple(axis_names)
    num = num_shards(mesh, axis_names)
    n_pad = t.words.shape[1]
    pos, wrd = _pad_updates_packed(upd_pos, upd_val, spec)
    words = _st_patch_packed_fn(mesh, axis_names, n_pad, num, pos.shape[0], spec)(
        t.words, pos, wrd
    )
    return PackedSparseTable(words=words)


@functools.lru_cache(maxsize=None)
def _blocked_patch_packed_fn(
    mesh: Mesh, axis_names: Tuple[str, ...], nb_local: int, bs: int, p: int, spec
):
    local_n = nb_local * bs
    k_levels = st_levels(nb_local) if nb_local > 1 else 1

    def local(s: PackedBlockRMQ, upd_pos, upd_w):
        flat = _flat_axis_index(axis_names)
        off = flat * local_n
        lp = upd_pos - off
        owned = (upd_pos >= 0) & (lp >= 0) & (lp < local_n)
        wf = s.blocks.reshape(-1)
        wf = wf.at[jnp.where(owned, lp, local_n)].set(
            upd_w.astype(wf.dtype), mode="drop"
        )
        wb = wf.reshape(nb_local, bs)
        blk = jnp.clip(lp // bs, 0, nb_local - 1)
        neww = jnp.min(jnp.take(wb, blk, axis=0), axis=1)  # O(bs) block repair
        tgt = jnp.where(owned, blk, nb_local)
        cur = s.stw[0].at[tgt].set(neww, mode="drop")
        mnb = jnp.min(jnp.where(owned, blk, _INT_BIG))
        mxb = jnp.max(jnp.where(owned, blk, -1))
        cols = jnp.arange(nb_local, dtype=jnp.int32)
        rows_out = [cur]
        for k in range(1, k_levels):
            h = 1 << (k - 1)
            if h >= nb_local:
                rows_out.append(cur)
                continue
            shifted = jnp.concatenate([cur[h:], jnp.broadcast_to(cur[-1], (h,))])
            cand = jnp.minimum(cur, shifted)
            in_win = (cols >= mnb - ((1 << k) - 1)) & (cols <= mxb)
            cur = jnp.where(in_win, cand, s.stw[k])
            rows_out.append(cur)
        return PackedBlockRMQ(blocks=wb, stw=jnp.stack(rows_out))

    specs = PackedBlockRMQ(blocks=P(axis_names), stw=P(None, axis_names))
    return jax.jit(
        shard_map(
            local,
            mesh=mesh,
            in_specs=(specs, P(), P()),
            out_specs=specs,
            check_vma=False,
        )
    )


def patch_sharded_packed(
    s: PackedBlockRMQ, upd_pos, upd_val, mesh: Mesh, axis_names: Sequence[str], spec
) -> PackedBlockRMQ:
    """Windowed patch of the mesh-sharded packed blocked structure.

    Scatter owned word updates, re-min touched blocks, window-repair the
    per-shard doubling plane — all on single word planes. Bit-identical to
    ``build_sharded_packed`` on the mutated array.
    """
    axis_names = tuple(axis_names)
    num = num_shards(mesh, axis_names)
    bs = s.blocks.shape[1]
    nb_local = s.blocks.shape[0] // num
    pos, wrd = _pad_updates_packed(upd_pos, upd_val, spec)
    return _blocked_patch_packed_fn(mesh, axis_names, nb_local, bs, pos.shape[0], spec)(
        s, pos, wrd
    )
