"""Level-3 of the hierarchy: RMQ sharded across the device mesh.

The paper leaves multi-BVH distribution as future work (§7.i): "one BVH per
cluster of blocks". On a TPU pod that is exactly block-range ownership per
device: each device holds a contiguous chunk of the array with its own local
blocked structure, answers the query restricted to its chunk, and the shards
merge with two all-reduce-mins over ICI (value min, then leftmost index among
value-matching shards — exact leftmost semantics with only min collectives).

Works on any mesh: the array is sharded over *all* given axes flattened, so
the same code runs a 16x16 pod and a (pod=2, 16, 16) multi-pod mesh.
"""

from __future__ import annotations

import functools
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

try:  # jax >= 0.6 exposes shard_map at top level with `check_vma`
    from jax import shard_map as _shard_map_impl

    _SHARD_MAP_CHECK_KW = "check_vma"
except ImportError:  # jax 0.4.x: experimental module, kwarg named `check_rep`
    from jax.experimental.shard_map import shard_map as _shard_map_impl

    _SHARD_MAP_CHECK_KW = "check_rep"


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=True):
    """Version-compat wrapper: maps ``check_vma`` to the installed jax's kwarg."""
    kw = {_SHARD_MAP_CHECK_KW: check_vma}
    return _shard_map_impl(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)

from . import block_rmq
from .block_rmq import BlockRMQ, maxval
from .sparse_table import SparseTable

__all__ = ["build_sharded", "make_query_fn", "pad_to_shards"]

_INT_BIG = jnp.int32(2**31 - 1)


def _axis_size(name: str):
    if hasattr(jax.lax, "axis_size"):  # jax >= 0.5
        return jax.lax.axis_size(name)
    return jax.lax.psum(1, name)  # folds to a constant inside shard_map


def _flat_axis_index(axis_names: Sequence[str]) -> jax.Array:
    """Flattened linear device index across the given mesh axes."""
    idx = jnp.int32(0)
    for name in axis_names:
        idx = idx * _axis_size(name) + jax.lax.axis_index(name)
    return idx


def pad_to_shards(x: jax.Array, num_shards: int, block_size: int) -> jax.Array:
    """Pad so every shard owns the same whole number of blocks."""
    chunk = num_shards * block_size
    n_pad = -(-x.shape[0] // chunk) * chunk
    return jnp.pad(x, (0, n_pad - x.shape[0]), constant_values=maxval(x.dtype))


def build_sharded(x: jax.Array, mesh: Mesh, axis_names: Sequence[str], block_size: int) -> BlockRMQ:
    """Build per-shard blocked structures; leaves are sharded on the block dim."""
    axis_names = tuple(axis_names)
    num = 1
    for a in axis_names:
        num *= mesh.shape[a]
    x = pad_to_shards(x, num, block_size)

    def local_build(x_local):
        return block_rmq.build(x_local[0], block_size)

    out_specs = BlockRMQ(
        x_blocks=P(axis_names),
        bmin_val=P(axis_names),
        bmin_gidx=P(axis_names),
        st=SparseTable(idx=P(None, axis_names), x=P(axis_names)),
    )
    fn = shard_map(
        local_build,
        mesh=mesh,
        in_specs=P(axis_names),
        out_specs=out_specs,
        check_vma=False,
    )
    # shard_map gives each shard x of shape (n/num,); wrap in a leading dim so
    # the local function sees a rank-1 chunk regardless of axis grouping.
    return fn(x.reshape(num, -1))


def make_query_fn(mesh: Mesh, axis_names: Sequence[str]):
    """Jitted batched distributed query: (sharded BlockRMQ, l, r) -> (idx, val)."""
    axis_names = tuple(axis_names)

    def local_query(s: BlockRMQ, l, r):
        bs = s.x_blocks.shape[1]
        local_n = s.x_blocks.shape[0] * bs
        big = maxval(s.x_blocks.dtype)
        off = _flat_axis_index(axis_names) * local_n

        has = (r >= off) & (l <= off + local_n - 1)
        ql = jnp.clip(l - off, 0, local_n - 1)
        qr = jnp.clip(r - off, 0, local_n - 1)
        idx, val = block_rmq.query(s, ql, qr)
        val = jnp.where(has, val, big)
        gidx = jnp.where(has, idx + off, _INT_BIG)

        # Exact leftmost merge with two min all-reduces over ICI.
        vmin = jax.lax.pmin(val, axis_names)
        cand = jnp.where(val == vmin, gidx, _INT_BIG)
        imin = jax.lax.pmin(cand, axis_names)
        return imin, vmin

    in_specs = (
        BlockRMQ(
            x_blocks=P(axis_names),
            bmin_val=P(axis_names),
            bmin_gidx=P(axis_names),
            st=SparseTable(idx=P(None, axis_names), x=P(axis_names)),
        ),
        P(),  # queries replicated
        P(),
    )
    fn = shard_map(
        local_query,
        mesh=mesh,
        in_specs=in_specs,
        out_specs=(P(), P()),
        check_vma=False,
    )
    return jax.jit(fn)
