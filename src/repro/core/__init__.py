"""repro.core — batched RMQ engines (the paper's contribution, TPU-adapted).

Engines:
  * ``block_rmq``  — RTXRMQ-TPU, paper-faithful blocked structure (pure jnp).
  * ``repro.kernels.ops`` — the same algorithm with fused Pallas kernels;
    ``query`` dispatches the single fused tiled megakernel.
  * ``lane_rmq``   — beyond-paper O(1)-gather variant.
  * ``sparse_table`` — classic doubling table (level-2 building block).
  * ``lca``        — Cartesian-tree/Euler-tour baseline (paper's LCA).
  * ``exhaustive`` — brute-force baseline (paper's EXHAUSTIVE).
  * ``hybrid``     — range-adaptive dispatcher exploiting the paper's
    small/large crossover: short ranges -> blocked path, long ranges ->
    sparse-table path, exact scatter-back merge.
  * ``distributed``— mesh-sharded engine (level-3, multi-pod).
  * ``sharded_hybrid`` — the two fused: range-adaptive dispatch where each
    regime sub-batch is served by a mesh-sharded constituent (blocked /
    global column-sharded doubling table), plus a batch-sharded mode.
  * ``calib_cache`` — persistent JSON cache of calibrated crossover
    thresholds, keyed by (n, block_size, backend, n_devices).
  * ``build``      — the staged BuildPlan pipeline (shard layout -> local
    build -> halo exchange -> finalize) every engine build lowers through.

``registry`` exposes every engine behind one uniform
``(build, query) -> (idx, val)`` interface for tests and benchmarks, plus
declared serving capabilities (``EngineSpec``) that the async serving
stack (``repro.serve``, ``repro.launch.serve``) derives its engine choices
and flag validation from.
"""

from . import (
    block_rmq,
    build,
    calib_cache,
    distributed,
    exhaustive,
    hybrid,
    lane_rmq,
    lca,
    ref,
    registry,
    sharded_hybrid,
    sparse_table,
)

__all__ = [
    "block_rmq",
    "build",
    "calib_cache",
    "distributed",
    "exhaustive",
    "hybrid",
    "lane_rmq",
    "lca",
    "ref",
    "registry",
    "sharded_hybrid",
    "sparse_table",
]
