"""repro.core — batched RMQ engines (the paper's contribution, TPU-adapted).

Engines:
  * ``block_rmq``  — RTXRMQ-TPU, paper-faithful blocked structure (pure jnp).
  * ``repro.kernels.ops`` — the same algorithm with fused Pallas kernels.
  * ``lane_rmq``   — beyond-paper O(1)-gather variant.
  * ``sparse_table`` — classic doubling table (level-2 building block).
  * ``lca``        — Cartesian-tree/Euler-tour baseline (paper's LCA).
  * ``exhaustive`` — brute-force baseline (paper's EXHAUSTIVE).
  * ``distributed``— mesh-sharded engine (level-3, multi-pod).
"""

from . import block_rmq, distributed, exhaustive, lane_rmq, lca, ref, sparse_table

__all__ = [
    "block_rmq",
    "distributed",
    "exhaustive",
    "lane_rmq",
    "lca",
    "ref",
    "sparse_table",
]
