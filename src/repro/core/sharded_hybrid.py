"""Sharded range-adaptive hybrid RMQ: the crossover, distributed.

The paper's two deferred directions meet here. §7.i leaves multi-BVH
distribution ("one BVH per cluster of blocks") as future work — that is
``core.distributed``'s mesh-sharded blocked engine. §6 shows the headline
result is regime-dependent — the blocked structure wins at small ranges, the
O(1) table family at large ones — which ``core.hybrid`` exploits on one
host. This engine fuses them: a sharded deployment that still routes every
query to the regime-appropriate structure.

Data flow (DESIGN.md §6):

    host batch (l, r)
      └─ partition by range length vs threshold        (numpy, host-side)
           ├─ short sub-batch -> sharded blocked path  (two-pmin merge)
           └─ long sub-batch  -> sharded sparse-table  (owner-column pmin)
      └─ exact leftmost scatter-back into batch order

Three distribution modes, one per scaling axis (plus the product):

* ``mode="shard_structure"`` (default): the *array* is sharded — per-device
  blocked chunks for the short path, a column-sharded global doubling table
  for the long path. Memory scales with device count; queries are replicated
  and merge via pmin collectives.
* ``mode="shard_batch"``: the *query batch* is sharded — each device holds
  the full (replicated) structures and answers only its slice, so serving
  throughput scales with device count instead of being replicated work.
* ``mode="shard_2d"``: both — the structure is sharded over the FIRST mesh
  axis and the query batch over the remaining axes, so memory scales with
  the structure axis and throughput with the batch axes. Each batch slice
  is answered by one structure-shard group (pmins over the structure axis
  only). On a 1-axis mesh it degrades to ``shard_structure``.

Builds lower through the staged ``core.build`` BuildPlan pipeline
(shard layout -> local build -> halo exchange -> finalize); the long-path
doubling table is built *distributed* (per-shard doubling + level-k halo
exchange), so build-time memory per device is bounded by the shard.

The routing threshold (``build(threshold=...)``): ``None`` is the
deterministic sqrt(n) default, exactly as in ``hybrid.build``; ``"cached"``
consults the persistent calibration cache (``calib_cache``, keyed by
``(n, block_size, backend, n_devices)``) and falls back to sqrt(n) on a
miss without ever measuring; ``"calibrated"`` measures on a miss and
persists the result; an int pins it explicitly. Machine state is opt-in —
default builds (registry, tests, benchmarks) never read the cache.

Results are bit-identical to ``block_rmq.query`` on the same batch — every
constituent is exact-leftmost, and the scatter-back preserves batch order.
"""

from __future__ import annotations

from typing import NamedTuple, Sequence, Tuple

import jax

from .hybrid import dispatch_by_length

__all__ = ["MODES", "ShardedHybridRMQ", "build", "query"]

MODES = ("shard_structure", "shard_batch", "shard_2d")


class ShardedHybridRMQ(NamedTuple):
    """Both distributed constituents plus routing/launch metadata."""

    blocked: object  # sharded (or replicated) BlockRMQ — short-range path
    st: object  # ShardedSparseTable (or replicated SparseTable) — long path
    n: int  # logical array length (pre-padding)
    threshold: int  # range lengths <= threshold go to the blocked path
    mode: str  # "shard_structure" | "shard_batch" | "shard_2d"
    n_shards: int  # flattened mesh size (batch-pad granularity)
    dtype: object  # value dtype for the host-side scatter-back
    short_fn: object  # jitted (blocked, l, r) -> (idx, val)
    long_fn: object  # jitted (st, l, r) -> (idx, val)


def build(
    x: jax.Array,
    mesh=None,
    axis_names: Sequence[str] | None = None,
    block_size: int = 128,
    *,
    threshold: int | str | None = None,
    mode: str = "shard_structure",
    cache_path=None,
    packed=None,
) -> ShardedHybridRMQ:
    """Build both distributed constituents over ``mesh`` (default: all devices).

    Lowers through the staged ``core.build`` BuildPlan pipeline.

    ``threshold``: int pins the crossover; ``None`` is the deterministic
    sqrt(n) default (no cache, matching ``hybrid.build``); ``"cached"``
    reads the calibration cache with the sqrt(n) fallback, never measuring;
    ``"calibrated"`` measures on a cache miss — timing the *sharded*
    constituents on this very mesh and mode — and persists the result under
    the existing ``(n, bs, backend, ndev)`` key.
    """
    from . import build as build_mod  # deferred: build.py hosts the planner

    return build_mod.build(
        "sharded_hybrid",
        x,
        mesh=mesh,
        axis_names=axis_names,
        block_size=block_size,
        threshold=threshold,
        mode=mode,
        cache_path=cache_path,
        packed=packed,
    )


def query(s: ShardedHybridRMQ, l, r) -> Tuple[jax.Array, jax.Array]:
    """Range-adaptive distributed batched RMQ -> (leftmost idx int32, value).

    Host-side partition by range length, per-regime *sharded* launches,
    ordered scatter-back — ``hybrid.dispatch_by_length`` with the sharded
    constituents closed over their states. (The batch-sharded query fns pad
    to a shard multiple internally, so divisibility is not this layer's
    concern.) Bit-identical to ``block_rmq.query``.
    """
    return dispatch_by_length(
        l,
        r,
        s.threshold,
        lambda lm, rm: s.short_fn(s.blocked, lm, rm),
        lambda lm, rm: s.long_fn(s.st, lm, rm),
        s.dtype,
    )
