"""Sharded range-adaptive hybrid RMQ: the crossover, distributed.

The paper's two deferred directions meet here. §7.i leaves multi-BVH
distribution ("one BVH per cluster of blocks") as future work — that is
``core.distributed``'s mesh-sharded blocked engine. §6 shows the headline
result is regime-dependent — the blocked structure wins at small ranges, the
O(1) table family at large ones — which ``core.hybrid`` exploits on one
host. This engine fuses them: a sharded deployment that still routes every
query to the regime-appropriate structure.

Data flow (DESIGN.md §6):

    host batch (l, r)
      └─ partition by range length vs threshold        (numpy, host-side)
           ├─ short sub-batch -> sharded blocked path  (two-pmin merge)
           └─ long sub-batch  -> sharded sparse-table  (owner-column pmin)
      └─ exact leftmost scatter-back into batch order

Two distribution modes, one per scaling axis:

* ``mode="shard_structure"`` (default): the *array* is sharded — per-device
  blocked chunks for the short path, a column-sharded global doubling table
  for the long path. Memory scales with device count; queries are replicated
  and merge via pmin collectives.
* ``mode="shard_batch"``: the *query batch* is sharded — each device holds
  the full (replicated) structures and answers only its slice, so serving
  throughput scales with device count instead of being replicated work.

The routing threshold (``build(threshold=...)``): ``None`` is the
deterministic sqrt(n) default, exactly as in ``hybrid.build``; ``"cached"``
consults the persistent calibration cache (``calib_cache``, keyed by
``(n, block_size, backend, n_devices)``) and falls back to sqrt(n) on a
miss without ever measuring; ``"calibrated"`` measures on a miss and
persists the result; an int pins it explicitly. Machine state is opt-in —
default builds (registry, tests, benchmarks) never read the cache.

Results are bit-identical to ``block_rmq.query`` on the same batch — every
constituent is exact-leftmost, and the scatter-back preserves batch order.
"""

from __future__ import annotations

from typing import NamedTuple, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import calib_cache, distributed
from .hybrid import DEFAULT_THRESHOLD_FRAC, dispatch_by_length

__all__ = ["MODES", "ShardedHybridRMQ", "build", "query"]

MODES = ("shard_structure", "shard_batch")


class ShardedHybridRMQ(NamedTuple):
    """Both distributed constituents plus routing/launch metadata."""

    blocked: object  # sharded (or replicated) BlockRMQ — short-range path
    st: object  # ShardedSparseTable (or replicated SparseTable) — long path
    n: int  # logical array length (pre-padding)
    threshold: int  # range lengths <= threshold go to the blocked path
    mode: str  # "shard_structure" | "shard_batch"
    n_shards: int  # flattened mesh size (batch-pad granularity)
    dtype: object  # value dtype for the host-side scatter-back
    short_fn: object  # jitted (blocked, l, r) -> (idx, val)
    long_fn: object  # jitted (st, l, r) -> (idx, val)


def _default_mesh():
    from repro.launch.mesh import make_mesh

    return make_mesh((len(jax.devices()),), ("shard",)), ("shard",)


def build(
    x: jax.Array,
    mesh=None,
    axis_names: Sequence[str] | None = None,
    block_size: int = 128,
    *,
    threshold: int | str | None = None,
    mode: str = "shard_structure",
    cache_path=None,
) -> ShardedHybridRMQ:
    """Build both distributed constituents over ``mesh`` (default: all devices).

    ``threshold``: int pins the crossover; ``None`` is the deterministic
    sqrt(n) default (no cache, matching ``hybrid.build``); ``"cached"``
    reads the calibration cache with the sqrt(n) fallback, never measuring;
    ``"calibrated"`` measures on a cache miss and persists the result.
    """
    if mode not in MODES:
        raise ValueError(f"unknown mode {mode!r}; have {MODES}")
    x = jnp.asarray(x)
    if mesh is None:
        mesh, axis_names = _default_mesh()
    axis_names = tuple(axis_names if axis_names is not None else mesh.axis_names)
    num = distributed.num_shards(mesh, axis_names)
    n = x.shape[0]

    if threshold is None:
        threshold = max(1, int(round(n**DEFAULT_THRESHOLD_FRAC)))
    elif threshold == "cached":
        key = calib_cache.cache_key(n, block_size, n_devices=num)
        cached = calib_cache.load(key, path=cache_path)
        threshold = (
            cached
            if cached is not None
            else max(1, int(round(n**DEFAULT_THRESHOLD_FRAC)))
        )
    elif threshold == "calibrated":
        # The crossover is a property of the constituent structures, measured
        # by hybrid.calibrate on the single-host paths; the cache key still
        # carries n_devices so a sharded deployment calibrates per mesh size.
        threshold = calib_cache.get_threshold(
            n, block_size, n_devices=num, path=cache_path, use_kernels=False
        )

    if mode == "shard_structure":
        blocked = distributed.build_sharded(x, mesh, axis_names, block_size)
        short_fn = distributed.make_query_fn(mesh, axis_names)
        st = distributed.build_sharded_st(x, mesh, axis_names)
        long_fn = distributed.make_st_query_fn(mesh, axis_names)
    else:  # shard_batch
        blocked = distributed.build_replicated(x, mesh, block_size)
        short_fn = distributed.make_query_fn(mesh, axis_names, batch_sharded=True)
        st = distributed.build_replicated_st(x, mesh)
        long_fn = distributed.make_st_query_fn(mesh, axis_names, batch_sharded=True)

    return ShardedHybridRMQ(
        blocked=blocked,
        st=st,
        n=int(n),
        threshold=int(threshold),
        mode=mode,
        n_shards=int(num),
        dtype=np.dtype(x.dtype),
        short_fn=short_fn,
        long_fn=long_fn,
    )


def query(s: ShardedHybridRMQ, l, r) -> Tuple[jax.Array, jax.Array]:
    """Range-adaptive distributed batched RMQ -> (leftmost idx int32, value).

    Host-side partition by range length, per-regime *sharded* launches,
    ordered scatter-back — ``hybrid.dispatch_by_length`` with the sharded
    constituents closed over their states. (The batch-sharded query fns pad
    to a shard multiple internally, so divisibility is not this layer's
    concern.) Bit-identical to ``block_rmq.query``.
    """
    return dispatch_by_length(
        l,
        r,
        s.threshold,
        lambda lm, rm: s.short_fn(s.blocked, lm, rm),
        lambda lm, rm: s.long_fn(s.st, lm, rm),
        s.dtype,
    )
