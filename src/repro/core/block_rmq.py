"""RTXRMQ-TPU: the paper's block-matrix RMQ, adapted to the TPU hierarchy.

Paper mapping (DESIGN.md §2):
  * Algorithm 5 (block-matrix triangle generation)  -> ``build``: the array is
    padded and reshaped into (num_blocks, block_size); per-block leftmost
    minima replace the per-block geometry; a sparse table over block minima
    replaces the second-level acceleration structure.
  * Algorithm 6 (block-matrix ray generation)       -> ``query``: each query
    decomposes into left-partial + fully-covered-blocks + right-partial,
    exactly the paper's Case #1 / Case #2 branching — here branch-free via
    masking so a whole batch runs data-parallel (one lane per ray).
  * Algorithm 3 (closest-hit payload)               -> the masked min+argmin
    within a block: the VPU's vector min is the TPU's "intersection test".

This module is the pure-jnp implementation (also the oracle for the Pallas
kernels in ``repro.kernels``). ``repro.kernels.ops`` provides the fused
kernel path; ``repro.core.lane_rmq`` is the beyond-paper O(1) gather variant.
"""

from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from . import sparse_table

__all__ = ["BlockRMQ", "build", "query", "maxval"]


def maxval(dtype):
    dtype = jnp.dtype(dtype)
    if jnp.issubdtype(dtype, jnp.floating):
        return jnp.array(jnp.inf, dtype)
    return jnp.array(jnp.iinfo(dtype).max, dtype)


class BlockRMQ(NamedTuple):
    """Static blocked RMQ structure (arrays only — shape carries bs/nb)."""

    x_blocks: jax.Array  # (nb, bs), padded with +inf / int-max
    bmin_val: jax.Array  # (nb,) per-block minimum value
    bmin_gidx: jax.Array  # (nb,) int32 global index of per-block leftmost min
    st: sparse_table.SparseTable  # doubling table over bmin_val


def build(x: jax.Array, block_size: int) -> BlockRMQ:
    """Preprocess ``x`` into the blocked structure (paper's preprocessing stage).

    ``block_size`` plays the paper's BS role; the Eq. 2 float-precision
    constraint becomes the VMEM/lane constraint: block_size must be a multiple
    of 128 (TPU lane width) — enforced here.
    """
    if block_size % 128 != 0:
        raise ValueError(f"block_size must be a multiple of 128, got {block_size}")
    n = x.shape[0]
    nb = -(-n // block_size)
    big = maxval(x.dtype)
    xp = jnp.pad(x, (0, nb * block_size - n), constant_values=big)
    xb = xp.reshape(nb, block_size)
    lidx = jnp.argmin(xb, axis=1).astype(jnp.int32)  # leftmost per block
    bmin_val = jnp.take_along_axis(xb, lidx[:, None], axis=1)[:, 0]
    bmin_gidx = jnp.arange(nb, dtype=jnp.int32) * block_size + lidx
    st = sparse_table.build(bmin_val)
    return BlockRMQ(x_blocks=xb, bmin_val=bmin_val, bmin_gidx=bmin_gidx, st=st)


def _block_scan(xb: jax.Array, blk: jax.Array, lo: jax.Array, hi: jax.Array):
    """Masked min+argmin of xb[blk, lo:hi+1] per query (the 'ray' primitive).

    Returns (value, global_index); value == +inf when lo > hi (empty range).
    """
    bs = xb.shape[1]
    big = maxval(xb.dtype)
    rows = jnp.take(xb, blk, axis=0)  # (B, bs) gather of the candidate block
    lanes = jnp.arange(bs, dtype=jnp.int32)[None, :]
    inside = (lanes >= lo[:, None]) & (lanes <= hi[:, None])
    masked = jnp.where(inside, rows, big)
    lidx = jnp.argmin(masked, axis=1).astype(jnp.int32)
    val = jnp.take_along_axis(masked, lidx[:, None], axis=1)[:, 0]
    gidx = blk * bs + lidx
    return val, gidx


def _pick(v1, i1, v2, i2):
    """Merge candidates; on ties prefer candidate 1 (index-ordered => leftmost)."""
    take1 = v1 <= v2
    return jnp.where(take1, v1, v2), jnp.where(take1, i1, i2)


def query(s: BlockRMQ, l: jax.Array, r: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Batched RMQ. Returns (leftmost argmin index int32, min value).

    Branch-free realization of the paper's Algorithm 6: Case #1 (single
    block) falls out of masking the right partial and the interior away.
    """
    bs = s.x_blocks.shape[1]
    nb = s.x_blocks.shape[0]
    big = maxval(s.x_blocks.dtype)
    l = l.astype(jnp.int32)
    r = r.astype(jnp.int32)

    bl = l // bs
    br = r // bs
    ll = l - bl * bs
    rl = r - br * bs

    # Left partial block (covers the whole query when bl == br).
    lend = jnp.where(bl == br, rl, bs - 1)
    lv, li = _block_scan(s.x_blocks, bl, ll, lend)

    # Right partial block, only when the query straddles blocks.
    rv, ri = _block_scan(s.x_blocks, br, jnp.zeros_like(rl), rl)
    rv = jnp.where(br > bl, rv, big)

    # Fully covered interior blocks via the level-2 sparse table.
    has_interior = (br - bl) >= 2
    ilo = jnp.clip(bl + 1, 0, nb - 1)
    ihi = jnp.clip(br - 1, 0, nb - 1)
    ihi = jnp.maximum(ihi, ilo)  # keep the ST query well-formed when masked off
    bi = sparse_table.query(s.st, ilo, ihi)
    iv = jnp.where(has_interior, s.bmin_val[bi], big)
    ii = s.bmin_gidx[bi]

    # Index ranges are ordered left < interior < right, so tie-prefer in order.
    v, i = _pick(lv, li, iv, ii)
    v, i = _pick(v, i, rv, ri)
    return i, v
