"""RTXRMQ-TPU: the paper's block-matrix RMQ, adapted to the TPU hierarchy.

Paper mapping (DESIGN.md §2):
  * Algorithm 5 (block-matrix triangle generation)  -> ``build``: the array is
    padded and reshaped into (num_blocks, block_size); per-block leftmost
    minima replace the per-block geometry; a sparse table over block minima
    replaces the second-level acceleration structure.
  * Algorithm 6 (block-matrix ray generation)       -> ``query``: each query
    decomposes into left-partial + fully-covered-blocks + right-partial,
    exactly the paper's Case #1 / Case #2 branching — here branch-free via
    masking so a whole batch runs data-parallel (one lane per ray).
  * Algorithm 3 (closest-hit payload)               -> the masked min+argmin
    within a block: the VPU's vector min is the TPU's "intersection test".

This module is the pure-jnp implementation (also the oracle for the Pallas
kernels in ``repro.kernels``). ``repro.kernels.ops`` provides the fused
kernel path; ``repro.core.lane_rmq`` is the beyond-paper O(1) gather variant.
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from . import packing, sparse_table

__all__ = [
    "BlockRMQ",
    "PackedBlockRMQ",
    "build",
    "build_packed",
    "maxval",
    "query",
    "query_packed",
    "query_words",
]


def maxval(dtype):
    dtype = jnp.dtype(dtype)
    if jnp.issubdtype(dtype, jnp.floating):
        return jnp.array(jnp.inf, dtype)
    return jnp.array(jnp.iinfo(dtype).max, dtype)


class BlockRMQ(NamedTuple):
    """Static blocked RMQ structure (arrays only — shape carries bs/nb)."""

    x_blocks: jax.Array  # (nb, bs), padded with +inf / int-max
    bmin_val: jax.Array  # (nb,) per-block minimum value
    bmin_gidx: jax.Array  # (nb,) int32 global index of per-block leftmost min
    st: sparse_table.SparseTable  # doubling table over bmin_val


def build(x: jax.Array, block_size: int) -> BlockRMQ:
    """Preprocess ``x`` into the blocked structure (paper's preprocessing stage).

    ``block_size`` plays the paper's BS role; the Eq. 2 float-precision
    constraint becomes the VMEM/lane constraint: block_size must be a multiple
    of 128 (TPU lane width) — enforced here.
    """
    if block_size % 128 != 0:
        raise ValueError(f"block_size must be a multiple of 128, got {block_size}")
    n = x.shape[0]
    nb = -(-n // block_size)
    big = maxval(x.dtype)
    xp = jnp.pad(x, (0, nb * block_size - n), constant_values=big)
    xb = xp.reshape(nb, block_size)
    lidx = jnp.argmin(xb, axis=1).astype(jnp.int32)  # leftmost per block
    bmin_val = jnp.take_along_axis(xb, lidx[:, None], axis=1)[:, 0]
    bmin_gidx = jnp.arange(nb, dtype=jnp.int32) * block_size + lidx
    st = sparse_table.build(bmin_val)
    return BlockRMQ(x_blocks=xb, bmin_val=bmin_val, bmin_gidx=bmin_gidx, st=st)


def _block_scan(xb: jax.Array, blk: jax.Array, lo: jax.Array, hi: jax.Array):
    """Masked min+argmin of xb[blk, lo:hi+1] per query (the 'ray' primitive).

    Returns (value, global_index); value == +inf when lo > hi (empty range).
    """
    bs = xb.shape[1]
    big = maxval(xb.dtype)
    rows = jnp.take(xb, blk, axis=0)  # (B, bs) gather of the candidate block
    lanes = jnp.arange(bs, dtype=jnp.int32)[None, :]
    inside = (lanes >= lo[:, None]) & (lanes <= hi[:, None])
    masked = jnp.where(inside, rows, big)
    lidx = jnp.argmin(masked, axis=1).astype(jnp.int32)
    val = jnp.take_along_axis(masked, lidx[:, None], axis=1)[:, 0]
    gidx = blk * bs + lidx
    return val, gidx


def _pick(v1, i1, v2, i2):
    """Merge candidates; on ties prefer candidate 1 (index-ordered => leftmost)."""
    take1 = v1 <= v2
    return jnp.where(take1, v1, v2), jnp.where(take1, i1, i2)


def query(s: BlockRMQ, l: jax.Array, r: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Batched RMQ. Returns (leftmost argmin index int32, min value).

    Branch-free realization of the paper's Algorithm 6: Case #1 (single
    block) falls out of masking the right partial and the interior away.
    """
    bs = s.x_blocks.shape[1]
    nb = s.x_blocks.shape[0]
    big = maxval(s.x_blocks.dtype)
    l = l.astype(jnp.int32)
    r = r.astype(jnp.int32)

    bl = l // bs
    br = r // bs
    ll = l - bl * bs
    rl = r - br * bs

    # Left partial block (covers the whole query when bl == br).
    lend = jnp.where(bl == br, rl, bs - 1)
    lv, li = _block_scan(s.x_blocks, bl, ll, lend)

    # Right partial block, only when the query straddles blocks.
    rv, ri = _block_scan(s.x_blocks, br, jnp.zeros_like(rl), rl)
    rv = jnp.where(br > bl, rv, big)

    # Fully covered interior blocks via the level-2 sparse table.
    has_interior = (br - bl) >= 2
    ilo = jnp.clip(bl + 1, 0, nb - 1)
    ihi = jnp.clip(br - 1, 0, nb - 1)
    ihi = jnp.maximum(ihi, ilo)  # keep the ST query well-formed when masked off
    bi = sparse_table.query(s.st, ilo, ihi)
    iv = jnp.where(has_interior, s.bmin_val[bi], big)
    ii = s.bmin_gidx[bi]

    # Index ranges are ordered left < interior < right, so tie-prefer in order.
    v, i = _pick(lv, li, iv, ii)
    v, i = _pick(v, i, rv, ri)
    return i, v


# --- packed variant ---------------------------------------------------------
#
# One word plane per tier (DESIGN.md §13): the partial-block scan, the
# interior doubling lookup, and the three-way merge all become plain word
# mins — no argmin/take_along_axis, no bmin_gidx plane, no select chains.
# Level 0 of ``stw`` *is* the per-block-minimum plane, so the blocked
# structure is exactly two planes: (nb, bs) words + (K, nb) words.


class PackedBlockRMQ(NamedTuple):
    """Blocked RMQ over packed (value, index) words.

    ``blocks`` holds the packed element words (global indices; pads are
    ``pad_word``) for the exact layouts, or the *raw* padded values for the
    quantized layout (partial scans must stay exact — only the interior
    doubling tier quantizes). ``stw`` is the packed doubling table over
    per-block minima; its index fields are exact in every layout.
    """

    blocks: jax.Array  # (nb, bs): packed words, or raw values when quantized
    stw: jax.Array  # (K, nb) packed words over per-block leftmost minima


def _doubling_min(words: jax.Array) -> jax.Array:
    """Doubling table over packed words: plain ``minimum`` per level."""
    n = words.shape[0]
    k_levels = max(1, (n - 1).bit_length() + 1) if n > 1 else 1
    cur = words
    rows = [cur]
    for k in range(1, k_levels):
        h = 1 << (k - 1)
        if h >= n:
            rows.append(cur)
            continue
        shifted = jnp.concatenate([cur[h:], jnp.broadcast_to(cur[-1], (h,))])
        cur = jnp.minimum(cur, shifted)
        rows.append(cur)
    return jnp.stack(rows)


def build_packed(x: jax.Array, block_size: int, spec=None, layout: str = "auto"):
    """Packed blocked build; returns ``(PackedBlockRMQ, spec)``.

    Elements pack with *global* indices before padding, so pads are the
    reserved ``pad_word`` (always lose a min) rather than packed maxval —
    this is what lets packed32 keep its fit even though the raw pad value
    (int-max / +inf) would blow the measured key range.
    """
    if block_size % 128 != 0:
        raise ValueError(f"block_size must be a multiple of 128, got {block_size}")
    n = x.shape[0]
    if spec is None:
        spec = packing.spec_for(x, n, layout)
    nb = -(-n // block_size)
    pad = nb * block_size - n
    if spec.layout == "quantized":
        # Exact partial tiers + quantized interior: raw blocks, exact
        # per-block argmins, then bucket-encode the exact doubling table.
        s = build(x, block_size)
        stw = packing.pack(spec, s.bmin_val[s.st.idx], s.bmin_gidx[s.st.idx])
        return PackedBlockRMQ(blocks=s.x_blocks, stw=stw), spec
    xw = packing.pack(spec, x, jnp.arange(n, dtype=jnp.int32))
    xw = jnp.pad(xw, (0, pad), constant_values=packing.pad_word(spec))
    xwb = xw.reshape(nb, block_size)
    stw = _doubling_min(jnp.min(xwb, axis=1))
    return PackedBlockRMQ(blocks=xwb, stw=stw), spec


def _scan_words(wb: jax.Array, blk, lo, hi, pad):
    """Masked word-min of wb[blk, lo:hi+1] per query; ``pad`` when empty."""
    bs = wb.shape[1]
    rows = jnp.take(wb, blk, axis=0)
    lanes = jnp.arange(bs, dtype=jnp.int32)[None, :]
    inside = (lanes >= lo[:, None]) & (lanes <= hi[:, None])
    return jnp.min(jnp.where(inside, rows, pad), axis=1)


def _interior_words(stw, bl, br, nb):
    """The fully-covered-blocks candidate as (wa, wb) doubling cells."""
    ilo = jnp.clip(bl + 1, 0, nb - 1)
    ihi = jnp.clip(br - 1, 0, nb - 1)
    ihi = jnp.maximum(ihi, ilo)
    k = sparse_table.exact_log2(ihi - ilo + 1)
    wa = stw[k, ilo]
    wb = stw[k, ihi - jnp.left_shift(jnp.int32(1), k) + 1]
    return wa, wb


def query_words(spec, blocks, stw, l, r):
    """Exact-layout blocked query -> the packed min *word* per query.

    The merge core shared by the single-host packed query and the
    distributed single-pmin merge (``core.distributed``): callers unpack,
    or pmin across shards first — the word stays the unit of exchange.
    """
    bs = blocks.shape[1]
    nb = blocks.shape[0]
    pad = jnp.asarray(packing.pad_word(spec), packing.word_dtype(spec))
    bl = l // bs
    br = r // bs
    ll = l - bl * bs
    rl = r - br * bs
    lend = jnp.where(bl == br, rl, bs - 1)
    has_interior = (br - bl) >= 2
    wa, wb = _interior_words(stw, bl, br, nb)
    lw = _scan_words(blocks, bl, ll, lend, pad)
    rw = _scan_words(blocks, br, jnp.zeros_like(rl), rl, pad)
    rw = jnp.where(br > bl, rw, pad)
    iw = jnp.where(has_interior, jnp.minimum(wa, wb), pad)
    return jnp.minimum(jnp.minimum(lw, iw), rw)


@partial(jax.jit, static_argnums=0)
def _query_packed_jit(spec, blocks, stw, l, r):
    bs = blocks.shape[1]
    nb = blocks.shape[0]
    if spec.layout != "quantized":
        w = query_words(spec, blocks, stw, l, r)
        return packing.unpack_idx(spec, w), packing.unpack_val(spec, w)

    big = maxval(blocks.dtype)
    bl = l // bs
    br = r // bs
    ll = l - bl * bs
    rl = r - br * bs
    lend = jnp.where(bl == br, rl, bs - 1)
    has_interior = (br - bl) >= 2
    wa, wb = _interior_words(stw, bl, br, nb)

    # Quantized: exact partial scans over raw blocks; interior cells break
    # bucket ties with exact value gathers from the flat raw plane.
    lv, li = _block_scan(blocks, bl, ll, lend)
    rv, ri = _block_scan(blocks, br, jnp.zeros_like(rl), rl)
    rv = jnp.where(br > bl, rv, big)
    flat = blocks.reshape(-1)
    ia = packing.unpack_idx(spec, wa)
    ib = packing.unpack_idx(spec, wb)
    va = flat[ia]
    vb = flat[ib]
    collide = (wa >> spec.idx_bits) == (wb >> spec.idx_bits)
    take_a = jnp.where(collide, va <= vb, wa <= wb)
    iv = jnp.where(take_a, va, vb)
    ii = jnp.where(take_a, ia, ib)
    iv = jnp.where(has_interior, iv, big)
    v, i = _pick(lv, li, iv, ii)
    v, i = _pick(v, i, rv, ri)
    return i, v


def query_packed(s: PackedBlockRMQ, spec, l: jax.Array, r: jax.Array):
    """Batched packed RMQ -> ``(idx int32, val)``, exact leftmost ties."""
    return _query_packed_jit(
        spec, s.blocks, s.stw, l.astype(jnp.int32), r.astype(jnp.int32)
    )
