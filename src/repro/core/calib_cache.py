"""Persistent measurement cache: calibration thresholds + tuned kernel configs.

``hybrid.calibrate`` measures the blocked-vs-sparse-table crossover by timing
both constituent paths — seconds of wall-clock per (n, block_size) point.
Re-measuring at every build is waste: the crossover is a property of the
machine, not of the process. This module persists measured thresholds in a
small JSON file keyed by ``(n, block_size, backend, n_devices)`` so builds
hit the cache and only a first-ever configuration pays the measurement.

File format (atomic rename on write):

    {"version": 2, "entries": {"n=1048576/bs=128/backend=tpu/ndev=8": 1024,
                               "kernel/n=65536/batch=4096/backend=tpu/ndev=8":
                                   {"tile": 8, "fetch": "dma", "block_size": 128}}}

Key v2: sharded measurements additionally carry the distribution mode and
mesh shape (``.../ndev=8/mode=shard_2d/mesh=2x4``) so modes no longer share
one threshold slot per mesh size.

Cache v2 (file ``version`` 2): entries are arbitrary JSON values, not just
int thresholds. The megakernel autotuner (``repro.kernels.tuning``) stores
winning ``(tile, fetch, block_size)`` configs as dicts under a ``kernel/``
key-namespace prefix, sharing the same file, atomic-write discipline, and
staleness rules as thresholds. ``load``/``store`` stay int-typed for
threshold callers; ``load_entry``/``store_entry`` are the generic seam.
The version bump marks every v1 entry stale (thresholds re-measure once).

Cache v3: the packed-structure ``layout`` joins the key schema. A
measurement on packed words is a different measurement (one plane moved,
one collective, different fetch volume), so ``cache_key``/the autotuner's
``tuning_key`` append ``/layout=<name>`` — but only for non-default
layouts, keeping every existing unpacked key byte-identical. v2 files are
*migrated*, not dropped: every v2 entry was measured on unpacked
structures, which is exactly what the unchanged unpacked keys mean, so
``_read`` keeps them (annotating ``kernel/`` config dicts with
``layout: "unpacked"``) and the next store persists the file as v3.

A pre-v2 version mismatch marks every entry stale: ``load`` misses, and
the next ``store`` drops the old entries wholesale. Corrupt or unreadable
files are treated as empty — a cache must never turn into a crash.

Path resolution: explicit ``path`` argument > ``RMQ_CALIB_CACHE`` env var >
``~/.cache/rtxrmq-tpu/calibration.json``.
"""

from __future__ import annotations

import json
import os
import tempfile
from pathlib import Path

import jax

__all__ = [
    "CACHE_VERSION",
    "ENV_VAR",
    "cache_key",
    "default_path",
    "get_threshold",
    "load",
    "load_entry",
    "store",
    "store_entry",
]

CACHE_VERSION = 3
ENV_VAR = "RMQ_CALIB_CACHE"

# v2 -> v3 is key-schema growth, not a measurement change: every v2 entry
# maps 1:1 onto a v3 unpacked-layout entry.
_MIGRATABLE_VERSIONS = (2,)


def default_path() -> Path:
    env = os.environ.get(ENV_VAR)
    if env:
        return Path(env)
    return Path.home() / ".cache" / "rtxrmq-tpu" / "calibration.json"


def cache_key(
    n: int,
    block_size: int,
    *,
    backend: str | None = None,
    n_devices: int | None = None,
    mode: str | None = None,
    mesh_shape=None,
    layout: str | None = None,
) -> str:
    """The cache key: array size, block size, backend, and device count.

    Key v2 (sharded builds): a sharded measurement varies with the
    distribution mode AND the mesh factoring (a 2x4 struct x batch grid
    times different collectives than an 8x1), so passing ``mode`` (with the
    mesh shape) extends the key — without it, whichever mode calibrated a
    configuration first owned the threshold for every mode on that mesh
    size (the ROADMAP bug). Single-host builds pass neither and keep the
    v1 key, so their existing entries stay valid.

    Key v3 (packed structures): a packed build's crossover is measured on
    word planes, so ``layout`` extends the key. The default (None or
    ``"unpacked"``) appends nothing — migrated v2 entries keep matching.
    """
    if backend is None:
        backend = jax.default_backend()
    if n_devices is None:
        n_devices = len(jax.devices())
    key = f"n={n}/bs={block_size}/backend={backend}/ndev={n_devices}"
    if mode is not None:
        shape = "x".join(str(int(s)) for s in mesh_shape) if mesh_shape else "?"
        key += f"/mode={mode}/mesh={shape}"
    if layout is not None and layout != "unpacked":
        key += f"/layout={layout}"
    return key


def _migrate(version, entries: dict) -> dict:
    """Lift a prior-version entries dict into the current schema.

    v2 -> v3: every v2 measurement was taken on unpacked structures and v3
    left unpacked keys unchanged, so the keys carry over verbatim; only the
    ``kernel/`` config dicts gain an explicit ``layout: "unpacked"`` stamp
    (threshold ints need none — their key IS the layout marker).
    """
    out = {}
    for key, value in entries.items():
        if key.startswith("kernel/") and isinstance(value, dict):
            value = {**value, "layout": value.get("layout", "unpacked")}
        out[key] = value
    return out


def _read(path: Path) -> dict:
    """Entries dict, or {} on missing / corrupt / stale-version files.

    Migratable prior versions (v2) are lifted in-memory; the file itself is
    rewritten as the current version on the next ``store``.
    """
    try:
        with open(path) as f:
            data = json.load(f)
    except (OSError, ValueError):
        return {}
    if not isinstance(data, dict):
        return {}
    entries = data.get("entries")
    if not isinstance(entries, dict):
        return {}
    version = data.get("version")
    if version == CACHE_VERSION:
        return entries
    if version in _MIGRATABLE_VERSIONS:
        return _migrate(version, entries)
    return {}  # stale format: every entry is a miss


def load_entry(key: str, path: str | Path | None = None):
    """Cached JSON value for ``key``, or None on miss/stale/corrupt."""
    entries = _read(Path(path) if path is not None else default_path())
    return entries.get(key)


def store_entry(key: str, value, path: str | Path | None = None) -> None:
    """Persist ``key -> value`` (any JSON value), keeping same-version entries."""
    p = Path(path) if path is not None else default_path()
    p.parent.mkdir(parents=True, exist_ok=True)
    entries = _read(p)  # drops stale-version/corrupt content wholesale
    entries[key] = value
    fd, tmp = tempfile.mkstemp(dir=p.parent, suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            json.dump({"version": CACHE_VERSION, "entries": entries}, f, indent=2)
        os.replace(tmp, p)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise


def load(key: str, path: str | Path | None = None) -> int | None:
    """Cached threshold for ``key``, or None on miss/stale/corrupt."""
    val = load_entry(key, path)
    return int(val) if val is not None else None


def store(key: str, threshold: int, path: str | Path | None = None) -> None:
    """Persist ``key -> threshold``, keeping other same-version entries."""
    store_entry(key, int(threshold), path)


def get_threshold(
    n: int,
    block_size: int,
    *,
    backend: str | None = None,
    n_devices: int | None = None,
    mode: str | None = None,
    mesh_shape=None,
    layout: str | None = None,
    path: str | Path | None = None,
    **calibrate_kw,
) -> int:
    """Cached crossover threshold; measures via ``hybrid.calibrate`` on miss.

    ``mode``/``mesh_shape`` extend the key for sharded measurements (key v2)
    and ``mode`` is forwarded to the calibration itself; single-host callers
    omit both and keep hitting their v1 entries. ``layout`` (key v3) does
    the same for packed builds: it extends the key and makes the miss-path
    measurement time the packed constituents.
    """
    key = cache_key(
        n,
        block_size,
        backend=backend,
        n_devices=n_devices,
        mode=mode,
        mesh_shape=mesh_shape,
        layout=layout,
    )
    hit = load(key, path)
    if hit is not None:
        return hit
    from . import hybrid  # deferred: hybrid also consumes this module

    if mode is not None:
        calibrate_kw["mode"] = mode
    if layout is not None and layout != "unpacked":
        calibrate_kw["layout"] = layout
    thr = hybrid.calibrate(n, block_size=block_size, **calibrate_kw)
    store(key, thr, path)
    return thr
