"""Persistent measurement cache: calibration thresholds + tuned kernel configs.

``hybrid.calibrate`` measures the blocked-vs-sparse-table crossover by timing
both constituent paths — seconds of wall-clock per (n, block_size) point.
Re-measuring at every build is waste: the crossover is a property of the
machine, not of the process. This module persists measured thresholds in a
small JSON file keyed by ``(n, block_size, backend, n_devices)`` so builds
hit the cache and only a first-ever configuration pays the measurement.

File format (atomic rename on write):

    {"version": 2, "entries": {"n=1048576/bs=128/backend=tpu/ndev=8": 1024,
                               "kernel/n=65536/batch=4096/backend=tpu/ndev=8":
                                   {"tile": 8, "fetch": "dma", "block_size": 128}}}

Key v2: sharded measurements additionally carry the distribution mode and
mesh shape (``.../ndev=8/mode=shard_2d/mesh=2x4``) so modes no longer share
one threshold slot per mesh size.

Cache v2 (file ``version`` 2): entries are arbitrary JSON values, not just
int thresholds. The megakernel autotuner (``repro.kernels.tuning``) stores
winning ``(tile, fetch, block_size)`` configs as dicts under a ``kernel/``
key-namespace prefix, sharing the same file, atomic-write discipline, and
staleness rules as thresholds. ``load``/``store`` stay int-typed for
threshold callers; ``load_entry``/``store_entry`` are the generic seam.
The version bump marks every v1 entry stale (thresholds re-measure once).

A version mismatch marks every entry stale: ``load`` misses, and the next
``store`` drops the old entries wholesale. Corrupt or unreadable files are
treated as empty — a cache must never turn into a crash.

Path resolution: explicit ``path`` argument > ``RMQ_CALIB_CACHE`` env var >
``~/.cache/rtxrmq-tpu/calibration.json``.
"""

from __future__ import annotations

import json
import os
import tempfile
from pathlib import Path

import jax

__all__ = [
    "CACHE_VERSION",
    "ENV_VAR",
    "cache_key",
    "default_path",
    "get_threshold",
    "load",
    "load_entry",
    "store",
    "store_entry",
]

CACHE_VERSION = 2
ENV_VAR = "RMQ_CALIB_CACHE"


def default_path() -> Path:
    env = os.environ.get(ENV_VAR)
    if env:
        return Path(env)
    return Path.home() / ".cache" / "rtxrmq-tpu" / "calibration.json"


def cache_key(
    n: int,
    block_size: int,
    *,
    backend: str | None = None,
    n_devices: int | None = None,
    mode: str | None = None,
    mesh_shape=None,
) -> str:
    """The cache key: array size, block size, backend, and device count.

    Key v2 (sharded builds): a sharded measurement varies with the
    distribution mode AND the mesh factoring (a 2x4 struct x batch grid
    times different collectives than an 8x1), so passing ``mode`` (with the
    mesh shape) extends the key — without it, whichever mode calibrated a
    configuration first owned the threshold for every mode on that mesh
    size (the ROADMAP bug). Single-host builds pass neither and keep the
    v1 key, so their existing entries stay valid.
    """
    if backend is None:
        backend = jax.default_backend()
    if n_devices is None:
        n_devices = len(jax.devices())
    key = f"n={n}/bs={block_size}/backend={backend}/ndev={n_devices}"
    if mode is not None:
        shape = "x".join(str(int(s)) for s in mesh_shape) if mesh_shape else "?"
        key += f"/mode={mode}/mesh={shape}"
    return key


def _read(path: Path) -> dict:
    """Entries dict, or {} on missing / corrupt / stale-version files."""
    try:
        with open(path) as f:
            data = json.load(f)
    except (OSError, ValueError):
        return {}
    if not isinstance(data, dict) or data.get("version") != CACHE_VERSION:
        return {}  # stale format: every entry is a miss
    entries = data.get("entries")
    return entries if isinstance(entries, dict) else {}


def load_entry(key: str, path: str | Path | None = None):
    """Cached JSON value for ``key``, or None on miss/stale/corrupt."""
    entries = _read(Path(path) if path is not None else default_path())
    return entries.get(key)


def store_entry(key: str, value, path: str | Path | None = None) -> None:
    """Persist ``key -> value`` (any JSON value), keeping same-version entries."""
    p = Path(path) if path is not None else default_path()
    p.parent.mkdir(parents=True, exist_ok=True)
    entries = _read(p)  # drops stale-version/corrupt content wholesale
    entries[key] = value
    fd, tmp = tempfile.mkstemp(dir=p.parent, suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            json.dump({"version": CACHE_VERSION, "entries": entries}, f, indent=2)
        os.replace(tmp, p)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise


def load(key: str, path: str | Path | None = None) -> int | None:
    """Cached threshold for ``key``, or None on miss/stale/corrupt."""
    val = load_entry(key, path)
    return int(val) if val is not None else None


def store(key: str, threshold: int, path: str | Path | None = None) -> None:
    """Persist ``key -> threshold``, keeping other same-version entries."""
    store_entry(key, int(threshold), path)


def get_threshold(
    n: int,
    block_size: int,
    *,
    backend: str | None = None,
    n_devices: int | None = None,
    mode: str | None = None,
    mesh_shape=None,
    path: str | Path | None = None,
    **calibrate_kw,
) -> int:
    """Cached crossover threshold; measures via ``hybrid.calibrate`` on miss.

    ``mode``/``mesh_shape`` extend the key for sharded measurements (key v2)
    and ``mode`` is forwarded to the calibration itself; single-host callers
    omit both and keep hitting their v1 entries.
    """
    key = cache_key(
        n,
        block_size,
        backend=backend,
        n_devices=n_devices,
        mode=mode,
        mesh_shape=mesh_shape,
    )
    hit = load(key, path)
    if hit is not None:
        return hit
    from . import hybrid  # deferred: hybrid also consumes this module

    if mode is not None:
        calibrate_kw["mode"] = mode
    thr = hybrid.calibrate(n, block_size=block_size, **calibrate_kw)
    store(key, thr, path)
    return thr
