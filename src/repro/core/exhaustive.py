"""EXHAUSTIVE baseline (paper §6.1): one lane per query, masked scan of X.

The paper's EXHAUSTIVE is one CUDA thread scanning [l, r]; the TPU-idiomatic
equivalent is a batched masked argmin over the full array — O(n) per query but
at full VPU throughput, used as the brute-force reference in benchmarks and as
a second oracle in tests (it is pure jnp and jit-able, unlike ref.rmq_ref).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["rmq_exhaustive"]


def _maxval(dtype):
    dtype = jnp.dtype(dtype)
    if jnp.issubdtype(dtype, jnp.floating):
        return jnp.array(jnp.inf, dtype)
    return jnp.array(jnp.iinfo(dtype).max, dtype)


def rmq_exhaustive(x: jax.Array, l: jax.Array, r: jax.Array, *, query_chunk: int = 256) -> jax.Array:
    """Batched brute-force RMQ. Returns leftmost argmin indices (int32).

    Chunked over queries to bound the (chunk, n) mask materialization.
    """
    n = x.shape[0]
    big = _maxval(x.dtype)
    idx = jnp.arange(n, dtype=jnp.int32)

    def one_chunk(lc, rc):
        inside = (idx[None, :] >= lc[:, None]) & (idx[None, :] <= rc[:, None])
        masked = jnp.where(inside, x[None, :], big)
        return jnp.argmin(masked, axis=1).astype(jnp.int32)  # argmin = leftmost

    b = l.shape[0]
    if b <= query_chunk:
        return one_chunk(l.astype(jnp.int32), r.astype(jnp.int32))
    pad = (-b) % query_chunk
    lp = jnp.pad(l.astype(jnp.int32), (0, pad))
    rp = jnp.pad(r.astype(jnp.int32), (0, pad))
    lc = lp.reshape(-1, query_chunk)
    rc = rp.reshape(-1, query_chunk)
    out = jax.lax.map(lambda args: one_chunk(*args), (lc, rc))
    return out.reshape(-1)[:b]
