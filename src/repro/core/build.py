"""Staged BuildPlan pipeline: the one path every engine build lowers through.

The paper's preprocessing step (building the blocked / sparse-table structure
rays are cast against) is the scalability bottleneck the serving layer
inherits, so construction is a first-class pipeline rather than a pile of
per-engine build functions. A ``BuildPlan`` is an ordered list of named
stages over a shared build-state dict:

    shard_layout   host-side: shard geometry (``ShardLayout``) + padding
    local_build    per-shard structures, no communication
    halo_exchange  collectives only (the distributed doubling recurrence)
    finalize       assemble the engine state (+ jitted query closures)

Single-host engines carry the degenerate layout (one shard) and skip the
halo stage; mesh engines get real sharding and — for the column-sharded
doubling table — a build whose per-device memory is bounded by the shard,
never the full (K, n) table (``distributed.st_local_level0`` /
``st_halo_doubling``).

``plan_for(engine, n, ...)`` resolves everything static at plan time (shard
geometry, the routing threshold including cache/calibration policy, the
distribution mode), so a plan is inspectable metadata: the serving layer
derives warmup query regimes from it (``warmup_bounds``) and benchmarks
observe per-stage allocations (``execute(..., observer=...)``).

``registry.EngineSpec`` lowers both its ``build`` and its serving build
through ``build()`` / ``plan_for()`` + ``execute()``; ``hybrid.build``,
``sharded_hybrid.build`` and ``distributed.build_sharded_st`` are thin
wrappers over the same planners.
"""

from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import trace as obs_trace

from . import block_rmq, calib_cache, distributed, lane_rmq, lca, packing, sparse_table

__all__ = [
    "BuildPlan",
    "BuildStage",
    "STAGE_NAMES",
    "ShardLayout",
    "build",
    "default_mesh",
    "execute",
    "execute_update",
    "plan_for",
    "planner_names",
    "run_stages",
    "update_plan",
    "warmup_bounds",
]

# Canonical stage order. The first four are the build pipeline; the last two
# are the online-update pipeline (``repro.update``): ``apply_deltas`` patches
# structures incrementally from a coalesced DeltaBatch, ``publish`` installs
# the patched state as the next MVCC version.
STAGE_NAMES = (
    "shard_layout",
    "local_build",
    "halo_exchange",
    "finalize",
    "apply_deltas",
    "publish",
)


class ShardLayout(NamedTuple):
    """Static shard geometry, resolved at plan time from ``n`` alone."""

    n: int  # logical array length (pre-padding)
    n_pad: int  # padded length (shard-divisible)
    num_shards: int  # flattened structure-shard count (1 on a single host)
    shard_len: int  # columns per structure shard (n_pad on a single host)


class BuildStage(NamedTuple):
    """One named pipeline stage: ``fn`` advances the build-state dict."""

    name: str  # one of STAGE_NAMES
    fn: Callable[[dict], dict]


class BuildPlan(NamedTuple):
    """A fully-resolved build: static layout + metadata + executable stages."""

    engine: str
    layout: ShardLayout
    stages: Tuple[BuildStage, ...]
    meta: Dict[str, Any]  # resolved threshold / mode / block_size / mesh ...


def default_mesh():
    """The all-devices 1-D mesh: (mesh, axis_names) — the one definition of
    "no mesh was passed", shared by the registry and the serve CLI."""
    from repro.launch.mesh import make_mesh

    return make_mesh((len(jax.devices()),), ("shard",)), ("shard",)


def _mesh_or_default(mesh, axis_names):
    if mesh is None:
        return default_mesh()
    return mesh, tuple(axis_names if axis_names is not None else mesh.axis_names)


def _resolve_threshold(
    threshold,
    n: int,
    block_size: int,
    *,
    n_devices: Optional[int] = None,
    cache_path=None,
    calibrate_kw: Optional[dict] = None,
    key_mode: Optional[str] = None,
    key_mesh_shape=None,
    layout: Optional[str] = None,
) -> int:
    """The routing-threshold policy, shared by both hybrid planners.

    ``None`` -> deterministic sqrt(n) (never touches machine state);
    ``"cached"`` -> persistent cache with the sqrt(n) fallback, never
    measuring; ``"calibrated"`` -> measure via ``hybrid.calibrate`` on a
    miss and persist (``calibrate_kw`` carries the mesh for sharded-aware
    measurement); an int pins it.

    Sharded planners pass ``key_mode``/``key_mesh_shape`` (cache key v2) so
    every (mode, mesh factoring) owns its own cached threshold; single-host
    planners omit them and keep reading their v1 entries. ``layout`` (cache
    key v3) scopes the measurement to a packed word layout — the crossover
    moves when both tiers read packed planes.
    """
    from . import hybrid  # deferred: hybrid lowers its build through here

    if threshold is None:
        return max(1, int(round(n**hybrid.DEFAULT_THRESHOLD_FRAC)))
    if isinstance(threshold, (int, np.integer)):
        return int(threshold)
    if threshold == "cached":
        key = calib_cache.cache_key(
            n,
            block_size,
            n_devices=n_devices,
            mode=key_mode,
            mesh_shape=key_mesh_shape,
            layout=layout,
        )
        hit = calib_cache.load(key, path=cache_path)
        if hit is not None:
            return hit
        return max(1, int(round(n**hybrid.DEFAULT_THRESHOLD_FRAC)))
    if threshold == "calibrated":
        return calib_cache.get_threshold(
            n,
            block_size,
            n_devices=n_devices,
            mode=key_mode,
            mesh_shape=key_mesh_shape,
            path=cache_path,
            layout=layout,
            **(calibrate_kw or {}),
        )
    raise ValueError(
        f"threshold must be an int, None, 'cached' or 'calibrated'; got {threshold!r}"
    )


def _resolve_kernel_config(kernel_config, n: int, block_size: int | None = None):
    """The megakernel launch-geometry policy (mirrors ``_resolve_threshold``).

    ``None`` -> the deterministic default config (never touches machine
    state); ``"cached"`` -> the persistent cache, default fallback, never
    measuring; ``"tuned"`` -> the cache, sweeping via ``tuning.autotune``
    only on a miss; a ``tuning.KernelConfig`` (or compatible tuple) pins it.
    ``block_size`` pins that knob when the caller's structure already
    committed to one.
    """
    from repro.kernels import tuning  # deferred: keep core importable alone

    if kernel_config is None or isinstance(kernel_config, str):
        return tuning.get_config(n, policy=kernel_config, block_size=block_size)
    return tuning.KernelConfig(*kernel_config)


def _norm_packed(packed) -> Optional[str]:
    """Normalise the ``packed=`` build kwarg to a layout request or ``None``.

    ``None``/``False`` -> unpacked structures (the historical default);
    ``True`` -> ``"auto"``; otherwise one of ``packing.LAYOUTS`` or
    ``"auto"``. The request is resolved to a concrete ``PackSpec`` only at
    execute time (``packing.spec_for``) — the winning layout depends on the
    data's key range, which a plan (static, pre-``x``) cannot see.
    """
    if packed is None or packed is False:
        return None
    if packed is True:
        return "auto"
    packed = str(packed)
    if packed == "unpacked":
        return None
    if packed != "auto" and packed not in packing.PACKED_LAYOUTS:
        raise ValueError(
            f"packed must be one of {('auto',) + packing.PACKED_LAYOUTS}, "
            f"a bool, or None; got {packed!r}"
        )
    return packed


# --- pipeline execution -----------------------------------------------------


def run_stages(plan: BuildPlan, state: dict, *, observer: Optional[Callable] = None):
    """Advance ``state`` through ``plan``'s stages; return ``state["result"]``.

    The one stage sequencer behind both pipelines (build and online update).
    ``observer(stage_name, state)`` fires after each stage — the seam the
    build-memory benchmark, the no-full-table allocation probes, and the
    update-throughput breakdown hook. When the process-global tracer is
    enabled, each stage additionally lands as a span (named after the stage,
    ``engine`` attr from the plan) under whatever span is ambient — build
    stages under the CLI's build span, update stages under the server's
    ``update`` span (DESIGN.md §14).
    """
    tr = obs_trace.get_tracer()
    if not tr.enabled:
        for stage in plan.stages:
            state = stage.fn(state)
            if observer is not None:
                observer(stage.name, state)
        return state["result"]
    for stage in plan.stages:
        with tr.span(stage.name, attrs={"engine": plan.engine}):
            state = stage.fn(state)
        if observer is not None:
            observer(stage.name, state)
    return state["result"]


def execute(plan: BuildPlan, x, *, observer: Optional[Callable] = None):
    """Run ``plan``'s build stages over ``x``; return the finalize result."""
    x = jnp.asarray(x)
    if x.ndim != 1 or x.shape[0] != plan.layout.n:
        raise ValueError(
            f"plan for n={plan.layout.n} executed on array of shape {x.shape}"
        )
    return run_stages(plan, {"x": x}, observer=observer)


# --- online-update pipeline --------------------------------------------------


def update_plan(
    engine: str,
    layout: ShardLayout,
    apply_fn: Callable[[dict], dict],
    publish_fn: Callable[[dict], dict],
    meta: Optional[Dict[str, Any]] = None,
) -> BuildPlan:
    """The two-stage online-update plan: ``apply_deltas`` -> ``publish``.

    ``apply_fn`` consumes ``state["deltas"]`` (a coalesced
    ``repro.update.DeltaBatch``) and writes ``state["patched"]`` (the next
    engine state, copy-on-write over the previous version's leaves);
    ``publish_fn`` installs it as the next MVCC version and writes
    ``state["result"]`` (an ``UpdateResult``). ``repro.update.OnlineEngine``
    constructs these plans; they run through the same ``run_stages``
    sequencer (and observer seam) as builds.
    """
    return BuildPlan(
        engine,
        layout,
        (
            BuildStage("apply_deltas", apply_fn),
            BuildStage("publish", publish_fn),
        ),
        dict(meta or {}),
    )


def execute_update(plan: BuildPlan, deltas, *, observer: Optional[Callable] = None):
    """Run an update plan over a coalesced ``DeltaBatch``."""
    return run_stages(plan, {"deltas": deltas}, observer=observer)


_PLANNERS: Dict[str, Callable] = {}


def _planner(name: str):
    def deco(fn):
        _PLANNERS[name] = fn
        return fn

    return deco


def planner_names() -> Tuple[str, ...]:
    return tuple(sorted(_PLANNERS))


def plan_for(engine: str, n: int, *, mesh=None, axis_names=None, **kwargs) -> BuildPlan:
    """Resolve the staged BuildPlan for ``engine`` over a length-``n`` array."""
    try:
        planner = _PLANNERS[engine]
    except KeyError:
        raise ValueError(
            f"no build planner for engine {engine!r}; have {planner_names()}"
        ) from None
    return planner(int(n), mesh=mesh, axis_names=axis_names, **kwargs)


def build(engine: str, x, *, mesh=None, axis_names=None, observer=None, **kwargs):
    """The single build entry point: ``plan_for`` + ``execute`` in one call."""
    x = jnp.asarray(x)
    plan = plan_for(engine, x.shape[0], mesh=mesh, axis_names=axis_names, **kwargs)
    return execute(plan, x, observer=observer)


def warmup_bounds(plan: BuildPlan) -> Callable[[int], list]:
    """Plan-derived warmup batches: ``(size) -> [(l, r), ...]`` int32 arrays.

    One batch per query regime the built engine can dispatch to: threshold
    engines get a longest-still-short probe and (when any length routes
    long) a full-range probe, so every constituent path compiles before the
    first client; single-path engines get the two extremes.
    """
    n = plan.layout.n
    thr = plan.meta.get("threshold")

    def bounds(size: int) -> list:
        zeros = np.zeros(size, np.int32)
        if thr is None:  # single-path engine: the two extremes
            out = [(zeros, zeros)]
            if n > 1:
                out.append((zeros, np.full(size, n - 1, np.int32)))
            return out
        out = []
        if thr >= 1:  # longest range that still routes short
            out.append((zeros, np.full(size, min(thr, n) - 1, np.int32)))
        if n > thr:  # full range routes long
            out.append((zeros, np.full(size, n - 1, np.int32)))
        return out

    return bounds


# --- single-host planners ---------------------------------------------------


def _single_host_plan(engine, n, build_fn, *, with_x=False, meta=None) -> BuildPlan:
    layout = ShardLayout(n=n, n_pad=n, num_shards=1, shard_len=n)

    def local(state):
        state["built"] = build_fn(state["x"])
        return state

    def fin(state):
        state["result"] = (state["built"], state["x"]) if with_x else state["built"]
        return state

    return BuildPlan(
        engine,
        layout,
        (
            BuildStage("shard_layout", lambda state: state),
            BuildStage("local_build", local),
            BuildStage("finalize", fin),
        ),
        dict(meta or {}),
    )


@_planner("sparse_table")
def _plan_sparse_table(n, *, mesh=None, axis_names=None, packed=None):
    layout = _norm_packed(packed)
    if layout is None:
        return _single_host_plan("sparse_table", n, sparse_table.build, with_x=True)
    # Packed state is ``((PackedSparseTable, PackSpec), x)`` — the registry
    # query wrapper dispatches on the tuple shape.
    return _single_host_plan(
        "sparse_table",
        n,
        lambda x: sparse_table.build_packed(x, layout=layout),
        with_x=True,
        meta={"packed": layout},
    )


@_planner("block")
def _plan_block(n, *, mesh=None, axis_names=None, block_size=128, packed=None):
    layout = _norm_packed(packed)
    if layout is None:
        return _single_host_plan(
            "block",
            n,
            lambda x: block_rmq.build(x, block_size),
            meta={"block_size": block_size},
        )
    return _single_host_plan(
        "block",
        n,
        lambda x: block_rmq.build_packed(x, block_size, layout=layout),
        meta={"block_size": block_size, "packed": layout},
    )


@_planner("lane")
def _plan_lane(n, *, mesh=None, axis_names=None):
    return _single_host_plan("lane", n, lane_rmq.build)


@_planner("lca")
def _plan_lca(n, *, mesh=None, axis_names=None):
    return _single_host_plan("lca", n, lca.build, with_x=True)


@_planner("exhaustive")
def _plan_exhaustive(n, *, mesh=None, axis_names=None):
    return _single_host_plan("exhaustive", n, lambda x: x, with_x=True)


@_planner("fused")
def _plan_fused(
    n, *, mesh=None, axis_names=None, block_size=None, kernel_config=None, packed=None
):
    layout = _norm_packed(packed)
    cfg = _resolve_kernel_config(kernel_config, n, block_size)
    # A tuned config may carry its own block size; an explicit block_size
    # pins the sweep, so the two can never disagree. A tuned layout rides
    # along the same way: the config's own layout field wins unless the
    # caller pins one via ``packed=``.
    bs = block_size if block_size is not None else cfg.block_size
    if layout is None and cfg.layout != "unpacked":
        layout = cfg.layout
    if layout == "packed64":
        raise ValueError(
            "packed64 words are int64 — outside the TPU kernel vocabulary; "
            "use the XLA engines (sparse_table/block/hybrid with packed=) "
            "or packed32/quantized for the fused kernels"
        )

    def build_fn(x):
        from repro import kernels

        if layout is None:
            return kernels.ops.build(x, bs)
        return kernels.ops.build_packed(x, bs, layout=layout)

    def fin(state):
        state["result"] = (state["built"], cfg)
        return state

    plan = _single_host_plan(
        "fused",
        n,
        build_fn,
        meta={"block_size": bs, "kernel_config": cfg, "packed": layout},
    )
    stages = tuple(
        BuildStage("finalize", fin) if s.name == "finalize" else s for s in plan.stages
    )
    return plan._replace(stages=stages)


@_planner("hybrid")
def _plan_hybrid(
    n,
    *,
    mesh=None,
    axis_names=None,
    block_size=128,
    threshold=None,
    use_kernels=None,
    kernel_config=None,
    packed=None,
):
    pack_layout = _norm_packed(packed)
    if use_kernels is None:
        use_kernels = jax.default_backend() == "tpu"
    thr = _resolve_threshold(
        threshold,
        n,
        block_size,
        calibrate_kw={"use_kernels": use_kernels},
        layout=pack_layout,
    )
    # The megakernel's launch geometry, swept within this build's block size
    # (the hybrid's structures are committed to it). Resolved only when the
    # short path actually runs the kernels.
    cfg = (
        _resolve_kernel_config(kernel_config, n, block_size) if use_kernels else None
    )
    layout = ShardLayout(n=n, n_pad=n, num_shards=1, shard_len=n)

    def local(state):
        x = state["x"]
        if pack_layout is not None:
            # One spec for both tiers: blocked and doubling structures pack
            # against the same (key bias, idx width), so cross-tier merges in
            # ``dispatch_by_length`` compare words from one total order.
            spec = packing.spec_for(x, n, pack_layout)
            state["spec"] = spec
            if use_kernels and spec.layout in ("packed32", "quantized"):
                from repro import kernels

                state["blocked"], _ = kernels.ops.build_packed(
                    x, block_size, spec=spec
                )
            else:
                # packed64 (int64 words) lives outside the TPU kernel
                # vocabulary; XLA packed structures serve it.
                state["blocked"], _ = block_rmq.build_packed(x, block_size, spec=spec)
            state["st"], _ = sparse_table.build_packed(x, spec=spec)
            return state
        if use_kernels:
            from repro import kernels

            state["blocked"] = kernels.ops.build(x, block_size)
        else:
            state["blocked"] = block_rmq.build(x, block_size)
        state["st"] = sparse_table.build(x)
        return state

    def fin(state):
        from . import hybrid

        x, blocked, table = state["x"], state["blocked"], state["st"]
        spec = state.get("spec")
        if spec is not None:
            if use_kernels and spec.layout in ("packed32", "quantized"):
                from repro import kernels

                short_fn = lambda l, r: kernels.ops.query_packed(
                    blocked, spec, l, r, config=cfg
                )
            else:
                short_fn = lambda l, r: block_rmq.query_packed(blocked, spec, l, r)
            long_fn = lambda l, r: sparse_table.query_packed(table, spec, l, r)
        elif use_kernels:
            from repro import kernels

            # jitted inside; closes over the tuned launch geometry
            short_fn = lambda l, r: kernels.ops.query(blocked, l, r, config=cfg)
            long_fn = None
        else:
            short_fn = jax.jit(lambda l, r: block_rmq.query(blocked, l, r))
            long_fn = None

        if long_fn is None:

            def _long(l, r):
                idx = sparse_table.query(table, l, r)
                return idx, x[idx]

            long_fn = jax.jit(_long)

        state["result"] = hybrid.HybridRMQ(
            blocked=blocked,
            st=table,
            x=x,
            threshold=thr,
            use_kernels=bool(use_kernels),
            short_fn=short_fn,
            long_fn=long_fn,
        )
        return state

    return BuildPlan(
        "hybrid",
        layout,
        (
            BuildStage("shard_layout", lambda state: state),
            BuildStage("local_build", local),
            BuildStage("finalize", fin),
        ),
        {
            "block_size": block_size,
            "threshold": thr,
            "use_kernels": bool(use_kernels),
            "kernel_config": cfg,
            "packed": pack_layout,
        },
    )


# --- mesh planners ----------------------------------------------------------


def _st_layout(n: int, num: int) -> ShardLayout:
    n_pad = -(-max(n, 1) // num) * num
    return ShardLayout(n=n, n_pad=n_pad, num_shards=num, shard_len=n_pad // num)


def _sharded_st_stages(mesh, axis_names, layout, *, key: str = "st"):
    """The distributed doubling-table build as (layout, local, halo) stage fns.

    Shared by the standalone ``sharded_st`` plan and the sharded-hybrid
    plans; writes ``{key}`` (a ``ShardedSparseTable``) into the build state.
    """

    def lay(state):
        x = state["x"]
        # Pad columns with +inf values; queries never index past n-1 and
        # every window [c, c + 2^k) they touch lies inside [l, r], so pads
        # never win.
        state[f"{key}_xp"] = jnp.pad(
            x, (0, layout.n_pad - layout.n), constant_values=block_rmq.maxval(x.dtype)
        )
        return state

    def local(state):
        idx0, val0 = distributed.st_local_level0(state[f"{key}_xp"], mesh, axis_names)
        state[f"{key}_level0"] = (idx0, val0)
        return state

    def halo(state):
        idx0, val0 = state.pop(f"{key}_level0")
        idx, val = distributed.st_halo_doubling(idx0, val0, mesh, axis_names)
        state[key] = distributed.ShardedSparseTable(idx=idx, val=val)
        del state[f"{key}_xp"]
        return state

    return lay, local, halo


@_planner("sharded_st")
def _plan_sharded_st(n, *, mesh=None, axis_names=None):
    mesh, axis_names = _mesh_or_default(mesh, axis_names)
    layout = _st_layout(n, distributed.num_shards(mesh, axis_names))
    lay, local, halo = _sharded_st_stages(mesh, axis_names, layout)

    def fin(state):
        state["result"] = state["st"]
        return state

    return BuildPlan(
        "sharded_st",
        layout,
        (
            BuildStage("shard_layout", lay),
            BuildStage("local_build", local),
            BuildStage("halo_exchange", halo),
            BuildStage("finalize", fin),
        ),
        {"mesh": mesh, "axis_names": axis_names},
    )


@_planner("distributed")
def _plan_distributed(n, *, mesh=None, axis_names=None, block_size=1024, packed=None):
    pack_layout = _norm_packed(packed)
    if pack_layout == "quantized":
        raise ValueError(
            "quantized packing is single-host only: its exact-fallback gather "
            "needs the raw blocks resident, which the sharded merge does not "
            "ship; use packed32/packed64/auto for mesh engines"
        )
    mesh, axis_names = _mesh_or_default(mesh, axis_names)
    num = distributed.num_shards(mesh, axis_names)
    chunk = num * block_size
    n_pad = -(-max(n, 1) // chunk) * chunk
    layout = ShardLayout(n=n, n_pad=n_pad, num_shards=num, shard_len=n_pad // num)

    def local(state):
        if pack_layout is not None:
            # auto resolves to packed32/packed64 only, never quantized.
            spec = packing.spec_for(state["x"], n, pack_layout)
            state["spec"] = spec
            state["blocked"] = distributed.build_sharded_packed(
                state["x"], mesh, axis_names, block_size, spec
            )
        else:
            state["blocked"] = distributed.build_sharded(
                state["x"], mesh, axis_names, block_size
            )
        return state

    def fin(state):
        if "spec" in state:
            qfn = distributed.make_packed_query_fn(mesh, axis_names, state["spec"])
        else:
            qfn = distributed.make_query_fn(mesh, axis_names)
        state["result"] = (state["blocked"], qfn)
        return state

    return BuildPlan(
        "distributed",
        layout,
        (
            BuildStage("shard_layout", lambda state: state),
            BuildStage("local_build", local),
            BuildStage("finalize", fin),
        ),
        {
            "block_size": block_size,
            "mesh": mesh,
            "axis_names": axis_names,
            "packed": pack_layout,
        },
    )


def _mode_axes(mode: str, axis_names: Tuple[str, ...]):
    """(structure axes, batch axes) per distribution mode.

    ``shard_2d`` puts the structure on the first axis and the batch on the
    rest; on a 1-axis mesh it degrades to ``shard_structure``.
    """
    if mode == "shard_structure":
        return axis_names, ()
    if mode == "shard_batch":
        return (), axis_names
    return axis_names[:1], axis_names[1:]  # shard_2d


@_planner("sharded_hybrid")
def _plan_sharded_hybrid(
    n,
    *,
    mesh=None,
    axis_names=None,
    block_size=128,
    threshold=None,
    mode="shard_structure",
    cache_path=None,
    packed=None,
):
    from . import sharded_hybrid

    if mode not in sharded_hybrid.MODES:
        raise ValueError(f"unknown mode {mode!r}; have {sharded_hybrid.MODES}")
    pack_layout = _norm_packed(packed)
    if pack_layout == "quantized":
        raise ValueError(
            "quantized packing is single-host only: its exact-fallback gather "
            "needs the raw blocks resident, which the sharded merge does not "
            "ship; use packed32/packed64/auto for mesh engines"
        )
    mesh, axis_names = _mesh_or_default(mesh, axis_names)
    num = distributed.num_shards(mesh, axis_names)
    struct_axes, batch_axes = _mode_axes(mode, axis_names)
    thr = _resolve_threshold(
        threshold,
        n,
        block_size,
        n_devices=num,
        cache_path=cache_path,
        # Sharded-aware measurement: calibrate times the sharded constituents
        # on this very mesh, so the cached value reflects collective costs.
        calibrate_kw={"use_kernels": False, "mesh": mesh, "axis_names": axis_names},
        # Cache key v2: the measurement varies per (mode, mesh factoring).
        key_mode=mode,
        key_mesh_shape=tuple(mesh.shape[a] for a in mesh.axis_names),
        layout=pack_layout,
    )
    num_struct = distributed.num_shards(mesh, struct_axes) if struct_axes else 1
    layout = _st_layout(n, num_struct)

    stages = []
    if struct_axes:
        lay, st_local, st_halo = _sharded_st_stages(mesh, struct_axes, layout)

        if pack_layout is not None:

            def local(state):
                x = state["x"]
                # One spec for both tiers (same key bias / idx width), so
                # the packed halo recurrence and the blocked merge share a
                # total order. Words carry GLOBAL indices — merges need no
                # per-shard offsetting and ship ONE plane per level.
                spec = packing.spec_for(x, n, pack_layout)
                state["spec"] = spec
                state["blocked"] = distributed.build_sharded_packed(
                    x, mesh, struct_axes, block_size, spec
                )
                state["st_w0"] = distributed.pack_global(x, spec, layout.n_pad)
                return state

            def halo(state):
                spec = state["spec"]
                words = distributed.st_halo_doubling_packed(
                    state.pop("st_w0"), mesh, struct_axes, spec
                )
                state["st"] = sparse_table.PackedSparseTable(words=words)
                return state

            stages.append(BuildStage("shard_layout", lambda state: state))
            stages.append(BuildStage("local_build", local))
            stages.append(BuildStage("halo_exchange", halo))
        else:

            def local(state):
                state["blocked"] = distributed.build_sharded(
                    state["x"], mesh, struct_axes, block_size
                )
                return st_local(state)

            stages.append(BuildStage("shard_layout", lay))
            stages.append(BuildStage("local_build", local))
            stages.append(BuildStage("halo_exchange", st_halo))
    else:  # shard_batch: replicated structures, no halo stage

        if pack_layout is not None:

            def local(state):
                x = state["x"]
                spec = packing.spec_for(x, n, pack_layout)
                state["spec"] = spec
                state["blocked"] = distributed.build_replicated_packed(
                    x, mesh, block_size, spec
                )
                state["st"] = distributed.build_replicated_st_packed(x, mesh, spec)
                return state

        else:

            def local(state):
                state["blocked"] = distributed.build_replicated(
                    state["x"], mesh, block_size
                )
                state["st"] = distributed.build_replicated_st(state["x"], mesh)
                return state

        stages.append(BuildStage("shard_layout", lambda state: state))
        stages.append(BuildStage("local_build", local))

    def _query_fns(spec):
        """Query closures, resolved at finalize time: the packed variants
        close over the data-dependent ``PackSpec`` a plan cannot know."""
        if spec is not None:
            if struct_axes:
                return (
                    distributed.make_packed_query_fn(
                        mesh, struct_axes, spec, batch_axes=batch_axes or None
                    ),
                    distributed.make_packed_st_query_fn(
                        mesh, struct_axes, spec, batch_axes=batch_axes or None
                    ),
                )
            return (
                distributed.make_packed_query_fn(
                    mesh, axis_names, spec, batch_sharded=True
                ),
                distributed.make_packed_st_query_fn(
                    mesh, axis_names, spec, batch_sharded=True
                ),
            )
        if struct_axes:
            return (
                distributed.make_query_fn(
                    mesh, struct_axes, batch_axes=batch_axes or None
                ),
                distributed.make_st_query_fn(
                    mesh, struct_axes, batch_axes=batch_axes or None
                ),
            )
        return (
            distributed.make_query_fn(mesh, axis_names, batch_sharded=True),
            distributed.make_st_query_fn(mesh, axis_names, batch_sharded=True),
        )

    def fin(state):
        x = state["x"]
        short_fn, long_fn = _query_fns(state.get("spec"))
        state["result"] = sharded_hybrid.ShardedHybridRMQ(
            blocked=state["blocked"],
            st=state["st"],
            n=int(n),
            threshold=int(thr),
            mode=mode,
            n_shards=int(num),
            dtype=np.dtype(x.dtype),
            short_fn=short_fn,
            long_fn=long_fn,
        )
        return state

    stages.append(BuildStage("finalize", fin))
    return BuildPlan(
        "sharded_hybrid",
        layout,
        tuple(stages),
        {
            "block_size": block_size,
            "threshold": int(thr),
            "mode": mode,
            "mesh": mesh,
            "axis_names": axis_names,
            "struct_axes": struct_axes,
            "batch_axes": batch_axes,
            "packed": pack_layout,
        },
    )
