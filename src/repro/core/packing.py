"""Order-isomorphic packed (value, index) words: one plane instead of two.

RMQ on accelerators is memory-bound — every pmin merge, DMA window, halo
exchange, and COW publish in this repo moves a value plane *and* an index
plane. Packing both into a single word makes leftmost-tie argmin a plain
``min``: no select chains, half the planes, one collective where the
unpacked merge needs two.

The encoding is ``word = (key(v) << IDX_BITS) | i`` where ``key`` maps the
value dtype to a monotone signed-int32 keyspace:

- int32 (and narrower signed ints): ``key = v`` — identity.
- float32: bitcast to int32, then flip the low 31 bits of negatives
  (``key = b ^ ((b >> 31) & 0x7fffffff)``) so the int order of keys matches
  the float order of values; ``-0.0`` is normalized to ``+0.0`` first so the
  two zeros compare equal. The transform is an involution, so the same
  formula decodes.

Because ``i`` occupies the low bits, comparing words compares ``(key, i)``
lexicographically: the minimum word *is* the leftmost minimum element.
Equal words decode to equal answers, so ``min`` over packed words is exact
— including ties, negatives, and int32 extremes.

Layouts (``LAYOUTS``):

- ``packed64``: ``word = key.astype(int64) << 32 | i`` — always exact for
  any int32/float32 data, needs jax x64 (``ensure_x64`` flips the flag).
- ``packed32``: ``word = (key - kmin) << idx_bits | i`` in int32 — fits when
  the *observed* key range and the index width share 31 bits
  (``fits_packed32``). Half the bytes of the unpacked planes; the build
  measures the data and ``spec_for(layout="auto")`` degrades to packed64
  when it does not fit.
- ``quantized``: ``qword = bucket(v) << idx_bits | i`` in int32 with a
  *non-strictly* monotone bucket code (int16-grade: at most 16 bucket
  bits). Quantized words order correctly **except** when two candidates
  land in the same bucket — engines must break bucket ties with an exact
  value compare (the "fallback mask" contract; see DESIGN.md §13). The
  structures built here always store *exact* argmin indices in the index
  field, so the fallback only ever needs a value gather, never a rescan.

Pad convention: structure padding uses ``pad_word(spec)`` — the word
dtype's max, strictly greater than every encodable word (packed32 reserves
it via the fit check; packed64 can never reach it while ``i < 2**31``) —
so padded lanes lose every ``min`` without masking.

All helpers exist in jnp (device) and numpy (``*_np``, for the update
mirrors in ``repro.update.patch``) flavors and are bit-identical.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "LAYOUTS",
    "PACKED_LAYOUTS",
    "PackSpec",
    "ensure_x64",
    "fits_packed32",
    "idx_bits_for",
    "pack",
    "pack_np",
    "pad_word",
    "spec_for",
    "unpack_idx",
    "unpack_idx_np",
    "unpack_val",
    "unpack_val_np",
    "word_dtype",
]

# The autotuner's layout axis and the ``packed=`` build-kwarg vocabulary.
LAYOUTS = ("unpacked", "packed64", "packed32", "quantized")
# Layouts that replace the (idx, val) planes with word planes.
PACKED_LAYOUTS = ("packed64", "packed32", "quantized")

_I32_MIN = -(1 << 31)
_I32_MAX = (1 << 31) - 1


class PackSpec(NamedTuple):
    """Static description of a packed encoding (hashable; jit-static).

    ``kmin`` biases packed32 keys to non-negative; ``qmin``/``qscale``
    place the quantized bucket grid; ``val_bits`` is the key/bucket field
    width (32 for packed64). The spec is plain ints/floats/strs so it can
    ride jit static args, cache keys, and checkpoint manifests.
    """

    layout: str
    dtype: str  # value dtype name, e.g. "float32" / "int32"
    idx_bits: int
    val_bits: int
    kmin: int = 0
    qmin: float = 0.0
    qscale: float = 1.0

    def to_meta(self) -> dict:
        return dict(self._asdict())

    @classmethod
    def from_meta(cls, meta) -> "PackSpec":
        return cls(**{k: meta[k] for k in cls._fields})


def ensure_x64() -> None:
    """Enable jax 64-bit mode (required for packed64 device words).

    Idempotent; flips the global flag the first time a packed64 spec is
    built. Existing compiled functions stay valid — only new traces see
    64-bit types, and this repo's structures pin their dtypes explicitly.
    """
    if not jax.config.read("jax_enable_x64"):
        jax.config.update("jax_enable_x64", True)


def idx_bits_for(n_index: int) -> int:
    """Bits needed to address ``n_index`` slots (the *padded* length)."""
    if n_index <= 0:
        raise ValueError(f"n_index must be positive, got {n_index}")
    return max(1, int(n_index - 1).bit_length())


def fits_packed32(kmin: int, kmax: int, idx_bits: int) -> bool:
    """True when keys in [kmin, kmax] plus ``idx_bits`` fit one int32 word.

    Strict by one: the max encodable word must stay *below* INT32_MAX so
    ``pad_word`` is reserved and can never collide with a real element.
    """
    if idx_bits >= 31:
        return False
    span = int(kmax) - int(kmin)
    return (span + 1) << idx_bits <= _I32_MAX  # max word = span<<bits | (2^bits-1)


# --- monotone value <-> key maps -------------------------------------------


def _key_np(v: np.ndarray) -> np.ndarray:
    v = np.asarray(v)
    if v.dtype == np.float32:
        b = (v + np.float32(0.0)).view(np.int32)  # -0.0 -> +0.0
        return b ^ ((b >> 31) & np.int32(_I32_MAX))
    if np.issubdtype(v.dtype, np.integer):
        return v.astype(np.int32)
    raise TypeError(f"unsupported value dtype for packing: {v.dtype}")


def _unkey_np(key: np.ndarray, dtype: str) -> np.ndarray:
    key = np.asarray(key, dtype=np.int32)
    if dtype == "float32":
        b = key ^ ((key >> 31) & np.int32(_I32_MAX))  # involution
        return b.view(np.float32)
    return key.astype(np.dtype(dtype))


def _key(v: jax.Array) -> jax.Array:
    if v.dtype == jnp.float32:
        b = jax.lax.bitcast_convert_type(v + jnp.float32(0.0), jnp.int32)
        return b ^ ((b >> 31) & jnp.int32(_I32_MAX))
    if jnp.issubdtype(v.dtype, jnp.integer):
        return v.astype(jnp.int32)
    raise TypeError(f"unsupported value dtype for packing: {v.dtype}")


def _unkey(key: jax.Array, dtype: str) -> jax.Array:
    key = key.astype(jnp.int32)
    if dtype == "float32":
        b = key ^ ((key >> 31) & jnp.int32(_I32_MAX))
        return jax.lax.bitcast_convert_type(b, jnp.float32)
    return key.astype(jnp.dtype(dtype))


# --- spec construction ------------------------------------------------------


def spec_for(x, n_index: int, layout: str = "auto") -> PackSpec:
    """Measure ``x`` and build the PackSpec for ``layout``.

    ``n_index`` is the padded index domain the structure will address
    (block padding, shard padding — indices up to ``n_index - 1`` must
    encode). ``layout="auto"`` picks packed32 when the observed key range
    fits, else packed64. An explicit ``layout="packed32"`` that does not
    fit raises (the caller asked for something the data cannot encode).
    """
    xh = np.asarray(x)
    if xh.ndim != 1 or xh.size == 0:
        raise ValueError(f"spec_for wants a non-empty 1-D array, got {xh.shape}")
    dtype = str(xh.dtype)
    bits = idx_bits_for(n_index)
    keys = _key_np(xh)
    kmin, kmax = int(keys.min()), int(keys.max())

    if layout == "auto":
        layout = "packed32" if fits_packed32(kmin, kmax, bits) else "packed64"
    if layout == "packed64":
        ensure_x64()
        return PackSpec("packed64", dtype, 32, 32, kmin=0)
    if layout == "packed32":
        if not fits_packed32(kmin, kmax, bits):
            raise ValueError(
                f"packed32 cannot encode key span [{kmin}, {kmax}] with "
                f"{bits} index bits; use layout='packed64' or 'auto'"
            )
        return PackSpec("packed32", dtype, bits, 31 - bits, kmin=kmin)
    if layout == "quantized":
        vbits = min(16, 31 - bits)  # int16-grade bucket codes
        if vbits < 1:
            raise ValueError(f"no bucket bits left for n_index={n_index}")
        lo = float(xh.min())
        hi = float(xh.max())
        span = hi - lo
        qscale = (span / float((1 << vbits) - 1)) if span > 0 else 1.0
        return PackSpec("quantized", dtype, bits, vbits, qmin=lo, qscale=qscale)
    raise ValueError(f"unknown layout {layout!r}; have {LAYOUTS}")


def word_dtype(spec: PackSpec):
    return jnp.int64 if spec.layout == "packed64" else jnp.int32


def word_dtype_np(spec: PackSpec):
    return np.int64 if spec.layout == "packed64" else np.int32


def pad_word(spec: PackSpec) -> int:
    """The +inf word: strictly greater than every encodable (key, i)."""
    return (1 << 63) - 1 if spec.layout == "packed64" else _I32_MAX


def word_nbytes(spec) -> int:
    """Bytes per packed word (8 for packed64, 4 otherwise)."""
    return 8 if getattr(spec, "layout", spec) == "packed64" else 4


# --- pack / unpack (device) -------------------------------------------------


def _bucket(spec: PackSpec, v: jax.Array) -> jax.Array:
    # Non-strictly monotone in v: sub/div/floor/clip all preserve order
    # under IEEE rounding, so b(v1) <= b(v2) whenever v1 <= v2.
    f = (v.astype(jnp.float32) - jnp.float32(spec.qmin)) / jnp.float32(spec.qscale)
    nb = (1 << spec.val_bits) - 1
    return jnp.clip(jnp.floor(f), 0, nb).astype(jnp.int32)


def _bucket_np(spec: PackSpec, v: np.ndarray) -> np.ndarray:
    f = (np.asarray(v, np.float32) - np.float32(spec.qmin)) / np.float32(spec.qscale)
    nb = (1 << spec.val_bits) - 1
    return np.clip(np.floor(f), 0, nb).astype(np.int32)


def pack(spec: PackSpec, v: jax.Array, i: jax.Array) -> jax.Array:
    """Encode values + indices into packed words (jnp).

    For ``quantized`` the word orders by (bucket, i) — callers own the
    bucket-tie fallback; the index field is still exact.
    """
    i = i.astype(jnp.int32)
    if spec.layout == "packed64":
        key = _key(v)
        return (key.astype(jnp.int64) << 32) | i.astype(jnp.int64)
    if spec.layout == "packed32":
        key = _key(v) - jnp.int32(spec.kmin)  # in [0, span]: no overflow by fit check
        return (key << spec.idx_bits) | i
    if spec.layout == "quantized":
        return (_bucket(spec, v) << spec.idx_bits) | i
    raise ValueError(f"cannot pack layout {spec.layout!r}")


def unpack_idx(spec: PackSpec, w: jax.Array) -> jax.Array:
    if spec.layout == "packed64":
        return (w & jnp.int64(0xFFFFFFFF)).astype(jnp.int32)
    return w & jnp.int32((1 << spec.idx_bits) - 1)


def unpack_val(spec: PackSpec, w: jax.Array) -> jax.Array:
    """Decode the value field. Exact for packed64/packed32.

    Quantized words only carry the bucket code — engines gather the exact
    value by ``unpack_idx`` instead; calling this on a quantized spec is a
    contract violation, not a lossy decode.
    """
    if spec.layout == "packed64":
        return _unkey((w >> 32).astype(jnp.int32), spec.dtype)
    if spec.layout == "packed32":
        # Words are non-negative, so >> is exact; pads decode to garbage
        # values but pads never win a min over a non-empty range.
        return _unkey((w >> spec.idx_bits) + jnp.int32(spec.kmin), spec.dtype)
    raise ValueError(f"unpack_val is undefined for layout {spec.layout!r}")


# --- pack / unpack (numpy twins, for the host update mirrors) ---------------


def pack_np(spec: PackSpec, v, i) -> np.ndarray:
    v = np.asarray(v, dtype=np.dtype(spec.dtype))
    i = np.asarray(i, np.int32)
    if spec.layout == "packed64":
        return (_key_np(v).astype(np.int64) << 32) | i.astype(np.int64)
    if spec.layout == "packed32":
        key = _key_np(v)
        if key.size and not (
            int(key.min()) >= spec.kmin
            and fits_packed32(spec.kmin, int(key.max()), spec.idx_bits)
        ):
            # A patch pushed a value outside the build-time key range: the
            # packed32 word cannot encode it. Callers catch this and fall
            # back to a structural rebuild with a fresh spec.
            raise OverflowError(
                f"value keys [{int(key.min())}, {int(key.max())}] exceed the "
                f"packed32 spec range (kmin={spec.kmin}, idx_bits={spec.idx_bits})"
            )
        return ((key - np.int32(spec.kmin)) << spec.idx_bits) | i
    if spec.layout == "quantized":
        return (_bucket_np(spec, v) << spec.idx_bits) | i
    raise ValueError(f"cannot pack layout {spec.layout!r}")


def unpack_idx_np(spec: PackSpec, w) -> np.ndarray:
    w = np.asarray(w)
    if spec.layout == "packed64":
        return (w & np.int64(0xFFFFFFFF)).astype(np.int32)
    return (w & np.int32((1 << spec.idx_bits) - 1)).astype(np.int32)


def unpack_val_np(spec: PackSpec, w) -> np.ndarray:
    w = np.asarray(w)
    if spec.layout == "packed64":
        return _unkey_np((w >> 32).astype(np.int32), spec.dtype)
    if spec.layout == "packed32":
        return _unkey_np((w >> spec.idx_bits) + np.int32(spec.kmin), spec.dtype)
    raise ValueError(f"unpack_val is undefined for layout {spec.layout!r}")
