"""Sparse-table (doubling) RMQ: O(n log n) build, O(1) batched query.

This is the level-2 structure of the blocked RMQ (DESIGN.md §2, Insight B):
RTXRMQ answers the fully-covered-blocks sub-query with a second RT geometry
over block minima; on TPU the natural O(1) analogue is the classic doubling
table — two gathers and a select per query, fully vectorized over the batch.

The table stores *indices* (int32), so queries answer argmin directly and the
leftmost-tie convention is preserved exactly (see ``_pick_left``).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["SparseTable", "build", "query"]


class SparseTable(NamedTuple):
    """Doubling table over ``x``. ``idx[k, i]`` = leftmost argmin of x[i : i+2^k]."""

    idx: jax.Array  # (K, n) int32
    x: jax.Array  # (n,) values the table indexes into


def _pick_left(x, a, b):
    """Leftmost-tie argmin merge: prefer ``a`` when values tie.

    Correct whenever, on ties, position ``a`` is guaranteed to be <= the
    leftmost min (holds for both the build windows and the query overlap —
    see the window-containment argument in DESIGN.md §2 note 4).
    """
    return jnp.where(x[a] <= x[b], a, b)


def build(x: jax.Array) -> SparseTable:
    """Build the doubling table. Python loop over K<=32 levels (n is static)."""
    n = x.shape[0]
    k_levels = max(1, (n - 1).bit_length() + 1) if n > 1 else 1
    cur = jnp.arange(n, dtype=jnp.int32)
    rows = [cur]
    for k in range(1, k_levels):
        h = 1 << (k - 1)
        if h >= n:
            rows.append(cur)
            continue
        shifted = jnp.concatenate([cur[h:], jnp.broadcast_to(cur[-1], (h,))])
        cur = _pick_left(x, cur, shifted)
        rows.append(cur)
    return SparseTable(idx=jnp.stack(rows), x=x)


def exact_log2(length: jax.Array) -> jax.Array:
    """floor(log2(length)) computed exactly for int32 length >= 1.

    float log2 alone can be off-by-one at powers of two; correct it with
    integer shifts so 2^k <= length < 2^(k+1) always holds.
    """
    k = jnp.floor(jnp.log2(length.astype(jnp.float32))).astype(jnp.int32)
    k = jnp.maximum(k, 0)
    k = jnp.where(jnp.left_shift(jnp.int32(1), k) > length, k - 1, k)
    k = jnp.where(jnp.left_shift(jnp.int32(1), k + 1) <= length, k + 1, k)
    return k


def query(table: SparseTable, l: jax.Array, r: jax.Array) -> jax.Array:
    """Batched O(1) query. Returns leftmost argmin indices (int32)."""
    l = l.astype(jnp.int32)
    r = r.astype(jnp.int32)
    length = r - l + 1
    k = exact_log2(length)
    a = table.idx[k, l]
    b = table.idx[k, r - jnp.left_shift(jnp.int32(1), k) + 1]
    return _pick_left(table.x, a, b)
