"""Sparse-table (doubling) RMQ: O(n log n) build, O(1) batched query.

This is the level-2 structure of the blocked RMQ (DESIGN.md §2, Insight B):
RTXRMQ answers the fully-covered-blocks sub-query with a second RT geometry
over block minima; on TPU the natural O(1) analogue is the classic doubling
table — two gathers and a select per query, fully vectorized over the batch.

The table stores *indices* (int32), so queries answer argmin directly and the
leftmost-tie convention is preserved exactly (see ``_pick_left``).
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from . import packing

__all__ = [
    "PackedSparseTable",
    "SparseTable",
    "build",
    "build_packed",
    "query",
    "query_packed",
]


class SparseTable(NamedTuple):
    """Doubling table over ``x``. ``idx[k, i]`` = leftmost argmin of x[i : i+2^k]."""

    idx: jax.Array  # (K, n) int32
    x: jax.Array  # (n,) values the table indexes into


def _pick_left(x, a, b):
    """Leftmost-tie argmin merge: prefer ``a`` when values tie.

    Correct whenever, on ties, position ``a`` is guaranteed to be <= the
    leftmost min (holds for both the build windows and the query overlap —
    see the window-containment argument in DESIGN.md §2 note 4).
    """
    return jnp.where(x[a] <= x[b], a, b)


def build(x: jax.Array) -> SparseTable:
    """Build the doubling table. Python loop over K<=32 levels (n is static)."""
    n = x.shape[0]
    k_levels = max(1, (n - 1).bit_length() + 1) if n > 1 else 1
    cur = jnp.arange(n, dtype=jnp.int32)
    rows = [cur]
    for k in range(1, k_levels):
        h = 1 << (k - 1)
        if h >= n:
            rows.append(cur)
            continue
        shifted = jnp.concatenate([cur[h:], jnp.broadcast_to(cur[-1], (h,))])
        cur = _pick_left(x, cur, shifted)
        rows.append(cur)
    return SparseTable(idx=jnp.stack(rows), x=x)


def exact_log2(length: jax.Array) -> jax.Array:
    """floor(log2(length)) computed exactly for int32 length >= 1.

    float log2 alone can be off-by-one at powers of two; correct it with
    integer shifts so 2^k <= length < 2^(k+1) always holds.
    """
    k = jnp.floor(jnp.log2(length.astype(jnp.float32))).astype(jnp.int32)
    k = jnp.maximum(k, 0)
    k = jnp.where(jnp.left_shift(jnp.int32(1), k) > length, k - 1, k)
    k = jnp.where(jnp.left_shift(jnp.int32(1), k + 1) <= length, k + 1, k)
    return k


def query(table: SparseTable, l: jax.Array, r: jax.Array) -> jax.Array:
    """Batched O(1) query. Returns leftmost argmin indices (int32)."""
    l = l.astype(jnp.int32)
    r = r.astype(jnp.int32)
    length = r - l + 1
    k = exact_log2(length)
    a = table.idx[k, l]
    b = table.idx[k, r - jnp.left_shift(jnp.int32(1), k) + 1]
    return _pick_left(table.x, a, b)


# --- packed variant ---------------------------------------------------------
#
# One word plane instead of idx + x: a query touches two table cells and is
# done — no value gathers, no select chain (DESIGN.md §13). For the
# quantized layout the word carries (bucket, exact-argmin-index); bucket
# ties fall back to an exact value compare against the retained ``x``.


class PackedSparseTable(NamedTuple):
    """Doubling table of packed words.

    ``words[k, i]`` encodes the leftmost argmin of ``x[i : i+2^k]`` as one
    ``(key << idx_bits) | index`` word (``core.packing``). ``x`` is kept
    only for the quantized layout's exact bucket-tie fallback (None for
    packed64/packed32 — exact decode needs no raw plane).
    """

    words: jax.Array  # (K, n) packed words
    x: Optional[jax.Array] = None  # (n,) raw values, quantized layouts only


def build_packed(x: jax.Array, spec=None, layout: str = "auto"):
    """Build the packed doubling table; returns ``(PackedSparseTable, spec)``.

    Exact layouts fold the doubling merge into ``jnp.minimum`` over words.
    The quantized layout first builds the exact index table (bucket codes
    cannot resolve in-bucket ties during construction) and then encodes
    each cell's exact argmin with its bucket.
    """
    n = x.shape[0]
    if spec is None:
        spec = packing.spec_for(x, n, layout)
    if spec.layout == "quantized":
        t = build(x)
        words = packing.pack(spec, x[t.idx], t.idx)
        return PackedSparseTable(words=words, x=x), spec
    k_levels = max(1, (n - 1).bit_length() + 1) if n > 1 else 1
    cur = packing.pack(spec, x, jnp.arange(n, dtype=jnp.int32))
    rows = [cur]
    for k in range(1, k_levels):
        h = 1 << (k - 1)
        if h >= n:
            rows.append(cur)
            continue
        shifted = jnp.concatenate([cur[h:], jnp.broadcast_to(cur[-1], (h,))])
        cur = jnp.minimum(cur, shifted)
        rows.append(cur)
    return PackedSparseTable(words=jnp.stack(rows)), spec


@partial(jax.jit, static_argnums=0)
def _query_packed_jit(spec, words, x, l, r):
    length = r - l + 1
    k = exact_log2(length)
    wa = words[k, l]
    wb = words[k, r - jnp.left_shift(jnp.int32(1), k) + 1]
    if spec.layout != "quantized":
        w = jnp.minimum(wa, wb)
        return packing.unpack_idx(spec, w), packing.unpack_val(spec, w)
    # Bucket-tie fallback: equal buckets gather both exact values; the
    # leftmost-tie argument of _pick_left carries over (window containment
    # gives ia <= ib on exact value ties).
    ia = packing.unpack_idx(spec, wa)
    ib = packing.unpack_idx(spec, wb)
    va = x[ia]
    vb = x[ib]
    collide = (wa >> spec.idx_bits) == (wb >> spec.idx_bits)
    take_a = jnp.where(collide, va <= vb, wa <= wb)
    return jnp.where(take_a, ia, ib), jnp.where(take_a, va, vb)


def query_packed(table: PackedSparseTable, spec, l: jax.Array, r: jax.Array):
    """Batched O(1) packed query -> ``(idx int32, val)``, exact leftmost ties."""
    return _query_packed_jit(
        spec, table.words, table.x, l.astype(jnp.int32), r.astype(jnp.int32)
    )
