"""Range-adaptive hybrid RMQ dispatcher (the paper's crossover, exploited).

RTXRMQ §6 (and GPU-RMQ independently) report a regime-dependent winner: the
blocked/RT-style structure is fastest for *small* query ranges, while the
O(1) table-lookup family (LCA / sparse table) overtakes it at medium/large
ranges. This engine exploits that crossover instead of living on one side of
it: a batch is partitioned by range length against a threshold, short ranges
go to the blocked path (pure-jnp ``block_rmq`` on CPU, the fused Pallas
megakernel ``kernels.ops`` on TPU), long ranges go to the pure sparse-table
path, and the two result sets are scattered back into the original batch
order. Results are bit-identical to ``block_rmq.query`` — every constituent
engine implements exact leftmost-tie semantics.

``calibrate`` measures both constituent engines at a few range lengths and
returns the measured crossover threshold; ``build`` takes it (or a default)
as a static attribute. The partition runs host-side (numpy) — query batches
arrive from the host in serving anyway, and a data-dependent partition under
``jit`` would force padded two-sided execution, which is exactly the waste
this engine removes. See DESIGN.md §5.
"""

from __future__ import annotations

import contextlib
import threading
import time
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import trace as obs_trace

from . import sparse_table
from .block_rmq import BlockRMQ

__all__ = [
    "HybridRMQ",
    "build",
    "query",
    "calibrate",
    "dispatch_by_length",
    "record_splits",
    "DEFAULT_THRESHOLD_FRAC",
]

# Fallback threshold when no calibration is run: the paper's small/medium
# boundary sits near n**0.5 for the sizes it sweeps; ranges shorter than
# sqrt(n) touch only a couple of blocks and favor the blocked path.
DEFAULT_THRESHOLD_FRAC = 0.5  # threshold = n ** DEFAULT_THRESHOLD_FRAC

_INT32_MAX = np.iinfo(np.int32).max


class HybridRMQ(NamedTuple):
    """Both constituent structures, routing threshold, jitted path closures."""

    blocked: BlockRMQ
    st: sparse_table.SparseTable  # doubling table over the raw array
    x: jax.Array  # raw values (answers value lookups for the long path)
    threshold: int  # range lengths <= threshold go to the blocked path
    use_kernels: bool  # short path: fused Pallas megakernel vs pure jnp
    short_fn: object  # jitted (l, r) -> (idx, val), structure closed over
    long_fn: object  # jitted (l, r) -> (idx, val)


def build(
    x: jax.Array,
    block_size: int = 128,
    *,
    threshold: int | str | None = None,
    use_kernels: bool | None = None,
    kernel_config=None,
    packed=None,
) -> HybridRMQ:
    """Build both constituent engines (via the staged ``core.build`` plan).

    ``threshold=None`` -> deterministic sqrt(n) default (never touches
    machine state); ``"cached"`` -> the persistent JSON cache
    (``calib_cache``) with the sqrt(n) fallback, never measuring;
    ``"calibrated"`` -> the cache, measuring via ``calibrate`` only on a
    miss, so repeated builds of the same configuration never re-measure.
    ``kernel_config`` is the megakernel launch-geometry policy for the
    kernelized short path (None | "cached" | "tuned" | a
    ``kernels.tuning.KernelConfig``), same cache lifecycle as thresholds.
    ``packed`` opts both tiers into fused (value, index) words
    (``core.packing``): None/False -> unpacked, True/"auto" -> measured
    best fit, or an explicit layout name.
    """
    from . import build as build_mod  # deferred: build.py hosts the planner

    return build_mod.build(
        "hybrid",
        x,
        block_size=block_size,
        threshold=threshold,
        use_kernels=use_kernels,
        kernel_config=kernel_config,
        packed=packed,
    )


# Per-thread sink for regime-split observations: the serving layer wraps each
# engine launch in ``record_splits`` so its stats can report how dispatch
# partitioned every coalesced batch without coupling the engines to the server.
_split_sink = threading.local()


@contextlib.contextmanager
def record_splits(cb):
    """Route this thread's ``dispatch_by_length`` splits to ``cb(n_short, n_long)``."""
    prev = getattr(_split_sink, "cb", None)
    _split_sink.cb = cb
    try:
        yield
    finally:
        _split_sink.cb = prev


def dispatch_by_length(l, r, threshold: int, short_fn, long_fn, out_dtype):
    """Range-adaptive dispatch core, shared by ``hybrid`` and ``sharded_hybrid``.

    Host-side partition of the batch by range length against ``threshold``,
    per-regime launches through ``short_fn`` / ``long_fn`` (each
    ``(l_jnp, r_jnp) -> (idx, val)``), ordered exact-leftmost scatter-back.
    Empty batches return empty ``(idx, val)`` without launching anything.

    Bounds must be integer arrays inside the int32 index range: every
    constituent engine computes int32 indices, so an out-of-range bound
    would wrap silently instead of failing loudly — checked here, the one
    query path both hybrids share.
    """
    l = np.asarray(l)
    r = np.asarray(r)
    if not (np.issubdtype(l.dtype, np.integer) and np.issubdtype(r.dtype, np.integer)):
        raise TypeError(f"query bounds must be integer arrays, got {l.dtype} / {r.dtype}")
    l = l.astype(np.int64)
    r = r.astype(np.int64)
    if l.size == 0:  # nothing to do: no phantom padded query, no launch
        return jnp.zeros(0, jnp.int32), jnp.zeros(0, out_dtype)
    if int(l.min()) < 0 or int(r.max()) > _INT32_MAX:
        raise ValueError(
            f"query bounds [{int(l.min())}, {int(r.max())}] outside the engines' "
            "int32 index range"
        )
    short = (r - l + 1) <= threshold
    cb = getattr(_split_sink, "cb", None)
    if cb is not None:
        cb(int(short.sum()), int(l.size - short.sum()))
    # Regime split onto the ambient trace span (the server's launch span
    # when tracing is on) — obs.set_attr is a no-op outside any span.
    if obs_trace.get_tracer().enabled:
        obs_trace.set_attr("split_short", int(short.sum()))
        obs_trace.set_attr("split_long", int(l.size - short.sum()))

    # Every launch pads its batch to a power of two so the jit cache stays
    # bounded (log2(B) shapes per path) however batch sizes and splits vary.
    def _launch(fn, lm, rm):
        k = lm.size
        kp = 1 << (k - 1).bit_length() if k > 1 else 1
        if kp != k:
            lp = np.zeros(kp, np.int64)
            rp = np.zeros(kp, np.int64)
            lp[:k] = lm
            rp[:k] = rm
            lm, rm = lp, rp
        qi, qv = fn(jnp.asarray(lm), jnp.asarray(rm))
        return qi, qv, k

    # Uniform batches skip the partition/scatter round-trip entirely.
    n_short = int(short.sum())
    if n_short == short.size or n_short == 0:
        qi, qv, k = _launch(short_fn if n_short else long_fn, l, r)
        return qi[:k], qv[:k]

    # Mixed batch: launch both sub-batches, then sync both — overlapping the
    # two engines' execution with a single wait.
    idx = np.empty(l.shape, np.int32)
    val = np.empty(l.shape, np.dtype(out_dtype))
    launched = []
    for mask, fn in ((short, short_fn), (~short, long_fn)):
        launched.append((mask, _launch(fn, l[mask], r[mask])))
    for mask, (qi, qv, k) in launched:
        idx[mask] = np.asarray(qi)[:k]
        val[mask] = np.asarray(qv)[:k]
    return jnp.asarray(idx), jnp.asarray(val)


def query(s: HybridRMQ, l, r) -> Tuple[jax.Array, jax.Array]:
    """Range-adaptive batched RMQ. Returns (leftmost argmin idx int32, value).

    Host-side partition by range length, per-engine sub-batches, ordered
    scatter-back. Bit-identical to ``block_rmq.query`` on the same batch.
    """
    return dispatch_by_length(l, r, s.threshold, s.short_fn, s.long_fn, s.x.dtype)


def _measure(kind: str, fn, lj, rj, repeats: int) -> float:
    """Median wall seconds of one jitted path (post-warmup).

    ``kind`` names the path ("short" / "long") purely so tests can swap this
    out for a deterministic fake and pin calibrate's control flow.
    """
    del kind
    fn(lj, rj)  # warmup / compile
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(lj, rj))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def calibrate(
    n: int,
    batch: int = 4096,
    *,
    block_size: int = 128,
    use_kernels: bool | None = None,
    seed: int = 0,
    repeats: int = 3,
    mesh=None,
    axis_names=None,
    mode: str = "shard_structure",
    layout: str | None = None,
) -> int:
    """Time both constituent paths across range lengths; return the crossover.

    Sweeps log-spaced range lengths, measures the per-call median of each
    path on a ``batch``-sized query load, and returns the largest swept
    length at which the short (blocked) path still wins — i.e. the value to
    pass as ``threshold`` given the ``len <= threshold -> short`` routing.
    Degenerate measurements stay honest: ``n`` when the short path wins
    everywhere, ``0`` (route everything long) when the long path wins even
    at length 1.

    With ``mesh`` (+ optional ``axis_names``/``mode``) the *sharded*
    constituents are measured — the sharded blocked path and the
    column-sharded doubling table in the given distribution mode — so the
    threshold reflects collective costs on that mesh, not single-host
    proxies. The cache key already carries ``ndev``; this makes the
    measurement match it.

    ``layout`` (cache key v3) measures the *packed* constituents instead —
    the crossover moves when both tiers read fused (value, index) word
    planes. packed32's key-range precondition is data-dependent, so that
    measurement runs over a narrow-range int32 proxy array (the layout it
    times is the layout served); the other layouts keep the float proxy.
    """
    rng = np.random.default_rng(seed)
    if layout == "packed32":
        # A proxy whose key span always fits 31 - idx_bits value bits.
        x = jnp.asarray(rng.integers(-1000, 1000, size=n).astype(np.int32))
    else:
        x = jnp.asarray(rng.random(n, dtype=np.float32))
    if mesh is None:
        s = build(x, block_size, use_kernels=use_kernels, packed=layout)
        short_fn, long_fn = s.short_fn, s.long_fn  # both already jit-wrapped
    else:
        # Deferred import: sharded_hybrid builds on this module's dispatcher.
        from . import sharded_hybrid

        sh = sharded_hybrid.build(
            x, mesh, axis_names, block_size, threshold=0, mode=mode, packed=layout
        )
        short_fn = lambda l, r: sh.short_fn(sh.blocked, l, r)
        long_fn = lambda l, r: sh.long_fn(sh.st, l, r)

    lengths = np.unique(
        np.geomspace(1, n, num=8).astype(np.int64).clip(1, n)
    )
    crossover = None
    prev_length = 0
    for length in lengths:
        lo = rng.integers(0, max(n - length + 1, 1), batch)
        lj = jnp.asarray(lo)
        rj = jnp.asarray(np.minimum(lo + length - 1, n - 1))

        if _measure("long", long_fn, lj, rj, repeats) < _measure(
            "short", short_fn, lj, rj, repeats
        ):
            # The long path wins at `length`; routing is `len <= threshold ->
            # short`, so the threshold is the last length where short won.
            crossover = int(prev_length)
            break
        prev_length = int(length)
    if crossover is None:
        crossover = prev_length  # short path won at every swept length (= n)
    return crossover  # 0 => route everything long (long won even at len 1)
