"""LCA baseline (paper §6.1 "LCA", Polak et al. style).

RMQ -> LCA reduction over the Cartesian tree: build the tree (nearest-smaller
stack, O(n), host-side numpy as a preprocessing stage, like the GPU baseline's
Euler-tour construction), take an Euler tour, and answer RMQ(l, r) as the
min-depth node between the first occurrences of l and r — a ±1-RMQ we serve
with the doubling table. Queries are fully batched/jit-able on device.
"""

from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import sparse_table

__all__ = ["LCARMQ", "build", "query"]


class LCARMQ(NamedTuple):
    euler_node: jax.Array  # (2n-1,) int32 node (=array index) per tour step
    first: jax.Array  # (n,) int32 first occurrence of each node in the tour
    st: sparse_table.SparseTable  # over tour depths


def _cartesian_tree(x: np.ndarray):
    """left/right children + root; strict '>' pops keep leftmost ties on top."""
    n = x.shape[0]
    left = np.full(n, -1, dtype=np.int64)
    right = np.full(n, -1, dtype=np.int64)
    stack: list[int] = []
    for i in range(n):
        last = -1
        while stack and x[stack[-1]] > x[i]:
            last = stack.pop()
        left[i] = last
        if stack:
            right[stack[-1]] = i
        stack.append(i)
    return left, right, stack[0]


def build(x) -> LCARMQ:
    x = np.asarray(x)
    n = x.shape[0]
    left, right, root = _cartesian_tree(x)

    tour_node = np.empty(2 * n - 1, dtype=np.int32)
    tour_depth = np.empty(2 * n - 1, dtype=np.int32)
    first = np.full(n, -1, dtype=np.int32)
    # Iterative Euler tour: re-record the parent after each child subtree.
    stack = [(int(root), 0, False)]
    pos = 0
    while stack:
        node, d, revisit = stack.pop()
        tour_node[pos] = node
        tour_depth[pos] = d
        if first[node] < 0:
            first[node] = pos
        pos += 1
        if not revisit:
            children = [c for c in (left[node], right[node]) if c >= 0]
            seq = []
            for c in children:
                seq.append(("v", int(c), d + 1))
                seq.append(("r", node, d))
            for op, nd, dd in reversed(seq):
                stack.append((nd, dd, op == "r"))
        # revisit entries carry no children (their subtrees were queued already)
    assert pos == 2 * n - 1, (pos, n)

    st = sparse_table.build(jnp.asarray(tour_depth))
    return LCARMQ(
        euler_node=jnp.asarray(tour_node),
        first=jnp.asarray(first),
        st=st,
    )


def query(s: LCARMQ, l: jax.Array, r: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Batched query. Returns leftmost argmin indices (int32)."""
    fl = s.first[l.astype(jnp.int32)]
    fr = s.first[r.astype(jnp.int32)]
    lo = jnp.minimum(fl, fr)
    hi = jnp.maximum(fl, fr)
    pos = sparse_table.query(s.st, lo, hi)
    return s.euler_node[pos]
