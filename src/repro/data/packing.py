"""RMQ-powered sequence packing — the paper's technique used *inside* the
training framework (DESIGN.md §3).

Greedy worst-fit packing of documents into fixed-length training sequences:
for each document, find the open bin with the **most remaining space** — a
range-MAX query, i.e. RMQ over negated free-space. Batched lookups run on
the blocked RMQ engine; the free-space array updates in place and the
structure is rebuilt every ``rebuild_every`` placements (the static-RMQ
amortization the paper's §7.iii "dynamic RMQ" future work would remove).
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import block_rmq

__all__ = ["pack_documents"]


def pack_documents(
    lengths: np.ndarray,
    seq_len: int,
    *,
    num_bins: int | None = None,
    block_size: int = 128,
    rebuild_every: int = 128,
) -> tuple[np.ndarray, np.ndarray]:
    """Pack documents (lengths) into bins of capacity seq_len.

    Returns (bin_assignment per doc, free space per bin). Documents longer
    than seq_len are truncated to seq_len (standard LM packing behavior).
    """
    lengths = np.minimum(np.asarray(lengths, np.int64), seq_len)
    order = np.argsort(-lengths)  # first-fit-decreasing order
    n = len(lengths)
    if num_bins is None:
        num_bins = max(1, int(np.ceil(lengths.sum() / seq_len * 1.3)))
    free = np.full(num_bins, seq_len, np.int64)
    assign = np.full(n, -1, np.int64)

    # RMQ over negated free space: argmin(-free) == argmax(free).
    structure = block_rmq.build(jnp.asarray(-free, jnp.int32), block_size)
    dirty = 0

    for d in order:
        need = lengths[d]
        idx, negv = block_rmq.query(
            structure, jnp.asarray([0]), jnp.asarray([num_bins - 1])
        )
        b = int(idx[0])
        # The structure may be stale (amortized rebuild); verify on the live
        # array and fall back to an exact scan when the hint no longer fits.
        if free[b] < need:
            b = int(np.argmax(free))
        if free[b] < need:  # all bins full: open fresh bins
            free = np.concatenate([free, np.full(num_bins, seq_len, np.int64)])
            num_bins *= 2
            b = int(np.argmax(free))
            structure = block_rmq.build(jnp.asarray(-free, jnp.int32), block_size)
            dirty = 0
        assign[d] = b
        free[b] -= need
        dirty += 1
        if dirty >= rebuild_every:
            structure = block_rmq.build(jnp.asarray(-free, jnp.int32), block_size)
            dirty = 0

    return assign, free
