"""Deterministic synthetic data pipeline.

Batches are a pure function of (seed, step), so a restarted job replays the
exact token stream — the property the fault-tolerance layer relies on for
bitwise-reproducible recovery (no data-loader state to checkpoint beyond the
step counter).
"""

from __future__ import annotations

from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["synthetic_batch", "batch_iterator", "synthetic_documents"]


def synthetic_batch(cfg, batch: int, seq_len: int, *, seed: int, step: int) -> dict:
    """{tokens|embeds, labels} for one step; stateless and replayable."""
    rng = np.random.default_rng(np.random.SeedSequence([seed, step]))
    labels = rng.integers(0, cfg.vocab_size, (batch, seq_len), dtype=np.int64)
    out = {"labels": jnp.asarray(labels, jnp.int32)}
    if cfg.embeds_input:
        emb = rng.standard_normal((batch, seq_len, cfg.d_model), dtype=np.float32)
        out["embeds"] = jnp.asarray(emb, cfg.dtype)
    else:
        tokens = rng.integers(0, cfg.vocab_size, (batch, seq_len), dtype=np.int64)
        out["tokens"] = jnp.asarray(tokens, jnp.int32)
    return out


def batch_iterator(cfg, batch: int, seq_len: int, *, seed: int, start_step: int = 0) -> Iterator[dict]:
    step = start_step
    while True:
        yield synthetic_batch(cfg, batch, seq_len, seed=seed, step=step)
        step += 1


def synthetic_documents(num_docs: int, max_len: int, *, seed: int) -> np.ndarray:
    """Document lengths with a heavy tail (log-normal), for the packer."""
    rng = np.random.default_rng(seed)
    lens = np.exp(rng.normal(np.log(max_len) - 1.5, 0.8, num_docs))
    return np.clip(lens, 1, max_len).astype(np.int64)
