"""repro.data — deterministic synthetic pipeline + RMQ-based sequence packing."""

from . import packing, pipeline
from .packing import pack_documents
from .pipeline import batch_iterator, synthetic_batch

__all__ = ["packing", "pipeline", "pack_documents", "batch_iterator", "synthetic_batch"]
