"""internvl2-1b [vlm] — 24L d_model=896 14H (GQA kv=2) d_ff=4864
vocab=151655 — InternViT + InternLM2/Qwen2 backbone. [arXiv:2404.16821; hf]

Backbone only: the InternViT frontend is a STUB; input_specs() provides
precomputed patch embeddings (repro.models.frontends)."""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b",
    family="vlm",
    num_layers=24,
    d_model=896,
    num_heads=14,
    num_kv_heads=2,
    d_ff=4864,
    vocab_size=151655,  # padded to 151808
    qkv_bias=True,
    tie_embeddings=True,
    embeds_input=True,
    attn_shard="seq",  # 14 heads don't divide the 16-wide model axis
)
