"""Config system: ModelConfig dataclass, input-shape registry, helpers.

Every assigned architecture gets a ``configs/<id>.py`` exporting CONFIG; the
registry in ``configs/__init__.py`` resolves ``--arch <id>``. Reduced smoke
variants are derived mechanically via ``reduce_for_smoke``.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp

__all__ = ["ModelConfig", "ShapeConfig", "SHAPES", "reduce_for_smoke", "pad_vocab"]


def pad_vocab(v: int, multiple: int = 256) -> int:
    return -(-v // multiple) * multiple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    scale_embed: bool = False
    # MoE
    num_experts: int = 0
    top_k: int = 0
    dense_residual: bool = False
    capacity_factor: float = 1.25
    # attention pattern
    sliding_window: int = 0  # 0 = full attention
    global_every: int = 0  # gemma3: every Nth layer is global
    # SSM (mamba2 / zamba2)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_chunk: int = 128
    # hybrid
    attn_every: int = 0  # zamba2: shared attn block after every N mamba layers
    # numerics / execution
    dtype: jnp.dtype = jnp.bfloat16
    param_dtype: jnp.dtype = jnp.bfloat16
    remat: bool = True
    # "nothing" = full recompute; "dots" = save matmul outputs (less
    # recompute + fewer backward weight all-gathers, more live memory)
    remat_policy: str = "nothing"
    attn_kv_chunk: int = 1024
    cache_pad: int = 0
    # cost-model mode: unroll scans so XLA cost_analysis counts every
    # iteration (it counts while-loop bodies ONCE — see launch/dryrun.py)
    unroll_layers: bool = False
    attn_unroll: bool = False
    ssm_unroll: bool = False
    # attention TP mode: "heads" (repeat KV, shard heads) or "seq"
    # (sequence-parallel Q; for head counts indivisible by the model axis)
    attn_shard: str = "heads"
    # parallelism policy: "2d" = FSDP(data) x TP(model) (+SP); "fsdp" = pure
    # FSDP over ALL mesh axes (no TP) — the right design point for dense
    # models whose per-device batch share stays >= 1 sequence (§Perf it. 6)
    parallelism: str = "2d"
    # mesh axis names injected by train/steps.py for sharding constraints
    mesh_dp: tuple = ()
    mesh_model: str = ""
    mesh_model_size: int = 0
    mesh_axis_sizes: tuple = ()  # ((axis, size), ...) injected with the mesh
    # sequence-parallel layer boundaries (Megatron-SP): scan-carry
    # activations shard their seq dim over the model axis
    seq_parallel: bool = True
    # whether the modality frontend is a stub fed with embeddings
    embeds_input: bool = False
    # documentation: why long_500k is runnable / skipped
    subquadratic: bool = False

    def __post_init__(self):
        if self.num_heads and not self.head_dim:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    @property
    def padded_vocab(self) -> int:
        return pad_vocab(self.vocab_size)

    def param_count(self) -> int:
        """Total parameters (for 6ND roofline math)."""
        d, f, v, l = self.d_model, self.d_ff, self.padded_vocab, self.num_layers
        n = v * d  # embed
        if not self.tie_embeddings:
            n += v * d
        if self.family == "ssm":
            n += l * self._ssm_layer_params()
            return n
        if self.family == "hybrid":
            n += l * self._ssm_layer_params()
            n += self._attn_layer_params() + self._ffn_params()  # one shared block
            return n
        n += l * (self._attn_layer_params() + self._ffn_params())
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k experts only)."""
        if not self.num_experts:
            return self.param_count()
        d, f, l = self.d_model, self.d_ff, self.num_layers
        total = self.param_count()
        expert_ffn = 3 * d * f
        inactive = l * (self.num_experts - self.top_k) * expert_ffn
        return total - inactive

    def _attn_layer_params(self) -> int:
        d, h, kv, hd = self.d_model, self.num_heads, self.num_kv_heads, self.head_dim
        return d * h * hd + 2 * d * kv * hd + h * hd * d + 2 * d

    def _ffn_params(self) -> int:
        d, f = self.d_model, self.d_ff
        if self.num_experts:
            n = self.num_experts * 3 * d * f + d * self.num_experts
            if self.dense_residual:
                n += 3 * d * f
            return n
        return 3 * d * f

    def _ssm_layer_params(self) -> int:
        from repro.models.ssm import ssm_dims

        dims = ssm_dims(self.d_model, self.ssm_expand, self.ssm_headdim, self.ssm_state, self.ssm_conv)
        return (
            self.d_model * dims["d_in_proj"]
            + dims["conv_k"] * dims["conv_dim"] + dims["conv_dim"]
            + 3 * dims["nheads"]
            + dims["d_inner"]
            + dims["d_inner"] * self.d_model
            + self.d_model
        )


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str  # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}


def reduce_for_smoke(cfg: ModelConfig) -> ModelConfig:
    """Tiny same-family variant for CPU smoke tests."""
    kw = dict(
        num_layers=max(2, (cfg.attn_every or 2)),
        d_model=128,
        d_ff=0 if cfg.family == "ssm" else 256,
        vocab_size=512,
        head_dim=32,
        remat=False,
        attn_kv_chunk=64,
        ssm_chunk=32,
        cache_pad=16,
        dtype=jnp.float32,
        param_dtype=jnp.float32,
    )
    if cfg.num_heads:
        kw["num_heads"] = 4
        kw["num_kv_heads"] = min(cfg.num_kv_heads, 2) if cfg.num_kv_heads < cfg.num_heads else 4
    if cfg.num_experts:
        kw["num_experts"] = 4
        kw["top_k"] = min(cfg.top_k, 2)
        # drop-free routing so decode-vs-full consistency is exact in tests
        kw["capacity_factor"] = 8.0
    if cfg.sliding_window:
        kw["sliding_window"] = 16
    if cfg.ssm_state:
        kw["ssm_state"] = 16
        kw["ssm_headdim"] = 16
        kw["ssm_expand"] = 2
    if cfg.attn_every:
        kw["attn_every"] = 2
        kw["num_layers"] = 4
    if cfg.global_every:
        kw["global_every"] = 2
    return dataclasses.replace(cfg, **kw)
