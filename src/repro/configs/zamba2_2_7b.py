"""zamba2-2.7b [hybrid] — 54L d_model=2560 32H (kv=32) d_ff=10240,
ssm_state=64 — Mamba2 backbone + shared attention blocks. [arXiv:2411.15242; hf]

Shared attention: ONE attention+FFN param set applied after every 6 Mamba2
layers (9 applications over 54 layers). Runs long_500k (hybrid/SSM)."""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    num_layers=54,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    d_ff=10240,
    vocab_size=32000,
    ssm_state=64,
    ssm_conv=4,
    ssm_expand=2,
    ssm_headdim=64,
    attn_every=6,
    tie_embeddings=True,
    subquadratic=True,
)
