"""gemma3-12b [dense] — 48L d_model=3840 16H (GQA kv=8) d_ff=15360
vocab=262144 — 5:1 local:global (window 1024), 128k context.
[hf:google/gemma-3-1b-pt; unverified]

Runs long_500k: predominantly sliding-window attention (DESIGN.md §5)."""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-12b",
    family="dense",
    num_layers=48,
    d_model=3840,
    num_heads=16,
    num_kv_heads=8,
    d_ff=15360,
    vocab_size=262144,
    head_dim=256,
    sliding_window=1024,
    global_every=6,  # every 6th layer global => 5:1 local:global
    rope_theta=1_000_000.0,
    scale_embed=True,
    tie_embeddings=True,
    subquadratic=True,
)
