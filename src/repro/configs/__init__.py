"""Architecture registry: ``get_config("<arch-id>")`` resolves --arch flags."""

from __future__ import annotations

import importlib

from .base import SHAPES, ModelConfig, ShapeConfig, reduce_for_smoke

_ARCHS = {
    "grok-1-314b": "grok_1_314b",
    "arctic-480b": "arctic_480b",
    "command-r-35b": "command_r_35b",
    "granite-3-8b": "granite_3_8b",
    "qwen2-1.5b": "qwen2_1_5b",
    "gemma3-12b": "gemma3_12b",
    "internvl2-1b": "internvl2_1b",
    "mamba2-2.7b": "mamba2_2_7b",
    "musicgen-large": "musicgen_large",
    "zamba2-2.7b": "zamba2_2_7b",
}

ARCH_IDS = tuple(_ARCHS)

# long_500k is only runnable for sub-quadratic archs (DESIGN.md §5); the
# skip set is derived from cfg.subquadratic so DESIGN and code cannot drift.


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _ARCHS:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_ARCHS)}")
    mod = importlib.import_module(f"repro.configs.{_ARCHS[arch_id]}")
    return mod.CONFIG


def cells(include_skipped: bool = False):
    """All (arch, shape) dry-run cells; long_500k filtered per applicability."""
    out = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            skipped = shape.name == "long_500k" and not cfg.subquadratic
            if skipped and not include_skipped:
                continue
            out.append((arch, shape.name, skipped))
    return out


__all__ = [
    "ARCH_IDS",
    "SHAPES",
    "ModelConfig",
    "ShapeConfig",
    "get_config",
    "reduce_for_smoke",
    "cells",
]
