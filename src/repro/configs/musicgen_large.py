"""musicgen-large [audio] — 48L d_model=2048 32H (kv=32) d_ff=8192
vocab=2048 — decoder-only over EnCodec tokens. [arXiv:2306.05284; hf]

Backbone only: the EnCodec frontend is a STUB; prefill input_specs() provides
precomputed frame embeddings; decode operates on EnCodec token ids."""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    family="audio",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=2048,
    embeds_input=True,
)
