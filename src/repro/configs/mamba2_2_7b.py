"""mamba2-2.7b [ssm] — 64L d_model=2560 (attn-free) vocab=50280,
ssm_state=128 — SSD (state-space duality). [arXiv:2405.21060; unverified]

Runs long_500k: O(1) state per token (sub-quadratic by construction)."""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    num_layers=64,
    d_model=2560,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50280,  # padded to 50432
    ssm_state=128,
    ssm_conv=4,
    ssm_expand=2,
    ssm_headdim=64,
    tie_embeddings=True,
    subquadratic=True,
)
