"""Copy-on-write version snapshots: MVCC for RMQ structures.

The consistency model of the online-update subsystem (the repo's first):

* Queries **pin** a version and are answered entirely against that version's
  structures — a snapshot. Mutation never blocks serving.
* An update **publishes** the next version atomically: after ``publish``
  returns, every new pin sees the new version; already-pinned queries keep
  their snapshot.
* Old versions **retire when drained**: once a superseded version's pin
  count reaches zero it is dropped from the store, releasing its structure
  arrays. Versions are copy-on-write at the array-leaf level: a publish
  installs fresh arrays for the leaves the patch rebuilt and never mutates
  a published one. (Because the doubling tables are single (K, n) arrays,
  a value change rebuilds most structure leaves today; chunking tables by
  row group for finer COW is a ROADMAP follow-up.)

Publish order is the consistency order: the server applies updates on a
single updater thread, so version ids are also the serialization of the
update stream. ``version_lag`` (current id minus a query's pinned id) is the
staleness metric the serving stats report.
"""

from __future__ import annotations

import threading
from typing import Any, Callable, Dict, NamedTuple, Optional

__all__ = ["RolloutTracker", "Version", "VersionStore"]


class Version(NamedTuple):
    """One immutable snapshot: engine state + the logical array length."""

    vid: int  # publish sequence number (0 = the initial build)
    state: Any  # engine state (registry conformance contract)
    n: int  # logical array length at this version
    # Host copy of the logical array at this version (None when the
    # publisher doesn't track one). The crash-safety layer relies on it: the
    # degraded pure-jnp fallback builds a correct engine for any pinned
    # version from it, and oracle verification replays against it.
    x_host: Any = None


class VersionStore:
    """Thread-safe pin/publish/retire over a chain of ``Version`` snapshots.

    ``first_vid`` seats the store mid-timeline: a restored engine's first
    publish reuses the version id the checkpoint recorded, so version ids
    stay continuous across a crash (a client's pinned-vid bookkeeping never
    sees the numbering restart).
    """

    def __init__(self, first_vid: int = 0):
        if first_vid < 0:
            raise ValueError(f"first_vid must be >= 0, got {first_vid}")
        self._lock = threading.Lock()
        self._versions: Dict[int, Version] = {}
        self._pins: Dict[int, int] = {}
        self._current = int(first_vid) - 1

    @property
    def current_vid(self) -> int:
        with self._lock:
            return self._current

    @property
    def current(self) -> Version:
        with self._lock:
            if self._current < 0:
                raise RuntimeError("no version published yet")
            return self._versions[self._current]

    def live_vids(self) -> tuple:
        """Version ids still held (current + any with outstanding pins)."""
        with self._lock:
            return tuple(sorted(self._versions))

    def publish(self, state, n: int, x_host=None) -> int:
        """Install ``state`` as the next version; returns its id.

        Atomic: pins taken after return see the new version. Superseded
        versions with no outstanding pins are retired immediately.
        """
        with self._lock:
            vid = self._current + 1
            self._versions[vid] = Version(vid, state, int(n), x_host)
            self._current = vid
            self._retire_locked()
            return vid

    def pin(self) -> Version:
        """Take a snapshot reference to the current version (refcounted)."""
        with self._lock:
            if self._current < 0:
                raise RuntimeError("pin() before the first publish")
            self._pins[self._current] = self._pins.get(self._current, 0) + 1
            return self._versions[self._current]

    def release(self, vid: int) -> None:
        """Drop one pin on ``vid``; retires it if superseded and drained."""
        with self._lock:
            left = self._pins.get(vid, 0) - 1
            if left < 0:
                raise ValueError(f"release() without a pin on version {vid}")
            if left:
                self._pins[vid] = left
            else:
                self._pins.pop(vid, None)
            self._retire_locked()

    def _retire_locked(self) -> None:
        for vid in [v for v in self._versions if v != self._current]:
            if not self._pins.get(vid):
                del self._versions[vid]


class RolloutTracker:
    """Min/max version-id tracking across a fleet of version stores.

    Each replica registers under a key and notes every version it publishes;
    the tracker maintains the fleet-wide min/max vid and implements the
    **bounded-lag rollout barrier**: ``wait_to_publish(vid)`` blocks a
    leader replica until publishing ``vid`` would keep the fleet spread
    (max vid minus min vid) within ``max_lag``. Crashed replicas must
    ``deregister`` so a dead store can never wedge the barrier; they
    re-``register`` at their restored vid when they rejoin.

    The front door shares the tracker's condition variable: ``wait_for``
    lets the router sleep until some replica reaches a session's min vid
    (read-your-writes) instead of spinning.
    """

    def __init__(self, max_lag: int = 1):
        if max_lag < 1:
            raise ValueError(f"max_lag must be >= 1, got {max_lag}")
        self.max_lag = int(max_lag)
        self._cv = threading.Condition(threading.Lock())
        self._vids: Dict[Any, int] = {}
        self._max_lag_seen = 0

    def register(self, key, vid: int) -> None:
        with self._cv:
            self._vids[key] = int(vid)
            self._record_spread_locked()
            self._cv.notify_all()

    def deregister(self, key) -> None:
        with self._cv:
            self._vids.pop(key, None)
            self._cv.notify_all()

    def note(self, key, vid: int) -> None:
        """Record that replica ``key`` now serves ``vid`` (monotonic)."""
        with self._cv:
            if key not in self._vids:
                return  # deregistered (crashed) mid-publish; rejoin re-seats
            if vid > self._vids[key]:
                self._vids[key] = int(vid)
            self._record_spread_locked()
            self._cv.notify_all()

    def _record_spread_locked(self) -> None:
        if self._vids:
            spread = max(self._vids.values()) - min(self._vids.values())
            if spread > self._max_lag_seen:
                self._max_lag_seen = spread

    @property
    def max_lag_seen(self) -> int:
        """Largest fleet spread ever observed (the measured version lag)."""
        with self._cv:
            return self._max_lag_seen

    def min_vid(self) -> int:
        with self._cv:
            return min(self._vids.values()) if self._vids else -1

    def max_vid(self) -> int:
        with self._cv:
            return max(self._vids.values()) if self._vids else -1

    def vids(self) -> Dict[Any, int]:
        with self._cv:
            return dict(self._vids)

    def wait_to_publish(self, vid: int, timeout: Optional[float] = None) -> bool:
        """Block until publishing ``vid`` keeps the fleet spread <= max_lag.

        Returns False on timeout. Deregistration of a trailing replica
        unblocks waiters (its vid no longer counts toward the minimum).
        """

        def ok() -> bool:
            if not self._vids:
                return True
            return vid - min(self._vids.values()) <= self.max_lag

        with self._cv:
            return self._cv.wait_for(ok, timeout)

    def wait_for(
        self, predicate: Callable[[Dict[Any, int]], bool], timeout: Optional[float] = None
    ) -> bool:
        """Block until ``predicate({key: vid})`` holds; False on timeout."""
        with self._cv:
            return self._cv.wait_for(lambda: predicate(dict(self._vids)), timeout)
