"""Copy-on-write version snapshots: MVCC for RMQ structures.

The consistency model of the online-update subsystem (the repo's first):

* Queries **pin** a version and are answered entirely against that version's
  structures — a snapshot. Mutation never blocks serving.
* An update **publishes** the next version atomically: after ``publish``
  returns, every new pin sees the new version; already-pinned queries keep
  their snapshot.
* Old versions **retire when drained**: once a superseded version's pin
  count reaches zero it is dropped from the store, releasing its structure
  arrays. Versions are copy-on-write at the array-leaf level: a publish
  installs fresh arrays for the leaves the patch rebuilt and never mutates
  a published one. (Because the doubling tables are single (K, n) arrays,
  a value change rebuilds most structure leaves today; chunking tables by
  row group for finer COW is a ROADMAP follow-up.)

Publish order is the consistency order: the server applies updates on a
single updater thread, so version ids are also the serialization of the
update stream. ``version_lag`` (current id minus a query's pinned id) is the
staleness metric the serving stats report.
"""

from __future__ import annotations

import threading
from typing import Any, Dict, NamedTuple

__all__ = ["Version", "VersionStore"]


class Version(NamedTuple):
    """One immutable snapshot: engine state + the logical array length."""

    vid: int  # publish sequence number (0 = the initial build)
    state: Any  # engine state (registry conformance contract)
    n: int  # logical array length at this version
    # Host copy of the logical array at this version (None when the
    # publisher doesn't track one). The crash-safety layer relies on it: the
    # degraded pure-jnp fallback builds a correct engine for any pinned
    # version from it, and oracle verification replays against it.
    x_host: Any = None


class VersionStore:
    """Thread-safe pin/publish/retire over a chain of ``Version`` snapshots.

    ``first_vid`` seats the store mid-timeline: a restored engine's first
    publish reuses the version id the checkpoint recorded, so version ids
    stay continuous across a crash (a client's pinned-vid bookkeeping never
    sees the numbering restart).
    """

    def __init__(self, first_vid: int = 0):
        if first_vid < 0:
            raise ValueError(f"first_vid must be >= 0, got {first_vid}")
        self._lock = threading.Lock()
        self._versions: Dict[int, Version] = {}
        self._pins: Dict[int, int] = {}
        self._current = int(first_vid) - 1

    @property
    def current_vid(self) -> int:
        with self._lock:
            return self._current

    @property
    def current(self) -> Version:
        with self._lock:
            if self._current < 0:
                raise RuntimeError("no version published yet")
            return self._versions[self._current]

    def live_vids(self) -> tuple:
        """Version ids still held (current + any with outstanding pins)."""
        with self._lock:
            return tuple(sorted(self._versions))

    def publish(self, state, n: int, x_host=None) -> int:
        """Install ``state`` as the next version; returns its id.

        Atomic: pins taken after return see the new version. Superseded
        versions with no outstanding pins are retired immediately.
        """
        with self._lock:
            vid = self._current + 1
            self._versions[vid] = Version(vid, state, int(n), x_host)
            self._current = vid
            self._retire_locked()
            return vid

    def pin(self) -> Version:
        """Take a snapshot reference to the current version (refcounted)."""
        with self._lock:
            if self._current < 0:
                raise RuntimeError("pin() before the first publish")
            self._pins[self._current] = self._pins.get(self._current, 0) + 1
            return self._versions[self._current]

    def release(self, vid: int) -> None:
        """Drop one pin on ``vid``; retires it if superseded and drained."""
        with self._lock:
            left = self._pins.get(vid, 0) - 1
            if left < 0:
                raise ValueError(f"release() without a pin on version {vid}")
            if left:
                self._pins[vid] = left
            else:
                self._pins.pop(vid, None)
            self._retire_locked()

    def _retire_locked(self) -> None:
        for vid in [v for v in self._versions if v != self._current]:
            if not self._pins.get(vid):
                del self._versions[vid]
