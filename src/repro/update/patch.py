"""Incremental recompute kernels: windowed structure patching on host mirrors.

The single-host engines keep a numpy **mirror** of their built structures
(materialized once from the device build, so the starting point is exactly
the built state). A coalesced ``DeltaBatch`` patches the mirror in place —
O(bs) block-min repair per touched block plus per-level doubling-table
recompute over only the affected column windows — and the engine publishes
the patched leaves as the next copy-on-write version.

Why host-side numpy: the structures contain **no arithmetic**, only
comparisons and leftmost argmins, so numpy patching is trivially
bit-identical to the jnp build (same IEEE comparisons, same leftmost-tie
argmin) — asserted leaf-for-leaf by tests/test_update.py. (NaN payloads are
out of scope, as everywhere else in the repo.)

Window math (the reason patching is cheap): a doubling-table entry
``idx[k, c]`` covers ``[c, c + 2^k)`` (reads clamped at the array end stay
inside it), so a write at position ``p`` can only change level-``k`` entries
with ``c in [p - 2^k + 1, p]``. Patching recomputes exactly those merged
windows per level, top-down from the patched level below — everything
outside is untouched and therefore already equal to a from-scratch rebuild.
A single point write costs ``sum_k min(2^k, n) ~ 2n`` entries against the
rebuild's ``n log n``. Appends extend the windows with the appended suffix
``[n_old, n_new)`` (which also re-resolves the old tail-clamped entries) and
grow new levels in full when ``n`` crosses a power of two.
"""

from __future__ import annotations

from typing import List, Optional, Tuple

import numpy as np

from .deltas import DeltaBatch

__all__ = [
    "BlockMirror",
    "STMirror",
    "k_levels",
    "level_windows",
    "np_maxval",
    "patch_doubling",
]


def np_maxval(dtype):
    """Numpy twin of ``block_rmq.maxval`` (pad identity for min)."""
    dtype = np.dtype(dtype)
    if np.issubdtype(dtype, np.floating):
        return dtype.type(np.inf)
    return np.iinfo(dtype).max


def k_levels(m: int) -> int:
    """Doubling-table depth for length ``m`` (matches ``sparse_table.build``)."""
    return max(1, (m - 1).bit_length() + 1) if m > 1 else 1


def level_windows(touched: np.ndarray, w: int, m: int) -> List[Tuple[int, int]]:
    """Merged inclusive windows ``[p - w, p]`` over sorted positions, clipped.

    The affected-column ranges for one table level: windows of adjacent
    touched positions merge, so scattered points stay scattered (two distant
    writes patch two small windows, not their hull).
    """
    out: List[Tuple[int, int]] = []
    for p in touched:
        p = int(p)
        if p >= m:
            p = m - 1  # clamped reads: the last column covers the overhang
        a = max(p - w, 0)
        if out and a <= out[-1][1] + 1:
            out[-1] = (out[-1][0], max(out[-1][1], p))
        else:
            out.append((a, p))
    return out


def patch_doubling(
    idx: np.ndarray,
    values: np.ndarray,
    touched: np.ndarray,
    m_old: int,
    windows: Optional[List[Tuple[int, int, int]]] = None,
) -> np.ndarray:
    """Windowed per-level repair of a doubling table's index rows.

    ``idx`` is the (K_old, m_old) table over the OLD values; ``values`` is
    the already-mutated (m_new,) value array; ``touched`` lists the sorted
    positions whose value changed (appends contribute ``[m_old, m_new)``).
    Returns the patched (K_new, m_new) table — the same array patched in
    place when the length is unchanged, a grown copy otherwise. Bit-identical
    to ``sparse_table.build(values)``'s ``idx``.

    ``windows`` (optional out-param) collects every recomputed cell range as
    ``(k, a, b)`` inclusive column windows — the windowed-COW publish
    (``update.engines``) uploads exactly these to the device instead of the
    whole table. Rows that repeat the level below (``h >= m_new``) report
    the sub-window where the level below changed.
    """
    m_new = int(values.shape[0])
    k_old = idx.shape[0]
    k_new = k_levels(m_new)
    if m_new != m_old or k_new != k_old:
        grown = np.empty((k_new, m_new), np.int32)
        grown[:k_old, :m_old] = idx
        grown[0, m_old:] = np.arange(m_old, m_new, dtype=np.int32)
        idx = grown
    touched = np.asarray(touched, np.int64)
    if touched.size == 0:
        return idx
    for k in range(1, k_new):
        h = 1 << (k - 1)
        if h >= m_new:  # window spans the whole array: rows repeat
            idx[k] = idx[k - 1]
            if windows is not None:
                # The repeated row differs from its old self only where the
                # level below changed: entries at c > max(touched) cover no
                # touched position, so [0, clamp(max touched)] suffices.
                windows.append((k, 0, min(int(touched[-1]), m_new - 1)))
            continue
        # New levels (n crossed a power of two) have no old row: full window.
        wins = (
            [(0, m_new - 1)]
            if k >= k_old
            else level_windows(touched, (1 << k) - 1, m_new)
        )
        if windows is not None:
            windows.extend((k, a, b) for a, b in wins)
        prev = idx[k - 1]
        for a, b in wins:
            c = np.arange(a, b + 1, dtype=np.int64)
            j = np.minimum(c + h, m_new - 1)  # build's tail clamp (cur[-1])
            left = prev[a : b + 1]
            right = prev[j]
            # Leftmost-tie merge: prefer the unshifted (left) operand.
            idx[k, a : b + 1] = np.where(values[left] <= values[right], left, right)
    return idx


class STMirror:
    """Host mirror of a raw-array ``SparseTable`` (idx rows + values).

    After each ``patch``, ``last_idx_windows`` / ``last_x_windows`` describe
    which device cells a windowed-COW publish must refresh: per-level
    ``(k, a, b)`` table windows and merged ``(a, b)`` value windows. ``None``
    means the leaf shapes changed (the array grew) and the publish must
    re-upload in full.
    """

    def __init__(self, idx: np.ndarray, x: np.ndarray):
        self.idx = np.array(idx, np.int32)  # writable copy
        self.x = np.array(x)
        self.last_idx_windows: Optional[List[Tuple[int, int, int]]] = None
        self.last_x_windows: Optional[List[Tuple[int, int]]] = None

    @classmethod
    def from_state(cls, table) -> "STMirror":
        return cls(np.asarray(table.idx), np.asarray(table.x))

    def patch(self, batch: DeltaBatch) -> None:
        if batch.n_old != self.x.shape[0]:
            raise ValueError(
                f"batch for n={batch.n_old} on mirror of n={self.x.shape[0]}"
            )
        if batch.tail.size:
            self.x = np.concatenate([self.x, batch.tail.astype(self.x.dtype)])
        self.x[batch.idx] = batch.val.astype(self.x.dtype)
        grew = batch.tail.size > 0
        wins: List[Tuple[int, int, int]] = []
        self.idx = patch_doubling(
            self.idx, self.x, batch.touched(), batch.n_old, windows=wins
        )
        self.last_idx_windows = None if grew else wins
        self.last_x_windows = (
            None if grew else level_windows(batch.idx, 0, self.x.shape[0])
        )


class BlockMirror:
    """Host mirror of a ``BlockRMQ``: padded blocks, block minima, level-2 table.

    ``patch`` is the O(bs)-per-touched-block repair: scatter the new values,
    re-argmin only the touched blocks, then window-patch the doubling table
    over the block-min array (whose "positions" are block ids).
    """

    def __init__(self, x_blocks, bmin_val, bmin_gidx, st_idx, n: int):
        self.x_blocks = np.array(x_blocks)
        self.bmin_val = np.array(bmin_val)
        self.bmin_gidx = np.array(bmin_gidx, np.int32)
        self.st_idx = np.array(st_idx, np.int32)
        self.n = int(n)  # logical (pre-padding) length
        # Windowed-COW publish hints (see STMirror): merged runs of touched
        # block rows + the block-level table's (k, a, b) windows; None when
        # the block count grew (full re-upload). Appends *within* the padded
        # capacity keep every leaf shape, so they stay windowed.
        self.last_block_runs: Optional[List[Tuple[int, int]]] = None
        self.last_st_windows: Optional[List[Tuple[int, int, int]]] = None

    @property
    def block_size(self) -> int:
        return self.x_blocks.shape[1]

    @classmethod
    def from_state(cls, s, n: int) -> "BlockMirror":
        return cls(
            np.asarray(s.x_blocks),
            np.asarray(s.bmin_val),
            np.asarray(s.bmin_gidx),
            np.asarray(s.st.idx),
            n,
        )

    def patch(self, batch: DeltaBatch) -> None:
        if batch.n_old != self.n:
            raise ValueError(f"batch for n={batch.n_old} on mirror of n={self.n}")
        bs = self.block_size
        nb_old = self.x_blocks.shape[0]
        nb_new = -(-max(batch.n_new, 1) // bs)
        if nb_new > nb_old:  # appends grew past the padded capacity: new blocks
            big = np_maxval(self.x_blocks.dtype)
            dt = self.x_blocks.dtype
            self.x_blocks = np.concatenate(
                [self.x_blocks, np.full((nb_new - nb_old, bs), big, dt)]
            )
            self.bmin_val = np.concatenate(
                [self.bmin_val, np.full(nb_new - nb_old, big, dt)]
            )
            self.bmin_gidx = np.concatenate(
                [self.bmin_gidx, np.zeros(nb_new - nb_old, np.int32)]
            )
        pos = batch.touched()
        vals = np.concatenate([batch.val, batch.tail]).astype(self.x_blocks.dtype)
        self.x_blocks.reshape(-1)[pos] = vals
        # O(bs) block-min repair, vectorized over the touched blocks only.
        tb = np.unique(pos // bs)
        rows = self.x_blocks[tb]
        lidx = np.argmin(rows, axis=1).astype(np.int32)  # leftmost, as jnp
        self.bmin_val[tb] = rows[np.arange(tb.size), lidx]
        self.bmin_gidx[tb] = (tb * bs).astype(np.int32) + lidx
        wins: List[Tuple[int, int, int]] = []
        self.st_idx = patch_doubling(self.st_idx, self.bmin_val, tb, nb_old, windows=wins)
        grew = nb_new > nb_old
        self.last_block_runs = None if grew else level_windows(tb, 0, nb_new)
        self.last_st_windows = None if grew else wins
        self.n = batch.n_new
