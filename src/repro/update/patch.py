"""Incremental recompute kernels: windowed structure patching on host mirrors.

The single-host engines keep a numpy **mirror** of their built structures
(materialized once from the device build, so the starting point is exactly
the built state). A coalesced ``DeltaBatch`` patches the mirror in place —
O(bs) block-min repair per touched block plus per-level doubling-table
recompute over only the affected column windows — and the engine publishes
the patched leaves as the next copy-on-write version.

Why host-side numpy: the structures contain **no arithmetic**, only
comparisons and leftmost argmins, so numpy patching is trivially
bit-identical to the jnp build (same IEEE comparisons, same leftmost-tie
argmin) — asserted leaf-for-leaf by tests/test_update.py. (NaN payloads are
out of scope, as everywhere else in the repo.)

Window math (the reason patching is cheap): a doubling-table entry
``idx[k, c]`` covers ``[c, c + 2^k)`` (reads clamped at the array end stay
inside it), so a write at position ``p`` can only change level-``k`` entries
with ``c in [p - 2^k + 1, p]``. Patching recomputes exactly those merged
windows per level, top-down from the patched level below — everything
outside is untouched and therefore already equal to a from-scratch rebuild.
A single point write costs ``sum_k min(2^k, n) ~ 2n`` entries against the
rebuild's ``n log n``. Appends extend the windows with the appended suffix
``[n_old, n_new)`` (which also re-resolves the old tail-clamped entries) and
grow new levels in full when ``n`` crosses a power of two.
"""

from __future__ import annotations

from typing import List, Optional, Tuple

import numpy as np

from repro.core import packing

from .deltas import DeltaBatch

__all__ = [
    "BlockMirror",
    "PackedBlockMirror",
    "PackedSTMirror",
    "STMirror",
    "k_levels",
    "level_windows",
    "np_maxval",
    "packed_fit_check",
    "patch_doubling",
]


def np_maxval(dtype):
    """Numpy twin of ``block_rmq.maxval`` (pad identity for min)."""
    dtype = np.dtype(dtype)
    if np.issubdtype(dtype, np.floating):
        return dtype.type(np.inf)
    return np.iinfo(dtype).max


def k_levels(m: int) -> int:
    """Doubling-table depth for length ``m`` (matches ``sparse_table.build``)."""
    return max(1, (m - 1).bit_length() + 1) if m > 1 else 1


def level_windows(touched: np.ndarray, w: int, m: int) -> List[Tuple[int, int]]:
    """Merged inclusive windows ``[p - w, p]`` over sorted positions, clipped.

    The affected-column ranges for one table level: windows of adjacent
    touched positions merge, so scattered points stay scattered (two distant
    writes patch two small windows, not their hull).
    """
    out: List[Tuple[int, int]] = []
    for p in touched:
        p = int(p)
        if p >= m:
            p = m - 1  # clamped reads: the last column covers the overhang
        a = max(p - w, 0)
        if out and a <= out[-1][1] + 1:
            out[-1] = (out[-1][0], max(out[-1][1], p))
        else:
            out.append((a, p))
    return out


def patch_doubling(
    idx: np.ndarray,
    values: np.ndarray,
    touched: np.ndarray,
    m_old: int,
    windows: Optional[List[Tuple[int, int, int]]] = None,
) -> np.ndarray:
    """Windowed per-level repair of a doubling table's index rows.

    ``idx`` is the (K_old, m_old) table over the OLD values; ``values`` is
    the already-mutated (m_new,) value array; ``touched`` lists the sorted
    positions whose value changed (appends contribute ``[m_old, m_new)``).
    Returns the patched (K_new, m_new) table — the same array patched in
    place when the length is unchanged, a grown copy otherwise. Bit-identical
    to ``sparse_table.build(values)``'s ``idx``.

    ``windows`` (optional out-param) collects every recomputed cell range as
    ``(k, a, b)`` inclusive column windows — the windowed-COW publish
    (``update.engines``) uploads exactly these to the device instead of the
    whole table. Rows that repeat the level below (``h >= m_new``) report
    the sub-window where the level below changed.
    """
    m_new = int(values.shape[0])
    k_old = idx.shape[0]
    k_new = k_levels(m_new)
    if m_new != m_old or k_new != k_old:
        grown = np.empty((k_new, m_new), np.int32)
        grown[:k_old, :m_old] = idx
        grown[0, m_old:] = np.arange(m_old, m_new, dtype=np.int32)
        idx = grown
    touched = np.asarray(touched, np.int64)
    if touched.size == 0:
        return idx
    for k in range(1, k_new):
        h = 1 << (k - 1)
        if h >= m_new:  # window spans the whole array: rows repeat
            idx[k] = idx[k - 1]
            if windows is not None:
                # The repeated row differs from its old self only where the
                # level below changed: entries at c > max(touched) cover no
                # touched position, so [0, clamp(max touched)] suffices.
                windows.append((k, 0, min(int(touched[-1]), m_new - 1)))
            continue
        # New levels (n crossed a power of two) have no old row: full window.
        wins = (
            [(0, m_new - 1)]
            if k >= k_old
            else level_windows(touched, (1 << k) - 1, m_new)
        )
        if windows is not None:
            windows.extend((k, a, b) for a, b in wins)
        prev = idx[k - 1]
        for a, b in wins:
            c = np.arange(a, b + 1, dtype=np.int64)
            j = np.minimum(c + h, m_new - 1)  # build's tail clamp (cur[-1])
            left = prev[a : b + 1]
            right = prev[j]
            # Leftmost-tie merge: prefer the unshifted (left) operand.
            idx[k, a : b + 1] = np.where(values[left] <= values[right], left, right)
    return idx


class STMirror:
    """Host mirror of a raw-array ``SparseTable`` (idx rows + values).

    After each ``patch``, ``last_idx_windows`` / ``last_x_windows`` describe
    which device cells a windowed-COW publish must refresh: per-level
    ``(k, a, b)`` table windows and merged ``(a, b)`` value windows. ``None``
    means the leaf shapes changed (the array grew) and the publish must
    re-upload in full.
    """

    def __init__(self, idx: np.ndarray, x: np.ndarray):
        self.idx = np.array(idx, np.int32)  # writable copy
        self.x = np.array(x)
        self.last_idx_windows: Optional[List[Tuple[int, int, int]]] = None
        self.last_x_windows: Optional[List[Tuple[int, int]]] = None

    @classmethod
    def from_state(cls, table) -> "STMirror":
        return cls(np.asarray(table.idx), np.asarray(table.x))

    def patch(self, batch: DeltaBatch) -> None:
        if batch.n_old != self.x.shape[0]:
            raise ValueError(
                f"batch for n={batch.n_old} on mirror of n={self.x.shape[0]}"
            )
        if batch.tail.size:
            self.x = np.concatenate([self.x, batch.tail.astype(self.x.dtype)])
        self.x[batch.idx] = batch.val.astype(self.x.dtype)
        grew = batch.tail.size > 0
        wins: List[Tuple[int, int, int]] = []
        self.idx = patch_doubling(
            self.idx, self.x, batch.touched(), batch.n_old, windows=wins
        )
        self.last_idx_windows = None if grew else wins
        self.last_x_windows = (
            None if grew else level_windows(batch.idx, 0, self.x.shape[0])
        )


class BlockMirror:
    """Host mirror of a ``BlockRMQ``: padded blocks, block minima, level-2 table.

    ``patch`` is the O(bs)-per-touched-block repair: scatter the new values,
    re-argmin only the touched blocks, then window-patch the doubling table
    over the block-min array (whose "positions" are block ids).
    """

    def __init__(self, x_blocks, bmin_val, bmin_gidx, st_idx, n: int):
        self.x_blocks = np.array(x_blocks)
        self.bmin_val = np.array(bmin_val)
        self.bmin_gidx = np.array(bmin_gidx, np.int32)
        self.st_idx = np.array(st_idx, np.int32)
        self.n = int(n)  # logical (pre-padding) length
        # Windowed-COW publish hints (see STMirror): merged runs of touched
        # block rows + the block-level table's (k, a, b) windows; None when
        # the block count grew (full re-upload). Appends *within* the padded
        # capacity keep every leaf shape, so they stay windowed.
        self.last_block_runs: Optional[List[Tuple[int, int]]] = None
        self.last_st_windows: Optional[List[Tuple[int, int, int]]] = None

    @property
    def block_size(self) -> int:
        return self.x_blocks.shape[1]

    @classmethod
    def from_state(cls, s, n: int) -> "BlockMirror":
        return cls(
            np.asarray(s.x_blocks),
            np.asarray(s.bmin_val),
            np.asarray(s.bmin_gidx),
            np.asarray(s.st.idx),
            n,
        )

    def patch(self, batch: DeltaBatch) -> None:
        if batch.n_old != self.n:
            raise ValueError(f"batch for n={batch.n_old} on mirror of n={self.n}")
        bs = self.block_size
        nb_old = self.x_blocks.shape[0]
        nb_new = -(-max(batch.n_new, 1) // bs)
        if nb_new > nb_old:  # appends grew past the padded capacity: new blocks
            big = np_maxval(self.x_blocks.dtype)
            dt = self.x_blocks.dtype
            self.x_blocks = np.concatenate(
                [self.x_blocks, np.full((nb_new - nb_old, bs), big, dt)]
            )
            self.bmin_val = np.concatenate(
                [self.bmin_val, np.full(nb_new - nb_old, big, dt)]
            )
            self.bmin_gidx = np.concatenate(
                [self.bmin_gidx, np.zeros(nb_new - nb_old, np.int32)]
            )
        pos = batch.touched()
        vals = np.concatenate([batch.val, batch.tail]).astype(self.x_blocks.dtype)
        self.x_blocks.reshape(-1)[pos] = vals
        # O(bs) block-min repair, vectorized over the touched blocks only.
        tb = np.unique(pos // bs)
        rows = self.x_blocks[tb]
        lidx = np.argmin(rows, axis=1).astype(np.int32)  # leftmost, as jnp
        self.bmin_val[tb] = rows[np.arange(tb.size), lidx]
        self.bmin_gidx[tb] = (tb * bs).astype(np.int32) + lidx
        wins: List[Tuple[int, int, int]] = []
        self.st_idx = patch_doubling(self.st_idx, self.bmin_val, tb, nb_old, windows=wins)
        grew = nb_new > nb_old
        self.last_block_runs = None if grew else level_windows(tb, 0, nb_new)
        self.last_st_windows = None if grew else wins
        self.n = batch.n_new


# --- packed mirrors ----------------------------------------------------------
#
# The packed structures' index fields are exact in every layout, so the
# packed mirrors delegate the windowed repair to the raw mirrors above and
# then REPACK words over exactly the recomputed windows. Bit-identity with a
# from-scratch ``build_packed`` follows from the order isomorphism: the
# word-min doubling picks the same leftmost argmin the exact index doubling
# does, so ``pack(x[idx[k, c]], idx[k, c])`` IS the word the build computes.


def packed_fit_check(spec, values: np.ndarray, n_new: int) -> None:
    """Raise ``OverflowError`` when a delta batch cannot encode under ``spec``.

    Called BEFORE any mirror mutation, so an infeasible batch (a packed32
    value outside the build-time key range, or an append pushing the index
    domain past ``idx_bits``) leaves the mirrors untouched and the caller
    falls back to a structural rebuild with a fresh spec. packed64 always
    fits (32-bit key + 32-bit index); quantized values clamp to the edge
    buckets (weakly monotone, resolved by the exact fallback) so only its
    index domain can overflow.
    """
    if spec.layout != "packed64" and packing.idx_bits_for(max(n_new, 1)) > spec.idx_bits:
        raise OverflowError(
            f"appends grew the index domain to {n_new}, past the "
            f"{spec.idx_bits}-bit index field"
        )
    if spec.layout == "packed32" and values.size:
        packing.pack_np(
            spec,
            np.asarray(values, np.dtype(spec.dtype)),
            np.zeros(values.size, np.int32),
        )


class PackedSTMirror:
    """Host mirror of a ``PackedSparseTable``: exact raw mirror + word plane.

    Wraps an ``STMirror`` (the exact index/value repair, with its window
    collection) and repacks ``words`` over only the recomputed cells.
    ``last_word_windows`` lists the repacked ``(k, a, b)`` windows for the
    windowed-COW publish (``None`` -> shapes changed, full re-upload);
    ``last_x_windows`` mirrors the raw value windows for the quantized
    layout's retained ``x`` leaf.
    """

    def __init__(self, words: np.ndarray, x: np.ndarray, spec):
        self.spec = spec
        self.words = np.array(words)
        self.inner = STMirror(packing.unpack_idx_np(spec, np.asarray(words)), x)
        self.last_word_windows: Optional[List[Tuple[int, int, int]]] = None
        self.last_x_windows: Optional[List[Tuple[int, int]]] = None

    @property
    def x(self) -> np.ndarray:
        return self.inner.x

    @classmethod
    def from_state(cls, table, x, spec) -> "PackedSTMirror":
        """``table`` is the built ``PackedSparseTable``; ``x`` the raw host
        values (the quantized table retains them; exact layouts pass the
        engine's value mirror)."""
        return cls(np.asarray(table.words), np.array(x), spec)

    def _repack(self, k: int, a: int, b: int) -> None:
        ii = self.inner.idx[k, a : b + 1]
        self.words[k, a : b + 1] = packing.pack_np(self.spec, self.inner.x[ii], ii)

    def patch(self, batch: DeltaBatch) -> None:
        self.inner.patch(batch)
        if self.inner.last_idx_windows is None:  # grew: shapes changed
            idx = self.inner.idx
            self.words = packing.pack_np(self.spec, self.inner.x[idx], idx)
            self.last_word_windows = None
            self.last_x_windows = None
            return
        # Level 0 is the packed value row itself: every changed value
        # re-encodes, even where the (identity) index row did not move.
        wins = [(0, a, b) for a, b in self.inner.last_x_windows]
        wins.extend(self.inner.last_idx_windows)
        for k, a, b in wins:
            self._repack(k, a, b)
        self.last_word_windows = wins
        self.last_x_windows = self.inner.last_x_windows


class PackedBlockMirror:
    """Host mirror of a ``PackedBlockRMQ``: raw ``BlockMirror`` + word planes.

    The raw mirrors are derived from the built packed state (exact decode:
    the word planes' index fields are exact, and level 0 of ``stw`` carries
    every per-block leftmost minimum). ``block_words`` is ``None`` for the
    quantized layout — its first tier stays raw and ``inner.x_blocks`` is
    the publishable leaf itself.
    """

    def __init__(self, blocks: np.ndarray, stw: np.ndarray, spec, n: int):
        self.spec = spec
        self.stw_words = np.array(stw)
        dtype = np.dtype(spec.dtype)
        if spec.layout == "quantized":
            self.block_words: Optional[np.ndarray] = None
            x_blocks = np.array(blocks)
        else:
            wb = np.asarray(blocks)
            self.block_words = np.array(wb)
            x_blocks = np.where(
                wb == packing.pad_word(spec),
                np_maxval(dtype),
                packing.unpack_val_np(spec, wb),
            ).astype(dtype)
        bs = x_blocks.shape[1]
        bmin_gidx = packing.unpack_idx_np(spec, self.stw_words[0])
        bmin_val = x_blocks.reshape(-1)[bmin_gidx]
        # stw index fields are *global element* indices in every layout; the
        # block id they live in is the exact block-level argmin (word-min
        # ties resolve to the smaller global index = the leftmost block).
        st_idx = packing.unpack_idx_np(spec, self.stw_words) // bs
        self.inner = BlockMirror(x_blocks, bmin_val, bmin_gidx, st_idx, n)
        self.last_block_runs: Optional[List[Tuple[int, int]]] = None
        self.last_st_windows: Optional[List[Tuple[int, int, int]]] = None

    @classmethod
    def from_state(cls, s, spec, n: int) -> "PackedBlockMirror":
        return cls(np.asarray(s.blocks), np.asarray(s.stw), spec, n)

    def _repack_block_rows(self, a: int, b: int) -> None:
        inner = self.inner
        bs = inner.block_size
        rows = inner.x_blocks[a : b + 1]
        gidx = (
            np.arange(a, b + 1, dtype=np.int64)[:, None] * bs
            + np.arange(bs, dtype=np.int64)[None, :]
        )
        flat_v = rows.reshape(-1)
        flat_i = gidx.reshape(-1)
        valid = flat_i < inner.n
        words = np.full(
            flat_v.shape, packing.pad_word(self.spec), packing.word_dtype_np(self.spec)
        )
        words[valid] = packing.pack_np(
            self.spec, flat_v[valid], flat_i[valid].astype(np.int32)
        )
        self.block_words[a : b + 1] = words.reshape(rows.shape)

    def _repack_stw(self, k: int, a: int, b: int) -> None:
        inner = self.inner
        blk = inner.st_idx[k, a : b + 1]
        self.stw_words[k, a : b + 1] = packing.pack_np(
            self.spec, inner.bmin_val[blk], inner.bmin_gidx[blk]
        )

    def patch(self, batch: DeltaBatch) -> None:
        inner = self.inner
        inner.patch(batch)
        if inner.last_block_runs is None:  # block count grew: shapes changed
            nb = inner.x_blocks.shape[0]
            if self.block_words is not None:
                self.block_words = np.empty(
                    inner.x_blocks.shape, packing.word_dtype_np(self.spec)
                )
                self._repack_block_rows(0, nb - 1)
            self.stw_words = packing.pack_np(
                self.spec,
                inner.bmin_val[inner.st_idx],
                inner.bmin_gidx[inner.st_idx],
            )
            self.last_block_runs = None
            self.last_st_windows = None
            return
        if self.block_words is not None:
            for a, b in inner.last_block_runs:
                self._repack_block_rows(a, b)
        # Level 0 of stw is the per-block-minimum word row: touched blocks
        # re-encode even when the block-level argmin table did not move.
        wins = [(0, a, b) for a, b in inner.last_block_runs]
        wins.extend(inner.last_st_windows)
        for k, a, b in wins:
            self._repack_stw(k, a, b)
        self.last_block_runs = inner.last_block_runs
        self.last_st_windows = wins
