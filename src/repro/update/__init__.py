"""Versioned online-update subsystem: mutate RMQ structures under live traffic.

The missing piece between the paper's frozen-array preprocessing and a
long-lived service over evolving data (GPU-RMQ's framing): point writes,
range writes, and appends coalesce into per-shard delta batches
(``deltas``), incremental recompute kernels patch only the affected block
minima and doubling-table windows (``patch`` on the host,
``core.distributed.patch_sharded[_st]`` on the mesh), and copy-on-write
MVCC snapshots (``versions``) let queries pin a consistent version while
updates publish the next one — serving never blocks on mutation.

``make_online`` wraps any registry engine marked ``updatable``;
``serve.RMQServer`` accepts the result and interleaves ``submit_update``
batches with query launches. See DESIGN.md §9 for the consistency model and
the patch-window math.
"""

from .deltas import Delta, DeltaBatch, DeltaLog, shard_batches
from .engines import (
    EnginePoisoned,
    OnlineEngine,
    UpdateResult,
    make_online,
    online_names,
)
from .patch import BlockMirror, STMirror, k_levels, level_windows, patch_doubling
from .versions import Version, VersionStore

__all__ = [
    "BlockMirror",
    "Delta",
    "DeltaBatch",
    "DeltaLog",
    "EnginePoisoned",
    "OnlineEngine",
    "STMirror",
    "UpdateResult",
    "Version",
    "VersionStore",
    "k_levels",
    "level_windows",
    "make_online",
    "online_names",
    "patch_doubling",
    "shard_batches",
]
