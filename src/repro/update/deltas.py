"""Delta log: the mutation vocabulary of the online-update subsystem.

Mutable-array RMQ workloads (streaming telemetry, order books, sliding
windows) express three mutations: point writes, contiguous range writes, and
appends. ``DeltaLog`` records them in arrival order; ``coalesce`` lowers the
log into one canonical ``DeltaBatch`` — last-write-wins in-place writes over
the existing prefix plus a single appended tail — which is what the patch
kernels consume. Coalescing here is what keeps incremental recompute cheap:
k writes to one hot position cost one block-min repair, not k, and a write
landing inside a just-appended region folds into the tail instead of
becoming a second patch pass.

``shard_batches`` splits a coalesced batch by structure-shard ownership (the
``ShardLayout`` geometry) — the accounting view behind
``UpdateResult.touched_shards`` (the SPMD kernels themselves scatter
replicated update arrays inside ``shard_map``).

Everything here is host-side numpy: deltas arrive from clients exactly like
query bounds do, and the patch planner needs the touched positions on the
host anyway (window math is static per patch).
"""

from __future__ import annotations

import io
from typing import List, NamedTuple, Tuple

import numpy as np

__all__ = ["Delta", "DeltaBatch", "DeltaLog", "shard_batches"]


class Delta(NamedTuple):
    """One logged mutation, in arrival order."""

    kind: str  # "point" | "write" | "append"
    pos: int  # start index (ignored for append)
    values: np.ndarray  # (1,) point / (len,) contiguous write / (len,) tail


class DeltaBatch(NamedTuple):
    """A coalesced update batch: the canonical input of the patch kernels.

    ``idx``/``val`` are last-write-wins in-place writes into ``[0, n_old)``
    (``idx`` sorted ascending, unique); ``tail`` is the appended suffix
    (writes into the appended region are already folded in). The mutated
    array is ``concat(x[:n_old] with idx<-val scattered, tail)``.
    """

    idx: np.ndarray  # (W,) int64 sorted unique write positions < n_old
    val: np.ndarray  # (W,) values to scatter at idx
    tail: np.ndarray  # (A,) appended values (n_new = n_old + A)
    n_old: int
    n_new: int

    @property
    def n_ops(self) -> int:
        return int(self.idx.size + self.tail.size)

    def touched(self) -> np.ndarray:
        """Sorted global positions whose value changes (writes + tail)."""
        return np.concatenate(
            [self.idx, np.arange(self.n_old, self.n_new, dtype=np.int64)]
        )

    def apply_numpy(self, x: np.ndarray) -> np.ndarray:
        """The oracle semantics: the mutated array, as plain numpy."""
        if x.shape[0] != self.n_old:
            raise ValueError(f"batch coalesced for n={self.n_old}, got {x.shape[0]}")
        out = np.concatenate([x, self.tail.astype(x.dtype)])
        out[self.idx] = self.val.astype(x.dtype)
        return out

    def to_bytes(self) -> bytes:
        """Serialize for the write-ahead journal (``repro.fault.wal``).

        npz keeps exact dtypes and shapes, so a journal round-trip replays
        bit-identically: ``from_bytes(b.to_bytes()).apply_numpy(x)`` equals
        ``b.apply_numpy(x)`` leaf-for-leaf.
        """
        bio = io.BytesIO()
        np.savez(
            bio,
            idx=self.idx,
            val=self.val,
            tail=self.tail,
            dims=np.asarray([self.n_old, self.n_new], np.int64),
        )
        return bio.getvalue()

    @classmethod
    def from_bytes(cls, raw: bytes) -> "DeltaBatch":
        """Inverse of ``to_bytes``."""
        with np.load(io.BytesIO(raw)) as z:
            dims = z["dims"]
            return cls(
                idx=z["idx"],
                val=z["val"],
                tail=z["tail"],
                n_old=int(dims[0]),
                n_new=int(dims[1]),
            )


class DeltaLog:
    """Arrival-ordered mutation log over a length-``n`` array.

    The log itself is append-only and cheap; all normalization (bounds
    checks aside) happens in ``coalesce``. One log = one update batch = one
    published version downstream.
    """

    def __init__(self):
        self._ops: List[Delta] = []

    def __len__(self) -> int:
        return len(self._ops)

    @property
    def ops(self) -> Tuple[Delta, ...]:
        return tuple(self._ops)

    def point(self, i: int, v) -> "DeltaLog":
        """Write one value at index ``i``."""
        if i < 0:
            raise ValueError(f"point write at negative index {i}")
        self._ops.append(Delta("point", int(i), np.asarray([v])))
        return self

    def write(self, l: int, values) -> "DeltaLog":
        """Write a contiguous run of values starting at index ``l``."""
        values = np.asarray(values)
        if values.ndim != 1 or values.size == 0:
            raise ValueError(f"write needs a non-empty 1-D run, got {values.shape}")
        if l < 0:
            raise ValueError(f"range write at negative index {l}")
        self._ops.append(Delta("write", int(l), values))
        return self

    def fill(self, l: int, r: int, v) -> "DeltaLog":
        """Write the constant ``v`` over the inclusive range ``[l, r]``."""
        if not 0 <= l <= r:
            raise ValueError(f"fill needs 0 <= l <= r, got [{l}, {r}]")
        return self.write(l, np.full(r - l + 1, v))

    def append(self, values) -> "DeltaLog":
        """Extend the array with ``values`` (n grows by ``len(values)``)."""
        values = np.asarray(values)
        if values.ndim != 1 or values.size == 0:
            raise ValueError(f"append needs a non-empty 1-D run, got {values.shape}")
        self._ops.append(Delta("append", 0, values))
        return self

    def coalesce(self, n: int, dtype=np.float32) -> DeltaBatch:
        """Lower the log to one canonical ``DeltaBatch`` over a length-``n`` array.

        Replays ops in arrival order into (sparse writes over the prefix,
        dense tail), so later writes win and writes into appended positions
        fold into the tail. Raises on writes past the (current, possibly
        already-extended) end — a delta log never creates holes.
        """
        if not self._ops:
            raise ValueError("coalesce() on an empty DeltaLog")
        n = int(n)
        pos_runs: List[np.ndarray] = []
        val_runs: List[np.ndarray] = []
        tail = np.zeros(0, dtype)
        n_cur = n
        for op in self._ops:
            if op.kind == "append":
                tail = np.concatenate([tail, op.values.astype(dtype)])
                n_cur = n + tail.size
                continue
            lo = op.pos
            hi = lo + op.values.size - 1
            if hi >= n_cur:
                raise ValueError(
                    f"{op.kind} over [{lo}, {hi}] past the end of the "
                    f"length-{n_cur} array (appends extend it first)"
                )
            pos_runs.append(np.arange(lo, hi + 1, dtype=np.int64))
            val_runs.append(op.values.astype(dtype))
        if pos_runs:
            # Last write wins: unique over the REVERSED stream keeps, for each
            # position, its final value; np.unique also sorts the positions.
            pos = np.concatenate(pos_runs)[::-1]
            val = np.concatenate(val_runs)[::-1]
            uniq, first = np.unique(pos, return_index=True)
            vals = val[first]
            in_tail = uniq >= n
            tail[uniq[in_tail] - n] = vals[in_tail]
            idx, val = uniq[~in_tail], vals[~in_tail]
        else:
            idx = np.zeros(0, np.int64)
            val = np.zeros(0, dtype)
        return DeltaBatch(idx=idx, val=val, tail=tail, n_old=n, n_new=n_cur)


def shard_batches(
    batch: DeltaBatch, num_shards: int, shard_len: int
) -> List[Tuple[int, np.ndarray, np.ndarray]]:
    """Split a coalesced batch's changed positions by structure-shard owner.

    Returns ``[(shard_id, global_positions, values), ...]`` for shards that
    own at least one changed position (tail values included — an append
    within the padded capacity is just writes at pad columns). The SPMD
    patch kernels scatter replicated (pos, val) arrays inside ``shard_map``
    (each device drops what it doesn't own), so this split is the
    *accounting* view: ``UpdateResult.touched_shards`` reports how local an
    update was, and tooling can inspect which shards a batch lands on.
    """
    pos = batch.touched()
    vals = np.concatenate([batch.val, batch.tail.astype(batch.val.dtype)])
    out = []
    shard = pos // shard_len
    for s in range(num_shards):
        m = shard == s
        if m.any():
            out.append((s, pos[m], vals[m]))
    return out
