"""Online engines: incremental mutation + MVCC versions per registry engine.

``make_online(name, x)`` wraps an ``updatable`` registry engine in an
``OnlineEngine``: the initial state is built through the engine's staged
BuildPlan, and every subsequent mutation lowers through the two online
stages (``core.build.update_plan``: ``apply_deltas`` -> ``publish``) instead
of a rebuild. Queries pin a version from the MVCC store and never block on
mutation; ``apply`` is serialized (one updater at a time), so version ids
are the consistency order.

Per-engine patch strategy:

* ``sparse_table`` / ``block128`` / ``block256`` / ``hybrid`` — host numpy
  mirrors (``repro.update.patch``): windowed per-level doubling repair and
  O(bs) block-min repair, then the patched leaves are published as fresh
  device arrays (copy-on-write at the leaf level). Hybrid versions share
  module-level jitted query closures so a publish never retraces.
* ``distributed`` / ``sharded_hybrid`` (structure-sharded modes) — the SPMD
  patch kernels (``distributed.patch_sharded`` / ``patch_sharded_st``):
  updates scatter on the owning devices, doubling levels re-run masked to
  the affected windows with the ``_flat_shift`` halo transport across shard
  boundaries. Appends that fit the padded capacity are patches (pad columns
  become real); growing past capacity falls back to a structural rebuild
  through the engine's BuildPlan (reported via ``UpdateResult.patched``).
* ``sharded_hybrid`` (``shard_batch``) — host mirrors patched once, then
  re-replicated (each device holds the full structure by construction).

Every patched state is bit-identical to a from-scratch rebuild of the
mutated array — the acceptance criterion tests/test_update.py asserts
leaf-for-leaf.
"""

from __future__ import annotations

import functools
import json
import threading
import time
from typing import Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import block_rmq, distributed, packing, registry, sparse_table
from repro.core import build as build_mod
from repro.obs import trace as obs_trace
from repro.core.block_rmq import BlockRMQ
from repro.core.hybrid import HybridRMQ
from repro.core.sparse_table import SparseTable

from .deltas import DeltaBatch, DeltaLog, shard_batches
from .patch import BlockMirror, PackedBlockMirror, PackedSTMirror, STMirror
from .patch import packed_fit_check
from .versions import Version, VersionStore

__all__ = [
    "EnginePoisoned",
    "OnlineEngine",
    "UpdateResult",
    "make_online",
    "online_names",
]


class EnginePoisoned(RuntimeError):
    """The engine fail-stopped after a mid-patch apply failure.

    Carries what recovery needs: ``cause`` is the original exception and
    ``seq`` the failing update's journal sequence number (``None`` when the
    engine runs unjournaled). Queries keep serving published versions; a
    successful checkpoint+journal restore (``fault.durable``) replaces the
    poisoned engine with a consistent one — the aborted seq is skipped on
    replay, so the restored state is the last published version.
    """

    def __init__(self, name: str, seq, cause: BaseException):
        at = f" applying journaled update seq {seq}" if seq is not None else ""
        super().__init__(
            f"online engine {name!r} is fail-stopped after an apply error{at}: "
            f"{cause!r}; restore from checkpoint+journal or rebuild (queries "
            f"still serve published versions)"
        )
        self.engine = name
        self.seq = seq
        self.cause = cause


class UpdateResult(NamedTuple):
    """What one applied update batch did."""

    version: int  # the published version id
    n: int  # logical array length after the batch
    patched: bool  # True = incremental patch; False = structural rebuild
    n_writes: int  # coalesced in-place writes
    n_appended: int  # appended elements
    seconds: float  # apply wall time (patch + publish material)
    touched_shards: int = 1  # structure shards owning >= 1 changed position
    # Host->device bytes this publish uploaded (single-host engines with the
    # windowed-COW publish; 0 = untracked — mesh engines scatter replicated
    # (pos, val) arrays inside shard_map, already O(batch) upload).
    publish_bytes: int = 0


# Module-level jitted query closures for published hybrid versions: binding a
# new same-shape structure is a jit-cache hit, so publishing never retraces.
_block_query_jit = jax.jit(block_rmq.query)


def _st_long(table: SparseTable, x, l, r):
    idx = sparse_table.query(table, l, r)
    return idx, x[idx]


_st_long_jit = jax.jit(_st_long)


def _block_state(m: BlockMirror) -> BlockRMQ:
    bmin = jnp.asarray(m.bmin_val)
    return BlockRMQ(
        x_blocks=jnp.asarray(m.x_blocks),
        bmin_val=bmin,
        bmin_gidx=jnp.asarray(m.bmin_gidx),
        st=SparseTable(idx=jnp.asarray(m.st_idx), x=bmin),
    )


# --- windowed copy-on-write publish ------------------------------------------
#
# A publish installs fresh device leaves for the next MVCC version. Uploading
# every host mirror in full costs ~O(n log n) host->device bytes for an
# O(log n)-window point patch (the ROADMAP carried-forward); instead each
# leaf keeps its current device array and a publish splices only the patched
# windows into it with one fused jit of chained dynamic_update_slice ops.
# The previous array is NOT donated — it belongs to a published version that
# pinned queries may still hold — so XLA materializes the copy device-side:
# COW is preserved while only the window bytes cross the host->device
# boundary. Window lengths are padded to powers of two (the padding uploads
# unchanged-but-correct mirror content) so the jit cache stays bounded at
# ~log2(n) shapes per leaf.


def _cow_splice(dev, wins, starts):
    for w, st in zip(wins, starts):
        dev = jax.lax.dynamic_update_slice(dev, w, st)
    return dev


_cow_splice_jit = jax.jit(_cow_splice)


def _padded_span(a: int, b: int, m: int) -> Tuple[int, int]:
    """Inclusive [a, b] -> (start, pow2 length), shifted left to fit in m."""
    ln = b - a + 1
    p = 1 << (ln - 1).bit_length()
    if p >= m:
        return 0, m
    return min(a, m - p), p


class _CowLeaf:
    """One device-resident structure leaf published copy-on-write.

    ``full(host)`` re-uploads the mirror (shape changed); ``splice`` /
    ``splice_rows`` upload only the padded patch windows and splice them
    into the previous device array. Either way the uploaded byte count
    accumulates into the shared ``counter`` (an UpdateResult.publish_bytes
    source) and ``dev`` is the leaf for the next version.
    """

    __slots__ = ("dev", "_counter")

    def __init__(self, dev, counter):
        self.dev = dev
        self._counter = counter

    def full(self, host):
        self.dev = jnp.asarray(host)
        self._counter["bytes"] += int(self.dev.nbytes)
        return self.dev

    def splice(self, host, spans):
        """``spans``: (row, a, b) windows — row=None for a 1-D leaf."""
        if not spans:
            return self.dev
        m = int(host.shape[-1])
        wins, starts = [], []
        for row, a, b in spans:
            s, p = _padded_span(a, b, m)
            if row is None:
                w = jnp.asarray(host[s : s + p])
                starts.append((np.int32(s),))
            else:
                w = jnp.asarray(host[row : row + 1, s : s + p])
                starts.append((np.int32(row), np.int32(s)))
            wins.append(w)
            self._counter["bytes"] += int(w.nbytes)
        self.dev = _cow_splice_jit(self.dev, tuple(wins), tuple(starts))
        return self.dev

    def splice_rows(self, host, runs):
        """``runs``: inclusive (a, b) row ranges of a 2-D leaf (full width)."""
        if not runs:
            return self.dev
        nrows = int(host.shape[0])
        wins, starts = [], []
        for a, b in runs:
            s, p = _padded_span(a, b, nrows)
            w = jnp.asarray(host[s : s + p])
            wins.append(w)
            starts.append((np.int32(s), np.int32(0)))
            self._counter["bytes"] += int(w.nbytes)
        self.dev = _cow_splice_jit(self.dev, tuple(wins), tuple(starts))
        return self.dev


class _BlockLeaves:
    """The four device leaves of a ``BlockRMQ``, published copy-on-write."""

    def __init__(self, m: BlockMirror, counter, state: Optional[BlockRMQ] = None):
        if state is None:  # restore: seed from the mirror (no argmin rebuild)
            bv = jnp.asarray(m.bmin_val)
            state = BlockRMQ(
                x_blocks=jnp.asarray(m.x_blocks),
                bmin_val=bv,
                bmin_gidx=jnp.asarray(m.bmin_gidx),
                st=SparseTable(idx=jnp.asarray(m.st_idx), x=bv),
            )
        self.xb = _CowLeaf(state.x_blocks, counter)
        self.bv = _CowLeaf(state.bmin_val, counter)
        self.bg = _CowLeaf(state.bmin_gidx, counter)
        self.bst = _CowLeaf(state.st.idx, counter)

    def state(self) -> BlockRMQ:
        return BlockRMQ(
            x_blocks=self.xb.dev,
            bmin_val=self.bv.dev,
            bmin_gidx=self.bg.dev,
            st=SparseTable(idx=self.bst.dev, x=self.bv.dev),
        )

    def publish(self, m: BlockMirror) -> BlockRMQ:
        """Refresh the leaves from the just-patched mirror, windowed."""
        if m.last_block_runs is None:  # block count grew: shapes changed
            self.xb.full(m.x_blocks)
            self.bv.full(m.bmin_val)
            self.bg.full(m.bmin_gidx)
            self.bst.full(m.st_idx)
        else:
            runs1d = [(None, a, b) for a, b in m.last_block_runs]
            self.xb.splice_rows(m.x_blocks, m.last_block_runs)
            self.bv.splice(m.bmin_val, runs1d)
            self.bg.splice(m.bmin_gidx, runs1d)
            self.bst.splice(m.st_idx, m.last_st_windows)
        return self.state()


class _Impl(NamedTuple):
    """One engine's online hooks: the resolved plan, the initial state,
    ``patch(batch, prev_state) -> (next_state, was_incremental)``, plus the
    crash-safety hooks — ``snapshot() -> {name: np.ndarray}`` (the host-side
    structure leaves a checkpoint persists; a factory given ``snap=...``
    reconstructs the same state without re-running the argmin build) and
    ``array() -> np.ndarray`` (a host copy of the current logical array:
    published on every version for the degraded fallback + oracle checks,
    and the rebuild source for mesh-resident engines)."""

    plan: build_mod.BuildPlan
    state0: object
    patch: Callable
    snapshot: Optional[Callable] = None
    array: Optional[Callable] = None
    # () -> int: host->device bytes the last patch's publish uploaded (the
    # windowed-COW engines); None = untracked (UpdateResult reports 0).
    publish_bytes: Optional[Callable] = None


# --- single-host implementations --------------------------------------------
#
# The single-host engines restore *instantly*: their host mirrors ARE the
# built structures, so a checkpoint persists the mirror leaves and a restore
# re-seats them without recomputing a single argmin. The mesh engines (below)
# snapshot only the logical array and restore by re-running their BuildPlan —
# bit-identical by the patched==rebuilt invariant this subsystem asserts.


def _sparse_table_impl(x, mesh, axis_names, kw, snap=None) -> _Impl:
    plan = build_mod.plan_for("sparse_table", x.shape[0])
    pub = {"bytes": 0}
    if snap is None:
        state0 = build_mod.execute(plan, x)
        mirror = STMirror.from_state(state0[0])
        idx_leaf = _CowLeaf(state0[0].idx, pub)
        x_leaf = _CowLeaf(state0[1], pub)
    else:
        mirror = STMirror(snap["st_idx"], snap["x"])
        idx_leaf = _CowLeaf(jnp.asarray(mirror.idx), pub)
        x_leaf = _CowLeaf(jnp.asarray(mirror.x), pub)
        state0 = (SparseTable(idx=idx_leaf.dev, x=x_leaf.dev), x_leaf.dev)

    def patch(batch: DeltaBatch, prev):
        pub["bytes"] = 0
        mirror.patch(batch)
        if mirror.last_idx_windows is None:  # grew: leaf shapes changed
            xj = x_leaf.full(mirror.x)
            ij = idx_leaf.full(mirror.idx)
        else:
            xj = x_leaf.splice(
                mirror.x, [(None, a, b) for a, b in mirror.last_x_windows]
            )
            ij = idx_leaf.splice(mirror.idx, mirror.last_idx_windows)
        return (SparseTable(idx=ij, x=xj), xj), True

    return _Impl(
        plan,
        state0,
        patch,
        snapshot=lambda: {"x": mirror.x.copy(), "st_idx": mirror.idx.copy()},
        array=lambda: mirror.x.copy(),
        publish_bytes=lambda: pub["bytes"],
    )


def _block_impl(block_size: int):
    def factory(x, mesh, axis_names, kw, snap=None) -> _Impl:
        bs = kw.get("block_size", block_size)
        plan = build_mod.plan_for("block", x.shape[0], block_size=bs)
        pub = {"bytes": 0}
        if snap is None:
            state0 = build_mod.execute(plan, x)
            mirror = BlockMirror.from_state(state0, x.shape[0])
            leaves = _BlockLeaves(mirror, pub, state=state0)
        else:
            mirror = BlockMirror(
                snap["x_blocks"],
                snap["bmin_val"],
                snap["bmin_gidx"],
                snap["st_idx"],
                snap["x"].shape[0],
            )
            leaves = _BlockLeaves(mirror, pub)
            state0 = leaves.state()

        def patch(batch: DeltaBatch, prev):
            pub["bytes"] = 0
            mirror.patch(batch)
            return leaves.publish(mirror), True

        return _Impl(
            plan,
            state0,
            patch,
            snapshot=lambda: {
                "x": mirror.x_blocks.reshape(-1)[: mirror.n].copy(),
                "x_blocks": mirror.x_blocks.copy(),
                "bmin_val": mirror.bmin_val.copy(),
                "bmin_gidx": mirror.bmin_gidx.copy(),
                "st_idx": mirror.st_idx.copy(),
            },
            array=lambda: mirror.x_blocks.reshape(-1)[: mirror.n].copy(),
            publish_bytes=lambda: pub["bytes"],
        )

    return factory


def _hybrid_impl(x, mesh, axis_names, kw, snap=None) -> _Impl:
    if build_mod._norm_packed(kw.get("packed")) is not None:
        return _packed_hybrid_impl(x, mesh, axis_names, kw, snap=snap)
    # The online hybrid pins the pure-jnp short path: the Pallas megakernel's
    # packed buffers are not patched in place yet (kernel-side COW is a
    # ROADMAP follow-up), and the CPU baseline never uses them anyway.
    plan = build_mod.plan_for(
        "hybrid",
        x.shape[0],
        block_size=kw.get("block_size", 128),
        threshold=kw.get("threshold"),
        use_kernels=False,
    )

    pub = {"bytes": 0}

    def _assemble(blocked: BlockRMQ, table: SparseTable, xj, threshold) -> HybridRMQ:
        return HybridRMQ(
            blocked=blocked,
            st=table,
            x=xj,
            threshold=threshold,
            use_kernels=False,
            short_fn=functools.partial(_block_query_jit, blocked),
            long_fn=functools.partial(_st_long_jit, table, xj),
        )

    if snap is None:
        state0 = build_mod.execute(plan, x)
        blocked_m = BlockMirror.from_state(state0.blocked, x.shape[0])
        st_m = STMirror.from_state(state0.st)
        leaves = _BlockLeaves(blocked_m, pub, state=state0.blocked)
        ti_leaf = _CowLeaf(state0.st.idx, pub)
        x_leaf = _CowLeaf(state0.st.x, pub)
    else:
        blocked_m = BlockMirror(
            snap["b_x_blocks"],
            snap["b_bmin_val"],
            snap["b_bmin_gidx"],
            snap["b_st_idx"],
            snap["x"].shape[0],
        )
        st_m = STMirror(snap["st_idx"], snap["x"])
        leaves = _BlockLeaves(blocked_m, pub)
        ti_leaf = _CowLeaf(jnp.asarray(st_m.idx), pub)
        x_leaf = _CowLeaf(jnp.asarray(st_m.x), pub)
        # The snapshot was taken under the plan's resolved threshold (the
        # restore kwargs pin it), so routing is identical to the live engine.
        state0 = _assemble(
            leaves.state(),
            SparseTable(idx=ti_leaf.dev, x=x_leaf.dev),
            x_leaf.dev,
            plan.meta["threshold"],
        )

    def patch(batch: DeltaBatch, prev: HybridRMQ):
        pub["bytes"] = 0
        blocked_m.patch(batch)
        st_m.patch(batch)
        blocked = leaves.publish(blocked_m)
        if st_m.last_idx_windows is None:  # grew: full-array leaves changed shape
            xj = x_leaf.full(st_m.x)
            ti = ti_leaf.full(st_m.idx)
        else:
            xj = x_leaf.splice(
                st_m.x, [(None, a, b) for a, b in st_m.last_x_windows]
            )
            ti = ti_leaf.splice(st_m.idx, st_m.last_idx_windows)
        return _assemble(blocked, SparseTable(idx=ti, x=xj), xj, prev.threshold), True

    return _Impl(
        plan,
        state0,
        patch,
        snapshot=lambda: {
            "x": st_m.x.copy(),
            "st_idx": st_m.idx.copy(),
            "b_x_blocks": blocked_m.x_blocks.copy(),
            "b_bmin_val": blocked_m.bmin_val.copy(),
            "b_bmin_gidx": blocked_m.bmin_gidx.copy(),
            "b_st_idx": blocked_m.st_idx.copy(),
        },
        array=lambda: st_m.x.copy(),
        publish_bytes=lambda: pub["bytes"],
    )


# --- packed single-host hybrid -----------------------------------------------


def _spec_blob(spec) -> np.ndarray:
    """The ``PackSpec`` as a uint8 JSON blob (checkpoints persist arrays only).

    The concrete spec must survive a checkpoint: an overflow-triggered
    rebuild re-biases the key range, after which ``spec_for`` over the
    restored array would derive a *different* (equally valid) spec — and a
    restore must be bit-identical to the live engine, not merely conformant.
    """
    return np.frombuffer(json.dumps(spec.to_meta()).encode(), np.uint8)


def _spec_from_blob(blob: np.ndarray):
    spec = packing.PackSpec.from_meta(json.loads(np.asarray(blob, np.uint8).tobytes()))
    if spec.layout == "packed64":
        packing.ensure_x64()  # spec_for normally flips this; restores skip it
    return spec


def _packed_hybrid_impl(x, mesh, axis_names, kw, snap=None) -> _Impl:
    """Online packed hybrid: packed mirrors + windowed word-plane publish.

    The packed mirrors (``update.patch``) delegate the exact windowed repair
    to the raw mirrors and repack words over only the recomputed windows, so
    a publish uploads the same O(windows) volume as the unpacked engine —
    but each window is one fused word plane instead of parallel idx/val
    leaves. A batch the build-time spec cannot encode (a packed32 value
    outside the key range, appends past the index field) raises
    ``OverflowError`` BEFORE any mirror mutates and falls back to a
    structural rebuild under a fresh spec; packed64 always fits, so its
    appends stay incremental.
    """
    layout_req = build_mod._norm_packed(kw.get("packed", "auto")) or "auto"
    plan = build_mod.plan_for(
        "hybrid",
        x.shape[0],
        block_size=kw.get("block_size", 128),
        threshold=kw.get("threshold"),
        use_kernels=False,
        packed=layout_req,
    )
    bs = plan.meta["block_size"]
    pub = {"bytes": 0}

    def _assemble(blocked, table, xj, threshold, spec) -> HybridRMQ:
        # query_packed jits internally with the spec static, so binding a
        # fresh same-shape structure on publish is a jit-cache hit.
        return HybridRMQ(
            blocked=blocked,
            st=table,
            x=xj,
            threshold=threshold,
            use_kernels=False,
            short_fn=lambda l, r: block_rmq.query_packed(blocked, spec, l, r),
            long_fn=lambda l, r: sparse_table.query_packed(table, spec, l, r),
        )

    def _seed(state, spec, x_host):
        """Mirrors + COW leaves over a freshly built packed state."""
        blocked_m = PackedBlockMirror.from_state(state.blocked, spec, x_host.shape[0])
        st_m = PackedSTMirror.from_state(state.st, x_host, spec)
        leaves = {
            "blocks": _CowLeaf(state.blocked.blocks, pub),
            "stw": _CowLeaf(state.blocked.stw, pub),
            "words": _CowLeaf(state.st.words, pub),
            "x": _CowLeaf(state.x, pub),
        }
        return blocked_m, st_m, leaves

    if snap is None:
        state0 = build_mod.execute(plan, x)
        # Deterministic from the data — identical to the spec the plan's
        # local stage derived (and discarded with the build state dict).
        spec = packing.spec_for(x, x.shape[0], plan.meta["packed"])
        blocked_m, st_m, leaves = _seed(state0, spec, np.asarray(x))
    else:
        spec = _spec_from_blob(snap["spec"])
        blocked_m = PackedBlockMirror(
            snap["b_blocks"], snap["b_stw"], spec, snap["x"].shape[0]
        )
        st_m = PackedSTMirror(snap["st_words"], snap["x"], spec)
        leaves = {
            "blocks": _CowLeaf(jnp.asarray(snap["b_blocks"]), pub),
            "stw": _CowLeaf(jnp.asarray(snap["b_stw"]), pub),
            "words": _CowLeaf(jnp.asarray(snap["st_words"]), pub),
            "x": _CowLeaf(jnp.asarray(snap["x"]), pub),
        }
        state0 = _assemble(
            block_rmq.PackedBlockRMQ(
                blocks=leaves["blocks"].dev, stw=leaves["stw"].dev
            ),
            sparse_table.PackedSparseTable(
                words=leaves["words"].dev,
                x=leaves["x"].dev if spec.layout == "quantized" else None,
            ),
            leaves["x"].dev,
            plan.meta["threshold"],
            spec,
        )

    def patch(batch: DeltaBatch, prev: HybridRMQ):
        nonlocal spec, blocked_m, st_m, leaves
        pub["bytes"] = 0
        vals = np.concatenate([batch.val, batch.tail.astype(batch.val.dtype)])
        try:
            packed_fit_check(spec, vals, batch.n_new)
        except OverflowError:
            # The build-time spec cannot encode this batch: structural
            # rebuild under a fresh spec (threshold pinned, deterministic).
            xj = jnp.asarray(batch.apply_numpy(st_m.x))
            p2 = build_mod.plan_for(
                "hybrid",
                batch.n_new,
                block_size=bs,
                threshold=int(prev.threshold),
                use_kernels=False,
                packed=layout_req,
            )
            state = build_mod.execute(p2, xj)
            spec = packing.spec_for(xj, batch.n_new, p2.meta["packed"])
            blocked_m, st_m, leaves = _seed(state, spec, np.asarray(xj))
            return state, False
        blocked_m.patch(batch)
        st_m.patch(batch)
        b_host = (
            blocked_m.block_words
            if blocked_m.block_words is not None  # quantized keeps raw blocks
            else blocked_m.inner.x_blocks
        )
        if blocked_m.last_block_runs is None:  # block count grew
            bw = leaves["blocks"].full(b_host)
            sw = leaves["stw"].full(blocked_m.stw_words)
        else:
            bw = leaves["blocks"].splice_rows(b_host, blocked_m.last_block_runs)
            sw = leaves["stw"].splice(blocked_m.stw_words, blocked_m.last_st_windows)
        if st_m.last_word_windows is None:  # grew: full-plane shapes changed
            wj = leaves["words"].full(st_m.words)
            xj = leaves["x"].full(st_m.x)
        else:
            wj = leaves["words"].splice(st_m.words, st_m.last_word_windows)
            xj = leaves["x"].splice(
                st_m.x, [(None, a, b) for a, b in st_m.last_x_windows]
            )
        blocked = block_rmq.PackedBlockRMQ(blocks=bw, stw=sw)
        table = sparse_table.PackedSparseTable(
            words=wj, x=xj if spec.layout == "quantized" else None
        )
        return _assemble(blocked, table, xj, prev.threshold, spec), True

    def snapshot():
        b_host = (
            blocked_m.block_words
            if blocked_m.block_words is not None
            else blocked_m.inner.x_blocks
        )
        return {
            "x": st_m.x.copy(),
            "st_words": st_m.words.copy(),
            "b_blocks": b_host.copy(),
            "b_stw": blocked_m.stw_words.copy(),
            "spec": _spec_blob(spec),
        }

    return _Impl(
        plan,
        state0,
        patch,
        snapshot=snapshot,
        array=lambda: st_m.x.copy(),
        publish_bytes=lambda: pub["bytes"],
    )


# --- mesh implementations ----------------------------------------------------


def _distributed_impl(x, mesh, axis_names, kw, snap=None) -> _Impl:
    # Mesh-resident structures: the snapshot is the logical array only, and a
    # restore re-executes the BuildPlan over it (bit-identical to the live
    # patched state by the patched==rebuilt invariant). ``snap`` therefore
    # needs no special casing here — ``from_snapshot`` hands the saved array
    # in as ``x`` and the normal build path is the restore path.
    plan = build_mod.plan_for(
        "distributed",
        x.shape[0],
        mesh=mesh,
        axis_names=axis_names,
        block_size=kw.get("block_size", 128),
    )
    state0 = build_mod.execute(plan, x)
    mesh, axes = plan.meta["mesh"], plan.meta["axis_names"]
    bs = plan.meta["block_size"]
    x_host = np.asarray(x)  # full-array mirror: the rebuild-fallback source

    def patch(batch: DeltaBatch, prev):
        nonlocal x_host
        x_host = batch.apply_numpy(x_host)
        s, qfn = prev
        capacity = s.x_blocks.shape[0] * s.x_blocks.shape[1]
        if batch.n_new > capacity:  # grew past the padded shard capacity
            p2 = build_mod.plan_for(
                "distributed", batch.n_new, mesh=mesh, axis_names=axes, block_size=bs
            )
            return build_mod.execute(p2, jnp.asarray(x_host)), False
        pos = batch.touched()
        val = np.concatenate([batch.val, batch.tail.astype(batch.val.dtype)])
        return (distributed.patch_sharded(s, pos, val, mesh, axes), qfn), True

    return _Impl(
        plan,
        state0,
        patch,
        snapshot=lambda: {"x": x_host.copy()},
        array=lambda: x_host.copy(),
    )


def _sharded_hybrid_impl(x, mesh, axis_names, kw, snap=None) -> _Impl:
    if build_mod._norm_packed(kw.get("packed")) is not None:
        return _packed_sharded_hybrid_impl(x, mesh, axis_names, kw, snap=snap)
    # Like ``_distributed_impl``: snapshot = the logical array, restore =
    # re-run the BuildPlan (with the threshold pinned via the restore
    # kwargs), bit-identical by the patched==rebuilt invariant.
    plan = build_mod.plan_for(
        "sharded_hybrid",
        x.shape[0],
        mesh=mesh,
        axis_names=axis_names,
        block_size=kw.get("block_size", 128),
        threshold=kw.get("threshold"),
        mode=kw.get("mode", "shard_structure"),
    )
    state0 = build_mod.execute(plan, x)
    mesh = plan.meta["mesh"]
    struct_axes = plan.meta["struct_axes"]
    mode, bs = plan.meta["mode"], plan.meta["block_size"]
    x_host = np.asarray(x)
    snapshot = lambda: {"x": x_host.copy()}
    array = lambda: x_host.copy()

    if not struct_axes:  # shard_batch: replicated structures, host mirrors
        blocked_m = BlockMirror.from_state(state0.blocked, x.shape[0])
        st_m = STMirror.from_state(state0.st)
        repl = NamedSharding(mesh, P())

        def patch(batch: DeltaBatch, prev):
            nonlocal x_host
            x_host = batch.apply_numpy(x_host)
            blocked_m.patch(batch)
            st_m.patch(batch)
            table = SparseTable(idx=jnp.asarray(st_m.idx), x=jnp.asarray(st_m.x))
            return (
                prev._replace(
                    blocked=jax.device_put(_block_state(blocked_m), repl),
                    st=jax.device_put(table, repl),
                    n=batch.n_new,
                ),
                True,
            )

        return _Impl(plan, state0, patch, snapshot=snapshot, array=array)

    def patch(batch: DeltaBatch, prev):
        nonlocal x_host
        x_host = batch.apply_numpy(x_host)
        cap_blocked = prev.blocked.x_blocks.shape[0] * prev.blocked.x_blocks.shape[1]
        cap_st = prev.st.idx.shape[1]
        if batch.n_new > min(cap_blocked, cap_st):
            # Structural rebuild (capacity exceeded); the routing threshold
            # stays pinned so the rebuild is as deterministic as the patch.
            p2 = build_mod.plan_for(
                "sharded_hybrid",
                batch.n_new,
                mesh=mesh,
                axis_names=plan.meta["axis_names"],
                block_size=bs,
                threshold=int(prev.threshold),
                mode=mode,
            )
            return build_mod.execute(p2, jnp.asarray(x_host)), False
        pos = batch.touched()
        val = np.concatenate([batch.val, batch.tail.astype(batch.val.dtype)])
        return (
            prev._replace(
                blocked=distributed.patch_sharded(
                    prev.blocked, pos, val, mesh, struct_axes
                ),
                st=distributed.patch_sharded_st(prev.st, pos, val, mesh, struct_axes),
                n=batch.n_new,
            ),
            True,
        )

    return _Impl(plan, state0, patch, snapshot=snapshot, array=array)


def _packed_sharded_hybrid_impl(x, mesh, axis_names, kw, snap=None) -> _Impl:
    """Online packed sharded hybrid: single-plane SPMD patches.

    Structure-sharded modes patch through ``distributed.patch_sharded_packed``
    / ``patch_sharded_st_packed`` — one word plane rides the halo transport
    per doubling level, half the unpacked patch's traffic. ``shard_batch``
    patches host packed mirrors and re-replicates. A batch the spec cannot
    encode (packed32 key range, appends past the index field) raises
    host-side BEFORE any device state mutates and falls back to a structural
    rebuild under a fresh spec. Snapshot = the logical array (the mesh
    convention): restore re-runs the BuildPlan, which re-derives the spec
    deterministically from the restored array.
    """
    layout_req = build_mod._norm_packed(kw.get("packed", "auto")) or "auto"
    plan = build_mod.plan_for(
        "sharded_hybrid",
        x.shape[0],
        mesh=mesh,
        axis_names=axis_names,
        block_size=kw.get("block_size", 128),
        threshold=kw.get("threshold"),
        mode=kw.get("mode", "shard_structure"),
        packed=layout_req,
    )
    state0 = build_mod.execute(plan, x)
    mesh = plan.meta["mesh"]
    struct_axes = plan.meta["struct_axes"]
    mode, bs = plan.meta["mode"], plan.meta["block_size"]
    x_host = np.asarray(x)
    spec = packing.spec_for(x, x.shape[0], plan.meta["packed"])
    snapshot = lambda: {"x": x_host.copy()}
    array = lambda: x_host.copy()

    def _rebuild(n_new, threshold):
        nonlocal spec
        xj = jnp.asarray(x_host)
        p2 = build_mod.plan_for(
            "sharded_hybrid",
            n_new,
            mesh=mesh,
            axis_names=plan.meta["axis_names"],
            block_size=bs,
            threshold=threshold,
            mode=mode,
            packed=layout_req,
        )
        state = build_mod.execute(p2, xj)
        spec = packing.spec_for(xj, n_new, p2.meta["packed"])
        return state

    if not struct_axes:  # shard_batch: replicated structures, packed mirrors
        blocked_m = PackedBlockMirror.from_state(state0.blocked, spec, x.shape[0])
        st_m = PackedSTMirror.from_state(state0.st, x_host, spec)
        repl = NamedSharding(mesh, P())

        def patch(batch: DeltaBatch, prev):
            nonlocal x_host, blocked_m, st_m
            vals = np.concatenate([batch.val, batch.tail.astype(batch.val.dtype)])
            try:
                packed_fit_check(spec, vals, batch.n_new)
            except OverflowError:
                x_host = batch.apply_numpy(x_host)
                state = _rebuild(batch.n_new, int(prev.threshold))
                blocked_m = PackedBlockMirror.from_state(
                    state.blocked, spec, batch.n_new
                )
                st_m = PackedSTMirror.from_state(state.st, x_host, spec)
                return state, False
            x_host = batch.apply_numpy(x_host)
            blocked_m.patch(batch)
            st_m.patch(batch)
            # Mesh packing is never quantized, so both word planes exist.
            blocked = block_rmq.PackedBlockRMQ(
                blocks=jnp.asarray(blocked_m.block_words),
                stw=jnp.asarray(blocked_m.stw_words),
            )
            table = sparse_table.PackedSparseTable(words=jnp.asarray(st_m.words))
            return (
                prev._replace(
                    blocked=jax.device_put(blocked, repl),
                    st=jax.device_put(table, repl),
                    n=batch.n_new,
                ),
                True,
            )

        return _Impl(plan, state0, patch, snapshot=snapshot, array=array)

    def patch(batch: DeltaBatch, prev):
        nonlocal x_host
        vals = np.concatenate([batch.val, batch.tail.astype(batch.val.dtype)])
        x_host = batch.apply_numpy(x_host)
        cap_blocked = prev.blocked.blocks.shape[0] * prev.blocked.blocks.shape[1]
        cap_st = prev.st.words.shape[1]
        if batch.n_new > min(cap_blocked, cap_st):
            return _rebuild(batch.n_new, int(prev.threshold)), False
        try:
            # Appends inside the padded capacity can still outgrow the
            # spec's index field — checked host-side before any scatter.
            packed_fit_check(spec, vals, batch.n_new)
        except OverflowError:
            return _rebuild(batch.n_new, int(prev.threshold)), False
        pos = batch.touched()
        return (
            prev._replace(
                blocked=distributed.patch_sharded_packed(
                    prev.blocked, pos, vals, mesh, struct_axes, spec
                ),
                st=distributed.patch_sharded_st_packed(
                    prev.st, pos, vals, mesh, struct_axes, spec
                ),
                n=batch.n_new,
            ),
            True,
        )

    return _Impl(plan, state0, patch, snapshot=snapshot, array=array)


_FACTORIES: Dict[str, Callable] = {
    "sparse_table": _sparse_table_impl,
    "block128": _block_impl(128),
    "block256": _block_impl(256),
    "hybrid": _hybrid_impl,
    "distributed": _distributed_impl,
    "sharded_hybrid": _sharded_hybrid_impl,
    "packed_hybrid": _packed_hybrid_impl,
    "packed_sharded_hybrid": _packed_sharded_hybrid_impl,
}


def online_names() -> Tuple[str, ...]:
    """Engines with an online patch implementation (= registry ``updatable``)."""
    return tuple(sorted(_FACTORIES))


class OnlineEngine:
    """One updatable engine under MVCC: pinned-version queries + delta apply.

    ``apply`` lowers through the ``apply_deltas`` -> ``publish`` stages of
    ``core.build.update_plan`` (observable like any BuildPlan); queries go
    through ``pin()``/``release()`` so in-flight work keeps its snapshot
    while updates publish. Thread-safe: ``apply`` is serialized, pins are
    refcounted.
    """

    def __init__(
        self,
        name: str,
        x,
        *,
        mesh=None,
        axis_names=None,
        _snapshot=None,  # checkpoint leaves: restore path (see from_snapshot)
        _first_vid: int = 0,  # version-id continuity across a restore
        **build_kw,
    ):
        spec = registry.get(name)
        if not spec.updatable:
            raise ValueError(
                f"engine {name!r} is not updatable; have {registry.updatable_names()}"
            )
        x = jnp.asarray(x)
        if x.ndim != 1:
            raise ValueError(f"need a 1-D array, got shape {x.shape}")
        self.name = name
        self.spec = spec
        impl = _FACTORIES[name](x, mesh, axis_names, build_kw, snap=_snapshot)
        self.plan = impl.plan
        self._dtype = np.dtype(x.dtype)
        # Pin the plan-resolved knobs: a snapshot restored with these kwargs
        # re-plans to the exact same layout/threshold/mode deterministically.
        self._build_kw = dict(build_kw)
        for key in ("block_size", "threshold", "mode", "packed"):
            val = self.plan.meta.get(key)
            if val is not None:
                self._build_kw[key] = int(val) if isinstance(val, (int, np.integer)) else val
        self.store = VersionStore(first_vid=_first_vid)
        self._apply_lock = threading.Lock()
        self._failed: Optional[BaseException] = None
        self._failed_seq: Optional[int] = None
        self.store.publish(impl.state0, x.shape[0], x_host=impl.array())
        # The store owns version 0 now; keeping state0 on the impl would pin
        # its arrays for the engine's whole lifetime.
        self._impl = impl._replace(state0=None)
        self._uplan = build_mod.update_plan(
            name, self.plan.layout, self._stage_apply, self._stage_publish,
            meta=self.plan.meta,
        )

    # -- versions -------------------------------------------------------------

    @property
    def n(self) -> int:
        return self.store.current.n

    @property
    def current_vid(self) -> int:
        return self.store.current_vid

    @property
    def dtype(self) -> np.dtype:
        """Value dtype (what ``DeltaLog.coalesce`` must target)."""
        return self._dtype

    @property
    def poisoned(self) -> bool:
        """True once a mid-patch failure fail-stopped the applier."""
        return self._failed is not None

    def pin(self) -> Version:
        return self.store.pin()

    def release(self, vid: int) -> None:
        self.store.release(vid)

    def query(self, state, l, r):
        """The registry conformance query against one pinned version's state."""
        return self.spec.query(state, l, r)

    # -- checkpointing --------------------------------------------------------

    def snapshot(self):
        """``(arrays, meta)`` capturing the current version durably.

        ``arrays`` holds host copies of the structure leaves (single-host
        engines) or the logical array (mesh engines — restore rebuilds
        through the BuildPlan, bit-identical by the patched==rebuilt
        invariant); ``meta`` is the JSON-serializable identity
        (engine/vid/n/dtype + plan-resolved build kwargs). Taken under the
        apply lock so a snapshot never interleaves with a half-applied
        patch; refuses on a poisoned engine (the mirrors may have diverged
        from the published chain — exactly what a snapshot must never
        persist).
        """
        with self._apply_lock:
            if self._failed is not None:
                raise EnginePoisoned(self.name, self._failed_seq, self._failed)
            arrays = dict(self._impl.snapshot())
            meta = {
                "engine": self.name,
                "vid": int(self.store.current_vid),
                "n": int(self.n),
                "dtype": str(self._dtype),
                "build_kw": dict(self._build_kw),
            }
            return arrays, meta

    @classmethod
    def from_snapshot(cls, arrays, meta, *, mesh=None, axis_names=None):
        """Reconstruct an engine from ``snapshot()`` output.

        Version ids continue from the snapshot's vid (the restored initial
        publish IS that version). Meshes are not serializable — the caller
        supplies the current process's mesh for mesh engines.
        """
        x = jnp.asarray(np.ascontiguousarray(arrays["x"]))
        return cls(
            meta["engine"],
            x,
            mesh=mesh,
            axis_names=axis_names,
            _snapshot=arrays,
            _first_vid=int(meta["vid"]),
            **meta.get("build_kw", {}),
        )

    # -- mutation -------------------------------------------------------------

    def _stage_apply(self, state: dict) -> dict:
        batch: DeltaBatch = state["deltas"]
        new_state, patched = self._impl.patch(batch, self.store.current.state)
        for leaf in jax.tree_util.tree_leaves(new_state):
            if isinstance(leaf, jax.Array):
                leaf.block_until_ready()
        state["patched"] = new_state
        state["incremental"] = patched
        return state

    def _stage_publish(self, state: dict) -> dict:
        batch: DeltaBatch = state["deltas"]
        vid = self.store.publish(
            state.pop("patched"), batch.n_new, x_host=self._impl.array()
        )
        layout = self.plan.layout
        state["result"] = UpdateResult(
            version=vid,
            n=batch.n_new,
            patched=state["incremental"],
            n_writes=int(batch.idx.size),
            n_appended=int(batch.tail.size),
            seconds=0.0,
            touched_shards=(
                len(shard_batches(batch, layout.num_shards, layout.shard_len))
                if layout.num_shards > 1
                else 1
            ),
            publish_bytes=(
                int(self._impl.publish_bytes())
                if self._impl.publish_bytes is not None
                else 0
            ),
        )
        return state

    def _check_batch(self, batch: DeltaBatch) -> None:
        """Reject malformed batches BEFORE any mirror mutation: patching is
        in-place on shared host mirrors, so a mid-patch failure cannot be
        rolled back (it fail-stops the engine instead — see ``apply``)."""
        if batch.n_old != self.n:
            raise ValueError(
                f"update batch coalesced for n={batch.n_old}, engine is at "
                f"n={self.n} (coalesce against the current length)"
            )
        if batch.idx.size:
            if batch.idx.min() < 0 or batch.idx.max() >= batch.n_old:
                raise ValueError(
                    f"write positions [{batch.idx.min()}, {batch.idx.max()}] "
                    f"outside [0, {batch.n_old})"
                )
            if batch.idx.size != batch.val.size:
                raise ValueError("idx/val length mismatch")
        if batch.n_new != batch.n_old + batch.tail.size:
            raise ValueError(f"inconsistent batch lengths: {batch}")

    def apply(
        self,
        deltas,
        *,
        observer: Optional[Callable] = None,
        seq: Optional[int] = None,
    ) -> UpdateResult:
        """Apply one update batch; returns the published ``UpdateResult``.

        ``deltas`` is a ``DeltaLog`` (coalesced here against the current
        length) or an already-coalesced ``DeltaBatch`` (validated before any
        mutation). Serialized: updates publish in apply order. Queries
        against pinned versions proceed concurrently throughout. ``seq`` is
        the batch's journal sequence number when the caller journals
        (``fault.durable``) — recorded on failure so the poison error names
        the exact lost update.

        Failure semantics are **fail-stop**: malformed batches are rejected
        up front with the engine untouched, but an exception raised mid-patch
        (device OOM, a bug) may leave the host mirrors inconsistent with the
        published chain — the engine marks itself failed and every later
        ``apply`` raises ``EnginePoisoned`` (carrying the original exception
        and failing seq), rather than silently publishing a diverged version.
        Queries keep serving the already-published versions; a journal-replay
        restore yields a clean replacement engine.
        """
        with self._apply_lock:
            if self._failed is not None:
                raise EnginePoisoned(
                    self.name, self._failed_seq, self._failed
                ) from self._failed
            tr = obs_trace.get_tracer()
            if isinstance(deltas, DeltaLog):
                with tr.span("coalesce", attrs={"engine": self.name} if tr.enabled else None):
                    batch = deltas.coalesce(self.n, dtype=self._dtype)
                    if tr.enabled:
                        obs_trace.set_attr("n_writes", int(batch.idx.size))
                        obs_trace.set_attr("n_appended", int(batch.tail.size))
            else:
                batch = deltas
            self._check_batch(batch)
            t0 = time.perf_counter()
            try:
                res = build_mod.execute_update(self._uplan, batch, observer=observer)
            except BaseException as e:
                self._failed = e
                self._failed_seq = seq
                raise
            return res._replace(seconds=time.perf_counter() - t0)


def make_online(
    name: str, x, *, mesh=None, axis_names=None, **build_kw
) -> OnlineEngine:
    """Build engine ``name`` as an ``OnlineEngine`` over ``x``."""
    return OnlineEngine(name, x, mesh=mesh, axis_names=axis_names, **build_kw)
