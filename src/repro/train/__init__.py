"""repro.train — sharded step builders + fault-tolerant runner."""

from . import runner, steps
from .steps import make_prefill_step, make_serve_step, make_train_step

__all__ = ["runner", "steps", "make_prefill_step", "make_serve_step", "make_train_step"]
