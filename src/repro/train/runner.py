"""Fault-tolerant training runner.

Wraps the jitted step with the operational machinery a 1000+-node job needs:

  * periodic async checkpoints (atomic; torn writes impossible);
  * crash recovery: on any step exception, reload the latest complete
    checkpoint and replay — the data pipeline is a pure function of
    (seed, step) so recovery is bitwise-deterministic;
  * straggler watchdog: a wall-clock budget per step (median of recent
    steps × multiplier); overruns are logged and counted — on a real pod
    this feeds the controller that re-shards around slow hosts, here it
    exercises the detection path;
  * retry budget so a persistently failing job stops instead of looping.
"""

from __future__ import annotations

import logging
import time
from dataclasses import dataclass, field
from typing import Callable

from repro import checkpoint
from repro.data import pipeline as data_pipeline

log = logging.getLogger("repro.runner")

__all__ = ["RunnerConfig", "run_training"]


@dataclass
class RunnerConfig:
    total_steps: int
    ckpt_dir: str
    ckpt_every: int = 50
    seed: int = 0
    max_retries: int = 3
    straggler_factor: float = 3.0
    log_every: int = 10
    data_period: int = 0  # >0: cycle the synthetic stream (memorizable)


@dataclass
class RunnerReport:
    steps_done: int = 0
    restarts: int = 0
    straggler_events: int = 0
    losses: list = field(default_factory=list)


def run_training(
    step_fn: Callable,
    params,
    opt_state,
    cfg,
    batch: int,
    seq_len: int,
    rcfg: RunnerConfig,
    *,
    fault_hook: Callable[[int], None] | None = None,
) -> RunnerReport:
    """Run ``total_steps``, surviving injected/real faults. Returns a report."""
    report = RunnerReport()
    start = 0

    latest = checkpoint.latest_step(rcfg.ckpt_dir)
    if latest is not None:
        state = checkpoint.restore(
            rcfg.ckpt_dir, latest, {"params": params, "opt": opt_state}
        )
        params, opt_state = state["params"], state["opt"]
        start = latest
        log.info("resumed from checkpoint step %d", latest)

    retries = 0
    step = start
    durations: list[float] = []
    while step < rcfg.total_steps:
        try:
            if fault_hook is not None:
                fault_hook(step)  # test hook: raise to simulate a node loss
            data_step = step % rcfg.data_period if rcfg.data_period else step
            batch_data = data_pipeline.synthetic_batch(
                cfg, batch, seq_len, seed=rcfg.seed, step=data_step
            )
            t0 = time.monotonic()
            params, opt_state, metrics = step_fn(params, opt_state, batch_data)
            loss = float(metrics["loss"])
            dt = time.monotonic() - t0
            # straggler detection against the running median
            if len(durations) >= 5:
                med = sorted(durations[-20:])[len(durations[-20:]) // 2]
                if dt > rcfg.straggler_factor * med:
                    report.straggler_events += 1
                    log.warning("straggler: step %d took %.3fs (median %.3fs)", step, dt, med)
            durations.append(dt)
            report.losses.append(loss)
            step += 1
            report.steps_done += 1
            retries = 0
            if step % rcfg.ckpt_every == 0 or step == rcfg.total_steps:
                checkpoint.save(
                    rcfg.ckpt_dir, step, {"params": params, "opt": opt_state},
                    background=True, meta={"loss": loss},
                )
            if step % rcfg.log_every == 0:
                log.info("step %d loss %.4f (%.3fs)", step, loss, dt)
        except Exception as e:  # noqa: BLE001 — any fault triggers recovery
            retries += 1
            report.restarts += 1
            log.warning("step %d failed (%s); recovery attempt %d", step, e, retries)
            if retries > rcfg.max_retries:
                raise
            checkpoint.wait_pending()
            latest = checkpoint.latest_step(rcfg.ckpt_dir)
            if latest is not None:
                state = checkpoint.restore(
                    rcfg.ckpt_dir, latest, {"params": params, "opt": opt_state}
                )
                params, opt_state = state["params"], state["opt"]
                step = latest
            else:
                step = start

    checkpoint.wait_pending()
    report.params = params
    report.opt_state = opt_state
    return report
