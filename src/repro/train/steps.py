"""Step builders: jitted, sharded train/prefill/serve steps for any mesh.

``make_train_step`` supports microbatch gradient accumulation (lax.scan, so
the weight all-gathers/grad reduce-scatters pipeline with compute under XLA's
latency-hiding scheduler) and optional int8 error-feedback gradient
compression at the data-parallel boundary.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import model as model_lib
from repro.optim import adamw, compress as compress_lib
from repro.launch import sharding as shard_rules

__all__ = ["make_train_step", "make_prefill_step", "make_serve_step", "TrainState"]


def _with_mesh_axes(cfg, mesh: Mesh, batch: int | None = None):
    """Inject mesh axis names so in-model sharding constraints can refer to
    them (only when the mesh actually has a model axis)."""
    if cfg.parallelism == "fsdp":
        # pure FSDP: the batch owns every axis it divides; no TP/SP inside
        dp = shard_rules.batch_axes(cfg, mesh, batch) if batch else tuple(mesh.axis_names)
        return dataclasses.replace(
            cfg,
            mesh_dp=dp or (),
            mesh_model="",
            mesh_model_size=0,
            mesh_axis_sizes=tuple(mesh.shape.items()),
        )
    model_axis = "model" if "model" in mesh.axis_names else ""
    return dataclasses.replace(
        cfg,
        mesh_dp=shard_rules.dp_axes(mesh),
        mesh_model=model_axis,
        mesh_model_size=mesh.shape[model_axis] if model_axis else 0,
        mesh_axis_sizes=tuple(mesh.shape.items()),
    )


def make_train_step(
    cfg,
    mesh: Mesh,
    *,
    lr_fn,
    batch: int,
    seq_len: int,
    microbatches: int = 1,
    grad_compress: bool = False,
):
    """Returns (jitted step, in/out shardings dict for inspection)."""
    cfg = _with_mesh_axes(cfg, mesh, batch)
    pspecs = shard_rules.param_specs(cfg, mesh)
    ospecs = shard_rules.opt_state_specs(pspecs)
    bspecs = shard_rules.batch_specs(cfg, mesh, batch, seq_len, "train")

    def loss_fn(params, mb):
        return model_lib.train_loss(params, mb, cfg)

    def step(params, opt_state, batch_data):
        if microbatches > 1:
            def split(x):
                return x.reshape(microbatches, x.shape[0] // microbatches, *x.shape[1:])

            mbs = jax.tree.map(split, batch_data)

            def accum(carry, mb):
                gsum, lsum = carry
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                gsum = jax.tree.map(lambda a, b: a + b.astype(jnp.float32), gsum, g)
                return (gsum, lsum + l), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), _ = jax.lax.scan(accum, (g0, jnp.float32(0.0)), mbs)
            grads = jax.tree.map(lambda g: g / microbatches, gsum)
            loss = lsum / microbatches
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch_data)

        if grad_compress:
            # int8 + error feedback carried in opt-state-adjacent buffer is
            # wired by the caller; stateless variant here for the jit path
            grads = jax.tree.map(
                lambda g: compress_lib.decompress(*compress_lib.compress(g)), grads
            )

        params, opt_state, metrics = adamw.update(
            grads, opt_state, lr_fn=lr_fn, param_dtype=cfg.param_dtype
        )
        return params, opt_state, {"loss": loss, **metrics}

    in_sh = (
        shard_rules.named(mesh, pspecs),
        shard_rules.named(mesh, ospecs),
        shard_rules.named(mesh, bspecs),
    )
    out_sh = (
        shard_rules.named(mesh, pspecs),
        shard_rules.named(mesh, ospecs),
        None,
    )
    # With f32 params the identity cast makes returned params alias
    # opt.master (XLA dedups equal outputs into one buffer), so donation
    # would fault with "donate the same buffer twice" on the next call.
    # bf16 params never alias the f32 master — donate both (production).
    donate = () if cfg.param_dtype == jnp.float32 else (0, 1)
    jitted = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh, donate_argnums=donate)
    batch_sh = shard_rules.named(mesh, bspecs)

    def call(params, opt_state, batch_data):
        # host-built batches arrive replicated/committed; place them on the
        # expected sharding (jit rejects mismatched committed args)
        batch_data = jax.device_put(batch_data, batch_sh)
        return jitted(params, opt_state, batch_data)

    call.lower = jitted.lower  # dry-run entry point
    return call, {"params": pspecs, "opt": ospecs, "batch": bspecs}


def make_prefill_step(cfg, mesh: Mesh, *, batch: int, seq_len: int):
    cfg = _with_mesh_axes(cfg, mesh, batch)
    pspecs = shard_rules.param_specs(cfg, mesh)
    ispec = shard_rules.batch_specs(cfg, mesh, batch, seq_len, "prefill")
    cspecs = shard_rules.cache_spec(cfg, mesh, batch, seq_len + cfg.cache_pad)
    bdim = shard_rules.batch_axes(cfg, mesh, batch)
    vdim = "model" if (cfg.parallelism != "fsdp" and "model" in mesh.axis_names) else None
    lspec = P(bdim, None, vdim)

    def step(params, inputs):
        return model_lib.prefill(params, inputs, cfg)

    jitted = jax.jit(
        step,
        in_shardings=(shard_rules.named(mesh, pspecs), NamedSharding(mesh, ispec)),
        out_shardings=(NamedSharding(mesh, lspec), shard_rules.named(mesh, cspecs)),
    )

    def call(params, inputs):
        inputs = jax.device_put(inputs, NamedSharding(mesh, ispec))
        return jitted(params, inputs)

    call.lower = jitted.lower  # dry-run entry point
    return call, {"params": pspecs, "input": ispec, "cache": cspecs}


def make_serve_step(cfg, mesh: Mesh, *, batch: int, capacity: int):
    """One-token decode step against a capacity-sized cache."""
    cfg = _with_mesh_axes(cfg, mesh, batch)
    pspecs = shard_rules.param_specs(cfg, mesh)
    tspec = shard_rules.batch_specs(cfg, mesh, batch, 1, "decode")
    cspecs = shard_rules.cache_spec(cfg, mesh, batch, capacity)
    bdim = shard_rules.batch_axes(cfg, mesh, batch)
    vdim = "model" if (cfg.parallelism != "fsdp" and "model" in mesh.axis_names) else None
    lspec = P(bdim, None, vdim)

    def step(params, token, cache):
        return model_lib.decode_step(params, token, cache, cfg)

    jitted = jax.jit(
        step,
        in_shardings=(
            shard_rules.named(mesh, pspecs),
            NamedSharding(mesh, tspec),
            shard_rules.named(mesh, cspecs),
        ),
        out_shardings=(NamedSharding(mesh, lspec), shard_rules.named(mesh, cspecs)),
        donate_argnums=(2,),
    )

    def call(params, token, cache):
        token = jax.device_put(token, NamedSharding(mesh, tspec))
        return jitted(params, token, cache)

    call.lower = jitted.lower  # dry-run entry point
    return call, {"params": pspecs, "token": tspec, "cache": cspecs}


def place_state(mesh: Mesh, specs: dict, params, opt_state=None):
    """device_put params/opt onto the shardings a step was built with
    (jit rejects committed args whose sharding mismatches in_shardings)."""
    params = jax.device_put(params, shard_rules.named(mesh, specs["params"]))
    if opt_state is None:
        return params
    opt_state = jax.device_put(opt_state, shard_rules.named(mesh, specs["opt"]))
    return params, opt_state


def _size(mesh: Mesh, axes) -> int:
    s = 1
    for a in axes:
        s *= mesh.shape[a]
    return s


class TrainState:
    """Convenience bundle used by launch/train.py and the examples."""

    def __init__(self, params, opt_state, step: int = 0):
        self.params = params
        self.opt_state = opt_state
        self.step = step
