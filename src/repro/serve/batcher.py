"""Pure micro-batching core: coalesce, power-of-two pad, scatter back.

Concurrent client requests are concatenated *in arrival order* into one
engine batch, the batch is padded to the next power of two, and after the
engine answers, each request gets back exactly its slice. Order-preserving
concatenation is what makes the scatter-back trivially exact: the engines
already implement exact leftmost-tie semantics per query, and no
re-ordering ever happens across the coalesce/scatter round-trip.

Padding every launch to a power-of-two bucket bounds the engine's jit
cache: however client batch sizes vary, a server with ``max_batch`` queries
per launch compiles at most ``log2(bucket(max_batch)) + 1`` shapes per
engine path. Pad queries are the trivial ``(0, 0)`` range (cheap, always
valid) and are sliced off before the scatter-back.

This module is deliberately free of threads and clocks so the exact
coalescing/padding/scatter logic unit-tests against the numpy oracle;
``server.RMQServer`` supplies the queue, deadline loop, and worker pool.
"""

from __future__ import annotations

from typing import List, NamedTuple, Sequence, Tuple

import numpy as np

__all__ = ["MicroBatch", "bucket", "coalesce", "scatter_back"]


def bucket(b: int) -> int:
    """Smallest power of two >= b: the padded launch shape for a b-query batch."""
    if b < 1:
        raise ValueError(f"batch size must be >= 1, got {b}")
    return 1 << (b - 1).bit_length()


class MicroBatch(NamedTuple):
    """One coalesced engine launch assembled from whole client requests."""

    l: np.ndarray  # (bucket(n_queries),) int32; tail padded with 0
    r: np.ndarray  # (bucket(n_queries),) int32; tail padded with 0
    n_queries: int  # valid prefix length (pre-padding)
    spans: Tuple[Tuple[int, int], ...]  # per-request (offset, length), arrival order

    @property
    def padded_size(self) -> int:
        """The launch shape actually compiled/executed (== bucket(n_queries))."""
        return self.l.shape[0]

    @property
    def fill_fraction(self) -> float:
        """Real queries / padded slots — the coalescing-efficiency signal the
        flush span exports (1.0 = the pad cost nothing)."""
        return self.n_queries / self.padded_size if self.padded_size else 0.0


def coalesce(ls: Sequence[np.ndarray], rs: Sequence[np.ndarray]) -> MicroBatch:
    """Concatenate per-request (l, r) in arrival order and pad to the bucket.

    Raises ``ValueError`` on a malformed request set: `ls`/`rs` of different
    lengths, or any request whose l and r arrays are not equal-length 1-D.
    Sizing the batch from `ls` alone while iterating ``zip(ls, rs)`` used to
    turn such mismatches into zero-filled slots silently answered as (0, 0)
    RMQs — wrong answers, not an error.
    """
    if len(ls) != len(rs):
        raise ValueError(
            f"coalesce: {len(ls)} l-arrays vs {len(rs)} r-arrays (must match)"
        )
    ls = [np.asarray(a) for a in ls]
    rs = [np.asarray(a) for a in rs]
    for i, (la, ra) in enumerate(zip(ls, rs)):
        if la.ndim != 1 or ra.ndim != 1 or la.shape != ra.shape:
            raise ValueError(
                f"coalesce: request {i} l/r must be equal-length 1-D arrays, "
                f"got shapes {la.shape} and {ra.shape}"
            )
    sizes = [a.shape[0] for a in ls]
    b = int(sum(sizes))
    bp = bucket(b)
    l = np.zeros(bp, np.int32)
    r = np.zeros(bp, np.int32)
    spans: List[Tuple[int, int]] = []
    off = 0
    for la, ra in zip(ls, rs):
        k = la.shape[0]
        l[off : off + k] = la
        r[off : off + k] = ra
        spans.append((off, k))
        off += k
    return MicroBatch(l=l, r=r, n_queries=b, spans=tuple(spans))


def scatter_back(mb: MicroBatch, idx, val) -> List[Tuple[np.ndarray, np.ndarray]]:
    """Slice batch results back per request (arrival order, pads dropped).

    Copies so a request's result never pins the whole batch's buffers.
    """
    idx = np.asarray(idx)
    val = np.asarray(val)
    return [(idx[o : o + k].copy(), val[o : o + k].copy()) for o, k in mb.spans]
