"""Async RMQ server: request queue -> deadline micro-batcher -> engine pool.

``RMQServer`` accepts variable-size query batches from concurrent clients
and coalesces them into power-of-two padded engine launches:

    submit(l, r) ─► admission control (bounded in-flight requests)
        └─► request queue ─► batcher thread
              │   flush when the coalesced batch reaches ``max_batch``
              │   queries OR the oldest pending request ages past
              │   ``deadline_s`` — latency is bounded by the deadline even
              │   at low offered load
              └─► microbatch queue ─► engine-pool worker threads
                    └─► scatter-back, per-request futures + latency stamps
    submit_update(deltas) ─► batcher barrier (flush what's pending first)
        └─► update queue ─► single updater thread
              └─► OnlineEngine.apply: patch + MVCC publish

Admission control bounds *in-flight* requests (queued + batching +
executing): past ``max_pending``, ``submit`` raises ``ServerOverloaded`` —
the backpressure signal open-loop clients drop on and closed-loop clients
retry on — so a stalled engine degrades into rejections instead of an
unbounded queue. Per-request latency decomposes as queue (submit -> flush)
plus service (flush -> done); ``stats()`` aggregates p50/p99 and sustained
throughput over the serving interval.

The engine is any ``(l, r) -> (idx, val)`` callable — typically a registry
``EngineSpec.query`` closed over its built state (``launch.serve`` wires
exactly that). jax dispatch is thread-safe; ``workers > 1`` overlaps one
batch's host-side partition/scatter work with another's device execution.

**Mutation under live traffic**: constructed over a ``repro.update``
``OnlineEngine`` instead of a bare callable, the server also accepts
``submit_update(DeltaLog)``. Updates interleave with query launches: the
batcher flushes pending queries first (so requests submitted before an
update are answered against the pre-update version), each flushed
microbatch **pins** the then-current MVCC version and is answered entirely
against that snapshot — mutation never blocks serving, and a query never
sees a half-applied update. A single updater thread applies updates in
submission order (publish order = consistency order). ``stats()`` adds
update-latency percentiles and version lag (how many versions were
published while a query batch was in flight).

**Adaptive deadline** (``ServeConfig.adaptive_deadline``): the batcher
shrinks its coalescing deadline while launches fill up (sustained load —
waiting longer only adds latency) and grows it back toward
``deadline_max_s`` when flushes are deadline-triggered and near-empty
(idle — waiting coalesces more per launch). The effective-deadline
trajectory is recorded per flush in ``ServeStats``.
"""

from __future__ import annotations

import queue
import threading
import time
from concurrent.futures import Future
from dataclasses import dataclass
from typing import Callable, List, NamedTuple, Optional, Sequence, Set, Tuple

import numpy as np

from repro.core import hybrid as _hybrid
from repro.fault.inject import InjectedFault
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import get_tracer

from .batcher import MicroBatch, bucket, coalesce, scatter_back

__all__ = [
    "DeadlineExceeded",
    "EngineFailure",
    "RMQServer",
    "RequestResult",
    "RequestTiming",
    "ServeConfig",
    "ServeStats",
    "ServerClosed",
    "ServerOverloaded",
    "StaleVersion",
]

_INT32_MAX = np.iinfo(np.int32).max
_STOP = object()

# On the CPU host platform, two overlapping executions of a mesh-sharded
# query deadlock: each run's cross-device AllReduce parks 8 rendezvous
# participants on the shared intra-op pool and neither set can complete.
# ONE process-wide gate — replica fleets run several servers over carved
# device groups, and two *servers'* sharded launches deadlock exactly the
# way two workers' do (the groups still share the host thread pool).
_CPU_MESH_LAUNCH_GATE = threading.Lock()


class ServerClosed(RuntimeError):
    """submit() after close() — or a request still unresolved when the
    server shut down (close() fails every leftover future with this rather
    than leaving a client hanging forever)."""


class ServerOverloaded(RuntimeError):
    """Admission control rejected the request: too many in flight."""


class EngineFailure(RuntimeError):
    """A query launch failed after exhausting its retry budget.

    Typed and (by default) retryable: the underlying failure is a worker
    crash, an injected fault, or an engine exception — resubmitting the
    request may well succeed (the supervisor restarts crashed workers, the
    breaker may have routed to the fallback meanwhile). ``cause`` holds the
    original exception.
    """

    def __init__(self, msg: str, *, cause: Optional[BaseException] = None, retryable: bool = True):
        super().__init__(msg)
        self.cause = cause
        self.retryable = retryable


class DeadlineExceeded(RuntimeError):
    """The request's ``request_timeout_s`` deadline passed before an engine
    answered it (in queue, or across too many retries)."""


class StaleVersion(RuntimeError):
    """``submit(min_version=V)`` on a server still serving a version < V.

    The read-your-writes signal: a fleet front door catches this and routes
    the request to (or waits for) a replica that has published V.
    """


@dataclass(frozen=True)
class ServeConfig:
    deadline_s: float = 2e-3  # max coalescing wait for the oldest request
    max_batch: int = 4096  # flush once the coalesced batch reaches this
    max_pending: int = 4096  # in-flight request bound (admission control)
    workers: int = 1  # engine-pool threads
    n: Optional[int] = None  # if set, submit validates r < n
    val_dtype: object = np.float32  # engine value dtype (empty-request results)
    # Adaptive deadline: start at deadline_s, halve toward deadline_min_s on
    # size-triggered flushes (sustained load), grow toward deadline_max_s on
    # near-empty deadline flushes (idle). None bounds derive from deadline_s.
    adaptive_deadline: bool = False
    deadline_min_s: Optional[float] = None  # default: deadline_s / 8
    deadline_max_s: Optional[float] = None  # default: deadline_s * 4
    # Crash-safe serving (supervised workers, retry, circuit breaker).
    request_timeout_s: Optional[float] = None  # per-request deadline (None = no limit)
    max_retries: int = 0  # automatic resubmits after a failed launch
    breaker_threshold: int = 0  # consecutive failures to trip (0 = disabled)
    breaker_cooldown_s: float = 0.05  # open time before a half-open health probe
    worker_backoff_s: float = 0.01  # first restart delay for a crashed worker
    worker_backoff_max_s: float = 1.0  # exponential backoff cap
    # Fleet routing hint: which query regime this server's pool is hot for
    # ("short" = blocked/kernel path, "long" = sparse-table path, None =
    # no affinity). Warmup compiles the hot regime first, and the fleet
    # front door routes matching batches here (DESIGN.md §11).
    regime_affinity: Optional[str] = None

    def __post_init__(self):
        if self.deadline_s < 0 or self.max_batch < 1 or self.max_pending < 1 or self.workers < 1:
            raise ValueError(f"invalid ServeConfig: {self}")
        if self.adaptive_deadline and self.deadline_s <= 0:
            raise ValueError("adaptive_deadline requires deadline_s > 0")
        lo, hi = self.deadline_bounds()
        if not 0 <= lo <= self.deadline_s <= hi:
            raise ValueError(
                f"deadline bounds must satisfy 0 <= min <= deadline_s <= max: {self}"
            )
        if (
            self.max_retries < 0
            or self.breaker_threshold < 0
            or self.breaker_cooldown_s < 0
            or self.worker_backoff_s <= 0
            or self.worker_backoff_max_s < self.worker_backoff_s
        ):
            raise ValueError(f"invalid ServeConfig: {self}")
        if self.request_timeout_s is not None and self.request_timeout_s <= 0:
            raise ValueError(f"request_timeout_s must be > 0 or None: {self}")
        if self.regime_affinity not in (None, "short", "long"):
            raise ValueError(
                f"regime_affinity must be None, 'short', or 'long': {self.regime_affinity!r}"
            )

    def deadline_bounds(self) -> Tuple[float, float]:
        """(min, max) the adaptive deadline moves within."""
        lo = self.deadline_min_s if self.deadline_min_s is not None else self.deadline_s / 8
        hi = self.deadline_max_s if self.deadline_max_s is not None else self.deadline_s * 4
        return lo, hi


class RequestTiming(NamedTuple):
    queue_s: float  # submit -> batch flush (coalescing wait)
    service_s: float  # flush -> engine done
    total_s: float


class RequestResult(NamedTuple):
    idx: np.ndarray  # (B,) int32 leftmost argmin per query
    val: np.ndarray  # (B,) corresponding values
    timing: RequestTiming
    version: Optional[int] = None  # MVCC version answered against (online only)


class _Request:
    __slots__ = ("l", "r", "future", "t_submit", "t_flush", "retries", "span", "qspan")

    def __init__(self, l, r, t_submit):
        self.l = l
        self.r = r
        self.future: Future = Future()
        self.t_submit = t_submit
        self.t_flush = 0.0
        self.retries = 0  # failed launches this request has survived so far
        self.span = None  # "request" root span (tracing enabled only)
        self.qspan = None  # open "queue" span: submit/requeue -> flush


class _UpdateReq:
    __slots__ = ("deltas", "future", "t_submit")

    def __init__(self, deltas, t_submit):
        self.deltas = deltas
        self.future: Future = Future()
        self.t_submit = t_submit


class ServeStats(NamedTuple):
    served_requests: int
    served_queries: int
    rejected_requests: int
    n_batches: int
    mean_batch_requests: float
    mean_batch_queries: float
    padded_sizes: Tuple[int, ...]  # distinct launch shapes (jit-cache bound)
    p50_queue_s: float
    p99_queue_s: float
    p50_total_s: float
    p99_total_s: float
    throughput_qps: float  # served queries / (first submit -> last done)
    # Per-launch regime split (short, long) sub-batch sizes, as reported by
    # the range-adaptive dispatcher — empty for single-path engines. The
    # measurement regime-aware routing (server-level split, per-engine
    # pools) will act on.
    regime_splits: Tuple[Tuple[int, int], ...] = ()
    # Online-update accounting (servers built over an OnlineEngine).
    applied_updates: int = 0
    p50_update_s: float = 0.0  # submit_update -> published
    p99_update_s: float = 0.0
    # Per-query-launch version lag: versions published between a batch's
    # pin and its completion (0 = answered against the newest version).
    version_lags: Tuple[int, ...] = ()
    # Effective batcher deadline after each flush (adaptive mode only).
    deadline_trajectory: Tuple[float, ...] = ()
    # Crash-safety accounting (supervision / retry / breaker / fallback).
    degraded_launches: int = 0  # launches served by the degraded fallback
    worker_restarts: int = 0  # crashed workers the supervisor restarted
    retried_requests: int = 0  # failed-launch requests resubmitted to the batcher
    expired_requests: int = 0  # requests failed on their request_timeout_s deadline
    failed_requests: int = 0  # requests failed with EngineFailure (retries exhausted)
    breaker_trips: int = 0  # closed -> open transitions of the circuit breaker

    @property
    def short_queries(self) -> int:
        return sum(s for s, _ in self.regime_splits)

    @property
    def long_queries(self) -> int:
        return sum(g for _, g in self.regime_splits)

    @property
    def mixed_batches(self) -> int:
        """Launches the dispatcher actually split (both regimes non-empty)."""
        return sum(1 for s, g in self.regime_splits if s and g)

    @property
    def version_lag_max(self) -> int:
        return max(self.version_lags) if self.version_lags else 0

    @property
    def version_lag_mean(self) -> float:
        return float(np.mean(self.version_lags)) if self.version_lags else 0.0

    def summary(self) -> str:
        out = (
            f"{self.served_requests} reqs / {self.served_queries} RMQs in "
            f"{self.n_batches} microbatches (mean {self.mean_batch_requests:.1f} "
            f"reqs, {self.mean_batch_queries:.1f} RMQs; padded shapes "
            f"{list(self.padded_sizes)}); latency p50 {self.p50_total_s*1e3:.2f} ms "
            f"p99 {self.p99_total_s*1e3:.2f} ms (queue p50 "
            f"{self.p50_queue_s*1e3:.2f} ms); {self.throughput_qps:,.0f} RMQ/s; "
            f"rejected {self.rejected_requests}"
        )
        if self.regime_splits:
            out += (
                f"; regime split {self.short_queries} short / "
                f"{self.long_queries} long RMQs, {self.mixed_batches}/"
                f"{len(self.regime_splits)} launches mixed"
            )
        if self.applied_updates:
            out += (
                f"; {self.applied_updates} updates (p50 "
                f"{self.p50_update_s*1e3:.2f} ms, p99 {self.p99_update_s*1e3:.2f} ms), "
                f"version lag max {self.version_lag_max} "
                f"mean {self.version_lag_mean:.2f}"
            )
        if len(self.deadline_trajectory) >= 2:
            out += (
                f"; adaptive deadline {self.deadline_trajectory[0]*1e3:.2f} -> "
                f"{self.deadline_trajectory[-1]*1e3:.2f} ms"
            )
        elif self.deadline_trajectory:
            # One adjusted flush: "X -> X ms" would misread as a flat
            # trajectory, so report the single point and the flush count.
            out += (
                f"; adaptive deadline {self.deadline_trajectory[0]*1e3:.2f} ms "
                f"(1 adjusted flush)"
            )
        if (
            self.worker_restarts
            or self.retried_requests
            or self.degraded_launches
            or self.expired_requests
            or self.failed_requests
            or self.breaker_trips
        ):
            out += (
                f"; faults: {self.worker_restarts} worker restarts, "
                f"{self.retried_requests} retried / {self.expired_requests} expired / "
                f"{self.failed_requests} failed reqs, breaker tripped "
                f"{self.breaker_trips}x ({self.degraded_launches} degraded launches)"
            )
        return out


class RMQServer:
    """Deadline micro-batching server over one built RMQ engine."""

    def __init__(
        self,
        query_fn: Optional[Callable] = None,
        config: Optional[ServeConfig] = None,
        *,
        warmup_bounds: Optional[Callable] = None,
        online=None,  # repro.update.OnlineEngine or fault.DurableEngine
        restore: Optional[str] = None,  # DurableEngine root to restore from
        mesh=None,  # mesh/axis_names forwarded to a restore (sharded engines)
        axis_names=None,
        fault_plan=None,  # fault.FaultPlan (or check callable): worker_query site
        fallback: Optional[Callable] = None,  # degraded (l, r) -> (idx, val)
        tracer=None,  # obs.Tracer (None = the process-global tracer)
        metrics=None,  # obs.MetricsRegistry (None = a fresh private registry)
        trace_attrs=None,  # static attrs stamped on every launch span
        **overrides,
    ):
        if sum(x is not None for x in (query_fn, online, restore)) != 1:
            raise ValueError("pass exactly one of query_fn, online, or restore")
        if restore is not None:
            # Crash recovery at construction: latest checkpoint + journal
            # suffix replay -> bit-identical to the never-crashed engine.
            from repro.fault.durable import DurableEngine

            online = DurableEngine.restore(
                restore, mesh=mesh, axis_names=axis_names, fault=fault_plan
            )
        self._online = online
        # Serialize mesh-sharded launches on CPU through the process-wide
        # gate (see _CPU_MESH_LAUNCH_GATE) — execution fully drains
        # (np.asarray) before the gate releases. Real accelerators queue
        # per-device and skip the gate.
        self._launch_gate: Optional[threading.Lock] = None
        spec = getattr(online, "spec", None)
        if spec is not None and getattr(spec, "needs_mesh", False):
            import jax

            if jax.default_backend() == "cpu":
                self._launch_gate = _CPU_MESH_LAUNCH_GATE
        if online is not None:
            # Warmup / direct path: answer against the then-current version.
            def query_fn(l, r):
                ver = online.pin()
                try:
                    return online.query(ver.state, l, r)
                finally:
                    online.release(ver.vid)

        self._query_fn = query_fn
        self._warmup_bounds = warmup_bounds  # (size) -> [(l, r), ...] per regime
        self._cfg = config if config is not None else ServeConfig(**overrides)
        self._inq: "queue.SimpleQueue" = queue.SimpleQueue()
        self._mbq: "queue.SimpleQueue" = queue.SimpleQueue()
        self._updq: "queue.SimpleQueue" = queue.SimpleQueue()
        self._lock = threading.Lock()
        self._inflight = 0
        self._closed = False
        self._started = False
        self._threads: List[threading.Thread] = []
        # Supervision + breaker state. _live tracks every admitted request /
        # update whose future is unresolved, so close() can fail leftovers
        # instead of leaving clients hanging.
        self._live: Set[object] = set()
        self._deaths: "queue.SimpleQueue" = queue.SimpleQueue()  # crashed worker slots
        self._fault = fault_plan.check if hasattr(fault_plan, "check") else fault_plan
        self._fallback_fn = fallback
        self._degraded = None  # lazy fault.DegradedFallback (online servers)
        if self._cfg.breaker_threshold > 0 and online is None and fallback is None:
            raise ValueError(
                "breaker_threshold > 0 needs a degraded path: an online engine "
                "(version x_host fallback) or an explicit fallback callable"
            )
        self._brk_fails = 0  # consecutive primary-launch failures
        self._brk_open = False
        self._brk_opened_t = 0.0
        self._brk_probing = False
        # Observability. The tracer defaults to the process-global one
        # (disabled unless `launch/serve.py --trace` or a test installed an
        # enabled tracer); the registry is private per server unless shared
        # (fleets pass one in to read replica metrics at the front door).
        # ServeStats is rendered FROM these instruments in stats(), so the
        # registry and the NamedTuple reconcile by construction.
        self._tracer = tracer if tracer is not None else get_tracer()
        self.metrics: MetricsRegistry = metrics if metrics is not None else MetricsRegistry()
        m = self.metrics
        ta = dict(trace_attrs) if trace_attrs else {}
        ta.setdefault(
            "engine",
            getattr(online, "name", None)
            or getattr(query_fn, "__name__", None)
            or "engine",
        )
        self._trace_attrs = ta
        self._m_out = {  # request terminal outcomes
            k: m.counter("serve_requests_total", outcome=k)
            for k in ("served", "rejected", "retried", "expired", "failed")
        }
        self._m_queries = m.counter("serve_queries_total")
        self._m_batches = m.counter("serve_batches_total")
        self._m_launches = {
            pool: m.counter("serve_launches_total", pool=pool)
            for pool in ("primary", "degraded")
        }
        self._m_regime = {
            reg: m.counter("serve_regime_queries_total", regime=reg)
            for reg in ("short", "long")
        }
        self._m_restarts = m.counter("serve_worker_restarts_total")
        self._m_trips = m.counter("serve_breaker_trips_total")
        self._m_updates = {
            k: m.counter("serve_updates_total", outcome=k) for k in ("applied", "failed")
        }
        self._h_queue = m.histogram("serve_queue_wait_s")
        self._h_service = m.histogram("serve_service_s")
        self._h_total = m.histogram("serve_total_s")
        self._h_update = m.histogram("serve_update_s")
        self._h_launch = {
            pool: m.histogram("serve_launch_s", pool=pool)
            for pool in ("primary", "degraded")
        }
        self._g_inflight = m.gauge("serve_inflight")
        self._g_deadline = m.gauge("serve_deadline_eff_s")
        self._g_vlag = m.gauge("serve_version_lag")
        # Structural accumulators (under _lock) — sequences/sets the scalar
        # instruments can't represent; ServeStats carries them verbatim.
        self._splits: List[Tuple[int, int]] = []  # per-launch (short, long)
        self._padded: Set[int] = set()
        self._lags: List[int] = []  # per-launch version lag
        self._deadlines: List[float] = []  # effective deadline per flush
        self._t_first_submit: Optional[float] = None
        self._t_last_done: Optional[float] = None

    @property
    def config(self) -> ServeConfig:
        return self._cfg

    @property
    def online(self):
        """The OnlineEngine/DurableEngine this server serves (None for bare
        query_fn servers). Fleet routing reads ``online.current_vid`` here."""
        return self._online

    @property
    def affinity(self) -> Optional[str]:
        """The regime this server's pool is hot for (``ServeConfig``)."""
        return self._cfg.regime_affinity

    # -- lifecycle ----------------------------------------------------------

    def start(self) -> "RMQServer":
        if self._started:
            return self
        self._started = True
        self._threads = [threading.Thread(target=self._batch_loop, daemon=True, name="rmq-batcher")]
        for i in range(self._cfg.workers):
            self._threads.append(
                threading.Thread(
                    target=self._worker_main, args=(i,), daemon=True, name=f"rmq-worker-{i}"
                )
            )
        if self._online is not None:
            # ONE updater: publish order == submission order == version order.
            self._threads.append(
                threading.Thread(target=self._update_loop, daemon=True, name="rmq-updater")
            )
        self._threads.append(
            threading.Thread(target=self._supervisor_loop, daemon=True, name="rmq-supervisor")
        )
        for t in self._threads:
            t.start()
        return self

    def __enter__(self) -> "RMQServer":
        return self.start()

    def __exit__(self, *exc):
        self.close()

    def close(self, timeout: Optional[float] = None):
        """Stop accepting, drain everything already admitted, join threads.

        With a ``timeout``, each join waits at most that long; any request or
        update future still unresolved afterwards (a wedged engine, a worker
        that died with no supervisor restart in time) is failed with
        ``ServerClosed`` — a client blocked on ``future.result()`` always
        unblocks.
        """
        with self._lock:
            if self._closed:
                return
            self._closed = True
            if self._started:
                self._inq.put(_STOP)  # under _lock: serialized against submit
        self._deaths.put(_STOP)  # supervisor exits; no restarts after close
        for t in self._threads:
            t.join(timeout)
        with self._lock:
            leftovers = [q for q in self._live if not q.future.done()]
            self._live.clear()
            self._inflight = 0
        for q in leftovers:
            if isinstance(q, _Request):
                self._trace_resolve(q, "closed")
            self._fail_future(
                q, ServerClosed("server closed before the request completed")
            )

    def warmup(self, sizes: Optional[Sequence[int]] = None):
        """Compile every padded launch shape before traffic hits.

        Client-visible tail latency must not include jit compiles; by default
        this runs the engine once per power-of-two bucket up to ``max_batch``
        — exactly the shapes the batcher can emit. The per-shape probe
        batches come from ``warmup_bounds`` when the server was built from a
        BuildPlan (``core.build.warmup_bounds``): one batch per query regime
        the plan's resolved threshold can dispatch to. Without a plan, when
        ``config.n`` is known each shape runs twice, on all-(0, 0) and
        all-(0, n-1) batches, so a range-adaptive engine still compiles both
        regimes instead of deferring the long path to the first client.
        """
        if sizes is None:
            top = bucket(self._cfg.max_batch)
            sizes, s = [], 1
            while s <= top:
                sizes.append(s)
                s *= 2
        n = self._cfg.n
        for s in sizes:
            if self._warmup_bounds is not None:
                probes = list(self._warmup_bounds(s))
                if self._cfg.regime_affinity == "long":
                    # Hot-pool affinity: compile the affinity regime first so
                    # a replica's first real batch hits a warm cache even if
                    # warmup is cut short. Probes come short-regime-first.
                    probes.reverse()
                for l, r in probes:
                    self._query_fn(l, r)
                continue
            zeros = np.zeros(s, np.int32)
            self._query_fn(zeros, zeros)
            if n is not None and n > 1:
                self._query_fn(zeros, np.full(s, n - 1, np.int32))

    # -- client API ---------------------------------------------------------

    def submit(self, l, r, *, min_version: Optional[int] = None) -> Future:
        """Enqueue one client request of (l, r) query bounds -> Future.

        The future resolves to a ``RequestResult`` whose idx/val line up
        elementwise with the submitted bounds. Raises ``ServerOverloaded``
        when admission control rejects (backpressure), ``ServerClosed`` after
        ``close()``, and ``ValueError``/``TypeError`` on malformed bounds.

        ``min_version`` (online servers) is the session token's floor: if
        this server's engine has not yet published version ``min_version``,
        raise ``StaleVersion`` instead of enqueueing. Version ids are
        monotone and batches pin the version current at flush time, so
        passing the check at submit time guarantees the response is answered
        at a version >= ``min_version`` — including across automatic retries.
        """
        if self._closed:
            raise ServerClosed("submit() on a closed server")
        if not self._started:
            raise ServerClosed("submit() before start()")
        if min_version is not None:
            if self._online is None:
                raise ValueError("min_version needs a server with an OnlineEngine")
            cur = self._online.current_vid
            if cur < min_version:
                raise StaleVersion(
                    f"server at version {cur}, request requires >= {min_version}"
                )
        l = np.asarray(l)
        r = np.asarray(r)
        if l.shape != r.shape or l.ndim != 1:
            raise ValueError(f"l/r must be equal-shape 1-D arrays, got {l.shape} / {r.shape}")
        if not (np.issubdtype(l.dtype, np.integer) and np.issubdtype(r.dtype, np.integer)):
            raise TypeError(f"query bounds must be integer arrays, got {l.dtype} / {r.dtype}")
        if l.size == 0:
            fut: Future = Future()
            fut.set_result(
                RequestResult(
                    np.zeros(0, np.int32),
                    np.zeros(0, np.dtype(self._cfg.val_dtype)),
                    RequestTiming(0.0, 0.0, 0.0),
                )
            )
            return fut
        if l.size > self._cfg.max_batch:
            raise ValueError(
                f"request of {l.size} queries exceeds max_batch={self._cfg.max_batch}; split it"
            )
        lo, hi = int(l.min()), int(np.asarray(r, np.int64).max())
        if lo < 0 or np.any(r < l):
            raise ValueError("query bounds must satisfy 0 <= l <= r")
        # Online servers validate against the CURRENT logical length: if a
        # client saw the post-append length, that append already published,
        # so any version pinned later can answer it.
        n_bound = self._online.n if self._online is not None else self._cfg.n
        if hi > _INT32_MAX or (n_bound is not None and hi >= n_bound):
            bound = n_bound if n_bound is not None else _INT32_MAX + 1
            raise ValueError(f"query upper bound {hi} outside [0, {bound})")

        now = time.perf_counter()
        req = _Request(l.astype(np.int32), r.astype(np.int32), now)
        tr = self._tracer
        with self._lock:
            if self._closed:
                raise ServerClosed("submit() on a closed server")
            if self._inflight >= self._cfg.max_pending:
                self._m_out["rejected"].inc()
                raise ServerOverloaded(
                    f"{self._inflight} requests in flight (max_pending={self._cfg.max_pending})"
                )
            self._inflight += 1
            self._g_inflight.set(self._inflight)
            self._live.add(req)
            if self._t_first_submit is None:
                self._t_first_submit = now
            if tr.enabled:
                # Request lifecycle root + its first children. parent=0 forces
                # a root: the client thread's ambient span (if any) is not
                # part of this request's chain.
                req.span = tr.start("request", parent=0, attrs={"queries": int(l.size)})
                tr.instant("admission", parent=req.span, attrs={"inflight": self._inflight})
                req.qspan = tr.start("queue", parent=req.span)
            self._inq.put(req)  # under _lock: never lands after close()'s _STOP
        return req.future

    def submit_update(self, deltas) -> Future:
        """Enqueue one update batch (a ``repro.update`` DeltaLog/DeltaBatch).

        The future resolves to the ``UpdateResult`` of the published version.
        Updates are barriers in the batcher (queries submitted before an
        update are flushed — and version-pinned — first) and are applied in
        submission order by the single updater thread. Shares admission
        control with queries: a stalled updater backpressures too.
        """
        if self._online is None:
            raise ValueError("submit_update() on a server without an OnlineEngine")
        if self._closed:
            raise ServerClosed("submit_update() on a closed server")
        if not self._started:
            raise ServerClosed("submit_update() before start()")
        # Emptiness: DeltaBatch is a NamedTuple, so len() would count its
        # *fields* (always truthy) — use the op count both types expose.
        n_ops = getattr(deltas, "n_ops", None)
        if not (len(deltas) if n_ops is None else n_ops):
            raise ValueError("submit_update() with an empty delta log")
        req = _UpdateReq(deltas, time.perf_counter())
        with self._lock:
            if self._closed:
                raise ServerClosed("submit_update() on a closed server")
            if self._inflight >= self._cfg.max_pending:
                self._m_out["rejected"].inc()
                raise ServerOverloaded(
                    f"{self._inflight} requests in flight (max_pending={self._cfg.max_pending})"
                )
            self._inflight += 1
            self._g_inflight.set(self._inflight)
            self._live.add(req)
            self._inq.put(req)
        return req.future

    # -- internals ----------------------------------------------------------

    def _batch_loop(self):
        cfg = self._cfg
        pending: List[_Request] = []
        pend_q = 0
        eff = cfg.deadline_s  # effective deadline (moves only when adaptive)
        dmin, dmax = cfg.deadline_bounds()

        def flush(reason: str):
            nonlocal pending, pend_q, eff
            tr = self._tracer
            if cfg.request_timeout_s is not None:
                # Requests past their deadline fail here instead of occupying
                # a launch: an expired client has stopped waiting already.
                now = time.perf_counter()
                expired = [q for q in pending if now - q.t_submit > cfg.request_timeout_s]
                if expired:
                    pending = [q for q in pending if now - q.t_submit <= cfg.request_timeout_s]
                    pend_q = sum(q.l.size for q in pending)
                    with self._lock:
                        self._inflight -= len(expired)
                        self._g_inflight.set(self._inflight)
                        for q in expired:
                            self._live.discard(q)
                    self._m_out["expired"].inc(len(expired))
                    for q in expired:
                        self._trace_resolve(q, "expired")
                        self._fail_future(
                            q,
                            DeadlineExceeded(
                                f"request expired after {now - q.t_submit:.3f}s "
                                f"(request_timeout_s={cfg.request_timeout_s})"
                            ),
                        )
                    if not pending:
                        return
            # The flush span is this batch's root: coalesce/launch/scatter
            # hang off it, and every member request links to it via its
            # "batch" attr. It travels to the worker and finishes there.
            fs = None
            if tr.enabled:
                fs = tr.start("flush", parent=0, attrs={"reason": reason})
                with tr.span("coalesce", parent=fs):
                    mb = coalesce([q.l for q in pending], [q.r for q in pending])
            else:
                mb = coalesce([q.l for q in pending], [q.r for q in pending])
            t = time.perf_counter()
            for q in pending:
                q.t_flush = t
            if fs is not None:
                fs.attrs["n_requests"] = len(pending)
                fs.attrs["n_queries"] = int(mb.n_queries)
                fs.attrs["padded"] = mb.padded_size
                fs.attrs["fill"] = round(mb.fill_fraction, 4)
                for q in pending:
                    if q.span is not None:
                        q.span.set_attr("batch", fs.span_id)
                    if q.qspan is not None:
                        tr.finish(q.qspan)
                        q.qspan = None
            # Snapshot isolation: the whole launch is answered against the
            # version current at flush time, however long it sits in the
            # microbatch queue and whatever publishes meanwhile.
            ver = self._online.pin() if self._online is not None else None
            if fs is not None and ver is not None:
                fs.attrs["version"] = ver.vid
            self._mbq.put((mb, pending, ver, fs))
            if cfg.adaptive_deadline:
                if reason == "full":  # sustained load: waiting only adds latency
                    eff = max(dmin, eff / 2)
                elif reason == "deadline" and mb.n_queries < cfg.max_batch / 4:
                    eff = min(dmax, eff * 1.5)  # idle: wait longer, coalesce more
                with self._lock:
                    self._deadlines.append(eff)
                self._g_deadline.set(eff)
            pending, pend_q = [], 0

        while True:
            if pending:
                left = eff - (time.perf_counter() - pending[0].t_submit)
                if left <= 0:
                    item = None
                else:
                    try:
                        item = self._inq.get(timeout=left)
                    except queue.Empty:
                        item = None
            else:
                item = self._inq.get()
            if item is _STOP:
                if pending:
                    flush("stop")
                for _ in range(cfg.workers):
                    self._mbq.put(_STOP)
                self._updq.put(_STOP)  # updater (if any) drains, then exits
                return
            if isinstance(item, _UpdateReq):
                # Update barrier: requests already pending were submitted
                # before the update, so they flush (and pin) first; the
                # single updater then applies in submission order.
                if pending:
                    flush("barrier")
                self._updq.put(item)
                continue
            if item is not None:
                # A request that would overflow the launch flushes what's
                # pending first, so a batch never exceeds max_batch queries.
                if pend_q and pend_q + item.l.size > cfg.max_batch:
                    flush("full")
                pending.append(item)
                pend_q += item.l.size
            if pending:
                if pend_q >= cfg.max_batch:
                    flush("full")
                elif time.perf_counter() - pending[0].t_submit >= eff:
                    flush("deadline")

    def _worker_main(self, slot: int):
        """Supervised worker entry: a crash reports the slot and dies.

        Everything short of an injected kill is absorbed inside
        ``_worker_loop`` (a failed launch fails or requeues only its own
        batch); an escaping exception means the thread is gone, so the
        supervisor is told which slot to restart.
        """
        try:
            self._worker_loop(slot)
        except BaseException:
            self._deaths.put(slot)

    def _worker_loop(self, slot: int = 0):
        while True:
            item = self._mbq.get()
            if item is _STOP:
                return
            mb, reqs, ver, fs = item
            try:
                parts, splits, degraded = self._launch(mb, ver, fs)
            except BaseException as e:
                # Failed launch: its requests retry or fail — never the whole
                # server. An injected crash additionally kills this worker
                # thread (after the batch is requeued) to exercise the
                # supervisor's restart path.
                self._requeue_or_fail(mb, reqs, ver, fs, e)
                if isinstance(e, InjectedFault) and e.kind == "crash":
                    raise
                continue
            self._finish(mb, reqs, ver, fs, parts, splits, degraded)

    def _launch_span(self, fs, ver, mb: MicroBatch, pool: str):
        """Context manager for one engine launch span under flush span ``fs``
        (the worker thread — cross-thread, so the parent is explicit)."""
        attrs = dict(self._trace_attrs)
        attrs["pool"] = pool
        attrs["padded"] = mb.padded_size
        attrs["queries"] = int(mb.n_queries)
        if ver is not None:
            attrs["version"] = ver.vid
        return self._tracer.span("launch", parent=fs, attrs=attrs)

    def _launch(self, mb: MicroBatch, ver, fs=None):
        """One engine launch -> (per-request parts, regime splits, degraded?).

        Routes to the degraded fallback while the breaker is open; otherwise
        runs the primary engine, feeding the breaker's consecutive-failure
        count on each outcome.
        """
        if self._use_degraded():
            return self._launch_degraded(mb, ver, fs)
        tr = self._tracer
        self._m_launches["primary"].inc()
        try:
            # Observe how the range-adaptive dispatcher (if any) splits
            # this launch: a thread-local sink, so concurrent workers
            # never see each other's splits.
            splits: List[Tuple[int, int]] = []
            lsp = None
            t0 = time.perf_counter()
            with _hybrid.record_splits(lambda s, g: splits.append((s, g))):
                cm = self._launch_span(fs, ver, mb, "primary") if tr.enabled else tr.span("launch")
                with cm as lsp:
                    if self._fault is not None:
                        self._fault("worker_query")
                    if ver is not None:
                        if self._launch_gate is not None:
                            with self._launch_gate:
                                idx, val = self._online.query(ver.state, mb.l, mb.r)
                                idx, val = np.asarray(idx), np.asarray(val)
                        else:
                            idx, val = self._online.query(ver.state, mb.l, mb.r)
                    else:
                        idx, val = self._query_fn(mb.l, mb.r)
            self._h_launch["primary"].observe(time.perf_counter() - t0)
            # The coalesced launch is power-of-two padded with trivial
            # (0, 0) queries; the dispatcher routes ALL pads to one side
            # (short when threshold >= 1, else long — real queries never
            # leave that side short of the pad count), so subtracting
            # from whichever side holds them leaves real-traffic splits.
            pad = mb.l.size - mb.n_queries
            splits = [(s - pad, g) if s >= pad else (s, g - pad) for s, g in splits]
            if splits and tr.enabled and lsp is not None:
                lsp.set_attr("short", sum(s for s, _ in splits))
                lsp.set_attr("long", sum(g for _, g in splits))
            with tr.span("scatter", parent=fs):
                parts = scatter_back(mb, idx, val)
        except BaseException:
            self._breaker_failure()
            raise
        self._breaker_success()
        return parts, splits, False

    def _launch_degraded(self, mb: MicroBatch, ver, fs=None):
        """Answer via the correct-but-slower fallback path (breaker open)."""
        tr = self._tracer
        self._m_launches["degraded"].inc()
        t0 = time.perf_counter()
        cm = self._launch_span(fs, ver, mb, "degraded") if tr.enabled else tr.span("launch")
        with cm:
            if self._online is not None:
                if self._degraded is None:
                    from repro.fault.fallback import DegradedFallback

                    self._degraded = DegradedFallback()
                idx, val = self._degraded.query(ver, mb.l, mb.r)
            elif self._fallback_fn is not None:
                idx, val = self._fallback_fn(mb.l, mb.r)
            else:  # unreachable: __init__ validates breaker => degraded path
                raise EngineFailure("breaker open and no fallback", retryable=False)
        self._h_launch["degraded"].observe(time.perf_counter() - t0)
        with tr.span("scatter", parent=fs):
            parts = scatter_back(mb, idx, val)
        return parts, [], True

    # -- circuit breaker ------------------------------------------------------

    def _use_degraded(self) -> bool:
        """True while the breaker routes launches to the fallback.

        closed -> open after ``breaker_threshold`` consecutive primary
        failures; open -> half-open once ``breaker_cooldown_s`` elapses (ONE
        worker runs a trivial health probe through the primary; the rest stay
        degraded); probe success closes, probe failure re-arms the cooldown.
        """
        if self._cfg.breaker_threshold <= 0:
            return False
        with self._lock:
            if not self._brk_open:
                return False
            cooled = time.perf_counter() - self._brk_opened_t >= self._cfg.breaker_cooldown_s
            if not cooled or self._brk_probing:
                return True
            self._brk_probing = True  # this worker owns the health probe
        ok = False
        try:
            ok = self._probe_primary()
        finally:
            with self._lock:
                self._brk_probing = False
                if ok:
                    self._brk_open = False
                    self._brk_fails = 0
                else:
                    self._brk_opened_t = time.perf_counter()  # re-arm cooldown
        return not ok

    def _probe_primary(self) -> bool:
        """Half-open health probe: one trivial query through the primary."""
        try:
            zeros = np.zeros(1, np.int32)
            if self._fault is not None:
                self._fault("worker_query")
            if self._online is not None:
                ver = self._online.pin()
                try:
                    if self._launch_gate is not None:
                        with self._launch_gate:
                            out = self._online.query(ver.state, zeros, zeros)
                            np.asarray(out[0])
                    else:
                        self._online.query(ver.state, zeros, zeros)
                finally:
                    self._online.release(ver.vid)
            else:
                self._query_fn(zeros, zeros)
            return True
        except BaseException:
            return False

    def _breaker_failure(self):
        if self._cfg.breaker_threshold <= 0:
            return
        with self._lock:
            self._brk_fails += 1
            if not self._brk_open and self._brk_fails >= self._cfg.breaker_threshold:
                self._brk_open = True
                self._brk_opened_t = time.perf_counter()
                self._m_trips.inc()

    def _breaker_success(self):
        if self._cfg.breaker_threshold <= 0:
            return
        with self._lock:
            self._brk_fails = 0

    # -- launch outcome plumbing ----------------------------------------------

    def _requeue_or_fail(self, mb: MicroBatch, reqs, ver, fs, err: BaseException):
        """Split a failed batch's requests into automatic retries and failures.

        A request retries while it has retry budget left, hasn't blown its
        ``request_timeout_s`` deadline, and the server is still open; retried
        requests re-enter the batcher (fresh coalescing, fresh version pin).
        The rest fail with a typed ``EngineFailure`` carrying the cause.
        """
        tr = self._tracer
        if ver is not None:
            self._online.release(ver.vid)
        if fs is not None:
            fs.set_attr("error", type(err).__name__)
            tr.finish(fs)
        now = time.perf_counter()
        retry, fail = [], []
        for q in reqs:
            expired = (
                self._cfg.request_timeout_s is not None
                and now - q.t_submit > self._cfg.request_timeout_s
            )
            if q.retries < self._cfg.max_retries and not expired and not self._closed:
                q.retries += 1
                retry.append(q)
            else:
                fail.append(q)
        with self._lock:
            self._inflight -= len(fail)
            self._m_out["retried"].inc(len(retry))
            self._m_out["failed"].inc(len(fail))
            for q in fail:
                self._live.discard(q)
            if retry and not self._closed:
                for q in retry:
                    # Back into the batcher: a fresh coalescing wait, so a
                    # fresh queue span under the same request root.
                    if q.span is not None:
                        q.qspan = tr.start("queue", parent=q.span)
                    self._inq.put(q)
                retry = []
            else:
                # close() raced us: its _STOP is already in _inq, so requeued
                # requests would never flush. Fail them instead.
                self._inflight -= len(retry)
                self._m_out["failed"].inc(len(retry))
                for q in retry:
                    self._live.discard(q)
            self._g_inflight.set(self._inflight)
        fail += retry
        if isinstance(err, (EngineFailure, DeadlineExceeded)):
            exc = err
        else:
            exc = EngineFailure(f"engine launch failed: {err!r}", cause=err)
        for q in fail:
            self._trace_resolve(q, "failed")
            self._fail_future(q, exc)

    def _finish(self, mb: MicroBatch, reqs, ver, fs, parts, splits, degraded: bool):
        tr = self._tracer
        lag = 0
        if ver is not None:
            lag = self._online.current_vid - ver.vid
            self._online.release(ver.vid)
        t_done = time.perf_counter()
        if fs is not None:
            if ver is not None:
                fs.set_attr("lag", lag)
            tr.finish(fs)
        with self._lock:
            self._inflight -= len(reqs)
            self._g_inflight.set(self._inflight)
            self._splits.extend(splits)
            self._padded.add(mb.padded_size)
            if ver is not None:
                self._lags.append(lag)
                self._g_vlag.set(lag)
            for q in reqs:
                self._live.discard(q)
            self._t_last_done = t_done
        self._m_batches.inc()
        self._m_queries.inc(int(mb.n_queries))
        self._m_out["served"].inc(len(reqs))
        for s, g in splits:
            self._m_regime["short"].inc(s)
            self._m_regime["long"].inc(g)
        for q, (qi, qv) in zip(reqs, parts):
            self._h_queue.observe(q.t_flush - q.t_submit)
            self._h_service.observe(t_done - q.t_flush)
            self._h_total.observe(t_done - q.t_submit)
            self._trace_resolve(q, "ok")
            try:
                q.future.set_result(
                    RequestResult(
                        qi,
                        qv,
                        RequestTiming(q.t_flush - q.t_submit, t_done - q.t_flush, t_done - q.t_submit),
                        ver.vid if ver is not None else None,
                    )
                )
            except Exception:
                pass  # already failed (expired/closed): result has no taker

    def _trace_resolve(self, q, outcome: str):
        """Terminal span bookkeeping for one request: close any open queue
        span, emit the ``resolve`` child, finish the root. Idempotent — the
        first terminal outcome wins (a request can reach here twice when
        close() races a worker)."""
        if q.span is None:
            return
        tr = self._tracer
        if q.qspan is not None:
            tr.finish(q.qspan)
            q.qspan = None
        tr.instant("resolve", parent=q.span, attrs={"outcome": outcome})
        tr.finish(q.span)
        q.span = None

    @staticmethod
    def _fail_future(q, exc: BaseException):
        try:
            q.future.set_exception(exc)
        except Exception:
            pass  # already resolved

    def _supervisor_loop(self):
        """Restart crashed workers with capped exponential backoff per slot."""
        delay = {}
        while True:
            slot = self._deaths.get()
            if slot is _STOP:
                return
            d = delay.get(slot, self._cfg.worker_backoff_s)
            delay[slot] = min(d * 2, self._cfg.worker_backoff_max_s)
            time.sleep(d)
            with self._lock:
                if self._closed:
                    continue  # shutting down: _STOP already drained the pool
                self._m_restarts.inc()
                t = threading.Thread(
                    target=self._worker_main,
                    args=(slot,),
                    daemon=True,
                    name=f"rmq-worker-{slot}r",
                )
                self._threads.append(t)
            t.start()

    def _update_loop(self):
        """The single updater: applies update batches in submission order."""
        tr = self._tracer
        while True:
            item = self._updq.get()
            if item is _STOP:
                return
            try:
                # The update root span: OnlineEngine.apply's coalesce span
                # and the apply_deltas/publish stage spans (via run_stages)
                # nest under it ambiently — same thread, same context.
                if tr.enabled:
                    cm = tr.span(
                        "update",
                        parent=0,
                        attrs={"queue_s": time.perf_counter() - item.t_submit},
                    )
                else:
                    cm = tr.span("update")
                with cm as us:
                    res = self._online.apply(item.deltas)
                    us.set_attr("version", getattr(res, "version", None))
            except BaseException as e:
                # Malformed batches are rejected with the engine untouched;
                # a mid-patch failure fail-stops the OnlineEngine (later
                # applies raise) while queries keep serving published
                # versions. Either way, fail this future and keep going.
                with self._lock:
                    self._inflight -= 1
                    self._g_inflight.set(self._inflight)
                    self._live.discard(item)
                self._m_updates["failed"].inc()
                self._fail_future(item, e)
                continue
            with self._lock:
                self._inflight -= 1
                self._g_inflight.set(self._inflight)
                self._live.discard(item)
            self._m_updates["applied"].inc()
            self._h_update.observe(time.perf_counter() - item.t_submit)
            try:
                item.future.set_result(res)
            except Exception:
                pass  # already failed (server closed under us)

    def stats(self) -> ServeStats:
        """Render the ServeStats snapshot FROM the metrics registry.

        The NamedTuple is a *view*: every scalar comes from a registry
        instrument (so registry totals and ServeStats reconcile exactly, by
        construction — check.sh gates this) and the percentiles come from the
        histogram reservoirs via the same ``np.percentile`` math the old
        ad-hoc lists used. Only structural sequences (splits, lags, padded
        shapes, deadline trajectory) live outside the registry.
        """
        with self._lock:
            splits = tuple(self._splits)
            padded = tuple(sorted(self._padded))
            lags = tuple(self._lags)
            deadlines = tuple(self._deadlines)
            t0, t1 = self._t_first_submit, self._t_last_done
        nreq = self._h_total.count
        nq = int(self._m_queries.value)
        nb = int(self._m_batches.value)
        span = t1 - t0 if nreq and t0 is not None and t1 is not None else 0.0
        q50, q99 = self._h_queue.percentiles((50, 99))
        t50, t99 = self._h_total.percentiles((50, 99))
        u50, u99 = self._h_update.percentiles((50, 99))
        return ServeStats(
            served_requests=nreq,
            served_queries=nq,
            rejected_requests=int(self._m_out["rejected"].value),
            n_batches=nb,
            mean_batch_requests=nreq / nb if nb else 0.0,
            mean_batch_queries=nq / nb if nb else 0.0,
            padded_sizes=padded,
            p50_queue_s=q50,
            p99_queue_s=q99,
            p50_total_s=t50,
            p99_total_s=t99,
            throughput_qps=nq / span if span > 0 else 0.0,
            regime_splits=splits,
            applied_updates=self._h_update.count,
            p50_update_s=u50,
            p99_update_s=u99,
            version_lags=lags,
            deadline_trajectory=deadlines,
            degraded_launches=int(self._m_launches["degraded"].value),
            worker_restarts=int(self._m_restarts.value),
            retried_requests=int(self._m_out["retried"].value),
            expired_requests=int(self._m_out["expired"].value),
            failed_requests=int(self._m_out["failed"].value),
            breaker_trips=int(self._m_trips.value),
        )
