"""Async RMQ server: request queue -> deadline micro-batcher -> engine pool.

``RMQServer`` accepts variable-size query batches from concurrent clients
and coalesces them into power-of-two padded engine launches:

    submit(l, r) ─► admission control (bounded in-flight requests)
        └─► request queue ─► batcher thread
              │   flush when the coalesced batch reaches ``max_batch``
              │   queries OR the oldest pending request ages past
              │   ``deadline_s`` — latency is bounded by the deadline even
              │   at low offered load
              └─► microbatch queue ─► engine-pool worker threads
                    └─► scatter-back, per-request futures + latency stamps

Admission control bounds *in-flight* requests (queued + batching +
executing): past ``max_pending``, ``submit`` raises ``ServerOverloaded`` —
the backpressure signal open-loop clients drop on and closed-loop clients
retry on — so a stalled engine degrades into rejections instead of an
unbounded queue. Per-request latency decomposes as queue (submit -> flush)
plus service (flush -> done); ``stats()`` aggregates p50/p99 and sustained
throughput over the serving interval.

The engine is any ``(l, r) -> (idx, val)`` callable — typically a registry
``EngineSpec.query`` closed over its built state (``launch.serve`` wires
exactly that). jax dispatch is thread-safe; ``workers > 1`` overlaps one
batch's host-side partition/scatter work with another's device execution.
"""

from __future__ import annotations

import queue
import threading
import time
from concurrent.futures import Future
from dataclasses import dataclass
from typing import Callable, List, NamedTuple, Optional, Sequence, Set, Tuple

import numpy as np

from repro.core import hybrid as _hybrid

from .batcher import MicroBatch, bucket, coalesce, scatter_back

__all__ = [
    "RMQServer",
    "RequestResult",
    "RequestTiming",
    "ServeConfig",
    "ServeStats",
    "ServerClosed",
    "ServerOverloaded",
]

_INT32_MAX = np.iinfo(np.int32).max
_STOP = object()


class ServerClosed(RuntimeError):
    """submit() after close()."""


class ServerOverloaded(RuntimeError):
    """Admission control rejected the request: too many in flight."""


@dataclass(frozen=True)
class ServeConfig:
    deadline_s: float = 2e-3  # max coalescing wait for the oldest request
    max_batch: int = 4096  # flush once the coalesced batch reaches this
    max_pending: int = 4096  # in-flight request bound (admission control)
    workers: int = 1  # engine-pool threads
    n: Optional[int] = None  # if set, submit validates r < n
    val_dtype: object = np.float32  # engine value dtype (empty-request results)

    def __post_init__(self):
        if self.deadline_s < 0 or self.max_batch < 1 or self.max_pending < 1 or self.workers < 1:
            raise ValueError(f"invalid ServeConfig: {self}")


class RequestTiming(NamedTuple):
    queue_s: float  # submit -> batch flush (coalescing wait)
    service_s: float  # flush -> engine done
    total_s: float


class RequestResult(NamedTuple):
    idx: np.ndarray  # (B,) int32 leftmost argmin per query
    val: np.ndarray  # (B,) corresponding values
    timing: RequestTiming


class _Request:
    __slots__ = ("l", "r", "future", "t_submit", "t_flush")

    def __init__(self, l, r, t_submit):
        self.l = l
        self.r = r
        self.future: Future = Future()
        self.t_submit = t_submit
        self.t_flush = 0.0


class ServeStats(NamedTuple):
    served_requests: int
    served_queries: int
    rejected_requests: int
    n_batches: int
    mean_batch_requests: float
    mean_batch_queries: float
    padded_sizes: Tuple[int, ...]  # distinct launch shapes (jit-cache bound)
    p50_queue_s: float
    p99_queue_s: float
    p50_total_s: float
    p99_total_s: float
    throughput_qps: float  # served queries / (first submit -> last done)
    # Per-launch regime split (short, long) sub-batch sizes, as reported by
    # the range-adaptive dispatcher — empty for single-path engines. The
    # measurement regime-aware routing (server-level split, per-engine
    # pools) will act on.
    regime_splits: Tuple[Tuple[int, int], ...] = ()

    @property
    def short_queries(self) -> int:
        return sum(s for s, _ in self.regime_splits)

    @property
    def long_queries(self) -> int:
        return sum(g for _, g in self.regime_splits)

    @property
    def mixed_batches(self) -> int:
        """Launches the dispatcher actually split (both regimes non-empty)."""
        return sum(1 for s, g in self.regime_splits if s and g)

    def summary(self) -> str:
        out = (
            f"{self.served_requests} reqs / {self.served_queries} RMQs in "
            f"{self.n_batches} microbatches (mean {self.mean_batch_requests:.1f} "
            f"reqs, {self.mean_batch_queries:.1f} RMQs; padded shapes "
            f"{list(self.padded_sizes)}); latency p50 {self.p50_total_s*1e3:.2f} ms "
            f"p99 {self.p99_total_s*1e3:.2f} ms (queue p50 "
            f"{self.p50_queue_s*1e3:.2f} ms); {self.throughput_qps:,.0f} RMQ/s; "
            f"rejected {self.rejected_requests}"
        )
        if self.regime_splits:
            out += (
                f"; regime split {self.short_queries} short / "
                f"{self.long_queries} long RMQs, {self.mixed_batches}/"
                f"{len(self.regime_splits)} launches mixed"
            )
        return out


class RMQServer:
    """Deadline micro-batching server over one built RMQ engine."""

    def __init__(
        self,
        query_fn: Callable,
        config: Optional[ServeConfig] = None,
        *,
        warmup_bounds: Optional[Callable] = None,
        **overrides,
    ):
        self._query_fn = query_fn
        self._warmup_bounds = warmup_bounds  # (size) -> [(l, r), ...] per regime
        self._cfg = config if config is not None else ServeConfig(**overrides)
        self._inq: "queue.SimpleQueue" = queue.SimpleQueue()
        self._mbq: "queue.SimpleQueue" = queue.SimpleQueue()
        self._lock = threading.Lock()
        self._inflight = 0
        self._closed = False
        self._started = False
        self._threads: List[threading.Thread] = []
        # Stats accumulators (under _lock).
        self._queue_lat: List[float] = []
        self._total_lat: List[float] = []
        self._batch_requests: List[int] = []
        self._batch_queries: List[int] = []
        self._splits: List[Tuple[int, int]] = []  # per-launch (short, long)
        self._padded: Set[int] = set()
        self._rejected = 0
        self._t_first_submit: Optional[float] = None
        self._t_last_done: Optional[float] = None

    @property
    def config(self) -> ServeConfig:
        return self._cfg

    # -- lifecycle ----------------------------------------------------------

    def start(self) -> "RMQServer":
        if self._started:
            return self
        self._started = True
        self._threads = [threading.Thread(target=self._batch_loop, daemon=True, name="rmq-batcher")]
        for i in range(self._cfg.workers):
            self._threads.append(
                threading.Thread(target=self._worker_loop, daemon=True, name=f"rmq-worker-{i}")
            )
        for t in self._threads:
            t.start()
        return self

    def __enter__(self) -> "RMQServer":
        return self.start()

    def __exit__(self, *exc):
        self.close()

    def close(self, timeout: Optional[float] = None):
        """Stop accepting, drain everything already admitted, join threads."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
            if self._started:
                self._inq.put(_STOP)  # under _lock: serialized against submit
        for t in self._threads:
            t.join(timeout)

    def warmup(self, sizes: Optional[Sequence[int]] = None):
        """Compile every padded launch shape before traffic hits.

        Client-visible tail latency must not include jit compiles; by default
        this runs the engine once per power-of-two bucket up to ``max_batch``
        — exactly the shapes the batcher can emit. The per-shape probe
        batches come from ``warmup_bounds`` when the server was built from a
        BuildPlan (``core.build.warmup_bounds``): one batch per query regime
        the plan's resolved threshold can dispatch to. Without a plan, when
        ``config.n`` is known each shape runs twice, on all-(0, 0) and
        all-(0, n-1) batches, so a range-adaptive engine still compiles both
        regimes instead of deferring the long path to the first client.
        """
        if sizes is None:
            top = bucket(self._cfg.max_batch)
            sizes, s = [], 1
            while s <= top:
                sizes.append(s)
                s *= 2
        n = self._cfg.n
        for s in sizes:
            if self._warmup_bounds is not None:
                for l, r in self._warmup_bounds(s):
                    self._query_fn(l, r)
                continue
            zeros = np.zeros(s, np.int32)
            self._query_fn(zeros, zeros)
            if n is not None and n > 1:
                self._query_fn(zeros, np.full(s, n - 1, np.int32))

    # -- client API ---------------------------------------------------------

    def submit(self, l, r) -> Future:
        """Enqueue one client request of (l, r) query bounds -> Future.

        The future resolves to a ``RequestResult`` whose idx/val line up
        elementwise with the submitted bounds. Raises ``ServerOverloaded``
        when admission control rejects (backpressure), ``ServerClosed`` after
        ``close()``, and ``ValueError``/``TypeError`` on malformed bounds.
        """
        if self._closed:
            raise ServerClosed("submit() on a closed server")
        if not self._started:
            raise ServerClosed("submit() before start()")
        l = np.asarray(l)
        r = np.asarray(r)
        if l.shape != r.shape or l.ndim != 1:
            raise ValueError(f"l/r must be equal-shape 1-D arrays, got {l.shape} / {r.shape}")
        if not (np.issubdtype(l.dtype, np.integer) and np.issubdtype(r.dtype, np.integer)):
            raise TypeError(f"query bounds must be integer arrays, got {l.dtype} / {r.dtype}")
        if l.size == 0:
            fut: Future = Future()
            fut.set_result(
                RequestResult(
                    np.zeros(0, np.int32),
                    np.zeros(0, np.dtype(self._cfg.val_dtype)),
                    RequestTiming(0.0, 0.0, 0.0),
                )
            )
            return fut
        if l.size > self._cfg.max_batch:
            raise ValueError(
                f"request of {l.size} queries exceeds max_batch={self._cfg.max_batch}; split it"
            )
        lo, hi = int(l.min()), int(np.asarray(r, np.int64).max())
        if lo < 0 or np.any(r < l):
            raise ValueError("query bounds must satisfy 0 <= l <= r")
        if hi > _INT32_MAX or (self._cfg.n is not None and hi >= self._cfg.n):
            bound = self._cfg.n if self._cfg.n is not None else _INT32_MAX + 1
            raise ValueError(f"query upper bound {hi} outside [0, {bound})")

        now = time.perf_counter()
        req = _Request(l.astype(np.int32), r.astype(np.int32), now)
        with self._lock:
            if self._closed:
                raise ServerClosed("submit() on a closed server")
            if self._inflight >= self._cfg.max_pending:
                self._rejected += 1
                raise ServerOverloaded(
                    f"{self._inflight} requests in flight (max_pending={self._cfg.max_pending})"
                )
            self._inflight += 1
            if self._t_first_submit is None:
                self._t_first_submit = now
            self._inq.put(req)  # under _lock: never lands after close()'s _STOP
        return req.future

    # -- internals ----------------------------------------------------------

    def _batch_loop(self):
        cfg = self._cfg
        pending: List[_Request] = []
        pend_q = 0

        def flush():
            nonlocal pending, pend_q
            mb = coalesce([q.l for q in pending], [q.r for q in pending])
            t = time.perf_counter()
            for q in pending:
                q.t_flush = t
            self._mbq.put((mb, pending))
            pending, pend_q = [], 0

        while True:
            if pending:
                left = cfg.deadline_s - (time.perf_counter() - pending[0].t_submit)
                if left <= 0:
                    item = None
                else:
                    try:
                        item = self._inq.get(timeout=left)
                    except queue.Empty:
                        item = None
            else:
                item = self._inq.get()
            if item is _STOP:
                if pending:
                    flush()
                for _ in range(cfg.workers):
                    self._mbq.put(_STOP)
                return
            if item is not None:
                # A request that would overflow the launch flushes what's
                # pending first, so a batch never exceeds max_batch queries.
                if pend_q and pend_q + item.l.size > cfg.max_batch:
                    flush()
                pending.append(item)
                pend_q += item.l.size
            if pending and (
                pend_q >= cfg.max_batch
                or time.perf_counter() - pending[0].t_submit >= cfg.deadline_s
            ):
                flush()

    def _worker_loop(self):
        while True:
            item = self._mbq.get()
            if item is _STOP:
                return
            mb, reqs = item
            try:
                # Observe how the range-adaptive dispatcher (if any) splits
                # this launch: a thread-local sink, so concurrent workers
                # never see each other's splits.
                splits: List[Tuple[int, int]] = []
                with _hybrid.record_splits(lambda s, g: splits.append((s, g))):
                    idx, val = self._query_fn(mb.l, mb.r)
                parts = scatter_back(mb, idx, val)
                # The coalesced launch is power-of-two padded with trivial
                # (0, 0) queries; the dispatcher routes ALL pads to one side
                # (short when threshold >= 1, else long — real queries never
                # leave that side short of the pad count), so subtracting
                # from whichever side holds them leaves real-traffic splits.
                pad = mb.l.size - mb.n_queries
                splits = [
                    (s - pad, g) if s >= pad else (s, g - pad) for s, g in splits
                ]
            except BaseException as e:  # engine failure: fail the batch, keep serving
                with self._lock:
                    self._inflight -= len(reqs)
                for q in reqs:
                    q.future.set_exception(e)
                continue
            t_done = time.perf_counter()
            with self._lock:
                self._inflight -= len(reqs)
                self._batch_requests.append(len(reqs))
                self._batch_queries.append(mb.n_queries)
                self._splits.extend(splits)
                self._padded.add(mb.l.size)
                for q in reqs:
                    self._queue_lat.append(q.t_flush - q.t_submit)
                    self._total_lat.append(t_done - q.t_submit)
                self._t_last_done = t_done
            for q, (qi, qv) in zip(reqs, parts):
                q.future.set_result(
                    RequestResult(
                        qi, qv, RequestTiming(q.t_flush - q.t_submit, t_done - q.t_flush, t_done - q.t_submit)
                    )
                )

    def stats(self) -> ServeStats:
        with self._lock:
            tlat = np.asarray(self._total_lat)
            qlat = np.asarray(self._queue_lat)
            nreq = int(tlat.size)
            nq = int(sum(self._batch_queries))
            nb = len(self._batch_queries)
            span = (
                self._t_last_done - self._t_first_submit
                if nreq and self._t_first_submit is not None and self._t_last_done is not None
                else 0.0
            )
            pct = lambda a, p: float(np.percentile(a, p)) if a.size else 0.0
            return ServeStats(
                served_requests=nreq,
                served_queries=nq,
                rejected_requests=self._rejected,
                n_batches=nb,
                mean_batch_requests=nreq / nb if nb else 0.0,
                mean_batch_queries=nq / nb if nb else 0.0,
                padded_sizes=tuple(sorted(self._padded)),
                p50_queue_s=pct(qlat, 50),
                p99_queue_s=pct(qlat, 99),
                p50_total_s=pct(tlat, 50),
                p99_total_s=pct(tlat, 99),
                throughput_qps=nq / span if span > 0 else 0.0,
                regime_splits=tuple(self._splits),
            )
