"""Async micro-batching serve subsystem (DESIGN.md §7).

The paper's headline workload is *batches* of RMQs; concurrent client
traffic arrives as many small, variable-size requests. This package turns
one into the other:

    submit(l, r) ─► admission control ─► request queue
        └─► deadline micro-batcher (coalesce + power-of-two pad)
              └─► engine-pool workers (any ``(l, r) -> (idx, val)`` engine)
                    └─► exact per-request scatter-back + latency stamps

``batcher`` is the pure coalescing/padding/scatter core (no threads, no
clocks — unit-testable against the numpy oracle); ``server.RMQServer``
wires it to a bounded request queue, a deadline flush loop, and a worker
pool; ``workload`` provides the paper's §6.4 range distributions (int32 at
the boundary) and open-loop Poisson arrival processes for clients.
"""

from .batcher import MicroBatch, bucket, coalesce, scatter_back
from .server import (
    DeadlineExceeded,
    EngineFailure,
    RMQServer,
    RequestResult,
    RequestTiming,
    ServeConfig,
    ServeStats,
    ServerClosed,
    ServerOverloaded,
    StaleVersion,
)
from .workload import make_queries, poisson_interarrivals, run_poisson_clients

# Fleet symbols resolve lazily (PEP 562): ``fleet`` is also a runnable soak
# (``python -m repro.serve.fleet``), and importing it eagerly here would
# double-import it under runpy.
_FLEET_EXPORTS = ("FleetConfig", "FleetSession", "FleetStats", "RMQFleet")


def __getattr__(name):
    if name in _FLEET_EXPORTS:
        from . import fleet

        return getattr(fleet, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


__all__ = [
    "DeadlineExceeded",
    "EngineFailure",
    "FleetConfig",
    "FleetSession",
    "FleetStats",
    "MicroBatch",
    "RMQFleet",
    "RMQServer",
    "RequestResult",
    "RequestTiming",
    "ServeConfig",
    "ServeStats",
    "ServerClosed",
    "ServerOverloaded",
    "StaleVersion",
    "bucket",
    "coalesce",
    "make_queries",
    "poisson_interarrivals",
    "run_poisson_clients",
    "scatter_back",
]
