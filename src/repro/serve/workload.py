"""Serving workloads: §6.4 range distributions + open-loop Poisson clients.

``make_queries`` is the single source of the paper's three query-range
regimes for the serving stack (``launch.serve`` and ``benchmarks.common``
both route here). It returns **int32** bounds: every engine computes int32
indices (the fused kernel, the blocked paths, the doubling tables), so the
int64 sampling intermediates are cast at this boundary, and ``n`` itself
must fit the int32 index range.

``run_poisson_clients`` is the one open-loop client fleet shared by the
serve CLI, the example, and the latency benchmark.
"""

from __future__ import annotations

import threading
import time
from typing import Callable, List, Optional, Tuple

import numpy as np

__all__ = [
    "INT32_MAX",
    "make_queries",
    "poisson_interarrivals",
    "run_poisson_clients",
]

INT32_MAX = np.iinfo(np.int32).max


def make_queries(rng, n: int, batch: int, dist: str):
    """Paper §6.4 range distributions (large / medium / small) -> int32 (l, r).

    Large: uniform range length in [1, n]; Medium: LogNormal(log n^0.6, .3);
    Small: LogNormal(log n^0.3, .3).
    """
    if not 1 <= n <= INT32_MAX:
        raise ValueError(f"n={n} outside the engines' int32 index range")
    if dist == "large":
        length = rng.integers(1, n + 1, batch)
    else:
        exp = 0.6 if dist == "medium" else 0.3
        length = np.exp(rng.normal(np.log(n**exp), 0.3, batch))
        length = np.clip(length, 1, n).astype(np.int64)
    l = rng.integers(0, np.maximum(n - length + 1, 1), batch)
    r = np.minimum(l + length - 1, n - 1)
    return l.astype(np.int32), r.astype(np.int32)


def poisson_interarrivals(rng, rate_hz: float, count: int) -> np.ndarray:
    """Exponential interarrival gaps (seconds) for an open-loop Poisson client.

    ``rate_hz <= 0`` means "as fast as possible": zero gaps.
    """
    if rate_hz <= 0:
        return np.zeros(count)
    return rng.exponential(1.0 / rate_hz, count)


def run_poisson_clients(
    n_clients: int,
    requests: int,
    rate_hz: float,
    make_request: Callable,  # (rng, client_idx) -> (l, r)
    submit: Callable,  # (l, r) -> Future; may raise ServerOverloaded
    *,
    seed: int = 0,
) -> List[List[Tuple[tuple, Optional[object]]]]:
    """Open-loop Poisson client fleet against a server's ``submit``.

    Each of ``n_clients`` threads paces ``requests`` submissions at
    ``rate_hz`` (Poisson arrivals fixed in advance — a slow server cannot
    slow the offer down). Returns per-client lists of ``((l, r), future)``;
    ``future`` is ``None`` when admission control rejected, which an
    open-loop client answers by dropping and keeping its pace.
    """
    from .server import ServerOverloaded

    out: List[List[Tuple[tuple, Optional[object]]]] = [[] for _ in range(n_clients)]

    def client(c: int) -> None:
        # Sequence seeding: (seed, c) keys a distinct stream per (run, client).
        # The old `seed + c` collides across runs — (seed=0, client=1) and
        # (seed=1, client=0) replayed identical traffic.
        rng = np.random.default_rng([seed, c])
        for gap in poisson_interarrivals(rng, rate_hz, requests):
            if gap > 0:
                time.sleep(gap)
            l, r = make_request(rng, c)
            try:
                fut = submit(l, r)
            except ServerOverloaded:
                fut = None
            out[c].append(((l, r), fut))

    threads = [
        threading.Thread(target=client, args=(c,), name=f"poisson-client-{c}")
        for c in range(n_clients)
    ]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    return out
