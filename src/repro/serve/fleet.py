"""Replica fleet: N ``RMQServer`` replicas behind one front door (DESIGN.md §11).

One server saturates one device group; the fleet carves the device mesh into
disjoint per-replica groups and runs a full serving stack on each, behind a
single routing front door:

* **Regime routing** — the paper's two query regimes want different hot
  pools: short ranges resolve on the blocked/kernel path, long ranges on the
  sparse-table path. Each replica declares a ``regime_affinity`` (its warmup
  compiles that regime first; its jit caches stay hot for it) and the front
  door classifies every batch by its range lengths against the plan's
  threshold, routing short-majority batches to short-affinity replicas and
  long-majority batches to long-affinity ones, round-robin within the pool.

* **Bounded-lag rollouts** — one ``submit_update`` coalesces the delta log
  ONCE against the fleet head, assigns the next fleet version id, and fans
  the identical batch out to every replica's rollout queue. Per-replica
  rollout workers publish independently (pipelined — a fast replica never
  waits for the slowest to finish the previous version) but a
  ``RolloutTracker`` barrier keeps the fleet spread (max vid − min vid)
  within ``max_version_lag``: a leader blocks before publishing a version
  that would leave a live replica too far behind. The fleet future resolves
  at the FIRST replica publish — from that point the update is readable.

* **Read-your-writes sessions** — a ``FleetSession`` carries the highest
  version id its owner has observed (updated when the owner's update first
  publishes and on every query response). The front door never routes a
  session's query to a replica still serving an older version: candidate
  filtering + ``submit(min_version=...)``'s ``StaleVersion`` backstop, with
  a tracker wait (not a spin) when no replica is fresh enough yet. Appends
  raise the floor implicitly: a query past an old length is routed only to
  replicas that have published the growing version.

* **Crash → restore → rejoin** — durable fleets place each replica's
  ``DurableEngine`` under ``<root>/replica<i>``. A replica that dies
  mid-rollout (the ``rollout_apply`` fault site, or an external
  ``crash_replica``) deregisters from the tracker (a dead replica can never
  wedge the barrier), is restored from its checkpoint + journal, catches up
  to the fleet head by replaying the missed rollout batches from the fleet's
  history (journaling each — durability is preserved), and re-registers at
  the current head. In-flight requests on the dead replica are re-routed by
  the front door's retry layer; nothing is lost.

Run the acceptance soak standalone (the check.sh fleet gate does, on 8 fake
devices)::

    XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \
        python -m repro.serve.fleet --engine sharded_hybrid --replicas 3
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import queue
import shutil
import sys
import tempfile
import threading
import time
from concurrent.futures import Future
from dataclasses import dataclass, field
from typing import Dict, List, NamedTuple, Optional, Tuple

import numpy as np

from repro.core import build as build_mod
from repro.core import registry
from repro.fault.durable import DurableEngine
from repro.fault.inject import FaultPlan, FaultSpec
from repro.launch.mesh import make_group_mesh
from repro.obs import trace as obs_trace
from repro.obs.metrics import MetricsRegistry, merge_snapshots
from repro.serve.server import (
    EngineFailure,
    RMQServer,
    ServeConfig,
    ServerClosed,
    ServerOverloaded,
    StaleVersion,
)
from repro.update.deltas import DeltaLog
from repro.update.engines import OnlineEngine, online_names
from repro.update.versions import RolloutTracker

__all__ = [
    "FleetConfig",
    "FleetSession",
    "FleetSoakReport",
    "FleetStats",
    "RMQFleet",
    "main",
    "run_fleet_soak",
]

_STOP = object()


@dataclass(frozen=True)
class FleetConfig:
    """Fleet shape + rollout/routing policy. ``server`` is the per-replica
    ``ServeConfig`` template; its ``regime_affinity`` is overwritten per
    replica from ``affinities`` (default: alternating short/long)."""

    replicas: int = 2
    max_version_lag: int = 1  # rollout barrier: max fleet vid spread
    threshold: Optional[int] = None  # short/long routing split (default: plan meta)
    route_timeout_s: float = 30.0  # front-door wait for a fresh-enough replica
    rollout_timeout_s: float = 120.0  # per-replica barrier + publish wait
    max_route_retries: int = 2  # front-door resubmits after a replica failure
    auto_revive: bool = True  # durable fleets: restore crashed replicas in place
    server: ServeConfig = field(default_factory=ServeConfig)
    affinities: Optional[Tuple[Optional[str], ...]] = None

    def __post_init__(self):
        if self.replicas < 1:
            raise ValueError(f"replicas must be >= 1, got {self.replicas}")
        if self.max_version_lag < 1:
            raise ValueError(f"max_version_lag must be >= 1, got {self.max_version_lag}")
        if self.route_timeout_s <= 0 or self.rollout_timeout_s <= 0:
            raise ValueError(f"timeouts must be > 0: {self}")
        if self.max_route_retries < 0:
            raise ValueError(f"max_route_retries must be >= 0, got {self.max_route_retries}")
        if self.affinities is not None:
            if len(self.affinities) != self.replicas:
                raise ValueError(
                    f"{len(self.affinities)} affinities for {self.replicas} replicas"
                )
            for a in self.affinities:
                if a not in (None, "short", "long"):
                    raise ValueError(f"affinity must be None, 'short', or 'long': {a!r}")

    def resolved_affinities(self) -> Tuple[Optional[str], ...]:
        if self.affinities is not None:
            return tuple(self.affinities)
        if self.replicas == 1:
            return (None,)
        return tuple("short" if i % 2 == 0 else "long" for i in range(self.replicas))


class FleetSession:
    """Read-your-writes token: the highest version id this client observed.

    Observed at the ack point of the client's own updates (the first replica
    publish — before the update future resolves, so a client that awaited
    its update always carries the new floor) and on every query response.
    The front door routes a session's queries only to replicas at or past
    the floor. Thread-safe and monotonic.
    """

    __slots__ = ("_lock", "_vid")

    def __init__(self):
        self._lock = threading.Lock()
        self._vid = -1  # below every published vid: no floor yet

    @property
    def last_vid(self) -> int:
        with self._lock:
            return self._vid

    def observe(self, vid: int) -> None:
        with self._lock:
            if vid > self._vid:
                self._vid = int(vid)


class _Rollout:
    """One fleet update: the coalesced batch fanned out to every replica.

    The future resolves at the FIRST successful publish (or catch-up apply);
    ``settle`` counts per-replica outcomes so an update that failed on every
    enqueued replica of a non-durable fleet fails the caller instead of
    hanging (durable fleets revive and ack through the catch-up path).
    """

    __slots__ = ("vid", "batch", "future", "session", "t_submit", "_lock", "_left", "_ok")

    def __init__(self, vid: int, batch, fanout: int, session: Optional[FleetSession]):
        self.vid = vid
        self.batch = batch
        self.future: Future = Future()
        self.session = session
        self.t_submit = time.perf_counter()
        self._lock = threading.Lock()
        self._left = fanout
        self._ok = 0

    def ack(self, result) -> None:
        # Session floor moves BEFORE the future resolves: a client that
        # awaited its update always reads its own write afterwards.
        if self.session is not None:
            self.session.observe(self.vid)
        if not self.future.done():
            try:
                self.future.set_result(result)
            except Exception:
                pass  # lost the set_result race to another replica

    def settle(self, durable: bool, ok: bool) -> None:
        with self._lock:
            self._left -= 1
            if ok:
                self._ok += 1
            exhausted = self._left <= 0 and self._ok == 0
        if exhausted and not durable and not self.future.done():
            try:
                self.future.set_exception(
                    RuntimeError(f"update v{self.vid} failed on every replica")
                )
            except Exception:
                pass


class _Replica:
    """One serving stack: engine + server + rollout queue + lifecycle state.

    ``gen`` increments on every crash and revive; a rollout worker exits as
    soon as its generation is superseded, so a revived replica's fresh queue
    and worker never race the old ones.
    """

    __slots__ = (
        "i",
        "engine",
        "server",
        "affinity",
        "root",
        "mesh",
        "axis_names",
        "server_cfg",
        "warmup_bounds",
        "lock",
        "revive_lock",
        "active",
        "gen",
        "crashes",
        "restores",
        "routed",
        "rollouts",
        "thread",
    )

    def __init__(self, i, engine, server, affinity, *, root, mesh, axis_names, server_cfg, warmup_bounds):
        self.i = i
        self.engine = engine
        self.server = server
        self.affinity = affinity
        self.root = root
        self.mesh = mesh
        self.axis_names = axis_names
        self.server_cfg = server_cfg
        self.warmup_bounds = warmup_bounds
        self.lock = threading.Lock()  # guards active/gen/crash bookkeeping
        self.revive_lock = threading.Lock()  # serializes restore attempts
        self.active = True
        self.gen = 0
        self.crashes = 0
        self.restores = 0
        self.routed = 0
        self.rollouts: "queue.SimpleQueue" = queue.SimpleQueue()
        self.thread: Optional[threading.Thread] = None

    @property
    def key(self) -> int:
        return self.i


class FleetStats(NamedTuple):
    replicas: int
    active: int
    requests: int  # client requests through the front door
    queries: int  # individual RMQs across those requests
    updates: int  # fleet rollouts submitted
    crashes: int  # replica deaths (injected or external)
    restores: int  # successful restore + rejoin cycles
    reroutes: int  # front-door resubmits after a replica failure
    stale_reroutes: int  # reroutes specifically due to StaleVersion
    affinity_hits: int  # batches routed to a matching-affinity replica
    affinity_misses: int  # matching pool existed but freshness forced elsewhere
    routed: Tuple[int, ...]  # per-replica request counts
    head_vid: int  # fleet head version id
    min_vid: int  # slowest live replica's version id
    max_lag_seen: int  # largest fleet vid spread ever observed

    def summary(self) -> str:
        return (
            f"fleet: {self.active}/{self.replicas} replicas, "
            f"{self.requests} reqs / {self.queries} RMQs, {self.updates} rollouts "
            f"(head v{self.head_vid}, min v{self.min_vid}, lag<= {self.max_lag_seen}); "
            f"routing {list(self.routed)} (affinity {self.affinity_hits} hit / "
            f"{self.affinity_misses} miss, {self.reroutes} reroutes of which "
            f"{self.stale_reroutes} stale); {self.crashes} crashes, {self.restores} restores"
        )


class RMQFleet:
    """N replica serving stacks behind a regime-routing, session-aware front
    door. Build with :meth:`build`; see the module docstring for semantics."""

    def __init__(self, replicas: List[_Replica], config: FleetConfig, *, engine: str, fault_plan=None, durable: bool = False):
        self._reps = list(replicas)
        self._cfg = config
        self.engine = engine
        self._durable = durable
        self._fault_plan = fault_plan
        self._fault = fault_plan.check if hasattr(fault_plan, "check") else fault_plan
        self._tracker = RolloutTracker(max_lag=config.max_version_lag)
        head = self._reps[0].engine
        self._dtype = head.dtype
        thr = config.threshold
        if thr is None:
            thr = head.plan.meta.get("threshold")
        self._threshold = int(thr) if thr is not None else max(1, int(round(head.n**0.5)))
        self._head_vid = head.current_vid
        self._head_n = head.n
        # Append history: (vid, n) whenever the logical length grew. Routing
        # derives a version floor from it so a query past an old length is
        # never sent to a replica that has not published the growth yet.
        self._growth: List[Tuple[int, int]] = [(self._head_vid, self._head_n)]
        self._history: Dict[int, _Rollout] = {}
        self._update_lock = threading.Lock()
        self._route_lock = threading.Lock()
        self._stats_lock = threading.Lock()
        self._cursor = {"short": -1, "long": -1}
        self._requests = 0
        self._queries = 0
        self._updates = 0
        self._crashes = 0
        self._restores = 0
        self._reroutes = 0
        self._stale_reroutes = 0
        self._aff_hits = 0
        self._aff_misses = 0
        self._closed = False
        self._retryq: "queue.SimpleQueue" = queue.SimpleQueue()
        self._retry_thread = threading.Thread(
            target=self._retry_loop, daemon=True, name="fleet-retry"
        )
        self._retry_thread.start()
        for rep in self._reps:
            self._tracker.register(rep.key, rep.engine.current_vid)
            self._start_worker(rep)

    # -- construction ---------------------------------------------------------

    @classmethod
    def build(
        cls,
        engine: str,
        x,
        *,
        config: Optional[FleetConfig] = None,
        durable_root: Optional[str] = None,
        fault_plan=None,
        **build_kw,
    ) -> "RMQFleet":
        """Build ``config.replicas`` serving stacks over ``x``.

        Mesh engines carve ``jax.devices()`` into disjoint equal groups, one
        per replica (requires at least one device per replica). With
        ``durable_root`` each replica journals under ``<root>/replica<i>``
        and crashed replicas can restore + rejoin; without it the fleet is
        in-memory and a crashed replica stays dead.
        """
        cfg = config if config is not None else FleetConfig()
        spec = registry.get(engine)
        if not spec.updatable:
            raise ValueError(f"fleet needs an updatable engine; {engine!r} is not")
        groups: List[Optional[list]] = [None] * cfg.replicas
        axis_names = None
        if spec.needs_mesh:
            import jax

            devs = jax.devices()
            if len(devs) < cfg.replicas:
                raise ValueError(
                    f"{cfg.replicas} replicas need >= {cfg.replicas} devices, have {len(devs)}"
                )
            per = len(devs) // cfg.replicas
            groups = [devs[i * per : (i + 1) * per] for i in range(cfg.replicas)]
            axis_names = ("shard",)
        affs = cfg.resolved_affinities()
        reps: List[_Replica] = []
        for i in range(cfg.replicas):
            mesh = make_group_mesh(groups[i]) if spec.needs_mesh else None
            if durable_root is not None:
                root = os.path.join(durable_root, f"replica{i}")
                eng = DurableEngine.create(
                    engine, x, root, mesh=mesh, axis_names=axis_names,
                    fault=fault_plan, **build_kw,
                )
            else:
                root = None
                eng = OnlineEngine(engine, x, mesh=mesh, axis_names=axis_names, **build_kw)
            scfg = dataclasses.replace(cfg.server, regime_affinity=affs[i])
            wb = build_mod.warmup_bounds(eng.plan)
            srv = RMQServer(
                online=eng, config=scfg, fault_plan=fault_plan, warmup_bounds=wb
            ).start()
            reps.append(
                _Replica(
                    i, eng, srv, affs[i],
                    root=root, mesh=mesh, axis_names=axis_names,
                    server_cfg=scfg, warmup_bounds=wb,
                )
            )
        return cls(reps, cfg, engine=engine, fault_plan=fault_plan, durable=durable_root is not None)

    # -- introspection --------------------------------------------------------

    @property
    def config(self) -> FleetConfig:
        return self._cfg

    @property
    def replicas(self) -> Tuple[_Replica, ...]:
        return tuple(self._reps)

    @property
    def threshold(self) -> int:
        """The short/long routing split (plan-resolved unless configured)."""
        return self._threshold

    @property
    def head_vid(self) -> int:
        """The fleet head version id (the last rollout's vid)."""
        return self._head_vid

    @property
    def head_n(self) -> int:
        """The logical array length at the fleet head."""
        return self._head_n

    @property
    def tracker(self) -> RolloutTracker:
        return self._tracker

    def session(self) -> FleetSession:
        return FleetSession()

    def warmup(self, sizes=None) -> None:
        """Warm every replica's jit caches (affinity regime first per replica)."""
        for rep in self._reps:
            if rep.active:
                rep.server.warmup(sizes)

    def stats(self) -> FleetStats:
        with self._route_lock:
            routed = tuple(rep.routed for rep in self._reps)
            active = sum(1 for rep in self._reps if rep.active)
            hits, misses = self._aff_hits, self._aff_misses
        with self._stats_lock:
            return FleetStats(
                replicas=len(self._reps),
                active=active,
                requests=self._requests,
                queries=self._queries,
                updates=self._updates,
                crashes=self._crashes,
                restores=self._restores,
                reroutes=self._reroutes,
                stale_reroutes=self._stale_reroutes,
                affinity_hits=hits,
                affinity_misses=misses,
                routed=routed,
                head_vid=self._head_vid,
                min_vid=self._tracker.min_vid(),
                max_lag_seen=self._tracker.max_lag_seen,
            )

    def metrics(self) -> dict:
        """Fleet-level metrics document: every replica's registry snapshot
        merged under a ``replica=<i>`` label, plus front-door families
        (routing counters, rollout totals, and the RolloutTracker's
        version-lag gauges) labelled ``replica=front``. One document, so a
        scrape or a ``--metrics-interval`` dump sees the whole fleet.
        """
        snaps = {}
        for rep in self._reps:
            with rep.lock:
                srv = rep.server
            snaps[str(rep.i)] = srv.metrics.snapshot()
        front = MetricsRegistry()
        st = self.stats()
        front.counter("fleet_requests_total").inc(st.requests)
        front.counter("fleet_queries_total").inc(st.queries)
        front.counter("fleet_rollouts_total").inc(st.updates)
        front.counter("fleet_crashes_total").inc(st.crashes)
        front.counter("fleet_restores_total").inc(st.restores)
        front.counter("fleet_reroutes_total", cause="stale").inc(st.stale_reroutes)
        front.counter("fleet_reroutes_total", cause="failure").inc(
            st.reroutes - st.stale_reroutes
        )
        front.counter("fleet_routing_total", affinity="hit").inc(st.affinity_hits)
        front.counter("fleet_routing_total", affinity="miss").inc(st.affinity_misses)
        front.gauge("fleet_active_replicas").set(st.active)
        front.gauge("fleet_head_vid").set(st.head_vid)
        front.gauge("fleet_min_vid").set(st.min_vid)
        front.gauge("fleet_version_lag").set(max(0, st.head_vid - st.min_vid))
        front.gauge("fleet_max_lag_seen").set(st.max_lag_seen)
        for rep in self._reps:
            front.gauge("fleet_replica_vid", replica_id=str(rep.i)).set(
                rep.engine.current_vid if rep.active else -1
            )
        snaps["front"] = front.snapshot()
        return merge_snapshots(snaps, label="replica")

    # -- lifecycle ------------------------------------------------------------

    def __enter__(self) -> "RMQFleet":
        return self

    def __exit__(self, *exc):
        self.close()

    def close(self, timeout: Optional[float] = None):
        with self._update_lock:
            if self._closed:
                return
            self._closed = True
        self._retryq.put(_STOP)
        for rep in self._reps:
            rep.rollouts.put(_STOP)
            # A closing fleet holds nothing back: dead keys can't wedge a
            # worker still waiting at the rollout barrier.
            self._tracker.deregister(rep.key)
        join_t = timeout if timeout is not None else 60.0
        for rep in self._reps:
            if rep.thread is not None:
                rep.thread.join(join_t)
        self._retry_thread.join(join_t)
        for rep in self._reps:
            with rep.lock:
                srv, eng = rep.server, rep.engine
                rep.active = False
            try:
                srv.close(timeout)
            except Exception:
                pass
            close_eng = getattr(eng, "close", None)
            if close_eng is not None:
                try:
                    close_eng()
                except Exception:
                    pass
        for ro in self._history.values():
            if not ro.future.done():
                try:
                    ro.future.set_exception(
                        ServerClosed("fleet closed before the rollout completed")
                    )
                except Exception:
                    pass

    # -- rollouts -------------------------------------------------------------

    def submit_update(self, deltas, *, session: Optional[FleetSession] = None) -> Future:
        """Publish one update batch to every replica (bounded-lag rollout).

        Coalesces a ``DeltaLog`` ONCE against the fleet head; every replica
        applies the identical ``DeltaBatch`` so version ids and structures
        stay aligned fleet-wide. The future resolves with the first replica's
        ``UpdateResult`` — the update is readable (and the session floor
        raised) from that moment; remaining replicas converge within
        ``max_version_lag`` versions. Use :meth:`wait_settled` for a full
        barrier.
        """
        if self._closed:
            raise ServerClosed("submit_update() on a closed fleet")
        n_ops = getattr(deltas, "n_ops", None)
        if not (len(deltas) if n_ops is None else n_ops):
            raise ValueError("submit_update() with an empty delta log")
        with self._update_lock:
            if self._closed:
                raise ServerClosed("submit_update() on a closed fleet")
            if isinstance(deltas, DeltaLog):
                batch = deltas.coalesce(self._head_n, dtype=self._dtype)
            else:
                batch = deltas
                if batch.n_old != self._head_n:
                    raise ValueError(
                        f"update batch coalesced for n={batch.n_old}, fleet head is "
                        f"n={self._head_n} (coalesce against the fleet head)"
                    )
            vid = self._head_vid + 1
            fanout = sum(1 for rep in self._reps if rep.active)
            if fanout == 0:
                raise ServerClosed("no active replicas")
            ro = _Rollout(vid, batch, fanout, session)
            self._head_vid = vid
            if batch.n_new != self._head_n:
                self._growth.append((vid, batch.n_new))
            self._head_n = batch.n_new
            self._history[vid] = ro
            for rep in self._reps:
                if rep.active:
                    rep.rollouts.put(ro)
        with self._stats_lock:
            self._updates += 1
        return ro.future

    def wait_settled(self, vid: Optional[int] = None, timeout: Optional[float] = None) -> bool:
        """Block until every live replica has published ``vid`` (default: the
        fleet head). False on timeout."""
        target = self._head_vid if vid is None else int(vid)
        return self._tracker.wait_for(
            lambda vids: (not vids) or min(vids.values()) >= target, timeout
        )

    def _start_worker(self, rep: _Replica) -> None:
        rep.thread = threading.Thread(
            target=self._rollout_worker,
            args=(rep, rep.gen),
            daemon=True,
            name=f"fleet-rollout-{rep.i}",
        )
        rep.thread.start()

    def _rollout_worker(self, rep: _Replica, gen: int) -> None:
        while True:
            item = rep.rollouts.get()
            if item is _STOP or rep.gen != gen:
                return
            ro: _Rollout = item
            tr = obs_trace.get_tracer()
            try:
                if rep.engine.current_vid >= ro.vid:
                    # A revive catch-up already applied (and acked) this
                    # batch directly; just refresh the tracker.
                    self._tracker.note(rep.key, rep.engine.current_vid)
                    ro.settle(self._durable, ok=True)
                    continue
                rospan = None
                if tr.enabled:
                    rospan = tr.start(
                        "rollout", parent=0, attrs={"replica": rep.i, "vid": ro.vid}
                    )
                try:
                    with tr.span("rollout_barrier", parent=rospan):
                        barrier_ok = self._tracker.wait_to_publish(
                            ro.vid, timeout=self._cfg.rollout_timeout_s
                        )
                    if not barrier_ok:
                        raise RuntimeError(
                            f"rollout v{ro.vid} barrier timed out on replica {rep.i}"
                        )
                    if self._fault is not None:
                        self._fault("rollout_apply")
                    with tr.span("rollout_apply", parent=rospan):
                        res = rep.server.submit_update(ro.batch).result(
                            timeout=self._cfg.rollout_timeout_s
                        )
                finally:
                    if rospan is not None:
                        tr.finish(rospan)
                self._tracker.note(rep.key, res.version)
                ro.ack(res)
                ro.settle(self._durable, ok=True)
            except BaseException as e:
                if rep.gen != gen:
                    return  # raced an external crash; the new owner cleans up
                self._crash(rep, cause=e)
                ro.settle(self._durable, ok=False)
                if self._durable and self._cfg.auto_revive and not self._closed:
                    threading.Thread(
                        target=self._revive_safe, args=(rep,), daemon=True,
                        name=f"fleet-revive-{rep.i}",
                    ).start()
                return

    # -- crash / restore ------------------------------------------------------

    def crash_replica(self, i: int, *, auto_revive: bool = False) -> None:
        """Abruptly kill replica ``i`` (chaos hook): its server is closed,
        its engine abandoned, its tracker key dropped. In-flight requests on
        it are re-routed by the front door's retry layer. Durable fleets can
        bring it back with :meth:`restore_replica` (or ``auto_revive=True``)."""
        rep = self._reps[i]
        self._crash(rep, cause=RuntimeError("externally injected crash"))
        if auto_revive and self._durable and not self._closed:
            threading.Thread(
                target=self._revive_safe, args=(rep,), daemon=True,
                name=f"fleet-revive-{rep.i}",
            ).start()

    def _crash(self, rep: _Replica, cause: BaseException) -> None:
        with rep.lock:
            if not rep.active:
                return
            rep.active = False
            rep.gen += 1
            rep.crashes += 1
            srv, eng = rep.server, rep.engine
            rep.rollouts.put(_STOP)  # unblock a worker parked on get()
        self._tracker.deregister(rep.key)
        with self._stats_lock:
            self._crashes += 1
        try:
            srv.close(timeout=10.0)
        except Exception:
            pass
        close_eng = getattr(eng, "close", None)
        if close_eng is not None:
            try:
                close_eng()
            except Exception:
                pass

    def _revive_safe(self, rep: _Replica) -> None:
        try:
            self.restore_replica(rep.i)
        except Exception:
            pass  # stays dead; restore_replica can be retried externally

    def restore_replica(self, i: int) -> None:
        """Restore crashed replica ``i`` from its durable root and rejoin it
        at the fleet head: checkpoint + journal replay brings back the vid it
        crashed at, then the missed rollout batches are replayed (and
        journaled) from the fleet history before the replica re-registers.
        No-op if the replica is already active."""
        rep = self._reps[i]
        if not self._durable:
            raise RuntimeError("restore_replica() needs a fleet built with durable_root")
        with rep.revive_lock:
            with rep.lock:
                if rep.active:
                    return
            eng = DurableEngine.restore(
                rep.root, mesh=rep.mesh, axis_names=rep.axis_names, fault=self._fault_plan
            )
            srv = RMQServer(
                online=eng,
                config=rep.server_cfg,
                fault_plan=self._fault_plan,
                warmup_bounds=rep.warmup_bounds,
            ).start()
            try:
                while True:
                    with self._update_lock:
                        nxt = self._history.get(eng.current_vid + 1)
                        if nxt is None:
                            if self._closed:
                                raise ServerClosed("fleet closed during restore")
                            # Fully caught up. Flip to active while holding
                            # the update lock so no rollout can slip between
                            # catch-up and registration.
                            with rep.lock:
                                rep.engine = eng
                                rep.server = srv
                                rep.gen += 1
                                rep.rollouts = queue.SimpleQueue()
                                rep.active = True
                                rep.restores += 1
                            self._tracker.register(rep.key, eng.current_vid)
                            self._start_worker(rep)
                            break
                    # Apply outside the lock: submissions proceed while the
                    # replica replays. Each apply journals to the replica's
                    # own WAL, so a crash during catch-up restores too.
                    res = eng.apply(nxt.batch)
                    nxt.ack(res)
            except BaseException:
                try:
                    srv.close(timeout=5.0)
                except Exception:
                    pass
                eng.close()
                raise
        with self._stats_lock:
            self._restores += 1

    # -- queries --------------------------------------------------------------

    def _classify(self, l: np.ndarray, r: np.ndarray) -> str:
        if l.size == 0:
            return "short"
        lens = np.asarray(r, np.int64) - np.asarray(l, np.int64) + 1
        return "short" if float(np.mean(lens <= self._threshold)) >= 0.5 else "long"

    def _needed_vid(self, hi: int) -> Optional[int]:
        """The version floor implied by the query's upper bound: the first
        vid whose logical length covers it (None = beyond the fleet head)."""
        g = self._growth
        if hi < g[0][1]:
            return -1  # the initial length covers it: any replica can answer
        for vid, n in g:
            if n > hi:
                return vid
        return None

    def submit(self, l, r, *, session: Optional[FleetSession] = None) -> Future:
        """Route one client request to a replica; Future -> ``RequestResult``.

        The batch's majority regime picks the replica pool (short-affinity
        vs long-affinity), round-robin within it. With a ``session``, only
        replicas at or past the session's observed version are eligible
        (read-your-writes); the response raises the session floor. Failed
        launches (a replica crashing underneath the request) are re-routed
        up to ``max_route_retries`` times before the client sees an error.
        """
        if self._closed:
            raise ServerClosed("submit() on a closed fleet")
        l = np.asarray(l)
        r = np.asarray(r)
        if l.shape != r.shape or l.ndim != 1:
            raise ValueError(f"l/r must be equal-shape 1-D arrays, got {l.shape} / {r.shape}")
        min_vid = session.last_vid if session is not None else -1
        if l.size:
            hi = int(np.asarray(r, np.int64).max())
            needed = self._needed_vid(hi)
            if needed is None:
                raise ValueError(f"query upper bound {hi} outside [0, {self._head_n})")
            min_vid = max(min_vid, needed)
        regime = self._classify(l, r)
        with self._stats_lock:
            self._requests += 1
            self._queries += int(l.size)
        outer: Future = Future()
        self._dispatch(l, r, regime, min_vid, session, outer, self._cfg.max_route_retries)
        return outer

    def _retry_loop(self) -> None:
        while True:
            item = self._retryq.get()
            if item is _STOP:
                return
            try:
                self._dispatch(*item)
            except BaseException as e:
                outer = item[5]
                if not outer.done():
                    outer.set_exception(e)

    def _dispatch(self, l, r, regime, min_vid, session, outer, tries) -> None:
        try:
            rep = self._pick(regime, min_vid)
            inner = rep.server.submit(l, r, min_version=min_vid if min_vid > 0 else None)
        except (ServerClosed, ServerOverloaded, StaleVersion) as e:
            if tries > 0 and not self._closed:
                with self._stats_lock:
                    self._reroutes += 1
                    if isinstance(e, StaleVersion):
                        self._stale_reroutes += 1
                self._dispatch(l, r, regime, min_vid, session, outer, tries - 1)
            elif not outer.done():
                outer.set_exception(e)
            return
        except BaseException as e:
            if not outer.done():
                outer.set_exception(e)
            return

        def _done(f: Future) -> None:
            exc = f.exception()
            if exc is None:
                res = f.result()
                if session is not None and res.version is not None:
                    session.observe(res.version)
                if not outer.done():
                    outer.set_result(res)
                return
            retryable = isinstance(exc, (ServerClosed, ServerOverloaded, StaleVersion)) or (
                isinstance(exc, EngineFailure) and exc.retryable
            )
            if retryable and tries > 0 and not self._closed:
                with self._stats_lock:
                    self._reroutes += 1
                    if isinstance(exc, StaleVersion):
                        self._stale_reroutes += 1
                # Re-dispatch on the fleet's retry thread: done-callbacks run
                # on replica worker threads, which must never block in _pick.
                self._retryq.put((l, r, regime, min_vid, session, outer, tries - 1))
            elif not outer.done():
                outer.set_exception(exc)

        inner.add_done_callback(_done)

    def _pick(self, regime: str, min_vid: int) -> _Replica:
        deadline = time.monotonic() + self._cfg.route_timeout_s
        while True:
            with self._route_lock:
                alive = [rep for rep in self._reps if rep.active]
                fresh = [rep for rep in alive if rep.engine.current_vid >= min_vid]
                if fresh:
                    pool = [rep for rep in fresh if rep.affinity == regime] or fresh
                    self._cursor[regime] += 1
                    rep = pool[self._cursor[regime] % len(pool)]
                    rep.routed += 1
                    if any(x.affinity == regime for x in alive):
                        if rep.affinity == regime:
                            self._aff_hits += 1
                        else:
                            self._aff_misses += 1
                    return rep
            if not alive:
                raise ServerClosed("no active replicas")
            left = deadline - time.monotonic()
            if left <= 0:
                raise StaleVersion(
                    f"no replica reached version {min_vid} within "
                    f"{self._cfg.route_timeout_s}s"
                )
            # Sleep on the tracker (not a spin): a publish, a register, or a
            # deregister re-evaluates. Short slices re-check replica health.
            self._tracker.wait_for(
                lambda vids: any(v >= min_vid for v in vids.values()),
                timeout=min(left, 0.25),
            )


# -- acceptance soak ----------------------------------------------------------


def _mutate(rng: np.random.Generator, cur: np.ndarray):
    """One random update batch + the expected post-update oracle array."""
    n = cur.shape[0]
    log = DeltaLog()
    new = cur.copy()
    op = rng.integers(0, 3)
    if op == 0:  # point writes
        for i in rng.integers(0, n, size=int(rng.integers(1, 5))):
            v = float(rng.standard_normal())
            log.point(int(i), v)
            new[int(i)] = np.float32(v)
    elif op == 1:  # constant range fill
        l = int(rng.integers(0, n))
        r = min(n - 1, l + int(rng.integers(0, 64)))
        v = float(rng.standard_normal())
        log.fill(l, r, v)
        new[l : r + 1] = np.float32(v)
    else:  # append
        tail = rng.standard_normal(int(rng.integers(1, 17))).astype(np.float32)
        log.append(tail)
        new = np.concatenate([new, tail])
    return log, new


class FleetSoakReport(NamedTuple):
    engine: str
    replicas: int
    seed: int
    requests: int
    queries: int
    updates: int
    crashes: int  # replica deaths (injected rollout fault + external)
    restores: int  # restore + rejoin cycles (auto-revive and explicit)
    reroutes: int
    lost_requests: int
    oracle_mismatches: int
    ryw_violations: int  # responses below the session's observed version
    max_lag_seen: int
    lag_bound: int
    settled: bool  # every live replica reached the fleet head at the end
    head_serves: bool  # post-soak head-version queries answer correctly
    elapsed_s: float

    @property
    def ok(self) -> bool:
        return (
            self.oracle_mismatches == 0
            and self.lost_requests == 0
            and self.ryw_violations == 0
            and self.max_lag_seen <= self.lag_bound
            and self.settled
            and self.head_serves
            and self.crashes >= 1
            and self.restores >= 1
        )

    def summary(self) -> str:
        return (
            f"[{'OK' if self.ok else 'FAIL'}] fleet {self.engine} x{self.replicas} "
            f"seed={self.seed}: {self.requests} reqs / {self.queries} RMQs, "
            f"{self.updates} rollouts, {self.crashes} crashes -> {self.restores} "
            f"restores, {self.reroutes} reroutes; mismatches={self.oracle_mismatches} "
            f"lost={self.lost_requests} ryw_violations={self.ryw_violations}; "
            f"lag {self.max_lag_seen} <= {self.lag_bound}, settled={self.settled}, "
            f"head_serves={self.head_serves}; {self.elapsed_s:.1f}s"
        )


def run_fleet_soak(
    *,
    engine: str = "hybrid",
    replicas: int = 3,
    n: int = 1 << 12,
    requests: int = 240,
    updates: int = 8,
    qbatch: int = 4,
    seed: int = 0,
    max_lag: int = 2,
    workers: int = 1,
    root: Optional[str] = None,
    packed: Optional[str] = None,
    log=None,
) -> FleetSoakReport:
    """Mutate-while-serving fleet soak with a mid-rollout crash (injected at
    the ``rollout_apply`` site -> auto-revive) AND an external replica crash
    with explicit restore. Every response is verified against the host
    oracle of the version it was answered at; session queries additionally
    assert read-your-writes. Deterministic given the arguments (thread
    interleaving aside — the invariants must hold under all of them)."""
    say = log if log is not None else (lambda *_: None)
    t0 = time.perf_counter()
    rng = np.random.default_rng(seed)
    x = rng.standard_normal(n).astype(np.float32)
    # The (replicas+1)-th rollout_apply check is the first replica to pick up
    # rollout 2: one deterministic mid-rollout death, auto-revived.
    plan = FaultPlan(seed, {"rollout_apply": FaultSpec(at=(replicas + 1,))})
    owned_root = root is None
    root = root if root is not None else tempfile.mkdtemp(prefix="rmq-fleet-")
    cfg = FleetConfig(
        replicas=replicas,
        max_version_lag=max_lag,
        auto_revive=True,
        server=ServeConfig(
            workers=workers,
            deadline_s=5e-4,
            max_retries=12,
            breaker_threshold=4,
            breaker_cooldown_s=0.02,
        ),
    )
    build_kw = {"packed": packed} if packed is not None else {}
    fleet = RMQFleet.build(
        engine, x, config=cfg, durable_root=root, fault_plan=plan, **build_kw
    )
    sessions = [fleet.session() for _ in range(3)]
    thr = fleet.threshold

    cur = x.copy()
    expected = {fleet.head_vid: cur.copy()}
    mismatches = lost = ryw = nreq = nq = 0
    pending = []  # (l, r, future, session_floor_at_submit)

    def drain():
        nonlocal mismatches, lost, ryw, nreq, nq
        for l, r, fut, floor in pending:
            nreq += 1
            nq += l.size
            try:
                res = fut.result(timeout=120)
            except Exception as e:
                lost += 1
                say(f"LOST request: {e!r}")
                continue
            if floor is not None and (res.version is None or res.version < floor):
                ryw += 1
                say(f"RYW violation: answered v{res.version} < floor v{floor}")
                continue
            ox = expected.get(res.version)
            if ox is None:
                mismatches += l.size
                say(f"unknown version {res.version}")
                continue
            for i in range(l.size):
                seg = ox[l[i] : r[i] + 1]
                if res.idx[i] != l[i] + int(np.argmin(seg)):
                    mismatches += 1
        pending.clear()

    update_every = max(1, requests // max(updates, 1))
    crash_at = requests // 2
    restore_at = (3 * requests) // 4
    victim = None
    for step in range(requests):
        if updates and step and step % update_every == 0:
            sess = sessions[(step // update_every) % len(sessions)]
            dlog, new = _mutate(rng, cur)
            res = fleet.submit_update(dlog, session=sess).result(timeout=120)
            if sess.last_vid < res.version:
                ryw += 1
                say(f"session floor {sess.last_vid} below acked v{res.version}")
            cur = new
            expected[res.version] = cur.copy()
        if step == crash_at:
            drain()
            alive = [rep.i for rep in fleet.replicas if rep.active]
            victim = alive[-1]
            say(f"externally crashing replica {victim}")
            fleet.crash_replica(victim)
        if step == restore_at and victim is not None:
            say(f"restoring replica {victim}")
            fleet.restore_replica(victim)
        nmax = cur.shape[0]
        short = step % 2 == 0
        span = max(1, thr // 2) if short else max(thr * 4, nmax // 4)
        l = rng.integers(0, nmax, qbatch).astype(np.int32)
        r = np.minimum(nmax - 1, l + rng.integers(0, span, qbatch)).astype(np.int32)
        sess = sessions[step % len(sessions)] if step % 3 == 0 else None
        floor = sess.last_vid if sess is not None and sess.last_vid >= 0 else None
        pending.append((l, r, fleet.submit(l, r, session=sess), floor))
    drain()

    settled = fleet.wait_settled(timeout=120)
    head = fleet.head_vid
    ox = expected[head]
    head_serves = True
    l = rng.integers(0, ox.shape[0], 8).astype(np.int32)
    r = np.minimum(ox.shape[0] - 1, l + rng.integers(0, 256, 8)).astype(np.int32)
    sess = fleet.session()
    sess.observe(head)
    try:
        res = fleet.submit(l, r, session=sess).result(timeout=120)
        if res.version != head:
            head_serves = False
        for i in range(8):
            seg = ox[l[i] : r[i] + 1]
            if res.idx[i] != l[i] + int(np.argmin(seg)):
                head_serves = False
    except Exception as e:
        say(f"head-version probe failed: {e!r}")
        head_serves = False

    st = fleet.stats()
    fleet.close()
    if owned_root:
        shutil.rmtree(root, ignore_errors=True)
    return FleetSoakReport(
        engine=engine,
        replicas=replicas,
        seed=seed,
        requests=nreq,
        queries=nq,
        updates=st.updates,
        crashes=st.crashes,
        restores=st.restores,
        reroutes=st.reroutes,
        lost_requests=lost,
        oracle_mismatches=mismatches,
        ryw_violations=ryw,
        max_lag_seen=st.max_lag_seen,
        lag_bound=max_lag,
        settled=settled,
        head_serves=head_serves,
        elapsed_s=time.perf_counter() - t0,
    )


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description="replica-fleet soak: regime routing, bounded-lag rollouts, crash+rejoin")
    p.add_argument("--engine", default="hybrid", choices=sorted(online_names()))
    p.add_argument("--replicas", type=int, default=3)
    p.add_argument("--n", type=int, default=1 << 12)
    p.add_argument("--requests", type=int, default=240)
    p.add_argument("--updates", type=int, default=8)
    p.add_argument("--qbatch", type=int, default=4)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--max-lag", type=int, default=2)
    p.add_argument("--workers", type=int, default=1)
    p.add_argument(
        "--packed",
        nargs="?",
        const="auto",
        choices=["auto", "packed32", "packed64", "quantized"],
        default=None,
        help="serve fused (value, index) word structures (engines declaring a "
        "'packed' build kwarg; bare --packed = 'auto')",
    )
    p.add_argument("--root", default=None, help="durability root (default: temp dir)")
    p.add_argument("--json", default=None, help="write the report as JSON here")
    p.add_argument("--quiet", action="store_true")
    args = p.parse_args(argv)

    if args.packed is not None and "packed" not in registry.get(args.engine).build_kwargs:
        p.error(
            f"--packed requires an engine with a 'packed' build kwarg; "
            f"{args.engine} declares {sorted(registry.get(args.engine).build_kwargs) or '()'}"
        )
    if registry.get(args.engine).needs_mesh:
        import jax

        ndev = len(jax.devices())
        if not args.quiet:
            print(f"{ndev} devices, {ndev // args.replicas} per replica group")

    report = run_fleet_soak(
        engine=args.engine,
        replicas=args.replicas,
        n=args.n,
        requests=args.requests,
        updates=args.updates,
        qbatch=args.qbatch,
        seed=args.seed,
        max_lag=args.max_lag,
        workers=args.workers,
        root=args.root,
        packed=args.packed,
        log=None if args.quiet else print,
    )
    print(report.summary())
    if args.json:
        with open(args.json, "w") as f:
            json.dump(report._asdict(), f, indent=2, default=str)
    return 0 if report.ok else 1


if __name__ == "__main__":
    sys.exit(main())
