"""Shared scaffolding for the tiled query kernels.

Two grid generations coexist here:

* v1 — a 1D grid ``(B // tile,)`` where every data-dependent row needs its
  own pallas_call operand slot, so callers repeat each operand ``tile``
  times, one ``row_spec`` (with its ``t=t`` default-arg closure capture) per
  slot. ``rmq_query`` and ``lane_query`` still use this idiom.
* v2 — a 2D grid ``(B // tile, tile)`` whose minor axis walks the queries of
  a tile, so ONE operand with a ``tiled2_*`` index map serves every slot and
  dispatch arg count stays constant in ``tile``. ``fused_query`` uses this
  (see its module docstring for the scratch-accumulator merge protocol).

The batch padding and SMEM scalar stacking are shared by both.
"""

from __future__ import annotations

import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = [
    "pad_to_tiles",
    "row_spec",
    "scalar_col",
    "tile_out_specs",
    "tiled2_out_specs",
    "tiled2_row_spec",
    "tiled2_window_spec",
]


def pad_to_tiles(args, b: int, tile: int):
    """Zero-pad each (B,) int array to a whole number of tiles.

    The pad queries resolve to block/row 0 with trivial bounds — valid by
    construction; callers slice outputs back to ``b``. Returns (args, bp).
    """
    bp = -(-b // tile) * tile
    if bp != b:
        args = [jnp.pad(a, (0, bp - b)) for a in args]
    return args, bp


def row_spec(block_shape, sel: int, t: int, tile: int) -> pl.BlockSpec:
    """BlockSpec fetching one data-dependent row per query.

    ``sel`` picks which scalar-prefetch operand carries the row id; ``t`` is
    the query's slot within the tile. The defaults pin the loop variables at
    definition time (the classic late-binding closure trap).
    """
    return pl.BlockSpec(
        block_shape, lambda i, *s, t=t, sel=sel: (s[sel][i * tile + t], 0)
    )


def scalar_col(ref, q0, tile: int):
    """Stack a tile's per-query scalars from an SMEM prefetch ref: (tile,)."""
    return jnp.stack([ref[q0 + t] for t in range(tile)])


def tile_out_specs(tile: int):
    """The two (tile, 1) outputs (value, index) every query kernel emits."""
    return [
        pl.BlockSpec((tile, 1), lambda i, *s: (i, 0)),
        pl.BlockSpec((tile, 1), lambda i, *s: (i, 0)),
    ]


def tiled2_row_spec(block_shape, sel: int, tile: int) -> pl.BlockSpec:
    """2D-grid BlockSpec fetching one data-dependent row per minor step.

    The minor grid id ``t`` selects the query within the tile, so a single
    operand serves all tile slots: row id = ``prefetch[sel][i * tile + t]``.
    """
    return pl.BlockSpec(
        block_shape, lambda i, t, *s, sel=sel: (s[sel][i * tile + t], 0)
    )


def tiled2_window_spec(w: int, rsel: int, wsel: int, tile: int) -> pl.BlockSpec:
    """2D-grid BlockSpec fetching a (1, w) window of a 2D table per minor step.

    Row id from ``prefetch[rsel]``, window (column-block) id from
    ``prefetch[wsel]`` — both indexed by the query slot ``i * tile + t``. The
    window id is in block coordinates: element offset = id * w.
    """
    return pl.BlockSpec(
        (1, w),
        lambda i, t, *s, rsel=rsel, wsel=wsel: (
            s[rsel][i * tile + t],
            s[wsel][i * tile + t],
        ),
    )


def tiled2_out_specs(tile: int):
    """The two (tile, 1) outputs on the 2D grid (block revisited across t)."""
    return [
        pl.BlockSpec((tile, 1), lambda i, t, *s: (i, 0)),
        pl.BlockSpec((tile, 1), lambda i, t, *s: (i, 0)),
    ]
