"""Shared scaffolding for the tiled query kernels.

All three query kernels (``rmq_query``, ``lane_query``, ``fused_query``) use
the same grid layout — ``tile`` queries per grid step, scalar-prefetch-driven
data-dependent row DMAs — so the batch padding, the per-query row BlockSpec
(with its ``t=t`` default-arg closure capture), the SMEM scalar stacking, and
the (tile, 1) output specs live here once.
"""

from __future__ import annotations

import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["pad_to_tiles", "row_spec", "scalar_col", "tile_out_specs"]


def pad_to_tiles(args, b: int, tile: int):
    """Zero-pad each (B,) int array to a whole number of tiles.

    The pad queries resolve to block/row 0 with trivial bounds — valid by
    construction; callers slice outputs back to ``b``. Returns (args, bp).
    """
    bp = -(-b // tile) * tile
    if bp != b:
        args = [jnp.pad(a, (0, bp - b)) for a in args]
    return args, bp


def row_spec(block_shape, sel: int, t: int, tile: int) -> pl.BlockSpec:
    """BlockSpec fetching one data-dependent row per query.

    ``sel`` picks which scalar-prefetch operand carries the row id; ``t`` is
    the query's slot within the tile. The defaults pin the loop variables at
    definition time (the classic late-binding closure trap).
    """
    return pl.BlockSpec(
        block_shape, lambda i, *s, t=t, sel=sel: (s[sel][i * tile + t], 0)
    )


def scalar_col(ref, q0, tile: int):
    """Stack a tile's per-query scalars from an SMEM prefetch ref: (tile,)."""
    return jnp.stack([ref[q0 + t] for t in range(tile)])


def tile_out_specs(tile: int):
    """The two (tile, 1) outputs (value, index) every query kernel emits."""
    return [
        pl.BlockSpec((tile, 1), lambda i, *s: (i, 0)),
        pl.BlockSpec((tile, 1), lambda i, *s: (i, 0)),
    ]
