"""Jit'd public wrappers: the kernelized RTXRMQ-TPU engine.

``build`` / ``query`` mirror ``repro.core.block_rmq`` but route the hot path
through the Pallas kernels (validated in interpret mode on CPU, compiled for
TPU on real hardware). ``query`` dispatches the *fused tiled megakernel*
(``fused_query.py``): one kernel launch answers the whole batch end-to-end —
partials, sparse-table interior, and final merge — ``tile`` queries per grid
step, with the launch geometry (tile, table fetch strategy) taken from a
``tuning.KernelConfig``. ``build`` returns a ``FusedRMQ``: the shared
``BlockRMQ`` fields plus the value-augmented doubling tables the DMA fetch
strategy reads, precomputed once so the per-query jaxpr stays gather-free.
The legacy two-pass path (partials kernel + XLA interior/merge) remains
available via ``query(..., fused=False)`` for A/B benchmarking.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import block_rmq, packing, sparse_table
from repro.core.block_rmq import BlockRMQ, maxval, _pick

from .block_min import block_min
from .fused_query import DEFAULT_TILE, fused_query, fused_query_packed, interior_tables
from .lane_query import lane_partials
from .rmq_query import rmq_partials
from .tuning import KernelConfig

__all__ = [
    "FusedRMQ",
    "PackedFusedRMQ",
    "build",
    "build_packed",
    "query",
    "query_packed",
    "block_min",
    "fused_query",
    "fused_query_packed",
    "rmq_partials",
    "lane_query",
    "lane_partials",
]


class FusedRMQ(NamedTuple):
    """Megakernel state: ``BlockRMQ``'s fields + the DMA-strategy tables.

    A separate type (rather than widening ``BlockRMQ``) because
    ``distributed.py``'s PartitionSpecs mirror ``BlockRMQ``'s field layout;
    the augmented tables are single-host kernel state only.
    """

    x_blocks: jax.Array  # (nb, bs)
    bmin_val: jax.Array  # (nb,)
    bmin_gidx: jax.Array  # (nb,) int32
    st: sparse_table.SparseTable  # doubling table over bmin_val
    st_val: jax.Array  # (K, nb): bmin_val[st.idx] (DMA fetch strategy)
    st_gidx: jax.Array  # (K, nb) int32: bmin_gidx[st.idx]


def build(x: jax.Array, block_size: int, *, interpret: bool | None = None) -> FusedRMQ:
    """Kernelized build: Pallas per-block minima + doubling tables."""
    if block_size % 128 != 0:
        raise ValueError(f"block_size must be a multiple of 128, got {block_size}")
    n = x.shape[0]
    nb = -(-n // block_size)
    big = maxval(x.dtype)
    xp = jnp.pad(x, (0, nb * block_size - n), constant_values=big)
    xb = xp.reshape(nb, block_size)
    bmin_val, lidx = block_min(xb, interpret=interpret)
    bmin_gidx = jnp.arange(nb, dtype=jnp.int32) * block_size + lidx
    st = sparse_table.build(bmin_val)
    st_val, st_gidx = interior_tables(bmin_val, bmin_gidx, st.idx)
    return FusedRMQ(
        x_blocks=xb,
        bmin_val=bmin_val,
        bmin_gidx=bmin_gidx,
        st=st,
        st_val=st_val,
        st_gidx=st_gidx,
    )


class PackedFusedRMQ(NamedTuple):
    """Packed megakernel state (DESIGN.md §13): single-plane tables.

    ``blocks`` holds packed words for exact layouts (the kernel's partial
    scan is a word min) or raw values for the quantized layout (partials
    need exact values); ``stw`` is the packed doubling table over block
    minima — the only table the kernel fetches. ``bmin_val`` is the
    quantized layout's exact-fallback resident plane (None otherwise).
    The shared ``PackSpec`` rides beside the state, not in it, so this
    pytree stays all-array (checkpoint leaves, device_put, shard specs).
    """

    blocks: jax.Array  # (nb, bs) packed words | raw values (quantized)
    stw: jax.Array  # (K, nb) packed doubling table
    bmin_val: jax.Array | None = None  # (nb,) exact minima, quantized only


def build_packed(
    x: jax.Array,
    block_size: int,
    *,
    spec=None,
    layout: str = "auto",
    interpret: bool | None = None,
):
    """Packed kernel build. Returns ``(PackedFusedRMQ, spec)``.

    Structure math is shared with ``core.block_rmq.build_packed`` (the
    kernel consumes the same word planes the XLA engines do); the quantized
    layout additionally keeps its exact per-block minima for the in-kernel
    fallback hop. ``interpret`` is accepted for signature parity with
    ``build`` — the packed build is pure XLA.
    """
    del interpret  # no Pallas stage in the packed build
    if block_size % 128 != 0:
        raise ValueError(f"block_size must be a multiple of 128, got {block_size}")
    s, spec = block_rmq.build_packed(x, block_size, spec=spec, layout=layout)
    bmin_val = None
    if spec.layout == "quantized":
        bmin_val = jnp.min(s.blocks, axis=1)  # blocks are raw (maxval-padded)
    return PackedFusedRMQ(blocks=s.blocks, stw=s.stw, bmin_val=bmin_val), spec


def query_packed(
    s: PackedFusedRMQ,
    spec,
    l: jax.Array,
    r: jax.Array,
    *,
    config: KernelConfig | None = None,
    tile: int | None = None,
    fetch: str | None = None,
    interpret: bool | None = None,
):
    """Packed megakernel batched query -> (leftmost argmin idx int32, value).

    Mirrors :func:`query` over ``PackedFusedRMQ`` state; the launch
    geometry comes from ``config`` (its ``layout`` field is the tuner's
    bookkeeping — the structure's ``spec`` is authoritative here).
    """
    if config is None:
        config = KernelConfig()
    if tile is None:
        tile = config.tile
    if fetch is None:
        fetch = config.fetch
    return fused_query_packed(
        s.blocks,
        s.stw,
        l,
        r,
        spec=spec,
        bmin_val=s.bmin_val,
        tile=tile,
        fetch=fetch,
        interpret=interpret,
    )


def query(
    s,
    l: jax.Array,
    r: jax.Array,
    *,
    config: KernelConfig | None = None,
    tile: int | None = None,
    fetch: str | None = None,
    fused: bool = True,
    interpret: bool | None = None,
):
    """Kernelized batched query. Returns (leftmost argmin idx int32, value).

    ``s`` is a ``FusedRMQ`` (or a bare ``BlockRMQ``, in which case the DMA
    strategy derives its augmented tables on the fly). ``config`` carries the
    tuned launch geometry (its build-time ``block_size`` knob is ignored here
    — the structure is already committed to one); ``tile``/``fetch`` override
    the individual knobs for direct A/B calls.

    ``fused=True`` (default): single megakernel dispatch (fused_query.py).
    ``fused=False``: legacy two-pass path — tiled partials kernel, then the
    XLA sparse-table interior + merge (kept for A/B benchmarking).
    """
    if config is None:
        config = KernelConfig()
    if tile is None:
        tile = config.tile
    if fetch is None:
        fetch = config.fetch
    if fused:
        return fused_query(
            s.x_blocks, s.bmin_val, s.bmin_gidx, s.st.idx, l, r,
            st_val=getattr(s, "st_val", None),
            st_gidx=getattr(s, "st_gidx", None),
            tile=tile, fetch=fetch, interpret=interpret,
        )
    bs = s.x_blocks.shape[1]
    nb = s.x_blocks.shape[0]
    big = maxval(s.x_blocks.dtype)
    l = l.astype(jnp.int32)
    r = r.astype(jnp.int32)

    bl = l // bs
    br = r // bs
    ll = l - bl * bs
    rl = r - br * bs
    lend = jnp.where(bl == br, rl, bs - 1)

    pv, pi = rmq_partials(s.x_blocks, bl, br, ll, lend, rl, tile=tile, interpret=interpret)

    has_interior = (br - bl) >= 2
    ilo = jnp.clip(bl + 1, 0, nb - 1)
    ihi = jnp.maximum(jnp.clip(br - 1, 0, nb - 1), ilo)
    bi = sparse_table.query(s.st, ilo, ihi)
    iv = jnp.where(has_interior, s.bmin_val[bi], big)
    ii = s.bmin_gidx[bi]

    # Partial candidates straddle the interior in index order; exactness of
    # the leftmost tie still holds: if the interior ties with the left
    # partial, the left partial's indices are smaller; if it ties with the
    # right partial, the interior's indices are smaller — and the fused
    # kernel already resolved left-vs-right. Prefer (left|right) only when
    # strictly smaller OR when it is the left partial (pi < interior block
    # range start).
    int_start = (bl + 1) * bs
    prefer_partial = (pv < iv) | ((pv == iv) & (pi < int_start))
    v = jnp.where(prefer_partial, pv, iv)
    i = jnp.where(prefer_partial, pi, ii)
    return i, v


def lane_query(
    s,
    l: jax.Array,
    r: jax.Array,
    *,
    tile: int = DEFAULT_TILE,
    interpret: bool | None = None,
):
    """Kernelized beyond-paper lane-RMQ query (mirrors core.lane_rmq.query).

    The fused tiled Pallas kernel answers the same-block case and the
    straddle prefix/suffix candidates (``tile`` queries per grid step); the
    O(1) sparse-table interior stays in XLA.
    """
    from repro.core import lane_rmq, sparse_table
    from repro.core.block_rmq import _pick

    nsub = s.xs.shape[0]
    big = maxval(s.xs.dtype)
    l = l.astype(jnp.int32)
    r = r.astype(jnp.int32)
    sl = l // lane_rmq.LANE
    sr = r // lane_rmq.LANE
    llo = l - sl * lane_rmq.LANE
    rlo = r - sr * lane_rmq.LANE

    pv, pi = lane_partials(
        s.xs, s.suff_val, s.suff_idx, s.pref_val, s.pref_idx,
        sl, sr, llo, rlo, tile=tile, interpret=interpret,
    )

    has_interior = (sr - sl) >= 2
    ilo = jnp.clip(sl + 1, 0, nsub - 1)
    ihi = jnp.maximum(jnp.clip(sr - 1, 0, nsub - 1), ilo)
    bi = sparse_table.query(s.st, ilo, ihi)
    iv = jnp.where(has_interior, s.st.x[bi], big)
    ii = s.sub_gidx[bi]
    # same tie logic as kernels.ops.query: the interior's indices sit between
    # the suffix and prefix candidates, so prefer the partial only when it is
    # strictly smaller or it comes from the left (suffix) side.
    int_start = (sl + 1) * lane_rmq.LANE
    prefer_partial = (pv < iv) | ((pv == iv) & (pi < int_start))
    return jnp.where(prefer_partial, pi, ii), jnp.where(prefer_partial, pv, iv)
