"""Pure-jnp oracles for the Pallas kernels (kernel sweeps assert against these)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.block_rmq import maxval

__all__ = ["block_min_ref", "rmq_partials_ref"]


def block_min_ref(x_blocks: jax.Array):
    """Per-block (min value, leftmost local argmin int32)."""
    lidx = jnp.argmin(x_blocks, axis=1).astype(jnp.int32)
    val = jnp.take_along_axis(x_blocks, lidx[:, None], axis=1)[:, 0]
    return val, lidx


def rmq_partials_ref(x_blocks, bl, br, lstart, lend, rend):
    """Combined partial-block candidate per query.

    Left partial  = min of x_blocks[bl, lstart:lend+1]   (always non-empty)
    Right partial = min of x_blocks[br, 0:rend+1]        (masked off when bl==br)
    Returns the leftmost-tie merge of both as (value, global index int32).
    """
    bs = x_blocks.shape[1]
    big = maxval(x_blocks.dtype)
    lanes = jnp.arange(bs, dtype=jnp.int32)[None, :]

    rows_l = jnp.take(x_blocks, bl, axis=0)
    ml = jnp.where((lanes >= lstart[:, None]) & (lanes <= lend[:, None]), rows_l, big)
    li = jnp.argmin(ml, axis=1).astype(jnp.int32)
    lv = jnp.take_along_axis(ml, li[:, None], axis=1)[:, 0]
    lg = bl * bs + li

    rows_r = jnp.take(x_blocks, br, axis=0)
    mr = jnp.where(lanes <= rend[:, None], rows_r, big)
    ri = jnp.argmin(mr, axis=1).astype(jnp.int32)
    rv = jnp.take_along_axis(mr, ri[:, None], axis=1)[:, 0]
    rv = jnp.where(br > bl, rv, big)
    rg = br * bs + ri

    take_l = lv <= rv
    return jnp.where(take_l, lv, rv), jnp.where(take_l, lg, rg)
