"""Pallas TPU kernels for the RMQ hot spots (+ ops wrappers, ref oracles)."""

from . import ops, ref
from .block_min import block_min
from .rmq_query import rmq_partials

__all__ = ["ops", "ref", "block_min", "rmq_partials"]
