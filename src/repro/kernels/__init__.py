"""Pallas TPU kernels for the RMQ hot spots (+ ops wrappers, ref oracles)."""

from . import ops, ref
from .block_min import block_min
from .fused_query import fused_query
from .rmq_query import rmq_partials

__all__ = ["ops", "ref", "block_min", "fused_query", "rmq_partials"]
