"""Pallas TPU kernel: per-block min + leftmost argmin (build phase, level 1).

This is the preprocessing analogue of RTXRMQ's geometry build: one VMEM tile
of blocks per grid step, a vector min along lanes, and a min-over-iota trick
for the *leftmost* argmin using only min-reductions (MXU/VPU friendly — no
data-dependent control flow, matching TPU's systolic/vector execution model).

Tiling: the (tile_rows, block_size) input block lives in VMEM; block_size is
a multiple of 128 (lane width) by construction (enforced in core.block_rmq),
and tile_rows trades VMEM footprint vs. grid overhead.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.block_rmq import maxval

__all__ = ["block_min"]


def _kernel(x_ref, val_ref, idx_ref):
    x = x_ref[...]  # (tile_rows, bs) in VMEM
    bs = x.shape[1]
    vmin = jnp.min(x, axis=1)
    lanes = jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)
    cand = jnp.where(x == vmin[:, None], lanes, jnp.int32(bs))
    lidx = jnp.min(cand, axis=1)  # leftmost argmin via min-reduce
    val_ref[...] = vmin[:, None]
    idx_ref[...] = lidx[:, None]


@functools.partial(jax.jit, static_argnames=("tile_rows", "interpret"))
def block_min(x_blocks: jax.Array, *, tile_rows: int = 8, interpret: bool | None = None):
    """Per-block (min value, leftmost local argmin). x_blocks: (nb, bs)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    nb, bs = x_blocks.shape
    pad = (-nb) % tile_rows
    if pad:
        x_blocks = jnp.pad(x_blocks, ((0, pad), (0, 0)), constant_values=maxval(x_blocks.dtype))
    nbp = nb + pad
    val, idx = pl.pallas_call(
        _kernel,
        grid=(nbp // tile_rows,),
        in_specs=[pl.BlockSpec((tile_rows, bs), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((tile_rows, 1), lambda i: (i, 0)),
            pl.BlockSpec((tile_rows, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nbp, 1), x_blocks.dtype),
            jax.ShapeDtypeStruct((nbp, 1), jnp.int32),
        ],
        interpret=interpret,
    )(x_blocks)
    return val[:nb, 0], idx[:nb, 0]
