"""Pallas TPU kernel: fused lane-RMQ query (beyond-paper O(1) engine).

Fuses the per-query work of ``repro.core.lane_rmq.query`` minus the O(1)
sparse-table interior (which stays in XLA): one grid step per query loads
three 128-lane rows — the suffix-min row of l's lane-block, the prefix-min
row of r's lane-block, and the raw row for the same-block case — and emits
the merged (value, global index) candidate. On TPU each row is exactly one
VREG, so the whole query is a handful of vector ops; scalar prefetch drives
the data-dependent row selection (same pattern as rmq_query.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.block_rmq import maxval
from repro.core.lane_rmq import LANE

__all__ = ["lane_partials"]


def _kernel(sl_ref, sr_ref, llo_ref, rlo_ref,
            sv_ref, si_ref, pv_ref, pi_ref, xs_ref,
            val_ref, idx_ref):
    i = pl.program_id(0)
    big = maxval(xs_ref.dtype)
    lanes = jax.lax.broadcasted_iota(jnp.int32, (1, LANE), 1)
    llo = llo_ref[i]
    rlo = rlo_ref[i]
    same = sl_ref[i] == sr_ref[i]

    # straddling candidates: one dynamic lane pick from each min row
    lv = sv_ref[0, llo]
    li = si_ref[0, llo]
    rv = pv_ref[0, rlo]
    ri = pi_ref[0, rlo]
    take_l = lv <= rv  # suffix candidate has smaller indices on ties
    str_v = jnp.where(take_l, lv, rv)
    str_i = jnp.where(take_l, li, ri)

    # same-block: masked vector min over the raw row (one VREG op)
    row = xs_ref[...]
    masked = jnp.where((lanes >= llo) & (lanes <= rlo), row, big)
    mv = jnp.min(masked)
    mi = jnp.min(jnp.where(masked == mv, lanes, jnp.int32(LANE)))
    mi = sl_ref[i] * LANE + mi

    val_ref[0, 0] = jnp.where(same, mv, str_v)
    idx_ref[0, 0] = jnp.where(same, mi, str_i)


@functools.partial(jax.jit, static_argnames=("interpret",))
def lane_partials(
    xs: jax.Array,  # (nsub, LANE)
    suff_val: jax.Array, suff_idx: jax.Array,  # (nsub, LANE)
    pref_val: jax.Array, pref_idx: jax.Array,
    sl: jax.Array, sr: jax.Array, llo: jax.Array, rlo: jax.Array,  # (B,)
    *,
    interpret: bool | None = None,
):
    """Fused non-interior candidates. Returns (value (B,), global idx (B,))."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b = sl.shape[0]
    args = [a.astype(jnp.int32) for a in (sl, sr, llo, rlo)]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, LANE), lambda i, sl, sr, llo, rlo: (sl[i], 0)),  # suff_val
            pl.BlockSpec((1, LANE), lambda i, sl, sr, llo, rlo: (sl[i], 0)),  # suff_idx
            pl.BlockSpec((1, LANE), lambda i, sl, sr, llo, rlo: (sr[i], 0)),  # pref_val
            pl.BlockSpec((1, LANE), lambda i, sl, sr, llo, rlo: (sr[i], 0)),  # pref_idx
            pl.BlockSpec((1, LANE), lambda i, sl, sr, llo, rlo: (sl[i], 0)),  # xs
        ],
        out_specs=[
            pl.BlockSpec((1, 1), lambda i, *_: (i, 0)),
            pl.BlockSpec((1, 1), lambda i, *_: (i, 0)),
        ],
    )
    val, idx = pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b, 1), xs.dtype),
            jax.ShapeDtypeStruct((b, 1), jnp.int32),
        ],
        interpret=interpret,
    )(*args, suff_val, suff_idx, pref_val, pref_idx, xs)
    return val[:, 0], idx[:, 0]
