"""Pallas TPU kernel: fused lane-RMQ query (beyond-paper O(1) engine).

Fuses the per-query work of ``repro.core.lane_rmq.query`` minus the O(1)
sparse-table interior (which stays in XLA). The grid is tiled
``(B // tile,)``: each step answers ``tile`` queries, loading per query three
128-lane rows — the suffix-min row of l's lane-block, the prefix-min row of
r's lane-block, and the raw row for the same-block case. The same-block
masked min runs vectorized on the ``(tile, LANE)`` stack of raw rows (one VPU
op per tile rather than per query); the straddle candidates are scalar VMEM
picks. Scalar prefetch drives the data-dependent row selection (same pattern
as rmq_query.py); ``tile=1`` reproduces the original one-query-per-step
layout.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.block_rmq import maxval
from repro.core.lane_rmq import LANE

from .tiling import pad_to_tiles, row_spec, scalar_col, tile_out_specs
from .tuning import DEFAULT_TILE

__all__ = ["lane_partials", "DEFAULT_TILE"]



def _kernel(tile, sl_ref, sr_ref, llo_ref, rlo_ref, *refs):
    sv_refs = refs[0:tile]
    si_refs = refs[tile : 2 * tile]
    pv_refs = refs[2 * tile : 3 * tile]
    pi_refs = refs[3 * tile : 4 * tile]
    xs_refs = refs[4 * tile : 5 * tile]
    val_ref, idx_ref = refs[5 * tile], refs[5 * tile + 1]

    i = pl.program_id(0)
    q0 = i * tile
    big = maxval(xs_refs[0].dtype)
    lanes = jax.lax.broadcasted_iota(jnp.int32, (tile, LANE), 1)

    def col(ref):
        return scalar_col(ref, q0, tile)

    sl, sr, llo, rlo = col(sl_ref), col(sr_ref), col(llo_ref), col(rlo_ref)
    same = sl == sr

    # Straddling candidates: one dynamic lane pick from each min row.
    lv = jnp.stack([sv_refs[t][0, llo_ref[q0 + t]] for t in range(tile)])
    li = jnp.stack([si_refs[t][0, llo_ref[q0 + t]] for t in range(tile)])
    rv = jnp.stack([pv_refs[t][0, rlo_ref[q0 + t]] for t in range(tile)])
    ri = jnp.stack([pi_refs[t][0, rlo_ref[q0 + t]] for t in range(tile)])
    take_l = lv <= rv  # suffix candidate has smaller indices on ties
    str_v = jnp.where(take_l, lv, rv)
    str_i = jnp.where(take_l, li, ri)

    # Same-block: masked vector min over the (tile, LANE) stack of raw rows.
    rows = jnp.concatenate([r[...] for r in xs_refs], axis=0)
    masked = jnp.where((lanes >= llo[:, None]) & (lanes <= rlo[:, None]), rows, big)
    mv = jnp.min(masked, axis=1)
    mi = jnp.min(jnp.where(masked == mv[:, None], lanes, jnp.int32(LANE)), axis=1)
    mi = sl * LANE + mi

    val_ref[...] = jnp.where(same, mv, str_v)[:, None]
    idx_ref[...] = jnp.where(same, mi, str_i)[:, None]


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def lane_partials(
    xs: jax.Array,  # (nsub, LANE)
    suff_val: jax.Array, suff_idx: jax.Array,  # (nsub, LANE)
    pref_val: jax.Array, pref_idx: jax.Array,
    sl: jax.Array, sr: jax.Array, llo: jax.Array, rlo: jax.Array,  # (B,)
    *,
    tile: int = DEFAULT_TILE,
    interpret: bool | None = None,
):
    """Fused non-interior candidates. Returns (value (B,), global idx (B,))."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b = sl.shape[0]
    args = [a.astype(jnp.int32) for a in (sl, sr, llo, rlo)]

    args, bp = pad_to_tiles(args, b, tile)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(bp // tile,),
        in_specs=(
            # data-dependent row picks driven by sl (sel=0) / sr (sel=1)
            [row_spec((1, LANE), 0, t, tile) for t in range(tile)]  # suff_val @ sl
            + [row_spec((1, LANE), 0, t, tile) for t in range(tile)]  # suff_idx @ sl
            + [row_spec((1, LANE), 1, t, tile) for t in range(tile)]  # pref_val @ sr
            + [row_spec((1, LANE), 1, t, tile) for t in range(tile)]  # pref_idx @ sr
            + [row_spec((1, LANE), 0, t, tile) for t in range(tile)]  # raw xs @ sl
        ),
        out_specs=tile_out_specs(tile),
    )
    val, idx = pl.pallas_call(
        functools.partial(_kernel, tile),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((bp, 1), xs.dtype),
            jax.ShapeDtypeStruct((bp, 1), jnp.int32),
        ],
        interpret=interpret,
    )(
        *args,
        *([suff_val] * tile),
        *([suff_idx] * tile),
        *([pref_val] * tile),
        *([pref_idx] * tile),
        *([xs] * tile),
    )
    return val[:b, 0], idx[:b, 0]
