"""Pallas TPU megakernel v2: tiled, fully-fused blocked-RMQ query.

One ``pallas_call`` answers a query batch end-to-end — left partial, right
partial, *and* the O(1) sparse-table interior candidate — emitting the final
``(idx, val)``. This collapses the previous three dispatches (partials
kernel, XLA sparse-table gathers, XLA merge) into a single kernel launch.

Grid: ``(B // tile, tile)``. The minor axis walks the queries of a tile; each
minor step DMAs exactly the rows *that one query* needs via scalar-prefetch
index maps and stages them into ``(tile, bs)`` VMEM scratch accumulators. At
the last minor step the whole tile merges vectorized — one VPU masked min per
partial side — and writes the revisited ``(tile, 1)`` output block. Compared
to v1 (a 1D grid whose pallas_call repeated every operand ``tile`` times so
each slot could carry its own index map), operand count is constant in
``tile``: one operand per logical input, with the minor grid id selecting the
per-query row. That keeps lowering time flat while the autotuner sweeps
larger tiles.

Two fetch strategies share the kernel body (``fetch=``):

  * ``"resident"`` — the per-block min arrays (``bmin_val``/``bmin_gidx``)
    ride along as constant whole-array VMEM residents and the level-k
    doubling-table row ``st.idx[k[q], :]`` is DMA'd per query. Per-step DMA
    volume grows with nb (the row is ``(1, nb)``), which caps this path at
    nb ~ 2^13 blocks.
  * ``"dma"`` — nothing nb-sized touches VMEM. The doubling table is
    *value-augmented* at build time (``st_val[k, p] = bmin_val[st.idx[k, p]]``
    and ``st_gidx`` likewise, see :func:`interior_tables`), so the interior
    candidate needs only the two table cells at ``(k, ilo)`` and
    ``(k, bpos)``. Each query DMAs four ``(1, 128)`` lane-aligned windows
    (value + gidx at each of the two positions) — bounded VMEM for
    arbitrarily large nb.

``fetch="auto"`` picks per the nb ceiling (``tuning.RESIDENT_NB_CEILING``).
Both strategies are bit-identical to the oracle: the lo window starts at or
before the hi window (``ilo <= bpos``), so preferring lo on value ties is
exactly the leftmost rule ``sparse_table._pick_left`` applies to the
resident tables.

Correctness: the merge keeps the exact leftmost-tie rule of
``kernels/ops.py`` — partial candidates are merged left-over-right
(``lv <= rv``), then preferred over the interior only when strictly smaller
or when the partial index lies left of the interior's block range
(``pi < (bl + 1) * bs``). See DESIGN.md §4 and §12.
"""

from __future__ import annotations

import functools
import logging

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import packing
from repro.core.block_rmq import maxval
from repro.core.sparse_table import exact_log2

from .tiling import (
    pad_to_tiles,
    scalar_col,
    tiled2_out_specs,
    tiled2_row_spec,
    tiled2_window_spec,
)
from .tuning import DEFAULT_TILE, RESIDENT_NB_CEILING, resolve_fetch

__all__ = ["fused_query", "fused_query_packed", "interior_tables", "DEFAULT_TILE"]

_logger = logging.getLogger(__name__)

# One warning per process for the derive-on-the-fly DMA path (below); a
# per-call warning would flood serving logs, and a per-jit-cache warning
# would be silent exactly when the recompute recurs (same shapes re-trace).
_warned_materialize = False

# DMA window width: one lane-aligned VREG row per fetched table cell.
_W = 128

# Scalar-prefetch operand order (SMEM, available to index maps + kernel).
_N_PREFETCH = 11  # bl, br, ls, le, re, k, ilo, bpos, hasint, wlo, whi


def _kernel(tile, fetch, *refs):
    (bl_ref, br_ref, ls_ref, le_ref, re_ref,
     k_ref, ilo_ref, bpos_ref, hasint_ref, wlo_ref, whi_ref) = refs[:_N_PREFETCH]
    body = refs[_N_PREFETCH:]
    xl_ref, xr_ref = body[0], body[1]
    if fetch == "resident":
        st_ref, bv_ref, bg_ref = body[2:5]
        val_ref, idx_ref = body[5:7]
        xl_acc, xr_acc, iv_acc, ii_acc = body[7:11]
    else:
        lov_ref, hiv_ref, log_ref, hig_ref = body[2:6]
        val_ref, idx_ref = body[6:8]
        xl_acc, xr_acc, iv_acc, ii_acc = body[8:12]

    i = pl.program_id(0)
    t = pl.program_id(1)
    q = i * tile + t
    bs = xl_ref.shape[1]
    big = maxval(xl_ref.dtype)

    # Stage this query's partial-block rows into the tile accumulators.
    xl_acc[pl.ds(t, 1)] = xl_ref[...]
    xr_acc[pl.ds(t, 1)] = xr_ref[...]

    # This query's interior candidate -> SMEM slots; the merge step reads
    # them back as a (tile,) vector.
    if fetch == "resident":
        a = st_ref[0, ilo_ref[q]]
        b = st_ref[0, bpos_ref[q]]
        av = bv_ref[0, a]
        bv = bv_ref[0, b]
        ai = bg_ref[0, a]
        bi = bg_ref[0, b]
    else:
        off_lo = ilo_ref[q] - wlo_ref[q] * _W
        off_hi = bpos_ref[q] - whi_ref[q] * _W
        av = lov_ref[0, off_lo]
        bv = hiv_ref[0, off_hi]
        ai = log_ref[0, off_lo]
        bi = hig_ref[0, off_hi]
    # Leftmost tie: the lo cell covers [ilo, ilo+2^k) which starts at or
    # before the hi cell's [bpos, ihi], so prefer lo on equal values.
    iv_acc[t] = jnp.where(hasint_ref[q] == 1, jnp.minimum(av, bv), big)
    ii_acc[t] = jnp.where(av <= bv, ai, bi)

    @pl.when(t == tile - 1)
    def _merge():
        big_i = jnp.int32(bs)
        lanes = jax.lax.broadcasted_iota(jnp.int32, (tile, bs), 1)
        q0 = i * tile

        def col(ref):  # (tile,) vector of per-query scalars from SMEM
            return scalar_col(ref, q0, tile)

        bl, br, ls, le, re = col(bl_ref), col(br_ref), col(ls_ref), col(le_ref), col(re_ref)

        # Left partials, whole tile at once: (tile, bs) masked min + leftmost.
        xl = xl_acc[...]
        ml = jnp.where((lanes >= ls[:, None]) & (lanes <= le[:, None]), xl, big)
        lv = jnp.min(ml, axis=1)
        li = jnp.min(jnp.where(ml == lv[:, None], lanes, big_i), axis=1)
        lg = bl * bs + li

        # Right partials (masked off for single-block queries).
        xr = xr_acc[...]
        mr = jnp.where(lanes <= re[:, None], xr, big)
        rv = jnp.min(mr, axis=1)
        rv = jnp.where(br > bl, rv, big)
        ri = jnp.min(jnp.where(mr == rv[:, None], lanes, big_i), axis=1)
        rg = br * bs + ri

        take_l = lv <= rv  # left candidate has smaller indices: leftmost ties
        pv = jnp.where(take_l, lv, rv)
        pi = jnp.where(take_l, lg, rg)

        iv = scalar_col(iv_acc, 0, tile)
        ii = scalar_col(ii_acc, 0, tile)

        # Final merge, exact leftmost: prefer the partial only when strictly
        # smaller, or tied with an index left of the interior block range.
        int_start = (bl + 1) * bs
        prefer_partial = (pv < iv) | ((pv == iv) & (pi < int_start))
        val_ref[...] = jnp.where(prefer_partial, pv, iv)[:, None]
        idx_ref[...] = jnp.where(prefer_partial, pi, ii)[:, None]


def interior_tables(bmin_val: jax.Array, bmin_gidx: jax.Array, st_idx: jax.Array):
    """Value-augmented doubling tables for the DMA fetch strategy.

    ``st_val[k, p] = bmin_val[st_idx[k, p]]`` and ``st_gidx`` likewise, so
    the in-kernel interior lookup is two direct cell reads instead of an
    index hop through the resident block-min arrays. Computed once at build
    (XLA gathers are fine here — this is O(K * nb) build work, keeping the
    per-query jaxpr gather-free).
    """
    return bmin_val[st_idx], bmin_gidx[st_idx]


@functools.partial(
    jax.jit, static_argnames=("tile", "fetch", "interpret", "materialize_interior")
)
def fused_query(
    x_blocks: jax.Array,  # (nb, bs)
    bmin_val: jax.Array,  # (nb,)
    bmin_gidx: jax.Array,  # (nb,) int32
    st_idx: jax.Array,  # (K, nb) int32 doubling table over bmin_val
    l: jax.Array,  # (B,)
    r: jax.Array,  # (B,)
    *,
    st_val: jax.Array | None = None,  # (K, nb) value-augmented table (dma)
    st_gidx: jax.Array | None = None,  # (K, nb) int32 gidx-augmented table (dma)
    tile: int = DEFAULT_TILE,
    fetch: str = "auto",
    interpret: bool | None = None,
    materialize_interior: bool | None = None,
):
    """End-to-end fused blocked RMQ. Returns (idx (B,) int32, value (B,)).

    Single kernel dispatch per batch; ``tile`` queries per grid step.
    ``fetch`` selects the table strategy ("resident" | "dma" | "auto", see
    module docstring). A DMA-strategy call that does not pass the augmented
    tables has them derived on the fly — O(K * nb) gathers *per jit trace*
    that build-time callers precompute exactly once via
    :func:`interior_tables`. ``materialize_interior`` makes that choice
    explicit: ``True`` opts into the on-the-fly derivation silently,
    ``False`` forbids it (raises instead of recomputing — for callers whose
    build stage owns the augmented tables and must notice losing them), and
    the default ``None`` derives but warns once per process.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    nb, bs = x_blocks.shape
    b = l.shape[0]
    big = maxval(x_blocks.dtype)
    fetch = resolve_fetch(fetch, nb)
    l = l.astype(jnp.int32)
    r = r.astype(jnp.int32)

    # Host-side (XLA) scalar decomposition — cheap int ops on (B,) vectors.
    bl = l // bs
    br = r // bs
    ls = l - bl * bs
    re = r - br * bs
    le = jnp.where(bl == br, re, bs - 1)

    hasint = ((br - bl) >= 2).astype(jnp.int32)
    ilo = jnp.clip(bl + 1, 0, nb - 1)
    ihi = jnp.maximum(jnp.clip(br - 1, 0, nb - 1), ilo)
    k = exact_log2(ihi - ilo + 1)
    bpos = ihi - jnp.left_shift(jnp.int32(1), k) + 1
    wlo = ilo // _W  # lane-aligned window ids for the dma fetch strategy
    whi = bpos // _W

    # Pad the batch to a whole number of tiles with trivial (0, 0) queries.
    scalars = [bl, br, ls, le, re, k, ilo, bpos, hasint, wlo, whi]
    scalars, bp = pad_to_tiles(scalars, b, tile)

    # Lane-align the per-block tables (last dim multiple of 128 for VMEM).
    # Per-call cost note: when nb is already lane-aligned (every large-n
    # config: nb = n/bs is a multiple of 128) the zero-width pads are elided
    # by XLA; a misaligned nb implies a small nb, so the copy is sub-VREG
    # noise. Keeping the pad here avoids widening the shared BlockRMQ pytree
    # (whose field layout distributed.py's PartitionSpecs mirror).
    nbp = -(-nb // _W) * _W
    grid = (bp // tile, tile)
    xl_spec = tiled2_row_spec((1, bs), 0, tile)  # x_blocks[bl[q]]
    xr_spec = tiled2_row_spec((1, bs), 1, tile)  # x_blocks[br[q]]
    if fetch == "resident":
        bv2 = jnp.pad(bmin_val, (0, nbp - nb), constant_values=big)[None, :]
        bg2 = jnp.pad(bmin_gidx, (0, nbp - nb))[None, :]
        st2 = jnp.pad(st_idx, ((0, 0), (0, nbp - nb)))
        in_specs = [
            xl_spec,
            xr_spec,
            tiled2_row_spec((1, nbp), 5, tile),  # st.idx[k[q], :]
            pl.BlockSpec((1, nbp), lambda i, t, *s: (0, 0)),  # bmin_val (resident)
            pl.BlockSpec((1, nbp), lambda i, t, *s: (0, 0)),  # bmin_gidx (resident)
        ]
        operands = (x_blocks, x_blocks, st2, bv2, bg2)
    else:
        if st_val is None or st_gidx is None:
            if materialize_interior is False:
                raise ValueError(
                    "fetch='dma' without st_val/st_gidx while "
                    "materialize_interior=False: the caller expected "
                    "precomputed augmented tables (interior_tables) but "
                    "the structure does not carry them"
                )
            if materialize_interior is None:
                global _warned_materialize
                if not _warned_materialize:
                    _warned_materialize = True
                    _logger.warning(
                        "fused_query fetch='dma' is deriving its augmented "
                        "interior tables on the fly (O(K*nb) gathers per jit "
                        "trace). Precompute them at build time "
                        "(kernels.ops.build / interior_tables), or pass "
                        "materialize_interior=True to opt in silently."
                    )
            st_val, st_gidx = interior_tables(bmin_val, bmin_gidx, st_idx)
        sv2 = jnp.pad(st_val, ((0, 0), (0, nbp - nb)), constant_values=big)
        sg2 = jnp.pad(st_gidx, ((0, 0), (0, nbp - nb)))
        in_specs = [
            xl_spec,
            xr_spec,
            tiled2_window_spec(_W, 5, 9, tile),  # st_val[k[q], ilo window]
            tiled2_window_spec(_W, 5, 10, tile),  # st_val[k[q], bpos window]
            tiled2_window_spec(_W, 5, 9, tile),  # st_gidx[k[q], ilo window]
            tiled2_window_spec(_W, 5, 10, tile),  # st_gidx[k[q], bpos window]
        ]
        operands = (x_blocks, x_blocks, sv2, sv2, sg2, sg2)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=_N_PREFETCH,
        grid=grid,
        in_specs=in_specs,
        out_specs=tiled2_out_specs(tile),
        scratch_shapes=[
            pltpu.VMEM((tile, bs), x_blocks.dtype),  # xl accumulator
            pltpu.VMEM((tile, bs), x_blocks.dtype),  # xr accumulator
            pltpu.SMEM((tile,), x_blocks.dtype),  # interior values
            pltpu.SMEM((tile,), jnp.int32),  # interior indices
        ],
    )
    val, idx = pl.pallas_call(
        functools.partial(_kernel, tile, fetch),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((bp, 1), x_blocks.dtype),
            jax.ShapeDtypeStruct((bp, 1), jnp.int32),
        ],
        interpret=interpret,
    )(*scalars, *operands)
    return idx[:b, 0], val[:b, 0]


# --- packed megakernel ------------------------------------------------------
#
# The bandwidth-optimal variant (DESIGN.md §13). For the exact packed32
# layout every table the kernel touches is ONE plane of order-isomorphic
# int32 words, so:
#
#   * the partial-block scan is a plain masked word min — no equality
#     rescan to recover the lane, the word IS (value, global index);
#   * the interior candidate is two cells of the packed doubling table
#     ``stw`` — the dma strategy fetches TWO (1, 128) windows per query
#     where the unpacked kernel fetches FOUR (value + gidx at each
#     position), and the resident strategy DMAs one (1, nb) ``stw`` row
#     with NO resident planes at all (the unpacked kernel additionally
#     parks ``bmin_val`` + ``bmin_gidx`` in VMEM);
#   * the final merge is ``min`` of three words — the leftmost-tie
#     select chain is subsumed by word order, and the kernel emits one
#     packed word per query that the host unpacks.
#
# The quantized layout keeps raw value blocks (partials need exact values)
# and fetches interior candidates from the int32 ``stw`` of
# (bucket, exact-argmin) words; bucket ties fall back to exact values via
# the resident ``bmin_val`` plane — the argmin of an interior window is the
# minimum of its own (fully covered) block, so ``bmin_val[idx // bs]`` IS
# its exact value. That fallback hop is why quantized has no dma strategy.
#
# packed64 words are int64 — outside the TPU kernel vocabulary — so that
# layout serves through the XLA packed engines, never this kernel.


def _kernel_packed(tile, fetch, idx_bits, pad, *refs):
    """Exact-layout (packed32) kernel body: everything is int32 words."""
    (bl_ref, br_ref, ls_ref, le_ref, re_ref,
     k_ref, ilo_ref, bpos_ref, hasint_ref, wlo_ref, whi_ref) = refs[:_N_PREFETCH]
    body = refs[_N_PREFETCH:]
    xl_ref, xr_ref = body[0], body[1]
    if fetch == "resident":
        stw_ref = body[2]
        word_ref = body[3]
        xl_acc, xr_acc, iw_acc = body[4:7]
    else:
        lo_ref, hi_ref = body[2], body[3]
        word_ref = body[4]
        xl_acc, xr_acc, iw_acc = body[5:8]

    i = pl.program_id(0)
    t = pl.program_id(1)
    q = i * tile + t
    bs = xl_ref.shape[1]

    xl_acc[pl.ds(t, 1)] = xl_ref[...]
    xr_acc[pl.ds(t, 1)] = xr_ref[...]

    if fetch == "resident":
        wa = stw_ref[0, ilo_ref[q]]
        wb = stw_ref[0, bpos_ref[q]]
    else:
        wa = lo_ref[0, ilo_ref[q] - wlo_ref[q] * _W]
        wb = hi_ref[0, bpos_ref[q] - whi_ref[q] * _W]
    iw_acc[t] = jnp.where(hasint_ref[q] == 1, jnp.minimum(wa, wb), pad)

    @pl.when(t == tile - 1)
    def _merge():
        lanes = jax.lax.broadcasted_iota(jnp.int32, (tile, bs), 1)
        q0 = i * tile

        def col(ref):
            return scalar_col(ref, q0, tile)

        bl, br, ls, le, re = col(bl_ref), col(br_ref), col(ls_ref), col(le_ref), col(re_ref)

        # Partials: one masked word min per side; the min word IS the
        # leftmost argmin (pad words strictly dominate real ones).
        lw = jnp.min(
            jnp.where((lanes >= ls[:, None]) & (lanes <= le[:, None]), xl_acc[...], pad),
            axis=1,
        )
        rw = jnp.min(jnp.where(lanes <= re[:, None], xr_acc[...], pad), axis=1)
        rw = jnp.where(br > bl, rw, pad)

        # Scratch is (tile,)-indexed from 0, unlike the (B,) prefetch refs
        # ``col`` reads at q0 + t.
        iw = scalar_col(iw_acc, 0, tile)
        word_ref[...] = jnp.minimum(jnp.minimum(lw, rw), iw)[:, None]


def _kernel_quantized(tile, idx_bits, *refs):
    """Quantized kernel body: raw-value partials + bucket-word interior with
    the exact fallback hop through the resident ``bmin_val`` plane."""
    (bl_ref, br_ref, ls_ref, le_ref, re_ref,
     k_ref, ilo_ref, bpos_ref, hasint_ref, wlo_ref, whi_ref) = refs[:_N_PREFETCH]
    body = refs[_N_PREFETCH:]
    xl_ref, xr_ref, stw_ref, bv_ref = body[0:4]
    val_ref, idx_ref = body[4:6]
    xl_acc, xr_acc, iv_acc, ii_acc = body[6:10]

    i = pl.program_id(0)
    t = pl.program_id(1)
    q = i * tile + t
    bs = xl_ref.shape[1]
    big = maxval(xl_ref.dtype)
    mask = (1 << idx_bits) - 1

    xl_acc[pl.ds(t, 1)] = xl_ref[...]
    xr_acc[pl.ds(t, 1)] = xr_ref[...]

    wa = stw_ref[0, ilo_ref[q]]
    wb = stw_ref[0, bpos_ref[q]]
    ai = wa & mask
    bi = wb & mask
    # Exact values via the block hop: an interior cell's argmin is the min
    # of its own fully-covered block, so its exact value is that block's.
    ava = bv_ref[0, ai // bs]
    avb = bv_ref[0, bi // bs]
    collide = (wa >> idx_bits) == (wb >> idx_bits)
    take_a = jnp.where(collide, ava <= avb, wa <= wb)
    iv_acc[t] = jnp.where(hasint_ref[q] == 1, jnp.where(take_a, ava, avb), big)
    ii_acc[t] = jnp.where(take_a, ai, bi)

    @pl.when(t == tile - 1)
    def _merge():
        big_i = jnp.int32(bs)
        lanes = jax.lax.broadcasted_iota(jnp.int32, (tile, bs), 1)
        q0 = i * tile

        def col(ref):
            return scalar_col(ref, q0, tile)

        bl, br, ls, le, re = col(bl_ref), col(br_ref), col(ls_ref), col(le_ref), col(re_ref)

        xl = xl_acc[...]
        ml = jnp.where((lanes >= ls[:, None]) & (lanes <= le[:, None]), xl, big)
        lv = jnp.min(ml, axis=1)
        li = jnp.min(jnp.where(ml == lv[:, None], lanes, big_i), axis=1)
        lg = bl * bs + li

        xr = xr_acc[...]
        mr = jnp.where(lanes <= re[:, None], xr, big)
        rv = jnp.min(mr, axis=1)
        rv = jnp.where(br > bl, rv, big)
        ri = jnp.min(jnp.where(mr == rv[:, None], lanes, big_i), axis=1)
        rg = br * bs + ri

        take_l = lv <= rv
        pv = jnp.where(take_l, lv, rv)
        pi = jnp.where(take_l, lg, rg)

        iv = scalar_col(iv_acc, 0, tile)
        ii = scalar_col(ii_acc, 0, tile)

        int_start = (bl + 1) * bs
        prefer_partial = (pv < iv) | ((pv == iv) & (pi < int_start))
        val_ref[...] = jnp.where(prefer_partial, pv, iv)[:, None]
        idx_ref[...] = jnp.where(prefer_partial, pi, ii)[:, None]


@functools.partial(
    jax.jit, static_argnames=("spec", "tile", "fetch", "interpret")
)
def fused_query_packed(
    blocks: jax.Array,  # (nb, bs): packed words (packed32) | raw values (quantized)
    stw: jax.Array,  # (K, nb) int32 packed doubling table over block minima
    l: jax.Array,  # (B,)
    r: jax.Array,  # (B,)
    *,
    spec,  # packing.PackSpec (static: hashable NamedTuple of primitives)
    bmin_val: jax.Array | None = None,  # (nb,) exact minima (quantized only)
    tile: int = DEFAULT_TILE,
    fetch: str = "auto",
    interpret: bool | None = None,
):
    """Packed fused blocked RMQ. Returns (idx (B,) int32, value (B,)).

    One kernel dispatch per batch over single-plane packed structures (see
    the section comment above for the per-layout fetch volumes). Layouts:
    packed32 (exact; both fetch strategies) and quantized (resident only).
    packed64 raises — int64 words have no TPU kernel path.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if spec.layout == "packed64":
        raise ValueError(
            "packed64 words are int64 and have no TPU kernel path; "
            "serve packed64 through the XLA packed engines"
        )
    if spec.layout not in ("packed32", "quantized"):
        raise ValueError(f"fused_query_packed wants packed32|quantized, got {spec.layout!r}")
    nb, bs = blocks.shape
    b = l.shape[0]
    fetch = resolve_fetch(fetch, nb)
    if spec.layout == "quantized":
        if bmin_val is None:
            raise ValueError("quantized fused_query_packed needs the bmin_val plane")
        fetch = "resident"  # the exact-fallback hop lives in the resident plane
    pad = packing.pad_word(spec)
    l = l.astype(jnp.int32)
    r = r.astype(jnp.int32)

    bl = l // bs
    br = r // bs
    ls = l - bl * bs
    re = r - br * bs
    le = jnp.where(bl == br, re, bs - 1)

    hasint = ((br - bl) >= 2).astype(jnp.int32)
    ilo = jnp.clip(bl + 1, 0, nb - 1)
    ihi = jnp.maximum(jnp.clip(br - 1, 0, nb - 1), ilo)
    k = exact_log2(ihi - ilo + 1)
    bpos = ihi - jnp.left_shift(jnp.int32(1), k) + 1
    wlo = ilo // _W
    whi = bpos // _W

    scalars = [bl, br, ls, le, re, k, ilo, bpos, hasint, wlo, whi]
    scalars, bp = pad_to_tiles(scalars, b, tile)

    nbp = -(-nb // _W) * _W
    grid = (bp // tile, tile)
    xl_spec = tiled2_row_spec((1, bs), 0, tile)
    xr_spec = tiled2_row_spec((1, bs), 1, tile)
    stw2 = jnp.pad(stw, ((0, 0), (0, nbp - nb)), constant_values=pad)

    if spec.layout == "quantized":
        bv2 = jnp.pad(bmin_val, (0, nbp - nb), constant_values=maxval(blocks.dtype))
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=_N_PREFETCH,
            grid=grid,
            in_specs=[
                xl_spec,
                xr_spec,
                tiled2_row_spec((1, nbp), 5, tile),  # stw[k[q], :]
                pl.BlockSpec((1, nbp), lambda i, t, *s: (0, 0)),  # bmin_val
            ],
            out_specs=tiled2_out_specs(tile),
            scratch_shapes=[
                pltpu.VMEM((tile, bs), blocks.dtype),
                pltpu.VMEM((tile, bs), blocks.dtype),
                pltpu.SMEM((tile,), blocks.dtype),
                pltpu.SMEM((tile,), jnp.int32),
            ],
        )
        val, idx = pl.pallas_call(
            functools.partial(_kernel_quantized, tile, spec.idx_bits),
            grid_spec=grid_spec,
            out_shape=[
                jax.ShapeDtypeStruct((bp, 1), blocks.dtype),
                jax.ShapeDtypeStruct((bp, 1), jnp.int32),
            ],
            interpret=interpret,
        )(*scalars, blocks, blocks, stw2, bv2[None, :])
        return idx[:b, 0], val[:b, 0]

    if fetch == "resident":
        in_specs = [
            xl_spec,
            xr_spec,
            tiled2_row_spec((1, nbp), 5, tile),  # stw[k[q], :] — sole table fetch
        ]
        operands = (blocks, blocks, stw2)
    else:
        in_specs = [
            xl_spec,
            xr_spec,
            tiled2_window_spec(_W, 5, 9, tile),  # stw[k[q], ilo window]
            tiled2_window_spec(_W, 5, 10, tile),  # stw[k[q], bpos window]
        ]
        operands = (blocks, blocks, stw2, stw2)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=_N_PREFETCH,
        grid=grid,
        in_specs=in_specs,
        out_specs=[pl.BlockSpec((tile, 1), lambda i, t, *s: (i, 0))],
        scratch_shapes=[
            pltpu.VMEM((tile, bs), jnp.int32),  # xl word accumulator
            pltpu.VMEM((tile, bs), jnp.int32),  # xr word accumulator
            pltpu.SMEM((tile,), jnp.int32),  # interior words
        ],
    )
    (word,) = pl.pallas_call(
        functools.partial(_kernel_packed, tile, fetch, spec.idx_bits, pad),
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((bp, 1), jnp.int32)],
        interpret=interpret,
    )(*scalars, *operands)
    w = word[:b, 0]
    return packing.unpack_idx(spec, w), packing.unpack_val(spec, w)
