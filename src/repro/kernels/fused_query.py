"""Pallas TPU megakernel: tiled, fully-fused blocked-RMQ query.

One ``pallas_call`` answers a query batch end-to-end — left partial, right
partial, *and* the O(1) sparse-table interior candidate — emitting the final
``(idx, val)``. This collapses the previous three dispatches (partials
kernel, XLA sparse-table gathers, XLA merge) into a single kernel launch.

Tiling: the grid is ``(B // tile,)`` and each grid step answers ``tile``
queries at once. Per query the step pulls three data-dependent rows via
scalar-prefetch index maps (the same "program the DMA with the block id"
trick as ``rmq_query.py``):

  * ``x_blocks[bl[q]]``       — left partial block,
  * ``x_blocks[br[q]]``       — right partial block,
  * ``st.idx[k[q], :]``       — the doubling-table level row, where
    ``k = floor(log2(interior_len))`` is precomputed on the host side of the
    dispatch; both interior gathers (``ilo`` and ``ihi - 2^k + 1``) read from
    this one row, so the whole sparse-table query costs one row DMA plus four
    scalar VMEM loads.

The partial scans run vectorized on ``(tile, bs)`` VMEM tiles (one VPU masked
min per side for the whole tile) instead of ``(1, bs)`` rows, amortizing both
DMA issue and grid overhead. The per-block min arrays (``bmin_val`` /
``bmin_gidx``) ride along as constant whole-array VMEM residents — they are
DMA'd once, not per step.

Correctness: the merge keeps the exact leftmost-tie rule of
``kernels/ops.py`` — partial candidates are merged left-over-right
(``lv <= rv``), then preferred over the interior only when strictly smaller
or when the partial index lies left of the interior's block range
(``pi < (bl + 1) * bs``). See DESIGN.md §4.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.block_rmq import maxval
from repro.core.sparse_table import exact_log2

from .tiling import pad_to_tiles, row_spec, scalar_col, tile_out_specs
from .tuning import DEFAULT_TILE

__all__ = ["fused_query", "DEFAULT_TILE"]


# Scalar-prefetch operand order (SMEM, available to index maps + kernel).
_N_PREFETCH = 9  # bl, br, ls, le, re, k, ilo, bpos, hasint


def _kernel(tile, *refs):
    (bl_ref, br_ref, ls_ref, le_ref, re_ref,
     k_ref, ilo_ref, bpos_ref, hasint_ref) = refs[:_N_PREFETCH]
    body = refs[_N_PREFETCH:]
    xl_refs = body[0:tile]
    xr_refs = body[tile : 2 * tile]
    st_refs = body[2 * tile : 3 * tile]
    bv_ref, bg_ref = body[3 * tile], body[3 * tile + 1]
    val_ref, idx_ref = body[3 * tile + 2], body[3 * tile + 3]

    i = pl.program_id(0)
    q0 = i * tile
    bs = xl_refs[0].shape[1]
    big = maxval(xl_refs[0].dtype)
    big_i = jnp.int32(bs)
    lanes = jax.lax.broadcasted_iota(jnp.int32, (tile, bs), 1)

    def col(ref):  # (tile,) vector of per-query scalars from SMEM
        return scalar_col(ref, q0, tile)

    bl, br, ls, le, re = col(bl_ref), col(br_ref), col(ls_ref), col(le_ref), col(re_ref)

    # Left partials, whole tile at once: (tile, bs) masked min + leftmost idx.
    xl = jnp.concatenate([r[...] for r in xl_refs], axis=0)
    ml = jnp.where((lanes >= ls[:, None]) & (lanes <= le[:, None]), xl, big)
    lv = jnp.min(ml, axis=1)
    li = jnp.min(jnp.where(ml == lv[:, None], lanes, big_i), axis=1)
    lg = bl * bs + li

    # Right partials (masked off for single-block queries).
    xr = jnp.concatenate([r[...] for r in xr_refs], axis=0)
    mr = jnp.where(lanes <= re[:, None], xr, big)
    rv = jnp.min(mr, axis=1)
    rv = jnp.where(br > bl, rv, big)
    ri = jnp.min(jnp.where(mr == rv[:, None], lanes, big_i), axis=1)
    rg = br * bs + ri

    take_l = lv <= rv  # left candidate has smaller indices: leftmost ties
    pv = jnp.where(take_l, lv, rv)
    pi = jnp.where(take_l, lg, rg)

    # Interior sparse-table candidate: two scalar gathers from the prefetched
    # level-k row, leftmost-tie pick via the block-min values.
    ivs, iis = [], []
    for t in range(tile):
        a = st_refs[t][0, ilo_ref[q0 + t]]
        b = st_refs[t][0, bpos_ref[q0 + t]]
        av = bv_ref[0, a]
        bv = bv_ref[0, b]
        bi = jnp.where(av <= bv, a, b)
        ivs.append(jnp.where(hasint_ref[q0 + t] == 1, jnp.minimum(av, bv), big))
        iis.append(bg_ref[0, bi])
    iv = jnp.stack(ivs)
    ii = jnp.stack(iis)

    # Final merge, exact leftmost: prefer the partial only when strictly
    # smaller, or tied with an index left of the interior block range.
    int_start = (bl + 1) * bs
    prefer_partial = (pv < iv) | ((pv == iv) & (pi < int_start))
    val_ref[...] = jnp.where(prefer_partial, pv, iv)[:, None]
    idx_ref[...] = jnp.where(prefer_partial, pi, ii)[:, None]


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def fused_query(
    x_blocks: jax.Array,  # (nb, bs)
    bmin_val: jax.Array,  # (nb,)
    bmin_gidx: jax.Array,  # (nb,) int32
    st_idx: jax.Array,  # (K, nb) int32 doubling table over bmin_val
    l: jax.Array,  # (B,)
    r: jax.Array,  # (B,)
    *,
    tile: int = DEFAULT_TILE,
    interpret: bool | None = None,
):
    """End-to-end fused blocked RMQ. Returns (idx (B,) int32, value (B,)).

    Single kernel dispatch per batch; ``tile`` queries per grid step.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    nb, bs = x_blocks.shape
    b = l.shape[0]
    big = maxval(x_blocks.dtype)
    l = l.astype(jnp.int32)
    r = r.astype(jnp.int32)

    # Host-side (XLA) scalar decomposition — cheap int ops on (B,) vectors.
    bl = l // bs
    br = r // bs
    ls = l - bl * bs
    re = r - br * bs
    le = jnp.where(bl == br, re, bs - 1)

    hasint = ((br - bl) >= 2).astype(jnp.int32)
    ilo = jnp.clip(bl + 1, 0, nb - 1)
    ihi = jnp.maximum(jnp.clip(br - 1, 0, nb - 1), ilo)
    k = exact_log2(ihi - ilo + 1)
    bpos = ihi - jnp.left_shift(jnp.int32(1), k) + 1

    # Pad the batch to a whole number of tiles with trivial (0, 0) queries.
    scalars = [bl, br, ls, le, re, k, ilo, bpos, hasint]
    scalars, bp = pad_to_tiles(scalars, b, tile)

    # Lane-align the per-block tables (last dim multiple of 128 for VMEM).
    # Per-call cost note: when nb is already lane-aligned (every large-n
    # config: nb = n/bs is a multiple of 128) the zero-width pads are elided
    # by XLA; a misaligned nb implies a small nb, so the copy is sub-VREG
    # noise. Keeping the pad here avoids widening the shared BlockRMQ pytree
    # (whose field layout distributed.py's PartitionSpecs mirror).
    nbp = -(-nb // 128) * 128
    bv2 = jnp.pad(bmin_val, (0, nbp - nb), constant_values=big)[None, :]
    bg2 = jnp.pad(bmin_gidx, (0, nbp - nb))[None, :]
    st2 = jnp.pad(st_idx, ((0, 0), (0, nbp - nb)))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=_N_PREFETCH,
        grid=(bp // tile,),
        in_specs=(
            # data-dependent rows: x_blocks[bl[q]], x_blocks[br[q]], and the
            # doubling-table level row st.idx[k[q], :] (k is prefetch slot 5)
            [row_spec((1, bs), 0, t, tile) for t in range(tile)]
            + [row_spec((1, bs), 1, t, tile) for t in range(tile)]
            + [row_spec((1, nbp), 5, t, tile) for t in range(tile)]
            + [
                pl.BlockSpec((1, nbp), lambda i, *s: (0, 0)),  # bmin_val (resident)
                pl.BlockSpec((1, nbp), lambda i, *s: (0, 0)),  # bmin_gidx (resident)
            ]
        ),
        out_specs=tile_out_specs(tile),
    )
    val, idx = pl.pallas_call(
        functools.partial(_kernel, tile),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((bp, 1), x_blocks.dtype),
            jax.ShapeDtypeStruct((bp, 1), jnp.int32),
        ],
        interpret=interpret,
    )(
        *scalars,
        *([x_blocks] * tile),
        *([x_blocks] * tile),
        *([st2] * tile),
        bv2,
        bg2,
    )
    return idx[:b, 0], val[:b, 0]
