"""Pallas TPU kernel: fused partial-block RMQ scans (query phase, level 1).

The RT-core analogue: ``tile`` queries per grid step ("a warp of rays"), with
each query's two candidate blocks streamed HBM->VMEM by the pipeline. Scalar
prefetch (SMEM) carries per-query block ids so the BlockSpec index_map can
select *data-dependent* blocks — the TPU-idiomatic replacement for the BVH
descent picking which leaf a ray visits: instead of a pointer walk, the DMA
engine is programmed with the block id while the previous tile computes.

Both partial scans (left tail, right head) are fused into one kernel, and the
grid is tiled ``(B // tile,)``: each step concatenates its ``tile`` left rows
and ``tile`` right rows into ``(tile, bs)`` VMEM tiles so the VPU does two
masked mins for the whole tile instead of per query, amortizing DMA issue and
grid overhead. ``tile=1`` reproduces the original one-ray-per-step layout.

For the fully fused path (interior sparse-table candidate + final merge in
the same dispatch) see ``fused_query.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.block_rmq import maxval

from .tiling import pad_to_tiles, row_spec, scalar_col, tile_out_specs
from .tuning import DEFAULT_TILE

__all__ = ["rmq_partials", "DEFAULT_TILE"]



def _kernel(tile, bl_ref, br_ref, ls_ref, le_ref, re_ref, *refs):
    xl_refs = refs[0:tile]
    xr_refs = refs[tile : 2 * tile]
    val_ref, idx_ref = refs[2 * tile], refs[2 * tile + 1]

    i = pl.program_id(0)
    q0 = i * tile
    bs = xl_refs[0].shape[1]
    big = maxval(xl_refs[0].dtype)
    big_i = jnp.int32(bs)
    lanes = jax.lax.broadcasted_iota(jnp.int32, (tile, bs), 1)

    def col(ref):
        return scalar_col(ref, q0, tile)

    bl, br, ls, le, re = col(bl_ref), col(br_ref), col(ls_ref), col(le_ref), col(re_ref)

    # Left partials: x[bl, ls:le+1] (non-empty by construction), whole tile.
    xl = jnp.concatenate([r[...] for r in xl_refs], axis=0)
    ml = jnp.where((lanes >= ls[:, None]) & (lanes <= le[:, None]), xl, big)
    lv = jnp.min(ml, axis=1)
    li = jnp.min(jnp.where(ml == lv[:, None], lanes, big_i), axis=1)
    lg = bl * bs + li

    # Right partials: x[br, 0:re+1], masked off for single-block queries.
    xr = jnp.concatenate([r[...] for r in xr_refs], axis=0)
    mr = jnp.where(lanes <= re[:, None], xr, big)
    rv = jnp.min(mr, axis=1)
    rv = jnp.where(br > bl, rv, big)
    ri = jnp.min(jnp.where(mr == rv[:, None], lanes, big_i), axis=1)
    rg = br * bs + ri

    take_l = lv <= rv  # left candidate has smaller indices: leftmost ties
    val_ref[...] = jnp.where(take_l, lv, rv)[:, None]
    idx_ref[...] = jnp.where(take_l, lg, rg)[:, None]


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def rmq_partials(
    x_blocks: jax.Array,
    bl: jax.Array,
    br: jax.Array,
    lstart: jax.Array,
    lend: jax.Array,
    rend: jax.Array,
    *,
    tile: int = DEFAULT_TILE,
    interpret: bool | None = None,
):
    """Fused partial-block candidates. Returns (value (B,), global idx (B,))."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b = bl.shape[0]
    _, bs = x_blocks.shape
    args = [a.astype(jnp.int32) for a in (bl, br, lstart, lend, rend)]

    # Pad the batch to a whole number of tiles with trivial block-0 queries.
    args, bp = pad_to_tiles(args, b, tile)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=5,
        grid=(bp // tile,),
        in_specs=(
            # data-dependent rows: x_blocks[bl[q]] then x_blocks[br[q]]
            [row_spec((1, bs), 0, t, tile) for t in range(tile)]
            + [row_spec((1, bs), 1, t, tile) for t in range(tile)]
        ),
        out_specs=tile_out_specs(tile),
    )
    val, idx = pl.pallas_call(
        functools.partial(_kernel, tile),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((bp, 1), x_blocks.dtype),
            jax.ShapeDtypeStruct((bp, 1), jnp.int32),
        ],
        interpret=interpret,
    )(*args, *([x_blocks] * (2 * tile)))
    return val[:b, 0], idx[:b, 0]
