"""Pallas TPU kernel: fused partial-block RMQ scans (query phase, level 1).

The RT-core analogue: one grid step per query ("one ray per query"), with the
query's two candidate blocks streamed HBM->VMEM by the pipeline. Scalar
prefetch (SMEM) carries per-query block ids so the BlockSpec index_map can
select *data-dependent* blocks — the TPU-idiomatic replacement for the BVH
descent picking which leaf a ray visits: instead of a pointer walk, the DMA
engine is programmed with the block id while the previous query computes.

Both partial scans (left tail, right head) are fused into one kernel so each
query costs exactly two VMEM block loads and two masked vector mins.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.block_rmq import maxval

__all__ = ["rmq_partials"]


def _kernel(bl_ref, br_ref, ls_ref, le_ref, re_ref, xl_ref, xr_ref, val_ref, idx_ref):
    i = pl.program_id(0)
    bs = xl_ref.shape[1]
    big = maxval(xl_ref.dtype)
    big_i = jnp.int32(bs)
    lanes = jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)

    bl = bl_ref[i]
    br = br_ref[i]

    # Left partial: x[bl, ls:le+1] (non-empty by construction).
    xl = xl_ref[...]
    ml = jnp.where((lanes >= ls_ref[i]) & (lanes <= le_ref[i]), xl, big)
    lv = jnp.min(ml)
    li = jnp.min(jnp.where(ml == lv, lanes, big_i))
    lg = bl * bs + li

    # Right partial: x[br, 0:re+1], masked off for single-block queries.
    xr = xr_ref[...]
    mr = jnp.where(lanes <= re_ref[i], xr, big)
    rv = jnp.min(mr)
    rv = jnp.where(br > bl, rv, big)
    ri = jnp.min(jnp.where(mr == rv, lanes, big_i))
    rg = br * bs + ri

    take_l = lv <= rv  # left candidate has smaller indices: leftmost ties
    val_ref[0, 0] = jnp.where(take_l, lv, rv)
    idx_ref[0, 0] = jnp.where(take_l, lg, rg)


@functools.partial(jax.jit, static_argnames=("interpret",))
def rmq_partials(
    x_blocks: jax.Array,
    bl: jax.Array,
    br: jax.Array,
    lstart: jax.Array,
    lend: jax.Array,
    rend: jax.Array,
    *,
    interpret: bool | None = None,
):
    """Fused partial-block candidates. Returns (value (B,), global idx (B,))."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b = bl.shape[0]
    _, bs = x_blocks.shape
    args = [a.astype(jnp.int32) for a in (bl, br, lstart, lend, rend)]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=5,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, bs), lambda i, bl, br, ls, le, re: (bl[i], 0)),
            pl.BlockSpec((1, bs), lambda i, bl, br, ls, le, re: (br[i], 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1), lambda i, *_: (i, 0)),
            pl.BlockSpec((1, 1), lambda i, *_: (i, 0)),
        ],
    )
    val, idx = pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b, 1), x_blocks.dtype),
            jax.ShapeDtypeStruct((b, 1), jnp.int32),
        ],
        interpret=interpret,
    )(*args, x_blocks, x_blocks)
    return val[:, 0], idx[:, 0]
