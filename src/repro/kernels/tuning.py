"""Shared kernel tunables (single source of truth for the query kernels).

``DEFAULT_TILE``: queries answered per grid step by the tiled query kernels
(``rmq_query``, ``lane_query``, ``fused_query``). 8 packs a full sublane and
was validated in interpret mode; ROADMAP carries the item to autotune it per
(block_size, batch) on real TPU hardware.
"""

DEFAULT_TILE = 8
