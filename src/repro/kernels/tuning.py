"""Persistent autotuner for the fused megakernel's launch geometry.

The megakernel has three static knobs — ``tile`` (queries per grid step),
``fetch`` (table strategy: VMEM-resident vs per-query DMA windows, see
``fused_query.py``), and ``block_size`` — and the right setting is a property
of (problem size, batch, machine), not of the code. This module sweeps the
config product, times each candidate with the same measurement seam
``hybrid.calibrate`` uses (``hybrid._measure``, monkeypatchable in tests),
and persists winners in the calibration JSON cache (``core.calib_cache``)
under a ``kernel/`` key namespace:

    kernel/n=65536/batch=4096/backend=tpu/ndev=8
        -> {"tile": 8, "fetch": "dma", "block_size": 128}

so serving and benchmarks load tuned configs with zero re-timing. Policy
resolution (``get_config``):

* ``None``      — the deterministic default config. Never touches the cache
  or any machine state: same answer on every host, before and after any
  cache write.
* ``"cached"``  — read-only cache lookup, default fallback on miss. Never
  measures.
* ``"tuned"``   — cache lookup; sweeps + persists on a miss, so repeated
  builds of one configuration time the product exactly once per machine.

The exemplar is the TVM/AttentionEngine autotuner shape (config product ->
timed best -> cached); the cache lifecycle (atomic writes, version staleness,
corrupt-file tolerance) is inherited from ``calib_cache``.
"""

from __future__ import annotations

import itertools
from typing import NamedTuple

__all__ = [
    "DEFAULT_TILE",
    "DEFAULT_TUNE_BATCH",
    "FETCH_STRATEGIES",
    "KernelConfig",
    "RESIDENT_NB_CEILING",
    "autotune",
    "candidate_configs",
    "config_from_entry",
    "default_config",
    "get_config",
    "resolve_fetch",
    "sweep",
    "tuning_key",
]

# Queries answered per grid step by the tiled query kernels. 8 packs a full
# sublane; the autotuner below replaces this guess per (n, batch, machine).
DEFAULT_TILE = 8

# Table fetch strategies fused_query implements (module docstring there).
FETCH_STRATEGIES = ("resident", "dma")

# Above this many blocks the resident strategy's per-step (1, nb) doubling
# row DMA (plus the resident bmin planes) stops fitting the VMEM budget;
# "auto" switches to the bounded-VMEM dma strategy. See DESIGN.md §12.
RESIDENT_NB_CEILING = 1 << 13

# Swept values. Small on purpose: each candidate costs a build + timed
# queries, and the product is per (n, batch, backend, ndev) cache entry.
TUNE_TILES = (4, 8, 16)
TUNE_BLOCK_SIZES = (128, 256)
DEFAULT_TUNE_BATCH = 4096

# The packed-structure layout axis (DESIGN.md §13). ``candidate_configs``
# sweeps only "unpacked" unless the caller opts the axis in (pass
# ``layouts=TUNE_LAYOUTS`` or an explicit subset) — the packed kernels carry
# their own feasibility rules (packed64 words are int64, outside the TPU
# kernel vocabulary; packed32 needs the data's key range to fit; the
# quantized fallback hop needs its resident plane, so no dma strategy) and
# ``sweep`` skips candidates the sweep data cannot express.
TUNE_LAYOUTS = ("unpacked", "packed32", "quantized", "packed64")


class KernelConfig(NamedTuple):
    """Static launch geometry for the fused megakernel.

    ``layout`` (config v3) names the packed-structure layout the geometry
    was tuned for — "unpacked" is the historical default, so pre-layout
    configs (and positional 3-tuples) keep constructing unchanged.
    """

    tile: int = DEFAULT_TILE
    fetch: str = "auto"  # "resident" | "dma" | "auto" (resolve by nb)
    block_size: int = 128
    layout: str = "unpacked"  # "unpacked" | "packed32" | "quantized" | "packed64"


def resolve_fetch(fetch: str, nb: int) -> str:
    """Concrete fetch strategy for ``nb`` blocks ("auto" -> by the ceiling)."""
    if fetch == "auto":
        return "dma" if nb > RESIDENT_NB_CEILING else "resident"
    if fetch not in FETCH_STRATEGIES:
        raise ValueError(f"unknown fetch strategy {fetch!r} (want {FETCH_STRATEGIES})")
    return fetch


def default_config(block_size: int = 128) -> KernelConfig:
    """The untuned config: machine-independent, deterministic."""
    return KernelConfig(tile=DEFAULT_TILE, fetch="auto", block_size=block_size)


def candidate_configs(n: int, block_size: int | None = None, *, layouts=None):
    """The swept config product for an ``n``-element array.

    ``block_size`` pins that knob (hybrid builds tune within their block
    size; fused builds sweep it). Resident candidates past the nb ceiling
    are excluded — they are exactly the configs the ceiling exists to avoid.
    The default config's resolution is always a member, so the tuned winner
    can never be slower than the default on the sweep's own measurements.

    ``layouts`` opts the packed-structure axis in (e.g. ``TUNE_LAYOUTS``);
    the default sweeps only "unpacked". Statically-infeasible members are
    excluded here: packed64 words are int64 (outside the TPU kernel
    vocabulary — packed64 serves through the XLA packed engines instead)
    and the quantized fallback hop keeps a resident plane, so it has no
    bounded-VMEM dma strategy. packed32's *data*-dependent feasibility
    (does the key range fit?) is settled by ``sweep`` per array.
    """
    sizes = (block_size,) if block_size is not None else TUNE_BLOCK_SIZES
    if layouts is None:
        layouts = ("unpacked",)
    out = []
    for bs, fetch, tile, lay in itertools.product(
        sizes, FETCH_STRATEGIES, TUNE_TILES, layouts
    ):
        if fetch == "resident" and -(-n // bs) > RESIDENT_NB_CEILING:
            continue
        if lay == "packed64":
            continue  # int64 words: no kernel path
        if lay == "quantized" and fetch == "dma":
            continue  # fallback hop needs the resident exact-minima plane
        out.append(KernelConfig(tile=tile, fetch=fetch, block_size=bs, layout=lay))
    for bs in sizes:  # the resolved default, if the product missed it
        d = KernelConfig(DEFAULT_TILE, resolve_fetch("auto", -(-n // bs)), bs)
        if d not in out:
            out.append(d)
    return out


def tuning_key(
    n: int,
    batch: int = DEFAULT_TUNE_BATCH,
    *,
    backend: str | None = None,
    n_devices: int | None = None,
    layout: str | None = None,
) -> str:
    """Cache key for a tuned config: ``kernel/`` namespace + (n, batch,
    backend, ndev) — disjoint from the threshold keys in the same file.

    ``layout`` (key v3) scopes a tuning slot to one packed layout; the
    default appends nothing, so migrated v2 entries keep matching. A sweep
    run *across* layouts stores under the default slot — the winning
    config's own ``layout`` field records what won.
    """
    import jax

    if backend is None:
        backend = jax.default_backend()
    if n_devices is None:
        n_devices = len(jax.devices())
    key = f"kernel/n={n}/batch={batch}/backend={backend}/ndev={n_devices}"
    if layout is not None and layout != "unpacked":
        key += f"/layout={layout}"
    return key


def config_from_entry(entry) -> KernelConfig | None:
    """KernelConfig from a cached JSON entry; None if malformed (treated as
    a miss — a cache must never turn into a crash)."""
    if not isinstance(entry, dict):
        return None
    try:
        cfg = KernelConfig(
            tile=int(entry["tile"]),
            fetch=str(entry["fetch"]),
            block_size=int(entry["block_size"]),
            # Pre-layout entries (and migrated v2 files) mean unpacked.
            layout=str(entry.get("layout", "unpacked")),
        )
    except (KeyError, TypeError, ValueError):
        return None
    if cfg.fetch not in FETCH_STRATEGIES + ("auto",):
        return None
    if cfg.tile < 1 or cfg.block_size % 128 != 0:
        return None
    if cfg.layout not in TUNE_LAYOUTS:
        return None
    return cfg


def sweep(
    n: int,
    batch: int = DEFAULT_TUNE_BATCH,
    *,
    block_size: int | None = None,
    candidates=None,
    seed: int = 0,
    repeats: int = 3,
    interpret: bool | None = None,
):
    """Time every candidate config. Returns ``[(KernelConfig, seconds)]``.

    One mixed-length query batch (seeded, so the sweep is reproducible) is
    timed through the fused megakernel per candidate, via the exact
    measurement seam ``hybrid.calibrate`` uses (``hybrid._measure`` — tests
    monkeypatch it to make sweeps deterministic and to assert a warm cache
    performs zero of them). Builds are shared across the candidates of a
    (block size, layout). Packed candidates the sweep data cannot encode
    (a packed32 key range that does not fit) are skipped, not errored —
    the winner must come from configs this machine can actually run.
    """
    import jax.numpy as jnp
    import numpy as np

    from repro.core import hybrid

    from . import ops

    if candidates is None:
        candidates = candidate_configs(n, block_size)
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.random(n, dtype=np.float32))
    a = rng.integers(0, n, batch)
    b = rng.integers(0, n, batch)
    lj = jnp.asarray(np.minimum(a, b))
    rj = jnp.asarray(np.maximum(a, b))

    results = []
    built = {}
    for cfg in candidates:
        bkey = (cfg.block_size, cfg.layout)
        if bkey not in built:
            if cfg.layout == "unpacked":
                built[bkey] = (
                    ops.build(x, cfg.block_size, interpret=interpret),
                    None,
                )
            else:
                try:
                    built[bkey] = ops.build_packed(
                        x, cfg.block_size, layout=cfg.layout, interpret=interpret
                    )
                except ValueError:
                    built[bkey] = None  # data can't express this layout
        if built[bkey] is None:
            continue
        s, spec = built[bkey]

        if cfg.layout == "unpacked":

            def fn(l, r, s=s, cfg=cfg):
                return ops.query(s, l, r, config=cfg, interpret=interpret)

        else:

            def fn(l, r, s=s, spec=spec, cfg=cfg):
                return ops.query_packed(s, spec, l, r, config=cfg, interpret=interpret)

        kind = f"kernel/tile={cfg.tile}/fetch={cfg.fetch}/bs={cfg.block_size}"
        if cfg.layout != "unpacked":  # unpacked kinds stay v2-identical
            kind += f"/layout={cfg.layout}"
        results.append((cfg, hybrid._measure(kind, fn, lj, rj, repeats)))
    return results


def autotune(
    n: int,
    batch: int = DEFAULT_TUNE_BATCH,
    *,
    block_size: int | None = None,
    candidates=None,
    seed: int = 0,
    repeats: int = 3,
    interpret: bool | None = None,
) -> KernelConfig:
    """Sweep the config product and return the fastest candidate.

    Ties break toward the earliest candidate in the (deterministic) product
    order, so a fake-measure test pins the winner exactly.
    """
    results = sweep(
        n,
        batch,
        block_size=block_size,
        candidates=candidates,
        seed=seed,
        repeats=repeats,
        interpret=interpret,
    )
    best_cfg, _ = min(results, key=lambda cv: cv[1])
    return best_cfg


def get_config(
    n: int,
    batch: int = DEFAULT_TUNE_BATCH,
    *,
    policy: str | None = None,
    block_size: int | None = None,
    backend: str | None = None,
    n_devices: int | None = None,
    path=None,
    **tune_kw,
) -> KernelConfig:
    """Resolve the kernel config for an (n, batch) point under ``policy``.

    See the module docstring for the three policies. ``block_size`` pins the
    sweep (and the default's block size) when the caller's structure is
    already committed to one.
    """
    if policy is None:
        return default_config(block_size if block_size is not None else 128)
    if policy not in ("cached", "tuned"):
        raise ValueError(f"unknown kernel-config policy {policy!r}")

    from repro.core import calib_cache

    key = tuning_key(n, batch, backend=backend, n_devices=n_devices)
    cfg = config_from_entry(calib_cache.load_entry(key, path))
    if cfg is not None:
        return cfg
    if policy == "cached":
        return default_config(block_size if block_size is not None else 128)
    cfg = autotune(n, batch, block_size=block_size, **tune_kw)
    calib_cache.store_entry(key, dict(cfg._asdict()), path)
    return cfg
