"""repro.optim — AdamW (+fp32 master), schedules, gradient compression."""

from . import adamw, compress
from .adamw import AdamWState, cosine_schedule

__all__ = ["adamw", "compress", "AdamWState", "cosine_schedule"]
