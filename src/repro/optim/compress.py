"""Int8 gradient compression with error feedback (1000+-node posture).

At multi-pod scale the cross-pod gradient all-reduce is the scarcest
bandwidth (DCN/optical, not ICI). This transform quantizes each gradient
leaf to int8 with a per-leaf scale before the reduction and decompresses
after, carrying the quantization residual to the next step (error feedback,
Seide et al. / 1-bit SGD lineage) so convergence is preserved.

Usage (train/steps.py): grads -> compress -> (collective) -> decompress.
Under jit/GSPMD the reduction is implicit, so the value of the transform is
realized when the step is built with ``shard_map`` cross-pod reductions; the
numerical contract (int8 + EF) is what unit tests pin down.
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["EFState", "init_ef", "compress", "decompress", "ef_compress_grads"]


class EFState(NamedTuple):
    residual: Any  # fp32 pytree, same structure as grads


def init_ef(grads_like) -> EFState:
    return EFState(residual=jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads_like))


def compress(g: jax.Array):
    """Symmetric per-tensor int8 quantization. Returns (q, scale)."""
    g32 = g.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def ef_compress_grads(grads, ef: EFState):
    """Quantize grads with error feedback. Returns (dequantized grads, new EF)."""

    def one(g, r):
        target = g.astype(jnp.float32) + r
        q, s = compress(target)
        deq = decompress(q, s)
        return deq, target - deq

    out = jax.tree.map(one, grads, ef.residual)
    deq = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    res = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    return deq, EFState(residual=res)
