"""AdamW with fp32 master weights, global-norm clipping, cosine schedule.

Mixed-precision contract: model params live in ``param_dtype`` (bf16 on TPU);
the optimizer holds the fp32 master copy plus two fp32 moments (ZeRO-sharded
on the mesh via the same PartitionSpecs as the params — launch/sharding.py).
"""

from __future__ import annotations

import math
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWState", "init", "update", "cosine_schedule", "global_norm"]


class AdamWState(NamedTuple):
    step: jax.Array  # () int32
    master: Any  # fp32 params
    mu: Any
    nu: Any


def init(params) -> AdamWState:
    f32 = lambda p: p.astype(jnp.float32)
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(
        step=jnp.int32(0),
        master=jax.tree.map(f32, params),
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
    )


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def cosine_schedule(base_lr: float, warmup: int, total: int):
    def lr(step):
        step = step.astype(jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = 0.5 * base_lr * (1.0 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup, warm, cos)

    return lr


def update(
    grads,
    state: AdamWState,
    *,
    lr_fn,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    clip_norm: float = 1.0,
    param_dtype=jnp.bfloat16,
):
    """One AdamW step. Returns (new model params, new state, metrics)."""
    step = state.step + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = lr_fn(step)
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m / bc1
        vhat = v / bc2
        p = p - lr * (mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p)
        return m, v, p

    out = jax.tree.map(upd, grads, state.mu, state.nu, state.master)
    mu = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    nu = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    master = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    # optimization_barrier: when param_dtype == f32 the cast is the identity
    # and XLA would alias params to master — a donating caller then hits
    # "donate the same buffer twice" on the next step.
    params = jax.lax.optimization_barrier(
        jax.tree.map(lambda p: p.astype(param_dtype), master)
    )
    new_state = AdamWState(step=step, master=master, mu=mu, nu=nu)
    return params, new_state, {"grad_norm": gnorm, "lr": lr}
