"""Sharded, atomic, async checkpointing with elastic restore.

Layout: ``<root>/step_<N>/`` holding one ``.npy`` per pytree leaf plus
``manifest.json`` (tree paths, shapes, dtypes, mesh metadata). Writes go to a
temp dir and are renamed into place, so a killed job never leaves a torn
checkpoint (restart reads the latest *complete* step — fault tolerance).

Elastic restore: leaves are stored unsharded-per-host; ``restore`` re-places
them with the *current* mesh's NamedShardings, so a job may come back on a
different device count (block ownership re-chunks automatically — the
distributed RMQ structure and FSDP params both re-shard this way). On a real
multi-host pod each host writes its own shard files and the manifest carries
the global shape; this single-process container exercises the same code path
with host-count 1.

Async: ``save(..., background=True)`` snapshots to host memory synchronously
(cheap) and writes to disk on a daemon thread, overlapping I/O with the next
training steps.
"""

from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Callable, Optional

import jax
import numpy as np

__all__ = [
    "latest_step",
    "load_snapshot",
    "restore",
    "save",
    "save_snapshot",
    "wait_pending",
]

_PENDING: list[threading.Thread] = []


def _fsync_dir(path: str) -> None:
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return [(jax.tree_util.keystr(path), leaf) for path, leaf in flat], treedef


def save(
    root: str,
    step: int,
    tree: Any,
    *,
    background: bool = False,
    meta: dict | None = None,
    fault: Optional[Callable[[str], None]] = None,
):
    """Checkpoint ``tree`` at ``step``. Atomic (write-temp-fsync-rename).

    Every leaf and the manifest are fsynced before the rename, and the
    parent directory after it: a power loss at any point leaves either the
    previous checkpoint or the new one, never a torn mix (``latest_step``
    ignores ``.tmp`` leftovers). ``fault`` is an optional ``check(site)``
    callable fired at the ``checkpoint_write`` site after the leaf writes
    but before the manifest/rename — the widest crash window.
    """
    flat, _ = _flatten(tree)
    # Snapshot to host memory first (fast, device -> host DMA) so async
    # writers never race live training buffers.
    host = [(k, np.asarray(v)) for k, v in flat]
    manifest = {
        "step": int(step),
        "leaves": [
            {"key": k, "shape": list(a.shape), "dtype": str(a.dtype), "file": f"leaf_{i}.npy"}
            for i, (k, a) in enumerate(host)
        ],
        "meta": meta or {},
    }

    def write():
        final = os.path.join(root, f"step_{step:08d}")
        tmp = final + ".tmp"
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp, exist_ok=True)
        for i, (_, a) in enumerate(host):
            with open(os.path.join(tmp, f"leaf_{i}.npy"), "wb") as f:
                np.save(f, a)
                f.flush()
                os.fsync(f.fileno())
        if fault is not None:
            # A crash here leaves a durable-but-manifestless temp dir, which
            # restore ignores — exactly a death between leaf writes and
            # publication. The torn temp stays on disk, like a real crash.
            fault("checkpoint_write")
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=1)
            f.flush()
            os.fsync(f.fileno())
        _fsync_dir(tmp)
        shutil.rmtree(final, ignore_errors=True)
        os.replace(tmp, final)
        _fsync_dir(root)

    if background:
        t = threading.Thread(target=write, daemon=True)
        t.start()
        _PENDING.append(t)
    else:
        write()


def wait_pending():
    for t in _PENDING:
        t.join()
    _PENDING.clear()


def latest_step(root: str) -> int | None:
    """Highest *complete* checkpoint step (tmp dirs are ignored)."""
    if not os.path.isdir(root):
        return None
    steps = []
    for d in os.listdir(root):
        if d.startswith("step_") and not d.endswith(".tmp"):
            if os.path.exists(os.path.join(root, d, "manifest.json")):
                steps.append(int(d.split("_")[1]))
    return max(steps) if steps else None


# Keys of a plain-dict tree flatten to "['name']" via jax.tree_util.keystr.
_DICT_KEY = re.compile(r"^\['(.*)'\]$")


def save_snapshot(
    root: str,
    step: int,
    arrays: dict,
    meta: dict,
    *,
    fault: Optional[Callable[[str], None]] = None,
) -> None:
    """Atomically snapshot a named-array dict (engine structure leaves).

    The durability half of ``fault.durable.DurableEngine.checkpoint``:
    ``arrays`` is an engine's host-side structure leaves keyed by name,
    ``meta`` the JSON-serializable identity needed to rebuild it (engine
    name, version id, journal seq, build kwargs). ``step`` is conventionally
    the journal seq the snapshot covers, so ``latest_step`` finds the most
    recent durable point.
    """
    save(root, step, dict(arrays), meta=dict(meta), fault=fault)


def load_snapshot(root: str, step: int | None = None):
    """Load a ``save_snapshot`` checkpoint -> ``(arrays, meta, step)``.

    ``step=None`` loads the latest complete checkpoint; raises
    ``FileNotFoundError`` when the root holds none.
    """
    if step is None:
        step = latest_step(root)
        if step is None:
            raise FileNotFoundError(f"no complete checkpoint under {root!r}")
    path = os.path.join(root, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    arrays = {}
    for e in manifest["leaves"]:
        m = _DICT_KEY.match(e["key"])
        key = m.group(1) if m else e["key"]
        arrays[key] = np.load(os.path.join(path, e["file"]))
    return arrays, manifest["meta"], int(step)


def restore(root: str, step: int, like: Any, *, shardings: Any = None) -> Any:
    """Load a checkpoint into the structure of ``like``.

    ``shardings``: optional matching pytree of NamedShardings — enables
    elastic restore onto whatever mesh the restarted job has.
    """
    path = os.path.join(root, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    flat_like, treedef = _flatten(like)
    by_key = {e["key"]: e for e in manifest["leaves"]}
    leaves = []
    for k, ref in flat_like:
        e = by_key[k]
        a = np.load(os.path.join(path, e["file"]))
        assert list(a.shape) == list(ref.shape), (k, a.shape, ref.shape)
        leaves.append(a)
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        tree = jax.tree.map(lambda a, s: jax.device_put(a, s), tree, shardings)
    return tree
