"""repro.checkpoint — atomic/async sharded checkpoints, elastic restore."""

from .store import latest_step, restore, save, wait_pending

__all__ = ["latest_step", "restore", "save", "wait_pending"]
