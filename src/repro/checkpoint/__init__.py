"""repro.checkpoint — atomic/async sharded checkpoints, elastic restore."""

from .store import (
    latest_step,
    load_snapshot,
    restore,
    save,
    save_snapshot,
    wait_pending,
)

__all__ = [
    "latest_step",
    "load_snapshot",
    "restore",
    "save",
    "save_snapshot",
    "wait_pending",
]
