"""Roofline-term extraction from compiled dry-run artifacts.

Terms per (arch × shape × mesh), in seconds (DESIGN.md §6):
    compute    = HLO_FLOPs / (chips × 197e12)      [bf16 MXU peak, v5e]
    memory     = HLO_bytes / (chips × 819e9)        [HBM BW]
    collective = collective_bytes / (chips × 50e9)  [ICI per-link BW]

HLO_FLOPs/bytes come from compiled.cost_analysis(). Empirically (verified on
this container) the numbers are for the post-SPMD *per-device* module, so the
terms divide by per-chip peaks directly; MODEL_FLOPS is global, so the
usefulness ratio multiplies HLO flops back by chip count. collective_bytes is
parsed from the post-SPMD HLO text: the sum of result-shape bytes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
instruction (shapes in the partitioned module are already per-device).
"""

from __future__ import annotations

import re
from dataclasses import dataclass, asdict

__all__ = ["HW", "collective_bytes", "roofline_terms", "Roofline"]

HW = {
    "peak_flops": 197e12,  # bf16 / chip
    "hbm_bw": 819e9,  # bytes/s / chip
    "ici_bw": 50e9,  # bytes/s / link
}

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "e4m3": 1, "e5m2": 1,
}

_COLL_RE = re.compile(
    r"=\s*(?:\(([^)]*)\)|(\S+))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\("
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-collective-kind result bytes in the (per-device) HLO."""
    out: dict[str, int] = {}
    seen_done = set()
    for m in _COLL_RE.finditer(hlo_text):
        shapes = m.group(1) or m.group(2)
        kind = m.group(3)
        full = m.group(0)
        # avoid double counting async start/done pairs: skip "-done"
        if "-done(" in full:
            continue
        out[kind] = out.get(kind, 0) + _shape_bytes(shapes)
    return out


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    coll_bytes_per_dev: float
    coll_breakdown: dict
    t_compute: float
    t_memory: float
    t_collective: float
    bottleneck: str
    model_flops: float
    useful_ratio: float
    bytes_per_device: float | None = None

    def to_dict(self):
        return asdict(self)


def roofline_terms(
    *, arch: str, shape: str, mesh_name: str, chips: int,
    cost: dict, hlo_text: str, model_flops: float, bytes_per_device=None,
) -> Roofline:
    flops = float(cost.get("flops", 0.0))  # per device
    byt = float(cost.get("bytes accessed", 0.0))  # per device
    coll = collective_bytes(hlo_text)
    coll_total = float(sum(coll.values()))  # per device
    t_c = flops / HW["peak_flops"]
    t_m = byt / HW["hbm_bw"]
    t_x = coll_total / HW["ici_bw"]  # per-device bytes over per-link BW
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    bottleneck = max(terms, key=terms.get)
    return Roofline(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        hlo_flops=flops, hlo_bytes=byt,
        coll_bytes_per_dev=coll_total, coll_breakdown=coll,
        t_compute=t_c, t_memory=t_m, t_collective=t_x,
        bottleneck=bottleneck,
        model_flops=model_flops,
        useful_ratio=(model_flops / (flops * chips)) if flops else 0.0,
        bytes_per_device=bytes_per_device,
    )
