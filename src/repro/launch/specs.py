"""ShapeDtypeStruct input stand-ins for every (arch × shape) cell.

No device allocation happens here — the dry-run lowers against these specs
(the shannon/kernels pattern): weak-type-correct, shardable, zero bytes.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, get_config
from repro.models import model as model_lib

__all__ = ["input_specs", "model_flops"]


def input_specs(arch: str, shape_name: str) -> dict:
    """Stand-ins for one cell: params/opt/batch (train) or params/cache/token."""
    return input_specs_for(get_config(arch), shape_name)


def input_specs_for(cfg, shape_name: str) -> dict:
    """Same, for an arbitrary (possibly variant) ModelConfig."""
    shape = SHAPES[shape_name]
    b, s = shape.global_batch, shape.seq_len
    params = model_lib.abstract_params(cfg)

    if shape.kind == "train":
        batch = {"labels": jax.ShapeDtypeStruct((b, s), jnp.int32)}
        if cfg.embeds_input:
            batch["embeds"] = jax.ShapeDtypeStruct((b, s, cfg.d_model), cfg.dtype)
        else:
            batch["tokens"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
        return {"params": params, "batch": batch}

    if shape.kind == "prefill":
        if cfg.embeds_input:
            inputs = jax.ShapeDtypeStruct((b, s, cfg.d_model), cfg.dtype)
        else:
            inputs = jax.ShapeDtypeStruct((b, s), jnp.int32)
        return {"params": params, "inputs": inputs}

    # decode: one new token against a seq_len cache
    cache = model_lib.abstract_cache(cfg, b, s)
    token = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    return {"params": params, "token": token, "cache": cache}


def model_flops(arch: str, shape_name: str) -> float:
    """MODEL_FLOPS for the usefulness ratio: 6·N·D train, 2·N·D inference
    (N = active params for MoE, D = processed tokens)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape.global_batch  # decode: one token per seq
