"""Sharding rules: parameter/batch/cache PartitionSpecs for any mesh.

Strategy (DESIGN.md §4):
  * params: 2-D sharded — tensor-parallel dim over "model", the other big
    dim FSDP over "data". Pods are data-parallel replicas of params, so
    specs never mention "pod" for weights; batch shards over ("pod","data").
  * MoE experts: expert dim over "model" when divisible (arctic 128/16),
    otherwise F over "model" (grok 8 experts) — EP degenerates to TP.
  * decode caches: batch over DP when divisible, sequence over "model"
    (sequence-parallel cache for long-context), SSM state heads over "model".
  * every rule checks divisibility and falls back to replication, so any
    (arch × shape × mesh) cell lowers.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import model as model_lib
from repro.models.transformer import Cache

__all__ = [
    "dp_axes",
    "param_specs",
    "batch_specs",
    "cache_spec",
    "named",
    "opt_state_specs",
]


def dp_axes(mesh: Mesh) -> tuple:
    """Data-parallel axes: ("pod","data") on multi-pod, else ("data",)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def batch_axes(cfg, mesh: Mesh, batch: int) -> tuple | None:
    """Axes the batch dim shards over. Pure-FSDP configs spread the batch
    over every mesh axis; fall back through shorter prefixes when the batch
    doesn't divide (e.g. 256 sequences on the 512-chip multi-pod mesh)."""
    if cfg.parallelism == "fsdp":
        candidates = [tuple(mesh.axis_names), dp_axes(mesh)]
    else:
        candidates = [dp_axes(mesh)]
    for cand in candidates:
        if cand and _div(batch, mesh, cand):
            return cand
    return None


def _div(n: int, mesh: Mesh, axis) -> bool:
    if axis is None:
        return True
    size = 1
    for a in axis if isinstance(axis, tuple) else (axis,):
        size *= mesh.shape[a]
    return n % size == 0


def _guard(shape: tuple, spec: tuple, mesh: Mesh) -> P:
    """Replace any non-divisible dim sharding with replication."""
    fixed = tuple(s if _div(dim, mesh, s) else None for dim, s in zip(shape, spec))
    return P(*fixed)


# (tp_dim_last?, rule) per leaf name; 2-D core weights are (in, out).
_ROW = ("data", "model")  # shard out-features over model (wq, w_gate, in_proj)
_COL = ("model", "data")  # shard in-features over model (wo, w_down, out_proj)

_CORE_RULES: dict[str, tuple] = {
    "embed": _COL,  # (V, D): vocab over model, D fsdp
    "lm_head": _COL,
    "final_norm": (None,),
    "ln1": (None,),
    "ln2": (None,),
    "norm_w": (None,),
    "wq": _ROW,
    "wk": _ROW,
    "wv": _ROW,
    "wo": _COL,
    "bq": ("model",),
    "bk": ("model",),
    "bv": ("model",),
    "w_gate": _ROW,
    "w_up": _ROW,
    "w_down": _COL,
    "wr_gate": _ROW,
    "wr_up": _ROW,
    "wr_down": _COL,
    "router": ("data", None),
    "in_proj": _ROW,
    "out_proj": _COL,
    "conv_w": (None, "model"),
    "conv_b": ("model",),
    "a_log": (None,),
    "d_skip": (None,),
    "dt_bias": (None,),
}

_MOE_LEAVES = {"w_gate", "w_up", "w_down"}


def _leaf_spec(name: str, shape: tuple, cfg, mesh: Mesh) -> P:
    core = _CORE_RULES[name]
    if cfg.num_experts and name in _MOE_LEAVES and len(shape) - len(core) >= 2:
        # expert-stacked (..., E, in, out): prefer EP over model axis
        e = cfg.num_experts
        if _div(e, mesh, "model"):
            core = ("model", "data", None) if name != "w_down" else ("model", None, "data")
        else:
            core = (None,) + core
    lead = len(shape) - len(core)
    # FSDP spans ALL data-parallel axes: on the multi-pod mesh the "data"
    # placeholder becomes ("pod","data") — ZeRO across pods, so a 480B
    # optimizer state divides by 512, not 256. Pure-FSDP configs fold the
    # model axis into FSDP and drop TP entirely.
    if cfg.parallelism == "fsdp":
        fsdp = tuple(mesh.axis_names)
        spec = tuple(
            fsdp if s == "data" else (None if s == "model" else s)
            for s in (None,) * lead + tuple(core)
        )
    else:
        dp = dp_axes(mesh)
        spec = tuple(dp if s == "data" else s for s in (None,) * lead + tuple(core))
    return _guard(shape, spec, mesh)


def param_specs(cfg, mesh: Mesh) -> Any:
    shapes = model_lib.param_shapes(cfg)

    def walk(tree):
        out = {}
        for k, v in tree.items():
            if isinstance(v, dict):
                out[k] = walk(v)
            else:
                out[k] = _leaf_spec(k, v, cfg, mesh)
        return out

    return walk(shapes)


def batch_specs(cfg, mesh: Mesh, batch: int, seq_len: int, kind: str) -> Any:
    bspec = batch_axes(cfg, mesh, batch)
    if kind == "train":
        out = {"labels": P(bspec, None)}
        if cfg.embeds_input:
            out["embeds"] = P(bspec, None, None)
        else:
            out["tokens"] = P(bspec, None)
        return out
    if kind == "prefill":
        return P(bspec, None, None) if cfg.embeds_input else P(bspec, None)
    if kind == "decode":
        return P(bspec, None)  # (B, 1) token ids
    raise ValueError(kind)


def cache_spec(cfg, mesh: Mesh, batch: int, capacity: int) -> Cache:
    """PartitionSpecs for the decode cache (see module docstring)."""
    b = batch_axes(cfg, mesh, batch)
    # sequence-parallel cache whenever the model axis isn't already carrying
    # the batch (long-context: batch=1 decodes shard the 500k cache seq dim)
    seq = None
    if (b is None or "model" not in b) and _div(capacity, mesh, "model"):
        seq = "model"
    kv = None
    shapes = model_lib.cache_shapes(cfg, batch, capacity)
    kw = {}
    if "k" in shapes:
        kw["k"] = P(None, b, seq, kv, None)
        kw["v"] = P(None, b, seq, kv, None)
    if "conv" in shapes:
        conv_c = shapes["conv"][-1]
        kw["conv"] = P(None, b, None, "model" if _div(conv_c, mesh, "model") else None)
        h = shapes["ssd"][2]
        kw["ssd"] = P(None, b, "model" if _div(h, mesh, "model") else None, None, None)
    return Cache(length=P(), **kw)


def opt_state_specs(pspecs) -> Any:
    """AdamW state inherits param specs (ZeRO: moments sharded like params)."""
    from repro.optim.adamw import AdamWState

    return AdamWState(step=P(), master=pspecs, mu=pspecs, nu=pspecs)


def named(mesh: Mesh, spec_tree: Any) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )
