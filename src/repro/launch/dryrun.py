import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape) on the production
meshes and extract roofline terms. MUST be run as a module entry point
(`python -m repro.launch.dryrun`) so the XLA_FLAGS above land before any jax
import — do not import this module from code that already initialized jax.

Roofline methodology (loop-corrected): XLA's cost_analysis counts a while
loop's body ONCE, so a scan-over-64-layers program under-reports flops ~64x.
We therefore compile each cell twice:

  1. the PRODUCTION artifact (scan-over-layers, flash-attention scan) — this
     is the lowering/memory/collective-schedule proof: memory_analysis() must
     fit, and its HLO is the collective schedule we report;
  2. COST variants with every scan unrolled, at 1 and 2 layers per layer
     *type* (dense archs: one type; gemma3: local + global; zamba2: mamba +
     shared-attn). Per-type cost = c(2) - c(1); the embed/head/optimizer base
     = c(1) - delta. Totals extrapolate exactly because layers of a type are
     homogeneous. flops/bytes/collective-bytes all extrapolate this way.

Usage:
  python -m repro.launch.dryrun --arch granite-3-8b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both --out experiments/dryrun
  python -m repro.launch.dryrun --all --mesh multi --compile-only   # lowering proof
"""

import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, cells, get_config
from repro.launch import roofline as roofline_lib
from repro.launch import sharding as shard_rules
from repro.launch.mesh import make_production_mesh
from repro.launch.mesh import set_mesh
from repro.launch.specs import input_specs_for, model_flops
from repro.optim.adamw import AdamWState


def lower_step(cfg, shape_name: str, mesh, *, lr: float = 1e-4):
    """Lower the cell's step function against ShapeDtypeStructs."""
    shape = SHAPES[shape_name]
    specs = input_specs_for(cfg, shape_name)

    if shape.kind == "train":
        from repro.optim import adamw
        from repro.train.steps import make_train_step

        f32 = lambda tree: jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), tree
        )
        opt_abstract = AdamWState(
            step=jax.ShapeDtypeStruct((), jnp.int32),
            master=f32(specs["params"]),
            mu=f32(specs["params"]),
            nu=f32(specs["params"]),
        )
        step, _ = make_train_step(
            cfg, mesh,
            lr_fn=adamw.cosine_schedule(lr, 100, 10_000),
            batch=shape.global_batch, seq_len=shape.seq_len,
        )
        return step.lower(specs["params"], opt_abstract, specs["batch"])

    if shape.kind == "prefill":
        from repro.train.steps import make_prefill_step

        step, _ = make_prefill_step(
            cfg, mesh, batch=shape.global_batch, seq_len=shape.seq_len
        )
        return step.lower(specs["params"], specs["inputs"])

    from repro.train.steps import make_serve_step

    step, _ = make_serve_step(
        cfg, mesh, batch=shape.global_batch, capacity=shape.seq_len
    )
    return step.lower(specs["params"], specs["token"], specs["cache"])


# --------------------------------------------------------------------------
# layer-type decomposition for cost extrapolation
# --------------------------------------------------------------------------


def _unrolled(cfg, n):
    return dataclasses.replace(
        cfg, num_layers=n, unroll_layers=True, attn_unroll=True, ssm_unroll=True
    )


def layer_types(arch: str):
    """[(name, build_cfg(k_layers), count)] per arch (see module docstring)."""
    cfg = get_config(arch)
    if cfg.family == "hybrid":
        ssm_like = dataclasses.replace(cfg, family="ssm", attn_every=0)
        attn_like = dataclasses.replace(
            cfg, family="dense", attn_every=0, ssm_state=0
        )
        n_seg = cfg.num_layers // cfg.attn_every
        return [
            ("mamba", lambda k: _unrolled(ssm_like, k), cfg.num_layers),
            ("shared_attn", lambda k: _unrolled(attn_like, k), n_seg),
        ]
    if cfg.global_every:
        local = dataclasses.replace(cfg, global_every=0)
        glob = dataclasses.replace(cfg, global_every=0, sliding_window=0)
        n_glob = cfg.num_layers // cfg.global_every
        return [
            ("local", lambda k: _unrolled(local, k), cfg.num_layers - n_glob),
            ("global", lambda k: _unrolled(glob, k), n_glob),
        ]
    return [("layer", lambda k: _unrolled(cfg, k), cfg.num_layers)]


def _measure(cfg, shape_name, mesh):
    lowered = lower_step(cfg, shape_name, mesh)
    compiled = lowered.compile()
    cost = compiled.cost_analysis()
    coll = roofline_lib.collective_bytes(compiled.as_text())
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll": float(sum(coll.values())),
    }


def cost_extrapolate(arch: str, shape_name: str, mesh) -> dict:
    total = {"flops": 0.0, "bytes": 0.0, "coll": 0.0}
    base = None
    detail = {}
    for i, (name, mk, count) in enumerate(layer_types(arch)):
        c1 = _measure(mk(1), shape_name, mesh)
        c2 = _measure(mk(2), shape_name, mesh)
        delta = {k: c2[k] - c1[k] for k in total}
        detail[name] = {"per_layer": delta, "count": count}
        if i == 0:
            base = {k: max(c1[k] - delta[k], 0.0) for k in total}
        for k in total:
            total[k] += count * delta[k]
    for k in total:
        total[k] += base[k]
    detail["base"] = base
    return {"total": total, "detail": detail}


# --------------------------------------------------------------------------
# cell runner
# --------------------------------------------------------------------------


def run_cell(arch: str, shape_name: str, mesh_name: str, out_dir: str | None,
             *, compile_only: bool = False):
    multi = mesh_name == "multi"
    mesh = make_production_mesh(multi_pod=multi)
    chips = 512 if multi else 256
    cfg = get_config(arch)
    t0 = time.time()
    with set_mesh(mesh):
        # 1) production artifact: proves lowering; memory + collective schedule
        lowered = lower_step(cfg, shape_name, mesh)
        compiled = lowered.compile()
        t1 = time.time()
        mem = compiled.memory_analysis()
        hlo = compiled.as_text()
        coll_sched = roofline_lib.collective_bytes(hlo)

        rec = {
            "arch": arch, "shape": shape_name, "mesh": mesh_name, "chips": chips,
            "compile_s": round(t1 - t0, 1),
            "temp_bytes_per_dev": getattr(mem, "temp_size_in_bytes", None),
            "arg_bytes_per_dev": getattr(mem, "argument_size_in_bytes", None),
            "out_bytes_per_dev": getattr(mem, "output_size_in_bytes", None),
            "coll_schedule_scan_artifact": coll_sched,
        }

        # 2) loop-corrected roofline terms (single-pod table per DESIGN §6)
        if not compile_only:
            est = cost_extrapolate(arch, shape_name, mesh)
            rl = roofline_lib.roofline_terms(
                arch=arch, shape=shape_name, mesh_name=mesh_name, chips=chips,
                cost={"flops": est["total"]["flops"], "bytes accessed": est["total"]["bytes"]},
                hlo_text="",  # collective bytes supplied below
                model_flops=model_flops(arch, shape_name),
                bytes_per_device=rec["temp_bytes_per_dev"],
            )
            rl.coll_bytes_per_dev = est["total"]["coll"]
            rl.t_collective = est["total"]["coll"] / roofline_lib.HW["ici_bw"]
            terms = {
                "compute": rl.t_compute, "memory": rl.t_memory,
                "collective": rl.t_collective,
            }
            rl.bottleneck = max(terms, key=terms.get)
            rec.update(rl.to_dict())
            rec["cost_detail"] = est["detail"]

    if not compile_only:
        print(
            f"[{arch} × {shape_name} × {mesh_name}] OK compile={rec['compile_s']}s "
            f"flops/dev={rec['hlo_flops']:.3e} bytes/dev={rec['hlo_bytes']:.3e} "
            f"coll/dev={rec['coll_bytes_per_dev']:.3e} "
            f"t=(c {rec['t_compute']*1e3:.2f} | m {rec['t_memory']*1e3:.2f} | "
            f"x {rec['t_collective']*1e3:.2f}) ms bottleneck={rec['bottleneck']} "
            f"useful={rec['useful_ratio']:.2f} temp/dev={_fmt_bytes(rec['temp_bytes_per_dev'])}"
        )
    else:
        print(
            f"[{arch} × {shape_name} × {mesh_name}] COMPILE OK "
            f"({rec['compile_s']}s, temp/dev={_fmt_bytes(rec['temp_bytes_per_dev'])}, "
            f"colls={sorted(rec['coll_schedule_scan_artifact'])})"
        )
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        suffix = "compileonly" if compile_only else "full"
        fn = os.path.join(out_dir, f"{arch}__{shape_name}__{mesh_name}__{suffix}.json")
        with open(fn, "w") as f:
            json.dump(rec, f, indent=1, default=str)
    return rec


def _fmt_bytes(b):
    if b is None:
        return "?"
    return f"{b/2**30:.2f}GiB"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--compile-only", action="store_true",
                    help="skip cost extrapolation (multi-pod lowering proof)")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    if args.all:
        todo = [(a, s) for a, s, skipped in cells() if not skipped]
    else:
        todo = [(args.arch, args.shape)]

    failures = []
    for arch, shape in todo:
        for m in meshes:
            try:
                run_cell(arch, shape, m, args.out, compile_only=args.compile_only)
            except Exception as e:
                failures.append((arch, shape, m, repr(e)))
                traceback.print_exc()
    if failures:
        print(f"FAILED {len(failures)} cells:")
        for f in failures:
            print("  ", f)
        raise SystemExit(1)
    print(f"all {len(todo) * len(meshes)} dry-run cells passed")


if __name__ == "__main__":
    main()
