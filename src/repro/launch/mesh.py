"""Production mesh construction.

Pure function — importing this module never touches jax device state, so
smoke tests see 1 device while the dry-run (which sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before any jax import)
sees the full placeholder fleet.
"""

from __future__ import annotations

import jax

try:  # jax >= 0.5: explicit axis types
    from jax.sharding import AxisType
except ImportError:  # jax 0.4.x: no AxisType; make_mesh takes no axis_types
    AxisType = None

__all__ = [
    "factor_2d",
    "make_group_mesh",
    "make_production_mesh",
    "make_mesh",
    "set_mesh",
]


def factor_2d(ndev: int):
    """Squarest (a, b) factoring of a device count, a <= b.

    The one definition of how ``--qshard 2d`` (and the benchmark that mirrors
    it) splits a flat device fleet into a (structure, batch) grid.
    """
    a = int(ndev**0.5)
    while ndev % a:
        a -= 1
    return a, ndev // a


def _mk(shape, axes):
    if AxisType is not None:
        return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(shape))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh for tests/examples (e.g. (2, 4) on 8 fake devices)."""
    return _mk(tuple(shape), tuple(axes))


def make_group_mesh(devices, axes=("shard",)):
    """1-D mesh over an *explicit* device subset.

    ``jax.make_mesh`` always spans every visible device; a replica fleet
    (``serve.fleet``) instead carves the fleet into disjoint per-replica
    groups, each serving a mesh engine on its own slice of the devices.
    """
    import numpy as np
    from jax.sharding import Mesh

    return Mesh(np.asarray(devices, dtype=object), tuple(axes))


def set_mesh(mesh):
    """Context manager activating ``mesh`` (jax.set_mesh, or the Mesh itself
    on jax 0.4.x where Mesh is the context manager)."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh
