"""Production mesh construction.

Pure function — importing this module never touches jax device state, so
smoke tests see 1 device while the dry-run (which sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before any jax import)
sees the full placeholder fleet.
"""

from __future__ import annotations

import jax
from jax.sharding import AxisType

__all__ = ["make_production_mesh", "make_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(shape))


def make_mesh(shape, axes):
    """Arbitrary mesh for tests/examples (e.g. (2, 4) on 8 fake devices)."""
    return jax.make_mesh(tuple(shape), tuple(axes), axis_types=(AxisType.Auto,) * len(shape))
