"""RMQ serving launcher — the paper's workload as a service (end-to-end driver).

Builds a distributed RMQ engine over the mesh, then serves batches of
RMQ(l, r) queries (uniform / lognormal range distributions, the paper's §6.4
workloads) and verifies a sample against the numpy oracle.

Engines (``--engine``):
  * ``distributed``    — the mesh-sharded blocked engine (structure sharded,
    queries replicated, two-pmin merge).
  * ``sharded_hybrid`` — the range-adaptive sharded engine: short ranges via
    the sharded blocked path, long ranges via the sharded sparse table, with
    ``--qshard`` switching to the batch-sharded mode (replicated structure,
    sharded queries) and ``--calibrate`` taking the routing threshold from
    the persistent calibration cache (measured once per configuration).

  PYTHONPATH=src python -m repro.launch.serve --n 1048576 --batch 4096 \
      --batches 8 --dist small --engine sharded_hybrid
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import distributed, ref, sharded_hybrid
from repro.launch.mesh import make_mesh, set_mesh


def make_queries(rng, n: int, batch: int, dist: str):
    """Paper §6.4 range distributions (large / medium / small)."""
    if dist == "large":
        length = rng.integers(1, n + 1, batch)
    else:
        exp = 0.6 if dist == "medium" else 0.3
        length = np.exp(rng.normal(np.log(n**exp), 0.3, batch))
        length = np.clip(length, 1, n).astype(np.int64)
    l = rng.integers(0, np.maximum(n - length + 1, 1), batch)
    r = np.minimum(l + length - 1, n - 1)
    return l.astype(np.int64), r.astype(np.int64)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=1 << 20)
    ap.add_argument("--batch", type=int, default=4096)
    ap.add_argument("--batches", type=int, default=8)
    ap.add_argument("--block-size", type=int, default=1024)
    ap.add_argument("--dist", choices=["large", "medium", "small"], default="small")
    ap.add_argument("--verify", type=int, default=64)
    ap.add_argument(
        "--engine", choices=["distributed", "sharded_hybrid"], default="distributed"
    )
    ap.add_argument(
        "--qshard",
        action="store_true",
        help="sharded_hybrid: shard the query batch (replicated structure) "
        "instead of the structure",
    )
    ap.add_argument(
        "--calibrate",
        action="store_true",
        help="sharded_hybrid: routing threshold from the calibration cache "
        "(measures once per (n, bs, backend, ndev) configuration)",
    )
    args = ap.parse_args()
    if args.engine != "sharded_hybrid" and (args.qshard or args.calibrate):
        ap.error("--qshard/--calibrate only apply to --engine sharded_hybrid")

    n_dev = len(jax.devices())
    mesh = make_mesh((n_dev,), ("shard",))
    rng = np.random.default_rng(0)
    x = rng.random(args.n, dtype=np.float32)

    with set_mesh(mesh):
        t0 = time.perf_counter()
        if args.engine == "sharded_hybrid":
            s = sharded_hybrid.build(
                jnp.asarray(x),
                mesh,
                ("shard",),
                args.block_size,
                threshold="calibrated" if args.calibrate else "cached",
                mode="shard_batch" if args.qshard else "shard_structure",
            )
            jax.block_until_ready(s.blocked.x_blocks)
            qfn = sharded_hybrid.query
        else:
            s = distributed.build_sharded(jnp.asarray(x), mesh, ("shard",), args.block_size)
            jax.block_until_ready(s.x_blocks)
            dist_q = distributed.make_query_fn(mesh, ("shard",))
            qfn = lambda st, l, r: dist_q(st, jnp.asarray(l), jnp.asarray(r))
        t_build = time.perf_counter() - t0

        total_q = 0
        t0 = time.perf_counter()
        last = None
        for b in range(args.batches):
            l, r = make_queries(rng, args.n, args.batch, args.dist)
            idx, val = qfn(s, l, r)
            last = (l, r, idx, val)
            total_q += args.batch
        jax.block_until_ready(last[2])
        t_serve = time.perf_counter() - t0

    l, r, idx, val = last
    k = min(args.verify, args.batch)
    gold = ref.rmq_ref(x, l[:k], r[:k])
    ok = (np.asarray(idx[:k]) == gold).all()
    mode = " qshard" if (args.engine == "sharded_hybrid" and args.qshard) else ""
    print(
        f"[{args.engine}{mode}] served {total_q} RMQs over n={args.n} "
        f"({args.dist} ranges) on {n_dev} shard(s): "
        f"build {t_build*1e3:.1f} ms, serve {t_serve*1e3:.1f} ms "
        f"({t_serve/total_q*1e9:.1f} ns/RMQ), verify[{k}] {'OK' if ok else 'MISMATCH'}"
    )
    if not ok:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
