"""RMQ serving launcher — thin CLI over the serve subsystem + engine registry.

Two modes:

* ``--mode oneshot`` (default): the benchmark-parity driver — build once,
  dispatch pre-formed query batches synchronously, verify a sample against
  the numpy oracle.
* ``--mode async``: concurrent simulated clients submit variable-size
  requests through ``repro.serve.RMQServer`` (open-loop Poisson arrivals);
  the deadline micro-batcher coalesces them into power-of-two padded engine
  launches, scatters per-request results back, and EVERY request is
  verified bit-identical against the oracle. Prints p50/p99 latency,
  sustained throughput, and the microbatch/coalescing profile. With
  ``--mutate K`` (engines declaring ``updatable``), a mutator thread
  interleaves K update batches (point writes, range fills, appends) through
  ``submit_update`` while the clients run: the engine is built as a
  ``repro.update.OnlineEngine``, each request is answered against its
  pinned MVCC version, and verification replays the delta stream on the
  host so every request is checked against the oracle **of its version**.
  ``--adaptive-deadline`` lets the batcher move its coalescing deadline
  with load (trajectory reported in the stats line).

Engine choices and flag validation derive from the registry's capability
metadata (``core.registry.EngineSpec``) — no hard-coded engine name lists:
``--qshard`` needs an engine with a ``"shard_batch"`` mode (``--qshard 2d``
needs ``"shard_2d"``: a 2D structure x batch mesh), ``--calibrate`` needs a
``"threshold"`` build kwarg, ``--block-size`` needs a ``"block_size"`` build
kwarg. Builds lower through the staged BuildPlan pipeline
(``registry.plan_for_serving`` + ``core.build.execute``); in async mode the
plan's resolved threshold drives per-regime engine warmup.

  PYTHONPATH=src python -m repro.launch.serve --n 1048576 --batch 4096 \
      --batches 8 --dist small --engine sharded_hybrid
  XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \
      python -m repro.launch.serve --mode async --engine sharded_hybrid \
      --n 65536 --dist medium --clients 4 --requests 32 --qshard 2d
"""

from __future__ import annotations

import argparse
import contextlib
import json
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import update as update_mod
from repro.core import build as build_mod
from repro.core import ref, registry
from repro.launch.mesh import factor_2d, make_mesh, set_mesh
from repro.obs import Tracer, default_registry, set_tracer, verify_request_chains
from repro.serve import RMQServer, ServeConfig, ServerOverloaded
from repro.serve.workload import make_queries, run_poisson_clients

__all__ = ["main"]

# --qshard values -> sharded_hybrid distribution modes.
_QSHARD_MODES = {"batch": "shard_batch", "2d": "shard_2d"}


def _parser() -> argparse.ArgumentParser:
    engines = registry.serveable_names()
    ap = argparse.ArgumentParser(
        description="Serve batched RMQs through any registry engine.",
        epilog="engines: "
        + "; ".join(f"{n} — {registry.get(n).doc}" for n in engines),
    )
    ap.add_argument("--mode", choices=["oneshot", "async"], default="oneshot")
    ap.add_argument("--n", type=int, default=1 << 20)
    ap.add_argument("--dist", choices=["large", "medium", "small"], default="small")
    ap.add_argument("--engine", choices=engines, default="sharded_hybrid")
    ap.add_argument(
        "--block-size",
        type=int,
        default=None,
        help="engine block size (engines declaring a 'block_size' build kwarg; "
        "default: the engine's own)",
    )
    ap.add_argument(
        "--packed",
        nargs="?",
        const="auto",
        choices=["auto", "packed32", "packed64", "quantized"],
        default=None,
        help="serve fused (value, index) word structures (core.packing): bare "
        "--packed (= 'auto') picks the tightest layout the data fits, or name "
        "one explicitly (engines declaring a 'packed' build kwarg)",
    )
    ap.add_argument(
        "--qshard",
        nargs="?",
        const="batch",
        choices=sorted(_QSHARD_MODES),
        default=None,
        help="shard the query batch: bare --qshard (= 'batch') replicates the "
        "structure and shards queries over all devices; '--qshard 2d' shards "
        "the structure over one mesh axis and the batch over the other "
        "(engines declaring the matching mode)",
    )
    ap.add_argument(
        "--calibrate",
        action="store_true",
        help="routing threshold from the calibration cache, measuring once per "
        "configuration (engines declaring a 'threshold' build kwarg)",
    )
    ap.add_argument(
        "--tune",
        action="store_true",
        help="megakernel launch geometry (tile, fetch, block size) from the "
        "autotune cache, sweeping once per configuration (engines declaring "
        "a 'kernel_config' build kwarg; without --tune, cached winners are "
        "still loaded read-only)",
    )
    one = ap.add_argument_group("oneshot")
    one.add_argument("--batch", type=int, default=4096, help="queries per batch")
    one.add_argument("--batches", type=int, default=8, help="batches to serve")
    one.add_argument("--verify", type=int, default=64, help="oracle sample size")
    asy = ap.add_argument_group("async")
    asy.add_argument("--clients", type=int, default=4, help="concurrent simulated clients")
    asy.add_argument("--requests", type=int, default=32, help="requests per client")
    asy.add_argument("--req-batch", type=int, default=16, help="queries per request")
    asy.add_argument(
        "--rate",
        type=float,
        default=200.0,
        help="per-client offered load, Poisson requests/s (0 = no pacing)",
    )
    asy.add_argument("--deadline-ms", type=float, default=2.0, help="micro-batch deadline")
    asy.add_argument("--max-batch", type=int, default=4096, help="queries per engine launch")
    asy.add_argument("--workers", type=int, default=1, help="engine-pool threads")
    asy.add_argument("--max-pending", type=int, default=4096, help="admission-control bound")
    asy.add_argument(
        "--replicas",
        type=int,
        default=1,
        help="replica fleet size: >1 serves through serve.fleet's regime-"
        "routing front door (updatable engines; mesh engines carve one "
        "device group per replica)",
    )
    asy.add_argument(
        "--max-lag",
        type=int,
        default=1,
        help="fleet rollout barrier: max version spread between replicas",
    )
    asy.add_argument(
        "--mutate",
        type=int,
        default=0,
        metavar="K",
        help="interleave K update batches (point/range writes + appends) "
        "while serving (engines declaring 'updatable'); every request is "
        "verified against the oracle of its pinned version",
    )
    asy.add_argument(
        "--mutate-rate",
        type=float,
        default=50.0,
        help="mutator offered load, update batches/s",
    )
    asy.add_argument(
        "--adaptive-deadline",
        action="store_true",
        help="let the batcher shrink its deadline under load and grow it when idle",
    )
    asy.add_argument(
        "--restore",
        default=None,
        metavar="DIR",
        help="durability root (with --mutate): restore the engine from DIR's "
        "latest checkpoint + journal suffix if one exists, else create it "
        "there; every update is WAL-journaled before it applies",
    )
    ap.add_argument(
        "--chaos",
        type=int,
        default=None,
        metavar="SEED",
        help="run the seeded chaos soak instead of serving: crash workers, "
        "fail patches and checkpoints mid-stream, then crash-restore and "
        "verify nothing was lost (engines declaring 'updatable')",
    )
    obs = ap.add_argument_group("observability")
    obs.add_argument(
        "--trace",
        default=None,
        metavar="OUT.json",
        help="record request/update/build lifecycle spans and export a "
        "Chrome-trace JSON here (open at https://ui.perfetto.dev); async "
        "modes additionally self-verify that every served request has a "
        "complete admission->flush->launch->scatter->resolve span chain",
    )
    obs.add_argument(
        "--metrics-interval",
        type=float,
        default=None,
        metavar="S",
        help="dump the metrics registry as one JSON line every S seconds "
        "(plus a final dump at shutdown)",
    )
    return ap


def _validate(ap: argparse.ArgumentParser, args, spec: registry.EngineSpec) -> None:
    """Flag validation straight off the EngineSpec capability metadata."""
    if args.qshard is not None and _QSHARD_MODES[args.qshard] not in spec.modes:
        ap.error(
            f"--qshard {args.qshard} requires an engine with a "
            f"'{_QSHARD_MODES[args.qshard]}' mode; "
            f"{args.engine} declares modes {spec.modes or '()'}"
        )
    if args.calibrate and "threshold" not in spec.build_kwargs:
        ap.error(
            f"--calibrate requires an engine with a 'threshold' build kwarg; "
            f"{args.engine} declares {sorted(spec.build_kwargs) or '()'}"
        )
    if args.block_size is not None and "block_size" not in spec.build_kwargs:
        ap.error(
            f"--block-size requires an engine with a 'block_size' build kwarg; "
            f"{args.engine} declares {sorted(spec.build_kwargs) or '()'}"
        )
    if args.tune and "kernel_config" not in spec.build_kwargs:
        ap.error(
            f"--tune requires an engine with a 'kernel_config' build kwarg; "
            f"{args.engine} declares {sorted(spec.build_kwargs) or '()'}"
        )
    if args.packed is not None and "packed" not in spec.build_kwargs:
        ap.error(
            f"--packed requires an engine with a 'packed' build kwarg; "
            f"{args.engine} declares {sorted(spec.build_kwargs) or '()'}"
        )
    if args.packed == "quantized" and spec.needs_mesh:
        ap.error(
            "--packed quantized is single-host only (its exact fallback needs "
            f"the raw blocks resident); {args.engine} is a mesh engine"
        )
    if args.mutate:
        if args.mode != "async":
            ap.error("--mutate requires --mode async")
        if not spec.updatable:
            ap.error(
                f"--mutate requires an updatable engine; "
                f"{args.engine} is not (have {registry.updatable_names()})"
            )
    if args.replicas > 1:
        if args.mode != "async":
            ap.error("--replicas > 1 requires --mode async")
        if not spec.updatable:
            ap.error(
                f"--replicas > 1 requires an updatable engine; "
                f"{args.engine} is not (have {registry.updatable_names()})"
            )
        if args.chaos is not None:
            ap.error("--chaos runs a single-engine soak; drop --replicas")
    if args.chaos is not None and not spec.updatable:
        ap.error(
            f"--chaos requires an updatable engine; "
            f"{args.engine} is not (have {registry.updatable_names()})"
        )
    if args.restore is not None and not args.mutate and args.chaos is None:
        ap.error("--restore requires --mutate (durable online serving) or --chaos")


def _build_kwargs(args, spec: registry.EngineSpec) -> dict:
    kw = {}
    if args.block_size is not None:
        kw["block_size"] = args.block_size
    if "threshold" in spec.build_kwargs:
        kw["threshold"] = "calibrated" if args.calibrate else "cached"
    if "kernel_config" in spec.build_kwargs:
        kw["kernel_config"] = "tuned" if args.tune else "cached"
    if args.packed is not None:
        kw["packed"] = args.packed
    if args.qshard is not None:
        kw["mode"] = _QSHARD_MODES[args.qshard]
    return kw


def _serve_mesh(args, spec: registry.EngineSpec):
    """(mesh, axis_names) for the engine — 2D (structure x batch) on demand.

    ``--qshard 2d`` factors the device count into the squarest (struct,
    qbatch) grid; everything else gets the default all-devices 1-D mesh.
    """
    if not spec.needs_mesh:
        return None, None
    ndev = len(jax.devices())
    if args.qshard == "2d" and ndev > 1:
        return make_mesh(factor_2d(ndev), ("struct", "qbatch")), ("struct", "qbatch")
    return registry.default_mesh()


def _block_on_state(state) -> None:
    for leaf in jax.tree_util.tree_leaves(state):
        if isinstance(leaf, jax.Array):
            leaf.block_until_ready()


def _run_oneshot(args, spec, state, x, rng) -> bool:
    total_q = 0
    last = None
    t0 = time.perf_counter()
    for _ in range(args.batches):
        l, r = make_queries(rng, args.n, args.batch, args.dist)
        idx, val = spec.query(state, l, r)
        last = (l, r, idx, val)
        total_q += args.batch
    jax.block_until_ready(last[2])
    t_serve = time.perf_counter() - t0

    l, r, idx, val = last
    k = min(args.verify, args.batch)
    gold = ref.rmq_ref(x, l[:k], r[:k])
    ok = (np.asarray(idx[:k]) == gold).all()
    mode = f" qshard={args.qshard}" if args.qshard else ""
    print(
        f"[{args.engine}{mode}] served {total_q} RMQs over n={args.n} "
        f"({args.dist} ranges) on {len(jax.devices())} device(s): "
        f"serve {t_serve*1e3:.1f} ms ({t_serve/total_q*1e9:.1f} ns/RMQ), "
        f"verify[{k}] {'OK' if ok else 'MISMATCH'}"
    )
    return bool(ok)


def _run_async(args, spec, state, x, plan, online=None) -> bool:
    cfg = ServeConfig(
        deadline_s=args.deadline_ms * 1e-3,
        max_batch=args.max_batch,
        max_pending=args.max_pending,
        workers=args.workers,
        n=args.n,
        adaptive_deadline=args.adaptive_deadline,
    )
    wb = build_mod.warmup_bounds(plan)
    # The process-wide registry so WAL/restore counters from a durable engine
    # land in the same snapshot; launch spans carry the resolved plan attrs.
    okw = dict(metrics=default_registry(), trace_attrs=_span_attrs(args.engine, plan))
    if online is not None:
        srv = RMQServer(online=online, config=cfg, warmup_bounds=wb, **okw)
    else:
        qfn = lambda l, r: spec.query(state, l, r)
        srv = RMQServer(qfn, cfg, warmup_bounds=wb, **okw)
    srv.warmup()  # compile every padded launch shape (per plan regime)
    # The oracle of the version serving starts from — a restored engine
    # continues its original timeline, so this need not be 0.
    base_vid = online.current_vid if online is not None else 0

    upd_futs = []

    def mutator():
        # Open-loop Poisson mutator: point writes every batch, a range fill
        # every 3rd, an append every 4th; overload rejections are dropped.
        mrng = np.random.default_rng(77)
        for i in range(args.mutate):
            if args.mutate_rate > 0:
                time.sleep(mrng.exponential(1.0 / args.mutate_rate))
            cur_n = online.n
            log = update_mod.DeltaLog()
            for _ in range(3):
                log.point(int(mrng.integers(0, cur_n)), float(mrng.random()))
            if i % 3 == 1 and cur_n > 2:
                a = int(mrng.integers(0, cur_n - 1))
                log.fill(a, min(a + 63, cur_n - 1), float(mrng.random()))
            if i % 4 == 3:
                log.append(mrng.random(32, dtype=np.float32))
            try:
                upd_futs.append((log, srv.submit_update(log)))
            except ServerOverloaded:
                pass

    with _metrics_dump(args.metrics_interval, srv.metrics.snapshot), srv:
        t0 = time.perf_counter()
        mut = None
        if online is not None and args.mutate:
            mut = threading.Thread(target=mutator, name="mutator")
            mut.start()
        per_client = run_poisson_clients(
            args.clients,
            args.requests,
            args.rate,
            lambda rng, c: make_queries(rng, args.n, args.req_batch, args.dist),
            srv.submit,
            seed=10_000,
        )
        if mut is not None:
            mut.join()
        done = []
        dropped = 0
        for out in per_client:
            for (l, r), fut in out:
                if fut is None:
                    dropped += 1
                else:
                    done.append((l, r, fut.result(timeout=300)))
        wall = time.perf_counter() - t0  # serving only: verification is below
    st = srv.stats()

    # Replay the delta stream on the host: one oracle array per published
    # version (submission order == publish order: single updater thread).
    oracles = {base_vid: np.asarray(x)}
    patched = rebuilt = 0
    if upd_futs:
        xm = np.asarray(x).copy()
        for log, fut in upd_futs:
            res = fut.result(timeout=300)
            xm = log.coalesce(xm.shape[0], xm.dtype).apply_numpy(xm)
            oracles[res.version] = xm.copy()
            patched += res.patched
            rebuilt += not res.patched

    served = len(done)
    mismatches = 0
    for l, r, res in done:
        ox = oracles[res.version if res.version is not None else base_vid]
        gold = ref.rmq_ref(ox, l, r)
        if not (np.array_equal(res.idx, gold) and np.array_equal(res.val, ox[gold])):
            mismatches += 1

    mode = f" qshard={args.qshard}" if args.qshard else ""
    print(
        f"[async {args.engine}{mode}] {args.clients} clients x {args.requests} reqs "
        f"x {args.req_batch} RMQs ({args.dist} ranges, {args.rate:g} req/s/client, "
        f"deadline {args.deadline_ms:g} ms) on {len(jax.devices())} device(s), "
        f"{wall*1e3:.0f} ms wall"
    )
    print(f"  {st.summary()}")
    if upd_futs:
        print(
            f"  mutate: {len(upd_futs)} update batches applied "
            f"({patched} patched, {rebuilt} rebuilt), n {args.n} -> {online.n}, "
            f"{len(oracles)} oracle versions"
        )
    print(
        f"  verify: {served - mismatches}/{served} requests bit-identical to the "
        f"oracle of their pinned version; dropped {dropped}"
    )
    ok = mismatches == 0 and served > 0
    if args.mutate:
        ok = ok and len(upd_futs) > 0
    return ok


def _run_fleet(args, spec, x) -> bool:
    """Serve through a replica fleet (serve.fleet): regime-routed front door,
    bounded-lag rollouts, per-version oracle verification — the multi-replica
    twin of ``_run_async``."""
    from repro.serve.fleet import FleetConfig, RMQFleet

    scfg = ServeConfig(
        deadline_s=args.deadline_ms * 1e-3,
        max_batch=args.max_batch,
        max_pending=args.max_pending,
        workers=args.workers,
        adaptive_deadline=args.adaptive_deadline,
        max_retries=4,
    )
    fcfg = FleetConfig(replicas=args.replicas, max_version_lag=args.max_lag, server=scfg)
    t0 = time.perf_counter()
    fleet = RMQFleet.build(
        args.engine,
        jnp.asarray(x),
        config=fcfg,
        durable_root=args.restore,
        **_build_kwargs(args, spec),
    )
    base_vid = fleet.head_vid
    fleet.warmup()
    print(
        f"[{args.engine} x{args.replicas}] fleet build+warmup "
        f"{(time.perf_counter() - t0)*1e3:.1f} ms (threshold {fleet.threshold}, "
        f"lag bound {fcfg.max_version_lag}, "
        f"affinities {list(fcfg.resolved_affinities())})"
    )

    upd_futs = []
    sess = fleet.session()

    def mutator():
        # Same open-loop Poisson mutator as the single-server path, but each
        # batch rolls out fleet-wide through the session (read-your-writes).
        mrng = np.random.default_rng(77)
        for i in range(args.mutate):
            if args.mutate_rate > 0:
                time.sleep(mrng.exponential(1.0 / args.mutate_rate))
            cur_n = fleet.head_n
            log = update_mod.DeltaLog()
            for _ in range(3):
                log.point(int(mrng.integers(0, cur_n)), float(mrng.random()))
            if i % 3 == 1 and cur_n > 2:
                a = int(mrng.integers(0, cur_n - 1))
                log.fill(a, min(a + 63, cur_n - 1), float(mrng.random()))
            if i % 4 == 3:
                log.append(mrng.random(32, dtype=np.float32))
            try:
                upd_futs.append((log, fleet.submit_update(log, session=sess)))
            except ServerOverloaded:
                pass

    with _metrics_dump(args.metrics_interval, fleet.metrics), fleet:
        t0 = time.perf_counter()
        mut = None
        if args.mutate:
            mut = threading.Thread(target=mutator, name="mutator")
            mut.start()
        per_client = run_poisson_clients(
            args.clients,
            args.requests,
            args.rate,
            lambda rng, c: make_queries(rng, args.n, args.req_batch, args.dist),
            fleet.submit,
            seed=10_000,
        )
        if mut is not None:
            mut.join()
        done = []
        dropped = 0
        for out in per_client:
            for (l, r), fut in out:
                if fut is None:
                    dropped += 1
                else:
                    done.append((l, r, fut.result(timeout=300)))
        settled = fleet.wait_settled(timeout=300)
        wall = time.perf_counter() - t0
        st = fleet.stats()

    # Per-version host oracles, exactly as _run_async: the fleet assigns vids
    # in submission order, so the replay below matches every replica.
    oracles = {base_vid: np.asarray(x)}
    patched = rebuilt = 0
    if upd_futs:
        xm = np.asarray(x).copy()
        for log, fut in upd_futs:
            res = fut.result(timeout=300)
            xm = log.coalesce(xm.shape[0], xm.dtype).apply_numpy(xm)
            oracles[res.version] = xm.copy()
            patched += res.patched
            rebuilt += not res.patched

    served = len(done)
    mismatches = 0
    for l, r, res in done:
        ox = oracles[res.version if res.version is not None else base_vid]
        gold = ref.rmq_ref(ox, l, r)
        if not (np.array_equal(res.idx, gold) and np.array_equal(res.val, ox[gold])):
            mismatches += 1

    print(
        f"[fleet {args.engine} x{args.replicas}] {args.clients} clients x "
        f"{args.requests} reqs x {args.req_batch} RMQs ({args.dist} ranges, "
        f"{args.rate:g} req/s/client) on {len(jax.devices())} device(s), "
        f"{wall*1e3:.0f} ms wall"
    )
    print(f"  {st.summary()}")
    if upd_futs:
        print(
            f"  mutate: {len(upd_futs)} rollouts ({patched} patched, {rebuilt} "
            f"rebuilt), n {args.n} -> {fleet.head_n}, settled={settled}, "
            f"session floor v{sess.last_vid}"
        )
    print(
        f"  verify: {served - mismatches}/{served} requests bit-identical to the "
        f"oracle of their pinned version; dropped {dropped}"
    )
    ok = mismatches == 0 and served > 0 and settled
    if args.mutate:
        ok = ok and len(upd_futs) > 0
    return ok


def _span_attrs(engine: str, plan) -> dict:
    """Static launch-span attrs derived from the resolved BuildPlan: the
    engine, packed layout, routing threshold, and kernel config every
    exported launch span should carry (DESIGN.md §14)."""
    attrs = {"engine": engine}
    meta = getattr(plan, "meta", None) or {}
    if meta.get("threshold") is not None:
        attrs["threshold"] = int(meta["threshold"])
    if meta.get("block_size") is not None:
        attrs["block_size"] = int(meta["block_size"])
    layout = meta.get("packed")
    attrs["layout"] = str(layout) if layout is not None else "unpacked"
    kcfg = meta.get("kernel_config")
    if kcfg is not None and hasattr(kcfg, "tile"):
        attrs["kernel_tile"] = int(kcfg.tile)
        attrs["fetch"] = str(kcfg.fetch)
        attrs["kernel_block_size"] = int(kcfg.block_size)
    return attrs


@contextlib.contextmanager
def _metrics_dump(interval, snapshot_fn):
    """Periodic one-line JSON dumps of ``snapshot_fn()`` every ``interval``
    seconds (daemon thread), plus a final dump on exit. No-op when
    ``interval`` is None."""
    if interval is None:
        yield
        return
    stop = threading.Event()

    def loop():
        while not stop.wait(interval):
            try:
                print("[metrics] " + json.dumps(snapshot_fn()))
            except Exception as e:  # a dump must never kill serving
                print(f"[metrics] dump failed: {e!r}")

    t = threading.Thread(target=loop, daemon=True, name="metrics-dump")
    t.start()
    try:
        yield
    finally:
        stop.set()
        t.join(interval + 1.0)
        print("[metrics] final " + json.dumps(snapshot_fn()))


def _export_trace(path: str, tracer, *, expect_requests: bool) -> bool:
    """Export the trace + self-verify request chains; False on a gap."""
    n = tracer.export(path)
    complete, problems = verify_request_chains(tracer.spans())
    extra = f", {tracer.dropped} spans dropped by ring buffer" if tracer.dropped else ""
    print(f"[trace] {n} spans -> {path} ({complete} complete request chains{extra})")
    ok = True
    if problems:
        for p in problems[:10]:
            print(f"[trace] INCOMPLETE: {p}")
        if len(problems) > 10:
            print(f"[trace] ... and {len(problems) - 10} more")
        ok = False
    if expect_requests and complete == 0:
        print("[trace] FAIL: no complete request chains recorded")
        ok = False
    return ok


def main(argv=None) -> None:
    ap = _parser()
    args = ap.parse_args(argv)
    spec = registry.get(args.engine)
    _validate(ap, args, spec)

    tracer = None
    if args.trace is not None:
        # Install globally BEFORE the build so build/update stage spans and
        # the serving layer all land in the same ring buffer.
        tracer = Tracer(enabled=True, capacity=1 << 17)
        set_tracer(tracer)
    try:
        ok = _run_modes(ap, args, spec)
    finally:
        if tracer is not None:
            set_tracer(None)
    if tracer is not None:
        served_requests = args.chaos is None and args.mode == "async"
        ok = _export_trace(args.trace, tracer, expect_requests=served_requests) and ok
    if not ok:
        raise SystemExit(1)


def _run_modes(ap, args, spec) -> bool:
    rng = np.random.default_rng(0)
    x = rng.random(args.n, dtype=np.float32)

    mesh, axes = _serve_mesh(args, spec)
    if args.chaos is not None:
        # Outside the mesh context on purpose: run_soak hands the mesh to the
        # engines explicitly (like `python -m repro.fault.chaos`). Activating
        # it globally switches jax 0.4.x sharded launches onto per-device
        # rendezvous collectives, and two pool workers launching concurrently
        # deadlock each other's rendezvous on the CPU backend.
        from repro.fault import chaos as chaos_mod

        report = chaos_mod.run_soak(
            engine=args.engine,
            n=args.n,
            seed=args.chaos,
            root=args.restore,
            workers=args.workers,
            mesh=mesh,
            axis_names=axes,
            log=print,
        )
        print(report.summary())
        return bool(report.ok)
    if args.replicas > 1:
        # Outside any global mesh context: the fleet carves its own disjoint
        # per-replica device groups (serve.fleet.RMQFleet.build).
        return _run_fleet(args, spec, x)
    ctx = set_mesh(mesh) if mesh is not None else contextlib.nullcontext()
    with ctx:
        if args.mutate:
            # Online build: the OnlineEngine plans + builds v0 and owns the
            # MVCC store; the server pins versions per launch. With
            # --restore, the engine is durable: WAL-journaled updates rooted
            # at DIR, resumed from its checkpoint + journal when one exists.
            t0 = time.perf_counter()
            if args.restore is not None:
                from repro import checkpoint as ckpt_mod
                from repro.fault import DurableEngine

                ckpt_dir = f"{args.restore}/ckpt"
                if ckpt_mod.latest_step(ckpt_dir) is not None:
                    online = DurableEngine.restore(args.restore, mesh=mesh, axis_names=axes)
                    x = np.asarray(online.store.current.x_host)
                    args.n = online.n
                    print(
                        f"[{args.engine}] restored from {args.restore}: "
                        f"version {online.current_vid}, seq {online.seq}, "
                        f"n={online.n} ({online.replayed} journal records replayed)"
                    )
                else:
                    online = DurableEngine.create(
                        args.engine,
                        jnp.asarray(x),
                        args.restore,
                        mesh=mesh,
                        axis_names=axes,
                        **_build_kwargs(args, spec),
                    )
            else:
                online = update_mod.make_online(
                    args.engine,
                    jnp.asarray(x),
                    mesh=mesh,
                    axis_names=axes,
                    **_build_kwargs(args, spec),
                )
            plan = online.plan
            _block_on_state(online.store.current.state)
            print(
                f"[{args.engine}] online build {((time.perf_counter() - t0))*1e3:.1f} ms "
                f"(n={args.n}, {plan.layout.num_shards} structure shard(s) x "
                f"{plan.layout.shard_len} cols, version {online.current_vid})"
            )
            return _run_async(args, spec, None, x, plan, online=online)

        # The staged BuildPlan resolves everything static (shard layout,
        # threshold, mode) before touching the array; async warmup reads the
        # plan's regimes instead of guessing.
        plan = registry.plan_for_serving(
            args.engine, args.n, mesh, axes, **_build_kwargs(args, spec)
        )
        t0 = time.perf_counter()
        state = build_mod.execute(plan, jnp.asarray(x))
        _block_on_state(state)
        kcfg = plan.meta.get("kernel_config")
        kmsg = (
            f", kernel tile={kcfg.tile} fetch={kcfg.fetch} bs={kcfg.block_size}"
            if kcfg is not None
            else ""
        )
        print(
            f"[{args.engine}] build {((time.perf_counter() - t0))*1e3:.1f} ms "
            f"(n={args.n}, {plan.layout.num_shards} structure shard(s) x "
            f"{plan.layout.shard_len} cols{kmsg})"
        )

        if args.mode == "oneshot":
            ok = _run_oneshot(args, spec, state, x, rng)
        else:
            ok = _run_async(args, spec, state, x, plan)
    return bool(ok)


if __name__ == "__main__":
    main()
