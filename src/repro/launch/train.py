"""Training launcher: real training on the available devices.

On this CPU container it trains reduced/small configs end-to-end (see
examples/train_lm.py for the ~100M run); on a real pod the same entry point
takes --arch/--shape and the production mesh.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b --smoke \
      --steps 50 --batch 8 --seq-len 128 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import logging

import jax

from repro.configs import get_config, reduce_for_smoke
from repro.launch.mesh import make_mesh, make_production_mesh, set_mesh
from repro.models import model as model_lib
from repro.optim import adamw
from repro.train import runner as runner_lib
from repro.train.steps import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", help="use the reduced config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--production-mesh", action="store_true")
    args = ap.parse_args()

    logging.basicConfig(level=logging.INFO, format="%(asctime)s %(name)s %(message)s")

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduce_for_smoke(cfg)

    if args.production_mesh:
        mesh = make_production_mesh()
    else:
        n = len(jax.devices())
        mesh = make_mesh((1, n), ("data", "model"))

    with set_mesh(mesh):
        params = model_lib.init_params(cfg, jax.random.PRNGKey(args.seed))
        opt_state = adamw.init(params)
        step_fn, info = make_train_step(
            cfg, mesh,
            lr_fn=adamw.cosine_schedule(args.lr, 10, args.steps),
            batch=args.batch, seq_len=args.seq_len,
            microbatches=args.microbatches,
        )
        from repro.train.steps import place_state

        params, opt_state = place_state(mesh, info, params, opt_state)
        rcfg = runner_lib.RunnerConfig(
            total_steps=args.steps, ckpt_dir=args.ckpt_dir,
            ckpt_every=args.ckpt_every, seed=args.seed,
        )
        report = runner_lib.run_training(
            step_fn, params, opt_state, cfg, args.batch, args.seq_len, rcfg
        )
    print(
        f"done: {report.steps_done} steps, first loss {report.losses[0]:.4f}, "
        f"last loss {report.losses[-1]:.4f}, restarts {report.restarts}"
    )


if __name__ == "__main__":
    main()
