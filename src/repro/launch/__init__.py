"""repro.launch — mesh, sharding rules, specs, dry-run, train/serve CLIs.

NOTE: ``repro.launch.dryrun`` must be executed as ``python -m
repro.launch.dryrun`` (it sets XLA_FLAGS before importing jax); it is
deliberately NOT imported here.
"""

from . import mesh, roofline, sharding, specs

__all__ = ["mesh", "roofline", "sharding", "specs"]
