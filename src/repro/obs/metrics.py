"""Process-wide metrics registry (DESIGN.md §14).

Three instrument kinds, all thread-safe and cheap enough for the serve hot
path (one lock acquire + a couple of scalar ops per observation):

* ``Counter`` — monotone float/int total (``inc``).
* ``Gauge``   — last-written value (``set``), e.g. the adaptive deadline or
  a replica's version lag.
* ``Histogram`` — fixed-bucket counts (for cheap export/merging) **plus** a
  bounded reservoir of raw observations so ``percentile(q)`` is *exact*
  (numpy linear interpolation, the same math ``ServeStats`` always used)
  as long as the observation count stays within the reservoir capacity —
  the default capacity (65536) comfortably covers every test/benchmark
  workload in this repo, so ``ServeStats`` snapshots rendered from the
  registry are bit-identical to the old ad-hoc list accumulation. Past
  capacity it degrades to uniform reservoir sampling (Algorithm R), never
  unbounded memory.

Instruments are named + labelled: ``registry.counter("serve_requests_total",
outcome="served")`` get-or-creates the child keyed by the sorted label set,
so the serving layer can cache instrument handles once and skip the dict
work per observation. ``MetricsRegistry.snapshot()`` renders everything to
one plain-dict document; ``merge_snapshots`` relabels and concatenates
per-replica snapshots into a fleet-level view at the front door.

Intentionally stdlib+numpy only; no imports from the rest of ``repro``.
"""

from __future__ import annotations

import bisect
import random
import threading
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "default_registry",
    "merge_snapshots",
]

# Default latency buckets (seconds): 100µs .. ~13s, factor ~2.
DEFAULT_BUCKETS = tuple(1e-4 * (2.0 ** k) for k in range(18))


def _label_key(labels: Mapping[str, str]) -> Tuple[Tuple[str, str], ...]:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


class Counter:
    __slots__ = ("labels", "_lock", "_value")

    def __init__(self, labels: Mapping[str, str]):
        self.labels = dict(labels)
        self._lock = threading.Lock()
        self._value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        with self._lock:
            self._value += amount

    @property
    def value(self) -> float:
        with self._lock:
            return self._value


class Gauge:
    __slots__ = ("labels", "_lock", "_value")

    def __init__(self, labels: Mapping[str, str]):
        self.labels = dict(labels)
        self._lock = threading.Lock()
        self._value = 0.0

    def set(self, value: float) -> None:
        with self._lock:
            self._value = float(value)

    def add(self, amount: float) -> None:
        with self._lock:
            self._value += amount

    @property
    def value(self) -> float:
        with self._lock:
            return self._value


class Histogram:
    """Fixed buckets + exact-until-capacity reservoir (module docstring)."""

    __slots__ = (
        "labels",
        "buckets",
        "capacity",
        "_lock",
        "_bucket_counts",
        "_count",
        "_sum",
        "_reservoir",
        "_rng",
    )

    def __init__(
        self,
        labels: Mapping[str, str],
        *,
        buckets: Sequence[float] = DEFAULT_BUCKETS,
        capacity: int = 65536,
    ):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.labels = dict(labels)
        self.buckets = tuple(sorted(float(b) for b in buckets))
        self.capacity = int(capacity)
        self._lock = threading.Lock()
        self._bucket_counts = [0] * (len(self.buckets) + 1)  # +inf overflow
        self._count = 0
        self._sum = 0.0
        self._reservoir: List[float] = []
        self._rng = random.Random(0x5EED)  # deterministic sampling past capacity

    def observe(self, value: float) -> None:
        value = float(value)
        idx = bisect.bisect_left(self.buckets, value)
        with self._lock:
            self._bucket_counts[idx] += 1
            self._count += 1
            self._sum += value
            if len(self._reservoir) < self.capacity:
                self._reservoir.append(value)
            else:
                j = self._rng.randrange(self._count)
                if j < self.capacity:
                    self._reservoir[j] = value

    @property
    def count(self) -> int:
        with self._lock:
            return self._count

    @property
    def sum(self) -> float:
        with self._lock:
            return self._sum

    def values(self) -> List[float]:
        """The reservoir contents (== all observations while exact)."""
        with self._lock:
            return list(self._reservoir)

    def percentile(self, q: float) -> float:
        """Exact-from-reservoir percentile (numpy linear interpolation);
        0.0 when empty, matching the old ServeStats convention."""
        with self._lock:
            if not self._reservoir:
                return 0.0
            return float(np.percentile(self._reservoir, q))

    def percentiles(self, qs: Sequence[float]) -> List[float]:
        with self._lock:
            if not self._reservoir:
                return [0.0 for _ in qs]
            return [float(v) for v in np.percentile(self._reservoir, list(qs))]

    def mean(self) -> float:
        with self._lock:
            return self._sum / self._count if self._count else 0.0


class MetricsRegistry:
    """Name+labels → instrument, with get-or-create semantics."""

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: Dict[Tuple[str, tuple], Counter] = {}
        self._gauges: Dict[Tuple[str, tuple], Gauge] = {}
        self._hists: Dict[Tuple[str, tuple], Histogram] = {}

    def counter(self, name: str, **labels: str) -> Counter:
        key = (name, _label_key(labels))
        with self._lock:
            inst = self._counters.get(key)
            if inst is None:
                inst = self._counters[key] = Counter(labels)
            return inst

    def gauge(self, name: str, **labels: str) -> Gauge:
        key = (name, _label_key(labels))
        with self._lock:
            inst = self._gauges.get(key)
            if inst is None:
                inst = self._gauges[key] = Gauge(labels)
            return inst

    def histogram(
        self,
        name: str,
        *,
        buckets: Sequence[float] = DEFAULT_BUCKETS,
        capacity: int = 65536,
        **labels: str,
    ) -> Histogram:
        key = (name, _label_key(labels))
        with self._lock:
            inst = self._hists.get(key)
            if inst is None:
                inst = self._hists[key] = Histogram(
                    labels, buckets=buckets, capacity=capacity
                )
            return inst

    # -- iteration / export --------------------------------------------------

    def counters(self) -> List[Tuple[str, Counter]]:
        with self._lock:
            return [(k[0], v) for k, v in self._counters.items()]

    def gauges(self) -> List[Tuple[str, Gauge]]:
        with self._lock:
            return [(k[0], v) for k, v in self._gauges.items()]

    def histograms(self) -> List[Tuple[str, Histogram]]:
        with self._lock:
            return [(k[0], v) for k, v in self._hists.items()]

    def counter_total(self, name: str, **labels: str) -> float:
        """Sum of all counter children of ``name`` whose labels are a
        superset of ``labels`` (empty labels = family total)."""
        want = set(_label_key(labels))
        total = 0.0
        for n, c in self.counters():
            if n == name and want <= set(_label_key(c.labels)):
                total += c.value
        return total

    def snapshot(self) -> dict:
        """Everything as one JSON-ready document (lists of labelled rows
        per family; histograms summarized, raw reservoirs omitted)."""
        doc: dict = {"counters": {}, "gauges": {}, "histograms": {}}
        for name, c in self.counters():
            doc["counters"].setdefault(name, []).append(
                {"labels": dict(c.labels), "value": c.value}
            )
        for name, g in self.gauges():
            doc["gauges"].setdefault(name, []).append(
                {"labels": dict(g.labels), "value": g.value}
            )
        for name, h in self.histograms():
            p50, p95, p99 = h.percentiles((50, 95, 99))
            doc["histograms"].setdefault(name, []).append(
                {
                    "labels": dict(h.labels),
                    "count": h.count,
                    "sum": h.sum,
                    "mean": h.mean(),
                    "p50": p50,
                    "p95": p95,
                    "p99": p99,
                    "buckets": {
                        "le": list(h.buckets),
                        "counts": list(h._bucket_counts),
                    },
                }
            )
        return doc


_DEFAULT = MetricsRegistry()
_DEFAULT_LOCK = threading.Lock()


def default_registry() -> MetricsRegistry:
    """The process-global registry (fault/durable counters live here)."""
    return _DEFAULT


def reset_default_registry() -> MetricsRegistry:
    """Swap in a fresh global registry (tests); returns the new one."""
    global _DEFAULT
    with _DEFAULT_LOCK:
        _DEFAULT = MetricsRegistry()
        return _DEFAULT


def merge_snapshots(
    snaps: Mapping[str, dict], *, label: str = "replica"
) -> dict:
    """Fleet-level aggregation: concatenate per-source snapshot rows,
    stamping each row's labels with ``label=<source key>``. Family totals
    then fall out of summing rows, and per-replica breakdowns survive."""
    out: dict = {"counters": {}, "gauges": {}, "histograms": {}}
    for src, snap in snaps.items():
        for kind in ("counters", "gauges", "histograms"):
            for name, rows in snap.get(kind, {}).items():
                for row in rows:
                    merged = dict(row)
                    merged["labels"] = {**row.get("labels", {}), label: str(src)}
                    out[kind].setdefault(name, []).append(merged)
    return out
