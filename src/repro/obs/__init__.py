"""Observability layer: span tracing + metrics registry (DESIGN.md §14).

``repro.obs.trace`` records request/update/build lifecycle spans into a
ring buffer and exports Chrome-trace JSON (open at https://ui.perfetto.dev);
``repro.obs.metrics`` is the process-wide counter/gauge/histogram registry
that ``ServeStats`` snapshots are rendered from. Both are dependency-free
w.r.t. the rest of ``repro`` so any layer may import them.
"""

from repro.obs.metrics import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    default_registry,
    merge_snapshots,
    reset_default_registry,
)
from repro.obs.trace import (
    NULL_TRACER,
    Span,
    Tracer,
    current_span,
    get_tracer,
    set_attr,
    set_tracer,
    verify_request_chains,
)

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "NULL_TRACER",
    "Span",
    "Tracer",
    "current_span",
    "default_registry",
    "get_tracer",
    "merge_snapshots",
    "reset_default_registry",
    "set_attr",
    "set_tracer",
    "verify_request_chains",
]
