"""Lightweight request-lifecycle span tracer (DESIGN.md §14).

A **span** is one named, timed unit of work: monotonic start/end stamps
(``time.perf_counter``), a process-unique id, an optional parent id, and a
small key/value attr dict. Spans form trees — the serving layer opens a
``request`` root per client request and hangs ``admission``/``queue``/
``resolve`` children off it, the batcher opens a ``flush`` root per
coalesced launch with ``coalesce``/``launch``/``scatter`` children, and the
build/update pipelines ride the ``core.build.run_stages`` sequencer so every
stage (``local_build``, ``apply_deltas``, ``publish``, ...) lands as a span
under whatever was current. Cross-thread parenting is explicit (pass
``parent=``); same-thread nesting is ambient via a ``contextvars`` current
span, which thread boundaries naturally reset.

Design constraints, in order:

1. **Zero cost when disabled.** The default global tracer is a shared
   disabled singleton: ``span()`` returns one reusable no-op context
   manager, ``start()`` returns one reusable no-op span, and neither path
   allocates (asserted by a tracemalloc probe in tests/test_obs.py). Hot
   paths gate attr-dict construction on ``tracer.enabled``.
2. **Bounded memory.** Finished spans land in a thread-safe ring buffer
   (``deque(maxlen=capacity)``): overflow drops the *oldest* spans, so a
   long soak keeps its newest history.
3. **Standard export.** ``to_chrome_trace()`` / ``export(path)`` emit the
   Chrome-trace JSON event format (``"X"`` complete events + ``"M"``
   thread-name metadata) that chrome://tracing and https://ui.perfetto.dev
   open directly; span/parent ids ride in ``args`` so the request chains
   survive the export.

``verify_request_chains`` is the acceptance-side consumer: it walks an
exported (or live) span set and checks that every successfully resolved
request has the complete admission→queue→resolve chain plus a linked flush
tree with launch (carrying engine/regime/layout/kernel attrs) and scatter.
check.sh's observability gate and ``launch/serve.py --trace`` both call it.
"""

from __future__ import annotations

import itertools
import json
import threading
from collections import deque
from contextvars import ContextVar
from time import perf_counter
from typing import Dict, Iterable, List, Optional, Tuple

__all__ = [
    "NULL_TRACER",
    "Span",
    "Tracer",
    "current_span",
    "get_tracer",
    "set_attr",
    "set_tracer",
    "verify_request_chains",
]

_ids = itertools.count(1)
_CURRENT: ContextVar[Optional["Span"]] = ContextVar("repro_obs_span", default=None)


class Span:
    """One timed unit of work. Mutable until finished; see module docstring."""

    __slots__ = ("name", "span_id", "parent_id", "t0", "t1", "attrs", "thread")

    def __init__(self, name: str, parent_id: Optional[int], attrs: Optional[dict]):
        self.name = name
        self.span_id = next(_ids)
        self.parent_id = parent_id
        self.t0 = perf_counter()
        self.t1: Optional[float] = None
        self.attrs: dict = attrs if attrs is not None else {}
        self.thread = threading.current_thread().name

    def set_attr(self, key: str, value) -> None:
        self.attrs[key] = value

    @property
    def duration_s(self) -> float:
        return (self.t1 if self.t1 is not None else perf_counter()) - self.t0

    def __repr__(self):  # pragma: no cover - debugging aid
        return f"Span({self.name!r}, id={self.span_id}, parent={self.parent_id})"


class _NoopSpan:
    """Shared do-nothing span: the disabled tracer hands out ONE of these."""

    __slots__ = ()
    name = ""
    span_id = 0
    parent_id = None
    t0 = 0.0
    t1 = 0.0
    attrs: dict = {}
    thread = ""
    duration_s = 0.0

    def set_attr(self, key: str, value) -> None:
        pass


_NOOP_SPAN = _NoopSpan()


class _NoopCtx:
    """Shared reusable no-op context manager (zero allocations per use)."""

    __slots__ = ()

    def __enter__(self) -> _NoopSpan:
        return _NOOP_SPAN

    def __exit__(self, *exc) -> bool:
        return False


_NOOP_CTX = _NoopCtx()


class _SpanCtx:
    """Context manager for one live span: finishes it and restores the
    ambient current span on exit (same-thread nesting)."""

    __slots__ = ("_tracer", "_span", "_token")

    def __init__(self, tracer: "Tracer", span: Span):
        self._tracer = tracer
        self._span = span
        self._token = None

    def __enter__(self) -> Span:
        self._token = _CURRENT.set(self._span)
        return self._span

    def __exit__(self, exc_type, exc, tb) -> bool:
        if exc_type is not None:
            self._span.attrs.setdefault("error", exc_type.__name__)
        _CURRENT.reset(self._token)
        self._tracer.finish(self._span)
        return False


class Tracer:
    """Thread-safe span recorder over a fixed-capacity ring buffer.

    ``enabled=False`` constructs the degenerate tracer every call site can
    keep unconditionally: all methods are no-ops that allocate nothing.
    """

    def __init__(self, *, enabled: bool = True, capacity: int = 65536):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.enabled = bool(enabled)
        self.capacity = int(capacity)
        self._lock = threading.Lock()
        self._buf: deque = deque(maxlen=self.capacity)
        self._dropped = 0
        self._t_epoch = perf_counter()  # export time origin

    # -- recording ----------------------------------------------------------

    def start(
        self, name: str, *, parent=None, attrs: Optional[dict] = None
    ) -> Span:
        """Begin a span (not yet in the buffer). ``parent`` is a Span, a span
        id, or None (= the ambient current span, if any)."""
        if not self.enabled:
            return _NOOP_SPAN
        if parent is None:
            cur = _CURRENT.get()
            pid = cur.span_id if cur is not None else None
        elif isinstance(parent, int):
            pid = parent or None
        else:
            pid = parent.span_id or None
        return Span(name, pid, attrs)

    def finish(self, span) -> None:
        """Stamp the end time and commit the span to the ring buffer."""
        if not self.enabled or span is _NOOP_SPAN:
            return
        if span.t1 is None:
            span.t1 = perf_counter()
        with self._lock:
            if len(self._buf) == self.capacity:
                self._dropped += 1
            self._buf.append(span)

    def span(self, name: str, *, parent=None, attrs: Optional[dict] = None):
        """Context manager: start + make-current + finish. Zero-alloc no-op
        when disabled (the shared context manager is reused)."""
        if not self.enabled:
            return _NOOP_CTX
        return _SpanCtx(self, self.start(name, parent=parent, attrs=attrs))

    def instant(self, name: str, *, parent=None, attrs: Optional[dict] = None) -> Span:
        """A zero-duration marker span, committed immediately."""
        if not self.enabled:
            return _NOOP_SPAN
        s = self.start(name, parent=parent, attrs=attrs)
        self.finish(s)
        return s

    # -- introspection / export ---------------------------------------------

    @property
    def dropped(self) -> int:
        """Spans evicted by ring-buffer overflow (newest are kept)."""
        with self._lock:
            return self._dropped

    def spans(self) -> List[Span]:
        """Snapshot of the buffered (finished) spans, oldest first."""
        with self._lock:
            return list(self._buf)

    def clear(self) -> None:
        with self._lock:
            self._buf.clear()
            self._dropped = 0

    def to_chrome_trace(self) -> dict:
        """The trace as a Chrome-trace/Perfetto JSON object (see module doc)."""
        spans = self.spans()
        tids: Dict[str, int] = {}
        events = []
        for s in spans:
            tid = tids.setdefault(s.thread, len(tids) + 1)
            args = {"span_id": s.span_id}
            if s.parent_id is not None:
                args["parent_id"] = s.parent_id
            for k, v in s.attrs.items():
                args[k] = v if isinstance(v, (int, float, str, bool, type(None))) else str(v)
            t1 = s.t1 if s.t1 is not None else s.t0
            events.append(
                {
                    "name": s.name,
                    "ph": "X",
                    "ts": (s.t0 - self._t_epoch) * 1e6,  # µs, monotonic origin
                    "dur": max(0.0, (t1 - s.t0) * 1e6),
                    "pid": 1,
                    "tid": tid,
                    "cat": "repro",
                    "args": args,
                }
            )
        for name, tid in tids.items():
            events.append(
                {
                    "name": "thread_name",
                    "ph": "M",
                    "pid": 1,
                    "tid": tid,
                    "args": {"name": name},
                }
            )
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def export(self, path: str) -> int:
        """Write the Chrome-trace JSON to ``path``; returns the span count."""
        doc = self.to_chrome_trace()
        with open(path, "w") as f:
            json.dump(doc, f)
        return sum(1 for e in doc["traceEvents"] if e["ph"] == "X")


NULL_TRACER = Tracer(enabled=False, capacity=1)
_GLOBAL = NULL_TRACER
_GLOBAL_LOCK = threading.Lock()


def get_tracer() -> Tracer:
    """The process-global tracer (the disabled singleton until configured)."""
    return _GLOBAL


def set_tracer(tracer: Optional[Tracer]) -> Tracer:
    """Install ``tracer`` globally (None restores the disabled singleton);
    returns the previous global so callers/tests can restore it."""
    global _GLOBAL
    with _GLOBAL_LOCK:
        prev = _GLOBAL
        _GLOBAL = tracer if tracer is not None else NULL_TRACER
        return prev


def current_span() -> Optional[Span]:
    """This context's ambient span (None outside any ``span()`` block)."""
    return _CURRENT.get()


def set_attr(key: str, value) -> None:
    """Annotate the ambient span, if any — the seam engine internals use
    (e.g. ``hybrid.dispatch_by_length`` stamping its regime split) without
    holding a tracer reference. No-op when nothing is current."""
    cur = _CURRENT.get()
    if cur is not None:
        cur.attrs[key] = value


# -- chain verification --------------------------------------------------------

# The per-request lifecycle contract (DESIGN.md §14): a resolved request span
# must carry these children, and its flush span these.
_REQUEST_CHILDREN = ("admission", "queue", "resolve")
_FLUSH_CHILDREN = ("launch", "scatter")
_LAUNCH_ATTRS = ("engine",)


def _spans_from_chrome(doc: dict) -> List[dict]:
    out = []
    for e in doc.get("traceEvents", ()):
        if e.get("ph") != "X":
            continue
        args = dict(e.get("args", {}))
        out.append(
            {
                "name": e["name"],
                "span_id": args.pop("span_id", None),
                "parent_id": args.pop("parent_id", None),
                "attrs": args,
            }
        )
    return out


def _normalize(spans) -> List[dict]:
    if isinstance(spans, dict):
        return _spans_from_chrome(spans)
    out = []
    for s in spans:
        if isinstance(s, dict):
            out.append(s)
        else:
            out.append(
                {
                    "name": s.name,
                    "span_id": s.span_id,
                    "parent_id": s.parent_id,
                    "attrs": dict(s.attrs),
                }
            )
    return out


def verify_request_chains(spans) -> Tuple[int, List[str]]:
    """Check every resolved request's span chain for completeness.

    ``spans`` is a list of ``Span``s, a list of dicts, or a parsed
    Chrome-trace document (``{"traceEvents": [...]}``). For each ``request``
    span whose ``resolve`` child carries ``outcome == "ok"``, require:

    * children named ``admission``, ``queue`` and ``resolve`` (no orphans);
    * a ``batch`` attr naming an exported ``flush`` span;
    * that flush span owning ``launch`` and ``scatter`` children, the launch
      carrying an ``engine`` attr (regime/layout/kernel attrs ride there).

    Returns ``(complete_count, problems)`` — ``problems`` is empty iff every
    resolved request has a complete chain.
    """
    rows = _normalize(spans)
    by_id = {r["span_id"]: r for r in rows if r["span_id"] is not None}
    kids: Dict[int, List[dict]] = {}
    for r in rows:
        pid = r.get("parent_id")
        if pid is not None:
            kids.setdefault(pid, []).append(r)

    complete = 0
    problems: List[str] = []
    for r in rows:
        if r["name"] != "request":
            continue
        rid = r["span_id"]
        names = {c["name"] for c in kids.get(rid, ())}
        resolve = next(
            (c for c in kids.get(rid, ()) if c["name"] == "resolve"), None
        )
        if resolve is None or resolve["attrs"].get("outcome") != "ok":
            continue  # failed/expired/closed requests need no full chain
        missing = [n for n in _REQUEST_CHILDREN if n not in names]
        if missing:
            problems.append(f"request {rid}: missing children {missing}")
            continue
        bid = r["attrs"].get("batch")
        flush = by_id.get(bid)
        if flush is None or flush["name"] != "flush":
            problems.append(f"request {rid}: batch attr {bid!r} is not a flush span")
            continue
        fnames = {c["name"] for c in kids.get(bid, ())}
        fmissing = [n for n in _FLUSH_CHILDREN if n not in fnames]
        if fmissing:
            problems.append(f"request {rid}: flush {bid} missing {fmissing}")
            continue
        launch = next(c for c in kids.get(bid, ()) if c["name"] == "launch")
        amissing = [a for a in _LAUNCH_ATTRS if a not in launch["attrs"]]
        if amissing:
            problems.append(f"request {rid}: launch missing attrs {amissing}")
            continue
        complete += 1
    return complete, problems
