"""Attention: GQA with RoPE, streaming (flash-style) softmax, KV cache.

``flash_attention`` never materializes the (Lq, Lk) score matrix: it scans
over KV chunks carrying the running max / normalizer / accumulator (the
standard online-softmax recurrence), which keeps activation memory O(L·chunk)
— required for the 32k prefill and 500k cells — and is also what a fused TPU
attention kernel computes, so the dry-run HLO reflects realistic traffic.

Sharding modes (set per-arch via ModelConfig.attn_shard; §Perf iteration 1):
  * "heads":  K/V are repeated to the full head count so every attention
    einsum carries an H dim divisible by the model axis — TP shards heads.
    (The grouped (kv, rep) einsum variant keeps HLO bytes minimal on one
    device but leaves kv=8 as the only shardable dim, which a 16-wide model
    axis cannot split — GSPMD then *replicates* the O(L^2) attention compute
    on every model-parallel device. Measured on granite-3-8b train_4k:
    4.5x flops/dev and 153GiB temp/dev. Head-repeat fixes both.)
  * "seq": sequence-parallel attention — Q rows are sharded over the model
    axis via sharding constraints; used when H is not divisible by the axis
    (qwen2 12H, internvl2 14H).

Matmuls run in the input dtype (bf16 in production) with f32 accumulation
(preferred_element_type), f32 softmax state — the TPU-native recipe.

Sliding-window and causal masks are generated per chunk pair on the fly;
``is_global`` may be a traced scalar so gemma3's 5:1 local:global pattern can
live inside a scan over stacked layers.
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

__all__ = ["flash_attention", "decode_attention", "KVCache"]

_NEG = -1e30


class KVCache(NamedTuple):
    k: jax.Array  # (num_layers, B, S, KV, hd)
    v: jax.Array  # (num_layers, B, S, KV, hd)
    length: jax.Array  # () int32 — tokens currently valid


def _mask(q_pos, k_pos, *, causal: bool, window: int, is_global, limit):
    """(Lq, Lk) boolean mask for one chunk pair; window==0 means full."""
    m = k_pos[None, :] < limit
    if causal:
        m &= q_pos[:, None] >= k_pos[None, :]
    if window:
        in_win = (q_pos[:, None] - k_pos[None, :]) < window
        if is_global is None:
            m &= in_win
        else:  # traced per-layer flag: select full vs local arithmetically
            m &= in_win | jnp.asarray(is_global, bool)
    return m


def _constrain(x, spec):
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec)


def flash_attention(
    q: jax.Array,  # (B, Lq, H, hd)
    k: jax.Array,  # (B, Lk, KV, hd)
    v: jax.Array,  # (B, Lk, KV, hd)
    *,
    causal: bool = True,
    window: int = 0,
    is_global=None,
    q_offset: int = 0,
    kv_chunk: int = 1024,
    kv_valid: jax.Array | None = None,
    unroll: bool = False,
    attn_shard: str = "heads",
    dp_axes: tuple = (),
    model_axis: str = "",
) -> jax.Array:
    """Online-softmax attention. Returns (B, Lq, H, hd).

    q_offset: position of q[0] relative to k[0] (for prefill continuation).
    kv_valid: optional () int — keys at positions >= kv_valid are masked
      (used when the cache is partially filled).
    """
    b, lq, h, hd = q.shape
    lk, kv = k.shape[1], k.shape[2]
    rep = h // kv
    scale = 1.0 / math.sqrt(hd)
    cdt = q.dtype

    kv_chunk = min(kv_chunk, lk)
    pad = (-lk) % kv_chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nk = (lk + pad) // kv_chunk
    # repeat K/V to full heads: every einsum then has an H dim for TP
    kc = jnp.repeat(k.reshape(b, nk, kv_chunk, kv, hd), rep, axis=3)
    vc = jnp.repeat(v.reshape(b, nk, kv_chunk, kv, hd), rep, axis=3)
    kc = kc.transpose(1, 0, 2, 3, 4)  # (nk, B, C, H, hd)
    vc = vc.transpose(1, 0, 2, 3, 4)

    q = (q * jnp.asarray(scale, cdt)).astype(cdt)
    q_spec = None
    if attn_shard == "seq" and model_axis:
        q_spec = P(dp_axes or None, model_axis, None, None)  # shard Lq
        q = _constrain(q, q_spec)
    q_pos = q_offset + jnp.arange(lq, dtype=jnp.int32)
    limit = jnp.asarray(lk if kv_valid is None else kv_valid, jnp.int32)

    def body(carry, chunk):
        m, l, acc = carry  # (B, H, Lq), (B, H, Lq), (B, H, Lq, hd)
        kj, vj, j = chunk  # kj/vj: (B, C, H, hd)
        k_pos = j * kv_chunk + jnp.arange(kv_chunk, dtype=jnp.int32)
        s = jnp.einsum("bqhd,bjhd->bhqj", q, kj, preferred_element_type=jnp.float32)
        msk = _mask(q_pos, k_pos, causal=causal, window=window, is_global=is_global, limit=limit)
        s = jnp.where(msk[None, None], s, _NEG)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l = l * alpha + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bhqj,bjhd->bhqd", p.astype(cdt), vj, preferred_element_type=jnp.float32)
        acc = acc * alpha[..., None] + pv
        return (m_new, l, acc), None

    m0 = jnp.full((b, h, lq), _NEG, jnp.float32)
    l0 = jnp.zeros((b, h, lq), jnp.float32)
    a0 = jnp.zeros((b, h, lq, hd), jnp.float32)
    if unroll:  # cost-model mode: XLA counts while bodies once (dryrun.py)
        carry = (m0, l0, a0)
        for j in range(nk):
            carry, _ = body(carry, (kc[j], vc[j], jnp.int32(j)))
        m, l, acc = carry
    else:
        # checkpoint the chunk body: differentiating a plain scan would stack
        # the (nk, B, H, Lq, C) score/prob chunks for the backward pass
        # (measured: 2GiB f32 + 1GiB bf16 per layer at 4k); recompute-per-
        # chunk is exactly what a fused flash backward does on real hardware
        body_ck = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
        (m, l, acc), _ = jax.lax.scan(body_ck, (m0, l0, a0), (kc, vc, jnp.arange(nk)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]  # (B, H, Lq, hd)
    out = out.transpose(0, 2, 1, 3)
    if q_spec is not None:
        out = _constrain(out, q_spec)
    return out.astype(cdt)


def decode_attention(
    q: jax.Array,  # (B, 1, H, hd)
    cache_k: jax.Array,  # (B, S, KV, hd)
    cache_v: jax.Array,
    length: jax.Array,  # () int32 — valid cache entries (q attends to < length)
    *,
    window: int = 0,
    is_global=None,
) -> jax.Array:
    """Single-token attention against a cache. Returns (B, 1, H, hd).

    Uses the grouped (kv, rep) form: decode is cache-bandwidth-bound and the
    cache shards over its sequence dim (launch/sharding.py), so the einsums
    contract over the sharded S dim and GSPMD reduces partial softmax stats —
    no head-dim sharding needed, no KV repeat traffic.
    """
    b, _, h, hd = q.shape
    s_len, kv = cache_k.shape[1], cache_k.shape[2]
    rep = h // kv
    scale = 1.0 / math.sqrt(hd)
    cdt = q.dtype
    qg = (q * jnp.asarray(scale, cdt)).reshape(b, 1, kv, rep, hd)
    s = jnp.einsum("bqkrd,bskd->bkrqs", qg, cache_k.astype(cdt), preferred_element_type=jnp.float32)
    k_pos = jnp.arange(s_len, dtype=jnp.int32)
    mask = k_pos < length
    if window:
        in_win = (length - 1 - k_pos) < window
        if is_global is None:
            mask &= in_win
        else:
            mask &= in_win | jnp.asarray(is_global, bool)
    s = jnp.where(mask[None, None, None, None], s, _NEG)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum(
        "bkrqs,bskd->bkrqd", p.astype(cdt), cache_v.astype(cdt),
        preferred_element_type=jnp.float32,
    )
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, 1, h, hd)
    return out.astype(cdt)
