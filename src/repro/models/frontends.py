"""Modality frontend STUBS for [vlm]/[audio] architectures.

Per the assignment, these entries specify the transformer BACKBONE only; the
modality frontend provides precomputed patch/frame embeddings. These helpers
generate deterministic synthetic embeddings with the right shapes/dtypes for
tests/examples, and the matching ShapeDtypeStructs for the dry-run.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["synthetic_embeddings", "embedding_spec"]


def synthetic_embeddings(cfg, batch: int, seq_len: int, seed: int = 0) -> jax.Array:
    """Stand-in for InternViT patch embeddings / EnCodec frame embeddings."""
    key = jax.random.PRNGKey(seed)
    return jax.random.normal(key, (batch, seq_len, cfg.d_model), jnp.float32).astype(cfg.dtype)


def embedding_spec(cfg, batch: int, seq_len: int) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct((batch, seq_len, cfg.d_model), cfg.dtype)
