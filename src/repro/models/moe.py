"""Mixture-of-Experts: token-choice top-k routing with capacity, GShard-style.

Structure follows GShard/GLaM: tokens are split into G groups (G = the
data-parallel shard count, injected by the step builder), each group routes
its own tokens into per-group expert capacity C_g, and the expert FFN runs
as a batched (G, E, C_g) einsum. Sharding: G over the data axes, E over the
model axis (arctic 128/16; grok's 8 experts can't split a 16-wide axis, so E
stays whole and capacity takes the model axis instead). All dispatch math
(sort, counts, scatter) is per group with explicit leading-G batched ops, so
GSPMD never needs a cross-shard scatter — a measured alternative (global
capacity buffers) cost 330GiB/dev in resharding temps; this layout avoids it.

Buffers are O(T·k + G·E·C_g·D) — what production TPU MoE systems ship; the
(tokens, experts, capacity) one-hot tensor is never materialized.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

__all__ = ["moe_ffn", "MoEOutput"]


class MoEOutput(NamedTuple):
    y: jax.Array  # (T, D)
    aux_loss: jax.Array  # () switch-style load-balance loss
    dropped_frac: jax.Array  # () fraction of routed assignments dropped


def _c(x, spec):
    return x if spec is None else jax.lax.with_sharding_constraint(x, spec)


def moe_ffn(
    x: jax.Array,  # (T, D) token embeddings (flattened batch*seq)
    router_w: jax.Array,  # (D, E)
    w_gate: jax.Array,  # (E, D, F)
    w_up: jax.Array,  # (E, D, F)
    w_down: jax.Array,  # (E, F, D)
    *,
    top_k: int,
    capacity_factor: float = 1.25,
    num_groups: int = 1,
    group_axes: tuple = (),  # mesh axes the group dim shards over (DP)
    ep_axis: str | None = None,  # model axis when E divides by it
    cap_axis: str | None = None,  # else capacity takes the model axis
) -> MoEOutput:
    t, d = x.shape
    e = router_w.shape[1]
    g = num_groups if (num_groups and t % num_groups == 0) else 1
    tg = t // g
    cap = max(int(capacity_factor * top_k * tg / e), top_k, 1)
    tk = tg * top_k

    gspec = tuple(group_axes) or None
    tok_spec = P(gspec, None, None) if group_axes else None
    buf_spec = P(gspec, ep_axis, cap_axis, None) if (group_axes or ep_axis or cap_axis) else None

    xg = _c(x.reshape(g, tg, d), tok_spec)

    # --- routing -----------------------------------------------------------
    logits = jnp.einsum("gtd,de->gte", xg.astype(jnp.float32), router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate, expert = jax.lax.top_k(probs, top_k)  # (G, Tg, k)
    gate = gate / jnp.maximum(jnp.sum(gate, axis=-1, keepdims=True), 1e-9)

    # Switch-style aux loss: E * sum_e fraction_routed_e * mean_prob_e.
    gi = jnp.arange(g, dtype=jnp.int32)[:, None]
    counts1 = jnp.zeros((g, e), jnp.float32).at[gi, expert[:, :, 0]].add(1.0)
    fe = counts1 / tg
    pe = jnp.mean(probs, axis=1)  # (G, E)
    aux = e * jnp.sum(fe * pe, axis=-1)  # (G,)

    # --- capacity positions via stable sort (earlier tokens win slots) ------
    flat_e = expert.reshape(g, tk)
    order = jnp.argsort(flat_e, axis=-1, stable=True)
    counts = jnp.zeros((g, e), jnp.int32).at[gi, flat_e].add(1)
    starts = jnp.cumsum(counts, axis=-1) - counts  # exclusive prefix (G, E)
    sorted_e = jnp.take_along_axis(flat_e, order, axis=-1)
    pos_sorted = (
        jnp.arange(tk, dtype=jnp.int32)[None, :]
        - jnp.take_along_axis(starts, sorted_e, axis=-1).astype(jnp.int32)
    )
    pos = jnp.zeros((g, tk), jnp.int32).at[gi, order].set(pos_sorted)
    keep = pos < cap
    slot = jnp.where(keep, pos, cap)  # cap == out-of-range -> dropped

    # --- dispatch: (G, E, C, D) expert input buffers -------------------------
    tok_id = jnp.repeat(jnp.arange(tg, dtype=jnp.int32), top_k)[None, :]  # (1, TK)
    src = jnp.where(
        keep[..., None], jnp.take_along_axis(xg, jnp.broadcast_to(tok_id, (g, tk))[..., None], axis=1), 0
    ).astype(x.dtype)
    # Scatter locally per group (buffer G-sharded only — a runtime-indexed
    # scatter into an E-sharded operand would force GSPMD to replicate it),
    # THEN reshard to the (G, E) expert layout: that single reshard IS the
    # GShard dispatch all-to-all, moving exactly the routed token bytes.
    local_spec = P(gspec, None, None, None) if group_axes else None
    xin = jnp.zeros((g, e, cap, d), x.dtype)
    xin = _c(xin.at[gi, flat_e, slot].set(src, mode="drop"), local_spec)
    xin = _c(xin, buf_spec)

    # --- expert FFN (batched over groups and experts) ------------------------
    g_act = _c(jnp.einsum("gecd,edf->gecf", xin, w_gate.astype(x.dtype)), buf_spec)
    u_act = _c(jnp.einsum("gecd,edf->gecf", xin, w_up.astype(x.dtype)), buf_spec)
    yout = _c(
        jnp.einsum("gecf,efd->gecd", jax.nn.silu(g_act) * u_act, w_down.astype(x.dtype)),
        buf_spec,
    )

    # --- combine --------------------------------------------------------------
    yout = _c(yout, local_spec)  # return all-to-all before the local gather
    slot_c = jnp.clip(slot, 0, cap - 1)
    gathered = yout[gi, flat_e, slot_c]  # (G, TK, D)
    w = jnp.where(keep, gate.reshape(g, tk), 0.0).astype(x.dtype)
    y = jnp.sum((gathered * w[..., None]).reshape(g, tg, top_k, d), axis=2)
    y = _c(y, tok_spec)

    dropped = 1.0 - jnp.mean(keep.astype(jnp.float32))
    return MoEOutput(y=y.reshape(t, d), aux_loss=jnp.mean(aux), dropped_frac=dropped)
