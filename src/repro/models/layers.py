"""Shared neural-net building blocks (pure functional JAX).

Conventions:
  * params are plain nested dicts of jax.Arrays;
  * activations run in ``cfg.dtype`` (bf16 on TPU), accumulations/norms in f32;
  * weights are stored as flat 2-D matrices where possible so tensor-parallel
    sharding works for any head count (DESIGN.md §4).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "rms_norm",
    "dense",
    "swiglu",
    "embed",
    "unembed",
    "rope",
    "softmax_cross_entropy",
]


def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + weight.astype(jnp.float32))).astype(dtype)


def dense(x: jax.Array, w: jax.Array, b: jax.Array | None = None) -> jax.Array:
    y = jnp.einsum("...d,df->...f", x, w.astype(x.dtype))
    if b is not None:
        y = y + b.astype(y.dtype)
    return y


def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array, w_down: jax.Array) -> jax.Array:
    g = dense(x, w_gate)
    u = dense(x, w_up)
    return dense(jax.nn.silu(g) * u, w_down)


def embed(tokens: jax.Array, table: jax.Array, dtype) -> jax.Array:
    return jnp.take(table, tokens, axis=0).astype(dtype)


def unembed(x: jax.Array, table: jax.Array) -> jax.Array:
    """Project to vocab logits (f32 for a stable loss/softmax)."""
    return jnp.einsum("...d,vd->...v", x.astype(jnp.float32), table.astype(jnp.float32))


def _rope_angles(positions: jax.Array, head_dim: int, theta: float) -> tuple[jax.Array, jax.Array]:
    half = head_dim // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freq  # (..., L, half)
    return jnp.cos(ang), jnp.sin(ang)


def rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0) -> jax.Array:
    """Rotary embedding. x: (B, L, H, hd); positions: (B, L) or (L,)."""
    head_dim = x.shape[-1]
    cos, sin = _rope_angles(positions, head_dim, theta)  # (B, L, half)
    cos = cos[..., None, :]  # (B, L, 1, half)
    sin = sin[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def softmax_cross_entropy(logits: jax.Array, labels: jax.Array, vocab_size: int) -> jax.Array:
    """Mean token cross-entropy. logits f32 (B, L, Vpad); labels int (B, L).

    Padded vocab entries never receive probability mass because the label ids
    are < vocab_size and padded logits are finite; we mask them to -inf.
    """
    if logits.shape[-1] > vocab_size:
        pad = logits.shape[-1] - vocab_size
        neg = jnp.full((pad,), -1e30, dtype=logits.dtype)
        logits = jnp.concatenate(
            [logits[..., :vocab_size], jnp.broadcast_to(neg, (*logits.shape[:-1], pad))],
            axis=-1,
        )
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)
