"""Mamba2 (SSD — state-space duality, arXiv:2405.21060), chunked form.

Train/prefill runs the block-decomposed SSD algorithm: intra-chunk
"attention-like" masked matmuls (MXU-friendly) plus an inter-chunk recurrence
carried by ``lax.scan`` — O(L·Q) compute with O(1) state, which is what makes
the 500k-token cells tractable (DESIGN.md §5).

Decode carries (conv window, SSM state) per layer — the attention-free
analogue of a KV cache with O(1) memory per step.

Group convention: n_groups=1 (B/C shared across heads), matching mamba2-2.7b.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .layers import rms_norm

__all__ = ["SSMParamsSpec", "ssm_forward", "ssm_decode_step", "SSMState", "ssm_dims"]


class SSMState(NamedTuple):
    conv: jax.Array  # (B, K-1, conv_dim) last inputs of the causal conv
    ssd: jax.Array  # (B, H, P, N) state matrix


def ssm_dims(d_model: int, expand: int, headdim: int, state: int, conv_k: int):
    d_inner = expand * d_model
    nheads = d_inner // headdim
    conv_dim = d_inner + 2 * state  # x + B + C (G=1)
    d_in_proj = 2 * d_inner + 2 * state + nheads  # z, xBC, dt
    return dict(
        d_inner=d_inner, nheads=nheads, conv_dim=conv_dim, d_in_proj=d_in_proj,
        headdim=headdim, state=state, conv_k=conv_k,
    )


class SSMParamsSpec(NamedTuple):
    """Per-layer parameter shapes (used by the init code in model.py)."""

    in_proj: tuple  # (D, d_in_proj)
    conv_w: tuple  # (K, conv_dim)
    conv_b: tuple  # (conv_dim,)
    a_log: tuple  # (H,)
    d_skip: tuple  # (H,)
    dt_bias: tuple  # (H,)
    norm_w: tuple  # (d_inner,)
    out_proj: tuple  # (d_inner, D)


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv. x: (B, L, C), w: (K, C)."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    # (B, L, C) with feature_group_count=C; kernel (K, 1, C)
    out = jax.lax.conv_general_dilated(
        xp, w[:, None, :].astype(x.dtype),
        window_strides=(1,), padding="VALID",
        dimension_numbers=("NHC", "HIO", "NHC"),
        feature_group_count=x.shape[-1],
    )
    return out + b.astype(x.dtype)


def _segsum_chunk(dA: jax.Array) -> jax.Array:
    """exp-safe segment sums within a chunk: out[..., i, j] = sum_{j<t<=i} dA_t."""
    q = dA.shape[-1]
    cs = jnp.cumsum(dA, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]  # (..., i, j)
    mask = jnp.tril(jnp.ones((q, q), bool))
    return jnp.where(mask, diff, -jnp.inf)


def ssm_forward(p: dict, u: jax.Array, cfg, *, return_state: bool = False):
    """One Mamba2 mixer. u: (B, L, D) -> (B, L, D) (+ final SSMState)."""
    dims = ssm_dims(cfg.d_model, cfg.ssm_expand, cfg.ssm_headdim, cfg.ssm_state, cfg.ssm_conv)
    d_inner, h, n, pdim = dims["d_inner"], dims["nheads"], dims["state"], dims["headdim"]
    b, l_real, _ = u.shape
    q = min(cfg.ssm_chunk, l_real)
    pad = (-l_real) % q
    if pad:
        u = jnp.pad(u, ((0, 0), (0, pad), (0, 0)))
    l = l_real + pad
    nc = l // q

    zxbcdt = jnp.einsum("bld,de->ble", u, p["in_proj"].astype(u.dtype))
    z = zxbcdt[..., :d_inner]
    xbc = zxbcdt[..., d_inner : d_inner + dims["conv_dim"]]
    dt = zxbcdt[..., -h:]

    xbc = jax.nn.silu(_causal_conv(xbc, p["conv_w"], p["conv_b"]))
    x = xbc[..., :d_inner].reshape(b, l, h, pdim)
    bmat = xbc[..., d_inner : d_inner + n]  # (B, L, N) — G=1
    cmat = xbc[..., d_inner + n :]  # (B, L, N)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))  # (B,L,H)
    if pad:
        # padded positions must be identity state updates: dt=0 => dA=0,
        # zero state contribution, zero output weight
        valid = (jnp.arange(l) < l_real)[None, :, None]
        dt = jnp.where(valid, dt, 0.0)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))  # (H,)
    da = dt * a  # (B, L, H)

    # --- chunked SSD ------------------------------------------------------
    xc = x.reshape(b, nc, q, h, pdim).astype(jnp.float32)
    bc = bmat.reshape(b, nc, q, n).astype(jnp.float32)
    cc = cmat.reshape(b, nc, q, n).astype(jnp.float32)
    dac = da.reshape(b, nc, q, h).transpose(0, 1, 3, 2)  # (B, NC, H, Q)
    dtc = dt.reshape(b, nc, q, h)

    # intra-chunk: y[i] = sum_{j<=i} C_i.B_j exp(sum dA (j,i]) dt_j x_j
    seg = _segsum_chunk(dac)  # (B, NC, H, Q, Q)
    lmat = jnp.exp(seg)
    scores = jnp.einsum("bcin,bcjn->bcij", cc, bc)  # (B, NC, Q, Q)
    m = scores[:, :, None] * lmat  # (B, NC, H, Q, Q)
    y_diag = jnp.einsum("bchij,bcjh,bcjhp->bcihp", m, dtc, xc)

    # chunk states: S_c = sum_j exp(sum dA (j, Q]) dt_j B_j x_j^T
    cum = jnp.cumsum(dac, axis=-1)  # (B, NC, H, Q)
    total = cum[..., -1:]
    decay_out = jnp.exp(total - cum)  # (B, NC, H, Q)
    states = jnp.einsum("bcjn,bchj,bcjh,bcjhp->bchpn", bc, decay_out, dtc, xc)

    # inter-chunk recurrence
    chunk_decay = jnp.exp(total[..., 0])  # (B, NC, H)

    def body(s, inp):
        st_c, dec_c = inp  # (B, H, P, N), (B, H)
        s_new = s * dec_c[..., None, None] + st_c
        return s_new, s  # emit state BEFORE this chunk

    s0 = jnp.zeros((b, h, pdim, n), jnp.float32)
    st_seq = states.transpose(1, 0, 2, 3, 4)
    dec_seq = chunk_decay.transpose(1, 0, 2)
    if getattr(cfg, "ssm_unroll", False):  # cost-model mode (see dryrun.py)
        s = s0
        prevs = []
        for c in range(nc):
            s, emitted = body(s, (st_seq[c], dec_seq[c]))
            prevs.append(emitted)
        s_final, prev = s, jnp.stack(prevs)
    else:
        s_final, prev = jax.lax.scan(body, s0, (st_seq, dec_seq))
    prev = prev.transpose(1, 0, 2, 3, 4)  # (B, NC, H, P, N)

    decay_in = jnp.exp(cum)  # (B, NC, H, Q)
    y_off = jnp.einsum("bcin,bchpn,bchi->bcihp", cc, prev, decay_in)

    y = (y_diag + y_off).reshape(b, l, h, pdim)
    y = y + xc.reshape(b, l, h, pdim) * p["d_skip"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(b, l, d_inner).astype(u.dtype)[:, :l_real]

    # gated RMSNorm then output projection
    y = rms_norm(y * jax.nn.silu(z[:, :l_real]), p["norm_w"])
    out = jnp.einsum("ble,ed->bld", y, p["out_proj"].astype(u.dtype))

    if not return_state:
        return out
    km1 = dims["conv_k"] - 1
    raw_xbc = zxbcdt[..., d_inner : d_inner + dims["conv_dim"]]
    conv_state = raw_xbc[:, l_real - km1 : l_real, :]
    return out, SSMState(conv=conv_state, ssd=s_final)


def ssm_decode_step(p: dict, u_t: jax.Array, state: SSMState, cfg):
    """One-token step. u_t: (B, D) -> (B, D), new state."""
    dims = ssm_dims(cfg.d_model, cfg.ssm_expand, cfg.ssm_headdim, cfg.ssm_state, cfg.ssm_conv)
    d_inner, h, n, pdim = dims["d_inner"], dims["nheads"], dims["state"], dims["headdim"]
    b = u_t.shape[0]

    zxbcdt = jnp.einsum("bd,de->be", u_t, p["in_proj"].astype(u_t.dtype))
    z = zxbcdt[..., :d_inner]
    xbc_t = zxbcdt[..., d_inner : d_inner + dims["conv_dim"]]
    dt = zxbcdt[..., -h:]

    # conv over the cached window
    window = jnp.concatenate([state.conv, xbc_t[:, None, :]], axis=1)  # (B, K, C)
    conv_out = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32), p["conv_w"].astype(jnp.float32))
    xbc = jax.nn.silu(conv_out + p["conv_b"].astype(jnp.float32))
    x = xbc[..., :d_inner].reshape(b, h, pdim)
    bvec = xbc[..., d_inner : d_inner + n]
    cvec = xbc[..., d_inner + n :]

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))  # (B,H)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    da = jnp.exp(dt * a)  # (B, H)

    s_new = state.ssd * da[..., None, None] + jnp.einsum(
        "bh,bhp,bn->bhpn", dt, x, bvec
    )
    y = jnp.einsum("bhpn,bn->bhp", s_new, cvec) + x * p["d_skip"].astype(jnp.float32)[None, :, None]
    y = y.reshape(b, d_inner).astype(u_t.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm_w"])
    out = jnp.einsum("be,ed->bd", y, p["out_proj"].astype(u_t.dtype))
    return out, SSMState(conv=window[:, 1:, :], ssd=s_new)
