"""repro.models — LM substrate: layers, attention, MoE, SSM, hybrid stacks."""

from . import attention, frontends, layers, model, moe, ssm, transformer
from .transformer import Cache

__all__ = [
    "attention",
    "frontends",
    "layers",
    "model",
    "moe",
    "ssm",
    "transformer",
    "Cache",
]
